"""End-to-end Gemma-analogue assembly (paper §VI): REAL task execution with
measured durations, FNN cost model trained on one configuration and applied
to another, CCM-LB balancing, wave-based homing.

  PYTHONPATH=src python examples/assembly_e2e.py
"""
import numpy as np

from repro.assembly import build_problem, run_assembly_comparison
from repro.assembly.execute import measure_durations
from repro.costmodel import train_cost_model
from repro.costmodel.train import evaluate_cost_model


def main():
    # --- collect training data on a small configuration (measured!) --------
    print("measuring task durations on the training configuration ...")
    train_p = build_problem(768, 4, task_limit_u=32, seed=1)
    feats = train_p.features()
    durs = measure_durations(train_p, repeats=2)
    print(f"  {train_p.num_tasks} tasks, durations "
          f"{durs.min() * 1e6:.0f}us .. {durs.max() * 1e6:.0f}us")

    print("training the FNN cost model (4x200, BN, dropout, LeakyReLU, "
          "AdamW, under-penalized RMSE, Alg.1 reduction) ...")
    model, hist = train_cost_model(feats, durs, epochs=120, batch_size=128,
                                   alpha=0.3,
                                   reduce_to=int(0.7 * len(durs)), seed=0)
    m = evaluate_cost_model(model, feats, durs)
    print(f"  train-set rel-err (median): {m['rel_err_median']:.2%}, "
          f"over-predict fraction: {m['over_predict_frac']:.2f}")

    # --- balance a larger, different configuration with predictions --------
    print("balancing the target configuration with PREDICTED durations ...")
    run = run_assembly_comparison(n_unknowns=1536, num_ranks=8,
                                  durations="measured", cost_model=model,
                                  seed=2, task_limit_u=32)
    homing_t = run.homing.est_time_s if run.homing else 0.0
    print(f"  A  baseline (no overdecomposition) : {run.makespan_baseline:.4f}s")
    print(f"  B  overdecomposed, home layout     : "
          f"{run.makespan_overdecomposed:.4f}s "
          f"({run.speedup_overdecomposed:.2f}x)")
    print(f"  C  + CCM-LB (+homing {homing_t * 1e3:.2f}ms)   : "
          f"{run.makespan_ccmlb:.4f}s ({run.speedup_ccmlb:.2f}x)")
    print(f"  imbalance {run.imbalance_before:.3f} -> "
          f"{run.imbalance_after:.3f}; off-home slab copies: "
          f"{run.n_off_home_ranks}; homing waves: "
          f"{len(run.homing.waves) if run.homing else 0}")


if __name__ == "__main__":
    main()
