"""Multi-phase demo: an iterative application (paper §III-B) whose task
loads drift between executions, balanced once per phase.

Shows the pipeline orchestrator's two amortizations — warm-started
assignments and shared CSR builds — against replanning every phase cold,
then the same machinery applied to a DP sequence-packing stream.

  PYTHONPATH=src python examples/pipeline_phases.py
"""
import dataclasses

import numpy as np

from repro.balance import rebalance_sequences_stream
from repro.core import CCMParams, ccm_lb_pipeline, random_phase


def drifting_phases(seed=0, ranks=32, n_phases=6, drift=0.08):
    base = random_phase(seed, num_ranks=ranks, num_tasks=25 * ranks,
                        num_blocks=3 * ranks, num_comms=50 * ranks,
                        mem_cap=1e12)
    rng = np.random.default_rng(seed + 1)
    phases = [base]
    for _ in range(n_phases - 1):
        prev = phases[-1]
        phases.append(dataclasses.replace(
            prev, task_load=prev.task_load
            * rng.lognormal(0.0, drift, prev.num_tasks)))
    return phases


def main():
    phases = drifting_phases()
    params = CCMParams(delta=1e-9)

    print(f"{len(phases)} phases, {phases[0].num_ranks} ranks, "
          f"{phases[0].num_tasks} tasks, load drift 8%/phase\n")

    cold = ccm_lb_pipeline(phases, params, warm_start=False, reuse_csr=False,
                           n_iter=3, batch_lock_events=8)
    warm = ccm_lb_pipeline(phases, params, n_iter=3, batch_lock_events=8)

    print("phase |  cold transfers  imb |  warm transfers  imb  csr")
    for k, (c, w) in enumerate(zip(cold.runs, warm.runs)):
        print(f"  {k}   |  {c.result.transfers:14d}  {c.result.imbalance[-1]:.3f}"
              f" |  {w.result.transfers:14d}  {w.result.imbalance[-1]:.3f}"
              f"  {'reused' if w.csr_reused else 'built '}")
    print(f"\ntotals: cold {cold.total_transfers} transfers / "
          f"{cold.total_seconds:.2f}s   warm {warm.total_transfers} "
          f"transfers / {warm.total_seconds:.2f}s "
          f"({cold.total_seconds / warm.total_seconds:.2f}x)")

    # --- the same orchestrator behind a framework feature ------------------
    rng = np.random.default_rng(3)
    batches = [rng.lognormal(0.0, 0.8, 256) for _ in range(5)]
    stream = rebalance_sequences_stream(batches, n_ranks=16, seed=0)
    print("\nDP seq-pack stream (5 batches, 16 ranks): imbalance per step:")
    print("  " + "  ".join(f"{r.imbalance_before:.3f}->{r.imbalance_after:.3f}"
                           for r in stream))


if __name__ == "__main__":
    main()
