"""The §IV-B lock/grant protocol under real asynchrony.

The synchronous driver (core/ccmlb.py) releases every lock within the
turn that took it, so its conflict/yield/grant-chain counters are zero by
construction.  This demo runs the SAME protocol through the async
event-loop simulator (core/async_sim.py):

  1. at zero latency the event queue serializes — the trajectory is
     bitwise-identical to the synchronous driver (the parity bar);
  2. with a seeded message-latency distribution, concurrent lock requests
     collide, deadlock-avoidance yields fire, and queued requests drain
     through multi-hop grant chains — while the balancer still converges;
  3. a contended start (half the ranks empty) drives the counters up, and
     a gossip deadline makes stale information observable;
  4. seeded faults (message loss, duplication, a rank killed
     mid-iteration) exercise the hardened protocol: timeouts retry with
     backoff, duplicate grants/releases are absorbed idempotently, dead
     ranks' locks are reclaimed and their work migrates to survivors —
     and the transfer log still replays exactly onto the final
     assignment;
  5. chaos: a split-brain partition severs the mesh into two islands —
     each keeps balancing locally off its own gossip, then the window
     closes, the islands re-merge and the run quiesces; finally two
     fresh ranks JOIN mid-stream, inherit gossip state through the
     ordinary flood and end the run owning real work.

  PYTHONPATH=src python examples/async_balancer.py
"""
import numpy as np

from repro.core import (CCMParams, FaultSpec, RankJoin, ccm_lb,
                        ccm_lb_async, random_phase)
from repro.core.problem import initial_assignment


def counters(tag, res):
    print(f"  {tag:<22} imb {res.imbalance[0]:.3f}->{res.imbalance[-1]:.4f}"
          f"  transfers={res.transfers:<4d} conflicts={res.lock_conflicts:<4d}"
          f" yields={res.yields:<4d} chains={res.grant_chains:<3d}"
          f" max_chain={res.max_grant_chain:<3d} msgs={res.messages}")


def main():
    phase = random_phase(1, num_ranks=16, num_tasks=400, num_blocks=48,
                         num_comms=800, mem_cap=1e12)
    params = CCMParams(delta=1e-9)
    a0 = initial_assignment(phase)
    lb = dict(n_iter=4, k_rounds=2, fanout=4, seed=0)

    print("1) zero latency == serialized schedule == the synchronous driver")
    ref = ccm_lb(phase, a0, params, **lb)
    got = ccm_lb_async(phase, a0, params, **lb)
    assert np.array_equal(ref.assignment, got.assignment)
    assert ref.transfer_log == got.transfer_log
    counters("sync", ref)
    counters("async latency=0", got)
    print("  -> identical assignment AND transfer sequence, bit for bit\n")

    print("2) message latency: the protocol branches become load-bearing")
    for latency in (0.5, ("uniform", 0.5, 1.5)):
        res = ccm_lb_async(phase, a0, params, latency=latency, **lb)
        counters(f"async latency={latency}", res)
    print()

    print("3) contention (half the ranks start empty) + a gossip deadline")
    a1 = (np.arange(phase.num_tasks) % 8).astype(np.int64)
    res = ccm_lb_async(phase, a1, params, n_iter=4, seed=3, fanout=6,
                       latency=("uniform", 0.5, 1.5))
    counters("contended", res)
    stale = ccm_lb_async(phase, a1, params, n_iter=4, seed=3, fanout=6,
                         latency=("uniform", 0.5, 1.5), gossip_timeout=1.0)
    counters("contended+deadline", stale)
    print(f"  -> gossip deliveries dropped as stale: {stale.gossip_dropped}")
    print()

    print("4) faults: message loss + duplication, then a rank death")
    lossy = FaultSpec(drop=0.03, dup=0.1, req_timeout=3.0, seed=7)
    res = ccm_lb_async(phase, a0, params, latency=("uniform", 0.5, 1.5),
                       fault=lossy, **lb)
    counters("lossy+dup", res)
    fs = res.fault_stats
    print(f"  -> injected: dropped={fs.dropped} duplicated={fs.duplicated};"
          f" absorbed: timeouts={res.timeouts}"
          f" retries_exhausted={res.retries_exhausted}"
          f" stale_grants={fs.stale_grants}"
          f" stale_releases={fs.stale_releases}"
          f" wedged_reclaimed={fs.wedged_reclaimed}")

    crash = FaultSpec(kill=((3, 1, 0.5),), seed=9)
    res = ccm_lb_async(phase, a0, params, latency=("uniform", 0.5, 1.5),
                       fault=crash, **lb)
    counters("rank 3 killed @it1", res)
    replay = a0.copy()
    for tasks, r_from, r_to in res.transfer_log:
        replay[np.asarray(tasks, np.int64)] = r_to
    assert np.array_equal(replay, res.assignment)
    assert not (res.assignment == 3).any()
    print(f"  -> dead={res.dead_ranks}"
          f" recovered_tasks={res.fault_stats.recovered_tasks};"
          " transfer log replays exactly, no task left on the dead rank")
    print()

    print("5) chaos: a split-brain heal, then two ranks join mid-stream")
    split = FaultSpec(partition=((tuple(range(8)), tuple(range(8, 16)),
                                  0, 0.0, 15.0),), seed=11)
    res = ccm_lb_async(phase, a0, params, latency=("uniform", 0.5, 1.5),
                       fault=split, n_iter=8, k_rounds=2, fanout=4,
                       seed=0, quiesce_after=2)
    counters("split-brain healed", res)
    fs = res.fault_stats
    print(f"  -> cross-island messages destroyed: {fs.partitioned_dropped};"
          f" after the heal the run quiesced in {len(res.iter_transfers)}"
          f" iterations (last two transfer counts:"
          f" {list(res.iter_transfers[-2:])})")

    res = ccm_lb_async(phase, a0, params, latency=("uniform", 0.5, 1.5),
                       membership=(RankJoin(iteration=1, count=2),), **lb)
    counters("2 ranks join @it1", res)
    on_joined = int(np.isin(res.assignment, res.joined_ranks).sum())
    replay = a0.copy()
    for tasks, r_from, r_to in res.transfer_log:
        replay[np.asarray(tasks, np.int64)] = r_to
    assert np.array_equal(replay, res.assignment)
    print(f"  -> joined={res.joined_ranks} now own {on_joined} tasks"
          f" ({res.state.phase.num_ranks} ranks at the end);"
          " the log replays exactly across the membership change")


if __name__ == "__main__":
    main()
