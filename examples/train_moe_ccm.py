"""End-to-end driver: train a ~100M-parameter MoE LM for a few hundred steps
with the full substrate — data pipeline, AdamW, checkpointing, fault-tolerant
restart, and CCM-LB expert re-placement from live router statistics.

  PYTHONPATH=src python examples/train_moe_ccm.py [--steps 300]
"""
import argparse
import dataclasses

import jax

from repro.configs.base import BLOCK_MOE, ModelConfig
from repro.launch.mesh import make_local_mesh
from repro.launch.train import train_loop
from repro.runtime.fault import FaultInjector, run_with_restarts

# ~100M params: 2*16k*512 embed + 8 layers x (attn ~1.3M + 16 experts x
# 3*512*512 + shared mlp) ~= 118M
CONFIG_100M = ModelConfig(
    name="moe-100m",
    family="moe",
    num_layers=8,
    d_model=512,
    num_heads=8,
    num_kv_heads=4,
    d_ff=1536,
    vocab_size=16384,
    head_dim=64,
    block_pattern=(BLOCK_MOE,),
    num_experts=16,
    top_k=2,
    moe_d_ff=512,
    act="silu",
    remat=False,
)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--ckpt-dir", default="/tmp/moe_ccm_ckpt")
    ap.add_argument("--fail-at", type=int, default=0,
                    help="inject a node failure at this step (0 = off)")
    args = ap.parse_args()

    mesh = make_local_mesh(1, 1)
    n = CONFIG_100M.param_count()
    print(f"[example] ~{n / 1e6:.0f}M params, {args.steps} steps, "
          f"CCM expert re-placement every 50 steps")
    inj = FaultInjector(fail_at_steps=(args.fail_at,) if args.fail_at else ())

    losses_all = []

    def once():
        _, _, losses = train_loop(
            CONFIG_100M, mesh, steps=args.steps, seq_len=args.seq_len,
            global_batch=args.global_batch, ckpt_dir=args.ckpt_dir,
            ckpt_every=50, rebalance_every=50, fault=inj, lr=1e-3,
            log_every=20)
        losses_all.append(losses)

    stats = run_with_restarts(once)
    losses = losses_all[-1]
    print(f"[example] done: loss {losses[0]:.3f} -> {losses[-1]:.3f} "
          f"(restarts={stats.restarts}, wall={stats.wall_s:.0f}s)")
    assert losses[-1] < losses[0], "loss did not decrease"


if __name__ == "__main__":
    main()
