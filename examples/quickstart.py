"""Quickstart: the CCM model + CCM-LB on a synthetic phase, certified
against the MILP optimum.

  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import CCMParams, CCMState, ccm_lb, random_phase
from repro.core.milp import build_fwmp_reduced, solve_milp
from repro.core.problem import initial_assignment


def main():
    # --- a phase: 16 ranks, 400 tasks, 48 shared blocks, 800 comm edges ----
    phase = random_phase(0, num_ranks=16, num_tasks=400, num_blocks=48,
                         num_comms=800, mem_cap=3e8)
    params = CCMParams(alpha=1.0, beta=1e-9, gamma=1e-11, delta=1e-9)
    a0 = initial_assignment(phase, "home")
    st0 = CCMState.build(phase, a0, params)
    print(f"initial : max work {st0.max_work():.3f}  "
          f"imbalance {st0.imbalance():.3f}")

    # --- CCM-LB: gossip + cluster transfers under memory constraints -------
    res = ccm_lb(phase, a0, params, n_iter=4, k_rounds=2, fanout=4, seed=1)
    print(f"CCM-LB  : max work {res.max_work[-1]:.3f}  "
          f"imbalance {res.imbalance[-1]:.4f}  "
          f"transfers {res.transfers}")
    mean = phase.task_load.sum() / phase.num_ranks
    print(f"          ({100 * (res.max_work[-1] / mean - 1):.2f}% above the "
          f"mean-load lower bound)")

    # --- certify on a small instance against the MILP (paper §V) -----------
    small = random_phase(7, num_ranks=4, num_tasks=14, num_blocks=4,
                         num_comms=16, mem_cap=5e8)
    a0s = initial_assignment(small)
    best = min(ccm_lb(small, a0s, params, n_iter=4, fanout=3,
                      seed=s).max_work[-1] for s in range(12))
    milp = solve_milp(build_fwmp_reduced(small, params), max_nodes=2000,
                      time_limit_s=60)
    print(f"\nMILP certification (4 ranks / 14 tasks):")
    print(f"  optimal W_max   : {milp.objective:.4f} ({milp.status}, "
          f"{milp.nodes} nodes, {milp.wall_s:.1f}s)")
    print(f"  CCM-LB best/12  : {best:.4f} "
          f"(+{100 * (best - milp.objective) / milp.objective:.2f}% vs opt)")


if __name__ == "__main__":
    main()
