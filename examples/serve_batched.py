"""Batched serving across architecture families (KV cache, WKV state,
RG-LRU state) with greedy decode.

  PYTHONPATH=src python examples/serve_batched.py
"""
import time

import jax
import numpy as np

from repro import configs
from repro.launch.mesh import make_local_mesh
from repro.launch.serve import serve_batch
from repro.models.layers import split_lp_tree
from repro.models.model import build_model


def main():
    mesh = make_local_mesh(1, 1)
    rng = np.random.default_rng(0)
    for arch in ("tinyllama-1.1b", "qwen3-moe-30b-a3b", "rwkv6-7b",
                 "recurrentgemma-9b"):
        cfg = configs.get_smoke_config(arch)
        model = build_model(cfg, mesh)
        params, _ = split_lp_tree(model.init(jax.random.key(0)))
        prompts = rng.integers(0, cfg.vocab_size, (4, 24)).astype(np.int32)
        t0 = time.time()
        out = serve_batch(model, params, prompts, max_new=16)
        dt = time.time() - t0
        print(f"{arch:24s} 4 reqs x 16 tokens in {dt:5.2f}s "
              f"({4 * 16 / dt:6.1f} tok/s)  first row: {out[0, :8]}")


if __name__ == "__main__":
    main()
