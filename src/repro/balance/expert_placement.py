"""CCM-driven MoE expert placement — the paper's technique as a first-class
framework feature.

Mapping (DESIGN.md §2): a (layer, expert) work item is a CCM *task* whose
load is the router's token count x per-token expert FLOPs; the expert's
weights are its *shared block* (replicable at HBM cost), homed where the
optimizer state lives; consecutive-layer co-activation gives the *comm*
edges (tokens flowing e_l -> e'_{l+1} cross the network iff the two experts
sit on different devices); the HBM budget is the hard eps constraint.

CCM-LB then plans a placement.  Applying an arbitrary plan = per-layer
permutations of the expert axis (slots): permuting expert weights AND the
router's output columns identically is a function-preserving transformation
(verified in tests), after which slot s lives on device s // (E / n_devices)
— i.e. the plan becomes real data placement under the existing shard_map
layout.  Plans that replicate an expert across ranks (sharded experts +
``replicate=True``) become REAL placements: ``PlacementPlan.serving``
carries the per-device replica sets, the per-copy routing shares and an
HBM byte audit for the serving engine, while the training path applies
the permutation-only projection of the plan (each expert at its primary
— heaviest-shard — device).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.configs.base import ModelConfig
from repro.core import CCMParams, ccm_lb_pipeline, run_ccm_lb
from repro.core.problem import Phase


def phase_from_router_stats(counts: np.ndarray, cfg: ModelConfig,
                            n_devices: int, *, hbm_budget_bytes: float,
                            bytes_per_token: Optional[float] = None,
                            coactivation: Optional[np.ndarray] = None,
                            rank_speed: Optional[np.ndarray] = None,
                            shards_per_expert: int = 1) -> Phase:
    """counts: (L, E) tokens routed per (layer, expert).

    Returns a Phase with K = L*E*shards_per_expert tasks and N = L*E
    blocks (expert weights).  ``shards_per_expert`` splits each expert's
    token load into equal sub-tasks that SHARE the expert's weight block:
    with more than one shard the balancer's replication moves can place
    shards of a hot expert on several devices — each holding a weight
    copy — which is exactly the serving-time replicated-expert trade
    (parallelism bought with HBM).  At the default 1 the phase is
    bitwise-identical to the unsharded construction.
    """
    l_n, e_n = counts.shape
    s = int(shards_per_expert)
    if s < 1:
        raise ValueError("shards_per_expert must be >= 1")
    d, f = cfg.d_model, cfg.moe_d_ff
    flops_per_token = 6.0 * d * f  # 3 GLU matmuls, fwd
    peak = 197e12
    task_load = np.repeat(
        counts.reshape(-1) * flops_per_token / peak / s, s)
    expert_bytes = 3.0 * d * f * 2.0  # bf16 gate/up/down
    bytes_per_token = bytes_per_token or (d * 2.0)

    g_n = l_n * e_n                               # expert-block grid size
    k = g_n * s
    # shard t of expert g is task g*s + t; all shards share block g
    task_block = np.repeat(np.arange(g_n, dtype=np.int64), s)
    block_home = (np.arange(g_n) % e_n) * n_devices // e_n  # initial layout
    # comm edges: consecutive-layer co-activation volume
    comm_src, comm_dst, comm_vol = [], [], []
    total = counts.sum(axis=1, keepdims=True) + 1e-9
    for l in range(l_n - 1):
        p_l = counts[l] / total[l]
        p_n = counts[l + 1] / total[l + 1]
        if coactivation is not None:
            flow = coactivation[l]
        else:  # independence approximation
            flow = np.outer(p_l, p_n) * total[l]
        top = np.argsort(flow.reshape(-1))[::-1][: 4 * e_n]  # sparsify
        for idx in top:
            e_a, e_b = divmod(int(idx), e_n)
            v = flow[e_a, e_b] * bytes_per_token
            if v <= 0:
                continue
            # attach the flow to shard 0 of each endpoint expert (the
            # volume follows the expert, not an individual shard)
            comm_src.append((l * e_n + e_a) * s)
            comm_dst.append(((l + 1) * e_n + e_b) * s)
            comm_vol.append(float(v))

    return Phase(
        task_load=task_load,
        task_mem=np.full(k, 1e4),
        task_overhead=np.zeros(k),
        task_block=task_block,
        block_size=np.full(g_n, expert_bytes),
        block_home=block_home,
        comm_src=np.array(comm_src, np.int64) if comm_src else np.zeros(0, np.int64),
        comm_dst=np.array(comm_dst, np.int64) if comm_dst else np.zeros(0, np.int64),
        comm_vol=np.array(comm_vol) if comm_vol else np.zeros(0),
        rank_mem_base=np.zeros(n_devices),
        rank_mem_cap=np.full(n_devices, hbm_budget_bytes),
        rank_speed=rank_speed,
    )


@dataclasses.dataclass
class ServingPlan:
    """A real replicated-expert placement for the serving engine.

    Derived from the balancer's block residency (``block_count > 0``):
    every device hosting at least one shard of an expert holds a weight
    copy, and the router splits that expert's tokens across the copies
    in proportion to the shard loads the balancer placed there.
    """

    replicas: np.ndarray        # (L, E, D) bool — device holds a copy
    routing_shares: np.ndarray  # (L, E, D) — token share served per copy
                                # (rows sum to 1 for routed-to experts)
    hbm_bytes: np.ndarray       # (D,) expert-weight bytes resident
    hbm_budget_bytes: float     # the per-device budget the plan ran under
    replicated_experts: List[Tuple[int, int]]  # (layer, expert), >1 copy

    def within_budget(self) -> bool:
        return bool((self.hbm_bytes <= self.hbm_budget_bytes).all())


@dataclasses.dataclass
class PlacementPlan:
    assignment: np.ndarray              # (K,) task (expert shard) -> device
    permutations: np.ndarray            # (L, E) slot s on layer l holds
                                        #        original expert perm[l, s]
    imbalance_before: float
    imbalance_after: float
    replicated_blocks: int              # experts materialized on >1 device
    max_work_before: float
    max_work_after: float
    lb_result: object
    serving: Optional[ServingPlan] = None  # the real replica placement


def plan_expert_placement(counts: np.ndarray, cfg: ModelConfig,
                          n_devices: int, *, hbm_budget_bytes: float,
                          params: Optional[CCMParams] = None,
                          rank_speed: Optional[np.ndarray] = None,
                          n_iter: int = 4, fanout: int = 4,
                          seed: int = 0,
                          use_engine: bool = True,
                          backend: str = "numpy",
                          batch_lock_events: int = 1,
                          spec_window: int = 1,
                          spec_mode: str = "scan",
                          async_mode: bool = False,
                          latency=0.0,
                          gossip_timeout=None,
                          quiesce_after: Optional[int] = None,
                          replicate: bool = False,
                          shards_per_expert: int = 1
                          ) -> PlacementPlan:
    """Plan an expert placement with CCM-LB.  ``use_engine`` selects the
    vectorized evaluation engine (default; the scalar reference path gives
    identical plans — the knob exists for A/B benchmarking); ``backend``
    ({"numpy", "jit", "pallas", "pallas_compiled"} — the compiled
    shape-bucketed jit runtime and the Pallas kernel are bitwise-equal to
    numpy in f64, see kernels/ccm_scorer/README.md) and
    ``batch_lock_events`` tune the engine's stage-2 scorer (deferred
    disjoint-pair batching, trajectory-exact); ``spec_window`` /
    ``spec_mode`` route stage 2 through the speculative compiled scan
    (core/spec.py — compiled-vs-host parity tier).  ``async_mode`` plans
    through the distributed event-loop simulator instead (``latency`` /
    ``gossip_timeout`` as in repro/core/async_sim.py; at the default zero
    latency the plan is identical to the synchronous one).
    ``quiesce_after`` stops early after that many consecutive
    zero-transfer iterations (repro/core/quiesce.py).

    ``shards_per_expert`` > 1 splits each expert's token load into equal
    sub-tasks sharing the weight block, and ``replicate=True`` lets the
    balancer materialize a hot expert's shards on several devices (the
    memory-pressure move vocabulary, repro/core/transfer.py) — the
    resulting copies and per-copy routing shares land in
    ``PlacementPlan.serving``."""
    l_n, e_n = counts.shape
    assert e_n % n_devices == 0
    phase = phase_from_router_stats(counts, cfg, n_devices,
                                    hbm_budget_bytes=hbm_budget_bytes,
                                    rank_speed=rank_speed,
                                    shards_per_expert=shards_per_expert)
    ccm = params or CCMParams(alpha=1.0, beta=2e-11, gamma=1e-13, delta=1e-12)
    # shards start at their expert's device
    a0 = np.repeat(phase.block_home, shards_per_expert).copy()
    res = run_ccm_lb(phase, a0, ccm, n_iter=n_iter, fanout=fanout, seed=seed,
                     use_engine=use_engine, backend=backend,
                     batch_lock_events=batch_lock_events,
                     spec_window=spec_window, spec_mode=spec_mode,
                     async_mode=async_mode, latency=latency,
                     gossip_timeout=gossip_timeout,
                     quiesce_after=quiesce_after, replicate=replicate)
    return _project_plan(counts, res, n_devices,
                         hbm_budget_bytes=hbm_budget_bytes)


def _serving_plan(res, l_n: int, e_n: int, n_devices: int,
                  hbm_budget_bytes: float) -> ServingPlan:
    """Turn block residency into the real serving placement: replicas
    from ``block_count > 0``, routing shares from the per-device shard
    loads, and a per-device HBM audit of the resident weight bytes."""
    st = res.state
    ph = st.phase
    g_n = l_n * e_n
    s = ph.num_tasks // g_n
    present = (st.block_count > 0)                      # (D, g_n)
    replicas = present.T.reshape(l_n, e_n, n_devices)
    # per-(expert, device) placed shard load -> routing shares
    placed = np.zeros((g_n, n_devices))
    np.add.at(placed, (np.arange(ph.num_tasks) // s, res.assignment),
              ph.task_load)
    tot = placed.sum(axis=1, keepdims=True)
    shares = np.divide(placed, tot, out=np.zeros_like(placed),
                       where=tot > 0)
    hbm = (present * ph.block_size[None, :]).sum(axis=1)
    multi = np.nonzero(present.sum(axis=0) > 1)[0]
    return ServingPlan(
        replicas=replicas,
        routing_shares=shares.reshape(l_n, e_n, n_devices),
        hbm_bytes=hbm,
        hbm_budget_bytes=float(hbm_budget_bytes),
        replicated_experts=[(int(g) // e_n, int(g) % e_n) for g in multi],
    )


def _project_plan(counts: np.ndarray, res, n_devices: int, *,
                  hbm_budget_bytes: Optional[float] = None) -> PlacementPlan:
    """Project a CCM-LB result onto per-layer slot permutations: on each
    layer, device dev gets the experts assigned to it (top e_loc by load if
    the plan overflows a device; spill handling keeps it a permutation).

    With sharded experts the permutation (the training path — one slot
    per expert) uses each expert's PRIMARY device, the one holding its
    heaviest shard; the full replica set goes to ``PlacementPlan.
    serving`` for the serving engine.  At one shard per expert the
    primary device is the task's device, matching the unsharded
    projection exactly."""
    l_n, e_n = counts.shape
    e_loc = e_n // n_devices
    perms = np.zeros((l_n, e_n), np.int64)
    ph = res.state.phase
    g_n = l_n * e_n
    s = ph.num_tasks // g_n
    heavy = np.argmax(ph.task_load.reshape(g_n, s), axis=1)
    primary = res.assignment[np.arange(g_n) * s + heavy]
    assign = primary.reshape(l_n, e_n)
    for l in range(l_n):
        buckets: List[List[int]] = [[] for _ in range(n_devices)]
        for e in range(e_n):
            buckets[int(assign[l, e])].append(e)
        # spill: move lightest experts out of overfull buckets
        loads = counts[l]
        overflow: List[int] = []
        for devb in buckets:
            devb.sort(key=lambda e: -loads[e])
            while len(devb) > e_loc:
                overflow.append(devb.pop())
        for devb in buckets:
            while len(devb) < e_loc and overflow:
                devb.append(overflow.pop(0))
        perm = [e for devb in buckets for e in devb]
        perms[l] = np.array(perm, np.int64)
    # replication realized by the plan: blocks present on >1 rank
    replicated = int(((res.state.block_count > 0).sum(axis=0) > 1).sum())

    budget = (float(ph.rank_mem_cap.max()) if hbm_budget_bytes is None
              else hbm_budget_bytes)
    return PlacementPlan(
        assignment=res.assignment,
        permutations=perms,
        imbalance_before=float(res.imbalance[0]),
        imbalance_after=res.state.imbalance(),
        replicated_blocks=replicated,
        max_work_before=float(res.max_work[0]),
        max_work_after=res.state.max_work(),
        lb_result=res,
        serving=_serving_plan(res, l_n, e_n, n_devices, budget),
    )


def plan_expert_placement_sequence(
        counts_seq: Sequence[np.ndarray], cfg: ModelConfig, n_devices: int, *,
        hbm_budget_bytes: float, params: Optional[CCMParams] = None,
        rank_speed: Optional[np.ndarray] = None, n_iter: int = 4,
        fanout: int = 4, seed: int = 0, warm_start: bool = True,
        use_engine: bool = True, backend: str = "numpy",
        batch_lock_events: int = 1, spec_window: int = 1,
        spec_mode: str = "scan",
        quiesce_after: Optional[int] = None,
        replicate: bool = False,
        shards_per_expert: int = 1) -> List[PlacementPlan]:
    """Plan placements for a SEQUENCE of router-stat windows (paper §III-B
    iterative executions): each window's phase shares the (layer, expert)
    task/block grid, so phase ``k+1`` warm-starts from phase ``k``'s
    placement via :func:`repro.core.pipeline.ccm_lb_pipeline`.  On slowly
    drifting routing distributions the balancer then only repairs the
    drift — a fraction of the transfers (and wall-clock) of replanning each
    window from scratch (``warm_start=False``, the cold reference).

    Comm edges are re-derived per window (they follow the routing flows),
    so only the warm start amortizes here — CSR reuse kicks in when
    consecutive windows produce identical sparsified flow graphs.
    """
    counts_seq = [np.asarray(c, np.float64) for c in counts_seq]
    if not counts_seq:
        return []
    l_n, e_n = counts_seq[0].shape
    assert e_n % n_devices == 0
    phases = [phase_from_router_stats(c, cfg, n_devices,
                                      hbm_budget_bytes=hbm_budget_bytes,
                                      rank_speed=rank_speed,
                                      shards_per_expert=shards_per_expert)
              for c in counts_seq]
    ccm = params or CCMParams(alpha=1.0, beta=2e-11, gamma=1e-13, delta=1e-12)
    a0 = np.repeat(phases[0].block_home, shards_per_expert).copy()
    pipe = ccm_lb_pipeline(phases, ccm, warm_start=warm_start,
                           a0=a0, seed=seed,
                           n_iter=n_iter, fanout=fanout,
                           use_engine=use_engine, backend=backend,
                           batch_lock_events=batch_lock_events,
                           spec_window=spec_window, spec_mode=spec_mode,
                           quiesce_after=quiesce_after, replicate=replicate)
    return [_project_plan(c, run.result, n_devices,
                          hbm_budget_bytes=hbm_budget_bytes)
            for c, run in zip(counts_seq, pipe.runs)]


def apply_expert_permutation(moe_params: Dict, perm: np.ndarray) -> Dict:
    """Function-preserving slot permutation of one MoE layer's params.

    perm[s] = original expert now living in slot s.  Router output columns
    are permuted identically, so routing decisions follow the weights.
    """
    out = dict(moe_params)
    out["w_gate"] = moe_params["w_gate"][perm]
    out["w_up"] = moe_params["w_up"][perm]
    out["w_down"] = moe_params["w_down"][perm]
    out["router"] = moe_params["router"][:, perm]
    return out


def all_to_all_bytes(counts: np.ndarray, assignment: np.ndarray,
                     n_devices: int, d_model: int,
                     bytes_per_el: float = 2.0) -> float:
    """Dispatch volume crossing the network under a placement: tokens
    originate uniformly across devices; a token reaching expert (l, e) on
    device dev crosses iff its source != dev (fraction 1 - 1/n)."""
    k = counts.size
    loads = counts.reshape(-1)
    cross = loads * (1.0 - 1.0 / n_devices)
    return float(cross.sum() * d_model * bytes_per_el)
