from repro.balance.expert_placement import (PlacementPlan,  # noqa: F401
                                            ServingPlan,
                                            apply_expert_permutation,
                                            phase_from_router_stats,
                                            plan_expert_placement,
                                            plan_expert_placement_sequence)
from repro.balance.pipeline_stages import (plan_pipeline_stages,  # noqa: F401
                                           plan_pipeline_stages_schedule)
from repro.balance.seqpack import (rebalance_sequences,  # noqa: F401
                                   rebalance_sequences_stream)
