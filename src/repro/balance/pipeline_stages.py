"""Layer -> pipeline-stage assignment via CCM (third framework application).

Mapping: layers are CCM tasks (per-layer flop cost — heterogeneous for
hybrid archs: an rglru block != a local-attn block != a MoE block); the
activation tensor flowing layer_i -> layer_{i+1} is a comm edge (crossing a
stage boundary = a send over the pipeline link); layer weights+optimizer
state are the memory load against each stage's HBM.  CCM-LB's beta term then
does the interesting work: non-contiguous stage assignments pay the
activation transfer repeatedly, so minimizing W induces contiguous,
cost-balanced stages — partitioning heterogeneous stacks without a bespoke
DP algorithm.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence

import numpy as np

from repro.configs.base import (BLOCK_ATTN, BLOCK_LOCAL, BLOCK_MOE, BLOCK_REC,
                                BLOCK_RWKV, ModelConfig)
from repro.core import CCMParams, ccm_lb_pipeline, run_ccm_lb
from repro.core.problem import Phase


def layer_flops(cfg: ModelConfig, kind: str, tokens: int) -> float:
    """Per-layer forward FLOPs for one microbatch of ``tokens`` tokens."""
    d, hd = cfg.d_model, cfg.head_dim
    h, hkv = cfg.num_heads, cfg.num_kv_heads
    attn_proj = 2 * tokens * d * (h * hd + 2 * hkv * hd + h * hd)
    if kind == BLOCK_REC:
        return 2 * tokens * (5 * d * d) + 6 * tokens * d * cfg.d_ff
    if kind == BLOCK_RWKV:
        return 2 * tokens * (5 * d * d) + 6 * tokens * d * cfg.d_ff
    if kind == BLOCK_MOE:
        moe = 6 * tokens * cfg.top_k * d * cfg.moe_d_ff
        shared = 6 * tokens * d * cfg.d_ff * cfg.num_shared_experts
        return attn_proj + moe + shared
    ffn = 6 * tokens * d * cfg.d_ff
    return attn_proj + ffn


def layer_param_bytes(cfg: ModelConfig, kind: str) -> float:
    d = cfg.d_model
    attn = 2 * d * (cfg.num_heads * cfg.head_dim * 2
                    + 2 * cfg.num_kv_heads * cfg.head_dim)
    if kind == BLOCK_MOE:
        return attn + 2 * (cfg.num_experts * 3 * d * cfg.moe_d_ff
                           + cfg.num_shared_experts * 3 * d * cfg.d_ff)
    if kind in (BLOCK_REC, BLOCK_RWKV):
        return 2 * (5 * d * d + 3 * d * cfg.d_ff)
    return attn + 2 * 3 * d * cfg.d_ff


@dataclasses.dataclass
class StagePlan:
    assignment: np.ndarray        # (L,) layer -> stage
    stage_flops: np.ndarray       # (S,)
    imbalance: float
    cut_bytes: float              # activation bytes crossing stage edges
    contiguous: bool


def _stage_phase(cfg: ModelConfig, n_stages: int, tokens: int,
                 hbm_budget_bytes: float) -> Phase:
    """Layers-as-tasks phase for one microbatch size.  The chain topology
    (comm endpoints, no blocks) is independent of ``tokens``, so phases for
    different microbatch sizes share one PhaseCSR (pipeline amortization)."""
    kinds = cfg.layer_kinds()
    l_n = len(kinds)
    loads = np.array([layer_flops(cfg, k, tokens) for k in kinds]) / 197e12
    act_bytes = float(tokens * cfg.d_model * 2)
    return Phase(
        task_load=loads,
        task_mem=np.array([layer_param_bytes(cfg, k) for k in kinds]),
        task_overhead=np.zeros(l_n),
        task_block=np.full(l_n, -1, np.int64),
        block_size=np.zeros(0),
        block_home=np.zeros(0, np.int64),
        comm_src=np.arange(l_n - 1, dtype=np.int64),
        comm_dst=np.arange(1, l_n, dtype=np.int64),
        comm_vol=np.full(l_n - 1, act_bytes),
        rank_mem_base=np.zeros(n_stages),
        rank_mem_cap=np.full(n_stages, hbm_budget_bytes),
    )


def _stage_params(phase: Phase) -> CCMParams:
    # beta chosen so one extra stage crossing costs ~ one layer's time:
    # beta * act_bytes ~ median layer time
    beta = float(np.median(phase.task_load) / phase.comm_vol[0]) \
        if phase.num_comms else 0.0
    return CCMParams(alpha=1.0, beta=beta, gamma=0.0, delta=0.0,
                     memory_constraint=True)


def _stage_plan(phase: Phase, res, n_stages: int) -> StagePlan:
    assign = res.assignment
    loads = phase.task_load
    stage_flops = np.bincount(assign, weights=loads, minlength=n_stages)
    crossings = assign[phase.comm_src] != assign[phase.comm_dst]
    contiguous = (bool(np.all(np.diff(assign) >= 0))
                  and crossings.sum() == n_stages - 1)
    mu = stage_flops.mean()
    return StagePlan(
        assignment=assign,
        stage_flops=stage_flops,
        imbalance=float(stage_flops.max() / mu - 1) if mu > 0 else 0.0,
        cut_bytes=float(phase.comm_vol[crossings].sum()),
        contiguous=contiguous,
    )


def plan_pipeline_stages(cfg: ModelConfig, n_stages: int, *,
                         tokens_per_microbatch: int = 4096,
                         hbm_budget_bytes: float = 16e9,
                         seed: int = 0,
                         use_engine: bool = True,
                         backend: str = "numpy",
                         batch_lock_events: int = 1,
                         spec_window: int = 1,
                         spec_mode: str = "scan",
                         async_mode: bool = False,
                         latency=0.0,
                         gossip_timeout=None,
                         quiesce_after: Optional[int] = None) -> StagePlan:
    """``backend`` selects the engine's stage-2 scorer ("numpy"/"jit"/
    "pallas"/"pallas_compiled" — the f64 tiers plan identically; see
    kernels/ccm_scorer/README.md); ``batch_lock_events`` defers and
    batches disjoint lock events, trajectory-exact; ``spec_window`` /
    ``spec_mode`` route stage 2 through the speculative compiled scan
    (core/spec.py).  ``async_mode`` plans
    through the distributed event-loop simulator (``latency`` /
    ``gossip_timeout`` per repro/core/async_sim.py; zero latency plans
    identically to the synchronous driver).  ``quiesce_after`` stops
    early after that many consecutive zero-transfer iterations
    (repro/core/quiesce.py)."""
    phase = _stage_phase(cfg, n_stages, tokens_per_microbatch,
                         hbm_budget_bytes)
    l_n = phase.num_tasks
    # initial: contiguous equal-count split
    a0 = np.minimum((np.arange(l_n) * n_stages) // l_n, n_stages - 1)
    res = run_ccm_lb(phase, a0, _stage_params(phase), n_iter=4,
                     fanout=min(4, n_stages - 1), seed=seed,
                     use_engine=use_engine, backend=backend,
                     batch_lock_events=batch_lock_events,
                     spec_window=spec_window, spec_mode=spec_mode,
                     async_mode=async_mode, latency=latency,
                     gossip_timeout=gossip_timeout,
                     quiesce_after=quiesce_after)
    return _stage_plan(phase, res, n_stages)


def plan_pipeline_stages_schedule(
        cfg: ModelConfig, n_stages: int,
        tokens_schedule: Sequence[int], *,
        hbm_budget_bytes: float = 16e9, seed: int = 0,
        warm_start: bool = True, use_engine: bool = True,
        backend: str = "numpy",
        batch_lock_events: int = 1, spec_window: int = 1,
        spec_mode: str = "scan",
        quiesce_after: Optional[int] = None) -> List[StagePlan]:
    """Re-plan the stage split as the microbatch size changes (sequence-
    length curriculum, serving traffic shifts): one CCM phase per entry of
    ``tokens_schedule``, run through :func:`ccm_lb_pipeline` so step ``k+1``
    starts from step ``k``'s split and — the chain topology being
    token-independent — every step after the first reuses the PhaseCSR.
    Work-model coefficients are re-derived per step (beta tracks the
    activation size)."""
    if not tokens_schedule:
        return []
    phases = [_stage_phase(cfg, n_stages, int(t), hbm_budget_bytes)
              for t in tokens_schedule]
    l_n = phases[0].num_tasks
    a0 = np.minimum((np.arange(l_n) * n_stages) // l_n, n_stages - 1)
    pipe = ccm_lb_pipeline(phases, [_stage_params(p) for p in phases],
                           warm_start=warm_start, a0=a0, seed=seed,
                           n_iter=4, fanout=min(4, n_stages - 1),
                           use_engine=use_engine, backend=backend,
                           batch_lock_events=batch_lock_events,
                           spec_window=spec_window, spec_mode=spec_mode,
                           quiesce_after=quiesce_after)
    return [_stage_plan(phase, run.result, n_stages)
            for phase, run in zip(phases, pipe.runs)]
