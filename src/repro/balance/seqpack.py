"""DP-batch sequence rebalancing via CCM (dense-arch application of the
paper's technique + straggler mitigation).

Variable-length sequences make data-parallel step time = the slowest rank's
work.  Sequences are CCM tasks (cost from the learned cost model or an
analytic len->time curve), ranks carry measured speed factors (EWMA from
repro.runtime.straggler), and CCM-LB plans the sequence->rank map; with
alpha=1 and no blocks this degenerates to speed-aware multiway number
partitioning — exactly the paper's model with beta=gamma=delta=0.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from repro.core import CCMParams, CCMState, ccm_lb
from repro.core.problem import Phase


@dataclasses.dataclass
class SeqPackResult:
    assignment: np.ndarray
    makespan_before: float
    makespan_after: float
    imbalance_before: float
    imbalance_after: float


def rebalance_sequences(costs: np.ndarray, n_ranks: int, *,
                        rank_speed: Optional[np.ndarray] = None,
                        act_bytes: Optional[np.ndarray] = None,
                        mem_cap: float = np.inf, seed: int = 0,
                        n_iter: int = 3,
                        use_engine: bool = True,
                        backend: str = "numpy",
                        batch_lock_events: int = 1) -> SeqPackResult:
    """costs: (n_seqs,) predicted step-time contribution per sequence."""
    k = costs.shape[0]
    phase = Phase(
        task_load=costs,
        task_mem=act_bytes if act_bytes is not None else np.zeros(k),
        task_overhead=np.zeros(k),
        task_block=np.full(k, -1, np.int64),
        block_size=np.zeros(0),
        block_home=np.zeros(0, np.int64),
        comm_src=np.zeros(0, np.int64),
        comm_dst=np.zeros(0, np.int64),
        comm_vol=np.zeros(0),
        rank_mem_base=np.zeros(n_ranks),
        rank_mem_cap=np.full(n_ranks, mem_cap),
        rank_speed=rank_speed,
    )
    a0 = (np.arange(k) % n_ranks).astype(np.int64)
    params = CCMParams(alpha=1.0, beta=0.0, gamma=0.0, delta=0.0,
                       memory_constraint=np.isfinite(mem_cap))
    st0 = CCMState.build(phase, a0, params)
    res = ccm_lb(phase, a0, params, n_iter=n_iter, fanout=4, seed=seed,
                 use_engine=use_engine, backend=backend,
                 batch_lock_events=batch_lock_events)
    return SeqPackResult(
        assignment=res.assignment,
        makespan_before=st0.max_work(),
        makespan_after=res.state.max_work(),
        imbalance_before=st0.imbalance(),
        imbalance_after=res.state.imbalance(),
    )
