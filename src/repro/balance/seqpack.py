"""DP-batch sequence rebalancing via CCM (dense-arch application of the
paper's technique + straggler mitigation).

Variable-length sequences make data-parallel step time = the slowest rank's
work.  Sequences are CCM tasks (cost from the learned cost model or an
analytic len->time curve), ranks carry measured speed factors (EWMA from
repro.runtime.straggler), and CCM-LB plans the sequence->rank map; with
alpha=1 and no blocks this degenerates to speed-aware multiway number
partitioning — exactly the paper's model with beta=gamma=delta=0.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence

import numpy as np

from repro.core import CCMParams, ccm_lb_pipeline, run_ccm_lb
from repro.core.problem import Phase


@dataclasses.dataclass
class SeqPackResult:
    assignment: np.ndarray
    makespan_before: float
    makespan_after: float
    imbalance_before: float
    imbalance_after: float


def _seq_phase(costs: np.ndarray, n_ranks: int,
               rank_speed: Optional[np.ndarray],
               act_bytes: Optional[np.ndarray], mem_cap: float) -> Phase:
    k = costs.shape[0]
    return Phase(
        task_load=costs,
        task_mem=act_bytes if act_bytes is not None else np.zeros(k),
        task_overhead=np.zeros(k),
        task_block=np.full(k, -1, np.int64),
        block_size=np.zeros(0),
        block_home=np.zeros(0, np.int64),
        comm_src=np.zeros(0, np.int64),
        comm_dst=np.zeros(0, np.int64),
        comm_vol=np.zeros(0),
        rank_mem_base=np.zeros(n_ranks),
        rank_mem_cap=np.full(n_ranks, mem_cap),
        rank_speed=rank_speed,
    )


def _seq_result(res) -> SeqPackResult:
    return SeqPackResult(
        assignment=res.assignment,
        makespan_before=float(res.max_work[0]),
        makespan_after=res.state.max_work(),
        imbalance_before=float(res.imbalance[0]),
        imbalance_after=res.state.imbalance(),
    )


def rebalance_sequences(costs: np.ndarray, n_ranks: int, *,
                        rank_speed: Optional[np.ndarray] = None,
                        act_bytes: Optional[np.ndarray] = None,
                        mem_cap: float = np.inf, seed: int = 0,
                        n_iter: int = 3,
                        use_engine: bool = True,
                        backend: str = "numpy",
                        batch_lock_events: int = 1,
                        spec_window: int = 1,
                        spec_mode: str = "scan",
                        async_mode: bool = False,
                        latency=0.0,
                        gossip_timeout=None,
                        quiesce_after: Optional[int] = None
                        ) -> SeqPackResult:
    """costs: (n_seqs,) predicted step-time contribution per sequence.

    ``backend`` selects the engine's stage-2 scorer ("numpy"/"jit"/
    "pallas"/"pallas_compiled"; the f64 tiers pack identically — see
    kernels/ccm_scorer/README.md); ``spec_window`` / ``spec_mode`` route
    stage 2 through the speculative compiled scan (core/spec.py).
    ``async_mode`` packs through the
    distributed event-loop simulator (``latency``/``gossip_timeout`` per
    repro/core/async_sim.py; zero latency packs identically).
    ``quiesce_after`` stops early after that many consecutive
    zero-transfer iterations (repro/core/quiesce.py)."""
    k = costs.shape[0]
    phase = _seq_phase(costs, n_ranks, rank_speed, act_bytes, mem_cap)
    a0 = (np.arange(k) % n_ranks).astype(np.int64)
    params = CCMParams(alpha=1.0, beta=0.0, gamma=0.0, delta=0.0,
                       memory_constraint=np.isfinite(mem_cap))
    res = run_ccm_lb(phase, a0, params, n_iter=n_iter, fanout=4, seed=seed,
                     use_engine=use_engine, backend=backend,
                     batch_lock_events=batch_lock_events,
                     spec_window=spec_window, spec_mode=spec_mode,
                     async_mode=async_mode, latency=latency,
                     gossip_timeout=gossip_timeout,
                     quiesce_after=quiesce_after)
    return _seq_result(res)


def rebalance_sequences_stream(
        cost_batches: Sequence[np.ndarray], n_ranks: int, *,
        rank_speed: Optional[np.ndarray] = None,
        mem_cap: float = np.inf, seed: int = 0, n_iter: int = 3,
        warm_start: bool = True, use_engine: bool = True,
        backend: str = "numpy",
        batch_lock_events: int = 1, spec_window: int = 1,
        spec_mode: str = "scan",
        quiesce_after: Optional[int] = None) -> List[SeqPackResult]:
    """Rebalance a STREAM of DP batches (one phase per step): slot ``i`` of
    batch ``k+1`` warm-starts on the rank slot ``i`` of batch ``k`` landed
    on — under steady length distributions the previous map is already
    near-balanced, so each step only repairs the drift.  Equal-sized
    batches also share the (trivial, comm-free) PhaseCSR.  Runs through
    :func:`repro.core.pipeline.ccm_lb_pipeline`; ``warm_start=False`` is
    the per-batch-from-scratch cold reference.
    """
    cost_batches = [np.asarray(c, np.float64) for c in cost_batches]
    if not cost_batches:
        return []
    phases = [_seq_phase(c, n_ranks, rank_speed, None, mem_cap)
              for c in cost_batches]
    params = CCMParams(alpha=1.0, beta=0.0, gamma=0.0, delta=0.0,
                       memory_constraint=np.isfinite(mem_cap))
    a0 = (np.arange(cost_batches[0].shape[0]) % n_ranks).astype(np.int64)
    pipe = ccm_lb_pipeline(phases, params, warm_start=warm_start, a0=a0,
                           initial_mode="round_robin", seed=seed,
                           n_iter=n_iter, fanout=4, use_engine=use_engine,
                           backend=backend,
                           batch_lock_events=batch_lock_events,
                           spec_window=spec_window, spec_mode=spec_mode,
                           quiesce_after=quiesce_after)
    return [_seq_result(run.result) for run in pipe.runs]
