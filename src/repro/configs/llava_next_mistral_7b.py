"""llava-next-mistral-7b [vlm] — 32L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=32000 — anyres tiling. [hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified]

The assignment specifies the transformer BACKBONE only; the vision frontend is
a STUB — ``input_specs()`` provides precomputed anyres patch embeddings that
occupy the first ``num_media_positions`` sequence slots.  Full attention ->
long_500k skipped.
"""
from repro.configs.base import BLOCK_ATTN, ModelConfig

CONFIG = ModelConfig(
    name="llava-next-mistral-7b",
    family="vlm",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=14336,
    vocab_size=32000,
    head_dim=128,
    block_pattern=(BLOCK_ATTN,),
    frontend="vision",
    num_media_positions=1152,  # anyres grid of CLIP patch embeddings (stub)
    rope_theta=1000000.0,
    act="silu",
    skip_shapes=("long_500k",),
)

SMOKE = ModelConfig(
    name="llava-smoke",
    family="vlm",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    d_ff=160,
    vocab_size=256,
    head_dim=16,
    block_pattern=(BLOCK_ATTN,),
    frontend="vision",
    num_media_positions=8,
    act="silu",
    skip_shapes=("long_500k",),
)
