"""gemma2-27b [dense] — 46L d_model=4608 32H (GQA kv=16) d_ff=36864 vocab=256000.

Local+global alternating attention, attention/final logit softcaps.
[arXiv:2408.00118; hf].

`long_500k` RUNS for this arch: local layers have O(window) KV and global
layers at decode are linear in KV length (sequence-sharded cache); see
DESIGN.md shape-skip notes.
"""
from repro.configs.base import BLOCK_ATTN, BLOCK_LOCAL, ModelConfig

CONFIG = ModelConfig(
    name="gemma2-27b",
    family="dense",
    num_layers=46,
    d_model=4608,
    num_heads=32,
    num_kv_heads=16,
    d_ff=36864,
    vocab_size=256000,
    head_dim=128,
    block_pattern=(BLOCK_LOCAL, BLOCK_ATTN),  # alternating sliding/global
    window_size=4096,
    logit_softcap=50.0,
    final_softcap=30.0,
    act="gelu",
    tie_embeddings=True,
)

SMOKE = ModelConfig(
    name="gemma2-smoke",
    family="dense",
    num_layers=4,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    d_ff=192,
    vocab_size=256,
    head_dim=16,
    block_pattern=(BLOCK_LOCAL, BLOCK_ATTN),
    window_size=16,
    logit_softcap=50.0,
    final_softcap=30.0,
    act="gelu",
    tie_embeddings=True,
)
