"""whisper-large-v3 [audio] — enc-dec, conv frontend stubbed per assignment.

32L d_model=1280 20H (GQA kv=20, i.e. MHA) d_ff=5120 vocab=51866.
[arXiv:2212.04356; unverified]

``seq_len`` is interpreted as the encoder frame count (the audio frontend is a
stub: ``input_specs`` provides precomputed frame embeddings); the decoder runs
min(448, seq_len // 8) text positions.  Full attention -> long_500k skipped.
"""
from repro.configs.base import BLOCK_ATTN, ModelConfig

CONFIG = ModelConfig(
    name="whisper-large-v3",
    family="audio",
    num_layers=32,
    num_decoder_layers=32,
    d_model=1280,
    num_heads=20,
    num_kv_heads=20,
    d_ff=5120,
    vocab_size=51866,
    block_pattern=(BLOCK_ATTN,),
    arch_type="encdec",
    frontend="audio",
    act="gelu",
    norm_eps=1e-5,
    skip_shapes=("long_500k",),
)

# Reduced config of the same family for CPU smoke tests.
SMOKE = ModelConfig(
    name="whisper-smoke",
    family="audio",
    num_layers=2,
    num_decoder_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=4,
    d_ff=128,
    vocab_size=256,
    block_pattern=(BLOCK_ATTN,),
    arch_type="encdec",
    frontend="audio",
    act="gelu",
    norm_eps=1e-5,
    skip_shapes=("long_500k",),
)
