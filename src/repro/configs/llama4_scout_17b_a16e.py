"""llama4-scout-17b-a16e [moe] — 48L d_model=5120 40H (GQA kv=8) d_ff=8192
vocab=202048, MoE 16 experts top-1 + shared expert, early fusion.
[hf:meta-llama/Llama-4-Scout-17B-16E; unverified]

The assignment line lists no local-attention note, so we conservatively treat
it as full attention -> long_500k skipped (DESIGN.md).
"""
from repro.configs.base import BLOCK_MOE, ModelConfig

CONFIG = ModelConfig(
    name="llama4-scout-17b-a16e",
    family="moe",
    num_layers=48,
    d_model=5120,
    num_heads=40,
    num_kv_heads=8,
    d_ff=8192,
    vocab_size=202048,
    head_dim=128,
    block_pattern=(BLOCK_MOE,),
    num_experts=16,
    top_k=1,
    moe_d_ff=8192,
    num_shared_experts=1,
    rope_theta=500000.0,
    act="silu",
    skip_shapes=("long_500k",),
)

SMOKE = ModelConfig(
    name="llama4-smoke",
    family="moe",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    d_ff=128,
    vocab_size=256,
    head_dim=16,
    block_pattern=(BLOCK_MOE,),
    num_experts=4,
    top_k=1,
    moe_d_ff=64,
    num_shared_experts=1,
    capacity_factor=8.0,   # no-drop for smoke/parity tests
    act="silu",
    skip_shapes=("long_500k",),
)
