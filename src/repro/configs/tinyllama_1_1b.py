"""tinyllama-1.1b [dense] — 22L d_model=2048 32H (GQA kv=4) d_ff=5632 vocab=32000.

llama2-arch small [arXiv:2401.02385; hf].  Full attention -> long_500k skipped.
"""
from repro.configs.base import BLOCK_ATTN, ModelConfig

CONFIG = ModelConfig(
    name="tinyllama-1.1b",
    family="dense",
    num_layers=22,
    d_model=2048,
    num_heads=32,
    num_kv_heads=4,
    d_ff=5632,
    vocab_size=32000,
    head_dim=64,
    block_pattern=(BLOCK_ATTN,),
    act="silu",
    skip_shapes=("long_500k",),
)

SMOKE = ModelConfig(
    name="tinyllama-smoke",
    family="dense",
    num_layers=2,
    d_model=64,
    num_heads=8,
    num_kv_heads=2,
    d_ff=176,
    vocab_size=256,
    head_dim=8,
    block_pattern=(BLOCK_ATTN,),
    act="silu",
    skip_shapes=("long_500k",),
)
