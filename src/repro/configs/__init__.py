"""Config registry: ``get_config(arch)`` / ``get_smoke_config(arch)``.

All ten assigned architectures plus the paper's own application config
(``gemma-assembly``, see repro.assembly).
"""
from __future__ import annotations

from repro.configs.base import (  # noqa: F401
    ALL_SHAPES,
    DECODE_32K,
    LONG_500K,
    PREFILL_32K,
    SHAPES_BY_NAME,
    TRAIN_4K,
    ModelConfig,
    ShapeConfig,
)

from repro.configs import (  # noqa: E402
    gemma2_27b,
    llama3_2_3b,
    llama4_scout_17b_a16e,
    llava_next_mistral_7b,
    qwen3_moe_30b_a3b,
    recurrentgemma_9b,
    rwkv6_7b,
    smollm_360m,
    tinyllama_1_1b,
    whisper_large_v3,
)

_MODULES = {
    "whisper-large-v3": whisper_large_v3,
    "llama3.2-3b": llama3_2_3b,
    "gemma2-27b": gemma2_27b,
    "smollm-360m": smollm_360m,
    "tinyllama-1.1b": tinyllama_1_1b,
    "llava-next-mistral-7b": llava_next_mistral_7b,
    "qwen3-moe-30b-a3b": qwen3_moe_30b_a3b,
    "llama4-scout-17b-a16e": llama4_scout_17b_a16e,
    "rwkv6-7b": rwkv6_7b,
    "recurrentgemma-9b": recurrentgemma_9b,
}

ARCH_IDS = tuple(_MODULES)


def get_config(arch: str) -> ModelConfig:
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; available: {sorted(_MODULES)}")
    return _MODULES[arch].CONFIG


def get_smoke_config(arch: str) -> ModelConfig:
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; available: {sorted(_MODULES)}")
    return _MODULES[arch].SMOKE


def get_shape(name: str) -> ShapeConfig:
    return SHAPES_BY_NAME[name]


def cells():
    """All runnable (arch, shape) dry-run cells, skips applied."""
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        for shape in cfg.shapes():
            yield arch, shape.name
