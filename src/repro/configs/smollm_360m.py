"""smollm-360m [dense] — 32L d_model=960 15H (GQA kv=5) d_ff=2560 vocab=49152.

llama-arch small [hf:HuggingFaceTB/SmolLM-135M family; hf].  Full attention ->
long_500k skipped.
"""
from repro.configs.base import BLOCK_ATTN, ModelConfig

CONFIG = ModelConfig(
    name="smollm-360m",
    family="dense",
    num_layers=32,
    d_model=960,
    num_heads=15,
    num_kv_heads=5,
    d_ff=2560,
    vocab_size=49152,
    head_dim=64,
    block_pattern=(BLOCK_ATTN,),
    act="silu",
    tie_embeddings=True,
    skip_shapes=("long_500k",),
)

SMOKE = ModelConfig(
    name="smollm-smoke",
    family="dense",
    num_layers=2,
    d_model=60,
    num_heads=3,
    num_kv_heads=1,
    d_ff=160,
    vocab_size=256,
    head_dim=20,
    block_pattern=(BLOCK_ATTN,),
    act="silu",
    tie_embeddings=True,
    skip_shapes=("long_500k",),
)
