"""recurrentgemma-9b [hybrid] — Griffin. 38L d_model=4096 16H (GQA kv=1, MQA)
d_ff=12288 vocab=256000 — RG-LRU + local attention at 2:1. [arXiv:2402.19427]

38 layers = 12 full (rglru, rglru, local_attn) periods + 2 unrolled rglru
layers.  Recurrent state is O(1) in sequence length and local attention has a
fixed window -> `long_500k` RUNS for this arch.
"""
from repro.configs.base import BLOCK_LOCAL, BLOCK_REC, ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-9b",
    family="hybrid",
    num_layers=38,
    d_model=4096,
    num_heads=16,
    num_kv_heads=1,
    d_ff=12288,
    vocab_size=256000,
    head_dim=256,
    block_pattern=(BLOCK_REC, BLOCK_REC, BLOCK_LOCAL),
    window_size=2048,
    rglru_conv_width=4,
    act="gelu",
    tie_embeddings=True,
)

SMOKE = ModelConfig(
    name="recurrentgemma-smoke",
    family="hybrid",
    num_layers=5,          # exercises the ragged tail (1 period + 2 unrolled)
    d_model=64,
    num_heads=4,
    num_kv_heads=1,
    d_ff=160,
    vocab_size=256,
    head_dim=16,
    block_pattern=(BLOCK_REC, BLOCK_REC, BLOCK_LOCAL),
    window_size=16,
    rglru_conv_width=4,
    act="gelu",
    tie_embeddings=True,
)
