"""Model configuration dataclasses for the assigned architecture pool.

Every architecture in the pool is expressed as a ``ModelConfig``; the model
builder (`repro.models.model.build_model`) dispatches on the per-layer
``block_pattern`` so that dense, MoE, SSM, hybrid, enc-dec and stub-frontend
archs share one transformer substrate.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

# Block kinds understood by the model builder.  A layer stack is described by
# a repeating ``block_pattern`` (period P); layers beyond the last full period
# are unrolled (e.g. recurrentgemma's 38 = 12*(rec,rec,attn) + (rec,rec)).
BLOCK_ATTN = "attn"          # full-attention transformer block
BLOCK_LOCAL = "local_attn"   # sliding-window attention block
BLOCK_MOE = "moe"            # attention + MoE FFN block
BLOCK_RWKV = "rwkv6"         # RWKV6 time-mix + channel-mix block
BLOCK_REC = "rglru"          # Griffin RG-LRU recurrent block


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One assigned (input-shape) cell."""

    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


TRAIN_4K = ShapeConfig("train_4k", 4096, 256, "train")
PREFILL_32K = ShapeConfig("prefill_32k", 32768, 32, "prefill")
DECODE_32K = ShapeConfig("decode_32k", 32768, 128, "decode")
LONG_500K = ShapeConfig("long_500k", 524288, 1, "decode")

ALL_SHAPES = (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)
SHAPES_BY_NAME = {s.name: s for s in ALL_SHAPES}


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                 # dense | moe | ssm | hybrid | audio | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0           # 0 -> d_model // num_heads

    # --- layer structure ---------------------------------------------------
    block_pattern: Tuple[str, ...] = (BLOCK_ATTN,)
    arch_type: str = "decoder"  # decoder | encdec
    num_decoder_layers: int = 0  # encdec only; 0 -> same as num_layers

    # --- attention ----------------------------------------------------------
    window_size: int = 4096     # for local_attn blocks
    logit_softcap: float = 0.0  # gemma2 attention-logit soft cap
    final_softcap: float = 0.0  # gemma2 final-logit soft cap
    rope_theta: float = 10000.0

    # --- MoE ----------------------------------------------------------------
    num_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0           # per-expert hidden dim
    num_shared_experts: int = 0
    capacity_factor: float = 1.25

    # --- recurrent families ---------------------------------------------------
    rwkv_head_dim: int = 64
    rglru_conv_width: int = 4
    rglru_c: float = 8.0        # Griffin's fixed constant c

    # --- frontends (stubs per the assignment) --------------------------------
    frontend: str = "none"      # none | audio | vision
    num_media_positions: int = 0  # vision: patch positions prepended to the sequence

    # --- numerics / misc ------------------------------------------------------
    act: str = "silu"
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    dtype: str = "bfloat16"

    # --- which assigned shape cells run (skips noted in DESIGN.md) ----------
    skip_shapes: Tuple[str, ...] = ()

    # --- distribution defaults (overridable by the launcher) -----------------
    remat: bool = True
    remat_policy: str = "full"   # full | dots (save matmul outputs) | none
    # Unroll the layer stack instead of lax.scan.  XLA's HloCostAnalysis
    # counts a while-loop body ONCE (verified: a scan of 10 matmuls reports
    # 1/10th of the flops), so the dry-run lowers with unroll_stack=True to
    # get exact per-cell flops/bytes/collective counts; production lowering
    # keeps the scan for O(1) HLO size.
    unroll_stack: bool = False

    # --- beyond-paper perf knobs (EXPERIMENTS.md §Perf) -----------------------
    ce_chunk: int = 0            # >0: cross-entropy in seq chunks (kills the
                                 # (B,S,V) f32 logits residency)
    attn_kv_chunk: int = 0       # >0: flash-style online-softmax attention
                                 # over KV chunks in the XLA path (kills the
                                 # (B,H,S,S) score residency)
    window_kv_cache: bool = False  # local_attn decode: ring cache of window
                                   # size instead of full seq length
    shard_rnn: bool = True       # shard recurrent width over 'model'; False
                                 # replicates the rnn block (trades 16x gate
                                 # compute for zero rnn-psum collectives)

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)
        if self.arch_type == "encdec" and self.num_decoder_layers == 0:
            object.__setattr__(self, "num_decoder_layers", self.num_layers)
        assert self.num_heads % max(self.num_kv_heads, 1) == 0, self.name

    # ------------------------------------------------------------------ utils
    @property
    def pattern_period(self) -> int:
        return len(self.block_pattern)

    def layer_kinds(self, num_layers: Optional[int] = None) -> Tuple[str, ...]:
        n = num_layers if num_layers is not None else self.num_layers
        p = self.block_pattern
        return tuple(p[i % len(p)] for i in range(n))

    @property
    def is_moe(self) -> bool:
        return self.num_experts > 0

    @property
    def attn_free(self) -> bool:
        return all(b in (BLOCK_RWKV, BLOCK_REC) for b in self.block_pattern)

    def shapes(self):
        return tuple(s for s in ALL_SHAPES if s.name not in self.skip_shapes)

    def param_count(self) -> int:
        """Analytic parameter count (used for 6ND model-FLOPs in roofline)."""
        d, v = self.d_model, self.vocab_size
        hd = self.head_dim
        total = v * d  # embeddings
        if not self.tie_embeddings:
            total += v * d
        kinds = self.layer_kinds()
        if self.arch_type == "encdec":
            kinds = kinds + self.layer_kinds(self.num_decoder_layers)
        for kind in kinds:
            total += 2 * d  # pre-norms (approximation: 2 norms / block)
            if kind in (BLOCK_ATTN, BLOCK_LOCAL, BLOCK_MOE):
                total += d * self.num_heads * hd + 2 * d * self.num_kv_heads * hd
                total += self.num_heads * hd * d
                if self.arch_type == "encdec":
                    # cross attention on decoder blocks (approx: count once per block)
                    total += d * self.num_heads * hd + 2 * d * self.num_kv_heads * hd
                    total += self.num_heads * hd * d
            if kind == BLOCK_MOE:
                total += d * self.num_experts  # router
                total += self.num_experts * 3 * d * self.moe_d_ff
                total += self.num_shared_experts * 3 * d * self.d_ff
            elif kind == BLOCK_RWKV:
                total += 4 * d * d + d * d  # r,k,v,g,o projections (approx)
                total += 3 * d * self.d_ff // 1  # channel mix (k,v,r)
            elif kind == BLOCK_REC:
                total += 2 * d * d  # in/out linear of recurrent block
                total += 3 * d * self.d_ff
            else:
                total += 3 * d * self.d_ff  # gated MLP
        return total

    def active_param_count(self) -> int:
        """Active params per token (MoE: only routed experts count)."""
        if not self.is_moe:
            return self.param_count()
        d = self.d_model
        total = self.param_count()
        n_moe = sum(1 for k in self.layer_kinds() if k == BLOCK_MOE)
        inactive = n_moe * (self.num_experts - self.top_k) * 3 * d * self.moe_d_ff
        return total - inactive
