"""qwen3-moe-30b-a3b [moe] — 48L d_model=2048 32H (GQA kv=4) per-expert
d_ff=768 vocab=151936, MoE 128 experts top-8. [hf:Qwen/Qwen3-30B-A3B; hf]

The paper's technique applies most directly here: experts are CCM shared
blocks, router statistics give task loads, dispatch volume gives comm edges
(see balance/expert_placement.py).  Full attention -> long_500k skipped.
"""
from repro.configs.base import BLOCK_MOE, ModelConfig

CONFIG = ModelConfig(
    name="qwen3-moe-30b-a3b",
    family="moe",
    num_layers=48,
    d_model=2048,
    num_heads=32,
    num_kv_heads=4,
    d_ff=6144,            # dense-equivalent (unused; all blocks are MoE)
    vocab_size=151936,
    head_dim=128,
    block_pattern=(BLOCK_MOE,),
    num_experts=128,
    top_k=8,
    moe_d_ff=768,
    rope_theta=1000000.0,
    act="silu",
    skip_shapes=("long_500k",),
)

SMOKE = ModelConfig(
    name="qwen3-moe-smoke",
    family="moe",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    d_ff=128,
    vocab_size=256,
    head_dim=16,
    block_pattern=(BLOCK_MOE,),
    num_experts=8,
    top_k=2,
    moe_d_ff=32,
    capacity_factor=8.0,   # no-drop for smoke/parity tests
    act="silu",
    skip_shapes=("long_500k",),
)
