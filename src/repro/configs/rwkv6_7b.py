"""rwkv6-7b [ssm] — Finch. 32L d_model=4096 (attn-free) d_ff=14336 vocab=65536.

Data-dependent decay WKV6 recurrence. [arXiv:2404.05892; hf]

Attention-free constant-size state -> `long_500k` RUNS for this arch.
"""
from repro.configs.base import BLOCK_RWKV, ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-7b",
    family="ssm",
    num_layers=32,
    d_model=4096,
    num_heads=64,          # rwkv heads = d_model / rwkv_head_dim
    num_kv_heads=64,
    d_ff=14336,
    vocab_size=65536,
    head_dim=64,
    block_pattern=(BLOCK_RWKV,),
    rwkv_head_dim=64,
    act="relu",            # rwkv channel-mix uses squared relu
)

SMOKE = ModelConfig(
    name="rwkv6-smoke",
    family="ssm",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=4,
    d_ff=128,
    vocab_size=256,
    head_dim=16,
    block_pattern=(BLOCK_RWKV,),
    rwkv_head_dim=16,
    act="relu",
)
