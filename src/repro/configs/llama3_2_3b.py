"""llama3.2-3b [dense] — 28L d_model=3072 24H (GQA kv=8) d_ff=8192 vocab=128256.

[hf:meta-llama/Llama-3.2-1B family; unverified].  Pure full attention ->
long_500k skipped (noted in DESIGN.md).
"""
from repro.configs.base import BLOCK_ATTN, ModelConfig

CONFIG = ModelConfig(
    name="llama3.2-3b",
    family="dense",
    num_layers=28,
    d_model=3072,
    num_heads=24,
    num_kv_heads=8,
    d_ff=8192,
    vocab_size=128256,
    head_dim=128,
    block_pattern=(BLOCK_ATTN,),
    rope_theta=500000.0,
    act="silu",
    tie_embeddings=True,
    skip_shapes=("long_500k",),
)

SMOKE = ModelConfig(
    name="llama3.2-smoke",
    family="dense",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    d_ff=160,
    vocab_size=256,
    head_dim=16,
    block_pattern=(BLOCK_ATTN,),
    rope_theta=500000.0,
    act="silu",
    tie_embeddings=True,
    skip_shapes=("long_500k",),
)
