from repro.data.pipeline import SyntheticLMData, make_batch  # noqa: F401
