"""Deterministic synthetic LM data pipeline.

Documents are variable-length Zipf-ish token runs with a learnable
(markov-flavored) structure so training loss actually decreases; batches are
built by packing documents into fixed-length rows.  Every batch is a pure
function of (seed, step, shard) — restart-safe by construction, which is what
the checkpoint/restart test relies on.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import numpy as np

from repro.configs.base import ModelConfig


@dataclasses.dataclass
class SyntheticLMData:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    num_shards: int = 1
    shard: int = 0

    def doc_lengths(self, rng) -> np.ndarray:
        # log-normal document lengths (the seqpack balancer's raw material)
        return np.clip(rng.lognormal(5.0, 1.0, size=64).astype(np.int64),
                       16, 4 * self.seq_len)

    def _tokens(self, rng, n: int) -> np.ndarray:
        # order-1 structure: t_{i+1} = (a * t_i + b) % V on a small alphabet
        v = min(self.vocab_size, 251)
        a, b = 31, int(rng.integers(1, v))
        t0 = int(rng.integers(0, v))
        out = np.empty(n, np.int64)
        cur = t0
        for i in range(n):
            out[i] = cur
            cur = (a * cur + b) % v
        noise = rng.random(n) < 0.1
        out[noise] = rng.integers(0, v, noise.sum())
        return out

    def batch(self, step: int) -> Dict[str, np.ndarray]:
        rng = np.random.default_rng(
            (self.seed * 1_000_003 + step) * 97 + self.shard)
        rows = self.global_batch // self.num_shards
        tokens = np.empty((rows, self.seq_len + 1), np.int64)
        for r in range(rows):
            buf = []
            total = 0
            while total <= self.seq_len:
                n = int(rng.lognormal(5.0, 1.0))
                n = max(16, min(n, self.seq_len + 1 - total)) \
                    if total + 16 <= self.seq_len else self.seq_len + 1 - total
                buf.append(self._tokens(rng, n))
                total += n
            tokens[r] = np.concatenate(buf)[: self.seq_len + 1]
        return {"tokens": tokens[:, :-1].astype(np.int32),
                "targets": tokens[:, 1:].astype(np.int32)}


def make_batch(cfg: ModelConfig, seq_len: int, global_batch: int, step: int,
               seed: int = 0) -> Dict[str, np.ndarray]:
    """Arch-aware batch builder (stub frontends get synthetic embeddings)."""
    data = SyntheticLMData(cfg.vocab_size, seq_len, global_batch, seed=seed)
    rng = np.random.default_rng(seed * 7919 + step)
    if cfg.arch_type == "encdec":
        from repro.models.encdec import decoder_len
        s_dec = decoder_len(cfg, seq_len)
        dec = SyntheticLMData(cfg.vocab_size, s_dec, global_batch, seed=seed)
        b = dec.batch(step)
        return {
            "audio_embed": rng.standard_normal(
                (global_batch, seq_len, cfg.d_model)).astype(np.float32) * 0.1,
            "tokens": b["tokens"],
            "targets": b["targets"],
        }
    if cfg.frontend == "vision":
        s_text = seq_len - cfg.num_media_positions
        text = SyntheticLMData(cfg.vocab_size, s_text, global_batch, seed=seed)
        b = text.batch(step)
        b["media_embed"] = rng.standard_normal(
            (global_batch, cfg.num_media_positions, cfg.d_model)
        ).astype(np.float32) * 0.1
        return b
    return data.batch(step)
