"""Cost-model training loop (paper §VI-D): mini-batch AdamW on the
under-penalized RMSE, with standard scaling and Algorithm-1 data reduction.
Targets are log-transformed (durations span orders of magnitude).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.costmodel.losses import mae, rmse, under_penalized_rmse
from repro.costmodel.network import FNNConfig, fnn_apply, fnn_init
from repro.costmodel.reduction import dynamic_data_reduce
from repro.costmodel.scaler import StandardScaler
from repro.optim import adamw_init, adamw_update


def _augment(features: np.ndarray) -> np.ndarray:
    """Append log1p features: task durations are ~log-linear in the raw
    counts (rows x cols x quad), so this makes the FNN's job easy."""
    return np.concatenate([features, np.log1p(np.abs(features))], axis=1)


@dataclasses.dataclass
class CostModel:
    cfg: FNNConfig
    params: Dict
    bn_state: Dict
    scaler: StandardScaler
    log_target: bool = True

    def predict(self, features: np.ndarray) -> np.ndarray:
        x = jnp.asarray(self.scaler.transform(_augment(features)), jnp.float32)
        pred, _ = fnn_apply(self.params, self.bn_state, x, self.cfg,
                            train=False)
        pred = np.asarray(pred)
        return np.exp(pred) if self.log_target else pred


@functools.partial(jax.jit, static_argnames=("cfg", "alpha"))
def _train_step(params, bn_state, opt_state, xb, yb, rng, cfg: FNNConfig,
                alpha: float):
    def loss_fn(p):
        pred, new_bn = fnn_apply(p, bn_state, xb, cfg, train=True, rng=rng)
        return under_penalized_rmse(pred, yb, alpha), new_bn

    (loss, new_bn), grads = jax.value_and_grad(loss_fn, has_aux=True)(params)
    params, opt_state = adamw_update(grads, opt_state, params, 1e-3,
                                     weight_decay=1e-4)
    return params, new_bn, opt_state, loss


def train_cost_model(features: np.ndarray, durations: np.ndarray, *,
                     epochs: int = 60, batch_size: int = 256,
                     alpha: float = 0.3, reduce_to: Optional[int] = None,
                     seed: int = 0, log_target: bool = True,
                     hidden=(200, 200, 200, 200), dropout: float = 0.1,
                     ) -> Tuple[CostModel, Dict]:
    """Returns (model, history).  ``reduce_to`` applies Algorithm 1 first."""
    features = np.asarray(features, np.float64)
    durations = np.asarray(durations, np.float64)
    if reduce_to is not None and reduce_to < features.shape[0]:
        keep = dynamic_data_reduce(durations, reduce_to, seed=seed)
        features, durations = features[keep], durations[keep]

    features = _augment(features)
    scaler = StandardScaler().fit(features)
    x = jnp.asarray(scaler.transform(features), jnp.float32)
    y = np.log(np.maximum(durations, 1e-12)) if log_target else durations
    y = jnp.asarray(y, jnp.float32)

    cfg = FNNConfig(in_dim=features.shape[1], hidden=tuple(hidden),
                    dropout=dropout)
    key = jax.random.key(seed)
    key, sub = jax.random.split(key)
    params, bn_state = fnn_init(sub, cfg)
    opt_state = adamw_init(params)

    n = x.shape[0]
    bs = min(batch_size, n)
    steps = max(n // bs, 1)
    history = {"loss": []}
    rng_np = np.random.default_rng(seed)
    for ep in range(epochs):
        perm = rng_np.permutation(n)
        ep_loss = 0.0
        for s in range(steps):
            idx = perm[s * bs:(s + 1) * bs]
            key, sub = jax.random.split(key)
            params, bn_state, opt_state, loss = _train_step(
                params, bn_state, opt_state, x[idx], y[idx], sub, cfg, alpha)
            ep_loss += float(loss)
        history["loss"].append(ep_loss / steps)
    model = CostModel(cfg, params, bn_state, scaler, log_target)
    return model, history


def evaluate_cost_model(model: CostModel, features: np.ndarray,
                        durations: np.ndarray) -> Dict[str, float]:
    pred = model.predict(features)
    p = jnp.asarray(pred)
    t = jnp.asarray(durations)
    over = np.mean(pred >= durations)
    return {
        "rmse": float(rmse(p, t)),
        "mae": float(mae(p, t)),
        "under_rmse": float(under_penalized_rmse(p, t, 0.3)),
        "over_predict_frac": float(over),
        "rel_err_median": float(np.median(np.abs(pred - durations) /
                                          np.maximum(durations, 1e-12))),
    }
