"""Standard scaler (paper §VI-D.1): zero mean / unit variance per feature."""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class StandardScaler:
    mean: np.ndarray = None
    std: np.ndarray = None

    def fit(self, x: np.ndarray) -> "StandardScaler":
        self.mean = x.mean(0)
        self.std = x.std(0)
        self.std = np.where(self.std < 1e-12, 1.0, self.std)
        return self

    def transform(self, x: np.ndarray) -> np.ndarray:
        return (x - self.mean) / self.std

    def fit_transform(self, x: np.ndarray) -> np.ndarray:
        return self.fit(x).transform(x)
