from repro.costmodel.network import FNNConfig, fnn_apply, fnn_init  # noqa: F401
from repro.costmodel.losses import (mae, rmse,  # noqa: F401
                                    under_penalized_rmse)
from repro.costmodel.reduction import dynamic_data_reduce  # noqa: F401
from repro.costmodel.scaler import StandardScaler  # noqa: F401
from repro.costmodel.train import CostModel, train_cost_model  # noqa: F401
