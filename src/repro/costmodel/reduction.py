"""Dynamic data-point reduction (paper Appendix B, Algorithm 1).

Short-duration tasks vastly outnumber long ones; the algorithm repeatedly
finds the fullest of ``n_bins`` histogram bins (over the target value) and
randomly drops ``theta`` of its rows until only ``n_target`` remain.
``theta=0.5`` is the paper's recommended trade-off.
"""
from __future__ import annotations

import numpy as np


def dynamic_data_reduce(values: np.ndarray, n_target: int, *,
                        n_bins: int = 32, theta: float = 0.5,
                        seed: int = 0) -> np.ndarray:
    """Returns indices of the rows to KEEP (<= n_target + rounding)."""
    assert 0.0 < theta < 1.0
    n_rows = values.shape[0]
    if n_rows <= n_target:
        return np.arange(n_rows)
    rng = np.random.default_rng(seed)
    edges = np.histogram_bin_edges(values, bins=n_bins)
    which = np.clip(np.digitize(values, edges[1:-1]), 0, n_bins - 1)
    bins = [list(np.nonzero(which == b)[0]) for b in range(n_bins)]
    n_drop = n_rows - n_target
    while n_drop > 0:
        b_max = int(np.argmax([len(b) for b in bins]))
        n_max = len(bins[b_max])
        if n_max == 0:
            break
        n = min(int(np.ceil(theta * n_max)), n_drop)
        drop = rng.choice(n_max, size=n, replace=False)
        keep_mask = np.ones(n_max, bool)
        keep_mask[drop] = False
        bins[b_max] = [t for t, k in zip(bins[b_max], keep_mask) if k]
        n_drop -= n
    kept = np.concatenate([np.array(b, np.int64) for b in bins if b])
    kept.sort()
    return kept
