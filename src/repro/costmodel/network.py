"""Task-duration prediction FNN (paper §VI-D.2) in pure JAX.

Architecture per the paper: feed-forward, 4 hidden layers x 200 neurons,
batch normalization on hidden layers, dropout, LeakyReLU (eq. 31) activation.
Trained with AdamW (repro.optim) on mini-batches.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class FNNConfig:
    in_dim: int
    hidden: Tuple[int, ...] = (200, 200, 200, 200)
    dropout: float = 0.1
    leaky_slope: float = 0.01
    bn_momentum: float = 0.9
    bn_eps: float = 1e-5


def leaky_relu(x, slope: float = 0.01):
    """Eq. (31): f(x) = x * 1_{R+}(x) + 0.01 x * 1_{R-*}(x)."""
    return jnp.where(x >= 0, x, slope * x)


def fnn_init(key, cfg: FNNConfig) -> Dict:
    params = {"layers": []}
    bn_state = {"layers": []}
    dims = (cfg.in_dim,) + cfg.hidden
    keys = jax.random.split(key, len(cfg.hidden) + 1)
    for li, (d_in, d_out) in enumerate(zip(dims[:-1], dims[1:])):
        w = jax.random.normal(keys[li], (d_in, d_out)) * jnp.sqrt(2.0 / d_in)
        params["layers"].append({
            "w": w.astype(jnp.float32),
            "b": jnp.zeros((d_out,), jnp.float32),
            "bn_scale": jnp.ones((d_out,), jnp.float32),
            "bn_bias": jnp.zeros((d_out,), jnp.float32),
        })
        bn_state["layers"].append({
            "mean": jnp.zeros((d_out,), jnp.float32),
            "var": jnp.ones((d_out,), jnp.float32),
        })
    params["out_w"] = (jax.random.normal(keys[-1], (dims[-1], 1))
                       * jnp.sqrt(1.0 / dims[-1])).astype(jnp.float32)
    params["out_b"] = jnp.zeros((1,), jnp.float32)
    return params, bn_state


def fnn_apply(params, bn_state, x, cfg: FNNConfig, *, train: bool,
              rng=None):
    """Returns (predictions (B,), new_bn_state)."""
    new_bn = {"layers": []}
    h = x
    for li, layer in enumerate(params["layers"]):
        h = h @ layer["w"] + layer["b"]
        if train:
            mu = h.mean(0)
            var = h.var(0)
            st = bn_state["layers"][li]
            new_bn["layers"].append({
                "mean": cfg.bn_momentum * st["mean"] + (1 - cfg.bn_momentum) * mu,
                "var": cfg.bn_momentum * st["var"] + (1 - cfg.bn_momentum) * var,
            })
        else:
            st = bn_state["layers"][li]
            mu, var = st["mean"], st["var"]
            new_bn["layers"].append(dict(st))
        h = (h - mu) * jax.lax.rsqrt(var + cfg.bn_eps)
        h = h * layer["bn_scale"] + layer["bn_bias"]
        h = leaky_relu(h, cfg.leaky_slope)
        if train and cfg.dropout > 0:
            assert rng is not None
            rng, sub = jax.random.split(rng)
            keep = jax.random.bernoulli(sub, 1 - cfg.dropout, h.shape)
            h = jnp.where(keep, h / (1 - cfg.dropout), 0.0)
    out = h @ params["out_w"] + params["out_b"]
    return out[:, 0], new_bn
