"""Loss functions for the cost model (paper §VI-D.3).

The under-penalized RMSE (eq. 32) discounts under-predictions by ``alpha``:
over-predicted compute times hurt load balance more (an over-predicted task
makes CCM-LB leave real work behind), so the trained model "barely
over-predicts".
"""
from __future__ import annotations

import jax.numpy as jnp


def rmse(pred, truth):
    return jnp.sqrt(jnp.mean(jnp.square(pred - truth)))


def mae(pred, truth):
    return jnp.mean(jnp.abs(pred - truth))


def under_penalized_rmse(pred, truth, alpha: float = 0.3):
    """sqrt(mean e_i) with e_i = (g-p)^2 if g>=p else alpha*(g-p)^2 (eq. 32)."""
    err = pred - truth
    sq = jnp.square(err)
    weighted = jnp.where(err >= 0, sq, alpha * sq)
    return jnp.sqrt(jnp.mean(weighted))
