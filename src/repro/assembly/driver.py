"""End-to-end assembly comparison (paper Fig. 5): A baseline / B
overdecomposed / C overdecomposed + CCM-LB.

A — the solver's native layout: every rank computes its full dense row-block,
    including non-coupling (zero) entries, as one unsplittable unit;
B — overdecomposed tasks co-located at their slab's home (zero tiles are
    skipped — the paper's ~1.3x);
C — CCM-LB redistributes the tasks using *predicted* durations from the cost
    model; reported makespan uses the TRUE durations plus the wave-based
    homing transfer time.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import numpy as np

from repro.assembly.execute import analytic_durations, measure_durations
from repro.assembly.homing import HomingPlan, plan_homing
from repro.assembly.problem import AssemblyProblem, build_problem
from repro.core import CCMParams, CCMState, ccm_lb
from repro.core.problem import initial_assignment


@dataclasses.dataclass
class AssemblyRun:
    problem: AssemblyProblem
    durations_true: np.ndarray
    durations_pred: np.ndarray
    makespan_baseline: float          # A
    makespan_overdecomposed: float    # B
    makespan_ccmlb: float             # C (compute only)
    homing: Optional[HomingPlan]      # C transfer phase
    imbalance_before: float
    imbalance_after: float
    n_off_home_ranks: int
    lb_result: object

    @property
    def speedup_overdecomposed(self) -> float:
        return self.makespan_baseline / self.makespan_overdecomposed

    @property
    def speedup_ccmlb(self) -> float:
        total_c = self.makespan_ccmlb + (self.homing.est_time_s
                                         if self.homing else 0.0)
        return self.makespan_baseline / total_c


def baseline_makespan(problem: AssemblyProblem,
                      flops_per_s: float = 2e9) -> float:
    """Mode A: dense row-block per rank, zero entries computed too."""
    geom = problem.geom
    n = geom.n
    worst = 0.0
    for rows in problem.rank_rows:
        # dense: every (row, col) pair at the tile's quadrature depth.
        # approximate cost per row set: sum over column tiles of nr*nc*q.
        cost = 0.0
        for c0 in range(0, n, 512):
            csel = np.arange(c0, min(c0 + 512, n))
            pr = geom.points[rows]
            pc = geom.points[csel]
            d = np.sqrt(((pr[:, None] - pc[None]) ** 2).sum(-1))
            dmin = d.min() if d.size else np.inf
            q = (192 if dmin < 0.005 else 64 if dmin < 0.05
                 else 16 if dmin < 0.2 else 4)
            cost += len(rows) * len(csel) * q * 8.0 / flops_per_s
        worst = max(worst, cost)
    return worst


def run_assembly_comparison(
        n_unknowns: int = 4096, num_ranks: int = 16, *,
        durations: str = "analytic", cost_model=None,
        ccm_params: Optional[CCMParams] = None, mem_cap_frac: float = 0.6,
        seed: int = 0, n_iter: int = 4, fanout: int = 4,
        task_limit_u: int = 96, use_engine: bool = True) -> AssemblyRun:
    problem = build_problem(n_unknowns, num_ranks, seed=seed,
                            task_limit_u=task_limit_u)
    if durations == "measured":
        durations_true = measure_durations(problem)
    else:
        durations_true = analytic_durations(problem)

    # cost model predictions (perfect predictions if no model given)
    if cost_model is not None:
        durations_pred = cost_model.predict(problem.features())
    else:
        durations_pred = durations_true.copy()

    # memory cap: fraction of what a rank would need to hold ALL slabs
    total_block_bytes = problem.slab_bytes.sum()
    per_rank_all = total_block_bytes / num_ranks
    mem_cap = max(per_rank_all * 4.0 * mem_cap_frac, problem.slab_bytes.max() * 3)

    params = ccm_params or CCMParams(alpha=1.0, beta=2e-10, gamma=1e-12,
                                     delta=2e-10)
    phase_pred = problem.to_phase(durations_pred, mem_cap_bytes=mem_cap)
    a0 = initial_assignment(phase_pred, "home")

    # B: overdecomposed, tasks at home
    loads_b = np.bincount(a0, weights=durations_true, minlength=num_ranks)
    makespan_b = float(loads_b.max())

    # C: CCM-LB on predictions, evaluated with true durations
    res = ccm_lb(phase_pred, a0, params, n_iter=n_iter, fanout=fanout,
                 seed=seed, use_engine=use_engine)
    loads_c = np.bincount(res.assignment, weights=durations_true,
                          minlength=num_ranks)
    makespan_c = float(loads_c.max())

    # homing: every off-home rank holding a slab copy ships it home in waves
    st = res.state
    items_bytes, items_home, items_loc = [], [], []
    for b in range(phase_pred.num_blocks):
        holders = np.nonzero(st.block_count[:, b] > 0)[0]
        for r in holders:
            if r != phase_pred.block_home[b]:
                items_bytes.append(phase_pred.block_size[b])
                items_home.append(phase_pred.block_home[b])
                items_loc.append(r)
    homing = None
    if items_bytes:
        ranks_per_node = 2
        n_nodes = (num_ranks + ranks_per_node - 1) // ranks_per_node
        node_used = np.zeros(n_nodes)
        for b in range(phase_pred.num_blocks):
            holders = np.nonzero(st.block_count[:, b] > 0)[0]
            for r in holders:
                node_used[r // ranks_per_node] += phase_pred.block_size[b]
        homing = plan_homing(
            np.array(items_bytes), np.array(items_home, np.int64),
            np.array(items_loc, np.int64), ranks_per_node=ranks_per_node,
            node_mem_cap=float(node_used.max() + phase_pred.block_size.max() * 2),
            node_mem_used=node_used)

    st0 = CCMState.build(phase_pred, a0, params)
    return AssemblyRun(
        problem=problem,
        durations_true=durations_true,
        durations_pred=durations_pred,
        makespan_baseline=baseline_makespan(problem),
        makespan_overdecomposed=makespan_b,
        makespan_ccmlb=makespan_c,
        homing=homing,
        imbalance_before=float(loads_b.max() / max(loads_b.mean(), 1e-12) - 1),
        imbalance_after=float(loads_c.max() / max(loads_c.mean(), 1e-12) - 1),
        n_off_home_ranks=len(items_bytes),
        lb_result=res,
    )
