"""Task execution: the MoM-analogue tile kernel, in JAX, with measured
durations (the ground truth the cost model learns — paper §VI-D collects
task data the same way).

Each task computes its tile of the interaction matrix with a regularized
Green's-function quadrature whose depth (``quad_order``) was set by the
near-singularity of the DOF pair — the source of the heavy-tailed costs.
The Pallas TPU kernel (repro.kernels.assembly) implements the same tile
computation with VMEM block tiling; this module is the portable path and the
oracle the kernel is tested against.
"""
from __future__ import annotations

import functools
import time
from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from repro.assembly.problem import AssemblyProblem, AssemblyTask

WAVENUMBER = 3.0


@functools.partial(jax.jit, static_argnames=("quad_order",))
def tile_kernel(pr, pc, couple, quad_order: int):
    """pr: (nr,3), pc: (nc,3), couple: (nr,nc) bool -> (nr,nc) f32 tile.

    Z_ij = sum_q w_q * cos(k d r_q) / (d + eps_q) over a quadrature ladder —
    a real-valued stand-in for the singular Green's function integral whose
    cost scales with quad_order like the true near-interaction refinement.
    """
    d = jnp.sqrt(((pr[:, None] - pc[None]) ** 2).sum(-1) + 1e-12)
    acc = jnp.zeros_like(d)
    for q in range(quad_order):
        r_q = (q + 0.5) / quad_order
        w_q = 1.0 / quad_order
        acc = acc + w_q * jnp.cos(WAVENUMBER * d * r_q) / (d + 0.05 * r_q + 1e-3)
    return jnp.where(couple, acc, 0.0)


def _task_inputs(problem: AssemblyProblem, t: AssemblyTask):
    g = problem.geom
    pr = jnp.asarray(g.points[t.rows], jnp.float32)
    pc = jnp.asarray(g.points[t.cols], jnp.float32)
    reg_r = g.region[t.rows][:, None]
    reg_c = g.region[t.cols][None, :]
    couple = jnp.asarray((reg_r == reg_c) | (reg_r == 2) | (reg_c == 2))
    return pr, pc, couple


def execute_task(problem: AssemblyProblem, t: AssemblyTask) -> np.ndarray:
    pr, pc, couple = _task_inputs(problem, t)
    return np.asarray(tile_kernel(pr, pc, couple, t.quad_order))


def measure_durations(problem: AssemblyProblem, *, repeats: int = 2,
                      warmup: bool = True) -> np.ndarray:
    """Wall-clock seconds per task (min over repeats)."""
    # warm the jit cache per (shape, quad_order) signature
    if warmup:
        seen = set()
        for t in problem.tasks:
            sig = (len(t.rows), len(t.cols), t.quad_order)
            if sig not in seen:
                seen.add(sig)
                execute_task(problem, t)
    out = np.zeros(problem.num_tasks)
    for i, t in enumerate(problem.tasks):
        pr, pc, couple = _task_inputs(problem, t)
        best = np.inf
        for _ in range(repeats):
            t0 = time.perf_counter()
            tile_kernel(pr, pc, couple, t.quad_order).block_until_ready()
            best = min(best, time.perf_counter() - t0)
        out[i] = best
    return out


def analytic_durations(problem: AssemblyProblem,
                       flops_per_s: float = 2e9) -> np.ndarray:
    """Deterministic cost model used by fast tests: FLOPs / rate."""
    out = np.zeros(problem.num_tasks)
    for i, t in enumerate(problem.tasks):
        out[i] = (len(t.rows) * len(t.cols) * t.quad_order * 8.0) / flops_per_s
    return out
