"""Geometry generator for the Gemma-analogue problem (paper §VI-A).

Mimics the yaml_rect_cavity_2_slots_curve topology: a conducting block with
an interior cavity coupled to the exterior through two slots.  Unknowns
(RWG-like DOFs) are sampled on three regions:

  region 0 — exterior surface (plane-wave excited),
  region 1 — interior cavity wall,
  region 2 — the two slots (thin strips that couple 0 <-> 1).

Coupling rule (drives the zero blocks of §VI-B): two DOFs interact iff they
share a region, or one of them lies on a slot.  Interactions between nearby
DOFs are near-singular -> higher quadrature order -> the heavy-tailed task
costs that cause the load imbalance this paper exists to fix.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class Geometry:
    points: np.ndarray      # (n, 3) DOF locations
    region: np.ndarray      # (n,) in {0, 1, 2}
    elem_type: np.ndarray   # (n,) in {0 tri, 1 bar} (slots use bar elements)

    @property
    def n(self) -> int:
        return self.points.shape[0]

    def couples(self, region_a: int, region_b: int) -> bool:
        return region_a == region_b or region_a == 2 or region_b == 2


def make_cavity_geometry(n_unknowns: int, seed: int = 0,
                         slot_frac: float = 0.04) -> Geometry:
    rng = np.random.default_rng(seed)
    n_slot = max(8, int(n_unknowns * slot_frac))
    n_rest = n_unknowns - n_slot
    n_out = n_rest * 6 // 10
    n_in = n_rest - n_out

    def cube_surface(n, lo, hi):
        face = rng.integers(0, 6, n)
        pts = rng.uniform(lo, hi, size=(n, 3))
        axis = face % 3
        val = np.where(face < 3, lo, hi)
        pts[np.arange(n), axis] = val
        return pts

    outer = cube_surface(n_out, 0.0, 2.0)
    inner = cube_surface(n_in, 0.1, 1.9)
    # two slots: thin strips on the x=0 and x=2 faces
    t = rng.uniform(0, 1, n_slot)
    half = n_slot // 2
    slot = np.zeros((n_slot, 3))
    slot[:half] = np.stack([np.zeros(half), 0.85 + 0.3 * t[:half],
                            np.full(half, 1.0)], 1)
    slot[half:] = np.stack([np.full(n_slot - half, 2.0),
                            0.85 + 0.3 * t[half:],
                            np.full(n_slot - half, 1.0)], 1)

    points = np.concatenate([outer, inner, slot])
    region = np.concatenate([np.zeros(n_out), np.ones(n_in),
                             np.full(n_slot, 2)]).astype(np.int64)
    elem_type = (region == 2).astype(np.int64)  # slots are bar elements
    # DOF numbering follows the mesh (region-contiguous, spatially sorted) —
    # this is what makes the solver's row-block layout imbalanced: ranks
    # owning slot/cavity rows get the near-singular, coupling-dense work.
    order = np.lexsort((points[:, 2], points[:, 1], points[:, 0], region))
    return Geometry(points[order], region[order], elem_type[order])
