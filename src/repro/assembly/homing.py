"""Wave-based homing transfers (paper §VI-C).

After assembly, slabs computed (in whole or part) off their home rank must be
shipped home without exceeding node memory: transfers proceed in *waves*; in
each wave a slab may move only if the destination node has room for it (the
source frees its copy at the end of the wave).  When two ranks need to swap
but neither has headroom, one slab detours via the compute node with the most
free memory (the paper's escape hatch).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import numpy as np


@dataclasses.dataclass
class HomingPlan:
    waves: List[List[Tuple[int, int, int]]]   # per wave: (slab, src_node, dst_node)
    detours: int
    total_bytes: float
    est_time_s: float

    @property
    def n_off_home(self) -> int:
        return sum(len(w) for w in self.waves)


def plan_homing(slab_bytes: np.ndarray, slab_home_rank: np.ndarray,
                slab_location_rank: np.ndarray, *, ranks_per_node: int = 2,
                node_mem_cap: float, node_mem_used: np.ndarray,
                bandwidth: float = 12.5e9) -> HomingPlan:
    """All arrays indexed by slab; locations/homes are RANKS, capacity is per
    NODE (the paper limits concurrent shared blocks per node, not per rank).
    ``node_mem_used`` (n_nodes,) is the post-assembly residency per node.
    """
    n_slabs = slab_bytes.shape[0]
    node_of = lambda r: int(r) // ranks_per_node
    free = node_mem_cap - np.asarray(node_mem_used, np.float64).copy()
    pending = [s for s in range(n_slabs)
               if node_of(slab_location_rank[s]) != node_of(slab_home_rank[s])]
    waves: List[List[Tuple[int, int, int]]] = []
    detours = 0
    total_bytes = 0.0
    # larger slabs first: hardest to place
    pending.sort(key=lambda s: -slab_bytes[s])
    guard = 0
    while pending and guard < 10 * n_slabs + 10:
        guard += 1
        wave: List[Tuple[int, int, int]] = []
        moved = []
        freed: Dict[int, float] = {}
        for s in pending:
            src, dst = node_of(slab_location_rank[s]), node_of(slab_home_rank[s])
            if free[dst] >= slab_bytes[s]:
                free[dst] -= slab_bytes[s]
                freed[src] = freed.get(src, 0.0) + slab_bytes[s]
                wave.append((s, src, dst))
                slab_location_rank[s] = slab_home_rank[s]
                total_bytes += slab_bytes[s]
                moved.append(s)
        if not moved:
            # deadlock (mutual swaps with no headroom): detour the largest
            # pending slab via the node with the most free memory
            s = pending[0]
            spare = int(np.argmax(free))
            if free[spare] < slab_bytes[s]:
                raise RuntimeError("homing infeasible: no node has headroom")
            src = node_of(slab_location_rank[s])
            free[spare] -= slab_bytes[s]
            wave.append((s, src, spare))
            # it now lives on the spare node; next wave can take it home
            slab_location_rank[s] = spare * ranks_per_node
            freed[src] = freed.get(src, 0.0) + slab_bytes[s]
            total_bytes += slab_bytes[s]
            detours += 1
        # sources release their copies at the end of the wave
        for node, b in freed.items():
            free[node] += b
        waves.append(wave)
        pending = [s for s in pending
                   if node_of(slab_location_rank[s]) != node_of(slab_home_rank[s])]
        pending.sort(key=lambda s: -slab_bytes[s])
    if pending:
        raise RuntimeError("homing did not converge")
    return HomingPlan(waves, detours, total_bytes, total_bytes / bandwidth)
