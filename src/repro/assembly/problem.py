"""Overdecomposition of the matrix assembly into shared blocks and tasks
(paper §VI-B).

The solver prescribes a row-block per rank.  Each rank's block is split into
*slabs* of contiguous memory (all of the rank's rows x a column chunk) — the
CCM shared blocks, homed at the owning rank.  Work is overdecomposed by
limiting each task to at most ``u`` rows x ``u`` columns of a slab; separate
tasks handle different element-type pairs; tasks whose DOF pair produces no
coupling (zero blocks) are never instantiated.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional

import numpy as np

from repro.assembly.geometry import Geometry, make_cavity_geometry
from repro.core.problem import Phase


@dataclasses.dataclass
class AssemblyTask:
    task_id: int
    slab: int               # shared block id
    home_rank: int
    rows: np.ndarray        # global DOF ids
    cols: np.ndarray
    elem_pair: int          # 0 tri-tri, 1 tri-bar / bar-tri, 2 bar-bar
    quad_order: int         # near-singular refinement level
    n_interactions: int

    def features(self, geom: Geometry) -> np.ndarray:
        """Inputs to the cost-model FNN (paper §VI-D: element types etc.)."""
        pr = geom.points[self.rows]
        pc = geom.points[self.cols]
        d_min = _min_dist(pr, pc)
        return np.array([
            len(self.rows), len(self.cols), self.n_interactions,
            float(self.elem_pair == 0), float(self.elem_pair == 1),
            float(self.elem_pair == 2), self.quad_order, d_min,
        ], np.float64)


FEATURE_NAMES = ("n_rows", "n_cols", "n_interactions", "is_tri_tri",
                 "is_tri_bar", "is_bar_bar", "quad_order", "min_dist")


def _min_dist(a: np.ndarray, b: np.ndarray) -> float:
    d = np.sqrt(((a[:, None] - b[None]) ** 2).sum(-1))
    return float(d.min()) if d.size else np.inf


def _quad_order(d_min: float) -> int:
    """Near-singular refinement: closer DOF sets need deeper quadrature.

    The steep ladder is what produces the paper's heavy-tailed task costs
    (singular Green's function for nearby DOFs, §VI-A)."""
    if d_min < 0.005:
        return 192
    if d_min < 0.05:
        return 64
    if d_min < 0.2:
        return 16
    return 4


@dataclasses.dataclass
class AssemblyProblem:
    geom: Geometry
    num_ranks: int
    rank_rows: List[np.ndarray]      # rows owned per rank (solver layout)
    slab_cols: List[np.ndarray]      # columns per slab
    slab_home: np.ndarray            # (n_slabs,)
    slab_bytes: np.ndarray           # (n_slabs,)
    tasks: List[AssemblyTask]

    @property
    def num_tasks(self) -> int:
        return len(self.tasks)

    def features(self) -> np.ndarray:
        return np.stack([t.features(self.geom) for t in self.tasks])

    def to_phase(self, durations: np.ndarray, *, mem_cap_bytes: float,
                 comm_byte: float = 8.0,
                 rank_speed: Optional[np.ndarray] = None) -> Phase:
        """Build the CCM phase: tasks with (predicted or measured) durations,
        slabs as shared blocks, and update-communication edges from tasks to
        the consumer of their slab (commutative += into the shared block)."""
        k = self.num_tasks
        task_block = np.array([t.slab for t in self.tasks], np.int64)
        # tasks contribute 'u x u' partial sums that must reach the slab —
        # modeled as a comm edge between tasks of the same slab (assembled
        # reduction), sized by the tile bytes.
        comm_src, comm_dst, comm_vol = [], [], []
        by_slab: dict = {}
        for t in self.tasks:
            by_slab.setdefault(t.slab, []).append(t.task_id)
        for slab, members in by_slab.items():
            anchor = members[0]
            for m in members[1:]:
                comm_src.append(m)
                comm_dst.append(anchor)
                tm = self.tasks[m]
                comm_vol.append(len(tm.rows) * len(tm.cols) * comm_byte)
        return Phase(
            task_load=durations,
            task_mem=np.array([len(t.rows) * len(t.cols) * comm_byte
                               for t in self.tasks]),
            task_overhead=np.full(k, 1e5),
            task_block=task_block,
            block_size=self.slab_bytes,
            block_home=self.slab_home,
            comm_src=np.array(comm_src, np.int64) if comm_src else np.zeros(0, np.int64),
            comm_dst=np.array(comm_dst, np.int64) if comm_dst else np.zeros(0, np.int64),
            comm_vol=np.array(comm_vol) if comm_vol else np.zeros(0),
            rank_mem_base=np.full(self.num_ranks, 1e6),
            rank_mem_cap=np.full(self.num_ranks, mem_cap_bytes),
            rank_speed=rank_speed,
        )


def build_problem(n_unknowns: int, num_ranks: int, *, task_limit_u: int = 96,
                  slabs_per_rank: int = 4, seed: int = 0,
                  entry_bytes: float = 8.0) -> AssemblyProblem:
    geom = make_cavity_geometry(n_unknowns, seed=seed)
    rank_rows = [np.array(r, np.int64)
                 for r in np.array_split(np.arange(n_unknowns), num_ranks)]

    slab_cols: List[np.ndarray] = []
    slab_home: List[int] = []
    slab_bytes: List[float] = []
    tasks: List[AssemblyTask] = []

    for r in range(num_ranks):
        rows = rank_rows[r]
        for cols in np.array_split(np.arange(n_unknowns), slabs_per_rank):
            slab_id = len(slab_cols)
            slab_cols.append(np.array(cols, np.int64))
            slab_home.append(r)
            slab_bytes.append(float(len(rows) * len(cols) * entry_bytes))
            # overdecompose the slab into u x u tasks, split by element pair
            for r0 in range(0, len(rows), task_limit_u):
                rsub = rows[r0:r0 + task_limit_u]
                for c0 in range(0, len(cols), task_limit_u):
                    csub = cols[c0:c0 + task_limit_u]
                    _emit_tasks(geom, rsub, csub, slab_id, r, tasks)

    return AssemblyProblem(
        geom=geom, num_ranks=num_ranks, rank_rows=rank_rows,
        slab_cols=slab_cols, slab_home=np.array(slab_home, np.int64),
        slab_bytes=np.array(slab_bytes), tasks=tasks)


def _emit_tasks(geom: Geometry, rows: np.ndarray, cols: np.ndarray,
                slab_id: int, home: int, out: List[AssemblyTask]):
    """Split a tile by element-type pair; skip zero (non-coupling) tiles."""
    for et_r in (0, 1):
        rsel = rows[geom.elem_type[rows] == et_r]
        if rsel.size == 0:
            continue
        for et_c in (0, 1):
            csel = cols[geom.elem_type[cols] == et_c]
            if csel.size == 0:
                continue
            inter = _interaction_count(geom, rsel, csel)
            if inter == 0:
                continue  # zero block: never instantiated (§VI-B)
            d_min = _min_dist(geom.points[rsel], geom.points[csel])
            pair = et_r + et_c  # 0 tri-tri, 1 mixed, 2 bar-bar
            out.append(AssemblyTask(
                task_id=len(out), slab=slab_id, home_rank=home,
                rows=rsel, cols=csel, elem_pair=pair,
                quad_order=_quad_order(d_min), n_interactions=inter))


def _interaction_count(geom: Geometry, rows: np.ndarray,
                       cols: np.ndarray) -> int:
    """DOF pairs that couple: same region, or either endpoint on a slot."""
    reg_r = geom.region[rows][:, None]
    reg_c = geom.region[cols][None, :]
    couple = (reg_r == reg_c) | (reg_r == 2) | (reg_c == 2)
    return int(couple.sum())
