from repro.assembly.driver import AssemblyRun, run_assembly_comparison  # noqa: F401
from repro.assembly.problem import AssemblyProblem, build_problem  # noqa: F401
