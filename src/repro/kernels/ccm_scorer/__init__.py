from repro.kernels.ccm_scorer import jit  # noqa: F401
from repro.kernels.ccm_scorer.layout import (AV, N_AV, N_OUT, N_PM,  # noqa: F401
                                             N_SC, OUT, PM, SC)
from repro.kernels.ccm_scorer.ops import (BACKENDS, ccm_score_tiles,  # noqa: F401
                                          combine_work, combine_work_pairs)
from repro.kernels.ccm_scorer.ref import (score_pairs_xp,  # noqa: F401
                                          score_tiles, score_tiles_xp)
