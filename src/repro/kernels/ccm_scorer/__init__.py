from repro.kernels.ccm_scorer.layout import (AV, N_AV, N_OUT, N_PM,  # noqa: F401
                                             N_SC, OUT, PM, SC)
from repro.kernels.ccm_scorer.ops import ccm_score_tiles, combine_work  # noqa: F401
from repro.kernels.ccm_scorer.ref import score_tiles  # noqa: F401
