"""Feature-plane indices shared by the NumPy reference and the Pallas
kernel.  ops.py documents the full packed-tile layout; this module only
pins the index constants so ref.py / kernel.py / engine packing cannot
drift apart.
"""
from __future__ import annotations


class AV:
    """Per-candidate feature planes, index into av (E, N_AV, A) — the same
    row meanings apply to bv (E, N_AV, B).  ``*_peer`` rows describe what the
    candidate does to the OTHER endpoint (e.g. ``s_add_peer`` on an
    a-candidate = shared bytes arriving at rank b)."""

    intra = 0        # v(C -> C) intra-cluster volume
    out_own = 1      # v(C -> own rank)
    in_own = 2       # v(own rank -> C)
    out_peer = 3     # v(C -> peer rank)
    in_peer = 4      # v(peer rank -> C)
    out_other = 5    # v(C -> any third rank)
    in_other = 6     # v(any third rank -> C)
    load = 7         # sum of task loads
    mem = 8          # sum of task memory
    ovh = 9          # max task overhead
    s_rm = 10        # shared bytes leaving the own rank if C moves
    h_rm = 11        # homing bytes leaving the own rank if C moves
    s_add_peer = 12  # shared bytes arriving at the peer rank if C moves
    h_add_peer = 13  # homing bytes arriving at the peer rank if C moves


N_AV = 14


class PM:
    """Pairwise feature planes, index into pm (E, N_PM, A, B)."""

    x_ab = 0   # v(A_i -> B_j)
    x_ba = 1   # v(B_j -> A_i)
    cs_a = 2   # shared-bytes correction on rank a for blocks in both A_i, B_j
    ch_a = 3   # homing correction on rank a
    cs_b = 4   # shared-bytes correction on rank b
    ch_b = 5   # homing correction on rank b


N_PM = 6


class SC:
    """Per-event scalars, index into sc (E, N_SC).  ``f_xy`` are current
    rank-to-rank flows (a = rank a, b = rank b, o = all other ranks);
    ``base_*`` are the incrementally-maintained CCMState volume bases the
    flow deltas are applied to.  The last four are consumed by the host-side
    work combine (ops.combine_work), not by the kernel."""

    f_ab = 0
    f_ba = 1
    f_aa = 2
    f_bb = 3
    f_ao = 4
    f_oa = 5
    f_bo = 6
    f_ob = 7
    base_sent_a = 8
    base_recv_a = 9
    base_sent_b = 10
    base_recv_b = 11
    vol_aa = 12
    vol_bb = 13
    load_a = 14
    load_b = 15
    shared_a = 16
    shared_b = 17
    hom_a = 18
    hom_b = 19
    mem_base_a = 20
    mem_task_a = 21
    ovh_a = 22
    mem_base_b = 23
    mem_task_b = 24
    ovh_b = 25
    na = 26          # true candidate count on a (mask bound, as float)
    nb = 27          # true candidate count on b
    speed_a = 28     # host combine only
    speed_b = 29
    mem_cap_a = 30   # packed pre-scaled via repro.core.ccm.effective_mem_cap
    mem_cap_b = 31   # (relative tolerance + pressure headroom baked in)


N_SC = 32


class OUT:
    """Output planes, index into out (E, N_OUT, A, B)."""

    load_a = 0
    load_b = 1
    off_a = 2
    off_b = 3
    on_a = 4
    on_b = 5
    hom_a = 6
    hom_b = 7
    mem_a = 8
    mem_b = 9


N_OUT = 10
