"""Pure-NumPy reference for the CCM stage-2 scorer tiles.

This IS the evaluation engine's ``backend="numpy"`` implementation as well
as the oracle the Pallas kernel (kernel.py) is held bitwise-equal to: both
compute the identical expression tree over the packed feature tiles (see
ops.py for the layout), using only additions, subtractions, maxima and
selects — the operations XLA cannot re-round — so interpret-mode kernel
outputs and this function agree bit for bit.  Keep the expression structure
in the two files in lockstep; tests/test_ccm_scorer.py enforces it.

Every expression below mirrors the original per-event broadcast section of
``PhaseEngine.batch_exchange_eval`` (repro/core/engine.py), re-rooted at the
packed event axis: ``col(v) = v[..., :, None]`` broadcasts a per-a-candidate
vector down the rows, ``row(v) = v[..., None, :]`` broadcasts a
per-b-candidate vector along the columns, and scalars enter via
``sc[:, i, None, None]``.
"""
from __future__ import annotations

import numpy as np

from repro.kernels.ccm_scorer.layout import AV, N_OUT, OUT, PM, SC


def score_tiles(av: np.ndarray, bv: np.ndarray, pm: np.ndarray,
                sc: np.ndarray) -> np.ndarray:
    """Score packed exchange tiles (NumPy reference path).

    av: (E, N_AV, A) per-a-candidate features, bv: (E, N_AV, B),
    pm: (E, N_PM, A, B) pairwise features, sc: (E, N_SC) scalars.
    Returns (E, N_OUT, A, B); the tail beyond (na+1, nb+1) is masked to 0
    (flow/load/homing planes) or +inf (memory planes).
    """
    e_n, _, a_n = av.shape
    b_n = bv.shape[2]

    def col(i):
        return av[:, i, :, None]

    def row(i):
        return bv[:, i, None, :]

    def colv(v):
        return v[:, :, None]

    def rowv(v):
        return v[:, None, :]

    def scal(i):
        return sc[:, i, None, None]

    x_ab, x_ba = pm[:, PM.x_ab], pm[:, PM.x_ba]
    cs_a, ch_a = pm[:, PM.cs_a], pm[:, PM.ch_a]
    cs_b, ch_b = pm[:, PM.cs_b], pm[:, PM.ch_b]

    # --- flows after the exchange (same expression tree as the engine) ---
    sent_a = (x_ba + rowv(bv[:, AV.out_own] - bv[:, AV.intra]
                          + bv[:, AV.out_other])
              + colv(av[:, AV.in_own] - av[:, AV.intra])
              + (scal(SC.f_ab) - col(AV.out_peer) - row(AV.in_peer) + x_ab)
              + (scal(SC.f_ao) - col(AV.out_other)))
    recv_a = (x_ab + rowv(bv[:, AV.in_own] - bv[:, AV.intra]
                          + bv[:, AV.in_other])
              + colv(av[:, AV.out_own] - av[:, AV.intra])
              + (scal(SC.f_ba) - row(AV.out_peer) - col(AV.in_peer) + x_ba)
              + (scal(SC.f_oa) - col(AV.in_other)))
    on_a = (row(AV.intra) + (row(AV.out_peer) - x_ba)
            + (row(AV.in_peer) - x_ab)
            + (scal(SC.f_aa) - colv(av[:, AV.out_own] + av[:, AV.in_own]
                                    - av[:, AV.intra])))
    sent_b = (x_ab + colv(av[:, AV.out_own] - av[:, AV.intra]
                          + av[:, AV.out_other])
              + rowv(bv[:, AV.in_own] - bv[:, AV.intra])
              + (scal(SC.f_ba) - row(AV.out_peer) - col(AV.in_peer) + x_ba)
              + (scal(SC.f_bo) - row(AV.out_other)))
    recv_b = (x_ba + colv(av[:, AV.in_own] - av[:, AV.intra]
                          + av[:, AV.in_other])
              + rowv(bv[:, AV.out_own] - bv[:, AV.intra])
              + (scal(SC.f_ab) - col(AV.out_peer) - row(AV.in_peer) + x_ab)
              + (scal(SC.f_ob) - row(AV.in_other)))
    on_b = (col(AV.intra) + (col(AV.out_peer) - x_ab)
            + (col(AV.in_peer) - x_ba)
            + (scal(SC.f_bb) - rowv(bv[:, AV.out_own] + bv[:, AV.in_own]
                                    - bv[:, AV.intra])))

    off_a = np.maximum(
        scal(SC.base_sent_a) + (sent_a - (sc[:, SC.f_ab, None, None]
                                          + sc[:, SC.f_ao, None, None])),
        scal(SC.base_recv_a) + (recv_a - (sc[:, SC.f_ba, None, None]
                                          + sc[:, SC.f_oa, None, None])))
    off_b = np.maximum(
        scal(SC.base_sent_b) + (sent_b - (sc[:, SC.f_ba, None, None]
                                          + sc[:, SC.f_bo, None, None])),
        scal(SC.base_recv_b) + (recv_b - (sc[:, SC.f_ab, None, None]
                                          + sc[:, SC.f_ob, None, None])))
    on_a = scal(SC.vol_aa) + (on_a - scal(SC.f_aa))
    on_b = scal(SC.vol_bb) + (on_b - scal(SC.f_bb))

    load_a = scal(SC.load_a) - col(AV.load) + row(AV.load)
    load_b = scal(SC.load_b) + col(AV.load) - row(AV.load)

    # --- homing / shared-memory transitions -----------------------------
    shared_a = (scal(SC.shared_a) - col(AV.s_rm) + row(AV.s_add_peer) + cs_a)
    shared_b = (scal(SC.shared_b) - row(AV.s_rm) + col(AV.s_add_peer) + cs_b)
    hom_a = scal(SC.hom_a) - col(AV.h_rm) + row(AV.h_add_peer) + ch_a
    hom_b = scal(SC.hom_b) - row(AV.h_rm) + col(AV.h_add_peer) + ch_b

    # --- memory (eq. 9 inputs) ------------------------------------------
    mem_a = (scal(SC.mem_base_a) + scal(SC.mem_task_a) - col(AV.mem)
             + row(AV.mem) + shared_a
             + np.maximum(scal(SC.ovh_a), row(AV.ovh)))
    mem_b = (scal(SC.mem_base_b) + scal(SC.mem_task_b) + col(AV.mem)
             - row(AV.mem) + shared_b
             + np.maximum(scal(SC.ovh_b), col(AV.ovh)))

    # --- masked tail -----------------------------------------------------
    ia = np.arange(a_n, dtype=np.float64)[None, :, None]
    ib = np.arange(b_n, dtype=np.float64)[None, None, :]
    mask = (ia <= sc[:, SC.na, None, None]) & (ib <= sc[:, SC.nb, None, None])

    out = np.empty((e_n, N_OUT, a_n, b_n), np.float64)
    zero, inf = np.float64(0.0), np.float64(np.inf)
    out[:, OUT.load_a] = np.where(mask, load_a, zero)
    out[:, OUT.load_b] = np.where(mask, load_b, zero)
    out[:, OUT.off_a] = np.where(mask, off_a, zero)
    out[:, OUT.off_b] = np.where(mask, off_b, zero)
    out[:, OUT.on_a] = np.where(mask, on_a, zero)
    out[:, OUT.on_b] = np.where(mask, on_b, zero)
    out[:, OUT.hom_a] = np.where(mask, hom_a, zero)
    out[:, OUT.hom_b] = np.where(mask, hom_b, zero)
    out[:, OUT.mem_a] = np.where(mask, mem_a, inf)
    out[:, OUT.mem_b] = np.where(mask, mem_b, inf)
    return out
