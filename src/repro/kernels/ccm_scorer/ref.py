"""Pure-NumPy reference for the CCM stage-2 scorer tiles.

This IS the evaluation engine's ``backend="numpy"`` implementation as well
as the oracle the Pallas kernel (kernel.py) and the bucketed jit launcher
(jit.py) are held bitwise-equal to.  All of them compute the identical
expression tree over the packed feature tiles (see ops.py for the layout),
using only additions, subtractions, maxima and selects — the operations
XLA cannot re-round (no multiply means no FMA contraction, no divide means
no reciprocal rewrite) — so interpret-mode kernel outputs, compiled-XLA
f64 outputs and this function agree bit for bit.

To keep the tree in ONE place for the NumPy and jit paths, the body is
parametrized over the array namespace: :func:`score_tiles_xp` evaluates the
same source expressions with ``xp=numpy`` (the reference) or ``xp=jax.numpy``
(traced by jit.py into the per-bucket compiled functions) — identical
syntax trees by construction, so the two cannot drift apart.  The Pallas
kernel body (kernel.py) remains a hand-kept copy because it reads from
Refs; tests/test_ccm_scorer.py enforces its lockstep.

Every expression below mirrors the original per-event broadcast section of
``PhaseEngine.batch_exchange_eval`` (repro/core/engine.py), re-rooted at the
packed event axis: ``col(v) = v[..., :, None]`` broadcasts a per-a-candidate
vector down the rows, ``row(v) = v[..., None, :]`` broadcasts a
per-b-candidate vector along the columns, and scalars enter via
``sc[:, i, None, None]``.
"""
from __future__ import annotations

import numpy as np

from repro.kernels.ccm_scorer.layout import AV, N_OUT, OUT, PM, SC


def score_tiles(av: np.ndarray, bv: np.ndarray, pm: np.ndarray,
                sc: np.ndarray) -> np.ndarray:
    """Score packed exchange tiles (NumPy reference path).

    av: (E, N_AV, A) per-a-candidate features, bv: (E, N_AV, B),
    pm: (E, N_PM, A, B) pairwise features, sc: (E, N_SC) scalars.
    Returns (E, N_OUT, A, B); the tail beyond (na+1, nb+1) is masked to 0
    (flow/load/homing planes) or +inf (memory planes).
    """
    return score_tiles_xp(av, bv, pm, sc, xp=np)


def score_planes(col, row, scal, pmp, xp):
    """The scorer expression tree, abstracted over index helpers.

    ``col(i)``/``row(i)`` read per-a-/per-b-candidate feature rows,
    ``scal(i)`` a per-event scalar, ``pmp(i)`` a pairwise plane — each
    returning arrays that broadcast against one another.  Two layouts feed
    this core:

      * *tile* (:func:`score_tiles_xp`): col -> (E, A, 1), row ->
        (E, 1, B), pmp -> (E, A, B); the result planes are (E, A, B).
      * *pairs* (:func:`score_pairs_xp`): all helpers return (E, P) arrays
        already gathered at a pair shortlist; the result planes are (E, P).

    Both evaluate the identical per-lane expression DAG (broadcasting
    never changes a lane's operand values or operation order), so tile
    scoring followed by a pair gather is bitwise-equal to pair scoring —
    the property the compiled hot path rests on.  Returns the N_OUT planes
    in ``layout.OUT`` order, *before* tail masking.
    """
    x_ab, x_ba = pmp(PM.x_ab), pmp(PM.x_ba)
    cs_a, ch_a = pmp(PM.cs_a), pmp(PM.ch_a)
    cs_b, ch_b = pmp(PM.cs_b), pmp(PM.ch_b)

    # --- flows after the exchange (same expression tree as the engine) ---
    sent_a = (x_ba + (row(AV.out_own) - row(AV.intra) + row(AV.out_other))
              + (col(AV.in_own) - col(AV.intra))
              + (scal(SC.f_ab) - col(AV.out_peer) - row(AV.in_peer) + x_ab)
              + (scal(SC.f_ao) - col(AV.out_other)))
    recv_a = (x_ab + (row(AV.in_own) - row(AV.intra) + row(AV.in_other))
              + (col(AV.out_own) - col(AV.intra))
              + (scal(SC.f_ba) - row(AV.out_peer) - col(AV.in_peer) + x_ba)
              + (scal(SC.f_oa) - col(AV.in_other)))
    on_a = (row(AV.intra) + (row(AV.out_peer) - x_ba)
            + (row(AV.in_peer) - x_ab)
            + (scal(SC.f_aa) - (col(AV.out_own) + col(AV.in_own)
                                - col(AV.intra))))
    sent_b = (x_ab + (col(AV.out_own) - col(AV.intra) + col(AV.out_other))
              + (row(AV.in_own) - row(AV.intra))
              + (scal(SC.f_ba) - row(AV.out_peer) - col(AV.in_peer) + x_ba)
              + (scal(SC.f_bo) - row(AV.out_other)))
    recv_b = (x_ba + (col(AV.in_own) - col(AV.intra) + col(AV.in_other))
              + (row(AV.out_own) - row(AV.intra))
              + (scal(SC.f_ab) - col(AV.out_peer) - row(AV.in_peer) + x_ab)
              + (scal(SC.f_ob) - row(AV.in_other)))
    on_b = (col(AV.intra) + (col(AV.out_peer) - x_ab)
            + (col(AV.in_peer) - x_ba)
            + (scal(SC.f_bb) - (row(AV.out_own) + row(AV.in_own)
                                - row(AV.intra))))

    off_a = xp.maximum(
        scal(SC.base_sent_a) + (sent_a - (scal(SC.f_ab) + scal(SC.f_ao))),
        scal(SC.base_recv_a) + (recv_a - (scal(SC.f_ba) + scal(SC.f_oa))))
    off_b = xp.maximum(
        scal(SC.base_sent_b) + (sent_b - (scal(SC.f_ba) + scal(SC.f_bo))),
        scal(SC.base_recv_b) + (recv_b - (scal(SC.f_ab) + scal(SC.f_ob))))
    on_a = scal(SC.vol_aa) + (on_a - scal(SC.f_aa))
    on_b = scal(SC.vol_bb) + (on_b - scal(SC.f_bb))

    load_a = scal(SC.load_a) - col(AV.load) + row(AV.load)
    load_b = scal(SC.load_b) + col(AV.load) - row(AV.load)

    # --- homing / shared-memory transitions -----------------------------
    shared_a = (scal(SC.shared_a) - col(AV.s_rm) + row(AV.s_add_peer) + cs_a)
    shared_b = (scal(SC.shared_b) - row(AV.s_rm) + col(AV.s_add_peer) + cs_b)
    hom_a = scal(SC.hom_a) - col(AV.h_rm) + row(AV.h_add_peer) + ch_a
    hom_b = scal(SC.hom_b) - row(AV.h_rm) + col(AV.h_add_peer) + ch_b

    # --- memory (eq. 9 inputs) ------------------------------------------
    mem_a = (scal(SC.mem_base_a) + scal(SC.mem_task_a) - col(AV.mem)
             + row(AV.mem) + shared_a
             + xp.maximum(scal(SC.ovh_a), row(AV.ovh)))
    mem_b = (scal(SC.mem_base_b) + scal(SC.mem_task_b) + col(AV.mem)
             - row(AV.mem) + shared_b
             + xp.maximum(scal(SC.ovh_b), col(AV.ovh)))

    planes = [None] * N_OUT
    planes[OUT.load_a] = load_a
    planes[OUT.load_b] = load_b
    planes[OUT.off_a] = off_a
    planes[OUT.off_b] = off_b
    planes[OUT.on_a] = on_a
    planes[OUT.on_b] = on_b
    planes[OUT.hom_a] = hom_a
    planes[OUT.hom_b] = hom_b
    planes[OUT.mem_a] = mem_a
    planes[OUT.mem_b] = mem_b
    return planes


def _mask_planes(planes, mask, dt, xp):
    """Masked tail: flow/load/homing planes -> 0, memory planes -> +inf
    (so padded pairs can never look feasible).  Plane order = layout.OUT."""
    zero = xp.zeros((), dt)
    inf = xp.full((), xp.inf, dt)
    out = [xp.where(mask, p, inf if i in (OUT.mem_a, OUT.mem_b) else zero)
           for i, p in enumerate(planes)]
    return xp.stack(out, axis=1)


def score_tiles_xp(av, bv, pm, sc, *, xp=np):
    """Full-tile layout of the expression tree (see :func:`score_planes`).

    ``xp=numpy`` is the production reference; ``xp=jax.numpy`` is traced by
    the bucketed jit launcher.  Output lane (ia, ib) depends only on
    ``av[:, :, ia]``, ``bv[:, :, ib]``, ``pm[:, :, ia, ib]`` and ``sc`` —
    every op is elementwise over the (A, B) tile — which is what makes
    bucket padding invariant: padded lanes cannot perturb real ones.
    """
    a_n = av.shape[2]
    b_n = bv.shape[2]

    planes = score_planes(
        col=lambda i: av[:, i, :, None],
        row=lambda i: bv[:, i, None, :],
        scal=lambda i: sc[:, i, None, None],
        pmp=lambda i: pm[:, i],
        xp=xp)

    dt = av.dtype
    ia = xp.arange(a_n, dtype=dt)[None, :, None]
    ib = xp.arange(b_n, dtype=dt)[None, None, :]
    mask = (ia <= sc[:, SC.na, None, None]) & (ib <= sc[:, SC.nb, None, None])
    return _mask_planes(planes, mask, dt, xp)


def score_pairs_xp(avp, bvp, pmp, sc, iaf, ibf, *, xp=np):
    """Pair-gathered layout: score only a shortlist of candidate pairs.

    ``avp``/``bvp``: (E, N_AV, P) feature rows gathered at the pairs' a-/
    b-candidate indices, ``pmp``: (E, N_PM, P) pairwise planes gathered at
    the pairs, ``iaf``/``ibf``: (E, P) pair indices as floats (mask bound
    compare only).  Returns (E, N_OUT, P) — bitwise-equal to full-tile
    scoring followed by the same gather, at O(P) instead of O(A*B) lanes.
    """
    planes = score_planes(
        col=lambda i: avp[:, i],
        row=lambda i: bvp[:, i],
        scal=lambda i: sc[:, i, None],
        pmp=lambda i: pmp[:, i],
        xp=xp)
    dt = avp.dtype
    mask = (iaf <= sc[:, SC.na, None]) & (ibf <= sc[:, SC.nb, None])
    return _mask_planes(planes, mask, dt, xp)
