"""Pallas kernel for the CCM stage-2 exchange-scorer tiles.

One grid step scores one lock event: the (A, B) candidate-pair tile of a
single (rank a, rank b) exchange, where A/B are the padded candidate counts
(empty candidate at index 0, masked tail past ``na``/``nb``).  A batched
lock event of E disjoint rank pairs is a single ``pallas_call`` with
``grid=(E,)`` — the block-diagonal flow decomposition means events never
read each other's planes, so the launch is embarrassingly parallel.

Bitwise contract (see ref.py): the kernel body uses ONLY additions,
subtractions, maxima, compares and selects — never a multiply or divide —
because XLA contracts ``mul+add`` into FMA and rewrites division by
constants into reciprocal multiplies, either of which would break the
bit-for-bit parity with the NumPy backend that the CCM-LB trajectory
guarantee rests on.  The affine work combine (alpha/beta/gamma/delta and
the speed divide) therefore lives in shared host code (ops.combine_work)
for BOTH backends.  Keep every expression tree here in lockstep with
ref.score_tiles.

On TPU the natural deployment pads B to the 128-lane boundary and runs in
f32; tier-1 CI runs the kernel with ``interpret=True`` on CPU in f64, where
it is held bitwise-equal to the reference.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.ccm_scorer.layout import AV, N_OUT, OUT, PM, SC


def _scorer_kernel(av_ref, bv_ref, pm_ref, sc_ref, o_ref):
    av = av_ref[0]          # (N_AV, A)
    bv = bv_ref[0]          # (N_AV, B)
    pm = pm_ref[0]          # (N_PM, A, B)
    sc = sc_ref[0]          # (N_SC,)
    a_n = av.shape[1]
    b_n = bv.shape[1]

    def col(i):
        return av[i][:, None]

    def row(i):
        return bv[i][None, :]

    def colv(v):
        return v[:, None]

    def rowv(v):
        return v[None, :]

    x_ab, x_ba = pm[PM.x_ab], pm[PM.x_ba]
    cs_a, ch_a = pm[PM.cs_a], pm[PM.ch_a]
    cs_b, ch_b = pm[PM.cs_b], pm[PM.ch_b]

    # --- flows after the exchange (expression trees == ref.py) -----------
    sent_a = (x_ba + rowv(bv[AV.out_own] - bv[AV.intra] + bv[AV.out_other])
              + colv(av[AV.in_own] - av[AV.intra])
              + (sc[SC.f_ab] - col(AV.out_peer) - row(AV.in_peer) + x_ab)
              + (sc[SC.f_ao] - col(AV.out_other)))
    recv_a = (x_ab + rowv(bv[AV.in_own] - bv[AV.intra] + bv[AV.in_other])
              + colv(av[AV.out_own] - av[AV.intra])
              + (sc[SC.f_ba] - row(AV.out_peer) - col(AV.in_peer) + x_ba)
              + (sc[SC.f_oa] - col(AV.in_other)))
    on_a = (row(AV.intra) + (row(AV.out_peer) - x_ba)
            + (row(AV.in_peer) - x_ab)
            + (sc[SC.f_aa] - colv(av[AV.out_own] + av[AV.in_own]
                                  - av[AV.intra])))
    sent_b = (x_ab + colv(av[AV.out_own] - av[AV.intra] + av[AV.out_other])
              + rowv(bv[AV.in_own] - bv[AV.intra])
              + (sc[SC.f_ba] - row(AV.out_peer) - col(AV.in_peer) + x_ba)
              + (sc[SC.f_bo] - row(AV.out_other)))
    recv_b = (x_ba + colv(av[AV.in_own] - av[AV.intra] + av[AV.in_other])
              + rowv(bv[AV.out_own] - bv[AV.intra])
              + (sc[SC.f_ab] - col(AV.out_peer) - row(AV.in_peer) + x_ab)
              + (sc[SC.f_ob] - row(AV.in_other)))
    on_b = (col(AV.intra) + (col(AV.out_peer) - x_ab)
            + (col(AV.in_peer) - x_ba)
            + (sc[SC.f_bb] - rowv(bv[AV.out_own] + bv[AV.in_own]
                                  - bv[AV.intra])))

    off_a = jnp.maximum(
        sc[SC.base_sent_a] + (sent_a - (sc[SC.f_ab] + sc[SC.f_ao])),
        sc[SC.base_recv_a] + (recv_a - (sc[SC.f_ba] + sc[SC.f_oa])))
    off_b = jnp.maximum(
        sc[SC.base_sent_b] + (sent_b - (sc[SC.f_ba] + sc[SC.f_bo])),
        sc[SC.base_recv_b] + (recv_b - (sc[SC.f_ab] + sc[SC.f_ob])))
    on_a = sc[SC.vol_aa] + (on_a - sc[SC.f_aa])
    on_b = sc[SC.vol_bb] + (on_b - sc[SC.f_bb])

    load_a = sc[SC.load_a] - col(AV.load) + row(AV.load)
    load_b = sc[SC.load_b] + col(AV.load) - row(AV.load)

    shared_a = sc[SC.shared_a] - col(AV.s_rm) + row(AV.s_add_peer) + cs_a
    shared_b = sc[SC.shared_b] - row(AV.s_rm) + col(AV.s_add_peer) + cs_b
    hom_a = sc[SC.hom_a] - col(AV.h_rm) + row(AV.h_add_peer) + ch_a
    hom_b = sc[SC.hom_b] - row(AV.h_rm) + col(AV.h_add_peer) + ch_b

    mem_a = (sc[SC.mem_base_a] + sc[SC.mem_task_a] - col(AV.mem)
             + row(AV.mem) + shared_a
             + jnp.maximum(sc[SC.ovh_a], row(AV.ovh)))
    mem_b = (sc[SC.mem_base_b] + sc[SC.mem_task_b] + col(AV.mem)
             - row(AV.mem) + shared_b
             + jnp.maximum(sc[SC.ovh_b], col(AV.ovh)))

    # --- masked tail -----------------------------------------------------
    ia = jax.lax.broadcasted_iota(av.dtype, (a_n, b_n), 0)
    ib = jax.lax.broadcasted_iota(av.dtype, (a_n, b_n), 1)
    mask = (ia <= sc[SC.na]) & (ib <= sc[SC.nb])
    zero = jnp.zeros((), av.dtype)
    inf = jnp.full((), jnp.inf, av.dtype)

    o_ref[0, OUT.load_a] = jnp.where(mask, load_a, zero)
    o_ref[0, OUT.load_b] = jnp.where(mask, load_b, zero)
    o_ref[0, OUT.off_a] = jnp.where(mask, off_a, zero)
    o_ref[0, OUT.off_b] = jnp.where(mask, off_b, zero)
    o_ref[0, OUT.on_a] = jnp.where(mask, on_a, zero)
    o_ref[0, OUT.on_b] = jnp.where(mask, on_b, zero)
    o_ref[0, OUT.hom_a] = jnp.where(mask, hom_a, zero)
    o_ref[0, OUT.hom_b] = jnp.where(mask, hom_b, zero)
    o_ref[0, OUT.mem_a] = jnp.where(mask, mem_a, inf)
    o_ref[0, OUT.mem_b] = jnp.where(mask, mem_b, inf)


@functools.partial(jax.jit, static_argnames=("interpret",))
def score_tiles_fwd(av, bv, pm, sc, *, interpret: bool = True):
    """av: (E, N_AV, A), bv: (E, N_AV, B), pm: (E, N_PM, A, B),
    sc: (E, N_SC) -> (E, N_OUT, A, B), one grid step per event."""
    e_n, n_av, a_n = av.shape
    b_n = bv.shape[2]
    n_pm = pm.shape[1]
    n_sc = sc.shape[1]
    return pl.pallas_call(
        _scorer_kernel,
        grid=(e_n,),
        in_specs=[
            pl.BlockSpec((1, n_av, a_n), lambda e: (e, 0, 0)),
            pl.BlockSpec((1, n_av, b_n), lambda e: (e, 0, 0)),
            pl.BlockSpec((1, n_pm, a_n, b_n), lambda e: (e, 0, 0, 0)),
            pl.BlockSpec((1, n_sc), lambda e: (e, 0)),
        ],
        out_specs=pl.BlockSpec((1, N_OUT, a_n, b_n), lambda e: (e, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((e_n, N_OUT, a_n, b_n), av.dtype),
        interpret=interpret,
    )(av, bv, pm, sc)
