"""Packing, dispatch and the shared work combine for the CCM scorer tiles.

Tile / mask layout
------------------
A *lock event* is one (rank a, rank b) exchange negotiation; scoring it
means evaluating every candidate cluster pair ``(A_ia a->b, B_ib b->a)``
with ``ia in 0..na``, ``ib in 0..nb`` (index 0 = the empty cluster, i.e.
one-sided gives).  A *batched* lock event packs E such events — with
pairwise-disjoint rank sets — into fixed-size device tiles:

  av  (E, N_AV, A)     per-a-candidate feature planes (layout.AV rows)
  bv  (E, N_AV, B)     per-b-candidate feature planes (same row meanings)
  pm  (E, N_PM, A, B)  pairwise planes: counter-flow volumes x_ab/x_ba and
                       the shared-block corrections cs/ch (layout.PM)
  sc  (E, N_SC)        per-event scalars: current rank-to-rank flows,
                       CCMState volume bases, load/mem/homing bases, the
                       mask bounds na/nb, and the combine-only scalars
                       speed/mem-cap (layout.SC)

``A``/``B`` are fixed pad sizes >= max(na)+1 / max(nb)+1 over the batch
(the engine rounds them up to a multiple of 8 for the kernel path; a real
TPU deployment would pad B to the 128-lane boundary).  Candidate slots past
``na``/``nb`` are the *masked tail*: feature planes are zero-padded, and
the scorer forces tail outputs to 0 (flow/load/homing planes) or +inf
(memory planes, so tail pairs can never appear feasible).  Events are
independent grid steps — the flow decomposition is block-diagonal across
the batch, assembled by ``PhaseEngine`` with one flat bincount.

The scorer itself (ref.score_tiles / kernel.score_tiles_fwd) produces the
ten *work components* per pair (layout.OUT): loads, off-/on-rank volumes,
homing bytes and memory highs after the exchange.  It deliberately contains
no multiplications — XLA's FMA contraction would re-round them and break
the bitwise NumPy/Pallas parity contract (kernel.py) — so applying the CCM
coefficients is a separate, backend-shared host step:

  ``combine_work``: W = alpha*L/speed + beta*Voff + gamma*Von + delta*M_H,
  feasibility from the memory planes vs the per-event caps (eq. 9), and
  infeasible pairs forced to +inf — the exact expression the scalar
  reference evaluates, applied to whole tiles at once (``combine_work``)
  or to the (N_OUT, P) planes gathered at one event's shortlisted pairs
  (``combine_work_pairs`` — the hot path; elementwise ops commute with
  the gather, so the two are bitwise-interchangeable).

The per-event dispatch itself (shape-bucket padding, the compiled f64
pipeline, the f32 128-lane path, pair gathering) lives in jit.py; this
module keeps the raw full-tile API and the shared combine.  See README.md
for the backend matrix and the two parity tiers.
"""
from __future__ import annotations

from typing import Tuple

import numpy as np

from repro.kernels.ccm_scorer import ref
from repro.kernels.ccm_scorer.layout import (AV, N_AV, N_OUT, N_PM, N_SC,
                                             OUT, PM, SC)

__all__ = ["ccm_score_tiles", "combine_work", "combine_work_pairs", "AV",
           "PM", "SC", "OUT", "N_AV", "N_PM", "N_SC", "N_OUT", "BACKENDS"]

INF = float("inf")

#: the scorer backend matrix (see kernels/ccm_scorer/README.md):
#: f64-bitwise tier: numpy / jit / pallas (interpret);
#: f32 assignment-identity tier: pallas_compiled.
BACKENDS = ("numpy", "jit", "pallas", "pallas_compiled")


def ccm_score_tiles(av: np.ndarray, bv: np.ndarray, pm: np.ndarray,
                    sc: np.ndarray, *, backend: str = "numpy",
                    interpret: bool = True) -> np.ndarray:
    """Dispatch packed tiles to a scorer backend (full-tile API).

    ``numpy`` (the reference), ``jit`` (bucketed compiled f64) and
    ``pallas`` (interpret mode) return (E, N_OUT, A, B) float64 and agree
    BITWISE.  ``pallas_compiled`` scores in f32 on 128-lane tiles
    (interpret fallback off-TPU) and returns the exact f32 values upcast
    to float64 — ulp-level approximate, assignment-identity parity tier.
    """
    if backend == "numpy":
        return ref.score_tiles(av, bv, pm, sc)
    if backend == "jit":
        from repro.kernels.ccm_scorer import jit as scorer_jit
        return scorer_jit.score_tiles_jit(av, bv, pm, sc)
    if backend == "pallas":
        import jax  # deferred: the numpy path must not require jax

        from repro.kernels.ccm_scorer.kernel import score_tiles_fwd
        with jax.experimental.enable_x64():
            out = score_tiles_fwd(av, bv, pm, sc, interpret=interpret)
        return np.asarray(out)
    if backend == "pallas_compiled":
        from repro.kernels.ccm_scorer import jit as scorer_jit
        return scorer_jit.score_tiles_f32(av, bv, pm, sc)
    raise ValueError(f"unknown ccm_scorer backend: {backend!r}")


def combine_work(out: np.ndarray, sc: np.ndarray, params,
                 ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Backend-shared affine combine: work components -> (w_a, w_b, feas).

    Mirrors ``CCMState.work`` / the scalar ``exchange_eval`` tail exactly
    (same expression tree, so the NumPy engine stays bitwise-compatible
    with the pre-kernel implementation).
    """
    speed_a = sc[:, SC.speed_a, None, None]
    speed_b = sc[:, SC.speed_b, None, None]
    # the SC cap slots are packed pre-scaled through
    # repro.core.ccm.effective_mem_cap (relative tolerance + optional
    # pressure headroom), so the combines compare plain <=
    if params.memory_constraint:
        feas = ((out[:, OUT.mem_a] <= sc[:, SC.mem_cap_a, None, None])
                & (out[:, OUT.mem_b] <= sc[:, SC.mem_cap_b, None, None]))
    else:
        feas = np.ones(out.shape[0:1] + out.shape[2:], bool)
    w_a = (params.alpha * out[:, OUT.load_a] / speed_a
           + params.beta * out[:, OUT.off_a]
           + params.gamma * out[:, OUT.on_a]
           + params.delta * out[:, OUT.hom_a])
    w_b = (params.alpha * out[:, OUT.load_b] / speed_b
           + params.beta * out[:, OUT.off_b]
           + params.gamma * out[:, OUT.on_b]
           + params.delta * out[:, OUT.hom_b])
    w_a = np.where(feas, w_a, INF)
    w_b = np.where(feas, w_b, INF)
    return w_a, w_b, feas


def combine_terms(terms: np.ndarray, sc_row: np.ndarray, params,
                  ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Host tail of the combine when the products were computed in the
    compiled region (jit pairs path): ``terms`` is (10, P) — the eight
    coefficient-scaled work terms (a: load/off/on/hom, then b) followed by
    the two memory planes.  Only ADDS happen here (XLA:CPU would
    FMA-contract them; lone muls in the compiled region are safe), in the
    exact association order of ``combine_work``, so the results are
    bitwise-identical to the all-host combine."""
    if params.memory_constraint:
        feas = ((terms[8] <= sc_row[SC.mem_cap_a])
                & (terms[9] <= sc_row[SC.mem_cap_b]))
    else:
        feas = np.ones(terms.shape[1], bool)
    w_a = terms[0] + terms[1] + terms[2] + terms[3]
    w_b = terms[4] + terms[5] + terms[6] + terms[7]
    w_a = np.where(feas, w_a, INF)
    w_b = np.where(feas, w_b, INF)
    return w_a, w_b, feas


def combine_work_pairs(outp: np.ndarray, sc_row: np.ndarray, params,
                       ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Work combine on (N_OUT, P) planes already gathered at one event's
    shortlisted pairs.  Elementwise ops commute with the gather, so this is
    bitwise-identical per pair to ``combine_work`` on the full tile followed
    by the gather — the hot path just skips combining lanes it will never
    read.  ``sc_row`` is the event's (N_SC,) scalar row."""
    if params.memory_constraint:
        feas = ((outp[OUT.mem_a] <= sc_row[SC.mem_cap_a])
                & (outp[OUT.mem_b] <= sc_row[SC.mem_cap_b]))
    else:
        feas = np.ones(outp.shape[1], bool)
    w_a = (params.alpha * outp[OUT.load_a] / sc_row[SC.speed_a]
           + params.beta * outp[OUT.off_a]
           + params.gamma * outp[OUT.on_a]
           + params.delta * outp[OUT.hom_a])
    w_b = (params.alpha * outp[OUT.load_b] / sc_row[SC.speed_b]
           + params.beta * outp[OUT.off_b]
           + params.gamma * outp[OUT.on_b]
           + params.delta * outp[OUT.hom_b])
    w_a = np.where(feas, w_a, INF)
    w_b = np.where(feas, w_b, INF)
    return w_a, w_b, feas
