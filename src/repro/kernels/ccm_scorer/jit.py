"""Compiled shape-bucketed scorer runtime: the jitted event pipeline.

Why buckets
-----------
``jax.jit`` specializes on input shapes: every distinct (E, A, B, P)
quadruple triggers a fresh trace + XLA compile.  Lock-event tiles are small
but their shapes churn (candidate counts vary per rank pair, shortlists
vary per event), so naive jitting would re-trace on the hot path — worse
than the numpy dispatch it replaces.  The launcher therefore pads every
tile into a small, fixed grid of *shape buckets*:

  * lane dims A/B (padded candidate counts): powers of two in
    [8, 128], then multiples of 128 — ``bucket_lanes``.  128 is the TPU
    lane boundary, so a bucket that reaches it stops specializing and
    grows in whole lanes instead.
  * the event dim E and the shortlist dim P: powers of two
    (``bucket_events`` / ``bucket_pairs``; P additionally floors at 32,
    the default shortlist cap, so one P bucket serves every
    normally-sized event).

With ``max_candidates=12`` and ``shortlist=32`` a whole CCM-LB trajectory
touches a handful of buckets; each compiles exactly once
(tests/test_scorer_jit.py guards the recompile count via
:func:`trace_count`).

What is fused
-------------
One jitted function per bucket evaluates the full scorer expression tree
(ref.score_tiles_xp traced with ``xp=jax.numpy`` — the SAME source
expressions as the numpy backend) and gathers the shortlisted (ia, ib)
pairs, so the host receives (E, P, N_OUT) instead of (E, N_OUT, A, B).
Padding is invariant by construction: every op in the tree is elementwise
over the (A, B) tile, so padded lanes cannot perturb real ones, and the
f64 outputs on real lanes are BITWISE-equal to the unpadded numpy backend
(adds/subs/maxima/selects only — nothing XLA can re-round).

The affine work combine stays on the host (ops.combine_work_pairs, shared
by every backend) for the same reason it is not in the Pallas kernel:
XLA:CPU compiles with ``FPOpFusion::Fast`` at instruction selection, so any
``mul`` feeding an ``add`` becomes an FMA **regardless of IR-level
fast-math flags** — measured on this tree: ``jit(0.37*x + 0.21*y)`` equals
``fma(0.37, x, 0.21*y)``, and neither ``lax.optimization_barrier`` nor
bitcast round-trips survive the simplifier to block it.  A fused combine
therefore cannot meet the bitwise f64 parity bar on CPU; combining on the
(P,)-gathered host side costs ~10 tiny numpy ops per event and keeps the
contract exact.

The f32 compiled path
---------------------
``backend="pallas_compiled"`` packs the same tiles in float32 with B padded
to the 128-lane boundary (A to the 8-sublane boundary) and launches the
Pallas kernel with ``interpret=False``.  On hosts without a Pallas compile
target (CPU CI) the launcher transparently falls back to f32 interpret mode
— same dtype, same layout, same masked tail — and records it in
:func:`pallas_compiled_fallback`.  The f32 path's parity bar is
*assignment identity* on well-separated instances (scores differ from f64
by ulps of f32), not bitwise equality; tests/test_scorer_jit.py implements
the bar and reports the ulp budget on adversarial tiles.
"""
from __future__ import annotations

from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro.kernels.ccm_scorer import ref
from repro.kernels.ccm_scorer.layout import N_AV, N_OUT, N_PM, N_SC, OUT, SC

__all__ = ["bucket_lanes", "bucket_events", "bucket_pairs", "score_events",
           "score_tiles_jit", "score_tiles_f32", "trace_count",
           "bucket_cache_size", "pallas_compiled_supported",
           "pallas_compiled_fallback", "LANE_CAP"]

LANE_CAP = 128      # TPU lane boundary: buckets stop doubling here
_LANE_FLOOR = 8     # sublane quantum; also the smallest useful tile

_TRACE_COUNT = 0          # incremented inside every traced body
_FN_CACHE: dict = {}      # bucket key -> compiled callable
_COMPILED_OK: Optional[bool] = None
_COMPILED_FALLBACK = False


# ------------------------------------------------------------- bucket grid
def bucket_lanes(n: int, *, floor: int = _LANE_FLOOR,
                 cap: int = LANE_CAP) -> int:
    """Round a lane count up to the bucket grid: powers of two in
    [floor, cap], multiples of ``cap`` beyond it."""
    n = max(int(n), 1)
    if n <= floor:
        return floor
    if n >= cap:
        return -(-n // cap) * cap
    return 1 << (n - 1).bit_length()


def bucket_events(e: int) -> int:
    """Event-axis bucket: next power of two (E is small — the
    ``batch_lock_events`` cap)."""
    e = max(int(e), 1)
    return 1 << (e - 1).bit_length()


def bucket_pairs(p: int) -> int:
    """Shortlist-axis bucket: powers of two with a floor of 32 (the default
    shortlist cap) — one bucket serves every normally-sized event, so P
    churn cannot multiply the compile count."""
    p = max(int(p), 1)
    return max(32, 1 << (p - 1).bit_length())


def trace_count() -> int:
    """How many times a bucketed scorer body has been TRACED (== compiled,
    barring jax's persistent cache).  The recompile-count guard asserts this
    stays bounded by the number of distinct buckets."""
    return _TRACE_COUNT


def bucket_cache_size() -> int:
    return len(_FN_CACHE)


# --------------------------------------------------------- compiled bodies
def _pair_offsets(p: int) -> Tuple[int, ...]:
    """Cumulative offsets of [avp | bvp | pmp | sc | iaf | ibf | coeffs]
    in one flat per-event row of the pair-gathered layout (coeffs =
    alpha/beta/gamma/delta).  A single input array keeps the host->device
    transfer to ONE numpy conversion per launch — with several separate
    small arrays the per-array ingest dominates the whole dispatch
    (~30 us each on CPU)."""
    o_av = N_AV * p
    o_bv = o_av + N_AV * p
    o_pm = o_bv + N_PM * p
    o_sc = o_pm + N_SC
    o_ia = o_sc + p
    o_ib = o_ia + p
    o_cf = o_ib + 4
    return o_av, o_bv, o_pm, o_sc, o_ia, o_ib, o_cf


def _get_fn(key):
    """Per-bucket compiled function.  key = (kind, *static shape info)."""
    fn = _FN_CACHE.get(key)
    if fn is None:
        import jax
        import jax.numpy as jnp

        kind = key[0]
        if kind == "pairs":
            # the hot path: pair-gathered scoring.  Tiles are gathered at
            # the shortlist on the host, so the compiled work is O(P) per
            # event — independent of the candidate counts — and the bucket
            # grid collapses to (E, P) keys.  The combine's multiplies and
            # divides also run here: a lone mul whose result feeds an
            # OUTPUT (not an add) cannot be FMA-contracted, so the bits
            # match the host products exactly; only the adds (which XLA
            # would contract) remain on the host (ops.combine_terms).
            _, e_n, p_n = key
            o_av, o_bv, o_pm, o_sc, o_ia, o_ib, o_cf = _pair_offsets(p_n)

            def body(buf):
                global _TRACE_COUNT
                _TRACE_COUNT += 1           # runs at trace time only
                avp = buf[:, :o_av].reshape(e_n, N_AV, p_n)
                bvp = buf[:, o_av:o_bv].reshape(e_n, N_AV, p_n)
                pmp = buf[:, o_bv:o_pm].reshape(e_n, N_PM, p_n)
                sc = buf[:, o_pm:o_sc]
                iaf = buf[:, o_sc:o_ia]
                ibf = buf[:, o_ia:o_ib]
                out = ref.score_pairs_xp(avp, bvp, pmp, sc, iaf, ibf,
                                         xp=jnp)     # (E, N_OUT, P)
                al = buf[:, o_ib + 0, None]
                be = buf[:, o_ib + 1, None]
                ga = buf[:, o_ib + 2, None]
                de = buf[:, o_ib + 3, None]
                terms = [
                    al * out[:, OUT.load_a] / sc[:, SC.speed_a, None],
                    be * out[:, OUT.off_a],
                    ga * out[:, OUT.on_a],
                    de * out[:, OUT.hom_a],
                    al * out[:, OUT.load_b] / sc[:, SC.speed_b, None],
                    be * out[:, OUT.off_b],
                    ga * out[:, OUT.on_b],
                    de * out[:, OUT.hom_b],
                    out[:, OUT.mem_a],
                    out[:, OUT.mem_b],
                ]
                return jnp.stack(terms, axis=1)      # (E, 10, P)
        elif kind == "full":
            def body(av, bv, pm, sc):
                global _TRACE_COUNT
                _TRACE_COUNT += 1
                return ref.score_tiles_xp(av, bv, pm, sc, xp=jnp)
        else:                               # pragma: no cover
            raise ValueError(f"unknown bucketed fn kind: {kind!r}")
        fn = jax.jit(body)
        _FN_CACHE[key] = fn
    return fn


def _x64():
    import jax
    return jax.experimental.enable_x64()


# -------------------------------------------------------------- f32 Pallas
def pallas_compiled_supported() -> bool:
    """True when this host can lower a Pallas kernel with
    ``interpret=False`` (TPU/GPU build); probed once, lazily."""
    global _COMPILED_OK
    if _COMPILED_OK is None:
        try:
            import jax
            import jax.numpy as jnp
            from jax.experimental import pallas as pl

            def k(x_ref, o_ref):
                o_ref[...] = x_ref[...] + 1.0
            pl.pallas_call(
                k, out_shape=jax.ShapeDtypeStruct((8, 128), jnp.float32),
                interpret=False)(jnp.zeros((8, 128), jnp.float32))
            _COMPILED_OK = True
        except Exception:
            _COMPILED_OK = False
    return _COMPILED_OK


def pallas_compiled_fallback() -> bool:
    """True when a ``pallas_compiled`` launch has fallen back to f32
    interpret mode on this host (no compile target)."""
    return _COMPILED_FALLBACK


def _pallas_score(av, bv, pm, sc, *, interpret: bool):
    import jax

    from repro.kernels.ccm_scorer.kernel import score_tiles_fwd
    if av.dtype == np.float64:
        with _x64():
            return np.asarray(score_tiles_fwd(av, bv, pm, sc,
                                              interpret=interpret))
    return np.asarray(score_tiles_fwd(av, bv, pm, sc, interpret=interpret))


def _f32_pads(a_n: int, b_n: int) -> Tuple[int, int]:
    """The f32 deployment tile rounding: A to the 8-sublane boundary, B to
    the 128-lane boundary — ONE definition for both f32 entry points (the
    launcher and the raw full-tile API), so the layout contract the README
    documents cannot fork."""
    return (bucket_lanes(a_n, floor=_LANE_FLOOR, cap=_LANE_FLOOR),
            bucket_lanes(b_n, floor=LANE_CAP, cap=LANE_CAP))


def _pallas_compiled_score(av32, bv32, pm32, sc32):
    global _COMPILED_FALLBACK
    if pallas_compiled_supported():
        return _pallas_score(av32, bv32, pm32, sc32, interpret=False)
    _COMPILED_FALLBACK = True
    return _pallas_score(av32, bv32, pm32, sc32, interpret=True)


# ------------------------------------------------------------ tile packing
def _pack(feats, a_pad: int, b_pad: int, e_pad: int, dtype) -> Tuple:
    av = np.zeros((e_pad, N_AV, a_pad), dtype)
    bv = np.zeros((e_pad, N_AV, b_pad), dtype)
    pm = np.zeros((e_pad, N_PM, a_pad, b_pad), dtype)
    sc = np.zeros((e_pad, N_SC), dtype)
    for k, (av_k, bv_k, pm_k, sc_k) in enumerate(feats):
        av[k, :, :av_k.shape[1]] = av_k
        bv[k, :, :bv_k.shape[1]] = bv_k
        pm[k, :, :pm_k.shape[1], :pm_k.shape[2]] = pm_k
        sc[k] = sc_k
    # pad events are never returned (the launcher slices to real events)
    # and their na = nb = 0 mask leaves only the (0, 0) lane live; give
    # them unit speeds so a full-tile combine doesn't divide by zero
    if len(feats) < e_pad:
        sc[len(feats):, SC.speed_a] = 1.0
        sc[len(feats):, SC.speed_b] = 1.0
    return av, bv, pm, sc


# ------------------------------------------------------------ full tiles
def score_tiles_jit(av: np.ndarray, bv: np.ndarray, pm: np.ndarray,
                    sc: np.ndarray) -> np.ndarray:
    """Full-tile f64 scoring through the bucketed compiled path: pads the
    tiles into their shape bucket, scores, and slices back to the caller's
    shape.  Bitwise-equal to ``ref.score_tiles`` on every returned lane."""
    e_n, _, a_n = av.shape
    b_n = bv.shape[2]
    a_pad, b_pad = bucket_lanes(a_n), bucket_lanes(b_n)
    e_pad = bucket_events(e_n) if e_n else 1
    feats = [(av[k], bv[k], pm[k], sc[k]) for k in range(e_n)]
    avp, bvp, pmp, scp = _pack(feats, a_pad, b_pad, e_pad, np.float64)
    fn = _get_fn(("full", e_pad, a_pad, b_pad))
    with _x64():
        out = np.asarray(fn(avp, bvp, pmp, scp))
    return out[:e_n, :, :a_n, :b_n]


def score_tiles_f32(av: np.ndarray, bv: np.ndarray, pm: np.ndarray,
                    sc: np.ndarray) -> np.ndarray:
    """Full-tile scoring through the f32 compiled-Pallas path (B padded to
    the 128-lane boundary, A to the sublane boundary; interpret fallback on
    hosts without a compile target).  Returns float64 holding the exact f32
    values (upcast is lossless)."""
    e_n, _, a_n = av.shape
    b_n = bv.shape[2]
    a_pad, b_pad = _f32_pads(a_n, b_n)
    e_pad = bucket_events(e_n) if e_n else 1
    feats = [(av[k], bv[k], pm[k], sc[k]) for k in range(e_n)]
    avp, bvp, pmp, scp = _pack(feats, a_pad, b_pad, e_pad, np.float32)
    out = _pallas_compiled_score(avp, bvp, pmp, scp)
    return np.asarray(out[:e_n, :, :a_n, :b_n], np.float64)


def warmup(max_candidates: int = 12, shortlist: int = 32,
           max_batch: int = 1) -> int:
    """Pre-compile the jit buckets a CCM-LB run with these knobs can touch
    (the shortlist P bucket and the event buckets up to ``max_batch``; the
    pair-gathered hot path is lane-free, so candidate counts do not add
    buckets).  Benchmarks call this so the timed region measures the
    steady-state runtime, not one-off XLA compiles; a persistent jax
    compilation cache (CI) makes even the first warmup cheap.  Returns the
    number of buckets now compiled."""
    del max_candidates      # lane-free: kept for call-site readability
    p_pad = bucket_pairs(shortlist)
    e = 1
    e_buckets = []
    while e <= bucket_events(max_batch):
        e_buckets.append(e)
        e *= 2
    import jax

    # the throwaway warm inputs are meaningless, and XLA's speculative
    # evaluation can surface transient NaNs from them that the real hot
    # path never produces — mask the nan checker for the warm calls only
    debug_nans = jax.config.jax_debug_nans
    jax.config.update("jax_debug_nans", False)
    try:
        with _x64():
            for e_pad in e_buckets:
                fn = _get_fn(("pairs", e_pad, p_pad))
                o_pm = _pair_offsets(p_pad)[2]       # sc row starts here
                buf = np.zeros((e_pad, _pair_offsets(p_pad)[-1]))
                buf[:, o_pm + SC.speed_a] = 1.0      # no 0/0 lanes
                buf[:, o_pm + SC.speed_b] = 1.0
                fn(buf)
    finally:
        jax.config.update("jax_debug_nans", debug_nans)
    return bucket_cache_size()


# -------------------------------------------------------- the event launcher
def score_events(feats: Sequence[Tuple], pairs_list: Sequence[np.ndarray],
                 params, *, backend: str = "numpy", interpret: bool = True,
                 ) -> List[Tuple[np.ndarray, np.ndarray, np.ndarray]]:
    """Score a batch of lock events through one backend launch.

    ``feats``: per-event unpadded feature tuples ``(av, bv, pm, sc)`` as
    built by ``PhaseEngine._event_features`` (av: (N_AV, na+1), ...);
    ``pairs_list``: per-event (P, 2) int64 shortlists.  Returns per-event
    ``(w_a, w_b, feasible)`` aligned with each event's pairs.  The combine
    finishes on the host either way: ``ops.combine_work_pairs`` for the
    tile backends, ``ops.combine_terms`` for the jit path (whose products
    were already computed, contraction-safe, in the compiled region) —
    bitwise-identical results.

    Backends: ``numpy`` (reference tiles, exact shapes), ``jit`` (bucketed
    f64 compiled pipeline, bitwise-equal to numpy), ``pallas`` (interpret
    kernel, bitwise-equal), ``pallas_compiled`` (f32, 128-lane tiles,
    assignment-identity bar).
    """
    from repro.kernels.ccm_scorer import ops as scorer_ops

    e_n = len(feats)
    if e_n == 0:
        return []
    results: List[Optional[Tuple]] = [None] * e_n
    live = [k for k in range(e_n) if pairs_list[k].shape[0]]
    for k in range(e_n):
        if pairs_list[k].shape[0] == 0:
            z = np.zeros(0)
            results[k] = (z, z, np.zeros(0, bool))
    if not live:
        return results

    lf = [feats[k] for k in live]

    if backend == "jit":
        e_pad = bucket_events(len(lf))
        p_pad = bucket_pairs(max(pairs_list[k].shape[0] for k in live))
        o_av, o_bv, o_pm, o_sc, o_ia, o_ib, o_cf = _pair_offsets(p_pad)
        buf = np.zeros((e_pad, o_cf))
        coeffs = (params.alpha, params.beta, params.gamma, params.delta)
        for j, k in enumerate(live):
            av_k, bv_k, pm_k, sc_k = feats[k]
            pr = pairs_list[k]                      # pad rows read (0, 0)
            p = pr.shape[0]
            ia, ib = pr[:, 0], pr[:, 1]
            buf[j, :o_av].reshape(N_AV, p_pad)[:, :p] = av_k[:, ia]
            buf[j, o_av:o_bv].reshape(N_AV, p_pad)[:, :p] = bv_k[:, ib]
            buf[j, o_bv:o_pm].reshape(N_PM, p_pad)[:, :p] = pm_k[:, ia, ib]
            buf[j, o_pm:o_sc] = sc_k
            buf[j, o_sc:o_sc + p] = ia
            buf[j, o_ia:o_ia + p] = ib
            buf[j, o_ib:o_cf] = coeffs
        # pad event rows: unit speeds so the in-jit load/speed divide
        # cannot produce 0/0 NaNs (results are discarded, but
        # jax_debug_nans would trip on them; mirrors _pack's guard)
        buf[len(lf):, o_pm + SC.speed_a] = 1.0
        buf[len(lf):, o_pm + SC.speed_b] = 1.0
        fn = _get_fn(("pairs", e_pad, p_pad))
        with _x64():
            terms = np.asarray(fn(buf))             # (E, 10, P)
        for j, k in enumerate(live):
            p = pairs_list[k].shape[0]
            results[k] = scorer_ops.combine_terms(
                terms[j, :, :p], feats[k][3], params)
        return results

    a_max = max(f[0].shape[1] for f in lf)
    b_max = max(f[1].shape[1] for f in lf)
    if backend == "numpy":
        if len(lf) == 1:
            av, bv, pm = (f[None] for f in lf[0][:3])
            sc = lf[0][3][None]
        else:
            av, bv, pm, sc = _pack(lf, a_max, b_max, len(lf), np.float64)
        out = ref.score_tiles(av, bv, pm, sc)
    elif backend == "pallas":
        # bucket the interpret path too: score_tiles_fwd is jitted, so
        # shape-stable launches avoid per-event retracing just like "jit"
        a_pad, b_pad = bucket_lanes(a_max), bucket_lanes(b_max)
        av, bv, pm, sc = _pack(lf, a_pad, b_pad, bucket_events(len(lf)),
                               np.float64)
        out = _pallas_score(av, bv, pm, sc, interpret=interpret)
    elif backend == "pallas_compiled":
        a_pad, b_pad = _f32_pads(a_max, b_max)
        av, bv, pm, sc = _pack(lf, a_pad, b_pad, bucket_events(len(lf)),
                               np.float32)
        out = _pallas_compiled_score(av, bv, pm, sc)
    else:
        raise ValueError(f"unknown ccm_scorer backend: {backend!r}")

    if out.dtype != np.float64:
        out = np.asarray(out, np.float64)       # f32 path: lossless upcast
    if len(live) == 1:
        # solo event: combine only the gathered shortlist lanes
        p = pairs_list[live[0]]
        outp = out[0][:, p[:, 0], p[:, 1]]              # (N_OUT, P)
        results[live[0]] = scorer_ops.combine_work_pairs(
            outp, feats[live[0]][3], params)
        return results
    # batched flush: ONE full-tile combine for all events amortizes the
    # numpy op dispatch (gather-then-combine per event would multiply it
    # by E); combine-then-gather is bitwise-identical per pair
    w_a, w_b, feas = scorer_ops.combine_work(out, sc, params)
    for j, k in enumerate(live):
        p = pairs_list[k]
        ia, ib = p[:, 0], p[:, 1]
        results[k] = (w_a[j, ia, ib], w_b[j, ia, ib], feas[j, ia, ib])
    return results
