"""Compiled shape-bucketed scorer runtime: the jitted event pipeline.

Why buckets
-----------
``jax.jit`` specializes on input shapes: every distinct (E, A, B, P)
quadruple triggers a fresh trace + XLA compile.  Lock-event tiles are small
but their shapes churn (candidate counts vary per rank pair, shortlists
vary per event), so naive jitting would re-trace on the hot path — worse
than the numpy dispatch it replaces.  The launcher therefore pads every
tile into a small, fixed grid of *shape buckets*:

  * lane dims A/B (padded candidate counts): powers of two in
    [8, 128], then multiples of 128 — ``bucket_lanes``.  128 is the TPU
    lane boundary, so a bucket that reaches it stops specializing and
    grows in whole lanes instead.
  * the event dim E and the shortlist dim P: powers of two
    (``bucket_events`` / ``bucket_pairs``; P additionally floors at 32,
    the default shortlist cap, so one P bucket serves every
    normally-sized event).

With ``max_candidates=12`` and ``shortlist=32`` a whole CCM-LB trajectory
touches a handful of buckets; each compiles exactly once
(tests/test_scorer_jit.py guards the recompile count via
:func:`trace_count`).

What is fused
-------------
One jitted function per bucket evaluates the full scorer expression tree
(ref.score_tiles_xp traced with ``xp=jax.numpy`` — the SAME source
expressions as the numpy backend) and gathers the shortlisted (ia, ib)
pairs, so the host receives (E, P, N_OUT) instead of (E, N_OUT, A, B).
Padding is invariant by construction: every op in the tree is elementwise
over the (A, B) tile, so padded lanes cannot perturb real ones, and the
f64 outputs on real lanes are BITWISE-equal to the unpadded numpy backend
(adds/subs/maxima/selects only — nothing XLA can re-round).

The affine work combine stays on the host (ops.combine_work_pairs, shared
by every backend) for the same reason it is not in the Pallas kernel:
XLA:CPU compiles with ``FPOpFusion::Fast`` at instruction selection, so any
``mul`` feeding an ``add`` becomes an FMA **regardless of IR-level
fast-math flags** — measured on this tree: ``jit(0.37*x + 0.21*y)`` equals
``fma(0.37, x, 0.21*y)``, and neither ``lax.optimization_barrier`` nor
bitcast round-trips survive the simplifier to block it.  A fused combine
therefore cannot meet the bitwise f64 parity bar on CPU; combining on the
(P,)-gathered host side costs ~10 tiny numpy ops per event and keeps the
contract exact.

The speculative-scan path
-------------------------
``kind="spec"`` buckets compile the OTHER direction of the same trade: one
launch scores a whole *window* of upcoming lock events, and the per-event
feature assembly itself — the group-flow matrix bincount and every slice
sum ``PhaseEngine._flow_matrices`` / ``_event_features`` used to run on the
host — moves into the traced body.  The host ships raw ingredients (edge
bins + volumes, the non-flow feature rows, a scalar row with the flow
slots zeroed) as ONE flat f64 row per event; the traced body scatter-adds
the flow matrix, derives all flow-dependent features, scores the shortlist
through the SAME ``ref.score_planes`` expression tree, applies the work
combine and the selection rule in-trace, and returns only the winning pair
per event.  A ``jax.lax.scan`` over the window axis (``mode="scan"``) or a
``jax.vmap`` over independent instances (``mode="vmap"``) wraps one shared
per-event body, so every window/fleet size reuses the same trace.

This path CANNOT meet the bitwise f64 bar: the scatter-add segment sums
combine duplicate bins in an XLA-chosen order, while the host reference's
``np.bincount`` accumulates sequentially (and numpy's ``.sum()`` pairwise
summation differs from XLA's reduce order), so the flow features differ by
summation-order ulps.  It therefore sits in its own *compiled-vs-host*
parity tier — end-to-end assignment identity on the ccmlb_scaling
instances plus a tracked ulp budget, with the host engine path kept as
the reference twin (README.md documents the full ladder).

The f32 compiled path
---------------------
``backend="pallas_compiled"`` packs the same tiles in float32 with B padded
to the 128-lane boundary (A to the 8-sublane boundary) and launches the
Pallas kernel with ``interpret=False``.  On hosts without a Pallas compile
target (CPU CI) the launcher transparently falls back to f32 interpret mode
— same dtype, same layout, same masked tail — and records it in
:func:`pallas_compiled_fallback`.  The f32 path's parity bar is
*assignment identity* on well-separated instances (scores differ from f64
by ulps of f32), not bitwise equality; tests/test_scorer_jit.py implements
the bar and reports the ulp budget on adversarial tiles.
"""
from __future__ import annotations

from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro.kernels.ccm_scorer import ref
from repro.kernels.ccm_scorer.layout import N_AV, N_OUT, N_PM, N_SC, OUT, SC

__all__ = ["bucket_lanes", "bucket_events", "bucket_pairs", "bucket_edges",
           "score_events", "score_spec", "spec_warmup",
           "score_tiles_jit", "score_tiles_f32", "trace_count",
           "bucket_cache_size", "pallas_compiled_supported",
           "pallas_compiled_fallback", "LANE_CAP"]

LANE_CAP = 128      # TPU lane boundary: buckets stop doubling here
_LANE_FLOOR = 8     # sublane quantum; also the smallest useful tile

_TRACE_COUNT = 0          # incremented inside every traced body
_FN_CACHE: dict = {}      # bucket key -> compiled callable
_COMPILED_OK: Optional[bool] = None
_COMPILED_FALLBACK = False


# ------------------------------------------------------------- bucket grid
def bucket_lanes(n: int, *, floor: int = _LANE_FLOOR,
                 cap: int = LANE_CAP) -> int:
    """Round a lane count up to the bucket grid: powers of two in
    [floor, cap], multiples of ``cap`` beyond it."""
    n = max(int(n), 1)
    if n <= floor:
        return floor
    if n >= cap:
        return -(-n // cap) * cap
    return 1 << (n - 1).bit_length()


def bucket_events(e: int) -> int:
    """Event-axis bucket: next power of two (E is small — the
    ``batch_lock_events`` cap)."""
    e = max(int(e), 1)
    return 1 << (e - 1).bit_length()


def bucket_pairs(p: int) -> int:
    """Shortlist-axis bucket: powers of two with a floor of 32 (the default
    shortlist cap) — one bucket serves every normally-sized event, so P
    churn cannot multiply the compile count."""
    p = max(int(p), 1)
    return max(32, 1 << (p - 1).bit_length())


def bucket_edges(n: int) -> int:
    """Edge-axis bucket for the speculative-scan rows: powers of two with a
    floor of 32.  Incident-edge counts churn per rank pair, so without the
    pow2 grid every distinct count would be a fresh compile; with it a whole
    trajectory touches at most log2(max incident edges) edge buckets."""
    n = max(int(n), 1)
    return max(32, 1 << (n - 1).bit_length())


def trace_count() -> int:
    """How many times a bucketed scorer body has been TRACED (== compiled,
    barring jax's persistent cache).  The recompile-count guard asserts this
    stays bounded by the number of distinct buckets."""
    return _TRACE_COUNT


def bucket_cache_size() -> int:
    return len(_FN_CACHE)


def bucket_keys() -> list:
    """The distinct compiled bucket keys, stringified (kind plus the static
    shape info).  Each key traces exactly once per process, so together
    with ``trace_count()`` this is the per-bucket compile ledger the
    benchmarks record PR to PR."""
    return sorted(str(k) for k in _FN_CACHE)


# --------------------------------------------------------- compiled bodies
def _pair_offsets(p: int) -> Tuple[int, ...]:
    """Cumulative offsets of [avp | bvp | pmp | sc | iaf | ibf | coeffs]
    in one flat per-event row of the pair-gathered layout (coeffs =
    alpha/beta/gamma/delta).  A single input array keeps the host->device
    transfer to ONE numpy conversion per launch — with several separate
    small arrays the per-array ingest dominates the whole dispatch
    (~30 us each on CPU)."""
    o_av = N_AV * p
    o_bv = o_av + N_AV * p
    o_pm = o_bv + N_PM * p
    o_sc = o_pm + N_SC
    o_ia = o_sc + p
    o_ib = o_ia + p
    o_cf = o_ib + 4
    return o_av, o_bv, o_pm, o_sc, o_ia, o_ib, o_cf


def _spec_offsets(eb: int, a_n: int, b_n: int, p_n: int) -> Tuple[int, ...]:
    """Cumulative offsets of
    ``[bins | w | avh | bvh | pmh | sch | iaf | ibf | misc]`` in one flat
    per-event row of the speculative-scan layout (misc = alpha, beta,
    gamma, delta, w_before, p_count).  ``bins``/``w`` are the flow-matrix
    scatter inputs (eb edge slots each), ``avh``/``bvh`` the seven host-side
    candidate feature rows (AV.load..AV.h_add_peer), ``pmh`` the four
    host-side pairwise correction planes gathered at the shortlist, ``sch``
    the scalar row with the eight flow slots zeroed (filled in-trace).
    One flat f64 row per event for the same reason as ``_pair_offsets``:
    per-array device ingest would dominate the launch."""
    o_w = eb
    o_av = o_w + eb
    o_bv = o_av + 7 * a_n
    o_pm = o_bv + 7 * b_n
    o_sc = o_pm + 4 * p_n
    o_ia = o_sc + N_SC
    o_ib = o_ia + p_n
    o_ms = o_ib + p_n
    return o_w, o_av, o_bv, o_pm, o_sc, o_ia, o_ib, o_ms, o_ms + 6


def _get_fn(key):
    """Per-bucket compiled function.  key = (kind, *static shape info)."""
    fn = _FN_CACHE.get(key)
    if fn is None:
        import jax
        import jax.numpy as jnp

        kind = key[0]
        if kind == "pairs":
            # the hot path: pair-gathered scoring.  Tiles are gathered at
            # the shortlist on the host, so the compiled work is O(P) per
            # event — independent of the candidate counts — and the bucket
            # grid collapses to (E, P) keys.  The combine's multiplies and
            # divides also run here: a lone mul whose result feeds an
            # OUTPUT (not an add) cannot be FMA-contracted, so the bits
            # match the host products exactly; only the adds (which XLA
            # would contract) remain on the host (ops.combine_terms).
            _, e_n, p_n = key
            o_av, o_bv, o_pm, o_sc, o_ia, o_ib, o_cf = _pair_offsets(p_n)

            def body(buf):
                global _TRACE_COUNT
                _TRACE_COUNT += 1           # runs at trace time only
                avp = buf[:, :o_av].reshape(e_n, N_AV, p_n)
                bvp = buf[:, o_av:o_bv].reshape(e_n, N_AV, p_n)
                pmp = buf[:, o_bv:o_pm].reshape(e_n, N_PM, p_n)
                sc = buf[:, o_pm:o_sc]
                iaf = buf[:, o_sc:o_ia]
                ibf = buf[:, o_ia:o_ib]
                out = ref.score_pairs_xp(avp, bvp, pmp, sc, iaf, ibf,
                                         xp=jnp)     # (E, N_OUT, P)
                al = buf[:, o_ib + 0, None]
                be = buf[:, o_ib + 1, None]
                ga = buf[:, o_ib + 2, None]
                de = buf[:, o_ib + 3, None]
                terms = [
                    al * out[:, OUT.load_a] / sc[:, SC.speed_a, None],
                    be * out[:, OUT.off_a],
                    ga * out[:, OUT.on_a],
                    de * out[:, OUT.hom_a],
                    al * out[:, OUT.load_b] / sc[:, SC.speed_b, None],
                    be * out[:, OUT.off_b],
                    ga * out[:, OUT.on_b],
                    de * out[:, OUT.hom_b],
                    out[:, OUT.mem_a],
                    out[:, OUT.mem_b],
                ]
                return jnp.stack(terms, axis=1)      # (E, 10, P)
        elif kind == "spec":
            # the speculative-scan path: the WHOLE per-event pipeline —
            # flow-matrix assembly (scatter-add over the fixed group-label
            # layout), slice-sum feature derivation, the score_planes
            # expression tree, the work combine AND the selection rule —
            # runs in-trace, once per window row.  Only the winning pair
            # index and its scores leave the device, so a window of W
            # events costs one dispatch instead of W.
            _, mode, w_n, eb, a_n, b_n, p_n = key
            (o_w, o_av, o_bv, o_pm, o_sc, o_ia, o_ib, o_ms,
             _row_len) = _spec_offsets(eb, a_n, b_n, p_n)
            # fixed group-label layout (mirrors PhaseEngine.spec_raw):
            # 0 = other ranks, 1 = stays on a, 2 = stays on b,
            # a-candidate i at sa + (i - 1), b-candidate j at sb + (j - 1).
            sa = 3
            sb = 3 + (a_n - 1)
            g_n = sb + (b_n - 1)

            def one(row):
                bins = row[:o_w].astype(jnp.int32)
                wgt = row[o_w:o_av]
                F = (jnp.zeros(g_n * g_n, row.dtype).at[bins].add(wgt)
                     .reshape(g_n, g_n))
                # slice sums over the fixed layout; unused candidate groups
                # received no edges, so their contribution is exactly zero
                row_to_a = F[:, 1] + F[:, sa:sb].sum(1)     # -> rank a
                row_to_b = F[:, 2] + F[:, sb:].sum(1)       # -> rank b
                col_from_a = F[1, :] + F[sa:sb, :].sum(0)   # rank a ->
                col_from_b = F[2, :] + F[sb:, :].sum(0)     # rank b ->
                ar = jnp.arange(sa, sb)
                br = jnp.arange(sb, g_n)
                z1 = jnp.zeros((1,), row.dtype)
                # in-trace AV rows 0..6 (flow-derived); rows 7..13 ride in
                # from the host (avh) — same split as _event_features
                avf = jnp.stack([
                    jnp.concatenate([z1, F[ar, ar]]),            # intra
                    jnp.concatenate([z1, row_to_a[sa:sb]]),      # out_own
                    jnp.concatenate([z1, col_from_a[sa:sb]]),    # in_own
                    jnp.concatenate([z1, row_to_b[sa:sb]]),      # out_peer
                    jnp.concatenate([z1, col_from_b[sa:sb]]),    # in_peer
                    jnp.concatenate([z1, F[ar, 0]]),             # out_other
                    jnp.concatenate([z1, F[0, ar]]),             # in_other
                ])
                bvf = jnp.stack([
                    jnp.concatenate([z1, F[br, br]]),
                    jnp.concatenate([z1, row_to_b[sb:]]),
                    jnp.concatenate([z1, col_from_b[sb:]]),
                    jnp.concatenate([z1, row_to_a[sb:]]),
                    jnp.concatenate([z1, col_from_a[sb:]]),
                    jnp.concatenate([z1, F[br, 0]]),
                    jnp.concatenate([z1, F[0, br]]),
                ])
                av = jnp.concatenate(
                    [avf, row[o_av:o_bv].reshape(7, a_n)], axis=0)
                bv = jnp.concatenate(
                    [bvf, row[o_bv:o_pm].reshape(7, b_n)], axis=0)
                flows = jnp.stack([
                    row_to_b[1] + row_to_b[sa:sb].sum(),    # f_ab
                    row_to_a[2] + row_to_a[sb:].sum(),      # f_ba
                    row_to_a[1] + row_to_a[sa:sb].sum(),    # f_aa
                    row_to_b[2] + row_to_b[sb:].sum(),      # f_bb
                    F[1, 0] + F[sa:sb, 0].sum(),            # f_ao
                    F[0, 1] + F[0, sa:sb].sum(),            # f_oa
                    F[2, 0] + F[sb:, 0].sum(),              # f_bo
                    F[0, 2] + F[0, sb:].sum(),              # f_ob
                ])
                sc = row[o_sc:o_ia].at[:8].set(flows)
                ia = row[o_ia:o_ib].astype(jnp.int32)
                ib = row[o_ib:o_ms].astype(jnp.int32)
                avp = av[:, ia]                             # (14, P)
                bvp = bv[:, ib]
                on_pair = (ia >= 1) & (ib >= 1)
                x_ab = jnp.where(on_pair, F[sa - 1 + ia, sb - 1 + ib], 0.0)
                x_ba = jnp.where(on_pair, F[sb - 1 + ib, sa - 1 + ia], 0.0)
                pm = jnp.concatenate(
                    [jnp.stack([x_ab, x_ba]),
                     row[o_pm:o_sc].reshape(4, p_n)], axis=0)   # (6, P)
                planes = ref.score_planes(
                    col=lambda i: avp[i], row=lambda i: bvp[i],
                    scal=lambda i: sc[i], pmp=lambda i: pm[i], xp=jnp)
                # in-trace combine + selection.  FMA contraction is fine
                # here: this path's parity bar is compiled-vs-host (ulp
                # budget + assignment identity), not bitwise f64.
                al, be = row[o_ms + 0], row[o_ms + 1]
                ga, de = row[o_ms + 2], row[o_ms + 3]
                w_before = row[o_ms + 4]
                p_cnt = row[o_ms + 5]
                w_a = (al * planes[OUT.load_a] / sc[SC.speed_a]
                       + be * planes[OUT.off_a] + ga * planes[OUT.on_a]
                       + de * planes[OUT.hom_a])
                w_b = (al * planes[OUT.load_b] / sc[SC.speed_b]
                       + be * planes[OUT.off_b] + ga * planes[OUT.on_b]
                       + de * planes[OUT.hom_b])
                # spec_raw packs the caps pre-scaled by effective_mem_cap
                # (inf when the constraint is off), so compare plain <=
                feas = ((planes[OUT.mem_a] <= sc[SC.mem_cap_a])
                        & (planes[OUT.mem_b] <= sc[SC.mem_cap_b]))
                valid = jnp.arange(p_n) < p_cnt
                diff = w_before - jnp.maximum(w_a, w_b)
                # argmax picks the FIRST max over the same candidate order
                # select_best walks, so selection matches the host rule
                score = jnp.where(valid & feas & (diff > 1e-12),
                                  diff, -jnp.inf)
                j = jnp.argmax(score)
                return jnp.stack([j.astype(row.dtype), score[j],
                                  w_a[j], w_b[j]])

            if mode == "scan":
                def body(buf):
                    global _TRACE_COUNT
                    _TRACE_COUNT += 1
                    _, out = jax.lax.scan(
                        lambda c, r: (c, one(r)),
                        jnp.zeros((), jnp.int32), buf)
                    return out                      # (W, 4)
            elif mode == "vmap":
                def body(buf):
                    global _TRACE_COUNT
                    _TRACE_COUNT += 1
                    return jax.vmap(one)(buf)       # (W, 4)
            else:                           # pragma: no cover
                raise ValueError(f"unknown spec mode: {mode!r}")
            del w_n                         # shape carried by buf itself
        elif kind == "full":
            def body(av, bv, pm, sc):
                global _TRACE_COUNT
                _TRACE_COUNT += 1
                return ref.score_tiles_xp(av, bv, pm, sc, xp=jnp)
        else:                               # pragma: no cover
            raise ValueError(f"unknown bucketed fn kind: {kind!r}")
        fn = jax.jit(body)
        _FN_CACHE[key] = fn
    return fn


def _x64():
    import jax
    return jax.experimental.enable_x64()


# -------------------------------------------------------------- f32 Pallas
def pallas_compiled_supported() -> bool:
    """True when this host can lower a Pallas kernel with
    ``interpret=False`` (TPU/GPU build); probed once, lazily."""
    global _COMPILED_OK
    if _COMPILED_OK is None:
        try:
            import jax
            import jax.numpy as jnp
            from jax.experimental import pallas as pl

            def k(x_ref, o_ref):
                o_ref[...] = x_ref[...] + 1.0
            pl.pallas_call(
                k, out_shape=jax.ShapeDtypeStruct((8, 128), jnp.float32),
                interpret=False)(jnp.zeros((8, 128), jnp.float32))
            _COMPILED_OK = True
        except Exception:
            _COMPILED_OK = False
    return _COMPILED_OK


def pallas_compiled_fallback() -> bool:
    """True when a ``pallas_compiled`` launch has fallen back to f32
    interpret mode on this host (no compile target)."""
    return _COMPILED_FALLBACK


def _pallas_score(av, bv, pm, sc, *, interpret: bool):
    import jax

    from repro.kernels.ccm_scorer.kernel import score_tiles_fwd
    if av.dtype == np.float64:
        with _x64():
            return np.asarray(score_tiles_fwd(av, bv, pm, sc,
                                              interpret=interpret))
    return np.asarray(score_tiles_fwd(av, bv, pm, sc, interpret=interpret))


def _f32_pads(a_n: int, b_n: int) -> Tuple[int, int]:
    """The f32 deployment tile rounding: A to the 8-sublane boundary, B to
    the 128-lane boundary — ONE definition for both f32 entry points (the
    launcher and the raw full-tile API), so the layout contract the README
    documents cannot fork."""
    return (bucket_lanes(a_n, floor=_LANE_FLOOR, cap=_LANE_FLOOR),
            bucket_lanes(b_n, floor=LANE_CAP, cap=LANE_CAP))


def _pallas_compiled_score(av32, bv32, pm32, sc32):
    global _COMPILED_FALLBACK
    if pallas_compiled_supported():
        return _pallas_score(av32, bv32, pm32, sc32, interpret=False)
    _COMPILED_FALLBACK = True
    return _pallas_score(av32, bv32, pm32, sc32, interpret=True)


# ------------------------------------------------------------ tile packing
def _pack(feats, a_pad: int, b_pad: int, e_pad: int, dtype) -> Tuple:
    av = np.zeros((e_pad, N_AV, a_pad), dtype)
    bv = np.zeros((e_pad, N_AV, b_pad), dtype)
    pm = np.zeros((e_pad, N_PM, a_pad, b_pad), dtype)
    sc = np.zeros((e_pad, N_SC), dtype)
    for k, (av_k, bv_k, pm_k, sc_k) in enumerate(feats):
        av[k, :, :av_k.shape[1]] = av_k
        bv[k, :, :bv_k.shape[1]] = bv_k
        pm[k, :, :pm_k.shape[1], :pm_k.shape[2]] = pm_k
        sc[k] = sc_k
    # pad events are never returned (the launcher slices to real events)
    # and their na = nb = 0 mask leaves only the (0, 0) lane live; give
    # them unit speeds so a full-tile combine doesn't divide by zero
    if len(feats) < e_pad:
        sc[len(feats):, SC.speed_a] = 1.0
        sc[len(feats):, SC.speed_b] = 1.0
    return av, bv, pm, sc


# ------------------------------------------------------------ full tiles
def score_tiles_jit(av: np.ndarray, bv: np.ndarray, pm: np.ndarray,
                    sc: np.ndarray) -> np.ndarray:
    """Full-tile f64 scoring through the bucketed compiled path: pads the
    tiles into their shape bucket, scores, and slices back to the caller's
    shape.  Bitwise-equal to ``ref.score_tiles`` on every returned lane."""
    e_n, _, a_n = av.shape
    b_n = bv.shape[2]
    a_pad, b_pad = bucket_lanes(a_n), bucket_lanes(b_n)
    e_pad = bucket_events(e_n) if e_n else 1
    feats = [(av[k], bv[k], pm[k], sc[k]) for k in range(e_n)]
    avp, bvp, pmp, scp = _pack(feats, a_pad, b_pad, e_pad, np.float64)
    fn = _get_fn(("full", e_pad, a_pad, b_pad))
    with _x64():
        out = np.asarray(fn(avp, bvp, pmp, scp))
    return out[:e_n, :, :a_n, :b_n]


def score_tiles_f32(av: np.ndarray, bv: np.ndarray, pm: np.ndarray,
                    sc: np.ndarray) -> np.ndarray:
    """Full-tile scoring through the f32 compiled-Pallas path (B padded to
    the 128-lane boundary, A to the sublane boundary; interpret fallback on
    hosts without a compile target).  Returns float64 holding the exact f32
    values (upcast is lossless)."""
    e_n, _, a_n = av.shape
    b_n = bv.shape[2]
    a_pad, b_pad = _f32_pads(a_n, b_n)
    e_pad = bucket_events(e_n) if e_n else 1
    feats = [(av[k], bv[k], pm[k], sc[k]) for k in range(e_n)]
    avp, bvp, pmp, scp = _pack(feats, a_pad, b_pad, e_pad, np.float32)
    out = _pallas_compiled_score(avp, bvp, pmp, scp)
    return np.asarray(out[:e_n, :, :a_n, :b_n], np.float64)


def warmup(max_candidates: int = 12, shortlist: int = 32,
           max_batch: int = 1) -> int:
    """Pre-compile the jit buckets a CCM-LB run with these knobs can touch
    (the shortlist P bucket and the event buckets up to ``max_batch``; the
    pair-gathered hot path is lane-free, so candidate counts do not add
    buckets).  Benchmarks call this so the timed region measures the
    steady-state runtime, not one-off XLA compiles; a persistent jax
    compilation cache (CI) makes even the first warmup cheap.  Returns the
    number of buckets now compiled."""
    del max_candidates      # lane-free: kept for call-site readability
    p_pad = bucket_pairs(shortlist)
    e = 1
    e_buckets = []
    while e <= bucket_events(max_batch):
        e_buckets.append(e)
        e *= 2
    import jax

    # the throwaway warm inputs are meaningless, and XLA's speculative
    # evaluation can surface transient NaNs from them that the real hot
    # path never produces — mask the nan checker for the warm calls only
    debug_nans = jax.config.jax_debug_nans
    jax.config.update("jax_debug_nans", False)
    try:
        with _x64():
            for e_pad in e_buckets:
                fn = _get_fn(("pairs", e_pad, p_pad))
                o_pm = _pair_offsets(p_pad)[2]       # sc row starts here
                buf = np.zeros((e_pad, _pair_offsets(p_pad)[-1]))
                buf[:, o_pm + SC.speed_a] = 1.0      # no 0/0 lanes
                buf[:, o_pm + SC.speed_b] = 1.0
                fn(buf)
    finally:
        jax.config.update("jax_debug_nans", debug_nans)
    return bucket_cache_size()


# ------------------------------------------------- the speculative launcher
def score_spec(raws: Sequence[Tuple[np.ndarray, int]], *, a_lanes: int,
               b_lanes: int, p_n: int, mode: str = "scan",
               window: Optional[int] = None) -> np.ndarray:
    """Score a window of speculative lock events in ONE compiled launch.

    ``raws``: per-event ``(row, eb)`` pairs as built by
    ``PhaseEngine.spec_raw`` — ``row`` a complete launch row in the
    ``_spec_offsets(eb, a_lanes, b_lanes, p_n)`` layout (params columns,
    pair count and the driver's w_before already baked in), ``eb`` its
    edge bucket.  Rows sharing the window's edge bucket stack verbatim;
    a smaller row lands with three slice copies, since everything after
    its ``[bins | w]`` head is eb-independent.  Returns ``(len(raws), 4)``
    float64 rows ``[pair slot, diff, w_a, w_b]``: the in-trace selection's
    winning shortlist slot, its work improvement (``-inf`` when no
    feasible improving pair exists — the event is a no-op), and the
    winner's resulting per-rank works.

    ``mode="scan"`` compiles a ``lax.scan`` over the window axis (the solo
    speculative driver), ``mode="vmap"`` a ``jax.vmap`` (the fleet mode);
    both share the identical per-event body.  Outputs sit in the
    compiled-vs-host parity tier (see module docstring), NOT the bitwise
    f64 tier.
    """
    n = len(raws)
    if n == 0:
        return np.zeros((0, 4))
    # bucket on the FILL, not the configured window: a short disjoint
    # prefix then runs a correspondingly small compiled scan instead of
    # padding to the window bucket (pad rows compute in-trace, so window-
    # sized buckets made large windows net losers).  ``window`` remains
    # the warmup hint for the bucket ladder's top.
    del window
    w_n = bucket_events(n)
    eb = max(r[1] for r in raws)
    o_sc, row_len = _spec_offsets(eb, a_lanes, b_lanes, p_n)[4::4]
    buf = np.zeros((w_n, row_len))
    if all(r[1] == eb for r in raws):
        for k, (row, _) in enumerate(raws):
            buf[k] = row
    else:
        for k, (row, e_k) in enumerate(raws):
            buf[k, :e_k] = row[:e_k]            # bins (pad bins stay in
            buf[k, eb:eb + e_k] = row[e_k:2 * e_k]  # (0, 0)); w
            buf[k, 2 * eb:] = row[2 * e_k:]     # the eb-independent tail
    # pad event rows: unit speeds so the in-trace divide cannot 0/0
    # (their p_count stays 0, masking them out of the in-trace argmax)
    buf[n:, o_sc + SC.speed_a] = 1.0
    buf[n:, o_sc + SC.speed_b] = 1.0
    fn = _get_fn(("spec", mode, w_n, eb, a_lanes, b_lanes, p_n))
    with _x64():
        out = np.asarray(fn(buf))
    return out[:n]


def spec_warmup(*, max_candidates: int = 12, shortlist: int = 32,
                window: int = 8, edges: Sequence[int] = (256,),
                modes: Sequence[str] = ("scan",)) -> int:
    """Pre-compile the speculative-scan buckets a run with these knobs can
    touch: the power-of-two fill ladder up to the window bucket, per
    (mode, edge bucket) — the lane and pair buckets are pinned by
    ``max_candidates``/``shortlist``.  (``score_spec`` buckets on the
    actual fill, so a run with window W touches every ladder rung, not
    just the top.)  Pass the edge buckets the instance family reaches
    (``bucket_edges`` of typical incident-edge counts); benchmarks call
    this so the timed region holds no XLA compiles.  Returns the number
    of buckets now compiled."""
    import jax

    lanes = bucket_lanes(max_candidates + 1)
    p_n = bucket_pairs(min(max_candidates * (max_candidates + 2),
                           shortlist))
    debug_nans = jax.config.jax_debug_nans
    jax.config.update("jax_debug_nans", False)
    try:
        for mode in modes:
            for e in edges:
                eb = bucket_edges(e)
                o_sc, row_len = _spec_offsets(eb, lanes, lanes, p_n)[4::4]
                row = np.zeros(row_len)
                row[o_sc + SC.speed_a] = 1.0    # no 0/0 lanes
                row[o_sc + SC.speed_b] = 1.0
                w = 1
                while w <= bucket_events(window):
                    score_spec([(row, eb)] * w, a_lanes=lanes,
                               b_lanes=lanes, p_n=p_n, mode=mode,
                               window=window)
                    w *= 2
    finally:
        jax.config.update("jax_debug_nans", debug_nans)
    return bucket_cache_size()


# -------------------------------------------------------- the event launcher
def score_events(feats: Sequence[Tuple], pairs_list: Sequence[np.ndarray],
                 params, *, backend: str = "numpy", interpret: bool = True,
                 ) -> List[Tuple[np.ndarray, np.ndarray, np.ndarray]]:
    """Score a batch of lock events through one backend launch.

    ``feats``: per-event unpadded feature tuples ``(av, bv, pm, sc)`` as
    built by ``PhaseEngine._event_features`` (av: (N_AV, na+1), ...);
    ``pairs_list``: per-event (P, 2) int64 shortlists.  Returns per-event
    ``(w_a, w_b, feasible)`` aligned with each event's pairs.  The combine
    finishes on the host either way: ``ops.combine_work_pairs`` for the
    tile backends, ``ops.combine_terms`` for the jit path (whose products
    were already computed, contraction-safe, in the compiled region) —
    bitwise-identical results.

    Backends: ``numpy`` (reference tiles, exact shapes), ``jit`` (bucketed
    f64 compiled pipeline, bitwise-equal to numpy), ``pallas`` (interpret
    kernel, bitwise-equal), ``pallas_compiled`` (f32, 128-lane tiles,
    assignment-identity bar).
    """
    from repro.kernels.ccm_scorer import ops as scorer_ops

    e_n = len(feats)
    if e_n == 0:
        return []
    results: List[Optional[Tuple]] = [None] * e_n
    live = [k for k in range(e_n) if pairs_list[k].shape[0]]
    for k in range(e_n):
        if pairs_list[k].shape[0] == 0:
            z = np.zeros(0)
            results[k] = (z, z, np.zeros(0, bool))
    if not live:
        return results

    lf = [feats[k] for k in live]

    if backend == "jit":
        e_pad = bucket_events(len(lf))
        p_pad = bucket_pairs(max(pairs_list[k].shape[0] for k in live))
        o_av, o_bv, o_pm, o_sc, o_ia, o_ib, o_cf = _pair_offsets(p_pad)
        buf = np.zeros((e_pad, o_cf))
        coeffs = (params.alpha, params.beta, params.gamma, params.delta)
        for j, k in enumerate(live):
            av_k, bv_k, pm_k, sc_k = feats[k]
            pr = pairs_list[k]                      # pad rows read (0, 0)
            p = pr.shape[0]
            ia, ib = pr[:, 0], pr[:, 1]
            buf[j, :o_av].reshape(N_AV, p_pad)[:, :p] = av_k[:, ia]
            buf[j, o_av:o_bv].reshape(N_AV, p_pad)[:, :p] = bv_k[:, ib]
            buf[j, o_bv:o_pm].reshape(N_PM, p_pad)[:, :p] = pm_k[:, ia, ib]
            buf[j, o_pm:o_sc] = sc_k
            buf[j, o_sc:o_sc + p] = ia
            buf[j, o_ia:o_ia + p] = ib
            buf[j, o_ib:o_cf] = coeffs
        # pad event rows: unit speeds so the in-jit load/speed divide
        # cannot produce 0/0 NaNs (results are discarded, but
        # jax_debug_nans would trip on them; mirrors _pack's guard)
        buf[len(lf):, o_pm + SC.speed_a] = 1.0
        buf[len(lf):, o_pm + SC.speed_b] = 1.0
        fn = _get_fn(("pairs", e_pad, p_pad))
        with _x64():
            terms = np.asarray(fn(buf))             # (E, 10, P)
        for j, k in enumerate(live):
            p = pairs_list[k].shape[0]
            results[k] = scorer_ops.combine_terms(
                terms[j, :, :p], feats[k][3], params)
        return results

    a_max = max(f[0].shape[1] for f in lf)
    b_max = max(f[1].shape[1] for f in lf)
    if backend == "numpy":
        if len(lf) == 1:
            av, bv, pm = (f[None] for f in lf[0][:3])
            sc = lf[0][3][None]
        else:
            av, bv, pm, sc = _pack(lf, a_max, b_max, len(lf), np.float64)
        out = ref.score_tiles(av, bv, pm, sc)
    elif backend == "pallas":
        # bucket the interpret path too: score_tiles_fwd is jitted, so
        # shape-stable launches avoid per-event retracing just like "jit"
        a_pad, b_pad = bucket_lanes(a_max), bucket_lanes(b_max)
        av, bv, pm, sc = _pack(lf, a_pad, b_pad, bucket_events(len(lf)),
                               np.float64)
        out = _pallas_score(av, bv, pm, sc, interpret=interpret)
    elif backend == "pallas_compiled":
        a_pad, b_pad = _f32_pads(a_max, b_max)
        av, bv, pm, sc = _pack(lf, a_pad, b_pad, bucket_events(len(lf)),
                               np.float32)
        out = _pallas_compiled_score(av, bv, pm, sc)
    else:
        raise ValueError(f"unknown ccm_scorer backend: {backend!r}")

    if out.dtype != np.float64:
        out = np.asarray(out, np.float64)       # f32 path: lossless upcast
    if len(live) == 1:
        # solo event: combine only the gathered shortlist lanes
        p = pairs_list[live[0]]
        outp = out[0][:, p[:, 0], p[:, 1]]              # (N_OUT, P)
        results[live[0]] = scorer_ops.combine_work_pairs(
            outp, feats[live[0]][3], params)
        return results
    # batched flush: ONE full-tile combine for all events amortizes the
    # numpy op dispatch (gather-then-combine per event would multiply it
    # by E); combine-then-gather is bitwise-identical per pair
    w_a, w_b, feas = scorer_ops.combine_work(out, sc, params)
    for j, k in enumerate(live):
        p = pairs_list[k]
        ia, ib = p[:, 0], p[:, 1]
        results[k] = (w_a[j, ia, ib], w_b[j, ia, ib], feas[j, ia, ib])
    return results
