"""Jit'd wrapper: pads (n, 3) coords to the (n, 8) lane layout the kernel
expects and dispatches on the (static) quadrature depth."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.assembly.kernel import assembly_tile_fwd


@functools.partial(jax.jit, static_argnames=("quad_order", "block_r",
                                             "block_c", "mxu_distance",
                                             "interpret"))
def assembly_tile(pr, pc, couple, *, quad_order: int, block_r: int = 128,
                  block_c: int = 128, mxu_distance: bool = False,
                  interpret: bool = False):
    """pr: (nr, 3), pc: (nc, 3), couple: bool (nr, nc) -> (nr, nc) f32."""
    pad = lambda p: jnp.pad(p.astype(jnp.float32), ((0, 0), (0, 8 - p.shape[1])))
    return assembly_tile_fwd(pad(pr), pad(pc), couple.astype(jnp.int8),
                             quad_order=quad_order, block_r=block_r,
                             block_c=block_c, mxu_distance=mxu_distance,
                             interpret=interpret)
