from repro.kernels.assembly.ops import assembly_tile  # noqa: F401
from repro.kernels.assembly.ref import reference_tile  # noqa: F401
