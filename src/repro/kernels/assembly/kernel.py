"""MoM matrix-assembly tile Pallas TPU kernel — the Gemma application's
compute hot-spot (paper §VI-A/B), adapted to TPU.

The CPU code evaluates the singular Green's-function quadrature entry by
entry; on TPU we re-think it as a TILED computation: row/column DOF
coordinate panels stream into VMEM, the (block_r x block_c) distance tile is
built with an MXU-friendly |x-y|^2 = |x|^2 + |y|^2 - 2<x,y> expansion, and
the quadrature ladder runs vectorized over the whole tile in VREGs.  The
quadrature depth (near-singular refinement) is a compile-time parameter —
exactly the per-task cost driver the CCM cost model learns.

Grid: (row_blocks, col_blocks); coords are padded to (n, 8) lanes.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

WAVENUMBER = 3.0


def _tile_kernel(pr_ref, pc_ref, couple_ref, o_ref, *, quad_order: int,
                 mxu_distance: bool):
    pr = pr_ref[...].astype(jnp.float32)       # (block_r, 8) padded coords
    pc = pc_ref[...].astype(jnp.float32)       # (block_c, 8)
    couple = couple_ref[...]                   # (block_r, block_c) int8

    if mxu_distance:
        # |x - y|^2 via MXU: -2 x.y^T + |x|^2 + |y|^2 (pad lanes are zero).
        # Fast but suffers cancellation exactly at near-singular pairs where
        # the integrand is largest — only use when ~1e-2 relative error on
        # the singular entries is acceptable.
        cross = jax.lax.dot_general(pr, pc, (((1,), (1,)), ((), ())),
                                    preferred_element_type=jnp.float32)
        sq = (pr * pr).sum(-1, keepdims=True) + (pc * pc).sum(-1)[None, :] \
            - 2.0 * cross
    else:
        # direct difference on the VPU: exact where it matters (the
        # quadrature ladder dominates compute anyway; the (r, c, 8) diff
        # tile is ~512KB VMEM at 128x128 blocks)
        diff = pr[:, None, :] - pc[None, :, :]
        sq = (diff * diff).sum(-1)
    d = jnp.sqrt(jnp.maximum(sq, 0.0) + 1e-12)

    acc = jnp.zeros_like(d)
    for q in range(quad_order):
        r_q = (q + 0.5) / quad_order
        w_q = 1.0 / quad_order
        acc = acc + w_q * jnp.cos(WAVENUMBER * d * r_q) / (d + 0.05 * r_q + 1e-3)
    o_ref[...] = jnp.where(couple != 0, acc, 0.0).astype(o_ref.dtype)


def assembly_tile_fwd(pr, pc, couple, *, quad_order: int, block_r: int = 128,
                      block_c: int = 128, mxu_distance: bool = False,
                      interpret: bool = False):
    """pr: (nr, 8), pc: (nc, 8) zero-padded coords; couple: (nr, nc) int8."""
    nr, lanes = pr.shape
    nc = pc.shape[0]
    assert lanes == 8
    block_r = min(block_r, nr)
    block_c = min(block_c, nc)
    kernel = functools.partial(_tile_kernel, quad_order=quad_order,
                               mxu_distance=mxu_distance)
    return pl.pallas_call(
        kernel,
        grid=(pl.cdiv(nr, block_r), pl.cdiv(nc, block_c)),
        in_specs=[
            pl.BlockSpec((block_r, 8), lambda ri, ci: (ri, 0)),
            pl.BlockSpec((block_c, 8), lambda ri, ci: (ci, 0)),
            pl.BlockSpec((block_r, block_c), lambda ri, ci: (ri, ci)),
        ],
        out_specs=pl.BlockSpec((block_r, block_c), lambda ri, ci: (ri, ci)),
        out_shape=jax.ShapeDtypeStruct((nr, nc), jnp.float32),
        interpret=interpret,
    )(pr, pc, couple)
