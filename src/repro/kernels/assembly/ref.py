"""Pure-jnp oracle for the assembly tile kernel — must match
repro.assembly.execute.tile_kernel (the application path)."""
from __future__ import annotations

import jax.numpy as jnp

WAVENUMBER = 3.0


def reference_tile(pr, pc, couple, quad_order: int):
    """pr: (nr, 3|8), pc: (nc, 3|8), couple: bool (nr, nc)."""
    pr = pr[:, :3].astype(jnp.float32)
    pc = pc[:, :3].astype(jnp.float32)
    d = jnp.sqrt(((pr[:, None] - pc[None]) ** 2).sum(-1) + 1e-12)
    acc = jnp.zeros_like(d)
    for q in range(quad_order):
        r_q = (q + 0.5) / quad_order
        w_q = 1.0 / quad_order
        acc = acc + w_q * jnp.cos(WAVENUMBER * d * r_q) / (d + 0.05 * r_q + 1e-3)
    return jnp.where(couple, acc, 0.0)
