# Pallas TPU kernels for the compute hot-spots.  Each subpackage has
# kernel.py (pl.pallas_call + BlockSpec VMEM tiling), ops.py (jit'd wrapper)
# and ref.py (pure-jnp oracle).  Validated with interpret=True on CPU; the
# TPU is the TARGET (see DESIGN.md hardware-adaptation notes).
# ccm_scorer deviates deliberately: its ref.py is pure NumPy and doubles as
# the CCM evaluation engine's production backend, and the kernel is held
# BITWISE-equal to it in interpret mode (not approximately) — see
# ccm_scorer/kernel.py for the multiplication-free contract that makes
# that possible.
