# Pallas TPU kernels for the compute hot-spots.  Each subpackage has
# kernel.py (pl.pallas_call + BlockSpec VMEM tiling), ops.py (jit'd wrapper)
# and ref.py (pure-jnp oracle).  Validated with interpret=True on CPU; the
# TPU is the TARGET (see DESIGN.md hardware-adaptation notes).
