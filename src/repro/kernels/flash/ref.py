"""Pure-jnp oracle for the flash-attention kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def reference_attention(q, k, v, *, causal: bool = True, window: int = 0,
                        softcap: float = 0.0):
    """q: (BHq, Sq, hd); k, v: (BHkv, Skv, hd).  Returns (BHq, Sq, hd)."""
    bhq, sq, hd = q.shape
    bhkv, skv, _ = k.shape
    group = bhq // bhkv
    k = jnp.repeat(k, group, axis=0)
    v = jnp.repeat(v, group, axis=0)
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / jnp.sqrt(jnp.float32(hd))
    if softcap > 0.0:
        s = softcap * jnp.tanh(s / softcap)
    q_pos = jnp.arange(sq)[:, None]
    k_pos = jnp.arange(skv)[None, :]
    mask = jnp.ones((sq, skv), bool)
    if causal:
        mask &= k_pos <= q_pos
    if window > 0:
        mask &= k_pos > q_pos - window
    s = jnp.where(mask[None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    # fully-masked rows: softmax of all -1e30 is uniform; zero them like the
    # kernel does (l == 0 guard)
    any_valid = mask.any(axis=-1)[None, :, None]
    out = jnp.einsum("bqk,bkd->bqd", p, v.astype(jnp.float32))
    return jnp.where(any_valid, out, 0.0).astype(q.dtype)
