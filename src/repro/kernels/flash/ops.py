"""Jit'd wrapper: model-layout (B, S, H, hd) -> kernel layout and back."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash.kernel import flash_attention_fwd


@functools.partial(jax.jit, static_argnames=("causal", "window", "softcap",
                                             "block_q", "block_k",
                                             "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    softcap: float = 0.0, block_q: int = 128,
                    block_k: int = 128, interpret: bool = False):
    """q: (B, Sq, Hq, hd); k, v: (B, Skv, Hkv, hd) -> (B, Sq, Hq, hd).

    GQA layout contract: q heads are grouped so that head h uses kv head
    h // (Hq // Hkv) — matching repro.models.attention's reshape grouping.
    """
    b, sq, hq, hd = q.shape
    _, skv, hkv, _ = k.shape
    group = hq // hkv
    # fold to (B * Hkv * group, S, hd) with kv-major order so kernel's
    # bh // group lands on the right kv head
    qt = q.transpose(0, 2, 1, 3).reshape(b, hkv, group, sq, hd)
    qt = qt.reshape(b * hkv * group, sq, hd)
    kt = k.transpose(0, 2, 1, 3).reshape(b * hkv, skv, hd)
    vt = v.transpose(0, 2, 1, 3).reshape(b * hkv, skv, hd)
    out = flash_attention_fwd(qt, kt, vt, causal=causal, window=window,
                              softcap=softcap, block_q=block_q,
                              block_k=block_k, interpret=interpret)
    out = out.reshape(b, hkv, group, sq, hd).reshape(b, hq, sq, hd)
    return out.transpose(0, 2, 1, 3)
