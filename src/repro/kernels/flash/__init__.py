from repro.kernels.flash.ops import flash_attention  # noqa: F401
from repro.kernels.flash.ref import reference_attention  # noqa: F401
