"""Flash-attention forward Pallas TPU kernel.

Online-softmax tiling (FlashAttention [arXiv:2205.14135] re-thought for the
TPU memory hierarchy): Q/K/V tiles stream HBM -> VMEM via BlockSpecs, the
(block_q x block_k) score tile lives only in VMEM/VREGs, the MXU does the two
GEMMs, and running (m, l, acc) scratch persists across the sequential
kv-block grid dimension.  Supports causal + sliding-window masks, gemma2
logit soft-cap, and GQA (q-head groups share a kv head via the k/v index
maps).

Block sizes default to MXU/VREG-aligned (128, 128); masks are applied
in-tile.  (On real TPUs fully-masked tiles should additionally be pruned
from the grid; the dry-run path uses the XLA lowering, so tile pruning is a
documented on-hardware follow-up.)
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  block_q: int, block_k: int, sm_scale: float, causal: bool,
                  window: int, softcap: float, kv_len: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32)              # (block_q, hd)
    k = k_ref[0].astype(jnp.float32)              # (block_k, hd)
    v = v_ref[0].astype(jnp.float32)
    # zero padded K/V rows of the ragged last block (padding memory is
    # undefined; 0 * NaN would poison the PV matmul)
    row_valid = (ki * block_k + jax.lax.broadcasted_iota(
        jnp.int32, (block_k, 1), 0)) < kv_len
    k = jnp.where(row_valid, k, 0.0)
    v = jnp.where(row_valid, v, 0.0)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)
    s = s * sm_scale
    if softcap > 0.0:
        s = softcap * jnp.tanh(s / softcap)

    q_pos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32,
                                                    (block_q, block_k), 0)
    k_pos = ki * block_k + jax.lax.broadcasted_iota(jnp.int32,
                                                    (block_q, block_k), 1)
    mask = k_pos < kv_len
    if causal:
        mask &= k_pos <= q_pos
    if window > 0:
        mask &= k_pos > q_pos - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]                            # (block_q, 1)
    l_prev = l_ref[...]
    m_cur = jnp.max(s, axis=1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.where(mask, jnp.exp(s - m_new), 0.0)
    correction = jnp.exp(m_prev - m_new)           # 1 when both still -inf
    l_new = correction * l_prev + jnp.sum(p, axis=1, keepdims=True)
    acc = acc_ref[...] * correction + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    m_ref[...] = m_new
    l_ref[...] = l_new
    acc_ref[...] = acc

    @pl.when(ki == nk - 1)
    def _finish():
        l = l_ref[...]
        o_ref[0] = (acc_ref[...] / jnp.where(l == 0.0, 1.0, l)).astype(
            o_ref.dtype)


def flash_attention_fwd(q, k, v, *, causal: bool = True, window: int = 0,
                        softcap: float = 0.0, block_q: int = 128,
                        block_k: int = 128, interpret: bool = False):
    """q: (BHq, Sq, hd); k, v: (BHkv, Skv, hd) with BHq = BHkv * group.

    Heads are folded into the leading grid dim; the k/v index maps divide by
    the GQA group so q-head groups share their kv head's tiles.
    """
    bhq, sq, hd = q.shape
    bhkv, skv, _ = k.shape
    assert bhq % bhkv == 0
    group = bhq // bhkv
    block_q = min(block_q, sq)
    block_k = min(block_k, skv)
    nq = pl.cdiv(sq, block_q)
    nk = pl.cdiv(skv, block_k)
    sm_scale = 1.0 / (hd ** 0.5)

    kernel = functools.partial(
        _flash_kernel, block_q=block_q, block_k=block_k, sm_scale=sm_scale,
        causal=causal, window=window, softcap=softcap, kv_len=skv)

    return pl.pallas_call(
        kernel,
        grid=(bhq, nq, nk),
        in_specs=[
            pl.BlockSpec((1, block_q, hd), lambda bh, qi, ki: (bh, qi, 0)),
            pl.BlockSpec((1, block_k, hd),
                         lambda bh, qi, ki: (bh // group, ki, 0)),
            pl.BlockSpec((1, block_k, hd),
                         lambda bh, qi, ki: (bh // group, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, hd),
                               lambda bh, qi, ki: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((bhq, sq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, hd), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
