"""Jit'd wrapper for the RG-LRU kernel."""
from __future__ import annotations

import functools

import jax

from repro.kernels.rglru.kernel import rglru_fwd


@functools.partial(jax.jit, static_argnames=("chunk", "block_w", "interpret"))
def rglru_scan_op(log_a, b, *, chunk: int = 64, block_w: int = 256,
                  interpret: bool = False):
    return rglru_fwd(log_a, b, chunk=chunk, block_w=block_w,
                     interpret=interpret)
