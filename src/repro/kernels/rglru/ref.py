"""Pure-jnp oracle: sequential RG-LRU recurrence."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def reference_rglru(log_a, b):
    """log_a, b: (B, S, W) -> h with h_t = exp(log_a_t) h_{t-1} + b_t."""
    a = jnp.exp(log_a.astype(jnp.float32))
    bf = b.astype(jnp.float32)

    def step(h, xs):
        at, bt = xs
        h = at * h + bt
        return h, h

    h0 = jnp.zeros((a.shape[0], a.shape[2]), jnp.float32)
    xs = (jnp.moveaxis(a, 1, 0), jnp.moveaxis(bf, 1, 0))
    _, hs = jax.lax.scan(step, h0, xs)
    return jnp.moveaxis(hs, 0, 1).astype(b.dtype)
