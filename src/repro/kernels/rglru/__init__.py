from repro.kernels.rglru.ops import rglru_scan_op  # noqa: F401
from repro.kernels.rglru.ref import reference_rglru  # noqa: F401
