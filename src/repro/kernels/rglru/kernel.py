"""RG-LRU gated diagonal linear recurrence Pallas TPU kernel (Griffin,
arXiv:2402.19427).

h_t = a_t * h_{t-1} + b_t, elementwise over the rnn width.  Chunked
state-passing: grid = (B, width_blocks, n_chunks) with the running h carried
in VMEM scratch across the sequential chunk dimension.  Within a chunk the
recurrence is evaluated in closed form with stable exp(non-positive) decay
ratios (a_t in (0,1)):

    h_t = exp(cum_t) * h_in + sum_{s<=t} exp(cum_t - cum_s) * b_s

where cum = cumsum(log a).  The (chunk x chunk) ratio matrix stays in VMEM;
the contraction against b runs on the MXU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _rglru_kernel(la_ref, b_ref, o_ref, h_ref, *, chunk: int,
                  block_w: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    la = la_ref[0].astype(jnp.float32)     # (chunk, block_w) log a_t <= 0
    b = b_ref[0].astype(jnp.float32)       # (chunk, block_w)
    h_in = h_ref[...]                      # (1, block_w)

    cum = jnp.cumsum(la, axis=0)           # inclusive
    # ratio[t, s] decay from s to t (s <= t): exp(cum_t - cum_s)
    # handled per width element — to keep VMEM bounded we contract width-wise
    # via a masked per-element accumulation using a scan-free closed form:
    # h_t = exp(cum_t) * (h_in + sum_{s<=t} exp(-cum_s) b_s) is UNSTABLE
    # (exp(-cum_s) overflows); instead accumulate per sub-tile with the
    # pairwise ratio tensor, chunk kept small enough for VMEM.
    ratio = cum[:, None, :] - cum[None, :, :]          # (t, s, w)
    tri = (jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0) >=
           jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1))
    decay = jnp.exp(jnp.minimum(ratio, 0.0)) * tri[:, :, None].astype(
        jnp.float32)
    h_intra = (decay * b[None, :, :]).sum(axis=1)      # (chunk, w)
    h = h_intra + jnp.exp(cum) * h_in
    o_ref[0] = h.astype(o_ref.dtype)
    h_ref[...] = h[-1:].astype(h_ref.dtype)


def rglru_fwd(log_a, b, *, chunk: int = 64, block_w: int = 256,
              interpret: bool = False):
    """log_a, b: (B, S, W) -> h: (B, S, W).  h_0 = 0."""
    bsz, s, w = log_a.shape
    chunk = min(chunk, s)
    block_w = min(block_w, w)
    assert s % chunk == 0 and w % block_w == 0, (s, chunk, w, block_w)
    nc = s // chunk
    nw = w // block_w
    kernel = functools.partial(_rglru_kernel, chunk=chunk, block_w=block_w)
    return pl.pallas_call(
        kernel,
        grid=(bsz, nw, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, block_w), lambda bi, wi, ci: (bi, ci, wi)),
            pl.BlockSpec((1, chunk, block_w), lambda bi, wi, ci: (bi, ci, wi)),
        ],
        out_specs=pl.BlockSpec((1, chunk, block_w),
                               lambda bi, wi, ci: (bi, ci, wi)),
        out_shape=jax.ShapeDtypeStruct((bsz, s, w), b.dtype),
        scratch_shapes=[pltpu.VMEM((1, block_w), jnp.float32)],
        interpret=interpret,
    )(log_a, b)
