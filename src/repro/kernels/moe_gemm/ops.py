"""Jit'd wrapper for the expert GEMM kernel."""
from __future__ import annotations

import functools

import jax

from repro.kernels.moe_gemm.kernel import expert_gemm_fwd


@functools.partial(jax.jit, static_argnames=("block_c", "block_f", "block_k",
                                             "interpret"))
def expert_gemm(x, w, *, block_c: int = 128, block_f: int = 128,
                block_k: int = 256, interpret: bool = False):
    return expert_gemm_fwd(x, w, block_c=block_c, block_f=block_f,
                           block_k=block_k, interpret=interpret)
