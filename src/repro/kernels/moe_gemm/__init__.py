from repro.kernels.moe_gemm.ops import expert_gemm  # noqa: F401
from repro.kernels.moe_gemm.ref import reference_expert_gemm  # noqa: F401
