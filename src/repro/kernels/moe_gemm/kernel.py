"""Capacity-batched expert GEMM Pallas TPU kernel.

The MoE layer (repro.models.moe) gathers each expert's top-capacity tokens
into a dense (E_local, C, d) buffer; this kernel runs the per-expert GEMM
(E, C, d) x (E, d, f) -> (E, C, f) with the K (d) dimension tiled and
accumulated in VMEM scratch — a grouped matmul whose expert dim rides the
grid, MegaBlocks-style but with static capacity (the TPU-friendly variant:
no dynamic group offsets, dropped tokens are zero rows).

Grid: (E, C_blocks, F_blocks, K_blocks); K minor => sequential accumulation.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _gemm_kernel(x_ref, w_ref, o_ref, acc_ref):
    ki = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[0]                       # (block_c, block_k)
    w = w_ref[0]                       # (block_k, block_f)
    acc_ref[...] += jax.lax.dot_general(
        x, w, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(ki == nk - 1)
    def _finish():
        o_ref[0] = acc_ref[...].astype(o_ref.dtype)


def expert_gemm_fwd(x, w, *, block_c: int = 128, block_f: int = 128,
                    block_k: int = 256, interpret: bool = False):
    """x: (E, C, d), w: (E, d, f) -> (E, C, f)."""
    e, c, d = x.shape
    _, _, f = w.shape
    block_c = min(block_c, c)
    block_f = min(block_f, f)
    block_k = min(block_k, d)
    assert d % block_k == 0, (d, block_k)
    return pl.pallas_call(
        _gemm_kernel,
        grid=(e, pl.cdiv(c, block_c), pl.cdiv(f, block_f),
              d // block_k),
        in_specs=[
            pl.BlockSpec((1, block_c, block_k),
                         lambda ei, ci, fi, ki: (ei, ci, ki)),
            pl.BlockSpec((1, block_k, block_f),
                         lambda ei, ci, fi, ki: (ei, ki, fi)),
        ],
        out_specs=pl.BlockSpec((1, block_c, block_f),
                               lambda ei, ci, fi, ki: (ei, ci, fi)),
        out_shape=jax.ShapeDtypeStruct((e, c, f), x.dtype),
        scratch_shapes=[pltpu.VMEM((block_c, block_f), jnp.float32)],
        interpret=interpret,
    )(x, w)
