"""Pure-jnp oracle for the capacity-batched expert GEMM."""
from __future__ import annotations

import jax.numpy as jnp


def reference_expert_gemm(x, w):
    """x: (E, C, d), w: (E, d, f) -> (E, C, f)."""
    return jnp.einsum("ecd,edf->ecf", x.astype(jnp.float32),
                      w.astype(jnp.float32)).astype(x.dtype)
