"""Jit'd wrapper for the WKV6 kernel: (B, S, H, hd) model layout."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.rwkv6.kernel import wkv6_fwd


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def wkv6(r, k, v, log_w, u, *, chunk: int = 64, interpret: bool = False):
    """r,k,v,log_w: (B, S, H, hd); u: (H, hd) -> (B, S, H, hd)."""
    b, s, h, hd = r.shape
    fold = lambda x: x.transpose(0, 2, 1, 3).reshape(b * h, s, hd)
    u_full = jnp.tile(u[None], (b, 1, 1)).reshape(b * h, hd)
    out = wkv6_fwd(fold(r), fold(k), fold(v), fold(log_w), u_full,
                   chunk=chunk, interpret=interpret)
    return out.reshape(b, h, s, hd).transpose(0, 2, 1, 3)
