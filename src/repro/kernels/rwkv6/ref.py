"""Pure-jnp sequential-recurrence oracle for WKV6."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def reference_wkv6(r, k, v, log_w, u):
    """r,k,v,log_w: (BH, S, hd); u: (BH, hd).  Exact sequential recurrence:

        y_t = S_{t-1}^T r_t + (sum_i r_i u_i k_i) v_t
        S_t = diag(w_t) S_{t-1} + k_t v_t^T
    """
    bh, s, hd = r.shape
    rf, kf, vf = (x.astype(jnp.float32) for x in (r, k, v))
    wf = jnp.exp(log_w.astype(jnp.float32))
    uf = u.astype(jnp.float32)

    def step(state, xs):
        rt, kt, vt, wt = xs
        y = jnp.einsum("bi,bij->bj", rt, state) + (
            (rt * uf * kt).sum(-1, keepdims=True) * vt)
        new_state = state * wt[..., None] + kt[..., :, None] * vt[..., None, :]
        return new_state, y

    state0 = jnp.zeros((bh, hd, hd), jnp.float32)
    xs = tuple(jnp.moveaxis(x, 1, 0) for x in (rf, kf, vf, wf))
    _, ys = jax.lax.scan(step, state0, xs)
    return jnp.moveaxis(ys, 0, 1).astype(r.dtype)
