from repro.kernels.rwkv6.ops import wkv6  # noqa: F401
from repro.kernels.rwkv6.ref import reference_wkv6  # noqa: F401
