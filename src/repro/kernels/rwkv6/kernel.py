"""WKV6 chunked-recurrence Pallas TPU kernel.

State-passing chunked linear attention with per-channel data-dependent decay
(RWKV6 "Finch", arXiv:2404.05892), adapted to TPU: the grid's minor
dimension walks chunks SEQUENTIALLY (TPU grids are sequential per core), so
the (hd x hd) state lives in VMEM scratch across chunk steps while r/k/v/w
tiles stream in via BlockSpecs.  All decay factors appear as
exp(non-positive) ratios — stable in f32 without log-space matmuls.

Grid: (B*H, n_chunks); blocks: (chunk, hd).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _wkv6_kernel(r_ref, k_ref, v_ref, lw_ref, u_ref, o_ref, state_ref, *,
                 chunk: int):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    r = r_ref[0].astype(jnp.float32)      # (chunk, hd)
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)
    lw = lw_ref[0].astype(jnp.float32)    # log decay, < 0
    u = u_ref[0].astype(jnp.float32)      # (1, hd) bonus

    cs = jnp.cumsum(lw, axis=0)           # inclusive
    cse = cs - lw                         # exclusive
    state = state_ref[...]                # (hd, hd)

    # inter-chunk: y1[t] = (r_t * exp(cse_t)) @ state
    q1 = r * jnp.exp(cse)
    y1 = jax.lax.dot_general(q1, state, (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)

    # intra-chunk: scores[t,s] = sum_i r_t[i] k_s[i] exp(cse_t - cs_s), s<t
    ratio = cse[:, None, :] - cs[None, :, :]          # (t, s, hd)
    pair = r[:, None, :] * k[None, :, :] * jnp.exp(jnp.minimum(ratio, 0.0))
    tri = (jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0) >
           jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1))
    scores = pair.sum(-1) * tri.astype(jnp.float32)
    y2 = jax.lax.dot_general(scores, v, (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)

    # diagonal bonus u
    diag = (r * u * k).sum(-1, keepdims=True) * v

    o_ref[0] = (y1 + y2 + diag).astype(o_ref.dtype)

    # state update: S' = diag(exp(cs_last)) S + sum_s exp(cs_last - cs_s) k_s v_s^T
    decay_to_end = jnp.exp(cs[-1:] - cs)              # (chunk, hd)
    kd = k * decay_to_end
    state_ref[...] = state * jnp.exp(cs[-1])[:, None] + jax.lax.dot_general(
        kd, v, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32)


def wkv6_fwd(r, k, v, log_w, u, *, chunk: int = 64, interpret: bool = False):
    """r,k,v,log_w: (BH, S, hd); u: (BH_heads? -> (BH, hd)).  Returns (BH,S,hd).

    ``u`` must already be broadcast to (BH, hd) (ops.py handles head tiling).
    """
    bh, s, hd = r.shape
    chunk = min(chunk, s)
    assert s % chunk == 0, (s, chunk)
    nc = s // chunk
    kernel = functools.partial(_wkv6_kernel, chunk=chunk)
    return pl.pallas_call(
        kernel,
        grid=(bh, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, hd), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, hd), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, hd), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, hd), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, 1, hd), lambda b, c: (b, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, chunk, hd), lambda b, c: (b, c, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, s, hd), r.dtype),
        scratch_shapes=[pltpu.VMEM((hd, hd), jnp.float32)],
        interpret=interpret,
    )(r, k, v, log_w, u.reshape(bh, 1, hd))
