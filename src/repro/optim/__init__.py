from repro.optim.adamw import adamw_init, adamw_update, global_norm  # noqa: F401
from repro.optim.schedule import warmup_cosine  # noqa: F401
