"""AdamW in pure JAX, used by both LM training and the paper's cost-model FNN
(§VI-D cites AdamW [36] for better generalization/convergence).

Moments are f32 and mirror the parameter tree (and therefore its sharding —
ZeRO-1 falls out of the params being FSDP+TP sharded already).
"""
from __future__ import annotations

from typing import Any, Dict, NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jnp.ndarray
    m: Any
    v: Any


def adamw_init(params) -> AdamWState:
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return AdamWState(step=jnp.zeros((), jnp.int32), m=zeros,
                      v=jax.tree.map(jnp.copy, zeros))


def global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def adamw_update(grads, state: AdamWState, params, lr, *, b1=0.9, b2=0.95,
                 eps=1e-8, weight_decay=0.0, clip_norm=1.0):
    """Returns (new_params, new_state).  ``lr`` may be a scalar or schedule(step)."""
    step = state.step + 1
    if callable(lr):
        lr = lr(step)
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, clip_norm / jnp.maximum(gnorm, 1e-9)) \
        if clip_norm else 1.0

    def upd(g, m, v, p):
        g = g.astype(jnp.float32) * scale
        m2 = b1 * m + (1 - b1) * g
        v2 = b2 * v + (1 - b2) * jnp.square(g)
        mhat = m2 / (1 - b1 ** step.astype(jnp.float32))
        vhat = v2 / (1 - b2 ** step.astype(jnp.float32))
        delta = mhat / (jnp.sqrt(vhat) + eps)
        if weight_decay:
            delta = delta + weight_decay * p.astype(jnp.float32)
        p2 = p.astype(jnp.float32) - lr * delta
        return p2.astype(p.dtype), m2, v2

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state.m)
    flat_v = treedef.flatten_up_to(state.v)
    out = [upd(g, m, v, p) for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_p, AdamWState(step=step, m=new_m, v=new_v)
