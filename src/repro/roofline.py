"""Roofline-term extraction from compiled XLA artifacts.

Three terms per (arch x shape x mesh), in seconds (TPU v5e constants):

  compute    = HLO_FLOPs_per_device / peak_FLOPs            (197 TFLOP/s bf16)
  memory     = HLO_bytes_per_device / HBM_bw                (819 GB/s)
  collective = collective_operand_bytes_per_device / link_bw (~50 GB/s/link)

``cost_analysis()`` provides per-device FLOPs / bytes-accessed for the
SPMD-partitioned module.  Collective bytes are NOT in cost_analysis: we parse
the post-optimization HLO (``compiled.as_text()``), resolve each collective's
operand shapes, and sum their sizes.
"""
from __future__ import annotations

import re
from typing import Dict, Optional

PEAK_FLOPS = 197e12          # bf16 per chip
HBM_BW = 819e9               # bytes/s per chip
LINK_BW = 50e9               # bytes/s per ICI link (~)

COLLECTIVE_OPS = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute",
)

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "s32": 4, "u32": 4,
    "s64": 8, "u64": 8, "f8e4m3fn": 1, "f8e5m2": 1, "bf16": 2, "f16": 2,
    "f32": 4, "f64": 8, "c64": 8, "c128": 16, "s4": 1, "u4": 1,
}

# "%name = bf16[1,2,3]{...} opcode(" — defining instruction
_DEF_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*\(?([a-z0-9]+)\[([\d,]*)\]")
# typed operand inside an op call: "bf16[8,128]{1,0} %name"
_TYPED_OPERAND_RE = re.compile(r"([a-z0-9]+)\[([\d,]*)\][^\s]*\s+%?([\w.\-]+)")


def _nbytes(dtype: str, dims: str) -> Optional[int]:
    if dtype not in _DTYPE_BYTES:
        return None
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


def collective_bytes_from_hlo(hlo_text: str) -> Dict[str, int]:
    """Sum operand bytes per collective kind from post-SPMD HLO text."""
    shapes: Dict[str, int] = {}
    for line in hlo_text.splitlines():
        m = _DEF_RE.match(line)
        if m:
            name, dtype, dims = m.groups()
            nb = _nbytes(dtype, dims)
            if nb is not None:
                shapes[name] = nb
    totals = {k: 0 for k in COLLECTIVE_OPS}
    counts = {k: 0 for k in COLLECTIVE_OPS}
    for line in hlo_text.splitlines():
        for op in COLLECTIVE_OPS:
            # match "= <type> op(" or "= (<tuple>) op(" — avoid -start/-done
            if f" {op}(" not in line:
                continue
            if f"{op}-start" in line or f"{op}-done" in line:
                # async start carries the operands; -done carries none
                if f"{op}-done" in line:
                    continue
            # operand section is inside the op's parens
            call = line.split(f" {op}(", 1)[1]
            depth, end = 1, 0
            for i, ch in enumerate(call):
                if ch == "(":
                    depth += 1
                elif ch == ")":
                    depth -= 1
                    if depth == 0:
                        end = i
                        break
            args = call[:end]
            got = 0
            for am in _TYPED_OPERAND_RE.finditer(args):
                dtype, dims, _ = am.groups()
                nb = _nbytes(dtype, dims)
                if nb is not None:
                    got += nb
            if got == 0:
                # untyped operand list: resolve via defining instructions
                for name in re.findall(r"%?([\w.\-]+)", args):
                    got += shapes.get(name, 0)
            totals[op] += got
            counts[op] += 1
            break
    totals["_counts"] = counts
    return totals


def analyze_compiled(compiled) -> dict:
    """FLOPs/bytes from cost_analysis + collective bytes from HLO text."""
    stats: dict = {}
    try:
        ca = compiled.cost_analysis()
        if isinstance(ca, (list, tuple)):
            ca = ca[0]
        stats["flops"] = float(ca.get("flops", 0.0))
        stats["bytes_accessed"] = float(ca.get("bytes accessed", 0.0))
        stats["transcendentals"] = float(ca.get("transcendentals", 0.0))
    except Exception as e:  # pragma: no cover
        stats["cost_analysis_error"] = str(e)
        stats["flops"] = 0.0
        stats["bytes_accessed"] = 0.0
    text = compiled.as_text()
    coll = collective_bytes_from_hlo(text)
    counts = coll.pop("_counts")
    stats["collective_bytes"] = coll
    stats["collective_counts"] = counts
    stats["collective_bytes_total"] = int(sum(coll.values()))
    stats["hlo_lines"] = text.count("\n")
    return stats


def model_flops(cfg, shape) -> float:
    """MODEL_FLOPS = 6*N*D (dense) / 6*N_active*D (MoE); decode: D=new tokens."""
    n = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        if cfg.arch_type == "encdec":
            # encoder fwd+bwd over frames (no 2x lm head) + decoder over labels
            from repro.models.encdec import decoder_len
            tokens = shape.global_batch * (shape.seq_len + decoder_len(cfg, shape.seq_len))
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens
    # decode: one token per sequence
    return 2.0 * n * shape.global_batch


def roofline_terms(stats: dict, cfg, shape, n_chips: int) -> dict:
    flops = stats.get("flops", 0.0)
    byts = stats.get("bytes_accessed", 0.0)
    coll = stats.get("collective_bytes_total", 0)
    t_comp = flops / PEAK_FLOPS
    t_mem = byts / HBM_BW
    t_coll = coll / LINK_BW
    dominant = max((("compute", t_comp), ("memory", t_mem),
                    ("collective", t_coll)), key=lambda kv: kv[1])[0]
    mf = model_flops(cfg, shape)
    hlo_total = flops * n_chips
    return {
        "compute_s": t_comp,
        "memory_s": t_mem,
        "collective_s": t_coll,
        "dominant": dominant,
        "model_flops": mf,
        "hlo_flops_total": hlo_total,
        "useful_flops_ratio": (mf / hlo_total) if hlo_total else 0.0,
        "bound_step_s": max(t_comp, t_mem, t_coll),
        "roofline_fraction": (mf / n_chips / PEAK_FLOPS) /
                             max(t_comp, t_mem, t_coll, 1e-30),
    }
