"""Production meshes.

``make_production_mesh`` is a FUNCTION (not a module constant) so importing
this module never touches jax device state; the 512-device host-platform
override lives only in launch/dryrun.py.
"""
from __future__ import annotations

import jax
from jax.sharding import Mesh

from repro.sharding import MeshAxes


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh(data: int = 1, model: int = 1) -> Mesh:
    """Small mesh over available devices (smoke tests / CPU training)."""
    n = len(jax.devices())
    assert data * model <= n, (data, model, n)
    return jax.make_mesh((data, model), ("data", "model"),
                         devices=jax.devices()[: data * model])


def axes_for(mesh: Mesh) -> MeshAxes:
    return MeshAxes.for_mesh(mesh)
