"""Step builders: abstract (ShapeDtypeStruct) params/optimizer/batch trees with
matching NamedShardings, and the jitted train/prefill/decode steps used by the
trainer, the server, and the multi-pod dry-run.
"""
from __future__ import annotations

import functools
from typing import Any, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models.layers import split_lp_tree
from repro.models.model import (Model, batch_specs, build_model, cache_specs,
                                decode_token_specs)
from repro.optim import adamw_init, adamw_update, warmup_cosine
from repro.sharding import MeshAxes, shardings_for_lp_tree


def named(mesh: Mesh, spec_tree):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P))


def abstract_params(model: Model):
    """(params SDS tree, NamedSharding tree) without allocating anything."""
    lp_tree = jax.eval_shape(model.init, jax.random.key(0))
    params_sds, _ = split_lp_tree(lp_tree)
    shardings = shardings_for_lp_tree(model.mesh, model.axes, lp_tree)
    return params_sds, shardings


def abstract_opt(params_sds, param_shardings):
    """AdamW state SDS + shardings mirroring the params (ZeRO-1)."""
    f32 = lambda sds: jax.ShapeDtypeStruct(sds.shape, jnp.float32)
    m = jax.tree.map(f32, params_sds)
    from repro.optim.adamw import AdamWState
    state = AdamWState(step=jax.ShapeDtypeStruct((), jnp.int32), m=m,
                       v=jax.tree.map(f32, params_sds))
    mesh = jax.tree.leaves(param_shardings)[0].mesh
    shardings = AdamWState(step=NamedSharding(mesh, P()),
                           m=param_shardings, v=param_shardings)
    return state, shardings


def make_train_step(model: Model, *, lr=3e-4, weight_decay=0.1,
                    warmup_steps=100, total_steps=10000):
    schedule = warmup_cosine(lr, warmup_steps, total_steps)

    def train_step(params, opt_state, batch):
        (loss, metrics), grads = jax.value_and_grad(
            model.loss_fn, has_aux=True)(params, batch)
        new_params, new_opt = adamw_update(
            grads, opt_state, params, schedule, weight_decay=weight_decay)
        metrics = dict(metrics)
        metrics["loss"] = loss
        return new_params, new_opt, metrics

    return train_step


def make_prefill_step(model: Model):
    def prefill_step(params, batch):
        return model.prefill_fn(params, batch)
    return prefill_step


def make_decode_step(model: Model):
    def decode_step(params, cache, token, pos):
        return model.decode_fn(params, cache, token, pos)
    return decode_step


# ------------------------------------------------------------------ lowering
def lower_train(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh):
    model = build_model(cfg, mesh)
    params_sds, p_sh = abstract_params(model)
    opt_sds, o_sh = abstract_opt(params_sds, p_sh)
    batch_sds, b_specs = batch_specs(cfg, shape, mesh, model.axes, "train")
    b_sh = named(mesh, b_specs)
    step = make_train_step(model)
    jitted = jax.jit(step, in_shardings=(p_sh, o_sh, b_sh),
                     donate_argnums=(0, 1))
    return jitted.lower(params_sds, opt_sds, batch_sds)


def lower_prefill(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh):
    model = build_model(cfg, mesh)
    params_sds, p_sh = abstract_params(model)
    batch_sds, b_specs = batch_specs(cfg, shape, mesh, model.axes, "prefill")
    jitted = jax.jit(make_prefill_step(model),
                     in_shardings=(p_sh, named(mesh, b_specs)))
    return jitted.lower(params_sds, batch_sds)


def lower_decode(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh):
    model = build_model(cfg, mesh)
    params_sds, p_sh = abstract_params(model)
    cache_sds, c_specs = cache_specs(cfg, shape, mesh, model.axes)
    tok_sds, tok_spec, pos_sds, pos_spec = decode_token_specs(
        cfg, shape, mesh, model.axes)
    jitted = jax.jit(
        make_decode_step(model),
        in_shardings=(p_sh, named(mesh, c_specs),
                      NamedSharding(mesh, tok_spec),
                      NamedSharding(mesh, pos_spec)),
        donate_argnums=(1,))
    return jitted.lower(params_sds, cache_sds, tok_sds, pos_sds)


def lower_cell(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh):
    if shape.kind == "train":
        return lower_train(cfg, shape, mesh)
    if shape.kind == "prefill":
        return lower_prefill(cfg, shape, mesh)
    return lower_decode(cfg, shape, mesh)
