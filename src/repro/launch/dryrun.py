import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape) cell on the
production meshes and extract roofline terms from the compiled artifact.

The two lines above MUST stay first: jax locks the device count on first
init, and only the dry-run wants 512 placeholder devices.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch llama3.2-3b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both
Results accumulate incrementally in benchmarks/results/dryrun.json.
"""
import argparse  # noqa: E402
import dataclasses  # noqa: E402
import json  # noqa: E402
import re  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402
from pathlib import Path  # noqa: E402

import jax  # noqa: E402

from repro import configs  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.launch.steps import lower_cell  # noqa: E402
from repro.roofline import analyze_compiled, roofline_terms  # noqa: E402

RESULTS = Path(__file__).resolve().parents[3] / "benchmarks" / "results"


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             unroll: bool = False, overrides: dict = None,
             variant: str = "") -> dict:
    cfg = configs.get_config(arch)
    if unroll:
        # exact flop/byte/collective accounting: XLA cost analysis counts a
        # while-loop body once, so the roofline pass unrolls the layer stack.
        cfg = dataclasses.replace(cfg, unroll_stack=True)
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    shape = configs.get_shape(shape_name)
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.devices.size
    t0 = time.time()
    lowered = lower_cell(cfg, shape, mesh)
    t1 = time.time()
    compiled = lowered.compile()
    t2 = time.time()
    stats = analyze_compiled(compiled)
    try:
        mem = compiled.memory_analysis()
        stats["memory"] = {
            "argument_bytes": int(getattr(mem, "argument_size_in_bytes", 0)),
            "output_bytes": int(getattr(mem, "output_size_in_bytes", 0)),
            "temp_bytes": int(getattr(mem, "temp_size_in_bytes", 0)),
            "generated_code_bytes": int(
                getattr(mem, "generated_code_size_in_bytes", 0)),
        }
    except Exception as e:  # pragma: no cover - backend dependent
        stats["memory"] = {"error": str(e)}
    terms = roofline_terms(stats, cfg, shape, n_chips)
    mesh_label = ("2x16x16" if multi_pod else "16x16") \
        + ("-unrolled" if unroll else "") \
        + (f"-{variant}" if variant else "")
    rec = {
        "arch": arch,
        "shape": shape_name,
        "mesh": mesh_label,
        "kind": shape.kind,
        "overrides": overrides or {},
        "lower_s": round(t1 - t0, 2),
        "compile_s": round(t2 - t1, 2),
        "stats": stats,
        "roofline": terms,
        "ok": True,
    }
    return rec


def save(record: dict, out: Path):
    out.parent.mkdir(parents=True, exist_ok=True)
    existing = {}
    if out.exists():
        existing = json.loads(out.read_text())
    key = f"{record['arch']}|{record['shape']}|{record['mesh']}"
    existing[key] = record
    out.write_text(json.dumps(existing, indent=1))


def already_done(arch, shape_name, mesh_name, out: Path) -> bool:
    if not out.exists():
        return False
    data = json.loads(out.read_text())
    rec = data.get(f"{arch}|{shape_name}|{mesh_name}")
    return bool(rec and rec.get("ok"))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="single")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--unroll", action="store_true",
                    help="unroll layer stacks for exact cost accounting "
                         "(roofline pass)")
    ap.add_argument("--variant", default="",
                    help="label for a §Perf variant (stored in the key)")
    ap.add_argument("--ce-chunk", type=int, default=None)
    ap.add_argument("--attn-chunk", type=int, default=None)
    ap.add_argument("--remat-policy", default=None)
    ap.add_argument("--window-cache", action="store_true", default=None)
    ap.add_argument("--capacity-factor", type=float, default=None)
    ap.add_argument("--no-shard-rnn", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--out", default=str(RESULTS / "dryrun.json"))
    args = ap.parse_args()
    out = Path(args.out)

    if args.all:
        cells = list(configs.cells())
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells = [(args.arch, args.shape)]

    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]
    failures = 0
    overrides = {}
    if args.ce_chunk is not None:
        overrides["ce_chunk"] = args.ce_chunk
    if args.attn_chunk is not None:
        overrides["attn_kv_chunk"] = args.attn_chunk
    if args.remat_policy is not None:
        overrides["remat_policy"] = args.remat_policy
    if args.window_cache:
        overrides["window_kv_cache"] = True
    if args.capacity_factor is not None:
        overrides["capacity_factor"] = args.capacity_factor
    if args.no_shard_rnn:
        overrides["shard_rnn"] = False

    for arch, shape_name in cells:
        for multi_pod in meshes:
            mesh_name = ("2x16x16" if multi_pod else "16x16") + \
                ("-unrolled" if args.unroll else "") + \
                (f"-{args.variant}" if args.variant else "")
            if not args.force and already_done(arch, shape_name, mesh_name, out):
                print(f"[skip] {arch} {shape_name} {mesh_name} (cached)")
                continue
            label = f"{arch} {shape_name} {mesh_name}"
            print(f"[run ] {label}", flush=True)
            try:
                rec = run_cell(arch, shape_name, multi_pod,
                               unroll=args.unroll, overrides=overrides,
                               variant=args.variant)
                save(rec, out)
                r = rec["roofline"]
                print(f"[ ok ] {label}: compile={rec['compile_s']}s "
                      f"dominant={r['dominant']} "
                      f"t_comp={r['compute_s']:.2e}s t_mem={r['memory_s']:.2e}s "
                      f"t_coll={r['collective_s']:.2e}s", flush=True)
            except Exception:
                failures += 1
                err = traceback.format_exc()
                save({"arch": arch, "shape": shape_name, "mesh": mesh_name,
                      "ok": False, "error": err[-4000:]}, out)
                print(f"[FAIL] {label}\n{err[-2000:]}", flush=True)
    print(f"done; failures={failures}")
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()
