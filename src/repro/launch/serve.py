"""Serving launcher: batched prefill + greedy decode with per-request
lengths (continuous-batching-lite: finished rows are masked, new requests
can be swapped in at the prefill boundary).

  PYTHONPATH=src python -m repro.launch.serve --arch tinyllama-1.1b --smoke \
      --batch 4 --prompt-len 32 --max-new 32
"""
from __future__ import annotations

import argparse
import time
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.launch.mesh import make_local_mesh
from repro.models.layers import split_lp_tree
from repro.models.model import build_model


# self-attention caches grow to prompt+new; cross-attention (ck/cv) stays
# at encoder length
_KV_KEYS = {"k", "v", "sk", "sv"}


def pad_caches(caches, target_len: int):
    """Pad attention K/V caches along the sequence axis to ``target_len``.

    Only leaves whose dict key names a K/V cache are touched — recurrent
    state (wkv/h/conv/shift) has no sequence axis."""
    def pad(path, leaf):
        key = path[-1].key if hasattr(path[-1], "key") else None
        if key in _KV_KEYS and leaf.shape[-3] < target_len:
            pad_width = [(0, 0)] * leaf.ndim
            pad_width[-3] = (0, target_len - leaf.shape[-3])
            return jnp.pad(leaf, pad_width)
        return leaf
    return jax.tree_util.tree_map_with_path(pad, caches)


def serve_batch(model, params, prompts: np.ndarray, max_new: int,
                media: Dict = None) -> np.ndarray:
    """prompts: (B, P) int32 -> (B, max_new) greedy continuations."""
    cfg = model.cfg
    b, p_len = prompts.shape
    batch = {"tokens": jnp.asarray(prompts)}
    if media:
        batch.update(media)
    caches, logits = jax.jit(model.prefill_fn)(params, batch)
    total = p_len + max_new
    if cfg.frontend == "vision":
        total += cfg.num_media_positions
        p_len += cfg.num_media_positions
    caches = pad_caches(caches, total)
    decode = jax.jit(model.decode_fn, donate_argnums=(1,))
    tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
    out: List[np.ndarray] = []
    for i in range(max_new):
        out.append(np.asarray(tok[:, 0]))
        caches, logits = decode(params, caches, tok, jnp.int32(p_len + i))
        tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
    return np.stack(out, axis=1)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=32)
    args = ap.parse_args()

    cfg = (configs.get_smoke_config(args.arch) if args.smoke
           else configs.get_config(args.arch))
    mesh = make_local_mesh(1, 1)
    model = build_model(cfg, mesh)
    params, _ = split_lp_tree(model.init(jax.random.key(0)))

    rng = np.random.default_rng(0)
    prompts = rng.integers(0, cfg.vocab_size,
                           (args.batch, args.prompt_len)).astype(np.int32)
    media = None
    if cfg.frontend == "vision":
        media = {"media_embed": jnp.asarray(rng.standard_normal(
            (args.batch, cfg.num_media_positions, cfg.d_model)) * 0.1,
            jnp.bfloat16)}
    t0 = time.time()
    tokens = serve_batch(model, params, prompts, args.max_new, media)
    dt = time.time() - t0
    print(f"[serve] {args.batch} requests x {args.max_new} new tokens "
          f"in {dt:.2f}s ({args.batch * args.max_new / dt:.1f} tok/s)")
    print(tokens[:, :16])


if __name__ == "__main__":
    main()
