"""Training launcher.

Runs any --arch (smoke configs on CPU; full configs are for the production
meshes) with: checkpoint/restart fault tolerance, straggler EWMA feeding CCM
speed factors, and — for MoE archs — periodic CCM-LB expert re-placement
applied as function-preserving slot permutations.

  PYTHONPATH=src python -m repro.launch.train --arch qwen3-moe-30b-a3b \
      --smoke --steps 50 --rebalance-every 20
"""
from __future__ import annotations

import argparse
import time
from pathlib import Path
from typing import Optional

import jax
import numpy as np

from repro import configs
from repro.balance.expert_placement import (apply_expert_permutation,
                                            plan_expert_placement)
from repro.checkpoint import CheckpointManager
from repro.data.pipeline import make_batch
from repro.launch.mesh import make_local_mesh, make_production_mesh
from repro.launch.steps import (abstract_opt, abstract_params, make_train_step,
                                named)
from repro.models.layers import split_lp_tree
from repro.models.model import batch_specs, build_model
from repro.optim import adamw_init
from repro.runtime.fault import FaultInjector, NodeFailure, run_with_restarts
from repro.runtime.straggler import StragglerTracker


def train_loop(cfg, mesh, *, steps: int, seq_len: int, global_batch: int,
               ckpt_dir: Optional[str] = None, ckpt_every: int = 50,
               rebalance_every: int = 0, fault: Optional[FaultInjector] = None,
               lr: float = 3e-4, log_every: int = 10, seed: int = 0):
    model = build_model(cfg, mesh)
    params_sds, p_sh = abstract_params(model)
    step_fn = jax.jit(make_train_step(model, lr=lr,
                                      warmup_steps=max(1, steps // 10),
                                      total_steps=steps),
                      donate_argnums=(0, 1))

    mgr = CheckpointManager(ckpt_dir) if ckpt_dir else None
    start = 0
    if mgr and mgr.latest() is not None:
        opt_sds, o_sh = abstract_opt(params_sds, p_sh)
        (params, opt_state), start = mgr.restore((params_sds, opt_sds),
                                                 (p_sh, o_sh))
        print(f"[train] restored step {start}")
    else:
        lp = model.init(jax.random.key(seed))
        params, _ = split_lp_tree(lp)
        params = jax.device_put(params, p_sh)
        opt_state = adamw_init(params)

    tracker = StragglerTracker(n_ranks=mesh.devices.size)
    losses = []
    for step in range(start, steps):
        if fault is not None:
            fault.maybe_fail(step)
        batch = make_batch(cfg, seq_len, global_batch, step, seed=seed)
        t0 = time.time()
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        loss = float(metrics["loss"])
        dt = time.time() - t0
        tracker.update(np.full(mesh.devices.size, dt))
        losses.append(loss)
        if step % log_every == 0 or step == steps - 1:
            print(f"[train] step {step} loss {loss:.4f} ({dt:.2f}s)",
                  flush=True)
        if mgr and ((step + 1) % ckpt_every == 0 or step == steps - 1):
            mgr.save(step + 1, (params, opt_state))
        if (rebalance_every and cfg.is_moe and (step + 1) % rebalance_every == 0
                and "expert_counts" in metrics):
            counts = np.asarray(metrics["expert_counts"])  # (periods, E)
            params = rebalance_experts(params, counts, cfg, mesh, tracker)
    if mgr:
        mgr.wait()
    return params, opt_state, losses


def rebalance_experts(params, counts, cfg, mesh, tracker):
    """CCM-LB plan -> per-layer slot permutation applied to live params."""
    n_model = int(mesh.shape["model"])
    n_dev = max(n_model, 1)
    if cfg.num_experts % n_dev:
        return params
    plan = plan_expert_placement(
        counts, cfg, n_dev,
        hbm_budget_bytes=16e9,
        rank_speed=None)
    if plan.max_work_after >= plan.max_work_before:
        return params
    scan = dict(params["scan"])
    for i, kind in enumerate(cfg.block_pattern):
        if kind != "moe":
            continue
        blk = dict(scan[f"b{i}"])
        moe = dict(blk["moe"])
        # apply the (layer-period-averaged) permutation of layer 0 to all
        # periods symmetrically: per-period perms would need per-period
        # stats; counts are per period already.
        import jax.numpy as jnp
        perms = jnp.asarray(plan.permutations)  # (periods, E)

        def permute(leaf, axis):
            def one(sl, p):
                return jnp.take(sl, p, axis=axis)
            return jax.vmap(one)(leaf, perms)

        moe["w_gate"] = permute(moe["w_gate"], 0)
        moe["w_up"] = permute(moe["w_up"], 0)
        moe["w_down"] = permute(moe["w_down"], 0)
        moe["router"] = permute(moe["router"], 1)
        blk["moe"] = moe
        scan[f"b{i}"] = blk
    out = dict(params)
    out["scan"] = scan
    print(f"[ccm-lb] expert re-placement: imbalance "
          f"{plan.imbalance_before:.3f} -> {plan.imbalance_after:.3f} "
          f"(replication suggested on {plan.replicated_blocks} blocks)",
          flush=True)
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--rebalance-every", type=int, default=0)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--production-mesh", action="store_true")
    args = ap.parse_args()

    cfg = (configs.get_smoke_config(args.arch) if args.smoke
           else configs.get_config(args.arch))
    mesh = (make_production_mesh() if args.production_mesh
            else make_local_mesh(1, 1))

    def once():
        train_loop(cfg, mesh, steps=args.steps, seq_len=args.seq_len,
                   global_batch=args.global_batch, ckpt_dir=args.ckpt_dir,
                   ckpt_every=args.ckpt_every,
                   rebalance_every=args.rebalance_every, lr=args.lr)

    stats = run_with_restarts(once)
    print(f"[train] done: restarts={stats.restarts} wall={stats.wall_s:.1f}s")


if __name__ == "__main__":
    main()
