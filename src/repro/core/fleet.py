"""Fleet mode: balance MANY independent CCM-LB instances through shared
compiled launches (``ccm_lb_many``).

The target workload is a scheduler balancing a fleet of similar problems —
per-job expert placements, per-replica pipeline stages, a sweep of phase
families — where each instance is small enough that a solo run is
dominated by fixed per-event host cost (shortlist assembly, flow-matrix
gather, the numpy scoring tile).  Running them one at a time repeats that
cost ``n`` times and leaves the compiled scorer scoring one event per
launch.

``ccm_lb_many`` instead advances all instances in LOCKSTEP: each iteration
runs every instance's prologue (cluster/summarize/gossip/work lists) on the
host, derives each instance's deterministic event sequence
(:func:`repro.core.spec.event_sequence`), and drains ALL the queues through
shared :func:`repro.core.spec.run_spec` windows — one compiled launch
scores a window of events drawn round-robin across the whole fleet.  Two
amortizations stack on top of the shared launches:

  * **compile-once-score-many** — every instance maps onto the same
    ``("spec", mode, W, ...)`` shape bucket, so the fleet compiles exactly
    once no matter how many instances run (the benchmark records
    ``trace_count`` to pin this down);
  * **quiet-iteration reuse** — each instance owns a
    :class:`~repro.core.quiesce.QuiesceTracker` (the same amortization
    layer the solo drivers run): clusters/summaries are patched for
    dirty ranks only, quiet gossip roots replay their cached epidemic
    reach, and work lists re-score only ranks whose info maps changed.
    Converged instances — the steady state of a fleet, where most
    iterations transfer nothing — pay a small constant per iteration,
    and their per-``(r, p, version)`` speculative captures
    (:class:`SpecInstance` ``cache``) re-score repeated events for the
    cost of a dict hit and a buffer fill.  Both reuses are value-exact:
    the reused objects are deterministic functions of an unchanged
    state, and every mutation bumps the state version, so stale
    speculative captures are simply never looked up again.

Parity contract: per-instance results are IDENTICAL (assignment and
transfer log) to solo ``ccm_lb(phase_i, a_i, params, seed=seeds[i], ...)``
runs — per-instance dirty sets and strict-prefix rollback keep each
instance's committed order equal to its solo event order, and the scoring
itself sits in the compiled-vs-host parity tier (see
kernels/ccm_scorer/README.md).  tests/test_spec_scan.py and
benchmarks/ccmlb_fleet.py assert the identity on every run.
"""
from __future__ import annotations

from collections import deque
from typing import List, Optional, Sequence

import numpy as np

from repro.core.ccm import CCMState
from repro.core.ccmlb import CCMLBResult, ProtocolStats, _rebuild_local
from repro.core.engine import PhaseEngine
from repro.core.problem import CCMParams, Phase
from repro.core.quiesce import QuiesceTracker
from repro.core.spec import SpecInstance, event_sequence, run_spec

__all__ = ["ccm_lb_many"]


def _mk_rebuild(state, clusters, engine, max_clusters_per_rank):
    # factory so each instance's closure binds ITS objects (late binding
    # in a loop would alias every closure to the last instance)
    return lambda r, p: _rebuild_local(state, clusters, engine,
                                       max_clusters_per_rank, r, p)


def _mk_log(log):
    def _cb(t, a, b):
        log.append((tuple(int(x) for x in t), int(a), int(b)))
    return _cb


def ccm_lb_many(phases: Sequence[Phase],
                assignments: Sequence[np.ndarray],
                params: CCMParams, *,
                n_iter: int = 4, k_rounds: int = 2, fanout: int = 4,
                seeds: Optional[Sequence[int]] = None, seed: int = 0,
                max_candidates: int = 12,
                max_clusters_per_rank: Optional[int] = None,
                backend: str = "numpy",
                window: Optional[int] = None, mode: str = "vmap",
                spec_trace: bool = False,
                csrs: Optional[Sequence] = None) -> List[CCMLBResult]:
    """Balance ``phases[i]`` from ``assignments[i]`` for every ``i``, in
    lockstep, scoring all instances' lock events through shared compiled
    windows.  Returns one :class:`CCMLBResult` per instance, identical to
    the corresponding solo ``ccm_lb`` run (module docstring).

    ``seeds[i]`` is instance ``i``'s gossip seed (solo-equivalent ``seed``
    argument); defaults to ``seed + i``.  ``window`` is the shared
    speculative window size, default ``len(phases)`` (every instance's
    next event fits one launch).  ``mode`` picks the compiled wrapper —
    ``"vmap"`` (default: events of a window are independent, so a
    vectorized map is the natural shape) or ``"scan"``.  ``csrs`` passes
    optional prebuilt ``PhaseCSR`` bundles through to the state builds.
    """
    n = len(phases)
    if n == 0:
        raise ValueError("ccm_lb_many needs at least one instance")
    if len(assignments) != n:
        raise ValueError("one assignment per phase required")
    if seeds is None:
        seeds = [seed + i for i in range(n)]
    elif len(seeds) != n:
        raise ValueError("one seed per phase required")
    if csrs is None:
        csrs = [None] * n
    win = int(window) if window is not None else n
    if win < 1:
        raise ValueError("window must be >= 1")

    states: List[CCMState] = []
    engines: List[PhaseEngine] = []
    trackers: List[QuiesceTracker] = []
    logs: List[list] = []
    cbs: List[object] = []
    stats: List[ProtocolStats] = []
    straces: List[Optional[list]] = []
    # speculative captures are keyed (r, p, state.version): any mutation
    # bumps the version, so stale entries are unreachable — no clearing
    caches: List[dict] = [dict() for _ in range(n)]
    t_max: List[List[float]] = []
    t_tot: List[List[float]] = []
    t_imb: List[List[float]] = []
    for i in range(n):
        st = CCMState.build(phases[i], assignments[i], params, csr=csrs[i])
        states.append(st)
        engines.append(PhaseEngine(st, backend=backend, incremental=True))
        trackers.append(QuiesceTracker(
            st, engines[i], params, seed=seeds[i], k_rounds=k_rounds,
            fanout=fanout, max_clusters_per_rank=max_clusters_per_rank))
        log: list = []
        cb = _mk_log(log)
        st.add_transfer_listener(cb)
        st.add_transfer_listener(trackers[i].note_transfer)
        logs.append(log)
        cbs.append(cb)
        stats.append(ProtocolStats())
        straces.append([] if spec_trace else None)
        t_max.append([st.max_work()])
        t_tot.append([st.total_work()])
        t_imb.append([st.imbalance()])

    try:
        for it in range(n_iter):
            insts: List[SpecInstance] = []
            for i in range(n):
                st = states[i]
                tr = trackers[i]
                tr.begin_iteration(it)
                clusters, summaries = tr.update_summaries()
                info = tr.update_gossip()
                work_lists = tr.update_work_lists(info)
                seq = event_sequence(phases[i].num_ranks, work_lists)
                if seq:
                    insts.append(SpecInstance(
                        state=st, engine=engines[i], clusters=clusters,
                        stats=stats[i],
                        rebuild=_mk_rebuild(st, clusters, engines[i],
                                            max_clusters_per_rank),
                        queue=deque(seq), max_candidates=max_candidates,
                        trace=straces[i], cache=caches[i]))
            if insts:
                run_spec(insts, params, window=win, mode=mode)
            for i in range(n):
                trackers[i].end_iteration()
                t_max[i].append(states[i].max_work())
                t_tot[i].append(states[i].total_work())
                t_imb[i].append(states[i].imbalance())
    finally:
        for i in range(n):
            states[i].remove_transfer_listener(cbs[i])
            states[i].remove_transfer_listener(trackers[i].note_transfer)

    return [CCMLBResult(states[i].assignment.copy(), states[i], t_max[i],
                        t_tot[i], t_imb[i], stats[i].transfers,
                        stats[i].conflicts, engine_used=True,
                        transfer_log=logs[i],
                        spec_rollbacks=stats[i].spec_rollbacks,
                        spec_windows=stats[i].spec_windows,
                        spec_trace=straces[i], engine=engines[i])
            for i in range(n)]
