"""Cluster generation (paper §IV, before the inform stage).

On each rank, tasks that access the same shared block or that communicate
heavily are clustered so they migrate together — splitting them would
replicate the block on more ranks (more memory + homing cost) or turn
intra-rank edges into off-rank ones (more work).

Implementation: union-find per rank over (a) same-shared-block relations and
(b) comm edges whose volume is above ``heavy_quantile`` of local edge volumes.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import numpy as np

from repro.core.ccm import CCMState


class _UF:
    def __init__(self, ids):
        self.parent = {int(i): int(i) for i in ids}

    def find(self, x):
        p = self.parent
        while p[x] != x:
            p[x] = p[p[x]]
            x = p[x]
        return x

    def union(self, a, b):
        ra, rb = self.find(a), self.find(b)
        if ra != rb:
            self.parent[rb] = ra


@dataclasses.dataclass
class ClusterSummary:
    """What the inform stage sends per cluster (§IV-A)."""

    rank: int
    local_id: int
    load: float            # L(c)
    mem: float             # M-(c) task baseline footprint
    overhead: float        # max task overhead in the cluster
    block_ids: np.ndarray  # shared blocks accessed
    block_bytes: float     # total size of those blocks
    vol_intra: float       # V(c): volume among the cluster's tasks
    vol_ext: float         # V∉(c): volume between cluster and anything else
    size: int


def build_clusters(state: CCMState, heavy_quantile: float = 0.75,
                   max_clusters_per_rank: Optional[int] = None,
                   split_frac: float = 0.25,
                   only_ranks: Optional[List[int]] = None
                   ) -> Dict[int, List[np.ndarray]]:
    """rank -> list of task-id arrays (clusters).  Singletons included.

    ``split_frac``: clusters whose load exceeds ``split_frac * mean rank
    load`` are split into load-bounded sub-clusters.  This is what enables the
    paper's replication trade-off (§III-A4): a shared block's tasks may then
    land on several ranks, replicating the block at a memory + homing cost
    that the delta term charges.

    ``only_ranks``: restrict to these ranks (incremental rebuild after a
    transfer touches two ranks).
    """
    ph = state.phase
    a = state.assignment
    mean_load = ph.task_load.sum() / max(ph.num_ranks, 1)
    load_cap = max(split_frac * mean_load, ph.task_load.max(initial=0.0))
    out: Dict[int, List[np.ndarray]] = {}
    # heavy threshold from the global edge-volume distribution
    thresh = (np.quantile(ph.comm_vol, heavy_quantile)
              if ph.num_comms else np.inf)
    same_rank = a[ph.comm_src] == a[ph.comm_dst]
    heavy = same_rank & (ph.comm_vol >= thresh)
    ranks = range(ph.num_ranks) if only_ranks is None else only_ranks
    for r in ranks:
        tasks = np.nonzero(a == r)[0]
        if tasks.size == 0:
            out[r] = []
            continue
        uf = _UF(tasks)
        # same shared block
        blocks: Dict[int, int] = {}
        for t in tasks:
            b = ph.task_block[t]
            if b >= 0:
                if b in blocks:
                    uf.union(blocks[b], int(t))
                else:
                    blocks[b] = int(t)
        # heavy same-rank comm edges
        for e in np.nonzero(heavy & (a[ph.comm_src] == r))[0]:
            uf.union(int(ph.comm_src[e]), int(ph.comm_dst[e]))
        groups: Dict[int, List[int]] = {}
        for t in tasks:
            groups.setdefault(uf.find(int(t)), []).append(int(t))
        clusters: List[np.ndarray] = []
        for g in groups.values():
            clusters.extend(_split_by_load(np.array(g, np.int64),
                                           ph.task_load, load_cap))
        clusters.sort(key=lambda c: -ph.task_load[c].sum())
        if max_clusters_per_rank is not None:
            clusters = clusters[:max_clusters_per_rank]
        out[r] = clusters
    return out


def _split_by_load(tasks: np.ndarray, loads: np.ndarray,
                   cap: float) -> List[np.ndarray]:
    """Greedy first-fit split of a cluster into sub-clusters of load <= cap."""
    total = loads[tasks].sum()
    if total <= cap or tasks.size <= 1:
        return [tasks]
    order = tasks[np.argsort(-loads[tasks])]
    bins: List[List[int]] = []
    bin_loads: List[float] = []
    for t in order:
        lt = loads[t]
        placed = False
        for i in range(len(bins)):
            if bin_loads[i] + lt <= cap:
                bins[i].append(int(t))
                bin_loads[i] += lt
                placed = True
                break
        if not placed:
            bins.append([int(t)])
            bin_loads.append(float(lt))
    return [np.array(b, np.int64) for b in bins]


def summarize_clusters(state: CCMState,
                       clusters: Dict[int, List[np.ndarray]]
                       ) -> Dict[int, List[ClusterSummary]]:
    ph = state.phase
    a = state.assignment
    out: Dict[int, List[ClusterSummary]] = {}
    for r, cls in clusters.items():
        summaries = []
        for ci, tasks in enumerate(cls):
            in_c = np.zeros(ph.num_tasks, bool)
            in_c[tasks] = True
            src_in = in_c[ph.comm_src]
            dst_in = in_c[ph.comm_dst]
            vol_intra = ph.comm_vol[src_in & dst_in].sum()
            vol_ext = ph.comm_vol[src_in ^ dst_in].sum()
            blk = np.unique(ph.task_block[tasks])
            blk = blk[blk >= 0]
            summaries.append(ClusterSummary(
                rank=r,
                local_id=ci,
                load=float(ph.task_load[tasks].sum()),
                mem=float(ph.task_mem[tasks].sum()),
                overhead=float(ph.task_overhead[tasks].max()) if tasks.size else 0.0,
                block_ids=blk,
                block_bytes=float(ph.block_size[blk].sum()),
                vol_intra=float(vol_intra),
                vol_ext=float(vol_ext),
                size=int(tasks.size),
            ))
        out[r] = summaries
    return out


@dataclasses.dataclass
class RankSummary:
    """Rank-level inform payload (§IV-A): loads + comm volumes + homing +
    baseline memory + cluster summaries."""

    rank: int
    load: float
    vol_on: float
    vol_off: float
    homing: float
    mem_used: float        # M_max(r)
    mem_cap: float
    speed: float
    clusters: List[ClusterSummary]


def summarize_rank(state: CCMState, r: int,
                   cluster_summaries: List[ClusterSummary]) -> RankSummary:
    return RankSummary(
        rank=r,
        load=float(state.load[r]),
        vol_on=state.on_rank_volume(r),
        vol_off=state.off_rank_volume(r),
        homing=state.homing_cost(r),
        mem_used=state.max_memory(r),
        mem_cap=float(state.phase.rank_mem_cap[r]),
        speed=float(state.phase.rank_speed[r]),
        clusters=cluster_summaries,
    )
