"""Cluster generation (paper §IV, before the inform stage).

On each rank, tasks that access the same shared block or that communicate
heavily are clustered so they migrate together — splitting them would
replicate the block on more ranks (more memory + homing cost) or turn
intra-rank edges into off-rank ones (more work).

Implementation: connected components per rank over (a) same-shared-block
relations and (b) comm edges whose volume is above ``heavy_quantile`` of
local edge volumes.  The production :func:`build_clusters` runs one
vectorized min-label propagation over flat union-edge arrays (rank
membership read from CSR segments); :func:`build_clusters_reference` is the
seed's per-rank union-find, kept as the reference implementation the parity
tests compare against — both produce identical cluster lists (same
partition, same ordering).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.ccm import CCMState
from repro.core.csr import rank_segments


class _UF:
    def __init__(self, ids):
        self.parent = {int(i): int(i) for i in ids}

    def find(self, x):
        p = self.parent
        while p[x] != x:
            p[x] = p[p[x]]
            x = p[x]
        return x

    def union(self, a, b):
        ra, rb = self.find(a), self.find(b)
        if ra != rb:
            self.parent[rb] = ra


@dataclasses.dataclass
class ClusterSummary:
    """What the inform stage sends per cluster (§IV-A)."""

    rank: int
    local_id: int
    load: float            # L(c)
    mem: float             # M-(c) task baseline footprint
    overhead: float        # max task overhead in the cluster
    block_ids: np.ndarray  # shared blocks accessed
    block_bytes: float     # total size of those blocks
    vol_intra: float       # V(c): volume among the cluster's tasks
    vol_ext: float         # V∉(c): volume between cluster and anything else
    size: int


def _heavy_threshold(state: CCMState, heavy_quantile: float) -> float:
    """Heavy-edge volume threshold from the global edge-volume distribution
    (static per phase -> cached on the state across the many incremental
    rebuilds)."""
    ph = state.phase
    qcache = getattr(state, "_quantile_cache", None)
    if qcache is None:
        qcache = {}
        state._quantile_cache = qcache
    thresh = qcache.get(heavy_quantile)
    if thresh is None:
        thresh = (np.quantile(ph.comm_vol, heavy_quantile)
                  if ph.num_comms else np.inf)
        qcache[heavy_quantile] = thresh
    return thresh


def build_clusters(state: CCMState, heavy_quantile: float = 0.75,
                   max_clusters_per_rank: Optional[int] = None,
                   split_frac: float = 0.25,
                   only_ranks: Optional[List[int]] = None,
                   rank_tasks=None) -> Dict[int, List[np.ndarray]]:
    """rank -> list of task-id arrays (clusters).  Singletons included.

    ``split_frac``: clusters whose load exceeds ``split_frac * mean rank
    load`` are split into load-bounded sub-clusters.  This is what enables the
    paper's replication trade-off (§III-A4): a shared block's tasks may then
    land on several ranks, replicating the block at a memory + homing cost
    that the delta term charges.

    ``only_ranks``: restrict to these ranks (incremental rebuild after a
    transfer touches two ranks).  ``rank_tasks``: optional ``r -> sorted
    member-task id array`` accessor (PhaseEngine.rank_tasks); with it, the
    ``only_ranks`` rebuild touches only the selected ranks' tasks and their
    incident edges instead of scanning every task and edge of the phase —
    same output bitwise (see ``_local_labels``).

    Vectorized: union relations become flat (u, v) pair arrays — consecutive
    tasks of each (block, rank) group plus the heavy same-rank edges — and
    components are found by min-label propagation with pointer jumping, so
    no per-task Python work is done.  Output is identical (composition AND
    order) to :func:`build_clusters_reference`.
    """
    ph = state.phase
    a = state.assignment
    mean_load = ph.task_load.sum() / max(ph.num_ranks, 1)
    load_cap = max(split_frac * mean_load, ph.task_load.max(initial=0.0))
    out: Dict[int, List[np.ndarray]] = {}
    thresh = _heavy_threshold(state, heavy_quantile)
    ranks = list(range(ph.num_ranks)) if only_ranks is None else list(only_ranks)

    if only_ranks is not None and rank_tasks is not None:
        tasks_sel, lab, lab_of = _local_labels(state, ranks, rank_tasks,
                                               thresh)
        rank_members = {r: rank_tasks(r) for r in ranks}
    else:
        lab = _global_labels(state, ranks, thresh)
        lab_of = None
        # full build: one argsort gives every rank's segment; incremental
        # rebuild (2 ranks): a direct membership scan per rank is cheaper
        segs = rank_segments(a, ph.num_ranks) if only_ranks is None else None
        rank_members = {
            r: (segs.row(r) if segs is not None else np.nonzero(a == r)[0])
            for r in ranks}

    for r in ranks:
        tasks = rank_members[r]
        if tasks.size == 0:
            out[r] = []
            continue
        labs = lab_of(tasks) if lab_of is not None else lab[tasks]
        uniq, inv = np.unique(labs, return_inverse=True)
        sorted_tasks = tasks[np.argsort(inv, kind="stable")]
        bounds = np.cumsum(np.bincount(inv, minlength=uniq.shape[0]))[:-1]
        clusters: List[np.ndarray] = []
        for g in np.split(sorted_tasks, bounds):
            clusters.extend(_split_by_load(g, ph.task_load, load_cap))
        clusters.sort(key=lambda c: -ph.task_load[c].sum())
        if max_clusters_per_rank is not None:
            clusters = clusters[:max_clusters_per_rank]
        out[r] = clusters
    return out


def _propagate_min_labels(lab: np.ndarray, u: np.ndarray,
                          v: np.ndarray) -> np.ndarray:
    """Min-label propagation + pointer jumping over union pairs (u, v):
    labels only ever decrease, so the fixpoint labels each element with its
    component's minimum initial label."""
    while u.size:
        m = np.minimum(lab[u], lab[v])
        np.minimum.at(lab, u, m)
        np.minimum.at(lab, v, m)
        while True:
            nl = lab[lab]
            if np.array_equal(nl, lab):
                break
            lab = nl
        if np.array_equal(lab[u], lab[v]):
            break
    return lab


def _global_labels(state: CCMState, ranks: List[int],
                   thresh: float) -> np.ndarray:
    """Component labels over all tasks of the selected ranks, scanning every
    task and edge of the phase (the full-build path)."""
    ph = state.phase
    a = state.assignment
    rank_sel = np.zeros(ph.num_ranks, bool)
    rank_sel[ranks] = True
    same_rank = a[ph.comm_src] == a[ph.comm_dst]
    heavy = same_rank & (ph.comm_vol >= thresh)

    # union pairs: consecutive members of each (block, rank) group ...
    bt = np.nonzero(rank_sel[a] & (ph.task_block >= 0))[0]
    order = np.lexsort((bt, a[bt], ph.task_block[bt]))
    bts = bt[order]
    grp = ((ph.task_block[bts][1:] == ph.task_block[bts][:-1])
           & (a[bts][1:] == a[bts][:-1])) if bts.size else np.zeros(0, bool)
    # ... plus heavy same-rank comm edges on the selected ranks
    he = np.nonzero(heavy & rank_sel[a[ph.comm_src]])[0]
    u = np.concatenate([bts[:-1][grp], ph.comm_src[he]])
    v = np.concatenate([bts[1:][grp], ph.comm_dst[he]])
    lab = np.arange(ph.num_tasks, dtype=np.int64)
    return _propagate_min_labels(lab, u, v)


def _local_labels(state: CCMState, ranks: List[int], rank_tasks,
                  thresh: float):
    """Component labels restricted to the selected ranks' tasks — O(their
    tasks + their incident edges) instead of O(num_tasks + num_comms).

    Exactness: union pairs never cross ranks (block groups are per (block,
    rank); heavy edges require ``a[src] == a[dst]``), so restricting to the
    selected ranks' tasks and their incident edges keeps every qualifying
    pair.  Labels are component-min LOCAL indices into the globally-sorted
    selected-task array; within any single rank the local index is monotone
    in the global task id, so per-rank ``np.unique`` grouping and group
    ORDER are bitwise-identical to the global-label path.
    """
    ph = state.phase
    a = state.assignment
    segs = [rank_tasks(r) for r in ranks]
    tasks_sel = (np.sort(np.concatenate(segs)) if segs
                 else np.zeros(0, np.int64))
    lab = np.arange(tasks_sel.shape[0], dtype=np.int64)

    if tasks_sel.size:
        # block pairs: consecutive members of each (block, rank) group
        tb = ph.task_block[tasks_sel]
        bt = tasks_sel[tb >= 0]
        order = np.lexsort((bt, a[bt], ph.task_block[bt]))
        bts = bt[order]
        grp = ((ph.task_block[bts][1:] == ph.task_block[bts][:-1])
               & (a[bts][1:] == a[bts][:-1])) if bts.size \
            else np.zeros(0, bool)
        # heavy same-rank edges: every qualifying edge is incident to a
        # selected task (both endpoints share the — selected — rank).  The
        # gather lists an edge once per selected endpoint; duplicate union
        # pairs are harmless to min-label propagation, so no dedupe.
        eids = state.csr.task_edges.gather(tasks_sel)
        src, dst = ph.comm_src[eids], ph.comm_dst[eids]
        hm = (a[src] == a[dst]) & (ph.comm_vol[eids] >= thresh)
        u_g = np.concatenate([bts[:-1][grp], src[hm]])
        v_g = np.concatenate([bts[1:][grp], dst[hm]])
        lab = _propagate_min_labels(lab, np.searchsorted(tasks_sel, u_g),
                                    np.searchsorted(tasks_sel, v_g))

    def lab_of(tasks: np.ndarray) -> np.ndarray:
        return lab[np.searchsorted(tasks_sel, tasks)]

    return tasks_sel, lab, lab_of


def build_clusters_reference(state: CCMState, heavy_quantile: float = 0.75,
                             max_clusters_per_rank: Optional[int] = None,
                             split_frac: float = 0.25,
                             only_ranks: Optional[List[int]] = None
                             ) -> Dict[int, List[np.ndarray]]:
    """Seed per-rank union-find implementation (reference for parity tests;
    see :func:`build_clusters` for the production vectorized path)."""
    ph = state.phase
    a = state.assignment
    mean_load = ph.task_load.sum() / max(ph.num_ranks, 1)
    load_cap = max(split_frac * mean_load, ph.task_load.max(initial=0.0))
    out: Dict[int, List[np.ndarray]] = {}
    # heavy threshold from the global edge-volume distribution
    thresh = (np.quantile(ph.comm_vol, heavy_quantile)
              if ph.num_comms else np.inf)
    same_rank = a[ph.comm_src] == a[ph.comm_dst]
    heavy = same_rank & (ph.comm_vol >= thresh)
    ranks = range(ph.num_ranks) if only_ranks is None else only_ranks
    for r in ranks:
        tasks = np.nonzero(a == r)[0]
        if tasks.size == 0:
            out[r] = []
            continue
        uf = _UF(tasks)
        # same shared block
        blocks: Dict[int, int] = {}
        for t in tasks:
            b = ph.task_block[t]
            if b >= 0:
                if b in blocks:
                    uf.union(blocks[b], int(t))
                else:
                    blocks[b] = int(t)
        # heavy same-rank comm edges
        for e in np.nonzero(heavy & (a[ph.comm_src] == r))[0]:
            uf.union(int(ph.comm_src[e]), int(ph.comm_dst[e]))
        groups: Dict[int, List[int]] = {}
        for t in tasks:
            groups.setdefault(uf.find(int(t)), []).append(int(t))
        clusters: List[np.ndarray] = []
        for g in groups.values():
            clusters.extend(_split_by_load(np.array(g, np.int64),
                                           ph.task_load, load_cap))
        clusters.sort(key=lambda c: -ph.task_load[c].sum())
        if max_clusters_per_rank is not None:
            clusters = clusters[:max_clusters_per_rank]
        out[r] = clusters
    return out


def _split_by_load(tasks: np.ndarray, loads: np.ndarray,
                   cap: float) -> List[np.ndarray]:
    """Greedy first-fit split of a cluster into sub-clusters of load <= cap."""
    total = loads[tasks].sum()
    if total <= cap or tasks.size <= 1:
        return [tasks]
    order = tasks[np.argsort(-loads[tasks])]
    bins: List[List[int]] = []
    bin_loads: List[float] = []
    for t in order:
        lt = loads[t]
        placed = False
        for i in range(len(bins)):
            if bin_loads[i] + lt <= cap:
                bins[i].append(int(t))
                bin_loads[i] += lt
                placed = True
                break
        if not placed:
            bins.append([int(t)])
            bin_loads.append(float(lt))
    return [np.array(b, np.int64) for b in bins]


def _half_split(task_load: np.ndarray, cluster: np.ndarray) -> np.ndarray:
    """Deterministic near-balanced bipartition of a cluster's tasks:
    greedy descending-load placement into two bins (stable sort, so equal
    loads keep ascending task-id order), returning the LIGHTER bin — the
    travelling half of a replication split.  For ``len(cluster) >= 2``
    both bins are non-empty, so the split is always a strict sub-cluster
    move."""
    cluster = np.asarray(cluster, np.int64)
    order = np.argsort(-task_load[cluster], kind="stable")
    bins: Tuple[List[int], List[int]] = ([], [])
    tot = [0.0, 0.0]
    for t in cluster[order]:
        j = 0 if tot[0] <= tot[1] else 1
        bins[j].append(int(t))
        tot[j] += float(task_load[t])
    move = bins[0] if tot[0] <= tot[1] else bins[1]
    return np.asarray(sorted(move), np.int64)


def summarize_clusters(state: CCMState,
                       clusters: Dict[int, List[np.ndarray]],
                       eids: Optional[np.ndarray] = None,
                       replicate: bool = False
                       ) -> Dict[int, List[ClusterSummary]]:
    """Cluster inform payloads, with the intra/external comm volumes of ALL
    clusters computed in one labelled pass over the edge list (the seed
    rebuilt an O(num_tasks) membership mask per cluster).

    ``eids``: optional ascending unique edge-id subset to scan instead of
    the full edge list — the amortized prologue (repro/core/quiesce.py)
    passes the edges incident to the dirty ranks' tasks.  Bitwise-exact
    for any ``clusters`` whose member tasks' incident edges are all in
    ``eids``: every edge contributing to a given cluster's bucket appears
    in the same relative order as in the full pass, so the bincount
    partial sums accumulate identically.

    ``replicate``: append one VIRTUAL summary per block-affine cluster
    (>= 2 tasks, all one block — the replication-split eligibility of
    ``memory_move_candidates``) describing its :func:`_half_split`
    travelling half, marked ``local_id=-1``.  Stage 1 scores whole
    clusters from these summaries, so without the virtual entries a rank
    whose only surplus is expressible as a half-split can never initiate
    a lock event and replication starves; with them, both the scalar
    ``approx_best_diff`` and the batched ``batch_peer_diffs`` see
    half-split granularity (identically — they read the same objects).
    Stage 2 re-derives the real candidates and evaluates them exactly,
    so the entries only ever gate WHICH events fire."""
    ph = state.phase
    flat: List[Tuple[int, int, np.ndarray]] = [
        (r, ci, tasks) for r, cls in clusters.items()
        for ci, tasks in enumerate(cls)]
    n = len(flat)
    gids = np.full(ph.num_tasks, -1, np.int64)
    for gid, (_, _, tasks) in enumerate(flat):
        gids[tasks] = gid
    if eids is None:
        e_src, e_dst, e_vol = ph.comm_src, ph.comm_dst, ph.comm_vol
        n_edges = ph.num_comms
    else:
        e_src, e_dst = ph.comm_src[eids], ph.comm_dst[eids]
        e_vol = ph.comm_vol[eids]
        n_edges = eids.shape[0]
    vol_intra = np.zeros(n)
    vol_ext = np.zeros(n)
    if n and n_edges:
        ls, ld = gids[e_src], gids[e_dst]
        intra = (ls == ld) & (ls >= 0)
        vol_intra = np.bincount(ls[intra], weights=e_vol[intra],
                                minlength=n)
        cut = ls != ld
        m = cut & (ls >= 0)
        vol_ext = np.bincount(ls[m], weights=e_vol[m], minlength=n)
        m = cut & (ld >= 0)
        vol_ext = vol_ext + np.bincount(ld[m], weights=e_vol[m],
                                        minlength=n)
    out: Dict[int, List[ClusterSummary]] = {r: [] for r in clusters}
    for gid, (r, ci, tasks) in enumerate(flat):
        blk = np.unique(ph.task_block[tasks])
        blk = blk[blk >= 0]
        out[r].append(ClusterSummary(
            rank=r,
            local_id=ci,
            load=float(ph.task_load[tasks].sum()),
            mem=float(ph.task_mem[tasks].sum()),
            overhead=float(ph.task_overhead[tasks].max()) if tasks.size else 0.0,
            block_ids=blk,
            block_bytes=float(ph.block_size[blk].sum()),
            vol_intra=float(vol_intra[gid]),
            vol_ext=float(vol_ext[gid]),
            size=int(tasks.size),
        ))
    if not replicate:
        return out
    # virtual half-split entries: a second labelled pass over the same
    # edge (sub)sequence, labelling only each travelling half — an edge
    # from the half to its kept sibling tasks correctly counts as
    # EXTERNAL (that is what it becomes once the split lands)
    vflat: List[Tuple[int, np.ndarray, int]] = []
    for r, cls in clusters.items():
        for tasks in cls:
            tasks = np.asarray(tasks, np.int64)
            if tasks.shape[0] < 2:
                continue
            blocks = ph.task_block[tasks]
            if blocks[0] < 0 or not (blocks == blocks[0]).all():
                continue
            vflat.append((r, _half_split(ph.task_load, tasks),
                          int(blocks[0])))
    if not vflat:
        return out
    vn = len(vflat)
    vgids = np.full(ph.num_tasks, -1, np.int64)
    for gid, (_, half, _) in enumerate(vflat):
        vgids[half] = gid
    v_intra = np.zeros(vn)
    v_ext = np.zeros(vn)
    if n_edges:
        ls, ld = vgids[e_src], vgids[e_dst]
        intra = (ls == ld) & (ls >= 0)
        v_intra = np.bincount(ls[intra], weights=e_vol[intra],
                              minlength=vn)
        cut = ls != ld
        m = cut & (ls >= 0)
        v_ext = np.bincount(ls[m], weights=e_vol[m], minlength=vn)
        m = cut & (ld >= 0)
        v_ext = v_ext + np.bincount(ld[m], weights=e_vol[m],
                                    minlength=vn)
    for gid, (r, half, b) in enumerate(vflat):
        out[r].append(ClusterSummary(
            rank=r,
            local_id=-1,            # virtual: stage-1 scoring only
            load=float(ph.task_load[half].sum()),
            mem=float(ph.task_mem[half].sum()),
            overhead=float(ph.task_overhead[half].max()),
            block_ids=np.array([b], np.int64),
            block_bytes=float(ph.block_size[b]),
            vol_intra=float(v_intra[gid]),
            vol_ext=float(v_ext[gid]),
            size=int(half.shape[0]),
        ))
    return out


@dataclasses.dataclass
class RankSummary:
    """Rank-level inform payload (§IV-A): loads + comm volumes + homing +
    baseline memory + cluster summaries."""

    rank: int
    load: float
    vol_on: float
    vol_off: float
    homing: float
    mem_used: float        # M_max(r)
    mem_cap: float
    speed: float
    clusters: List[ClusterSummary]


def summarize_rank(state: CCMState, r: int,
                   cluster_summaries: List[ClusterSummary]) -> RankSummary:
    return RankSummary(
        rank=r,
        load=float(state.load[r]),
        vol_on=state.on_rank_volume(r),
        vol_off=state.off_rank_volume(r),
        homing=state.homing_cost(r),
        mem_used=state.max_memory(r),
        mem_cap=float(state.phase.rank_mem_cap[r]),
        speed=float(state.phase.rank_speed[r]),
        clusters=cluster_summaries,
    )
