"""Deadlock-free lock protocol (paper §IV-B, Fig. 1 lines 42–49).

Semantics simulated faithfully:
  * each rank may be locked by at most one other rank; requests queue FIFO;
  * a rank may hold a lock while being locked itself (that is the deadlock
    setup) — cycles are broken by the priority rule: if rank r, locked by
    r_x, obtains a lock on r_2 and r_x <= r_2, r immediately releases r_2 and
    re-queues the attempt for later.

Grant tokens: every request may carry a ``req_id`` — a unique token minted
by the requester.  The token travels REQ -> GRANT -> RELEASE, and the
fault-tolerant surface below (:meth:`holds_grant` / :meth:`dequeue` /
:meth:`purge_requester` / :meth:`reclaim`) uses it to make the handlers
idempotent on a lossy, duplicating network: a RELEASE only frees the lock
whose exact grant it closes (a stale or duplicated RELEASE for an older
grant epoch is a no-op even when the same pair re-locked in between), a
timed-out queued request can be surgically dequeued, and a dead rank's
lock state can be reclaimed wholesale.  The synchronous driver and the
fault-free async driver pass ``req_id=None`` everywhere and never touch
the fault surface — their behavior is exactly the pre-token protocol.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Deque, Dict, Optional, Tuple


@dataclasses.dataclass
class LockManager:
    n_ranks: int

    def __post_init__(self):
        self.locked_by: Dict[int, Optional[int]] = {
            r: None for r in range(self.n_ranks)}
        # FIFO of (requester, req_id) pairs per target
        self.queue: Dict[int, Deque[Tuple[int, Optional[int]]]] = {
            r: deque() for r in range(self.n_ranks)}
        # token of the grant currently held on each target (None when free
        # or when the grant was token-less)
        self.grant_id: Dict[int, Optional[int]] = {
            r: None for r in range(self.n_ranks)}

    def request(self, requester: int, target: int,
                req_id: Optional[int] = None) -> bool:
        """Returns True if the lock is granted immediately; else queues."""
        if self.locked_by[target] is None:
            self.locked_by[target] = requester
            self.grant_id[target] = req_id
            return True
        self.queue[target].append((requester, req_id))
        return False

    def release(self, holder: int, target: int) -> Optional[int]:
        """Release target; grant to next queued requester (returned)."""
        assert self.locked_by[target] == holder, (holder, target,
                                                  self.locked_by[target])
        self.locked_by[target] = None
        self.grant_id[target] = None
        if self.queue[target]:
            nxt, rid = self.queue[target].popleft()
            self.locked_by[target] = nxt
            self.grant_id[target] = rid
            return nxt
        return None

    def must_yield(self, holder: int, held: int) -> bool:
        """Fig. 1 line 45: holder is locked by r_x and r_x <= held."""
        r_x = self.locked_by[holder]
        return r_x is not None and r_x <= held

    def is_locked(self, r: int) -> bool:
        return self.locked_by[r] is not None

    def held_by(self, holder: int) -> list:
        """Targets currently locked by ``holder``.  The async protocol
        suite asserts through this after every event that a rank holds at
        most one lock net of in-flight releases (a rank only ever has one
        outstanding request; a released target keeps the old holder of
        record until the RELEASE message lands)."""
        return [t for t, h in self.locked_by.items() if h == holder]

    def quiescent(self) -> bool:
        """No lock held and no request queued — the stage-end liveness
        condition both drivers must reach (asserted by the async driver
        at every stage-2 termination)."""
        return (all(h is None for h in self.locked_by.values())
                and all(not q for q in self.queue.values()))

    # -------------------------------------------------- fault-tolerant surface
    # Used only by the async driver under an active FaultSpec
    # (repro/core/async_sim.py); no synchronous code path reaches these.

    def holds_grant(self, holder: int, target: int,
                    req_id: Optional[int]) -> bool:
        """True iff ``holder`` holds ``target``'s lock under exactly this
        grant token — the idempotence predicate for RELEASE handling (a
        duplicated RELEASE whose grant epoch already closed must not free
        a newer lock, even between the same pair of ranks)."""
        return (self.locked_by[target] == holder
                and self.grant_id[target] == req_id)

    def dequeue(self, requester: int, target: int,
                req_id: Optional[int]) -> bool:
        """Remove one queued ``(requester, req_id)`` entry — a timed-out
        request's abort.  Returns True iff an entry was removed (False
        means the request was never delivered, already granted, or
        already dequeued — all no-ops by design)."""
        q = self.queue[target]
        for i, (r, rid) in enumerate(q):
            if r == requester and rid == req_id:
                del q[i]
                return True
        return False

    def purge_requester(self, requester: int) -> int:
        """Drop every queued request BY ``requester`` (rank death: a dead
        rank must never be granted a lock).  Returns the number removed."""
        removed = 0
        for t in range(self.n_ranks):
            q = self.queue[t]
            if any(r == requester for r, _ in q):
                kept = [(r, rid) for r, rid in q if r != requester]
                removed += len(q) - len(kept)
                q.clear()
                q.extend(kept)
        return removed

    def reclaim(self, target: int) -> int:
        """Forget all lock state ON ``target``: holder of record, grant
        token, queued requests.  Used when ``target`` dies (its lock table
        dies with it) and at the stage-end barrier to clear locks wedged
        by dropped RELEASE messages.  Returns the number of discarded
        entries (held lock + queue length)."""
        cleared = ((1 if self.locked_by[target] is not None else 0)
                   + len(self.queue[target]))
        self.locked_by[target] = None
        self.grant_id[target] = None
        self.queue[target].clear()
        return cleared
