"""Deadlock-free lock protocol (paper §IV-B, Fig. 1 lines 42–49).

Semantics simulated faithfully:
  * each rank may be locked by at most one other rank; requests queue FIFO;
  * a rank may hold a lock while being locked itself (that is the deadlock
    setup) — cycles are broken by the priority rule: if rank r, locked by
    r_x, obtains a lock on r_2 and r_x <= r_2, r immediately releases r_2 and
    re-queues the attempt for later.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Deque, Dict, Optional


@dataclasses.dataclass
class LockManager:
    n_ranks: int

    def __post_init__(self):
        self.locked_by: Dict[int, Optional[int]] = {
            r: None for r in range(self.n_ranks)}
        self.queue: Dict[int, Deque[int]] = {
            r: deque() for r in range(self.n_ranks)}

    def request(self, requester: int, target: int) -> bool:
        """Returns True if the lock is granted immediately; else queues."""
        if self.locked_by[target] is None:
            self.locked_by[target] = requester
            return True
        self.queue[target].append(requester)
        return False

    def release(self, holder: int, target: int) -> Optional[int]:
        """Release target; grant to next queued requester (returned)."""
        assert self.locked_by[target] == holder, (holder, target,
                                                  self.locked_by[target])
        self.locked_by[target] = None
        if self.queue[target]:
            nxt = self.queue[target].popleft()
            self.locked_by[target] = nxt
            return nxt
        return None

    def must_yield(self, holder: int, held: int) -> bool:
        """Fig. 1 line 45: holder is locked by r_x and r_x <= held."""
        r_x = self.locked_by[holder]
        return r_x is not None and r_x <= held

    def is_locked(self, r: int) -> bool:
        return self.locked_by[r] is not None

    def held_by(self, holder: int) -> list:
        """Targets currently locked by ``holder``.  The async protocol
        suite asserts through this after every event that a rank holds at
        most one lock net of in-flight releases (a rank only ever has one
        outstanding request; a released target keeps the old holder of
        record until the RELEASE message lands)."""
        return [t for t, h in self.locked_by.items() if h == holder]

    def quiescent(self) -> bool:
        """No lock held and no request queued — the stage-end liveness
        condition both drivers must reach (asserted by the async driver
        at every stage-2 termination)."""
        return (all(h is None for h in self.locked_by.values())
                and all(not q for q in self.queue.values()))
