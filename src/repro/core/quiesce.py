"""Quiescence tracking: make quiet CCM-LB iterations nearly free.

The paper's algorithm converges in a handful of iterations and then
mostly *confirms* quiescence; profiles (kernels/ccm_scorer/README.md)
show 60%+ of a converged iteration is parity-shared host work — gossip
network construction, work-list assembly, cluster/summary rebuilds and
commit bookkeeping.  :class:`QuiesceTracker` makes all four cost centers
incremental in the number of **dirty ranks**, with bitwise-identical
trajectories as the bar (the rebuild reference and the amortized path
must produce the same assignments, transfer logs and work traces).

Dirty propagation per committed transfer ``(tasks, r_from, r_to)``
(delivered through ``CCMState.add_transfer_listener``):

  * **cluster-dirty** = ``{r_from, r_to}`` — cluster membership is a
    function of the rank's own task set, so third ranks' clusters cannot
    change (tests/test_quiesce.py asserts this against full rebuilds);
  * **value-dirty**  = cluster-dirty ∪ ranks hosting an endpoint of any
    edge incident to the moved tasks.  Third ranks' loads, memory,
    homing and on-rank volumes are untouched by construction
    (``apply_transfer`` only shifts block presence on the two endpoint
    ranks), but ``off_rank_volume`` row/column sums can shift by ulps
    when touched-edge buckets are rearranged, so those ranks' summaries
    must be recomputed to stay bitwise-faithful.

Per-rank **epochs** then drive the gossip stream keys: ``epoch[r]`` is
the iteration at which rank ``r`` last became value-dirty, and root
``r``'s epidemic draws from ``gossip_root_key(gossip_seed(seed,
epoch[r]), r)``.  Epochs are ALGORITHM state, not cache state: the
tracker runs (and folds epochs) in every configuration — incremental or
not, sync or async — so the full-rebuild reference re-draws each root
from exactly the key whose cached reach the amortized path replays.
That is the whole bitwise-equality argument: both paths evaluate the
same pure function of the same key; one of them just remembers the
answer (see repro/core/gossip.py).

Caching (``self.caching``) additionally retains, across iterations:
maintained cluster lists + cluster/rank summaries (patched for dirty
ranks only), the flat :class:`~repro.core.engine.SummaryTables` (rows
patched in place while per-rank cluster counts are stable), each rank's
sorted stage-2 work list (re-scored only for ranks whose ``info`` map
content changed), and a version-validated memo of failed exact
evaluations (``memo[(r, p)] == state.version`` proves the pair still
fails — the version is bumped by every mutation).  A converged
(zero-transfer) iteration therefore performs zero cluster builds, zero
gossip draws, zero work-list scorings and zero exact evaluations: its
cost is a small constant in the number of ranks actually changing, not
O(ranks + tasks + edges).
"""
from __future__ import annotations

from collections import deque
from typing import Dict, List, Optional, Set

import numpy as np

from repro.core.clusters import (build_clusters, summarize_clusters,
                                 summarize_rank)
from repro.core.ccm import INF, effective_mem_cap
from repro.core.engine import batch_peer_diffs, build_summary_tables
from repro.core.gossip import (build_peer_networks, gossip_root_key,
                               gossip_seed, update_peer_networks)

__all__ = ["QuiesceTracker", "phase_values_equal"]

_VALUE_ARRAYS = ("task_load", "task_mem", "task_overhead", "comm_vol",
                 "block_size", "rank_speed", "rank_mem_base",
                 "rank_mem_cap")


def phase_values_equal(a, b) -> bool:
    """True when two same-topology phases carry identical value arrays —
    the condition under which a carried tracker's caches (clusters,
    summaries, gossip reach sets) remain bitwise-valid for the new
    phase."""
    return all(np.array_equal(getattr(a, f), getattr(b, f))
               for f in _VALUE_ARRAYS)


class QuiesceTracker:
    """Per-run activity tracker + amortized-iteration cache (module
    docstring).  One tracker per balancer instance; register
    :meth:`note_transfer` as a transfer listener on the instance's
    ``CCMState`` and drive each iteration as::

        tracker.begin_iteration(it)            # fold dirty -> epochs
        clusters, summaries = tracker.update_summaries()
        info = tracker.update_gossip()         # sync/fleet drivers only
        work_lists = tracker.update_work_lists(info)   # caching only
        ... stage 2 ...
        tracker.end_iteration()

    The async driver skips :meth:`update_gossip`/:meth:`update_work_lists`
    (its info maps are latency-dependent) but still folds epochs and asks
    :meth:`root_key` for the per-root gossip streams, which is what keeps
    the zero-latency parity bar aligned with the sync driver.
    """

    def __init__(self, state, engine, params, *, seed: int, k_rounds: int,
                 fanout: int, max_clusters_per_rank: Optional[int] = None,
                 caching: bool = True, replicate: bool = False):
        self.state = state
        self.engine = engine
        self.params = params
        self.seed = int(seed)
        self.k_rounds = int(k_rounds)
        self.fanout = int(fanout)
        self.mcpr = max_clusters_per_rank
        # thread the replication vocabulary into the summary prologue:
        # stage 1 needs the virtual half-split entries (summarize_clusters)
        # or replication-shaped surplus can never initiate a lock event
        self.replicate = bool(replicate)
        self.n = int(state.phase.num_ranks)
        # caching needs the engine's incrementally-maintained rank
        # segments (cluster rebuild scope) and flat summary tables
        self._want_caching = bool(caching)
        self.caching = bool(caching and engine is not None
                            and getattr(engine, "incremental", False))
        self.counters: Dict[str, int] = {}
        self.iter_counters: List[Dict[str, int]] = []
        self.memo: Dict[tuple, int] = {}
        self.reset()

    # ---- dirty propagation ------------------------------------------------

    def note_transfer(self, tasks, r_from: int, r_to: int) -> None:
        """Transfer listener (``CCMState.add_transfer_listener``): mark
        the endpoint ranks cluster-dirty and every rank hosting an
        endpoint of a touched edge value-dirty (module docstring)."""
        r_from, r_to = int(r_from), int(r_to)
        self.cluster_dirty.update((r_from, r_to))
        vd = self.value_dirty
        vd.update((r_from, r_to))
        st = self.state
        eids = st._touched_edges(np.asarray(tasks))
        if eids.size:
            ph = st.phase
            a = st.assignment
            for x in np.unique(a[ph.comm_src[eids]]):
                vd.add(int(x))
            for x in np.unique(a[ph.comm_dst[eids]]):
                vd.add(int(x))

    def force_dirty(self, ranks) -> None:
        """Mark ``ranks`` cluster- AND value-dirty for the next epoch fold
        regardless of transfer activity.  The fault/membership paths use
        this for state changes that do not flow through a transfer —
        deaths, partitions healing, joins — so quiescence stays absorbing:
        an externally-perturbed rank re-keys its gossip epoch and re-scores
        exactly once instead of replaying stale cached state forever."""
        for r in ranks:
            r = int(r)
            self.cluster_dirty.add(r)
            self.value_dirty.add(r)

    def purge_ranks(self, ranks) -> None:
        """Evict dead ranks from every cache family so no stale entry of
        theirs can ever be served again:

          * **clusters / summaries** — the dead ranks' cached cluster lists
            are emptied (crash recovery just migrated their tasks away;
            they are also force-marked dirty, so the next iteration
            rebuilds them from the now-empty task sets);
          * **gossip reach** — the dead roots' cached epidemics are
            dropped and their summaries spliced out of every rank's info
            map (a dead rank's summary must never re-enter a work list);
          * **work-list score tables** — the dead ranks' own candidate
            lists are cleared and they are removed from every other
            rank's scored candidates;
          * **commit memo** — every memoized failed evaluation touching a
            dead rank is deleted.

        Ranks that had heard a dead root are force-marked dirty too, so
        their work lists re-score on the caching (sync-driver) path.
        """
        dead = {int(r) for r in ranks}
        if not dead:
            return
        self.force_dirty(dead)
        for k in [k for k in self.memo if k[0] in dead or k[1] in dead]:
            del self.memo[k]
        affected: Set[int] = set()
        for d in dead:
            old = self.reach.pop(d, ())
            self.reach_key.pop(d, None)
            if self.info is not None:
                for dst in old:
                    if dst in self.info and self.info[dst].pop(d, None) \
                            is not None:
                        affected.add(dst)
                self.info[d] = {}
        if self.clusters is not None:
            for d in dead:
                self.clusters[d] = []
                self.csum[d] = []
        if self.scores is not None:
            for r in list(self.scores):
                if r in dead:
                    self.scores[r] = []
                else:
                    kept = [(s, p) for (s, p) in self.scores[r]
                            if p not in dead]
                    if len(kept) != len(self.scores[r]):
                        self.scores[r] = kept
        self.force_dirty(affected - dead)

    def regrow(self, state, engine) -> None:
        """Re-target the tracker at a WIDER mesh after a membership join
        (``ccm_lb_async(membership=...)`` rebuilt the state/engine on the
        expanded phase).  Every cache is dropped and every rank marked
        dirty — peer candidate sets are a function of the rank count, so
        no cached reach, score list or memo entry survives a join — but
        the cumulative counters and per-iteration snapshots are kept, so
        accounting stays continuous across the membership change."""
        self.state = state
        self.engine = engine
        self.n = int(state.phase.num_ranks)
        self.caching = bool(self._want_caching and engine is not None
                            and getattr(engine, "incremental", False))
        self.reset()

    # ---- lifecycle --------------------------------------------------------

    def reset(self) -> None:
        """Drop every cache and mark everything dirty (fresh run, or a
        carry whose phase values/params changed)."""
        n = self.n
        self.cluster_dirty: Set[int] = set(range(n))
        self.value_dirty: Set[int] = set(range(n))
        self.epoch = np.zeros(n, np.int64)
        self.clusters = None
        self.csum = None
        self.summaries = None
        self.tables = None
        self.info = None
        self.reach: Dict[int, List[int]] = {}
        self.reach_key: Dict[int, tuple] = {}
        self.scores: Optional[Dict[int, list]] = None
        self.memo.clear()
        self._cd: List[int] = []
        self._vd: List[int] = []
        self._affected: Optional[Set[int]] = None

    def rebind(self, *, seed: int, params, keep: bool) -> None:
        """Re-target a carried tracker at a new phase (the caller already
        retargeted the state and checked ``same_topology``).  ``keep``
        asserts the new phase's value arrays AND params equal the old
        ones, so every cache remains bitwise-valid; epochs reset to 0 —
        exactly what a fresh run starts with — and the new seed makes
        every cached reach key mismatch when it differs, forcing the same
        full gossip redraw a fresh run performs.  Pending dirty ranks
        from the previous phase's tail are carried and folded at
        iteration 0, which recomputes their summaries against the final
        (carried) assignment just as a fresh build would."""
        self.seed = int(seed)
        self.params = params
        if keep and self.caching and self.clusters is not None:
            self.epoch[:] = 0
            self.memo.clear()
        else:
            self.reset()

    def begin_iteration(self, it: int) -> None:
        """Fold the pending dirty sets: value-dirty ranks stamp their
        epoch with this iteration (their gossip key changes), and the
        folded sets become this iteration's patch scope."""
        for r in self.value_dirty:
            self.epoch[r] = it
        self._cd = sorted(self.cluster_dirty)
        self._vd = sorted(self.value_dirty)
        self.cluster_dirty = set()
        self.value_dirty = set()

    def end_iteration(self) -> None:
        """Snapshot the cumulative counters (tests diff consecutive
        snapshots to assert a converged iteration did zero work)."""
        self.iter_counters.append(dict(self.counters))

    def _count(self, key: str, inc: int = 1) -> None:
        self.counters[key] = self.counters.get(key, 0) + inc

    # ---- stage 0: clusters + summaries ------------------------------------

    def _full_summaries(self):
        st = self.state
        clusters = build_clusters(st, max_clusters_per_rank=self.mcpr)
        csum = summarize_clusters(st, clusters, replicate=self.replicate)
        summaries = {r: summarize_rank(st, r, csum[r]) for r in range(self.n)}
        self._count("cluster_rank_builds", self.n)
        return clusters, csum, summaries

    def update_summaries(self):
        """Returns ``(clusters, summaries)`` for this iteration, bitwise
        what ``iteration_summaries`` recomputes from scratch.  Caching
        path: rebuild clusters + cluster summaries only for cluster-dirty
        ranks (one ``build_clusters(only_ranks=...)`` call over the edges
        incident to their tasks) and rank summaries only for value-dirty
        ranks; everything else is reused by object."""
        st = self.state
        if not self.caching:
            clusters, csum, summaries = self._full_summaries()
            # retained for update_gossip (epochs still key the streams on
            # the rebuild reference); rebuilt from scratch next iteration
            self.summaries = summaries
            return clusters, summaries
        if self.clusters is None:
            # post-reset invariant: the pending dirty sets were full, so
            # the epoch fold already covered every rank
            self.clusters, self.csum, self.summaries = self._full_summaries()
            return self.clusters, self.summaries
        if self._cd:
            eng = self.engine
            sub = build_clusters(st, max_clusters_per_rank=self.mcpr,
                                 only_ranks=self._cd,
                                 rank_tasks=eng.rank_tasks)
            for r in self._cd:
                self.clusters[r] = sub[r]
            self._count("cluster_rank_builds", len(self._cd))
            # cluster summaries from the edges incident to the dirty
            # ranks' tasks only: per summary bucket that is the same
            # contributing edge subsequence in the same order as the
            # global pass, so the bincount partial sums are bitwise equal
            tasks = [eng.rank_tasks(r) for r in self._cd]
            eids = np.unique(st.csr.task_edges.gather(
                np.concatenate(tasks) if tasks else
                np.zeros(0, np.int64)))
            csl = summarize_clusters(st, {r: sub[r] for r in self._cd},
                                     eids=eids, replicate=self.replicate)
            for r in self._cd:
                self.csum[r] = csl[r]
        for r in self._vd:
            self.summaries[r] = summarize_rank(st, r, self.csum[r])
        return self.clusters, self.summaries

    # ---- stage 1: gossip ---------------------------------------------------

    def root_key(self, r: int) -> list:
        """Root ``r``'s epidemic stream key for the current epoch —
        shared verbatim by the full rebuild, the cached replay and the
        async event-loop flood."""
        return gossip_root_key(gossip_seed(self.seed, int(self.epoch[r])), r)

    def update_gossip(self):
        """Returns this iteration's per-rank info maps.  Rebuild path:
        every root re-drawn from its epoch key.  Caching path: re-draw
        only roots whose key changed (value-dirty ranks bumped their
        epoch; a carry swapped the seed), splicing old reach out and new
        reach in — content-identical to the rebuild because clean roots'
        epidemics are pure functions of their unchanged keys."""
        n = self.n
        keys = {r: self.root_key(r) for r in range(n)}
        if not self.caching:
            self.info = build_peer_networks(
                self.summaries, k_rounds=self.k_rounds, fanout=self.fanout,
                root_seeds=keys, stats=self.counters)
            self._count("gossip_redraws", n)
            self._affected = None
            return self.info
        if self.info is None:
            self.info = {r: {r: self.summaries[r]} for r in range(n)}
            self.reach = {}
            self.reach_key = {}
        dirty = [r for r in range(n)
                 if self.reach_key.get(r) != tuple(keys[r])]
        affected = update_peer_networks(
            self.summaries, self.info, self.reach, k_rounds=self.k_rounds,
            fanout=self.fanout, root_seeds=keys, dirty_roots=dirty,
            stats=self.counters)
        for r in dirty:
            self.reach_key[r] = tuple(keys[r])
        self._affected = affected
        return self.info

    # ---- stage 1b: work lists ----------------------------------------------

    def update_work_lists(self, info) -> Dict[int, deque]:
        """Caching twin of ``ccmlb.build_work_lists`` (engine path): keep
        the flat summary tables patched in place and each rank's sorted
        candidate list cached, re-scoring only ranks whose info content
        changed.  Valid because ``batch_peer_diffs`` reads nothing but
        the (r, peer) rows/segments, and the final ``(-diff, peer)`` sort
        canonicalizes any insertion-order difference."""
        n = self.n
        params = self.params
        counts_ok = self.tables is not None
        if counts_ok and self._cd:
            ip = self.tables.c_ids.indptr
            for r in self._cd:
                if len(self.csum[r]) != ip[r + 1] - ip[r]:
                    counts_ok = False     # cluster-count change shifts the
                    break                 # flat segment layout: rebuild
        if not counts_ok:
            self.tables = build_summary_tables(self.summaries, params)
            self._count("tables_rebuilds")
        else:
            t = self.tables
            for r in self._vd:
                s = self.summaries[r]
                t.load[r] = s.load
                t.vol_on[r] = s.vol_on
                t.vol_off[r] = s.vol_off
                t.homing[r] = s.homing
                t.mem_used[r] = s.mem_used
                # elementwise re-evaluation of the vectorized work
                # expression: same IEEE ops on the same float64 scalars,
                # including build_summary_tables' eq. 9 soft-cap barrier
                if (params.memory_constraint and t.mem_used[r]
                        > effective_mem_cap(t.mem_cap[r], params)):
                    t.work[r] = INF
                else:
                    t.work[r] = (params.alpha * t.load[r] / t.speed[r]
                                 + params.beta * t.vol_off[r]
                                 + params.gamma * t.vol_on[r]
                                 + params.delta * t.homing[r])
            ip = t.c_ids.indptr
            for r in self._cd:
                cl = self.csum[r]
                sl = slice(ip[r], ip[r + 1])
                t.c_load[sl] = [c.load for c in cl]
                t.c_mem[sl] = [c.mem for c in cl]
                t.c_block_bytes[sl] = [c.block_bytes for c in cl]
                t.c_vol_intra[sl] = [c.vol_intra for c in cl]
                t.c_vol_ext[sl] = [c.vol_ext for c in cl]
        if self.scores is None:
            self.scores = {}
            affected = list(range(n))
        elif self._affected is None:
            affected = list(range(n))
        else:
            affected = sorted(self._affected)
        for r in affected:
            self._rescore(r, info)
        self._count("worklist_rescored", len(affected))
        return {r: deque(self.scores[r]) for r in range(n)}

    def _rescore(self, r: int, info) -> None:
        t = self.tables
        peers = np.array([p for p in info[r] if p != r], dtype=np.int64)
        for p in peers:
            assert info[r][int(p)] is self.summaries[int(p)], \
                "info payload must alias the current summary object"
        diffs = batch_peer_diffs(t, r, peers, self.params)
        scored = [(float(d), int(p)) for d, p in zip(diffs, peers) if d > 0]
        scored.sort(key=lambda x: (-x[0], x[1]))
        self.scores[r] = scored
