"""Flat CSR/SoA view of a :class:`~repro.core.problem.Phase`.

The CCM evaluation hot path needs three adjacency structures over and over:

  * task -> incident communication edges  (update formulae, Thm III.1);
  * block -> member tasks                 (homing / shared-memory deltas);
  * rank -> member tasks                  (cluster build, batched scoring).

The seed implementation re-derived these with Python loops and
list-of-arrays at every call site.  This module stores each of them ONCE as
a pair of flat ``indptr``/``indices`` arrays (classic CSR), which

  * makes every traversal a vectorized gather instead of a Python loop;
  * is the layout a Pallas/JAX kernel can consume directly (contiguous,
    statically-shaped segments — see ROADMAP "Open items").

Everything here is immutable with respect to the *phase*: task→edge and
block→task adjacency never change during balancing (the balancer only moves
tasks between ranks).  Rank membership does change, so ``rank_segments`` is
a cheap function of the current assignment rather than a cached structure.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.problem import Phase

_EMPTY = np.zeros(0, np.int64)


@dataclasses.dataclass(frozen=True)
class CSR:
    """Rows of variable length stored as ``indices[indptr[i]:indptr[i+1]]``."""

    indptr: np.ndarray   # (R+1,) int64
    indices: np.ndarray  # (nnz,) int64

    @property
    def num_rows(self) -> int:
        return int(self.indptr.shape[0] - 1)

    def row(self, i: int) -> np.ndarray:
        return self.indices[self.indptr[i]:self.indptr[i + 1]]

    def row_lengths(self) -> np.ndarray:
        return np.diff(self.indptr)

    def gather(self, rows: np.ndarray) -> np.ndarray:
        """Concatenation of ``row(r) for r in rows`` without a Python loop."""
        rows = np.asarray(rows, np.int64)
        if rows.size == 0:
            return _EMPTY
        starts = self.indptr[rows]
        counts = self.indptr[rows + 1] - starts
        total = int(counts.sum())
        if total == 0:
            return _EMPTY
        # segment gather: out[j] = indices[starts[seg(j)] + offset_in_seg(j)]
        seg_ends = np.cumsum(counts)
        seg_base = np.repeat(seg_ends - counts, counts)
        idx = np.arange(total, dtype=np.int64) - seg_base \
            + np.repeat(starts, counts)
        return self.indices[idx]


def csr_from_groups(group: np.ndarray, payload: np.ndarray,
                    num_groups: int) -> CSR:
    """CSR with ``row(g) = payload[group == g]`` (payload order preserved
    within a row via a stable sort)."""
    group = np.asarray(group, np.int64)
    payload = np.asarray(payload, np.int64)
    order = np.argsort(group, kind="stable")
    counts = np.bincount(group, minlength=num_groups)
    indptr = np.zeros(num_groups + 1, np.int64)
    np.cumsum(counts, out=indptr[1:])
    return CSR(indptr, payload[order])


def build_task_edge_csr(phase: Phase) -> CSR:
    """task -> ids of incident comm edges (each edge listed once per distinct
    endpoint; a self-edge appears once under its task)."""
    not_self = phase.comm_dst != phase.comm_src
    eid = np.arange(phase.num_comms, dtype=np.int64)
    tasks = np.concatenate([phase.comm_src, phase.comm_dst[not_self]])
    eids = np.concatenate([eid, eid[not_self]])
    return csr_from_groups(tasks, eids, phase.num_tasks)


def build_block_task_csr(phase: Phase) -> CSR:
    """block -> member task ids (ascending within a block)."""
    has = phase.task_block >= 0
    tasks = np.nonzero(has)[0]
    return csr_from_groups(phase.task_block[has], tasks, phase.num_blocks)


def rank_segments(assignment: np.ndarray, num_ranks: int) -> CSR:
    """rank -> member task ids as sorted segments of one flat array."""
    assignment = np.asarray(assignment, np.int64)
    tasks = np.arange(assignment.shape[0], dtype=np.int64)
    return csr_from_groups(assignment, tasks, num_ranks)


@dataclasses.dataclass(frozen=True)
class PhaseCSR:
    """The frozen CSR bundle the evaluation engine reads.

    ``task_edges`` and ``block_tasks`` are valid for the lifetime of the
    phase; rank membership is derived on demand with :func:`rank_segments`.
    """

    task_edges: CSR    # task -> incident edge ids
    block_tasks: CSR   # block -> member task ids

    @staticmethod
    def from_phase(phase: Phase) -> "PhaseCSR":
        return PhaseCSR(task_edges=build_task_edge_csr(phase),
                        block_tasks=build_block_task_csr(phase))
