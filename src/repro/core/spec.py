"""Speculative lock-event scan: compile the stage-2 event loop.

PR 4 measured the wall this module removes: one XLA dispatch+sync costs as
much as the whole numpy scoring tree at default tile sizes, so per-event
host orchestration — not scoring — dominates the engine drivers.  The
synchronous round-robin driver has a property that makes a compiled fix
possible: its event sequence is DETERMINISTIC.  Locks are always granted
(no lock outlives a turn), deadlock-avoidance yields are structurally
unreachable, and release handoffs never fire, so the full ordered list of
(r, p) lock events of an iteration is derivable up front from the stage-1
work lists alone (:func:`event_sequence`), before any event is scored.

:func:`run_spec` exploits that: it speculatively captures a *window* of
upcoming events from the CURRENT (pre-window) state — shortlists via
``shortlist_pairs`` and raw flow-assembly inputs via
``PhaseEngine.spec_raw`` — and scores the whole window in ONE compiled
launch (``kernels/ccm_scorer/jit.py`` kind="spec": flow-matrix assembly,
feature derivation, the scorer expression tree, the work combine and the
selection rule all run in-trace).  The host then walks the window in event
order and commits winners, rolling back every event an earlier commit
invalidated:

  * ``dirty`` = ranks touched by transfers committed in this window;
  * the first event whose ranks intersect ``dirty`` is rolled back —
    its speculative shortlist/scores/clusters are stale — and so is every
    LATER event of the same instance, even rank-disjoint ones.  The
    strict-prefix cut is what keeps the committed event order equal to the
    reference event order (committing a later disjoint event before the
    rolled-back one re-runs would permute the transfer log);
  * rolled-back events re-enter the queue front, in order, and are
    re-captured against the post-commit state in the next window — except
    that an event rolled back ONLY by the prefix cut (its ranks disjoint
    from every committed transfer's) keeps its capture: nothing a
    transfer on other ranks mutates enters the capture, so the next
    window reuses it instead of re-running the host prep.  Validity is
    tracked per rank (version of the last transfer touching it); the
    reuse carries the same sub-ulp caveat as the batched driver's
    deferred events (a disjoint swap relabels third-rank vol entries
    without changing their true sums — see repro/core/ccmlb.py).

Committed prefixes therefore replay the exact reference event sequence,
and each committed event's inputs are exactly what the host engine driver
would have computed at that point — up to the compiled path's
summation-order ulps (numpy pairwise bincount vs XLA scatter-add), which
is why the whole path sits in the *compiled-vs-host* parity tier:
assignment identity asserted empirically (tests/test_spec_scan.py,
benchmarks), not bitwise f64.  The first event of each instance in every
window can never be rolled back, so every window makes progress and
termination is inherited from the (finite) event sequence.

The same machinery batches across INSTANCES: ``run_spec`` accepts many
:class:`SpecInstance` objects and fills each window round-robin (one event
per live instance per sweep), which is the vmapped fleet mode
(``core/fleet.py``).  Dirty sets, prefix cuts and commit order are all
per-instance, so an instance's committed sequence is always exactly its
solo event order, and a quiet window (no commits anywhere) never rolls
anything back — the common fleet steady state, where every launch scores
one event per instance and commits them all.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Callable, Deque, Dict, List, Optional, Tuple

import numpy as np

from repro.core.engine import ExchangeEvent, PhaseEngine
from repro.core.transfer import shortlist_pairs
from repro.kernels.ccm_scorer import jit as scorer_jit

__all__ = ["SpecInstance", "event_sequence", "run_spec"]


def event_sequence(num_ranks: int,
                   work_lists: Dict[int, deque]) -> List[Tuple[int, int]]:
    """The ordered (r, p) lock events the synchronous round-robin driver
    (``ccmlb._stage2``) executes for these work lists — derivable without
    scoring anything because on that driver every lock request is granted
    and every lock is released within its turn (yields and grant chains
    are structurally unreachable; see the module docstring).  Mirrors the
    driver exactly, including the spin budget.  Consumes the deques."""
    active = deque(r for r in range(num_ranks) if work_lists[r])
    seq: List[Tuple[int, int]] = []
    spins = 0
    max_spins = 50 * num_ranks + 1000
    while active and spins < max_spins:
        spins += 1
        r = active.popleft()
        if not work_lists[r]:       # unreachable like the driver's branch,
            continue                # but mirrored so the spin budget agrees
        _diff, p = work_lists[r].popleft()
        seq.append((r, p))
        if work_lists[r]:
            active.append(r)
    return seq


@dataclasses.dataclass
class SpecInstance:
    """One balance problem's slice of a speculative scan.

    ``queue`` holds the instance's remaining (r, p) events in reference
    order; ``rebuild`` is the post-transfer local cluster rebuild closure
    (``ccmlb._rebuild_local`` bound to this instance's state/clusters);
    ``stats`` only needs ``transfers``/``spec_rollbacks``/``spec_windows``
    counters (``ccmlb.ProtocolStats`` provides them).  ``trace``, when a
    list, records (window, kind, r, p) tuples with kind in {"transfer",
    "commit", "noop", "rollback"} — the rollback-safety property tests
    read it.  ``cache`` maps (r, p, state.version) to captured
    (shortlist, raw) preparations; pass a persistent dict ONLY when the
    cluster list objects are stable while the version is (the fleet driver
    guarantees this by reusing cluster lists across quiet iterations) —
    entries are value-exact because every cached quantity is a
    deterministic function of (state, clusters).
    """

    state: object
    engine: PhaseEngine
    clusters: Dict[int, list]
    stats: object
    rebuild: Callable[[int, int], None]
    queue: Deque[Tuple[int, int]]
    max_candidates: int = 12
    shortlist: int = 32
    trace: Optional[list] = None
    cache: Optional[dict] = None


def _prepare(inst: SpecInstance, r: int, p: int, a_lanes: int,
             b_lanes: int, p_n: int):
    """Speculatively capture event (r, p) from the instance's CURRENT
    state: the shortlist (identical to what the host driver's
    ``try_transfer`` would enumerate) and the ready-to-stack launch row
    with the pre-exchange work bound baked into its w_before slot.
    Returns (capture, raw) with raw = (row, eb) — capture is None for
    events with no candidate pairs (both ranks clusterless: a structural
    no-op)."""
    key = (r, p, inst.state.version)
    if inst.cache is not None:
        hit = inst.cache.get(key)
        if hit is not None:
            return hit
    cand_a, cand_b, pairs, agg_a, agg_b = shortlist_pairs(
        inst.state, inst.clusters[r], inst.clusters[p], r, p,
        inst.max_candidates, inst.shortlist, engine=inst.engine)
    if pairs.shape[0] == 0:
        entry = (None, None)
    else:
        ev = ExchangeEvent(r, p, cand_a, cand_b, pairs, agg_a, agg_b)
        row, eb = inst.engine.spec_raw(ev, a_lanes, b_lanes, p_n)
        row[-2] = max(inst.state.work(r), inst.state.work(p))   # w_before
        entry = ((cand_a, cand_b, pairs), (row, eb))
    if inst.cache is not None:
        inst.cache[key] = entry
    return entry


def run_spec(instances: List[SpecInstance], params, *, window: int,
             mode: str = "scan", fill: str = "disjoint") -> None:
    """Drain every instance's event queue through windowed compiled
    launches with strict-prefix commit/rollback (module docstring).
    Mutates the instances' states/clusters/stats in place.  ``params``
    must be the CCMParams the instances' states were built with — the
    launch rows bake their coefficient columns from ``state.params``.

    ``fill`` picks the speculation policy:

      * ``"disjoint"`` (default) — stop taking events from an instance's
        queue at the first event whose ranks overlap an event already
        taken from that instance this window.  A commit then can never
        dirty a later window event (dirty sets are per-instance and every
        taken prefix is pairwise rank-disjoint), so rollback is
        structurally impossible and large windows amortize the dispatch
        without speculation waste — the same disjointness argument the
        batched driver flushes on, minus the flush (untaken events just
        stay queued).
      * ``"greedy"`` — fill blindly; overlapping speculations roll back
        through the strict-prefix cut.  This keeps the rollback path
        load-bearing (the property tests drive it) and measures the
        speculation-waste trade the benchmark reports.
    """
    if window < 1:
        raise ValueError("spec window must be >= 1")
    if fill not in ("disjoint", "greedy"):
        raise ValueError("fill must be 'disjoint' or 'greedy'")
    a_lanes = b_lanes = scorer_jit.bucket_lanes(
        max(i.max_candidates for i in instances) + 1)
    # pair bucket pinned by the instances' knobs (same formula as
    # spec_warmup) so every launch row of the run shares one layout
    p_n = scorer_jit.bucket_pairs(max(
        min(i.max_candidates * (i.max_candidates + 2), i.shortlist)
        for i in instances))
    # captures held across windows for cut-but-disjoint rollbacks:
    # (id(inst), r, p) -> (version at capture, cap, raw), valid while no
    # committed transfer has touched r or p since the capture (tracked in
    # ``touched``: (id(inst), rank) -> version of the last commit there)
    held: Dict[Tuple[int, int, int], tuple] = {}
    touched: Dict[Tuple[int, int], int] = {}
    wid = 0
    while any(inst.queue for inst in instances):
        # ---- fill: round-robin one event per live instance per sweep, so
        # a window shared by many instances interleaves them fairly
        # (sweeps repeat until the window is full or every queue is dry;
        # under fill="disjoint" an instance also stops contributing at its
        # first rank overlap, leaving the event queued for the next window)
        entries: List[list] = []    # [inst, r, p, capture, raw, result]
        taken: Dict[int, set] = {}
        blocked: set = set()
        while len(entries) < window:
            took = False
            for inst in instances:
                if len(entries) >= window:
                    break
                if id(inst) in blocked or not inst.queue:
                    continue
                r, p = inst.queue[0]
                t = taken.setdefault(id(inst), set())
                if fill == "disjoint" and (r in t or p in t):
                    blocked.add(id(inst))
                    continue
                inst.queue.popleft()
                t.update((r, p))
                entries.append([inst, r, p, None, None, None])
                took = True
            if not took:
                break
        # ---- speculate: capture every entry from the pre-window state;
        # a valid held capture skips the host prep, and a held SCORE (the
        # launch already ran before the rollback) skips the launch slot
        # too.  Under fill="disjoint" rollback is impossible, so nothing
        # is ever held — skip the bookkeeping entirely on that path.
        raws, launch = [], []
        for idx, ent in enumerate(entries):
            inst, r, p = ent[0], ent[1], ent[2]
            if fill == "disjoint":
                cap, raw = _prepare(inst, r, p, a_lanes, b_lanes, p_n)
                res = None
            else:
                hkey = (id(inst), r, p)
                h = held.pop(hkey, None)
                if (h is not None
                        and touched.get((id(inst), r), -1) <= h[0]
                        and touched.get((id(inst), p), -1) <= h[0]):
                    cap, raw, res = h[1], h[2], h[3]
                else:
                    cap, raw = _prepare(inst, r, p, a_lanes, b_lanes, p_n)
                    res = None
                held[hkey] = (inst.state.version, cap, raw, None)
            ent[3] = cap
            ent[4] = raw
            ent[5] = res
            if cap is not None and res is None:
                raws.append(raw)
                launch.append(idx)
        # ---- one compiled launch over the whole window
        if raws:
            out = scorer_jit.score_spec(raws, a_lanes=a_lanes,
                                        b_lanes=b_lanes, p_n=p_n,
                                        mode=mode)
            for j, idx in enumerate(launch):
                entries[idx][5] = out[j]
        # ---- commit walk: strict per-instance prefix in window order
        dirty: Dict[int, set] = {}
        cut: Dict[int, bool] = {}
        deferred: Dict[int, List[Tuple[int, int]]] = {}
        seen: Dict[int, SpecInstance] = {}
        for ent in entries:
            inst, r, p, cap, _raw, res = ent
            key = id(inst)
            seen.setdefault(key, inst)
            d = dirty.setdefault(key, set())
            if cut.get(key) or r in d or p in d:
                # an earlier commit invalidated this speculation (or an
                # earlier rollback cut the prefix): roll back, re-queue —
                # keeping the computed score with the held capture, so a
                # still-valid (rank-disjoint) speculation re-commits next
                # window without re-running prep or launch
                cut[key] = True
                deferred.setdefault(key, []).append((r, p))
                h = held.get((key, r, p))
                if h is not None and h[1] is cap:
                    held[(key, r, p)] = (h[0], cap, _raw, res)
                inst.stats.spec_rollbacks += 1
                if inst.trace is not None:
                    inst.trace.append((wid, "rollback", r, p))
                continue
            if cap is None:
                if inst.trace is not None:
                    inst.trace.append((wid, "noop", r, p))
                continue
            score = res[1]
            if np.isfinite(score):
                cand_a, cand_b, pairs = cap
                k = int(res[0])
                ia, ib = int(pairs[k, 0]), int(pairs[k, 1])
                inst.state.swap(cand_a[ia], r, cand_b[ib], p)
                inst.stats.transfers += 1
                inst.rebuild(r, p)
                d.update((r, p))
                touched[(key, r)] = touched[(key, p)] = inst.state.version
                if inst.trace is not None:
                    inst.trace.append((wid, "transfer", r, p))
            elif inst.trace is not None:
                inst.trace.append((wid, "commit", r, p))
        for key, dq in deferred.items():
            if dq:      # re-enter at the queue FRONT, preserving order
                seen[key].queue.extendleft(reversed(dq))
        for key in seen:
            seen[key].stats.spec_windows += 1
        wid += 1
