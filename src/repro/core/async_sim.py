"""Asynchronous distributed event-loop simulator for CCM-LB (paper §IV-B).

Why this exists: the synchronous driver in :mod:`repro.core.ccmlb` runs
the lock/transfer stage as a round-robin loop in which every lock is
released within the turn that took it — lock conflicts, deadlock-avoidance
yields and grant chains are structurally unreachable there, so the §IV-B
protocol machinery was only ever exercised by direct unit tests.  This
module drives the SAME shared handlers (``lock_request`` / ``note_yield``
/ ``lock_release`` / ``execute_transfer`` — see "two drivers, one
protocol" in repro/core/ccmlb.py) through a seeded discrete-event
simulation with per-rank mailboxes and a configurable message-latency
distribution, in the spirit of asynchronous diffusion-style balancers on
arbitrary networks (arXiv:1308.0148): concurrent lock requests collide,
``must_yield`` fires, queued requests drain through real grant chains,
and gossip arrives in latency-permuted (optionally deadline-dropped,
i.e. stale) order.

Event <-> paper mapping (§IV, Fig. 1)
-------------------------------------
  ``GOSSIP``    lines 24–30 (BuildPeerNetwork): a rank's accumulated
                ``info_known`` snapshot in flight to a fanout peer; the
                recipient merges it (dedupe: repro/core/gossip.py) and,
                below ``k_rounds``, forwards to peers the message has not
                visited.  Same messages, same rng, same merge rule as the
                synchronous epidemic — only the delivery schedule differs.
  ``DECIDE``    line 41's while-loop head: the rank's local scheduler pops
                the best remaining peer off its stage-1 work list and
                issues a lock request.  Not a network message (priority
                class LOCAL, see below).
  ``LOCK_REQ``  line 42 (requestLock): arrives at the target's mailbox;
                a free target locks itself to the requester and answers
                with ``GRANT``; a busy target queues the request FIFO —
                one *lock conflict*.
  ``GRANT``     line 43: the lock is held from the moment the target
                granted it (REQ receipt or release handoff) until the
                holder's ``RELEASE`` arrives back.  A grant arriving at a
                rank that is itself locked by ``r_x <= target`` triggers
                the line-45 deadlock-avoidance *yield*: release unused,
                re-queue the attempt (bounded by ``max_retries``).
  transfer      lines 46–48 (recvUpdate / TryTransfer / sendUpdate): the
                holder evaluates exactly with fresh info at grant-receipt
                time and executes the best positive exchange.
  ``RELEASE``   line 49 (releaseLock): frees the target; a queued
                requester is granted next — consecutive handoffs on one
                target form a *grant chain* (lengths are accounted in
                ``ProtocolStats`` / ``CCMLBResult.max_grant_chain``).

Determinism and the zero-latency parity bar
-------------------------------------------
All scheduling runs through one binary heap keyed ``(time, class, seq)``:
``seq`` is a global creation counter, so ties at equal time break
deterministically in creation order, and message events (class 0) always
precede local DECIDE timers (class 1) at the same timestamp.  Latency
draws come from a dedicated seeded stream, gossip peer picks from the
same per-iteration stream the synchronous driver uses — the whole run is
a pure function of ``(phase, params, seed, latency, ...)`` (determinism
asserted in tests/test_async_protocol.py).

With zero latency this schedule *serializes*: a DECIDE's entire
REQ→GRANT→transfer→RELEASE cascade lands at the same timestamp and class
0, so it drains before the next rank's DECIDE — exactly the synchronous
driver's round-robin turn order.  No lock then ever outlives a turn, no
conflict/yield/chain fires, and the trajectory (assignment, transfer
sequence, traces) is bitwise-identical to ``ccm_lb`` (asserted in
tests/test_async_sim.py and benchmarks/ccmlb_async.py).  Under nonzero
latency the interleaving is arbitrary-but-seeded; safety and liveness
invariants are property-tested in tests/test_async_protocol.py.

Differences from the synchronous driver, by design:

  * a requester whose LOCK_REQ is queued WAITS for the eventual grant
    (the sync loop re-queues a halved-priority retry instead — it gets
    an immediate boolean answer, a message protocol does not);
  * a yield re-queues the attempt at most ``max_retries`` times, bounding
    total work (the sync loop re-queues unboundedly; its yield branch is
    unreachable so termination never depended on it);
  * ``batch_lock_events`` stays a synchronous-driver knob: deferred
    disjoint-event scoring relies on the turn order being independent of
    scoring outcomes, which no longer holds once grants interleave.
"""
from __future__ import annotations

import heapq
from collections import deque
from typing import Callable, Dict, List, Optional

import numpy as np

from repro.core.ccm import CCMState
from repro.core.ccmlb import (CCMLBResult, ProtocolStats, build_work_lists,
                              ccm_lb, execute_transfer, iteration_summaries,
                              lock_release, lock_request, note_yield)
from repro.core.engine import PhaseEngine
from repro.core.gossip import gossip_deliver, pick_peers
from repro.core.locks import LockManager
from repro.core.problem import CCMParams, Phase

__all__ = ["ccm_lb_async", "run_ccm_lb", "make_latency", "EVENT_KINDS"]

# event kinds (values appear in traces; names in EVENT_KINDS)
GOSSIP, LOCK_REQ, GRANT, RELEASE, DECIDE = range(5)
EVENT_KINDS = ("GOSSIP", "LOCK_REQ", "GRANT", "RELEASE", "DECIDE")

# priority classes: messages always beat same-time local DECIDE timers —
# this is what serializes the zero-latency schedule into sync turn order
_MSG, _LOCAL = 0, 1


def make_latency(spec) -> Callable:
    """Normalize a latency spec into ``fn(rng, src, dst) -> float``.

    Accepted specs: ``None``/``0``/``"zero"`` (the serialized schedule),
    a non-negative float (constant), ``("uniform", lo, hi)``,
    ``("exp", scale)``, or a callable ``(rng, src, dst) -> float``.
    """
    if spec is None or spec == "zero":
        return lambda rng, s, d: 0.0
    if callable(spec):
        return spec
    if isinstance(spec, (int, float)):
        v = float(spec)
        if v < 0:
            raise ValueError(f"latency must be >= 0, got {v}")
        return lambda rng, s, d: v
    if isinstance(spec, (tuple, list)) and spec:
        if spec[0] == "uniform" and len(spec) == 3:
            lo, hi = float(spec[1]), float(spec[2])
            if not 0 <= lo <= hi:
                raise ValueError(f"bad uniform latency bounds: {spec!r}")
            return lambda rng, s, d: float(rng.uniform(lo, hi))
        if spec[0] == "exp" and len(spec) == 2:
            scale = float(spec[1])
            if scale < 0:
                raise ValueError(f"bad exp latency scale: {spec!r}")
            return lambda rng, s, d: float(rng.exponential(scale))
    raise ValueError(f"unknown latency spec: {spec!r}")


class _Sim:
    """The event queue + clock: per-rank mailboxes collapse into one heap
    because an entry's ``dst`` IS the mailbox.  Latencies are drawn per
    message, so messages may overtake each other both across AND within a
    link — e.g. a rank's retry LOCK_REQ to ``p`` can arrive before its
    own earlier RELEASE of ``p``, in which case the requester queues
    behind itself and is later granted via its own release; the handlers
    tolerate this, and the protocol must stay safe under any such
    interleaving (the property suite's job).  Only constant latency gives
    per-link FIFO delivery (equal delays + ``(time, class, seq)``
    tie-break in send order)."""

    def __init__(self, latency_fn, rng, max_events: int,
                 trace: Optional[list]):
        self.heap: list = []
        self.seq = 0
        self.now = 0.0
        self.messages = 0          # delivered network messages
        self.processed = 0
        self.max_events = max_events
        self.latency = latency_fn
        self.rng = rng
        self.trace = trace

    def push(self, time: float, klass: int, kind: int, src: int, dst: int,
             data=None) -> None:
        heapq.heappush(self.heap, (time, klass, self.seq, kind, src, dst,
                                   data))
        self.seq += 1

    def send(self, kind: int, src: int, dst: int, data=None) -> None:
        """Network send: delivery at now + one seeded latency draw."""
        self.push(self.now + self.latency(self.rng, src, dst), _MSG, kind,
                  src, dst, data)

    def pop(self):
        time, klass, seq, kind, src, dst, data = heapq.heappop(self.heap)
        self.now = time
        self.processed += 1
        if self.processed > self.max_events:
            raise RuntimeError(
                f"async sim exceeded {self.max_events} events — "
                "protocol liveness bug (a message loop that never drains)")
        if klass == _MSG:
            self.messages += 1
        if self.trace is not None:
            self.trace.append((time, seq, EVENT_KINDS[kind], src, dst))
        return time, kind, src, dst, data


def _run_gossip(sim: _Sim, summaries, info, *, k_rounds: int, fanout: int,
                seed: int, deadline: Optional[float]) -> int:
    """Stage 1a: the augmented-inform epidemic as latency-delayed messages.

    Same message set, rng stream and merge/dedupe rule as the synchronous
    ``build_peer_networks(seed=...)`` — at zero latency the heap pops in
    creation order, which IS the synchronous round order, so the resulting
    ``info`` maps are identical.  Nonzero latency permutes delivery (and
    therefore the forward peer picks); a ``deadline`` drops deliveries
    that arrive too late to inform this iteration's scoring — stale
    gossip made observable.  Returns the number of dropped deliveries.
    """
    n = len(summaries)
    rng = np.random.default_rng(seed)
    dropped = 0
    if k_rounds >= 1:
        for r in range(n):
            peers = pick_peers(rng, n, r, fanout, visited={r})
            snap = dict(info[r])        # shared: payloads are read-only
            for p in peers:
                sim.send(GOSSIP, r, int(p),
                         (1, frozenset([r]) | {int(p)}, snap))
    while sim.heap:
        time, kind, src, dst, data = sim.pop()
        assert kind == GOSSIP
        rnd, visited, payload = data
        if deadline is not None and time > deadline:
            dropped += 1                # arrived stale: no merge, no forward
            continue
        if not gossip_deliver(info[dst], payload):
            continue
        if rnd < k_rounds:
            peers = pick_peers(rng, n, dst, fanout, visited=set(visited))
            snap = dict(info[dst])
            for p in peers:
                sim.send(GOSSIP, dst, int(p),
                         (rnd + 1, frozenset(visited) | {int(p)}, snap))
    return dropped


def _run_stage2(sim: _Sim, phase, state, clusters, work_lists, engine,
                locks: LockManager, stats: ProtocolStats, *,
                max_candidates: int, max_clusters_per_rank,
                max_retries: int, on_event) -> None:
    """Stage 2: the lock/transfer protocol as mailbox events (see the
    module docstring for the event <-> Fig. 1 mapping)."""
    n = phase.num_ranks
    waiting = [False] * n        # sent LOCK_REQ, grant not yet received
    attempt: List[Optional[tuple]] = [None] * n   # (diff, p) in flight
    retries: List[Dict[int, int]] = [dict() for _ in range(n)]
    spins = 0
    max_spins = 50 * n + 1000    # mirrors the sync driver's turn cap

    for r in range(n):
        if work_lists[r]:
            sim.push(sim.now, _LOCAL, DECIDE, r, r)

    while sim.heap:
        time, kind, src, dst, data = sim.pop()
        if kind == DECIDE:
            r = dst
            assert not waiting[r], f"rank {r} decided while awaiting a grant"
            if spins >= max_spins or not work_lists[r]:
                continue
            spins += 1
            diff, p = work_lists[r].popleft()
            waiting[r] = True
            attempt[r] = (diff, p)
            sim.send(LOCK_REQ, r, p)
        elif kind == LOCK_REQ:
            r, p = src, dst
            if lock_request(locks, stats, r, p):
                sim.send(GRANT, p, r)
            # else: queued FIFO at p — the grant arrives on a release
        elif kind == GRANT:
            p, r = src, dst
            assert waiting[r], f"rank {r} granted without an open request"
            waiting[r] = False
            diff, p_req = attempt[r]
            attempt[r] = None
            assert p_req == p
            if locks.must_yield(r, p):
                # Fig. 1 line 45: release unused, retry later (bounded —
                # unlike the sync driver's unbounded re-queue, so a yield
                # storm cannot stall termination)
                note_yield(stats)
                cnt = retries[r].get(p, 0)
                if cnt < max_retries:
                    retries[r][p] = cnt + 1
                    work_lists[r].append((diff, p))
            else:
                # mutation under mutual exclusion: r must be p's holder of
                # record for the whole (instantaneous) evaluation
                assert locks.locked_by[p] == r
                execute_transfer(state, clusters, engine, stats, r, p,
                                 max_candidates, max_clusters_per_rank)
            sim.send(RELEASE, r, p)
            if work_lists[r]:
                sim.push(sim.now, _LOCAL, DECIDE, r, r)
        elif kind == RELEASE:
            r, p = src, dst
            nxt = lock_release(locks, stats, r, p)
            if nxt is not None:
                sim.send(GRANT, p, nxt)
        else:  # pragma: no cover - defensive
            raise AssertionError(f"unknown event kind {kind}")
        if on_event is not None:
            on_event(time, kind, src, dst, locks, state)

    # liveness at termination: every request answered, every lock released
    assert not any(waiting), "rank still awaiting a grant at termination"
    assert locks.quiescent(), "locks/queues not drained at termination"


def ccm_lb_async(phase: Phase, assignment: np.ndarray, params: CCMParams, *,
                 n_iter: int = 4, k_rounds: int = 2, fanout: int = 4,
                 seed: int = 0, latency=0.0,
                 gossip_timeout: Optional[float] = None,
                 max_retries: int = 4, max_candidates: int = 12,
                 max_clusters_per_rank: Optional[int] = None,
                 use_engine: bool = True, backend: str = "numpy",
                 incremental: bool = True, csr=None,
                 collect_trace: bool = False,
                 max_events: Optional[int] = None,
                 on_event=None) -> CCMLBResult:
    """CCM-LB through the asynchronous event-loop driver.

    Same optimization knobs as :func:`repro.core.ccmlb.ccm_lb` (engine /
    backend / incremental / csr), plus the simulation knobs:

    ``latency``         message-latency spec (see :func:`make_latency`).
                        The default ``0.0`` is the serialized schedule —
                        bitwise-identical trajectories to ``ccm_lb``.
    ``gossip_timeout``  per-iteration gossip deadline in sim-time units;
                        deliveries past it are dropped (stale).  ``None``
                        drains the epidemic fully.
    ``max_retries``     per-(rank, peer) bound on yield re-queues.
    ``collect_trace``   record the ``(time, seq, kind, src, dst)`` event
                        trace into ``CCMLBResult.events``.
    ``on_event``        optional hook ``(time, kind, src, dst, locks,
                        state)`` called after every stage-2 event — the
                        protocol-safety suite's invariant probe.

    Iterations stay globally synchronized (the paper's outer loop);
    asynchrony lives inside each iteration's gossip and lock/transfer
    stages.  ``CCMLBResult.lock_conflicts`` / ``yields`` /
    ``grant_chains`` / ``max_grant_chain`` are meaningful here, and
    ``transfer_log`` replays onto the initial assignment to the returned
    one exactly.
    """
    state = CCMState.build(phase, assignment, params, csr=csr)
    engine = (PhaseEngine(state, backend=backend, incremental=incremental)
              if use_engine else None)
    transfer_log: list = []
    state.add_transfer_listener(
        lambda t, a, b: transfer_log.append(
            (tuple(int(x) for x in t), int(a), int(b))))

    latency_fn = make_latency(latency)
    rng_lat = np.random.default_rng([seed, 0x51D])   # latency-draw stream
    if max_events is None:
        # DECIDEs are spin-capped, each spawns <= 3 protocol messages,
        # gossip is <= n * fanout**k_rounds per iteration; x8 headroom
        max_events = 8 * n_iter * (
            4 * (50 * phase.num_ranks + 1000)
            + phase.num_ranks * max(fanout, 1) ** max(k_rounds, 1))
    trace: Optional[list] = [] if collect_trace else None
    sim = _Sim(latency_fn, rng_lat, max_events, trace)
    stats = ProtocolStats()
    gossip_dropped = 0

    trace_max = [state.max_work()]
    trace_tot = [state.total_work()]
    trace_imb = [state.imbalance()]

    for it in range(n_iter):
        clusters, summaries = iteration_summaries(state, phase,
                                                  max_clusters_per_rank)
        info = {r: {r: summaries[r]} for r in range(phase.num_ranks)}
        deadline = (None if gossip_timeout is None
                    else sim.now + gossip_timeout)
        gossip_dropped += _run_gossip(
            sim, summaries, info, k_rounds=k_rounds, fanout=fanout,
            seed=seed * 1000 + it, deadline=deadline)
        work_lists = build_work_lists(phase, summaries, info, params, engine)
        locks = LockManager(phase.num_ranks)
        _run_stage2(sim, phase, state, clusters, work_lists, engine, locks,
                    stats, max_candidates=max_candidates,
                    max_clusters_per_rank=max_clusters_per_rank,
                    max_retries=max_retries, on_event=on_event)

        trace_max.append(state.max_work())
        trace_tot.append(state.total_work())
        trace_imb.append(state.imbalance())

    return CCMLBResult(state.assignment.copy(), state, trace_max, trace_tot,
                       trace_imb, stats.transfers, stats.conflicts,
                       engine_used=engine is not None, yields=stats.yields,
                       grant_chains=stats.grant_chains,
                       max_grant_chain=stats.max_grant_chain,
                       messages=sim.messages, sim_time=sim.now,
                       gossip_dropped=gossip_dropped, events=trace,
                       transfer_log=transfer_log)


def run_ccm_lb(phase, a0, params, *, async_mode: bool = False, latency=0.0,
               gossip_timeout=None, batch_lock_events: int = 1,
               spec_window: int = 1, spec_mode: str = "scan",
               **kw) -> CCMLBResult:
    """Dispatch one balancing run to the synchronous driver or — with
    ``async_mode=True`` — to this module's event-loop simulator, which
    models message latency and makes the §IV-B conflict/yield/chain
    counters on the returned ``CCMLBResult`` meaningful.  Used by the
    ``repro.balance`` planners to expose the async knobs uniformly.
    ``batch_lock_events`` and ``spec_window`` are synchronous-driver knobs
    (the async turn order depends on grant interleavings, so neither the
    deferred disjoint-event batching nor the speculative scan — whose
    event sequence must be derivable up front — applies there); conversely
    ``latency`` / ``gossip_timeout`` only exist under ``async_mode=True``
    — either inconsistency raises instead of silently dropping the
    knob."""
    if not async_mode:
        if not (latency is None or latency == 0.0 or latency == "zero"):
            raise ValueError("latency is an async-driver knob; pass "
                             "async_mode=True to simulate message latency")
        if gossip_timeout is not None:
            raise ValueError("gossip_timeout is an async-driver knob; pass "
                             "async_mode=True")
        return ccm_lb(phase, a0, params, batch_lock_events=batch_lock_events,
                      spec_window=spec_window, spec_mode=spec_mode, **kw)
    if batch_lock_events != 1:
        raise ValueError("batch_lock_events is a synchronous-driver knob; "
                         "unsupported with async_mode=True")
    if spec_window != 1:
        raise ValueError("spec_window is a synchronous-driver knob (the "
                         "async event sequence is not derivable up front); "
                         "unsupported with async_mode=True")
    return ccm_lb_async(phase, a0, params, latency=latency,
                        gossip_timeout=gossip_timeout, **kw)
