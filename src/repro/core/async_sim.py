"""Asynchronous distributed event-loop simulator for CCM-LB (paper §IV-B).

Why this exists: the synchronous driver in :mod:`repro.core.ccmlb` runs
the lock/transfer stage as a round-robin loop in which every lock is
released within the turn that took it — lock conflicts, deadlock-avoidance
yields and grant chains are structurally unreachable there, so the §IV-B
protocol machinery was only ever exercised by direct unit tests.  This
module drives the SAME shared handlers (``lock_request`` / ``note_yield``
/ ``lock_release`` / ``execute_transfer`` — see "two drivers, one
protocol" in repro/core/ccmlb.py) through a seeded discrete-event
simulation with per-rank mailboxes and a configurable message-latency
distribution, in the spirit of asynchronous diffusion-style balancers on
arbitrary networks (arXiv:1308.0148): concurrent lock requests collide,
``must_yield`` fires, queued requests drain through real grant chains,
and gossip arrives in latency-permuted (optionally deadline-dropped,
i.e. stale) order.

Event <-> paper mapping (§IV, Fig. 1)
-------------------------------------
  ``GOSSIP``    lines 24–30 (BuildPeerNetwork): a rank's accumulated
                ``info_known`` snapshot in flight to a fanout peer; the
                recipient merges it (dedupe: repro/core/gossip.py) and,
                below ``k_rounds``, forwards to peers the message has not
                visited.  Same messages, same rng, same merge rule as the
                synchronous epidemic — only the delivery schedule differs.
  ``DECIDE``    line 41's while-loop head: the rank's local scheduler pops
                the best remaining peer off its stage-1 work list and
                issues a lock request.  Not a network message (priority
                class LOCAL, see below).
  ``LOCK_REQ``  line 42 (requestLock): arrives at the target's mailbox;
                a free target locks itself to the requester and answers
                with ``GRANT``; a busy target queues the request FIFO —
                one *lock conflict*.
  ``GRANT``     line 43: the lock is held from the moment the target
                granted it (REQ receipt or release handoff) until the
                holder's ``RELEASE`` arrives back.  A grant arriving at a
                rank that is itself locked by ``r_x <= target`` triggers
                the line-45 deadlock-avoidance *yield*: release unused,
                re-queue the attempt (bounded by ``max_retries``).
  transfer      lines 46–48 (recvUpdate / TryTransfer / sendUpdate): the
                holder evaluates exactly with fresh info at grant-receipt
                time and executes the best positive exchange.
  ``RELEASE``   line 49 (releaseLock): frees the target; a queued
                requester is granted next — consecutive handoffs on one
                target form a *grant chain* (lengths are accounted in
                ``ProtocolStats`` / ``CCMLBResult.max_grant_chain``).
  ``TIMEOUT``   fault-hardening only (local timer at the requester): a
                lock request unanswered for ``FaultSpec.req_timeout`` is
                aborted (a RELEASE closes whatever state it reached) and
                retried with exponential backoff, bounded by
                ``max_retries``.  Never scheduled on a fault-free run.
  ``FAIL``      a ``FaultSpec.kill`` firing: the rank dies mid-iteration.
                Its queued requests are purged, locks it held are
                force-released (granting to the next live requester), its
                own lock table is reclaimed, and after the stage the
                survivor set is warm-started (see "Fault injection").

Determinism and the zero-latency parity bar
-------------------------------------------
All scheduling runs through one binary heap keyed ``(time, class, seq)``:
``seq`` is a global creation counter, so ties at equal time break
deterministically in creation order, and message events (class 0) always
precede local DECIDE timers (class 1) at the same timestamp.  Latency
draws come from a dedicated seeded stream, gossip peer picks from the
same per-iteration stream the synchronous driver uses — the whole run is
a pure function of ``(phase, params, seed, latency, fault, ...)``
(determinism asserted in tests/test_async_protocol.py).

With zero latency this schedule *serializes*: a DECIDE's entire
REQ→GRANT→transfer→RELEASE cascade lands at the same timestamp and class
0, so it drains before the next rank's DECIDE — exactly the synchronous
driver's round-robin turn order.  No lock then ever outlives a turn, no
conflict/yield/chain fires, and the trajectory (assignment, transfer
sequence, traces) is bitwise-identical to ``ccm_lb`` (asserted in
tests/test_async_sim.py and benchmarks/ccmlb_async.py).  Under nonzero
latency the interleaving is arbitrary-but-seeded; safety and liveness
invariants are property-tested in tests/test_async_protocol.py.

Fault injection
---------------
``ccm_lb_async(fault=FaultSpec(...))`` degrades the network and the
ranks themselves, seeded and per-link:

  * ``drop`` / ``dup`` / ``reorder`` — per-message probabilities (float,
    ``{(src, dst): p}`` dict, or ``fn(src, dst) -> p``) applied to every
    network send, gossip included; a reordered or duplicated copy is
    delayed by an extra Exp(``reorder_scale``) draw;
  * ``pause`` — ``(rank, iteration, start, end)`` windows (sim-time
    relative to that iteration's stage-2 start) during which every event
    addressed to the rank is deferred to the window's end;
  * ``kill`` — ``(rank, iteration, offset)`` dies at stage-2 start +
    offset; ``(rank, iteration, offset, stage)`` with ``stage=1`` dies
    mid-epidemic, offset from the ITERATION (gossip) start.  Dead ranks
    stay dead: messages to them vanish, messages they sent before dying
    still deliver, and a root dying mid-flood neither wedges the
    epidemic (live ranks keep forwarding its already-spread summary)
    nor poisons the epoch-keyed quiesce replay (the tracker purges the
    dead rank from every cache family — see ``QuiesceTracker.
    purge_ranks``);
  * ``partition`` — ``(ranks_a, ranks_b, iteration, start, end)``
    link-level BIDIRECTIONAL outages: every message crossing between
    the two groups while the window (sim-time relative to that
    iteration's gossip start) is open is destroyed, at send or at
    delivery time, splitting the mesh into islands.  Islands keep
    making local progress: a rank deciding on a peer it cannot
    currently reach skips the doomed request outright instead of
    burning a ``req_timeout`` wait (counted ``partition_skips``,
    bounded by the same per-(rank, peer) retry budget as yields), so
    intra-island transfers proceed at full speed.  After the window
    heals, the next iteration's gossip re-merges the islands — fresh
    summaries flood globally, work lists span the whole mesh again —
    without ever having violated mutual exclusion or the transfer-log
    replay invariant (cross-island lock requests either never arrived
    or timed out and were aborted/reclaimed like any lost message);
  * ``corrupt`` — per-link probability of mutating a gossip payload in
    flight (a seeded choice of flipped load, truncated cluster list,
    or stale epoch stamp — always on a COPY; the shared payload object
    is never touched).  Receivers validate a checksum
    (``repro.core.gossip.summary_checksum``) and an iteration stamp on
    every delivery and QUARANTINE mismatches — counted
    ``corrupt_quarantined``, no merge, no forward — so a corrupted
    summary can never enter a work list; the root's clean epidemic
    keeps spreading through other paths.

Membership is the inverse degradation: ``ccm_lb_async(membership=
(RankJoin(iteration=k, count=m), ...))`` grows the mesh mid-stream.
At iteration ``k`` the phase is expanded (``repro.runtime.elastic.
expand_phase`` — fresh ranks default to median capacity/speed), the
state/engine are rebuilt on the wider rank set (the CSR bundle is
rank-independent and carries over), and the tracker is re-grown
(``QuiesceTracker.regrow``).  The joined ranks inherit gossip state
through the ordinary epidemic flood of their first iteration and,
starting empty, attract transfers like any underloaded rank — the
rebalance IS the protocol, no side channel.  Joined ranks are recorded
in ``CCMLBResult.joined_ranks``.

The protocol survives by construction, not by luck: every LOCK_REQ
carries a unique ``req_id`` token that travels REQ→GRANT→RELEASE, making
duplicate requests, stale grants and stale releases token-checked no-ops
(repro/core/locks.py); unanswered requests time out, abort and retry
with bounded exponential backoff; locks wedged by dropped RELEASEs are
reclaimed at the stage-end barrier (safe: an open request always keeps a
TIMEOUT queued, so an empty heap means no live requester is waiting);
dead ranks' lock state is reclaimed at death and the survivor set is
re-warm-started through ``repro.core.pipeline.warm_start_assignment``
over ``repro.runtime.elastic.survivor_resize``'s renumbering — the same
elastic-resize framing a mesh shrink uses.  Killing every rank raises
:class:`repro.runtime.fault.RankDeath` (a ``NodeFailure``), handing the
problem to the checkpoint-restart layer where it belongs.

The parity bar under faults: a ``fault=None`` or all-inactive
``FaultSpec`` run is BITWISE-identical to the fault-free driver (no
extra events, no extra rng draws, same trace); an active fault changes
trajectories but never invariants — at most one live lock per rank,
transfers only under mutual exclusion and never to/from dead ranks,
transfer-log replay == final assignment, quiescent termination
(tests/test_async_protocol.py).  ``quiesce_after`` respects pending
faults and joins: the quiet counter only advances while no partition or
pause window is open and no kill/join is still scheduled, so early
termination cannot race a scheduled perturbation.

Differences from the synchronous driver, by design:

  * a requester whose LOCK_REQ is queued WAITS for the eventual grant
    (the sync loop re-queues a halved-priority retry instead — it gets
    an immediate boolean answer, a message protocol does not);
  * a yield re-queues the attempt at most ``max_retries`` times, bounding
    total work (the sync loop re-queues unboundedly; its yield branch is
    unreachable so termination never depended on it).  Work items dropped
    at the cap are counted in ``retries_exhausted`` — never silently;
  * ``batch_lock_events`` stays a synchronous-driver knob: deferred
    disjoint-event scoring relies on the turn order being independent of
    scoring outcomes, which no longer holds once grants interleave.
"""
from __future__ import annotations

import dataclasses
import heapq
import itertools
from time import perf_counter
from typing import Callable, Dict, List, Optional, Set, Tuple

import numpy as np

from repro.core.ccm import CCMState, effective_mem_cap
from repro.core.ccmlb import (CCMLBResult, ProtocolStats, build_work_lists,
                              ccm_lb, execute_transfer, lock_release,
                              lock_request, note_yield)
from repro.core.engine import PhaseEngine
from repro.core.gossip import (gossip_deliver, gossip_root_key, pick_peers,
                               summary_checksum)
from repro.core.locks import LockManager
from repro.core.pipeline import warm_start_assignment
from repro.core.problem import CCMParams, Phase
from repro.core.quiesce import QuiesceTracker
from repro.runtime.elastic import RankJoin, expand_phase, survivor_resize
from repro.runtime.fault import RankDeath

__all__ = ["ccm_lb_async", "run_ccm_lb", "make_latency", "EVENT_KINDS",
           "FaultSpec", "FaultStats", "LivelockError", "RankJoin",
           "RecoveryOOMError"]

# event kinds (values appear in traces; names in EVENT_KINDS).  TIMEOUT
# and FAIL only ever fire under an active FaultSpec — the first five
# values are pinned so fault-free traces stay bitwise-comparable across
# versions.
GOSSIP, LOCK_REQ, GRANT, RELEASE, DECIDE, TIMEOUT, FAIL = range(7)
EVENT_KINDS = ("GOSSIP", "LOCK_REQ", "GRANT", "RELEASE", "DECIDE",
               "TIMEOUT", "FAIL")

# priority classes: messages always beat same-time local DECIDE timers —
# this is what serializes the zero-latency schedule into sync turn order
_MSG, _LOCAL = 0, 1


class LivelockError(RuntimeError):
    """The event budget ran out before the protocol drained.

    Structured so fault sweeps can report WHY a config livelocked instead
    of losing all accumulated accounting: ``processed`` / ``queued`` /
    ``sim_time`` are set at raise time inside the event loop;
    :func:`ccm_lb_async` enriches the in-flight exception with the
    partial ``stats`` (:class:`~repro.core.ccmlb.ProtocolStats`),
    ``fault_stats`` and the ``iteration`` it died in before re-raising.
    Subclasses ``RuntimeError`` with "events" in the message, so guards
    written against the old bare error keep matching.
    """

    def __init__(self, max_events: int, processed: int, queued: int,
                 sim_time: float):
        super().__init__(
            f"async sim exceeded {max_events} events — protocol liveness "
            f"bug or fault storm ({processed} processed, {queued} still "
            f"queued at sim time {sim_time:.3f})")
        self.max_events = max_events
        self.processed = processed
        self.queued = queued
        self.sim_time = sim_time
        self.stats: Optional[ProtocolStats] = None
        self.fault_stats: Optional["FaultStats"] = None
        self.iteration: Optional[int] = None


class RecoveryOOMError(RuntimeError):
    """Crash recovery found no survivor with memory room for a stranded
    task group.

    Raised by :func:`_recover_survivors` under an active memory
    constraint when every survivor's post-placement M_max (eq. 7) would
    exceed its (headroom-scaled) cap — the cluster genuinely cannot
    absorb the dead rank's working set and must shed load or restart
    from a checkpoint on more ranks.  Carries the stranded ``tasks``
    (tuple of task ids), the ``dead_rank`` they were on, and
    ``overflow_bytes``: the smallest cap excess across survivors, i.e.
    how much memory the least-bad placement still lacked.
    """

    def __init__(self, tasks, dead_rank: int, overflow_bytes: float):
        super().__init__(
            f"crash recovery OOM: no survivor can hold {len(tasks)} "
            f"task(s) stranded on dead rank {dead_rank} — best placement "
            f"still {overflow_bytes:.3e} bytes over its memory cap")
        self.tasks = tuple(int(t) for t in tasks)
        self.dead_rank = int(dead_rank)
        self.overflow_bytes = float(overflow_bytes)


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """Seeded fault model for the async driver (see module docstring).

    ``drop`` / ``dup`` / ``reorder`` / ``corrupt`` accept a float
    probability, a per-link ``{(src, dst): p}`` dict (unlisted links are
    fault-free), or a callable ``(src, dst) -> p``; ``corrupt`` applies
    to gossip payloads only (protocol messages carry tokens, not
    summaries).  ``pause`` entries are ``(rank, iteration, start, end)``,
    ``kill`` entries ``(rank, iteration, offset)`` (dies at stage-2
    start + offset) or ``(rank, iteration, offset, stage)`` with
    ``stage`` 1 (offset from the iteration's gossip start) or 2.
    ``partition`` entries are ``(ranks_a, ranks_b, iteration, start,
    end)``: two disjoint rank groups whose cross links are severed for
    the sim-time window (relative to that iteration's gossip start; the
    absolute window persists across stage — and iteration — boundaries
    until it closes).  ``req_timeout`` is the base lock-request timeout,
    multiplied by ``backoff ** attempt`` on each retry.  All fault
    randomness comes from a dedicated stream keyed on ``seed`` — a run
    with an inactive spec (everything zero/empty) draws nothing from it
    and is bitwise-identical to ``fault=None``.
    """

    drop: object = 0.0
    dup: object = 0.0
    reorder: object = 0.0
    reorder_scale: float = 1.0
    pause: tuple = ()
    kill: tuple = ()
    partition: tuple = ()
    corrupt: object = 0.0
    req_timeout: float = 4.0
    backoff: float = 2.0
    seed: int = 0

    def active(self) -> bool:
        def nonzero(p):
            if callable(p):
                return True
            if isinstance(p, dict):
                return any(float(v) != 0.0 for v in p.values())
            return float(p) != 0.0
        return (nonzero(self.drop) or nonzero(self.dup)
                or nonzero(self.reorder) or nonzero(self.corrupt)
                or bool(self.pause) or bool(self.kill)
                or bool(self.partition))

    def validate(self, n_ranks: int, n_iter: int) -> None:
        for name in ("drop", "dup", "reorder", "corrupt"):
            p = getattr(self, name)
            if callable(p):
                continue
            vals = p.values() if isinstance(p, dict) else [p]
            for v in vals:
                if not 0.0 <= float(v) <= 1.0:
                    raise ValueError(f"{name} probability {v!r} not in "
                                     "[0, 1]")
        if self.reorder_scale < 0:
            raise ValueError("reorder_scale must be >= 0")
        if self.req_timeout <= 0:
            raise ValueError("req_timeout must be > 0")
        if self.backoff < 1.0:
            raise ValueError("backoff must be >= 1")
        by_rank_it: Dict[tuple, list] = {}
        for entry in self.pause:
            r, it, start, end = entry
            if not (0 <= r < n_ranks and 0 <= it < n_iter
                    and 0 <= start <= end):
                raise ValueError(f"bad pause entry {entry!r}")
            by_rank_it.setdefault((int(r), int(it)), []).append(
                (float(start), float(end), entry))
        for (r, it), wins in by_rank_it.items():
            wins.sort()
            for (s0, e0, a), (s1, e1, b) in zip(wins, wins[1:]):
                if s1 < e0:
                    raise ValueError(
                        f"pause windows {a!r} and {b!r} overlap on rank "
                        f"{r} in iteration {it}: a rank cannot be paused "
                        "twice at once — merge them into one window")
        seen_kill: Dict[int, tuple] = {}
        for entry in self.kill:
            if len(entry) == 4:
                r, it, off, stage = entry
                if stage not in (1, 2):
                    raise ValueError(
                        f"bad kill entry {entry!r}: stage must be 1 "
                        "(gossip) or 2 (lock/transfer)")
            elif len(entry) == 3:
                r, it, off = entry
            else:
                raise ValueError(
                    f"bad kill entry {entry!r}: expected (rank, iteration,"
                    " offset) or (rank, iteration, offset, stage)")
            if not (0 <= r < n_ranks and 0 <= it < n_iter and off >= 0):
                raise ValueError(f"bad kill entry {entry!r}")
            if int(r) in seen_kill:
                raise ValueError(
                    f"duplicate kill entries {seen_kill[int(r)]!r} and "
                    f"{entry!r} for rank {r}: a rank dies once — drop "
                    "the later entry")
            seen_kill[int(r)] = entry
        for entry in self.partition:
            if len(entry) != 5:
                raise ValueError(
                    f"bad partition entry {entry!r}: expected (ranks_a, "
                    "ranks_b, iteration, start, end)")
            ra, rb, it, start, end = entry
            sa = {int(x) for x in ra}
            sb = {int(x) for x in rb}
            if not sa or not sb:
                raise ValueError(f"bad partition entry {entry!r}: both "
                                 "rank groups must be non-empty")
            if sa & sb:
                raise ValueError(
                    f"bad partition entry {entry!r}: groups share ranks "
                    f"{sorted(sa & sb)} — a rank cannot sit on both "
                    "sides of a split")
            bad = [x for x in sa | sb if not 0 <= x < n_ranks]
            if bad:
                raise ValueError(
                    f"bad partition entry {entry!r}: ranks {sorted(bad)} "
                    f"out of range [0, {n_ranks})")
            if not (0 <= it < n_iter and 0 <= start <= end):
                raise ValueError(f"bad partition entry {entry!r}: need "
                                 "0 <= iteration < n_iter and "
                                 "0 <= start <= end")


@dataclasses.dataclass
class FaultStats:
    """What the injector did and how the hardened protocol absorbed it."""

    # injector side
    dropped: int = 0            # messages destroyed in flight
    duplicated: int = 0         # extra delayed copies injected
    reordered: int = 0          # messages given an extra delay
    dead_dropped: int = 0       # messages addressed to a dead rank
    paused_deferrals: int = 0   # deliveries deferred past a pause window
    killed: int = 0             # ranks killed
    partitioned_dropped: int = 0  # messages destroyed crossing a severed link
    corrupted: int = 0          # gossip payloads mutated in flight
    # protocol side (each counter is one hardening mechanism firing)
    dup_requests: int = 0       # duplicate LOCK_REQ deliveries ignored
    regrants: int = 0           # GRANT retransmitted on a duplicate REQ
    stale_grants: int = 0       # grants for aborted/consumed requests
    stale_releases: int = 0     # releases whose grant epoch already closed
    aborted_dequeues: int = 0   # timed-out requests removed from a queue
    purged_requests: int = 0    # dead ranks' requests purged/refused
    reclaimed_locks: int = 0    # lock-table entries freed at rank death
    wedged_reclaimed: int = 0   # stage-end reclaims of wedged locks
    dead_peer_skips: int = 0    # decisions/transfers skipped on dead peers
    recovered_tasks: int = 0    # tasks migrated off dead ranks at recovery
    partition_skips: int = 0    # decisions skipped on unreachable peers
    corrupt_quarantined: int = 0  # corrupted gossip payloads caught + dropped
    recovery_spills: int = 0    # stranded groups redirected off an over-cap
                                # warm-start target at recovery


class _FaultCtx:
    """Live fault-injection state threaded through one async run."""

    def __init__(self, spec: FaultSpec, n_ranks: int):
        self.spec = spec
        # dedicated stream: fault draws must never perturb the latency
        # stream, or an inactive spec would change fault-free trajectories
        self.rng = np.random.default_rng([int(spec.seed), 0xFA01])
        self.stats = FaultStats()
        self.dead: Set[int] = set()
        self.recovered: Set[int] = set()
        self.n_ranks = n_ranks
        self._pauses: Dict[int, list] = {}
        self._partitions: List[tuple] = []   # (set_a, set_b, t0, t1) absolute
        # corruption draws are gated on this flag so legacy specs (no
        # corrupt field) keep their exact fault-stream draw sequences
        c = spec.corrupt
        self.corrupt_active = bool(
            callable(c) or (isinstance(c, dict)
                            and any(float(v) != 0.0 for v in c.values()))
            or (not isinstance(c, dict) and float(c) != 0.0))

    def register_gossip(self, it: int, sim: "_Sim") -> None:
        """Anchor this iteration's partition windows and stage-1 kill
        timers at the current sim time (= this iteration's gossip
        start).  Partition windows are absolute once anchored, so a long
        window stays severed across the stage boundary and into later
        iterations until it closes."""
        t0 = sim.now
        for ra, rb, pit, start, end in self.spec.partition:
            if pit == it:
                self._partitions.append(
                    (frozenset(int(x) for x in ra),
                     frozenset(int(x) for x in rb),
                     t0 + float(start), t0 + float(end)))
        for entry in self.spec.kill:
            if len(entry) == 4 and entry[3] == 1 and entry[1] == it:
                sim.push(t0 + float(entry[2]), _MSG, FAIL,
                         int(entry[0]), int(entry[0]))

    def register_iteration(self, it: int, sim: "_Sim") -> None:
        """Anchor this iteration's pause windows and stage-2 kill timers
        at the current sim time (= this iteration's stage-2 start)."""
        t0 = sim.now
        for r, kit, start, end in self.spec.pause:
            if kit == it:
                self._pauses.setdefault(int(r), []).append(
                    (t0 + float(start), t0 + float(end)))
        for entry in self.spec.kill:
            stage = entry[3] if len(entry) == 4 else 2
            if entry[1] == it and stage == 2:
                sim.push(t0 + float(entry[2]), _MSG, FAIL,
                         int(entry[0]), int(entry[0]))

    def pause_until(self, rank: int, time: float) -> Optional[float]:
        for s, e in self._pauses.get(rank, ()):
            if s <= time < e:
                return e
        return None

    def severed(self, a: int, b: int, time: float) -> bool:
        """True while an anchored partition window separates ``a`` from
        ``b`` at ``time`` (bidirectional: group order is irrelevant)."""
        for sa, sb, s, e in self._partitions:
            if s <= time < e and ((a in sa and b in sb)
                                  or (a in sb and b in sa)):
                return True
        return False

    def unsettled(self, it: int, now: float) -> bool:
        """True while this fault spec can still perturb the run: a kill,
        pause or partition scheduled for a LATER iteration, or an
        already-anchored pause/partition window that has not closed.
        ``quiesce_after`` consults this so early termination never races
        a scheduled fault."""
        if any(entry[1] > it for entry in self.spec.kill):
            return True
        if any(entry[1] > it for entry in self.spec.pause):
            return True
        if any(entry[2] > it for entry in self.spec.partition):
            return True
        if any(e > now for _, _, _, e in self._partitions):
            return True
        return any(e > now for wins in self._pauses.values()
                   for _, e in wins)

    def maybe_corrupt(self, src: int, dst: int, data):
        """Send-side gossip corruption: with probability ``corrupt(src,
        dst)`` return a mutated COPY of the in-flight gossip tuple
        ``(root, rnd, visited, stamp, checksum, payload)`` — the shared
        payload object is never touched.  Three seeded mutation modes:
        flipped load, truncated cluster list, stale epoch stamp (the
        last keeps payload and checksum valid so the stamp check is
        load-bearing too)."""
        if self.rng.random() >= self.prob(self.spec.corrupt, src, dst):
            return data
        root, rnd, visited, stamp, chk, payload = data
        self.stats.corrupted += 1
        mode = int(self.rng.integers(3))
        s = payload[root]
        if mode == 2:
            return (root, rnd, visited, stamp - 1, chk, payload)
        if mode == 1 and s.clusters:
            bad = dataclasses.replace(
                s, clusters=s.clusters[:len(s.clusters) // 2])
        else:
            # load flip doubles as the fallback when there is nothing to
            # truncate (an emptied empty list would checksum-match)
            bad = dataclasses.replace(s, load=-(s.load + 1.0))
        return (root, rnd, visited, stamp, chk, {root: bad})

    def prob(self, p, src: int, dst: int) -> float:
        if callable(p):
            return float(p(src, dst))
        if isinstance(p, dict):
            return float(p.get((src, dst), 0.0))
        return float(p)


def make_latency(spec) -> Callable:
    """Normalize a latency spec into ``fn(rng, src, dst) -> float``.

    Accepted specs: ``None``/``0``/``"zero"`` (the serialized schedule),
    a non-negative float (constant), ``("uniform", lo, hi)``,
    ``("exp", scale)``, or a callable ``(rng, src, dst) -> float``.
    """
    if spec is None or spec == "zero":
        return lambda rng, s, d: 0.0
    if callable(spec):
        return spec
    if isinstance(spec, (int, float)):
        v = float(spec)
        if v < 0:
            raise ValueError(f"latency must be >= 0, got {v}")
        return lambda rng, s, d: v
    if isinstance(spec, (tuple, list)) and spec:
        if spec[0] == "uniform" and len(spec) == 3:
            lo, hi = float(spec[1]), float(spec[2])
            if not 0 <= lo <= hi:
                raise ValueError(f"bad uniform latency bounds: {spec!r}")
            return lambda rng, s, d: float(rng.uniform(lo, hi))
        if spec[0] == "exp" and len(spec) == 2:
            scale = float(spec[1])
            if scale < 0:
                raise ValueError(f"bad exp latency scale: {spec!r}")
            return lambda rng, s, d: float(rng.exponential(scale))
    raise ValueError(f"unknown latency spec: {spec!r}")


class _Sim:
    """The event queue + clock: per-rank mailboxes collapse into one heap
    because an entry's ``dst`` IS the mailbox.  Latencies are drawn per
    message, so messages may overtake each other both across AND within a
    link — e.g. a rank's retry LOCK_REQ to ``p`` can arrive before its
    own earlier RELEASE of ``p``, in which case the requester queues
    behind itself and is later granted via its own release; the handlers
    tolerate this, and the protocol must stay safe under any such
    interleaving (the property suite's job).  Only constant latency gives
    per-link FIFO delivery (equal delays + ``(time, class, seq)``
    tie-break in send order).

    ``fault`` (a :class:`_FaultCtx`, or None) makes the network lossy:
    sends may be dropped, duplicated or extra-delayed; pops addressed to
    a dead rank vanish, pops addressed to a paused rank are re-queued at
    the pause's end — both signalled to the caller by ``pop`` returning
    ``None`` (nothing was delivered: not counted, not traced, no handler
    runs).  ``FAIL`` events are exempt from both gates (death fires even
    while paused, and a dead rank's FAIL is handled idempotently).
    """

    def __init__(self, latency_fn, rng, max_events: int,
                 trace: Optional[list], fault: Optional[_FaultCtx] = None):
        self.heap: list = []
        self.seq = 0
        self.now = 0.0
        self.messages = 0          # delivered network messages
        self.processed = 0
        self.max_events = max_events
        self.latency = latency_fn
        self.rng = rng
        self.trace = trace
        self.fault = fault

    def push(self, time: float, klass: int, kind: int, src: int, dst: int,
             data=None) -> None:
        heapq.heappush(self.heap, (time, klass, self.seq, kind, src, dst,
                                   data))
        self.seq += 1

    def send(self, kind: int, src: int, dst: int, data=None) -> None:
        """Network send: delivery at now + one seeded latency draw.  With
        an active fault context the message additionally runs the
        drop → reorder → dup gauntlet (fixed draw order from the
        dedicated fault stream, so runs stay deterministic)."""
        delay = self.latency(self.rng, src, dst)
        f = self.fault
        if f is not None:
            sp = f.spec
            if f._partitions and f.severed(src, dst, self.now):
                f.stats.partitioned_dropped += 1
                return
            if kind == GOSSIP and f.corrupt_active and len(data) == 6:
                data = f.maybe_corrupt(src, dst, data)
            if f.rng.random() < f.prob(sp.drop, src, dst):
                f.stats.dropped += 1
                return
            extra = 0.0
            if f.rng.random() < f.prob(sp.reorder, src, dst):
                extra = float(f.rng.exponential(sp.reorder_scale))
                f.stats.reordered += 1
            if f.rng.random() < f.prob(sp.dup, src, dst):
                f.stats.duplicated += 1
                self.push(self.now + delay
                          + float(f.rng.exponential(sp.reorder_scale)),
                          _MSG, kind, src, dst, data)
        else:
            extra = 0.0
        self.push(self.now + delay + extra, _MSG, kind, src, dst, data)

    def pop(self):
        """Deliver the next event, or return ``None`` when the fault
        gates swallowed it (dead destination) or deferred it (pause)."""
        time, klass, seq, kind, src, dst, data = heapq.heappop(self.heap)
        self.now = time
        self.processed += 1
        if self.processed > self.max_events:
            raise LivelockError(self.max_events, self.processed,
                                len(self.heap), self.now)
        f = self.fault
        if f is not None and kind != FAIL:
            if dst in f.dead:
                if klass == _MSG:
                    f.stats.dead_dropped += 1
                return None
            if (klass == _MSG and f._partitions
                    and f.severed(src, dst, time)):
                # severed at delivery time too: a message in flight when
                # the window opened is cut with the link
                f.stats.partitioned_dropped += 1
                return None
            until = f.pause_until(dst, time)
            if until is not None:
                f.stats.paused_deferrals += 1
                heapq.heappush(self.heap,
                               (until, klass, seq, kind, src, dst, data))
                return None
        if klass == _MSG:
            self.messages += 1
        if self.trace is not None:
            self.trace.append((time, seq, EVENT_KINDS[kind], src, dst))
        return time, kind, src, dst, data


def _run_gossip(sim: _Sim, summaries, info, *, k_rounds: int, fanout: int,
                seed=None, root_seeds: Optional[Dict[int, list]] = None,
                deadline: Optional[float],
                dead: frozenset = frozenset(),
                stats: Optional[dict] = None,
                fault: Optional[_FaultCtx] = None, it: int = 0) -> int:
    """Stage 1a: the per-root augmented-inform epidemics as latency-
    delayed messages.

    Each live root floods exactly ``{root: summaries[root]}``, drawing
    forward targets from its PRIVATE stream keyed ``root_seeds[root]``
    (default ``gossip_root_key(seed, root)``) — the same keys, message
    set and merge/dedupe rule as the synchronous ``build_peer_networks``.
    At zero latency the heap delivers each root's messages in creation
    order, which IS that root's synchronous BFS round order; roots never
    share a stream, so however the roots' deliveries interleave, each
    root's draws and dedupe decisions — and therefore the resulting
    ``info`` maps — are identical to the sync epidemic's.  (This per-root
    independence is also what lets the quiescence path replay a quiet
    root's cached reach: see repro/core/gossip.py.)  Nonzero latency
    permutes delivery (and therefore the forward peer picks within each
    root's stream); a ``deadline`` drops deliveries that arrive too late
    to inform this iteration's scoring — stale gossip made observable.
    ``dead`` ranks neither seed, forward, nor receive (their deliveries
    vanish at the pop gate), so no dead rank's summary ever enters a
    live work list.  Returns the number of deadline-dropped deliveries.

    Under an active ``fault`` context (``it`` is the iteration index)
    the hardened path runs: every message carries an iteration stamp, a
    :func:`~repro.core.gossip.summary_checksum` and the payload itself
    (so in-flight corruption can mutate a copy without touching the
    shared object), receivers validate stamp + checksum before merging
    and QUARANTINE mismatches (counted, no merge, no forward — a later
    clean copy still delivers), and ``FAIL`` events may fire mid-flood:
    the killed rank joins the live ``fault.dead`` set, so subsequent
    forwards exclude it and its queued deliveries vanish at the pop
    gate, while its already-spread summary keeps flooding through live
    ranks — a dying root cannot wedge the epidemic.  Fault-free runs
    take none of these branches and stay bitwise-identical.
    """
    n = len(summaries)
    rngs: Dict[int, np.random.Generator] = {}
    payloads: Dict[int, dict] = {}
    checks: Dict[int, int] = {}
    dead_live = fault.dead if fault is not None else set(dead)
    dropped = 0
    if k_rounds >= 1:
        for r in range(n):
            if r in dead_live:
                continue
            key = (root_seeds[r] if root_seeds is not None
                   else gossip_root_key(seed, r))
            rngs[r] = np.random.default_rng(key)
            payloads[r] = {r: summaries[r]}     # shared, read-only
            if fault is not None:
                checks[r] = summary_checksum(summaries[r])
            for p in pick_peers(rngs[r], n, r, fanout,
                                visited={r} | set(dead_live)):
                data = ((r, 1, frozenset([r, int(p)])) if fault is None
                        else (r, 1, frozenset([r, int(p)]), it, checks[r],
                              payloads[r]))
                sim.send(GOSSIP, r, int(p), data)
    while sim.heap:
        ev = sim.pop()
        if ev is None:
            continue
        time, kind, src, dst, data = ev
        if kind == FAIL:
            assert fault is not None, "FAIL event without a fault context"
            d = dst
            if d in fault.dead:
                continue        # duplicate kill — already dead
            fault.dead.add(d)
            fault.stats.killed += 1
            if len(fault.dead) >= n:
                raise RankDeath("all ranks dead — no survivor set left "
                                "to balance; restart from checkpoint")
            continue
        assert kind == GOSSIP
        root, rnd, visited = data[0], data[1], data[2]
        if deadline is not None and time > deadline:
            dropped += 1                # arrived stale: no merge, no forward
            continue
        if fault is not None:
            stamp, chk, payload = data[3], data[4], data[5]
            s = payload.get(root)
            if stamp != it or s is None or summary_checksum(s) != chk:
                fault.stats.corrupt_quarantined += 1
                continue                # quarantine: no merge, no forward
        else:
            payload = payloads[root]
        if not gossip_deliver(info[dst], payload, stats):
            continue                    # dedupe: no forward
        if rnd < k_rounds:
            for p in pick_peers(rngs[root], n, dst, fanout,
                                visited=set(visited) | set(dead_live)):
                fwd = ((root, rnd + 1, frozenset(visited) | {int(p)})
                       if fault is None
                       else (root, rnd + 1, frozenset(visited) | {int(p)},
                             it, checks[root], payloads[root]))
                sim.send(GOSSIP, dst, int(p), fwd)
    return dropped


def _run_stage2(sim: _Sim, phase, state, clusters, work_lists, engine,
                locks: LockManager, stats: ProtocolStats, *,
                max_candidates: int, max_clusters_per_rank,
                max_retries: int, on_event,
                fault: Optional[_FaultCtx] = None,
                replicate: bool = False) -> None:
    """Stage 2: the lock/transfer protocol as mailbox events (see the
    module docstring for the event <-> Fig. 1 mapping, and the "Fault
    injection" section for the TIMEOUT/FAIL hardening paths — none of
    which schedules an event or draws randomness when ``fault`` is
    None, keeping fault-free runs bitwise-identical)."""
    n = phase.num_ranks
    f = fault
    # open_req[r] = (req_id, diff, p): the single in-flight lock request
    # of rank r (a rank never has two — DECIDEs are only scheduled when
    # the slot clears)
    open_req: List[Optional[Tuple[int, float, int]]] = [None] * n
    retries: List[Dict[int, int]] = [dict() for _ in range(n)]
    req_ids = itertools.count()     # grant tokens, unique per stage
    # per-target sets of request tokens already seen (duplicate-REQ
    # idempotence; only consulted under an active fault)
    seen_req: List[Set[int]] = [set() for _ in range(n)]
    spins = 0
    max_spins = 50 * n + 1000    # mirrors the sync driver's turn cap

    for r in range(n):
        if work_lists[r] and (f is None or r not in f.dead):
            sim.push(sim.now, _LOCAL, DECIDE, r, r)

    while sim.heap:
        ev = sim.pop()
        if ev is None:
            continue
        time, kind, src, dst, data = ev
        if kind == DECIDE:
            r = dst
            if f is None:
                assert open_req[r] is None, \
                    f"rank {r} decided while awaiting a grant"
            elif open_req[r] is not None:
                # a deferred DECIDE can land after a retry re-opened a
                # request; deciding is idempotent — skip
                continue
            if spins >= max_spins or not work_lists[r]:
                continue
            spins += 1
            diff, p = work_lists[r].popleft()
            if f is not None and p in f.dead:
                f.stats.dead_peer_skips += 1
                if work_lists[r]:
                    sim.push(sim.now, _LOCAL, DECIDE, r, r)
                continue
            if (f is not None and f._partitions
                    and f.severed(r, p, sim.now)):
                # partition-aware timeout accounting: the REQ would be
                # destroyed on the severed link and the rank would idle a
                # full req_timeout before retrying — skip the doomed send
                # outright so the island keeps making local progress.
                # Bounded by the same per-(rank, peer) retry budget as
                # yields; the item re-queues at the back, so reachable
                # intra-island peers are tried first.
                f.stats.partition_skips += 1
                cnt = retries[r].get(p, 0)
                if cnt < max_retries:
                    retries[r][p] = cnt + 1
                    work_lists[r].append((diff, p))
                else:
                    stats.retries_exhausted += 1
                if work_lists[r]:
                    sim.push(sim.now, _LOCAL, DECIDE, r, r)
                continue
            rid = next(req_ids)
            open_req[r] = (rid, diff, p)
            sim.send(LOCK_REQ, r, p, rid)
            if f is not None:
                # the request might never be answered on a lossy network;
                # arm the abort timer (exponential backoff per retry)
                wait = (f.spec.req_timeout
                        * f.spec.backoff ** retries[r].get(p, 0))
                sim.push(sim.now + wait, _LOCAL, TIMEOUT, r, r,
                         (rid, diff, p))
        elif kind == LOCK_REQ:
            r, p = src, dst
            rid = data
            if f is not None:
                if r in f.dead:
                    # sent before the requester died — a dead rank must
                    # never be granted a lock
                    f.stats.purged_requests += 1
                    continue
                if rid in seen_req[p]:
                    f.stats.dup_requests += 1
                    if locks.holds_grant(r, p, rid):
                        # the original GRANT may have been dropped —
                        # retransmit (idempotent at the requester)
                        f.stats.regrants += 1
                        sim.send(GRANT, p, r, rid)
                    continue
                seen_req[p].add(rid)
            if lock_request(locks, stats, r, p, rid):
                sim.send(GRANT, p, r, rid)
            # else: queued FIFO at p — the grant arrives on a release
        elif kind == GRANT:
            p, r = src, dst
            rid = data
            if f is None:
                assert open_req[r] is not None, \
                    f"rank {r} granted without an open request"
            elif open_req[r] is None or open_req[r][0] != rid:
                # the request was aborted by its timeout, or this is a
                # duplicate of an already-consumed grant — hand the lock
                # straight back (token-checked no-op if it, too, is stale)
                f.stats.stale_grants += 1
                sim.send(RELEASE, r, p, rid)
                continue
            rid2, diff, p_req = open_req[r]
            open_req[r] = None
            assert p_req == p and rid2 == rid
            if f is not None and p in f.dead:
                # target died after granting; its lock table died with it
                # — nothing to use, nothing to release
                f.stats.dead_peer_skips += 1
                if work_lists[r]:
                    sim.push(sim.now, _LOCAL, DECIDE, r, r)
                continue
            if locks.must_yield(r, p):
                # Fig. 1 line 45: release unused, retry later (bounded —
                # unlike the sync driver's unbounded re-queue, so a yield
                # storm cannot stall termination)
                note_yield(stats)
                cnt = retries[r].get(p, 0)
                if cnt < max_retries:
                    retries[r][p] = cnt + 1
                    work_lists[r].append((diff, p))
                else:
                    stats.retries_exhausted += 1
            else:
                # mutation under mutual exclusion: r must hold p's lock
                # under exactly this grant token for the whole
                # (instantaneous) evaluation
                assert locks.holds_grant(r, p, rid)
                execute_transfer(state, clusters, engine, stats, r, p,
                                 max_candidates, max_clusters_per_rank,
                                 replicate=replicate)
            sim.send(RELEASE, r, p, rid)
            if work_lists[r]:
                sim.push(sim.now, _LOCAL, DECIDE, r, r)
        elif kind == RELEASE:
            r, p = src, dst
            rid = data
            if f is None:
                nxt = lock_release(locks, stats, r, p)
                if nxt is not None:
                    sim.send(GRANT, p, nxt, locks.grant_id[p])
            elif locks.holds_grant(r, p, rid):
                nxt = lock_release(locks, stats, r, p)
                while nxt is not None and nxt in f.dead:
                    # defensive: dead requesters are purged at death and
                    # their late REQs refused, so the queue should never
                    # surface one — but never hand a dead rank a lock
                    f.stats.purged_requests += 1
                    nxt = lock_release(locks, stats, nxt, p)
                if nxt is not None:
                    sim.send(GRANT, p, nxt, locks.grant_id[p])
            elif locks.dequeue(r, p, rid):
                # a timed-out request aborted while still queued
                f.stats.aborted_dequeues += 1
            else:
                # duplicate of a consumed release, or abort of a REQ
                # that never arrived — token mismatch makes it a no-op
                f.stats.stale_releases += 1
        elif kind == TIMEOUT:
            r = dst
            rid, diff, p = data
            if open_req[r] is None or open_req[r][0] != rid:
                continue        # answered (or aborted) before the timer
            stats.timeouts += 1
            open_req[r] = None
            # abort: frees the grant if it was granted (GRANT lost),
            # dequeues if still queued, no-ops if the REQ itself was lost
            sim.send(RELEASE, r, p, rid)
            cnt = retries[r].get(p, 0)
            if cnt < max_retries:
                retries[r][p] = cnt + 1
                work_lists[r].append((diff, p))
            else:
                stats.retries_exhausted += 1
            if work_lists[r]:
                sim.push(sim.now, _LOCAL, DECIDE, r, r)
        elif kind == FAIL:
            assert f is not None, "FAIL event without a fault context"
            d = dst
            if d in f.dead:
                continue        # duplicate kill entry — already dead
            f.dead.add(d)
            f.stats.killed += 1
            # a dead rank must never be granted a lock it can't release
            f.stats.purged_requests += locks.purge_requester(d)
            # locks d held on others would wedge them forever — force-
            # release, handing each to its next live queued requester
            for t in locks.held_by(d):
                nxt = lock_release(locks, stats, d, t)
                while nxt is not None and nxt in f.dead:
                    f.stats.purged_requests += 1
                    nxt = lock_release(locks, stats, nxt, t)
                if nxt is not None:
                    sim.send(GRANT, t, nxt, locks.grant_id[t])
            # d's own lock table (holder of record, queue) dies with it
            f.stats.reclaimed_locks += locks.reclaim(d)
            open_req[d] = None
            work_lists[d].clear()
            if len(f.dead) >= n:
                raise RankDeath("all ranks dead — no survivor set left "
                                "to balance; restart from checkpoint")
        else:  # pragma: no cover - defensive
            raise AssertionError(f"unknown event kind {kind}")
        if on_event is not None:
            on_event(time, kind, src, dst, locks, state)

    # liveness at termination: every request answered, every lock released
    if f is None:
        assert not any(o is not None for o in open_req), \
            "rank still awaiting a grant at termination"
        assert locks.quiescent(), "locks/queues not drained at termination"
    else:
        # an open request always keeps a TIMEOUT queued, so an empty heap
        # proves no live rank is still waiting...
        assert all(o is None for o in open_req), \
            "open request at stage end despite timeout timers"
        # ...which makes anything still held or queued a wedge left by a
        # dropped RELEASE (or an un-dequeued abort) — reclaim at the
        # barrier, where no requester can race us
        for t in range(n):
            if locks.locked_by[t] is not None or locks.queue[t]:
                f.stats.wedged_reclaimed += locks.reclaim(t)
        assert locks.quiescent(), \
            "locks/queues not drained after stage-end reclamation"


def _mem_after_add(state: CCMState, tasks: np.ndarray, r: int) -> float:
    """M_max(r) (eq. 7) after hypothetically adding ``tasks`` to rank r.

    Pure read — mirrors the accounting ``apply_transfer`` maintains
    incrementally (task-memory sum, running overhead max, shared bytes of
    blocks the rank does not already hold) without mutating the state, so
    recovery can test a placement before committing it.
    """
    ph = state.phase
    add_mem = float(ph.task_mem[tasks].sum())
    over = float(state.mem_overhead_max[r])
    if tasks.size:
        over = max(over, float(ph.task_overhead[tasks].max()))
    blocks = ph.task_block[tasks]
    blocks = np.unique(blocks[blocks >= 0])
    new_blocks = blocks[state.block_count[r, blocks] == 0]
    shared = float(state.shared_cache[r]) + float(
        ph.block_size[new_blocks].sum())
    return (float(ph.rank_mem_base[r]) + float(state.mem_task[r])
            + add_mem + over + shared)


def _recover_survivors(phase, state: CCMState, f: _FaultCtx,
                       recovery_log: list) -> None:
    """Post-crash warm start of the survivor set (elastic resize framing).

    The survivor set is renumbered contiguously (``survivor_resize``),
    the current assignment is mapped through it — dead ranks land OUT of
    the survivor range — and ``warm_start_assignment`` re-places exactly
    the stranded tasks via its rank clipping while every surviving task
    keeps its rank.  Migrations are applied through
    ``state.apply_transfer`` in the ORIGINAL rank numbering, so they flow
    through the transfer listener like protocol transfers and the
    transfer-log replay invariant keeps covering crash recovery.

    Under an active memory constraint each stranded group's warm-start
    target is checked against its (headroom-scaled) cap BEFORE the
    transfer commits; an over-cap target spills the group to the
    least-loaded survivor with room (ties broken by rank id, counted in
    ``FaultStats.recovery_spills``), and if no survivor has room the
    recovery raises :class:`RecoveryOOMError` instead of silently
    landing tasks over the cap.  With the constraint off, or when every
    warm-start target fits, the migration sequence is bitwise-identical
    to the unchecked path.
    """
    newly = sorted(f.dead - f.recovered)
    if not newly:
        return
    rs = survivor_resize(phase.num_ranks, f.dead)
    o2n = rs.old_to_new
    # the restricted phase only needs valid rank-indexed arrays; only the
    # round_robin fallback below ever reads it, and that reads none of
    # the block/comm structure
    bh = (np.minimum(o2n[phase.block_home], rs.n_new - 1)
          if phase.num_blocks > 0 else phase.block_home)
    surv_phase = Phase(
        task_load=phase.task_load, task_mem=phase.task_mem,
        task_overhead=phase.task_overhead, task_block=phase.task_block,
        block_size=phase.block_size, block_home=bh,
        comm_src=phase.comm_src, comm_dst=phase.comm_dst,
        comm_vol=phase.comm_vol,
        rank_mem_base=phase.rank_mem_base[rs.survivors],
        rank_mem_cap=phase.rank_mem_cap[rs.survivors],
        rank_speed=phase.rank_speed[rs.survivors])
    prev = o2n[state.assignment]            # dead ranks -> out of range
    warm, _ = warm_start_assignment(phase, prev, surv_phase,
                                    mode="round_robin")
    target = rs.survivors[warm]             # back to original numbering
    p = state.params
    for d in newly:
        stranded = np.nonzero(state.assignment == d)[0]
        for s in np.unique(target[stranded]):
            tasks = stranded[target[stranded] == s]
            dest = int(s)
            if p.memory_constraint:
                caps = effective_mem_cap(phase.rank_mem_cap, p)
                if _mem_after_add(state, tasks, dest) > caps[dest]:
                    # over-cap warm-start target: spill to the least-
                    # loaded survivor with room (the checks run against
                    # the live state, so earlier recovery transfers in
                    # this same pass are already accounted for)
                    spill_to = None
                    best_over = float("inf")
                    for _, c in sorted((float(state.load[c]), int(c))
                                       for c in rs.survivors):
                        m = _mem_after_add(state, tasks, c)
                        if m <= caps[c]:
                            spill_to = c
                            break
                        best_over = min(best_over, m - caps[c])
                    if spill_to is None:
                        raise RecoveryOOMError(tasks, d, best_over)
                    dest = spill_to
                    f.stats.recovery_spills += 1
            state.apply_transfer(tasks, d, dest)
            recovery_log.append((tuple(int(x) for x in tasks), d, dest))
            f.stats.recovered_tasks += int(tasks.size)
    f.recovered |= set(newly)


def ccm_lb_async(phase: Phase, assignment: np.ndarray, params: CCMParams, *,
                 n_iter: int = 4, k_rounds: int = 2, fanout: int = 4,
                 seed: int = 0, latency=0.0,
                 gossip_timeout: Optional[float] = None,
                 max_retries: int = 4, max_candidates: int = 12,
                 max_clusters_per_rank: Optional[int] = None,
                 use_engine: bool = True, backend: str = "numpy",
                 incremental: bool = True, csr=None,
                 collect_trace: bool = False,
                 max_events: Optional[int] = None,
                 on_event=None,
                 fault: Optional[FaultSpec] = None,
                 membership: tuple = (),
                 quiesce_after: Optional[int] = None,
                 profile: bool = False,
                 replicate: bool = False) -> CCMLBResult:
    """CCM-LB through the asynchronous event-loop driver.

    Same optimization knobs as :func:`repro.core.ccmlb.ccm_lb` (engine /
    backend / incremental / csr), plus the simulation knobs:

    ``latency``         message-latency spec (see :func:`make_latency`).
                        The default ``0.0`` is the serialized schedule —
                        bitwise-identical trajectories to ``ccm_lb``.
    ``gossip_timeout``  per-iteration gossip deadline in sim-time units;
                        deliveries past it are dropped (stale).  ``None``
                        drains the epidemic fully.
    ``max_retries``     per-(rank, peer) bound on yield/timeout re-queues;
                        items dropped at the cap are counted in
                        ``retries_exhausted``.
    ``collect_trace``   record the ``(time, seq, kind, src, dst)`` event
                        trace into ``CCMLBResult.events``.
    ``on_event``        optional hook ``(time, kind, src, dst, locks,
                        state)`` called after every stage-2 event — the
                        protocol-safety suite's invariant probe.
    ``fault``           a :class:`FaultSpec` degrading the network and
                        the ranks (module docstring, "Fault injection").
                        ``None`` or an inactive spec is bitwise-identical
                        to the fault-free driver.  Killing every rank
                        raises :class:`repro.runtime.fault.RankDeath`;
                        exceeding the event budget raises
                        :class:`LivelockError` carrying partial stats.
    ``membership``      :class:`~repro.runtime.elastic.RankJoin` events
                        (or plain ``(iteration, count)`` tuples): fresh
                        ranks join the mesh at the start of the named
                        iteration.  The phase is expanded in place
                        (median-default capacities, rank-independent CSR
                        carried over), the state/engine/tracker are
                        re-grown on the wider rank set, and the joiners
                        inherit gossip state through their first
                        iteration's epidemic and attract transfers as
                        ordinary underloaded ranks.  Joined rank ids land
                        in ``CCMLBResult.joined_ranks``; ``CCMLBResult.
                        state.phase`` is the final (expanded) phase.
    ``quiesce_after``   stop after this many consecutive zero-transfer
                        iterations (same early-termination knob as the
                        sync driver; ``None`` runs all ``n_iter``).  The
                        quiet counter only advances while no fault
                        window is open and no kill/join is still
                        scheduled, so early exit never races a pending
                        perturbation.
    ``profile``         record per-iteration host-side stage timings into
                        ``CCMLBResult.stage_timings`` (stage-2 scoring
                        and commit time accumulate under "score" /
                        "commit" as grants execute).
    ``replicate``       enable the memory-pressure move vocabulary
                        (block replication splits and de-replication
                        consolidations) in every grant's exchange search
                        — same semantics as the sync driver's knob (see
                        :func:`repro.core.ccmlb.ccm_lb`).  Extra
                        candidates only win on strictly better eq. 4
                        scores, so runs where they never win are
                        bitwise-identical to ``replicate=False``.

    The same :class:`~repro.core.quiesce.QuiesceTracker` that amortizes
    the sync driver runs here too: summaries are patched for dirty ranks
    only (``incremental=True``), per-root gossip streams are keyed by the
    tracker's epochs, and failed exact evaluations are memoized against
    the state version.  Work lists are always rebuilt in full — the async
    info maps are latency-dependent, so the sync driver's cached-list
    replay does not apply.

    Iterations stay globally synchronized (the paper's outer loop);
    asynchrony lives inside each iteration's gossip and lock/transfer
    stages.  ``CCMLBResult.lock_conflicts`` / ``yields`` /
    ``grant_chains`` / ``max_grant_chain`` are meaningful here, and
    ``transfer_log`` replays onto the initial assignment to the returned
    one exactly — crash-recovery migrations included (they are also
    listed separately in ``recovery_log``).
    """
    if quiesce_after is not None and quiesce_after < 1:
        raise ValueError("quiesce_after must be >= 1 (or None)")
    joins: List[RankJoin] = [
        j if isinstance(j, RankJoin) else RankJoin(*j) for j in membership]
    for j in joins:
        if not 0 <= j.iteration < n_iter:
            raise ValueError(f"membership event {j!r}: iteration out of "
                             f"range [0, {n_iter})")
    f: Optional[_FaultCtx] = None
    if fault is not None and fault.active():
        # fault entries address the INITIAL rank set; ranks that only
        # exist after a membership join cannot be named in a FaultSpec
        fault.validate(phase.num_ranks, n_iter)
        f = _FaultCtx(fault, phase.num_ranks)
    state = CCMState.build(phase, assignment, params, csr=csr)
    engine = (PhaseEngine(state, backend=backend, incremental=incremental)
              if use_engine else None)
    tracker = QuiesceTracker(state, engine, params, seed=seed,
                             k_rounds=k_rounds, fanout=fanout,
                             max_clusters_per_rank=max_clusters_per_rank,
                             caching=incremental, replicate=replicate)
    transfer_log: list = []
    recovery_log: list = []

    def _log_transfer(t, a, b):
        transfer_log.append((tuple(int(x) for x in t), int(a), int(b)))

    state.add_transfer_listener(_log_transfer)
    state.add_transfer_listener(tracker.note_transfer)
    joined_ranks: List[int] = []

    latency_fn = make_latency(latency)
    rng_lat = np.random.default_rng([seed, 0x51D])   # latency-draw stream
    if max_events is None:
        # DECIDEs are spin-capped, each spawns <= 3 protocol messages;
        # each of the n per-root epidemics delivers <= fanout messages per
        # reached rank per round, geometric in fanout over k_rounds;
        # x8 headroom
        max_events = 8 * n_iter * (
            4 * (50 * phase.num_ranks + 1000)
            + phase.num_ranks * (1 + max(fanout, 1))
            * max(fanout, 1) ** max(k_rounds, 1))
        if f is not None:
            # timeout aborts, retries, duplicates and pause re-deliveries
            # legitimately need more than the polite-network budget
            max_events *= 4
    trace: Optional[list] = [] if collect_trace else None
    sim = _Sim(latency_fn, rng_lat, max_events, trace, fault=f)
    stats = ProtocolStats()
    stats.memo = tracker.memo if tracker.caching else None
    gossip_dropped = 0
    iter_transfers: List[int] = []
    stage_timings: List[dict] = []
    quiet = 0

    trace_max = [state.max_work()]
    trace_tot = [state.total_work()]
    trace_imb = [state.imbalance()]

    it = 0
    try:
        for it in range(n_iter):
            joins_now = [j for j in joins if j.iteration == it]
            if joins_now:
                old_n = phase.num_ranks
                for j in joins_now:
                    phase = expand_phase(phase, j.count,
                                         mem_base=j.mem_base,
                                         mem_cap=j.mem_cap, speed=j.speed)
                joined_ranks.extend(range(old_n, phase.num_ranks))
                # rebuild on the wider rank set; the CSR bundle is rank-
                # independent so it carries over, and the assignment is
                # already valid (joiners start empty by construction)
                state = CCMState.build(phase, state.assignment, params,
                                       csr=state.csr)
                engine = (PhaseEngine(state, backend=backend,
                                      incremental=incremental)
                          if use_engine else None)
                state.add_transfer_listener(_log_transfer)
                tracker.regrow(state, engine)
                state.add_transfer_listener(tracker.note_transfer)
            tm = None
            if profile:
                tm = {"clusters": 0.0, "gossip": 0.0, "work_lists": 0.0,
                      "score": 0.0, "commit": 0.0}
                stats.timings = tm
            tracker.begin_iteration(it)
            t0 = perf_counter() if profile else 0.0
            clusters, summaries = tracker.update_summaries()
            if profile:
                tm["clusters"] = perf_counter() - t0
                t0 = perf_counter()
            info = {r: {r: summaries[r]} for r in range(phase.num_ranks)}
            deadline = (None if gossip_timeout is None
                        else sim.now + gossip_timeout)
            if f is not None:
                f.register_gossip(it, sim)
            dead_now = frozenset(f.dead) if f is not None else frozenset()
            gossip_dropped += _run_gossip(
                sim, summaries, info, k_rounds=k_rounds, fanout=fanout,
                root_seeds={r: tracker.root_key(r)
                            for r in range(phase.num_ranks)},
                deadline=deadline, dead=dead_now, stats=tracker.counters,
                fault=f, it=it)
            if profile:
                tm["gossip"] = perf_counter() - t0
                t0 = perf_counter()
            work_lists = build_work_lists(phase, summaries, info, params,
                                          engine)
            if profile:
                tm["work_lists"] = perf_counter() - t0
            locks = LockManager(phase.num_ranks)
            if f is not None:
                f.register_iteration(it, sim)
            before = stats.transfers
            _run_stage2(sim, phase, state, clusters, work_lists, engine,
                        locks, stats, max_candidates=max_candidates,
                        max_clusters_per_rank=max_clusters_per_rank,
                        max_retries=max_retries, on_event=on_event,
                        fault=f, replicate=replicate)
            if f is not None and f.dead - f.recovered:
                newly_dead = sorted(f.dead - f.recovered)
                _recover_survivors(phase, state, f, recovery_log)
                # evict the dead ranks from every tracker cache family
                # and force-dirty everything that knew them, so the
                # epoch-keyed replay never serves their stale state and
                # quiescence stays absorbing
                tracker.purge_ranks(newly_dead)
            iter_transfers.append(stats.transfers - before)
            tracker.end_iteration()
            if profile:
                stage_timings.append(tm)

            trace_max.append(state.max_work())
            trace_tot.append(state.total_work())
            trace_imb.append(state.imbalance())
            if quiesce_after is not None:
                settled = ((f is None or not f.unsettled(it, sim.now))
                           and not any(j.iteration > it for j in joins))
                quiet = quiet + 1 if (iter_transfers[-1] == 0
                                      and settled) else 0
                if quiet >= quiesce_after:
                    break
    except LivelockError as e:
        # attach the partial accounting so sweeps can report WHY
        e.stats = stats
        e.fault_stats = f.stats if f is not None else None
        e.iteration = it
        raise
    finally:
        state.remove_transfer_listener(tracker.note_transfer)

    return CCMLBResult(state.assignment.copy(), state, trace_max, trace_tot,
                       trace_imb, stats.transfers, stats.conflicts,
                       engine_used=engine is not None, yields=stats.yields,
                       grant_chains=stats.grant_chains,
                       max_grant_chain=stats.max_grant_chain,
                       messages=sim.messages, sim_time=sim.now,
                       gossip_dropped=gossip_dropped, events=trace,
                       transfer_log=transfer_log,
                       timeouts=stats.timeouts,
                       retries_exhausted=stats.retries_exhausted,
                       fault_stats=f.stats if f is not None else None,
                       recovery_log=(recovery_log if f is not None
                                     else None),
                       dead_ranks=(sorted(f.dead) if f is not None
                                   else None),
                       joined_ranks=joined_ranks if joins else None,
                       iter_transfers=iter_transfers,
                       stage_timings=stage_timings if profile else None,
                       quiesce_counters=tracker.iter_counters,
                       memo_hits=stats.memo_hits,
                       gossip_noop_merges=tracker.counters.get(
                           "gossip_noop_merges", 0),
                       tracker=tracker)


def run_ccm_lb(phase, a0, params, *, async_mode: bool = False, latency=0.0,
               gossip_timeout=None, batch_lock_events: int = 1,
               spec_window: int = 1, spec_mode: str = "scan",
               fault: Optional[FaultSpec] = None,
               membership: tuple = (), **kw) -> CCMLBResult:
    """Dispatch one balancing run to the synchronous driver or — with
    ``async_mode=True`` — to this module's event-loop simulator, which
    models message latency and makes the §IV-B conflict/yield/chain
    counters on the returned ``CCMLBResult`` meaningful.  Used by the
    ``repro.balance`` planners to expose the async knobs uniformly.
    ``batch_lock_events`` and ``spec_window`` are synchronous-driver knobs
    (the async turn order depends on grant interleavings, so neither the
    deferred disjoint-event batching nor the speculative scan — whose
    event sequence must be derivable up front — applies there); conversely
    ``latency`` / ``gossip_timeout`` / ``fault`` only exist under
    ``async_mode=True`` — either inconsistency raises instead of silently
    dropping the knob."""
    if not async_mode:
        if not (latency is None or latency == 0.0 or latency == "zero"):
            raise ValueError("latency is an async-driver knob; pass "
                             "async_mode=True to simulate message latency")
        if gossip_timeout is not None:
            raise ValueError("gossip_timeout is an async-driver knob; pass "
                             "async_mode=True")
        if fault is not None:
            raise ValueError("fault is an async-driver knob (the sync "
                             "round-robin loop has no network to degrade); "
                             "pass async_mode=True")
        if membership:
            raise ValueError("membership is an async-driver knob (mid-run "
                             "joins need the event loop; for inter-phase "
                             "joins use ccm_lb_pipeline(membership=...)); "
                             "pass async_mode=True")
        return ccm_lb(phase, a0, params, batch_lock_events=batch_lock_events,
                      spec_window=spec_window, spec_mode=spec_mode, **kw)
    if batch_lock_events != 1:
        raise ValueError("batch_lock_events is a synchronous-driver knob; "
                         "unsupported with async_mode=True")
    if spec_window != 1:
        raise ValueError("spec_window is a synchronous-driver knob (the "
                         "async event sequence is not derivable up front); "
                         "unsupported with async_mode=True")
    return ccm_lb_async(phase, a0, params, latency=latency,
                        gossip_timeout=gossip_timeout, fault=fault,
                        membership=membership, **kw)
