"""Phase/task-graph representation for the CCM model (paper §III-A).

A *phase* is a set of tasks between two synchronization points, plus their
communications and shared memory blocks.  Everything is stored as flat numpy
arrays so the CCM evaluation, the distributed CCM-LB simulation, the MILP
builder, and the vectorized scorer all read the same structure.

Conventions (paper):
  - each task is assigned to exactly one rank (``assignment``);
  - each task accesses at most ONE shared block (``task_block``, -1 if none);
  - each block is homed at exactly one rank (``block_home``); homes and
    block-task membership are parameters the balancer may NOT change;
  - communications are directed task->task edges with a byte volume.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np


@dataclasses.dataclass
class Phase:
    # --- tasks ---------------------------------------------------------------
    task_load: np.ndarray        # (K,) float, seconds — L(t)
    task_mem: np.ndarray         # (K,) float, bytes — M-(t) baseline
    task_overhead: np.ndarray    # (K,) float, bytes — M+(t) working overhead
    task_block: np.ndarray       # (K,) int, block id or -1
    # --- blocks --------------------------------------------------------------
    block_size: np.ndarray       # (N,) float, bytes — M(s)
    block_home: np.ndarray       # (N,) int, home rank
    # --- communications ------------------------------------------------------
    comm_src: np.ndarray         # (M,) int task id
    comm_dst: np.ndarray         # (M,) int task id
    comm_vol: np.ndarray         # (M,) float bytes
    # --- ranks ---------------------------------------------------------------
    rank_mem_base: np.ndarray    # (I,) float bytes — M-(r)
    rank_mem_cap: np.ndarray     # (I,) float bytes — M∞(r) per-rank bound (9)
    rank_speed: Optional[np.ndarray] = None  # (I,) relative speed (straggler
                                             # mitigation: load/speed)

    def __post_init__(self):
        self.task_load = np.asarray(self.task_load, np.float64)
        self.task_mem = np.asarray(self.task_mem, np.float64)
        self.task_overhead = np.asarray(self.task_overhead, np.float64)
        self.task_block = np.asarray(self.task_block, np.int64)
        self.block_size = np.asarray(self.block_size, np.float64)
        self.block_home = np.asarray(self.block_home, np.int64)
        self.comm_src = np.asarray(self.comm_src, np.int64)
        self.comm_dst = np.asarray(self.comm_dst, np.int64)
        self.comm_vol = np.asarray(self.comm_vol, np.float64)
        self.rank_mem_base = np.asarray(self.rank_mem_base, np.float64)
        self.rank_mem_cap = np.asarray(self.rank_mem_cap, np.float64)
        if self.rank_speed is None:
            self.rank_speed = np.ones(self.num_ranks, np.float64)
        else:
            self.rank_speed = np.asarray(self.rank_speed, np.float64)

    # ------------------------------------------------------------------ sizes
    @property
    def num_tasks(self) -> int:
        return int(self.task_load.shape[0])

    @property
    def num_blocks(self) -> int:
        return int(self.block_size.shape[0])

    @property
    def num_comms(self) -> int:
        return int(self.comm_vol.shape[0])

    @property
    def num_ranks(self) -> int:
        return int(self.rank_mem_base.shape[0])

    def validate(self):
        k, n, i = self.num_tasks, self.num_blocks, self.num_ranks
        assert self.task_block.max(initial=-1) < n
        assert self.task_block.min(initial=0) >= -1
        assert (0 <= self.block_home).all() and (self.block_home < i).all()
        assert (0 <= self.comm_src).all() and (self.comm_src < k).all()
        assert (0 <= self.comm_dst).all() and (self.comm_dst < k).all()
        assert (self.task_load >= 0).all() and (self.comm_vol >= 0).all()


@dataclasses.dataclass(frozen=True)
class CCMParams:
    """Coefficients of the work model (13)."""

    alpha: float = 1.0    # include compute load (Z2 in the paper)
    beta: float = 1e-9    # s/B off-rank communication
    gamma: float = 1e-11  # s/B on-rank communication
    delta: float = 1e-9   # s/B homing cost
    memory_constraint: bool = True  # epsilon in {0, +inf}
    # pressure policy: fraction of rank_mem_cap held back as headroom.
    # Every feasibility comparison (scalar, engine, compiled scorer) tests
    # against cap*(1-mem_headroom) — see repro.core.ccm.effective_mem_cap —
    # so a rank drifting into the headroom band gets the eq. 9 barrier
    # (work = inf) and the stage-2 optimizer trades migration against
    # de-replication to restore feasibility.  0.0 (default) is bitwise
    # the legacy behavior.
    mem_headroom: float = 0.0


def same_topology(a: Phase, b: Phase) -> bool:
    """True iff the two phases share the adjacency structure a
    :class:`PhaseCSR` encodes — same task/block counts, same comm edge
    endpoints, same task->block map.  Loads, volumes, memory sizes and rank
    parameters may differ freely (none of them enter the CSR).  Both the
    pipeline's CSR sharing and ``CCMState.retarget`` engine carry-over are
    gated on this predicate."""
    if a is b:
        return True
    if (a.num_tasks != b.num_tasks or a.num_blocks != b.num_blocks
            or a.num_comms != b.num_comms):
        return False
    return (np.array_equal(a.comm_src, b.comm_src)
            and np.array_equal(a.comm_dst, b.comm_dst)
            and np.array_equal(a.task_block, b.task_block))


def random_phase(key: int, *, num_ranks: int, num_tasks: int, num_blocks: int,
                 num_comms: int, mem_cap: float = 1e9,
                 load_imbalance: float = 2.0) -> Phase:
    """Synthetic phase generator for tests/benchmarks.

    Task loads are log-normal (heavy-tailed, like Gemma's near-singular
    tiles); blocks get contiguous task groups (slab-like); comms connect
    random task pairs.
    """
    rng = np.random.default_rng(key)
    load = rng.lognormal(mean=0.0, sigma=load_imbalance * 0.5, size=num_tasks)
    task_mem = rng.uniform(1e4, 1e6, size=num_tasks)
    overhead = rng.uniform(1e4, 5e5, size=num_tasks)
    # contiguous groups of tasks share a block; some tasks have none
    task_block = np.full(num_tasks, -1, np.int64)
    if num_blocks > 0:
        groups = np.array_split(rng.permutation(num_tasks), num_blocks)
        for b, g in enumerate(groups):
            take = g[: max(1, int(len(g) * 0.9))]
            task_block[take] = b
    block_size = rng.uniform(1e6, 5e7, size=num_blocks)
    block_home = rng.integers(0, num_ranks, size=num_blocks)
    src = rng.integers(0, num_tasks, size=num_comms)
    dst = rng.integers(0, num_tasks, size=num_comms)
    keep = src != dst
    src, dst = src[keep], dst[keep]
    vol = rng.lognormal(10.0, 1.0, size=src.shape[0])
    phase = Phase(
        task_load=load,
        task_mem=task_mem,
        task_overhead=overhead,
        task_block=task_block,
        block_size=block_size,
        block_home=block_home,
        comm_src=src,
        comm_dst=dst,
        comm_vol=vol,
        rank_mem_base=rng.uniform(1e6, 2e6, size=num_ranks),
        rank_mem_cap=np.full(num_ranks, mem_cap),
    )
    phase.validate()
    return phase


def scaling_phase(ranks: int) -> Phase:
    """THE ``ccmlb_scaling`` benchmark instance family (25 tasks, 3 blocks
    and 50 comm edges per rank, uncapped memory).  Lives here — not
    re-derived per consumer — because several parity bars are defined ON
    these instances: benchmarks/ccmlb_scaling.py asserts assignment
    identity across all engine configs, and benchmarks/ccmlb_async.py +
    tests/test_async_sim.py assert the async driver's zero-latency
    bitwise-parity bar on the same phases."""
    return random_phase(1, num_ranks=ranks, num_tasks=25 * ranks,
                        num_blocks=3 * ranks, num_comms=50 * ranks,
                        mem_cap=1e12)


def initial_assignment(phase: Phase, mode: str = "home") -> np.ndarray:
    """Paper default: tasks start co-located with their block's home rank."""
    k = phase.num_tasks
    if mode == "home":
        if phase.num_blocks == 0:   # blockless phase: nothing is homed
            return (np.arange(k) % phase.num_ranks).astype(np.int64)
        a = np.where(phase.task_block >= 0,
                     phase.block_home[np.clip(phase.task_block, 0, None)],
                     np.arange(k) % phase.num_ranks)
        return a.astype(np.int64)
    if mode == "round_robin":
        return (np.arange(k) % phase.num_ranks).astype(np.int64)
    raise ValueError(mode)
