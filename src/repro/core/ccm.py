"""CCM work model (paper §III): per-rank work

    W(r) = alpha*L(r) + beta*Voff(r) + gamma*Von(r) + delta*M_H(r) + eps

with the memory-capacity barrier eps in {0, +inf} (eq. 9), plus the O(1)
update formulae (eq. 2, Thm III.1) used by the optimizer to evaluate task /
cluster transfers without recomputation.

``RankState`` carries, per rank: load, on-rank volume, per-peer in/out
volumes, block presence, memory components — everything needed so that moving
a set of tasks updates W in time proportional to the tasks' edges and blocks
(not to phase size).

Scalar-vs-vectorized contract: :func:`exchange_eval` here is the REFERENCE
evaluator — one candidate exchange per call, per-edge Python accumulation.
The production path is :class:`repro.core.engine.PhaseEngine`, which scores
all candidates of a lock event in one vectorized pass over the CSR phase
view (``self.csr``, built once per state).  tests/test_engine.py asserts the
two agree; keep them in sync when touching the model.
"""
from __future__ import annotations

import dataclasses
import weakref
from typing import Callable, Dict, Iterable, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.csr import PhaseCSR
from repro.core.problem import CCMParams, Phase

TransferListener = Callable[[np.ndarray, int, int], None]

INF = float("inf")

# Relative memory-feasibility tolerance.  The old absolute ``+ 1e-6``
# slack was calibrated for nothing: on byte-scale phases (HBM budgets,
# ``balance/expert_placement``) it is immeasurable noise, while on
# normalized-unit phases it can admit placements a full 1e-6 units over
# the cap.  Scaling the slack by |cap| keeps it meaning "float
# accumulation noise" at every unit scale.
MEM_REL_TOL = 1e-9


def effective_mem_cap(cap, params: Optional[CCMParams] = None):
    """THE soft memory cap every feasibility comparison tests against.

    Single definition shared by the scalar reference (``memory_feasible``,
    ``exchange_eval``), the vectorized engine (``batch_peer_diffs`` and the
    SC scalar planes — caps are packed pre-scaled so the compiled combines
    compare plain ``<=``), and the stage-1 summary approximations — the
    paths cannot disagree about what "fits" means.

    ``params.mem_headroom`` (fraction in [0, 1)) shrinks the cap below the
    hard ``rank_mem_cap`` so the pressure policy starts migrating/evicting
    BEFORE the hard ceiling is touched; the default 0.0 skips the multiply
    entirely, keeping legacy configs bitwise-identical.  Works elementwise
    on arrays; ``inf`` caps stay ``inf``.
    """
    if params is not None and params.mem_headroom:
        cap = cap * (1.0 - params.mem_headroom)
    return cap + MEM_REL_TOL * np.abs(cap)


@dataclasses.dataclass
class CCMState:
    """Mutable evaluation state for a full assignment."""

    phase: Phase
    params: CCMParams
    assignment: np.ndarray              # (K,) task -> rank
    # derived, maintained incrementally:
    load: np.ndarray                    # (I,)
    vol: np.ndarray                     # (I, I) rank-to-rank volumes (4)
    block_count: np.ndarray             # (I, N) #tasks of block b on rank i
    mem_task: np.ndarray                # (I,) sum of task baseline memory
    mem_overhead_max: np.ndarray        # (I,) max task overhead (recomputed)

    # ------------------------------------------------------------------ build
    @staticmethod
    def build(phase: Phase, assignment: np.ndarray, params: CCMParams,
              csr: Optional[PhaseCSR] = None) -> "CCMState":
        """``csr``: a prebuilt :class:`PhaseCSR` for this phase's topology
        (task->edge / block->task adjacency).  Multi-phase pipelines pass the
        previous phase's bundle when the topology is unchanged, amortizing
        the build (see repro/core/pipeline.py); the content is identical to
        a fresh build, so results cannot differ."""
        i_n = phase.num_ranks
        assignment = np.asarray(assignment, np.int64).copy()
        load = np.bincount(assignment, weights=phase.task_load, minlength=i_n)
        if phase.rank_speed is not None:
            load = load / 1.0  # speed applied at W() time (task loads raw)
        vol = np.zeros((i_n, i_n), np.float64)
        np.add.at(vol, (assignment[phase.comm_src], assignment[phase.comm_dst]),
                  phase.comm_vol)
        block_count = np.zeros((i_n, phase.num_blocks), np.int64)
        has_blk = phase.task_block >= 0
        np.add.at(block_count,
                  (assignment[has_blk], phase.task_block[has_blk]), 1)
        mem_task = np.bincount(assignment, weights=phase.task_mem,
                               minlength=i_n)
        mem_overhead_max = np.zeros(i_n, np.float64)
        for r in range(i_n):
            sel = assignment == r
            if sel.any():
                mem_overhead_max[r] = phase.task_overhead[sel].max()
        st = CCMState(phase, params, assignment, load, vol, block_count,
                      mem_task, mem_overhead_max)
        st._build_caches(csr)
        return st

    def _build_caches(self, csr: Optional[PhaseCSR] = None):
        """CSR phase view + per-rank homing/shared caches (exchange_eval hot
        path: O(all edges + all blocks) per call -> O(touched edges +
        blocks)).  The CSR bundle is phase-static and shared with the
        vectorized engine."""
        ph = self.phase
        self.csr = csr if csr is not None else PhaseCSR.from_phase(ph)
        # monotonically increasing mutation counter: bumped by every
        # apply_transfer, so derived-value caches (engine block terms, vol
        # row sums, incident-edge sets, per-rank work) can validate with
        # one int compare and recompute bitwise-identically on miss
        self.version = 0
        self._work_cache: Dict[int, Tuple[int, float]] = {}
        # transfer listeners: every mutation (apply_transfer/swap) is
        # reported AFTER the state is consistent, so long-lived observers
        # (PhaseEngine's incremental rank segments) can update in place
        # instead of re-deriving from the assignment.  Entries are
        # zero-arg resolvers returning the callback or None once its owner
        # was garbage-collected (see add_transfer_listener).
        self._transfer_listeners: List[Callable[
            [], Optional[TransferListener]]] = []
        # per-rank task counts: lets apply_transfer keep mem_overhead_max
        # exact without a full `assignment == r` scan per commit
        self.task_count = np.bincount(self.assignment,
                                      minlength=ph.num_ranks).astype(np.int64)
        present = self.block_count > 0                     # (I, N)
        off_home = present.copy()
        off_home[ph.block_home, np.arange(ph.num_blocks)] = False
        self.hom_cache = (off_home * ph.block_size[None, :]).sum(1)
        self.shared_cache = (present * ph.block_size[None, :]).sum(1)

    def add_transfer_listener(self, cb: TransferListener) -> None:
        """Register ``cb(tasks, r_from, r_to)`` to run after every
        :meth:`apply_transfer` (tasks is the moved id array, state already
        updated).

        Bound methods are held WEAKLY so a discarded observer (e.g. a
        throwaway ``PhaseEngine`` on a long-lived state) is detached by
        garbage collection instead of being pinned forever and spliced on
        every transfer; plain functions/lambdas are held strongly (a weak
        ref to an anonymous lambda would die immediately)."""
        if hasattr(cb, "__self__"):
            self._transfer_listeners.append(weakref.WeakMethod(cb))
        else:
            self._transfer_listeners.append(lambda _cb=cb: _cb)

    def remove_transfer_listener(self, cb: TransferListener) -> None:
        """Detach a listener previously registered with
        :meth:`add_transfer_listener`, matched by equality through the
        resolver entries.  Equality (not identity) because accessing a
        bound method creates a fresh object each time — ``obj.m is obj.m``
        is False while ``obj.m == obj.m`` compares the underlying
        (receiver, function) pair; plain functions compare by identity
        either way.  Unknown callbacks are a no-op; already-collected
        entries are pruned on the way through."""
        self._transfer_listeners = [
            e for e in self._transfer_listeners
            if e() is not None and e() != cb]

    def retarget(self, phase: Phase, params: CCMParams) -> None:
        """Re-bind this state to a NEW phase with the same adjacency
        topology (the ``same_topology`` predicate: identical comm endpoints
        and task->block map — callers check it; this method only asserts
        the counts), keeping the assignment.

        Multi-phase pipelines use this to carry a state+engine across
        phases whose loads/volumes/memory drift while the topology holds:
        the value-derived arrays (load, vol, mem_task, overhead maxima,
        homing/shared caches) are recomputed with the SAME operations a
        fresh ``build`` runs — bitwise-identical results, asserted by
        tests/test_spec_scan.py — while the topology-derived structures are
        carried: the frozen CSR bundle (the expensive part), the integer
        block counters (incrementally exact for the unchanged assignment),
        and the registered transfer listeners (a carried engine's segments
        depend only on the assignment, which is unchanged).  Bumps
        ``version`` so every version-validated downstream cache
        re-derives."""
        if (phase.num_tasks != self.phase.num_tasks
                or phase.num_ranks != self.phase.num_ranks
                or phase.num_blocks != self.phase.num_blocks):
            raise ValueError("retarget requires matching task/rank/block "
                             "counts (same_topology phases)")
        i_n = phase.num_ranks
        a = self.assignment
        self.phase = phase
        self.params = params
        self.version += 1
        self._work_cache.clear()
        # the heavy-edge threshold cache is keyed on quantile but derived
        # from phase.comm_vol — a drifted phase must not reuse it
        if getattr(self, "_quantile_cache", None) is not None:
            self._quantile_cache.clear()
        load = np.bincount(a, weights=phase.task_load, minlength=i_n)
        if phase.rank_speed is not None:
            load = load / 1.0  # mirror build(): speed applied at W() time
        self.load = load
        vol = np.zeros((i_n, i_n), np.float64)
        np.add.at(vol, (a[phase.comm_src], a[phase.comm_dst]),
                  phase.comm_vol)
        self.vol = vol
        self.mem_task = np.bincount(a, weights=phase.task_mem,
                                    minlength=i_n)
        self.mem_overhead_max = np.zeros(i_n, np.float64)
        for r in range(i_n):
            sel = a == r
            if sel.any():
                self.mem_overhead_max[r] = phase.task_overhead[sel].max()
        present = self.block_count > 0
        off_home = present.copy()
        off_home[phase.block_home, np.arange(phase.num_blocks)] = False
        self.hom_cache = (off_home * phase.block_size[None, :]).sum(1)
        self.shared_cache = (present * phase.block_size[None, :]).sum(1)

    def _touched_edges(self, tasks: np.ndarray) -> np.ndarray:
        """Unique ids of comm edges incident to ``tasks`` (CSR gather)."""
        if len(tasks) == 0:
            return np.zeros(0, np.int64)
        return np.unique(self.csr.task_edges.gather(np.asarray(tasks)))

    # ----------------------------------------------------------------- pieces
    def off_rank_volume(self, r: int) -> float:
        """V_notin(r): max(sent off-rank, received off-rank) (eq. 5)."""
        sent = self.vol[r].sum() - self.vol[r, r]
        recv = self.vol[:, r].sum() - self.vol[r, r]
        return float(max(sent, recv))

    def on_rank_volume(self, r: int) -> float:
        return float(self.vol[r, r])

    def homing_cost(self, r: int) -> float:
        """M_H(r): bytes of blocks present on r that are not homed at r (10)."""
        return float(self.hom_cache[r])

    def rank_shared_mem(self, r: int) -> float:
        return float(self.shared_cache[r])

    def max_memory(self, r: int) -> float:
        """M_max(r) (eq. 7): baseline + task memory (6) + shared blocks."""
        return (self.phase.rank_mem_base[r] + self.mem_task[r]
                + self.mem_overhead_max[r] + self.rank_shared_mem(r))

    def memory_feasible(self, r: int) -> bool:
        return self.max_memory(r) <= effective_mem_cap(
            self.phase.rank_mem_cap[r], self.params)

    def work(self, r: int) -> float:
        """W(r) (eq. 13).  Cached per state version: the hot path asks for
        the same rank's work several times between transfers (lock-event
        w_before, stage traces), and a hit returns the float the recompute
        produced — bitwise-neutral."""
        hit = self._work_cache.get(r)
        if hit is not None and hit[0] == self.version:
            return hit[1]
        p = self.params
        if p.memory_constraint and not self.memory_feasible(r):
            w = INF
        else:
            w = float(p.alpha * self.load[r] / self.phase.rank_speed[r]
                      + p.beta * self.off_rank_volume(r)
                      + p.gamma * self.on_rank_volume(r)
                      + p.delta * self.homing_cost(r))
        self._work_cache[r] = (self.version, w)
        return w

    def all_work(self) -> np.ndarray:
        return np.array([self.work(r) for r in range(self.phase.num_ranks)])

    def max_work(self) -> float:
        return float(self.all_work().max())

    def total_work(self) -> float:
        w = self.all_work()
        return float(w.sum())

    def imbalance(self) -> float:
        """I_L = max(L)/mean(L) - 1 (§II-A, on loads)."""
        mu = self.load.mean()
        return float(self.load.max() / mu - 1.0) if mu > 0 else 0.0

    # ------------------------------------------------------- transfer updates
    def apply_transfer(self, tasks: Sequence[int], r_from: int, r_to: int):
        """Mutate state: move tasks from r_from to r_to (update formulae)."""
        ph = self.phase
        self.version += 1
        tasks = np.asarray(list(tasks), np.int64)
        assert (self.assignment[tasks] == r_from).all()
        self.assignment[tasks] = r_to
        moved_load = ph.task_load[tasks].sum()
        self.load[r_from] -= moved_load          # eq. (2)
        self.load[r_to] += moved_load
        # communication volumes: edges incident to moved tasks change buckets
        moved = np.zeros(ph.num_tasks, bool)
        moved[tasks] = True
        eids = self._touched_edges(tasks)
        if eids.size:
            # assignment already updated; reconstruct old buckets
            src, dst = ph.comm_src[eids], ph.comm_dst[eids]
            s_new = self.assignment[src]
            d_new = self.assignment[dst]
            s_old = np.where(moved[src], r_from, s_new)
            d_old = np.where(moved[dst], r_from, d_new)
            v = ph.comm_vol[eids]
            np.subtract.at(self.vol, (s_old, d_old), v)
            np.add.at(self.vol, (s_new, d_new), v)
        # blocks (+ presence caches: homing / shared-memory transitions)
        blk = ph.task_block[tasks]
        for b in blk[blk >= 0]:
            size = ph.block_size[b]
            self.block_count[r_from, b] -= 1
            if self.block_count[r_from, b] == 0:
                self.shared_cache[r_from] -= size
                if ph.block_home[b] != r_from:
                    self.hom_cache[r_from] -= size
            if self.block_count[r_to, b] == 0:
                self.shared_cache[r_to] += size
                if ph.block_home[b] != r_to:
                    self.hom_cache[r_to] += size
            self.block_count[r_to, b] += 1
        # task memory
        moved_mem = ph.task_mem[tasks].sum()
        self.mem_task[r_from] -= moved_mem
        self.mem_task[r_to] += moved_mem
        # overhead maxima: exact incremental update.  The receiving max
        # only grows (toward the moved max); the sender needs a rescan
        # only when the departing set could have held its maximum —
        # float max has no rounding, so the rescan-on-demand value is
        # bitwise what the old full `assignment == r` scans computed.
        k = int(tasks.size)
        mo = float(ph.task_overhead[tasks].max()) if k else 0.0
        old_from = float(self.mem_overhead_max[r_from])
        if self.task_count[r_to] == 0:
            self.mem_overhead_max[r_to] = mo
        elif mo > self.mem_overhead_max[r_to]:
            self.mem_overhead_max[r_to] = mo
        self.task_count[r_from] -= k
        self.task_count[r_to] += k
        if self.task_count[r_from] == 0:
            self.mem_overhead_max[r_from] = 0.0
        elif k and mo >= old_from:
            self.mem_overhead_max[r_from] = \
                ph.task_overhead[self.assignment == r_from].max()
        if self._transfer_listeners:
            dead = False
            for entry in self._transfer_listeners:
                cb = entry()
                if cb is None:
                    dead = True
                else:
                    cb(tasks, r_from, r_to)
            if dead:    # prune collected observers
                self._transfer_listeners = [
                    e for e in self._transfer_listeners if e() is not None]

    def swap(self, tasks_a: Sequence[int], r_a: int, tasks_b: Sequence[int],
             r_b: int):
        if len(tasks_a):
            self.apply_transfer(tasks_a, r_a, r_b)
        if len(tasks_b):
            self.apply_transfer(tasks_b, r_b, r_a)


@dataclasses.dataclass
class ExchangeEval:
    """Work of the two endpoints after a candidate exchange (no mutation)."""

    work_a_after: float
    work_b_after: float
    feasible: bool

    @property
    def max_after(self) -> float:
        return max(self.work_a_after, self.work_b_after)


def exchange_eval(state: CCMState, tasks_ab: Sequence[int],
                  tasks_ba: Sequence[int], r_a: int, r_b: int) -> ExchangeEval:
    """Evaluate moving ``tasks_ab`` (a->b) and ``tasks_ba`` (b->a)
    simultaneously, via the update formulae — O(moved tasks + their edges +
    their blocks); does NOT mutate state.
    """
    ph = state.phase
    p = state.params
    tasks_ab = np.asarray(list(tasks_ab), np.int64)
    tasks_ba = np.asarray(list(tasks_ba), np.int64)
    load_ab = ph.task_load[tasks_ab].sum()
    load_ba = ph.task_load[tasks_ba].sum()
    load_a = state.load[r_a] - load_ab + load_ba
    load_b = state.load[r_b] + load_ab - load_ba

    # --- communication deltas ------------------------------------------------
    moved_all = np.concatenate([tasks_ab, tasks_ba])
    new_rank_map: Dict[int, int] = {}
    for t in tasks_ab:
        new_rank_map[int(t)] = r_b
    for t in tasks_ba:
        new_rank_map[int(t)] = r_a
    dvol: Dict[Tuple[int, int], float] = {}
    a = state.assignment
    for e in state._touched_edges(moved_all):
        ts, td = int(ph.comm_src[e]), int(ph.comm_dst[e])
        s, d = a[ts], a[td]
        s2 = new_rank_map.get(ts, s)
        d2 = new_rank_map.get(td, d)
        v = ph.comm_vol[e]
        dvol[(s, d)] = dvol.get((s, d), 0.0) - v
        dvol[(s2, d2)] = dvol.get((s2, d2), 0.0) + v

    def off_after(r: int) -> float:
        sent = state.vol[r].sum() - state.vol[r, r]
        recv = state.vol[:, r].sum() - state.vol[r, r]
        for (s, d), v in dvol.items():
            if s == r and d != r:
                sent += v
            if d == r and s != r:
                recv += v
        return max(sent, recv)

    def on_after(r: int) -> float:
        return state.vol[r, r] + dvol.get((r, r), 0.0)

    # --- homing / shared-block deltas (Thm III.1, both directions) ----------
    dcount: Dict[int, Tuple[int, int]] = {}  # block -> (delta on a, delta on b)
    for b in ph.task_block[tasks_ab]:
        if b >= 0:
            da, db = dcount.get(int(b), (0, 0))
            dcount[int(b)] = (da - 1, db + 1)
    for b in ph.task_block[tasks_ba]:
        if b >= 0:
            da, db = dcount.get(int(b), (0, 0))
            dcount[int(b)] = (da + 1, db - 1)

    hom = {r_a: state.homing_cost(r_a), r_b: state.homing_cost(r_b)}
    shared = {r_a: state.rank_shared_mem(r_a), r_b: state.rank_shared_mem(r_b)}
    for b, (da, db) in dcount.items():
        size = ph.block_size[b]
        for r, dc in ((r_a, da), (r_b, db)):
            before = state.block_count[r, b]
            after = before + dc
            if before > 0 and after == 0:
                shared[r] -= size
                if ph.block_home[b] != r:
                    hom[r] -= size
            elif before == 0 and after > 0:
                shared[r] += size
                if ph.block_home[b] != r:
                    hom[r] += size

    # --- memory feasibility ---------------------------------------------------
    mem_ab = ph.task_mem[tasks_ab].sum()
    mem_ba = ph.task_mem[tasks_ba].sum()
    over_ab = ph.task_overhead[tasks_ab].max() if len(tasks_ab) else 0.0
    over_ba = ph.task_overhead[tasks_ba].max() if len(tasks_ba) else 0.0
    mem_a = (ph.rank_mem_base[r_a] + state.mem_task[r_a] - mem_ab + mem_ba
             + shared[r_a] + max(state.mem_overhead_max[r_a], over_ba))
    mem_b = (ph.rank_mem_base[r_b] + state.mem_task[r_b] + mem_ab - mem_ba
             + shared[r_b] + max(state.mem_overhead_max[r_b], over_ab))
    feasible = True
    if p.memory_constraint:
        feasible = (mem_a <= effective_mem_cap(ph.rank_mem_cap[r_a], p)
                    and mem_b <= effective_mem_cap(ph.rank_mem_cap[r_b], p))

    def w(load, off, on, h, r):
        return (p.alpha * load / ph.rank_speed[r] + p.beta * off
                + p.gamma * on + p.delta * h)

    wa = w(load_a, off_after(r_a), on_after(r_a), hom[r_a], r_a)
    wb = w(load_b, off_after(r_b), on_after(r_b), hom[r_b], r_b)
    if not feasible:
        wa, wb = INF, INF
    return ExchangeEval(float(wa), float(wb), bool(feasible))
