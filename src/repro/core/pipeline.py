"""Multi-phase CCM-LB orchestrator (paper §III-B, iterative executions).

The paper's setting is an application that runs a SEQUENCE of phases and
re-invokes the balancer each time — warm, not from scratch.  This module
turns the single-phase :func:`repro.core.ccmlb.ccm_lb` into that loop:

  * **warm-started assignments** — phase ``k+1`` starts from phase ``k``'s
    balanced output, mapped through shared persistent task ids
    (:func:`warm_start_assignment`).  Tasks present in both phases keep
    their rank; new tasks fall back to the phase's initial-assignment rule.
    On slowly-drifting workloads this leaves the balancer a near-balanced
    start, so later phases converge in a fraction of the transfers.
  * **amortized CSR builds** — consecutive phases whose adjacency topology
    is unchanged (same comm endpoints, same task->block map;
    :func:`same_topology`) share one frozen :class:`PhaseCSR` bundle
    instead of rebuilding it per phase.  The bundle's content is identical
    to a fresh build, so sharing cannot change results.
  * **per-phase traces** — :class:`PipelineResult` keeps every phase's
    :class:`CCMLBResult` plus orchestration metadata (warm-start coverage,
    CSR reuse, wall-clock seconds).

Parity contract: over phases run with ``warm_start=True`` the pipeline is
trajectory-IDENTICAL to hand-chaining ``ccm_lb`` calls with each phase
seeded ``seed + k`` and started from the previous result's assignment
(tests/test_pipeline.py asserts it) — the orchestrator only removes
redundant work, it never changes what the balancer computes.
"""
from __future__ import annotations

import dataclasses
import time
from typing import List, Optional, Sequence, Tuple, Union

import numpy as np

from repro.core.ccmlb import CCMLBResult, ccm_lb
from repro.core.csr import PhaseCSR
from repro.core.problem import (CCMParams, Phase, initial_assignment,
                                same_topology)
from repro.runtime.elastic import RankJoin, expand_phase

__all__ = ["PipelinePhase", "PhaseRun", "PipelineResult",
           "ccm_lb_pipeline", "same_topology", "warm_start_assignment",
           "RankJoin"]


@dataclasses.dataclass
class PipelinePhase:
    """One phase of an iterative execution.

    ``task_ids``: optional persistent GLOBAL id per task (shape
    ``(num_tasks,)``, unique).  Two phases' tasks are matched by these ids
    for warm starting; omitted, tasks are matched positionally (valid only
    when consecutive phases have the same task count).
    """

    phase: Phase
    task_ids: Optional[np.ndarray] = None

    def __post_init__(self):
        if self.task_ids is not None:
            self.task_ids = np.asarray(self.task_ids, np.int64)
            if self.task_ids.shape[0] != self.phase.num_tasks:
                raise ValueError("task_ids must have one id per task")


@dataclasses.dataclass
class PhaseRun:
    """One phase's balancing outcome plus orchestration metadata."""

    result: CCMLBResult
    warm_started: bool      # start mapped from the previous phase's output
    csr_reused: bool        # PhaseCSR shared with the previous phase
    carried_tasks: int      # tasks whose rank was carried over
    seconds: float          # wall-clock of this phase's ccm_lb call
    engine_carried: bool = False    # state+engine retargeted, not rebuilt


@dataclasses.dataclass
class PipelineResult:
    """Per-phase results of one pipeline run (index = phase position)."""

    runs: List[PhaseRun]

    @property
    def assignments(self) -> List[np.ndarray]:
        return [r.result.assignment for r in self.runs]

    @property
    def final_assignment(self) -> np.ndarray:
        return self.runs[-1].result.assignment

    @property
    def total_transfers(self) -> int:
        return sum(r.result.transfers for r in self.runs)

    @property
    def total_seconds(self) -> float:
        return sum(r.seconds for r in self.runs)

    def max_work(self) -> List[List[float]]:
        """Per-phase max-work traces (incl. each phase's initial point)."""
        return [r.result.max_work for r in self.runs]


def warm_start_assignment(prev_phase: Phase, prev_assignment: np.ndarray,
                          next_phase: Phase, *,
                          prev_ids: Optional[np.ndarray] = None,
                          next_ids: Optional[np.ndarray] = None,
                          mode: str = "home") -> Tuple[np.ndarray, int]:
    """Map a balanced assignment onto the next phase's task set.

    Tasks matched between the phases (by persistent id, or positionally
    when both id arrays are omitted and the counts agree) start on their
    previous rank — clipped to ranks that exist in ``next_phase``;
    unmatched tasks start from ``initial_assignment(next_phase, mode)``.
    Returns ``(assignment, carried)`` where ``carried`` counts the matched
    tasks.

    The rank clipping doubles as the crash-recovery path: the async fault
    harness (repro/core/async_sim.py) renumbers the survivor set with
    ``repro.runtime.elastic.survivor_resize`` — dead ranks map OUT of
    range — and warm-starts through here, so exactly the tasks stranded
    on dead ranks fall back to the fresh initial placement while every
    surviving task keeps its rank.
    """
    prev_assignment = np.asarray(prev_assignment, np.int64)
    base = initial_assignment(next_phase, mode)
    if prev_ids is None and next_ids is None:
        if prev_phase.num_tasks != next_phase.num_tasks:
            return base, 0
        ok = prev_assignment < next_phase.num_ranks
        out = np.where(ok, prev_assignment, base).astype(np.int64)
        return out, int(ok.sum())
    if prev_ids is None:
        prev_ids = np.arange(prev_phase.num_tasks, dtype=np.int64)
    if next_ids is None:
        next_ids = np.arange(next_phase.num_tasks, dtype=np.int64)
    order = np.argsort(prev_ids, kind="stable")
    sorted_ids = prev_ids[order]
    if sorted_ids.size == 0:    # empty previous phase: nothing to carry
        return base, 0
    pos = np.searchsorted(sorted_ids, next_ids)
    pos_c = np.minimum(pos, sorted_ids.shape[0] - 1)
    hit = sorted_ids[pos_c] == next_ids
    ranks = prev_assignment[order[pos_c]]
    ok = hit & (ranks < next_phase.num_ranks)
    out = np.where(ok, ranks, base).astype(np.int64)
    return out, int(ok.sum())


def ccm_lb_pipeline(phases: Sequence[Union[Phase, PipelinePhase]],
                    params: Union[CCMParams, Sequence[CCMParams]], *,
                    warm_start: bool = True,
                    reuse_csr: bool = True,
                    carry_engine: bool = False,
                    initial_mode: str = "home",
                    a0: Optional[np.ndarray] = None,
                    seed: int = 0,
                    membership: tuple = (),
                    **lb_kwargs) -> PipelineResult:
    """Balance a sequence of phases with warm-started assignments and
    amortized CSR builds.

    ``params`` is one :class:`CCMParams` shared by every phase, or a
    sequence with one entry per phase (consumers that re-derive
    coefficients per phase, e.g. a beta tracking the activation size).
    ``a0`` overrides the derived start: with ``warm_start=True`` it seeds
    the first phase (later phases warm-start from the previous output);
    with ``warm_start=False`` — the cold reference — every phase of
    matching task count starts from ``a0``, or from ``initial_mode`` when
    ``a0`` is omitted.  Phase ``k`` runs with seed ``seed + k``.

    ``carry_engine=True`` additionally hands each ``ccm_lb`` call the
    previous phase's result as ``carry``: when the phases share topology
    and the warm start carried the full assignment, the CCMState is
    retargeted in place (bitwise-equal to a rebuild; see
    ``CCMState.retarget``) and the incremental engine — segments, edge
    caches — survives across the phase boundary, as does the phase's
    :class:`~repro.core.quiesce.QuiesceTracker`: when the new phase's
    value arrays and params are unchanged too, its cluster/summary/gossip
    caches stay live across the boundary (epochs restart at 0 and the new
    seed forces the same full gossip redraw a fresh run performs, so
    trajectories are bitwise those of an uncarried run).  ``ccm_lb``
    falls back to a fresh build silently whenever the carry conditions
    fail, so enabling this can only remove redundant work; ``PhaseRun.
    engine_carried`` reports which happened per phase.  Requires
    ``warm_start`` (a cold start discards the assignment the carried
    state serves).
    ``membership``: :class:`~repro.runtime.elastic.RankJoin` events (or
    plain ``(iteration, count)`` tuples) whose ``iteration`` names the
    PHASE index at which fresh ranks join the stream.  From that phase
    onward every phase's rank set is expanded with the joined rows
    (capacities/speed resolved once, at join time, against the
    then-current mesh — median defaults), so a pod that joins mid-stream
    persists; the warm start carries every task (old ranks all remain
    valid) and the joiners fill through ordinary balancing.  Topology is
    rank-independent, so CSR sharing across the join boundary is
    unaffected; ``carry_engine`` falls back to a fresh build for exactly
    the join phase (rank counts differ) and resumes after it.
    Remaining keyword arguments (``n_iter``, ``fanout``, ``use_engine``,
    ``backend`` — including the compiled ``"jit"`` scorer runtime, whose
    shape buckets persist across phases so a long stream compiles exactly
    once — ``batch_lock_events``, ``quiesce_after`` for early exit once a
    phase stops transferring, ...) pass through to every :func:`ccm_lb`
    call.
    """
    if not phases:
        raise ValueError("ccm_lb_pipeline needs at least one phase")
    if carry_engine and not warm_start:
        raise ValueError("carry_engine requires warm_start=True")
    if isinstance(params, CCMParams):
        params_seq: List[CCMParams] = [params] * len(phases)
    else:
        params_seq = list(params)
        if len(params_seq) != len(phases):
            raise ValueError("params sequence must match the phase count")
    joins = [j if isinstance(j, RankJoin) else RankJoin(*j)
             for j in membership]
    for j in joins:
        if not 0 <= j.iteration < len(phases):
            raise ValueError(f"membership event {j!r}: phase index out of "
                             f"range [0, {len(phases)})")
    joined_rows: List[Tuple[float, float, float]] = []
    runs: List[PhaseRun] = []
    prev: Optional[Tuple[Phase, np.ndarray, Optional[np.ndarray]]] = None
    csr: Optional[PhaseCSR] = None
    csr_phase: Optional[Phase] = None
    for k, item in enumerate(phases):
        pp = item if isinstance(item, PipelinePhase) else PipelinePhase(item)
        ph = pp.phase
        # ranks joined at an earlier phase persist: re-apply their rows,
        # then resolve this phase's joins against the expanded mesh
        for mb, mc, sp in joined_rows:
            ph = expand_phase(ph, 1, mem_base=mb, mem_cap=mc, speed=sp)
        for j in joins:
            if j.iteration != k:
                continue
            for _ in range(j.count):
                mb = (float(np.median(ph.rank_mem_base))
                      if j.mem_base is None else float(j.mem_base))
                mc = (float(np.median(ph.rank_mem_cap))
                      if j.mem_cap is None else float(j.mem_cap))
                sp = (float(np.median(ph.rank_speed))
                      if j.speed is None else float(j.speed))
                joined_rows.append((mb, mc, sp))
                ph = expand_phase(ph, 1, mem_base=mb, mem_cap=mc, speed=sp)
        carried = 0
        use_a0 = a0 is not None and (k == 0 or not warm_start) \
            and np.asarray(a0).shape[0] == ph.num_tasks
        if use_a0:
            start = np.asarray(a0, np.int64).copy()
        elif warm_start and prev is not None:
            start, carried = warm_start_assignment(
                prev[0], prev[1], ph, prev_ids=prev[2], next_ids=pp.task_ids,
                mode=initial_mode)
        else:
            start = initial_assignment(ph, initial_mode)
        # timer covers the CSR build too: a cold run (csr=None) pays it
        # inside ccm_lb, so starting the clock here keeps cold/warm
        # per-phase seconds comparable
        t0 = time.perf_counter()
        reused = csr is not None and same_topology(csr_phase, ph)
        if not reused:
            if not reuse_csr:
                csr = None
            else:
                csr = PhaseCSR.from_phase(ph)
                csr_phase = ph
        carry = (runs[-1].result
                 if carry_engine and warm_start and runs else None)
        res = ccm_lb(ph, start, params_seq[k], seed=seed + k, csr=csr,
                     carry=carry, **lb_kwargs)
        runs.append(PhaseRun(result=res, warm_started=carried > 0,
                             csr_reused=reused, carried_tasks=carried,
                             seconds=time.perf_counter() - t0,
                             engine_carried=res.engine_carried))
        prev = (ph, res.assignment, pp.task_ids)
    return PipelineResult(runs)
