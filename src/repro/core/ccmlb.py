"""CCM-LB: the distributed, heuristic load-balancing algorithm (paper §IV,
Fig. 1), as a deterministic multi-rank discrete-event simulation.

Per iteration:
  1. cluster tasks on every rank (shared blocks + heavy comm edges);
  2. augmented inform stage — gossip rank+cluster summaries with ``fanout``
     over ``k_rounds`` (core/gossip.py);
  3. every rank scores its known peers with the stale-info approximation and
     builds a sorted work_list;
  4. lock/transfer stage — ranks try to lock their best peers (deadlock-free
     priority rule), then evaluate exactly (update formulae) with fresh info
     and execute the best cluster give/swap.

Evaluation engine: with ``use_engine=True`` (default) stages 3 and 4 run on
the vectorized :class:`~repro.core.engine.PhaseEngine` — stage 3 scores all
of a rank's known peers with one matrix op, stage 4 scores all shortlisted
cluster pairs of a lock event in one batched pass.  ``use_engine=False``
keeps the seed's scalar per-candidate loops (the reference path); both
produce identical transfer traces on the parity suite
(tests/test_engine.py; see repro/core/engine.py for the exact strength of
that guarantee — stage-2 scores may differ by summation-order ulps, so a
sub-ulp near-tie between two candidate exchanges could in principle
diverge the paths).

Incremental engine state: the engine is a long-lived object whose per-rank
member-task segments are updated in place by a transfer listener on the
``CCMState`` — every mutation this module performs (direct transfers,
grant-chain handoffs, batched deferred flushes) goes through
``state.swap``/``state.apply_transfer`` and therefore through that hook;
the per-transfer cluster rebuilds pass ``rank_tasks=engine.rank_tasks`` so
``build_clusters(only_ranks=...)`` touches only the two ranks' tasks and
their incident edges.  The served segments are bitwise what an assignment
scan returns (parity guarantee: tests/test_incremental.py asserts segments
and end-to-end trajectories against ``incremental=False``, the full
re-gather reference that remains available for A/B benchmarking).

``backend`` selects the engine's stage-4 tile scorer: ``"numpy"`` (the
reference), ``"jit"`` (the shape-bucketed compiled runtime — scores are
bitwise-equal to numpy, one XLA compile per shape bucket), ``"pallas"``
(the kernel in interpret mode, bitwise-equal) or ``"pallas_compiled"``
(f32 tiles on the 128-lane boundary; assignment-identity parity tier).
See repro/kernels/ccm_scorer/README.md for the backend matrix.

Batched lock events: ``batch_lock_events=k`` defers the scoring of up to
``k`` executable lock events whose rank pairs are pairwise disjoint, then
scores them in ONE engine call (one block-diagonal flow assembly, one
Pallas launch under ``backend="pallas"``).  Trajectory-exact in exact
arithmetic: a transfer between ranks (a, b) cannot change the score,
shortlist or clusters of a disjoint pair (c, d) — see
``PhaseEngine.batch_exchange_eval_multi`` — and the event sequence itself
is independent of scoring outcomes (turn order is fixed by the stage-3
work lists and the lock protocol).  The batch is flushed the moment a turn
touches a rank with a deferred event, on a full batch, and at stage end,
so the sequential order of state mutations is preserved.  Grant-chain
handoffs ride the same deferred machinery as single-event batches: each
chain transfer on (cur, p) is appended to the pending batch (joining
already-deferred disjoint events) and the shared rank p forces a flush
before the next chain element scores — the same disjointness argument, the
same sequential mutation order.
The guarantee carries the same sub-ulp caveat as the engine-vs-scalar
contract: a disjoint (a, b) swap relabels entries of vol rows/columns of
third ranks without changing their true sums, so the ``st.vol[r].sum()``
bases a deferred event reads can differ from the sequential path's
post-swap re-summation by summation-order ulps — a near-tie inside that
window could in principle flip the selected exchange.
tests/test_engine.py and the scaling benchmark assert identical
trajectories empirically (they hold on every tested instance).

Returns the improved assignment plus a trace (max work, imbalance, transfers
per iteration) used by tests and benchmarks.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.ccm import CCMState
from repro.core.clusters import (build_clusters, summarize_clusters,
                                 summarize_rank)
from repro.core.engine import (ExchangeEvent, PhaseEngine, batch_peer_diffs,
                               build_summary_tables)
from repro.core.gossip import build_peer_networks
from repro.core.locks import LockManager
from repro.core.problem import CCMParams, Phase
from repro.core.transfer import (approx_best_diff, select_best,
                                 shortlist_pairs, try_transfer)


@dataclasses.dataclass
class CCMLBResult:
    assignment: np.ndarray
    state: CCMState
    max_work: List[float]          # per iteration (incl. initial)
    total_work: List[float]
    imbalance: List[float]
    transfers: int
    lock_conflicts: int
    engine_used: bool = True


def ccm_lb(phase: Phase, assignment: np.ndarray, params: CCMParams, *,
           n_iter: int = 4, k_rounds: int = 2, fanout: int = 4,
           seed: int = 0, max_candidates: int = 12,
           max_clusters_per_rank: Optional[int] = None,
           use_engine: bool = True, backend: str = "numpy",
           batch_lock_events: int = 1, incremental: bool = True,
           csr=None) -> CCMLBResult:
    """``incremental`` keeps the engine's per-rank segments current via the
    transfer hook (default; ``False`` re-gathers per event — the rebuild
    reference).  ``csr`` is an optional prebuilt ``PhaseCSR`` for this
    phase's topology (multi-phase pipelines amortize it)."""
    if batch_lock_events < 1:
        raise ValueError("batch_lock_events must be >= 1")
    if batch_lock_events > 1 and not use_engine:
        raise ValueError("batch_lock_events > 1 requires use_engine=True")
    state = CCMState.build(phase, assignment, params, csr=csr)
    engine = (PhaseEngine(state, backend=backend, incremental=incremental)
              if use_engine else None)
    trace_max = [state.max_work()]
    trace_tot = [state.total_work()]
    trace_imb = [state.imbalance()]
    transfers = 0
    conflicts = 0

    for it in range(n_iter):
        clusters = build_clusters(state,
                                  max_clusters_per_rank=max_clusters_per_rank)
        csum = summarize_clusters(state, clusters)
        summaries = {r: summarize_rank(state, r, csum[r])
                     for r in range(phase.num_ranks)}
        info = build_peer_networks(summaries, k_rounds=k_rounds,
                                   fanout=fanout, seed=seed * 1000 + it)

        # stage 1: score peers from (stale) gossip info.  The batched path
        # reads the global summary tables — valid because gossip payloads
        # are references to this iteration's summary objects, so only the
        # known-peer SETS are stale, never the values (see batch_peer_diffs)
        work_lists: Dict[int, deque] = {}
        if engine is not None:
            tables = build_summary_tables(summaries, params)
        for r in range(phase.num_ranks):
            scored: List[Tuple[float, int]] = []
            if engine is not None:
                peers = np.array([p for p in info[r] if p != r], np.int64)
                # the tables are valid stand-ins for the gossip payloads
                # only while payloads alias this iteration's summaries
                assert all(info[r][int(p)] is summaries[int(p)]
                           for p in peers), \
                    "gossip payloads must alias current summaries"
                diffs = batch_peer_diffs(tables, r, peers, params)
                scored = [(float(d), int(p)) for d, p in zip(diffs, peers)
                          if d > 0]
            else:
                for p, psum in info[r].items():
                    if p == r:
                        continue
                    diff = approx_best_diff(summaries[r], psum, params)
                    if diff > 0:
                        scored.append((diff, p))
            scored.sort(key=lambda t: (-t[0], t[1]))
            work_lists[r] = deque(scored)

        # stage 2: lock/transfer event loop
        if batch_lock_events > 1:
            dt, dc = _stage2_batched(phase, state, clusters, work_lists,
                                     engine, max_candidates,
                                     max_clusters_per_rank, batch_lock_events)
        else:
            dt, dc = _stage2(phase, state, clusters, work_lists, engine,
                             max_candidates, max_clusters_per_rank)
        transfers += dt
        conflicts += dc

        trace_max.append(state.max_work())
        trace_tot.append(state.total_work())
        trace_imb.append(state.imbalance())

    return CCMLBResult(state.assignment.copy(), state, trace_max, trace_tot,
                       trace_imb, transfers, conflicts,
                       engine_used=engine is not None)


def _rebuild_local(state, clusters, engine, max_clusters_per_rank, r, p):
    """Post-transfer cluster rebuild for the two touched ranks, fed from the
    engine's incremental segments when available."""
    rt = (engine.rank_tasks
          if engine is not None and engine.incremental else None)
    local = build_clusters(state, max_clusters_per_rank=max_clusters_per_rank,
                           only_ranks=[r, p], rank_tasks=rt)
    clusters[r] = local[r]
    clusters[p] = local[p]


def _stage2(phase, state, clusters, work_lists, engine, max_candidates,
            max_clusters_per_rank) -> Tuple[int, int]:
    """One-event-at-a-time lock/transfer loop (the reference event order)."""
    transfers = conflicts = 0
    locks = LockManager(phase.num_ranks)
    # round-robin over ranks for fairness; each "turn" a rank either
    # requests its best remaining peer or is idle.  Queued lock requests
    # are drained synchronously on release (_handle_grant), so a
    # non-empty active deque is the only liveness condition.
    active = deque(r for r in range(phase.num_ranks) if work_lists[r])
    spins = 0
    max_spins = 50 * phase.num_ranks + 1000
    while active and spins < max_spins:
        spins += 1
        r = active.popleft()
        if not work_lists[r]:
            continue
        diff, p = work_lists[r].popleft()
        granted = locks.request(r, p)
        if not granted:
            conflicts += 1
            # re-queue the attempt at the back (retry later)
            work_lists[r].append((diff * 0.5, p))
            if work_lists[r]:
                active.append(r)
            continue
        # granted: deadlock-avoidance check (Fig.1 line 45)
        if locks.must_yield(r, p):
            conflicts += 1
            nxt = locks.release(r, p)
            work_lists[r].append((diff, p))
            active.append(r)
            if nxt is not None:
                transfers += _handle_grant(
                    nxt, p, state, clusters, locks, work_lists, active,
                    max_candidates, max_clusters_per_rank, engine)
            continue
        # fresh info exchange + exact transfer (recvUpdate/TryTransfer)
        best = try_transfer(state, clusters[r], clusters[p], r, p,
                            max_candidates, engine=engine)
        if best is not None:
            transfers += 1
            # cluster membership changed on r and p: rebuild locally
            _rebuild_local(state, clusters, engine, max_clusters_per_rank,
                           r, p)
        nxt = locks.release(r, p)
        if nxt is not None:
            transfers += _handle_grant(
                nxt, p, state, clusters, locks, work_lists, active,
                max_candidates, max_clusters_per_rank, engine)
        if work_lists[r]:
            active.append(r)
    return transfers, conflicts


@dataclasses.dataclass
class _PendingEvent:
    """An executable lock event whose scoring has been deferred."""

    r: int
    p: int
    cand_a: list
    cand_b: list
    pairs: np.ndarray       # (P, 2) shortlist rows
    agg_a: object
    agg_b: object
    w_before: float


def _stage2_batched(phase, state, clusters, work_lists, engine,
                    max_candidates, max_clusters_per_rank,
                    batch: int) -> Tuple[int, int]:
    """Lock/transfer loop with deferred, batched event scoring.

    Identical turn order to :func:`_stage2` (lock state never outlives a
    turn, so request/grant outcomes cannot differ); only the try_transfer
    evaluation of up to ``batch`` pairwise-disjoint events is deferred and
    executed at flush points in original event order.  Flushes happen
    before any turn that touches a deferred rank, on a full batch, and at
    stage end — exactly the moments the sequential loop would have
    interleaved state mutations.  Grant-chain handoffs go through
    :func:`_handle_grant_deferred`: each chain event joins the pending
    batch as a single-event entry (it may share a flush with
    already-deferred DISJOINT events; the chain's shared rank ``p`` forces
    a flush before the next chain element scores), so chains ride the same
    deferred-scoring machinery with the same trajectory argument.
    """
    transfers = conflicts = 0
    locks = LockManager(phase.num_ranks)
    active = deque(r for r in range(phase.num_ranks) if work_lists[r])
    pending: List[_PendingEvent] = []
    busy: set = set()

    def flush():
        nonlocal transfers
        if not pending:
            return
        results = engine.batch_exchange_eval_multi([
            ExchangeEvent(e.r, e.p, e.cand_a, e.cand_b, e.pairs,
                          e.agg_a, e.agg_b) for e in pending])
        for e, (wa, wb, feas) in zip(pending, results):
            best = select_best(e.cand_a, e.cand_b, e.pairs, wa, wb, feas,
                               e.w_before)
            if best is not None:
                state.swap(best.tasks_ab, e.r, best.tasks_ba, e.p)
                transfers += 1
                _rebuild_local(state, clusters, engine,
                               max_clusters_per_rank, e.r, e.p)
        pending.clear()
        busy.clear()

    def defer(r, p):
        # capture candidates/shortlist now (invariant under the other
        # deferred events' transfers — disjoint ranks), score at flush
        cand_a, cand_b, pairs, agg_a, agg_b = shortlist_pairs(
            state, clusters[r], clusters[p], r, p, max_candidates,
            engine=engine)
        w_before = max(state.work(r), state.work(p))
        pending.append(_PendingEvent(r, p, cand_a, cand_b, pairs,
                                     agg_a, agg_b, w_before))
        busy.update((r, p))
        if len(pending) >= batch:
            flush()

    spins = 0
    max_spins = 50 * phase.num_ranks + 1000
    while active and spins < max_spins:
        spins += 1
        r = active.popleft()
        if not work_lists[r]:
            continue
        if r in busy or work_lists[r][0][1] in busy:
            flush()     # this turn reads/mutates a deferred rank
        diff, p = work_lists[r].popleft()
        granted = locks.request(r, p)
        if not granted:
            conflicts += 1
            work_lists[r].append((diff * 0.5, p))
            if work_lists[r]:
                active.append(r)
            continue
        if locks.must_yield(r, p):
            conflicts += 1
            nxt = locks.release(r, p)
            work_lists[r].append((diff, p))
            active.append(r)
            if nxt is not None:
                _handle_grant_deferred(nxt, p, state, locks, work_lists,
                                       active, busy, defer, flush)
            continue
        defer(r, p)
        nxt = locks.release(r, p)
        if nxt is not None:
            _handle_grant_deferred(nxt, p, state, locks, work_lists, active,
                                   busy, defer, flush)
        if work_lists[r]:
            active.append(r)
    flush()
    return transfers, conflicts


def _handle_grant_deferred(r: int, p: int, state, locks, work_lists, active,
                           busy, defer, flush) -> None:
    """Grant-chain drain for the batched path: chain events are deferred
    through the same single-flush machinery instead of scored scalarly.

    Mirrors :func:`_handle_grant`'s control flow exactly — the chain
    structure (who yields, who releases to whom, re-activation order) never
    depends on scoring outcomes, so deferring the evaluations preserves the
    sequential trajectory: an event only joins the pending batch when its
    ranks are disjoint from every deferred event (otherwise ``flush()``
    first), and consecutive chain elements share ``p``, so each forces the
    previous element's flush before it captures its shortlist.
    """
    post: List[int] = []
    cur: Optional[int] = r
    while cur is not None:
        if locks.must_yield(cur, p):
            nxt = locks.release(cur, p)
            active.append(cur)
            cur = nxt
            continue
        if cur in busy or p in busy:
            flush()     # chain event must see the deferred swaps it touches
        defer(cur, p)
        nxt = locks.release(cur, p)
        post.append(cur)
        cur = nxt
    for rr in reversed(post):
        if work_lists[rr]:
            active.append(rr)


def _handle_grant(r: int, p: int, state, clusters, locks, work_lists, active,
                  max_candidates, max_clusters_per_rank=None, engine=None
                  ) -> int:
    """Drain the lock-release handoff chain on ``p`` starting at requester
    ``r``.  Iterative (a long chain of queued requesters must not hit the
    Python recursion limit at large rank counts); the re-activation order
    matches the original recursive formulation: yielding ranks re-activate
    immediately, transferring ranks re-activate after everyone deeper in the
    chain.  Returns the number of executed transfers.
    """
    n_transfers = 0
    post: List[int] = []  # ranks to re-activate after the chain, innermost first
    cur: Optional[int] = r
    while cur is not None:
        if locks.must_yield(cur, p):
            nxt = locks.release(cur, p)
            active.append(cur)
            cur = nxt
            continue
        best = try_transfer(state, clusters[cur], clusters[p], cur, p,
                            max_candidates, engine=engine)
        if best is not None:
            n_transfers += 1
            _rebuild_local(state, clusters, engine, max_clusters_per_rank,
                           cur, p)
        nxt = locks.release(cur, p)
        post.append(cur)
        cur = nxt
    for rr in reversed(post):
        if work_lists[rr]:
            active.append(rr)
    return n_transfers
