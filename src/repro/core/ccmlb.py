"""CCM-LB: the distributed, heuristic load-balancing algorithm (paper §IV,
Fig. 1), as a deterministic multi-rank discrete-event simulation.

Per iteration:
  1. cluster tasks on every rank (shared blocks + heavy comm edges);
  2. augmented inform stage — gossip rank+cluster summaries with ``fanout``
     over ``k_rounds`` (core/gossip.py);
  3. every rank scores its known peers with the stale-info approximation and
     builds a sorted work_list;
  4. lock/transfer stage — ranks try to lock their best peers (deadlock-free
     priority rule), then evaluate exactly (update formulae) with fresh info
     and execute the best cluster give/swap.

Evaluation engine: with ``use_engine=True`` (default) stages 3 and 4 run on
the vectorized :class:`~repro.core.engine.PhaseEngine` — stage 3 scores all
of a rank's known peers with one matrix op, stage 4 scores all shortlisted
cluster pairs of a lock event in one batched pass.  ``use_engine=False``
keeps the seed's scalar per-candidate loops (the reference path); both
produce identical transfer traces on the parity suite
(tests/test_engine.py; see repro/core/engine.py for the exact strength of
that guarantee — stage-2 scores may differ by summation-order ulps, so a
sub-ulp near-tie between two candidate exchanges could in principle
diverge the paths).

Returns the improved assignment plus a trace (max work, imbalance, transfers
per iteration) used by tests and benchmarks.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.ccm import CCMState
from repro.core.clusters import (build_clusters, summarize_clusters,
                                 summarize_rank)
from repro.core.engine import (PhaseEngine, batch_peer_diffs,
                               build_summary_tables)
from repro.core.gossip import build_peer_networks
from repro.core.locks import LockManager
from repro.core.problem import CCMParams, Phase
from repro.core.transfer import approx_best_diff, try_transfer


@dataclasses.dataclass
class CCMLBResult:
    assignment: np.ndarray
    state: CCMState
    max_work: List[float]          # per iteration (incl. initial)
    total_work: List[float]
    imbalance: List[float]
    transfers: int
    lock_conflicts: int
    engine_used: bool = True


def ccm_lb(phase: Phase, assignment: np.ndarray, params: CCMParams, *,
           n_iter: int = 4, k_rounds: int = 2, fanout: int = 4,
           seed: int = 0, max_candidates: int = 12,
           max_clusters_per_rank: Optional[int] = None,
           use_engine: bool = True) -> CCMLBResult:
    state = CCMState.build(phase, assignment, params)
    engine = PhaseEngine(state) if use_engine else None
    trace_max = [state.max_work()]
    trace_tot = [state.total_work()]
    trace_imb = [state.imbalance()]
    transfers = 0
    conflicts = 0

    for it in range(n_iter):
        clusters = build_clusters(state,
                                  max_clusters_per_rank=max_clusters_per_rank)
        csum = summarize_clusters(state, clusters)
        summaries = {r: summarize_rank(state, r, csum[r])
                     for r in range(phase.num_ranks)}
        info = build_peer_networks(summaries, k_rounds=k_rounds,
                                   fanout=fanout, seed=seed * 1000 + it)

        # stage 1: score peers from (stale) gossip info.  The batched path
        # reads the global summary tables — valid because gossip payloads
        # are references to this iteration's summary objects, so only the
        # known-peer SETS are stale, never the values (see batch_peer_diffs)
        work_lists: Dict[int, deque] = {}
        if engine is not None:
            tables = build_summary_tables(summaries, params)
        for r in range(phase.num_ranks):
            scored: List[Tuple[float, int]] = []
            if engine is not None:
                peers = np.array([p for p in info[r] if p != r], np.int64)
                # the tables are valid stand-ins for the gossip payloads
                # only while payloads alias this iteration's summaries
                assert all(info[r][int(p)] is summaries[int(p)]
                           for p in peers), \
                    "gossip payloads must alias current summaries"
                diffs = batch_peer_diffs(tables, r, peers, params)
                scored = [(float(d), int(p)) for d, p in zip(diffs, peers)
                          if d > 0]
            else:
                for p, psum in info[r].items():
                    if p == r:
                        continue
                    diff = approx_best_diff(summaries[r], psum, params)
                    if diff > 0:
                        scored.append((diff, p))
            scored.sort(key=lambda t: (-t[0], t[1]))
            work_lists[r] = deque(scored)

        # stage 2: lock/transfer event loop
        locks = LockManager(phase.num_ranks)
        # round-robin over ranks for fairness; each "turn" a rank either
        # requests its best remaining peer or is idle.  Queued lock requests
        # are drained synchronously on release (_handle_grant), so a
        # non-empty active deque is the only liveness condition.
        active = deque(r for r in range(phase.num_ranks) if work_lists[r])
        spins = 0
        max_spins = 50 * phase.num_ranks + 1000
        while active and spins < max_spins:
            spins += 1
            r = active.popleft()
            if not work_lists[r]:
                continue
            diff, p = work_lists[r].popleft()
            granted = locks.request(r, p)
            if not granted:
                conflicts += 1
                # re-queue the attempt at the back (retry later)
                work_lists[r].append((diff * 0.5, p))
                if work_lists[r]:
                    active.append(r)
                continue
            # granted: deadlock-avoidance check (Fig.1 line 45)
            if locks.must_yield(r, p):
                conflicts += 1
                nxt = locks.release(r, p)
                work_lists[r].append((diff, p))
                active.append(r)
                if nxt is not None:
                    transfers += _handle_grant(
                        nxt, p, state, clusters, locks, work_lists, active,
                        max_candidates, max_clusters_per_rank, engine)
                continue
            # fresh info exchange + exact transfer (recvUpdate/TryTransfer)
            best = try_transfer(state, clusters[r], clusters[p], r, p,
                                max_candidates, engine=engine)
            if best is not None:
                transfers += 1
                # cluster membership changed on r and p: rebuild locally
                local = build_clusters(
                    state, max_clusters_per_rank=max_clusters_per_rank,
                    only_ranks=[r, p])
                clusters[r] = local[r]
                clusters[p] = local[p]
            nxt = locks.release(r, p)
            if nxt is not None:
                transfers += _handle_grant(
                    nxt, p, state, clusters, locks, work_lists, active,
                    max_candidates, max_clusters_per_rank, engine)
            if work_lists[r]:
                active.append(r)

        trace_max.append(state.max_work())
        trace_tot.append(state.total_work())
        trace_imb.append(state.imbalance())

    return CCMLBResult(state.assignment.copy(), state, trace_max, trace_tot,
                       trace_imb, transfers, conflicts,
                       engine_used=engine is not None)


def _handle_grant(r: int, p: int, state, clusters, locks, work_lists, active,
                  max_candidates, max_clusters_per_rank=None, engine=None
                  ) -> int:
    """Drain the lock-release handoff chain on ``p`` starting at requester
    ``r``.  Iterative (a long chain of queued requesters must not hit the
    Python recursion limit at large rank counts); the re-activation order
    matches the original recursive formulation: yielding ranks re-activate
    immediately, transferring ranks re-activate after everyone deeper in the
    chain.  Returns the number of executed transfers.
    """
    n_transfers = 0
    post: List[int] = []  # ranks to re-activate after the chain, innermost first
    cur: Optional[int] = r
    while cur is not None:
        if locks.must_yield(cur, p):
            nxt = locks.release(cur, p)
            active.append(cur)
            cur = nxt
            continue
        best = try_transfer(state, clusters[cur], clusters[p], cur, p,
                            max_candidates, engine=engine)
        if best is not None:
            n_transfers += 1
            local = build_clusters(state,
                                   max_clusters_per_rank=max_clusters_per_rank,
                                   only_ranks=[cur, p])
            clusters[cur] = local[cur]
            clusters[p] = local[p]
        nxt = locks.release(cur, p)
        post.append(cur)
        cur = nxt
    for rr in reversed(post):
        if work_lists[rr]:
            active.append(rr)
    return n_transfers
