"""CCM-LB: the distributed, heuristic load-balancing algorithm (paper §IV,
Fig. 1), as a deterministic multi-rank discrete-event simulation.

Per iteration:
  1. cluster tasks on every rank (shared blocks + heavy comm edges);
  2. augmented inform stage — gossip rank+cluster summaries with ``fanout``
     over ``k_rounds`` (core/gossip.py);
  3. every rank scores its known peers with the stale-info approximation and
     builds a sorted work_list;
  4. lock/transfer stage — ranks try to lock their best peers (deadlock-free
     priority rule), then evaluate exactly (update formulae) with fresh info
     and execute the best cluster give/swap.

Returns the improved assignment plus a trace (max work, imbalance, transfers
per iteration) used by tests and benchmarks.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.ccm import CCMState
from repro.core.clusters import (build_clusters, summarize_clusters,
                                 summarize_rank)
from repro.core.gossip import build_peer_networks
from repro.core.locks import LockManager
from repro.core.problem import CCMParams, Phase
from repro.core.transfer import approx_best_diff, try_transfer


@dataclasses.dataclass
class CCMLBResult:
    assignment: np.ndarray
    state: CCMState
    max_work: List[float]          # per iteration (incl. initial)
    total_work: List[float]
    imbalance: List[float]
    transfers: int
    lock_conflicts: int


def ccm_lb(phase: Phase, assignment: np.ndarray, params: CCMParams, *,
           n_iter: int = 4, k_rounds: int = 2, fanout: int = 4,
           seed: int = 0, max_candidates: int = 12,
           max_clusters_per_rank: Optional[int] = None) -> CCMLBResult:
    state = CCMState.build(phase, assignment, params)
    trace_max = [state.max_work()]
    trace_tot = [state.total_work()]
    trace_imb = [state.imbalance()]
    transfers = 0
    conflicts = 0

    for it in range(n_iter):
        clusters = build_clusters(state,
                                  max_clusters_per_rank=max_clusters_per_rank)
        csum = summarize_clusters(state, clusters)
        summaries = {r: summarize_rank(state, r, csum[r])
                     for r in range(phase.num_ranks)}
        info = build_peer_networks(summaries, k_rounds=k_rounds,
                                   fanout=fanout, seed=seed * 1000 + it)

        # stage 1: score peers from (stale) gossip info
        work_lists: Dict[int, deque] = {}
        for r in range(phase.num_ranks):
            scored: List[Tuple[float, int]] = []
            for p, psum in info[r].items():
                if p == r:
                    continue
                diff = approx_best_diff(summaries[r], psum, params)
                if diff > 0:
                    scored.append((diff, p))
            scored.sort(key=lambda t: (-t[0], t[1]))
            work_lists[r] = deque(scored)

        # stage 2: lock/transfer event loop
        locks = LockManager(phase.num_ranks)
        # round-robin over ranks for fairness; each "turn" a rank either
        # requests its best remaining peer or is idle/waiting.
        active = deque(r for r in range(phase.num_ranks) if work_lists[r])
        waiting_grant: Dict[int, int] = {}  # requester -> target queued on
        spins = 0
        max_spins = 50 * phase.num_ranks + 1000
        while (active or waiting_grant) and spins < max_spins:
            spins += 1
            if not active:
                # everyone is queued on busy targets; queues drain on release
                # — if nothing holds a lock, drop all waits (no progress).
                if not any(locks.is_locked(r) for r in range(phase.num_ranks)):
                    break
                # force-release: cannot happen (every grant transfers then
                # releases synchronously below); guard anyway.
                break
            r = active.popleft()
            if not work_lists[r]:
                continue
            diff, p = work_lists[r].popleft()
            granted = locks.request(r, p)
            if not granted:
                conflicts += 1
                # re-queue the attempt at the back (retry later)
                work_lists[r].append((diff * 0.5, p))
                if work_lists[r]:
                    active.append(r)
                continue
            # granted: deadlock-avoidance check (Fig.1 line 45)
            if locks.must_yield(r, p):
                conflicts += 1
                nxt = locks.release(r, p)
                work_lists[r].append((diff, p))
                active.append(r)
                if nxt is not None:
                    _handle_grant(nxt, p, state, clusters, locks, work_lists,
                                  active, max_candidates)
                continue
            # fresh info exchange + exact transfer (recvUpdate/TryTransfer)
            best = try_transfer(state, clusters[r], clusters[p], r, p,
                                max_candidates)
            if best is not None:
                transfers += 1
                # cluster membership changed on r and p: rebuild locally
                local = build_clusters(
                    state, max_clusters_per_rank=max_clusters_per_rank,
                    only_ranks=[r, p])
                clusters[r] = local[r]
                clusters[p] = local[p]
            nxt = locks.release(r, p)
            if nxt is not None:
                _handle_grant(nxt, p, state, clusters, locks, work_lists,
                              active, max_candidates)
            if work_lists[r]:
                active.append(r)

        trace_max.append(state.max_work())
        trace_tot.append(state.total_work())
        trace_imb.append(state.imbalance())

    return CCMLBResult(state.assignment.copy(), state, trace_max, trace_tot,
                       trace_imb, transfers, conflicts)


def _handle_grant(r: int, p: int, state, clusters, locks, work_lists, active,
                  max_candidates):
    """A queued requester r just got the lock on p (release handoff)."""
    if locks.must_yield(r, p):
        nxt = locks.release(r, p)
        active.append(r)
        if nxt is not None:
            _handle_grant(nxt, p, state, clusters, locks, work_lists, active,
                          max_candidates)
        return
    best = try_transfer(state, clusters[r], clusters[p], r, p, max_candidates)
    if best is not None:
        local = build_clusters(state, only_ranks=[r, p])
        clusters[r] = local[r]
        clusters[p] = local[p]
    nxt = locks.release(r, p)
    if nxt is not None:
        _handle_grant(nxt, p, state, clusters, locks, work_lists, active,
                      max_candidates)
    if work_lists[r]:
        active.append(r)
