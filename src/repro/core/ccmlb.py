"""CCM-LB: the distributed, heuristic load-balancing algorithm (paper §IV,
Fig. 1), as a deterministic multi-rank discrete-event simulation.

Per iteration:
  1. cluster tasks on every rank (shared blocks + heavy comm edges);
  2. augmented inform stage — gossip rank+cluster summaries with ``fanout``
     over ``k_rounds`` (core/gossip.py);
  3. every rank scores its known peers with the stale-info approximation and
     builds a sorted work_list;
  4. lock/transfer stage — ranks try to lock their best peers (deadlock-free
     priority rule), then evaluate exactly (update formulae) with fresh info
     and execute the best cluster give/swap.

Evaluation engine: with ``use_engine=True`` (default) stages 3 and 4 run on
the vectorized :class:`~repro.core.engine.PhaseEngine` — stage 3 scores all
of a rank's known peers with one matrix op, stage 4 scores all shortlisted
cluster pairs of a lock event in one batched pass.  ``use_engine=False``
keeps the seed's scalar per-candidate loops (the reference path); both
produce identical transfer traces on the parity suite
(tests/test_engine.py; see repro/core/engine.py for the exact strength of
that guarantee — stage-2 scores may differ by summation-order ulps, so a
sub-ulp near-tie between two candidate exchanges could in principle
diverge the paths).

Incremental engine state: the engine is a long-lived object whose per-rank
member-task segments are updated in place by a transfer listener on the
``CCMState`` — every mutation this module performs (direct transfers,
grant-chain handoffs, batched deferred flushes) goes through
``state.swap``/``state.apply_transfer`` and therefore through that hook;
the per-transfer cluster rebuilds pass ``rank_tasks=engine.rank_tasks`` so
``build_clusters(only_ranks=...)`` touches only the two ranks' tasks and
their incident edges.  The served segments are bitwise what an assignment
scan returns (parity guarantee: tests/test_incremental.py asserts segments
and end-to-end trajectories against ``incremental=False``, the full
re-gather reference that remains available for A/B benchmarking).

``backend`` selects the engine's stage-4 tile scorer: ``"numpy"`` (the
reference), ``"jit"`` (the shape-bucketed compiled runtime — scores are
bitwise-equal to numpy, one XLA compile per shape bucket), ``"pallas"``
(the kernel in interpret mode, bitwise-equal) or ``"pallas_compiled"``
(f32 tiles on the 128-lane boundary; assignment-identity parity tier).
See repro/kernels/ccm_scorer/README.md for the backend matrix.

Batched lock events: ``batch_lock_events=k`` defers the scoring of up to
``k`` executable lock events whose rank pairs are pairwise disjoint, then
scores them in ONE engine call (one block-diagonal flow assembly, one
Pallas launch under ``backend="pallas"``).  Trajectory-exact in exact
arithmetic: a transfer between ranks (a, b) cannot change the score,
shortlist or clusters of a disjoint pair (c, d) — see
``PhaseEngine.batch_exchange_eval_multi`` — and the event sequence itself
is independent of scoring outcomes (turn order is fixed by the stage-3
work lists and the lock protocol).  The batch is flushed the moment a turn
touches a rank with a deferred event, on a full batch, and at stage end,
so the sequential order of state mutations is preserved.  Grant-chain
handoffs ride the same deferred machinery as single-event batches: each
chain transfer on (cur, p) is appended to the pending batch (joining
already-deferred disjoint events) and the shared rank p forces a flush
before the next chain element scores — the same disjointness argument, the
same sequential mutation order.
The guarantee carries the same sub-ulp caveat as the engine-vs-scalar
contract: a disjoint (a, b) swap relabels entries of vol rows/columns of
third ranks without changing their true sums, so the ``st.vol[r].sum()``
bases a deferred event reads can differ from the sequential path's
post-swap re-summation by summation-order ulps — a near-tie inside that
window could in principle flip the selected exchange.
tests/test_engine.py and the scaling benchmark assert identical
trajectories empirically (they hold on every tested instance).

Two drivers, one protocol
-------------------------
The §IV-B lock/grant machinery is shared between TWO drivers:

  * this module's synchronous round-robin loops (``_stage2`` /
    ``_stage2_batched``) — every lock is requested, used and released
    within the turn that took it, so lock conflicts, deadlock-avoidance
    yields and grant chains are STRUCTURALLY UNREACHABLE here
    (``CCMLBResult.lock_conflicts`` is zero by construction on this
    driver; see :class:`ProtocolStats`);
  * the asynchronous discrete-event simulator
    (:func:`repro.core.async_sim.ccm_lb_async`) — lock requests, grants,
    yields and releases travel as messages with latency, so concurrent
    requests collide, ``must_yield`` fires and queued requests drain
    through real grant chains.

Both drivers call the same handler functions (:func:`lock_request`,
:func:`note_yield`, :func:`lock_release`, :func:`execute_transfer`) over
the same :class:`~repro.core.locks.LockManager`, score stage 1 through the
same :func:`build_work_lists`, and account protocol events uniformly in
one :class:`ProtocolStats` — with zero latency the async event loop
serializes into exactly this module's round-robin turn order and the two
trajectories are bitwise-identical (tests/test_async_sim.py).

Returns the improved assignment plus a trace (max work, imbalance, transfers
per iteration) used by tests and benchmarks.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from time import perf_counter
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.ccm import CCMState
from repro.core.clusters import (build_clusters, summarize_clusters,
                                 summarize_rank)
from repro.core.engine import (ExchangeEvent, PhaseEngine, batch_peer_diffs,
                               build_summary_tables)
from repro.core.gossip import build_peer_networks, gossip_seed
from repro.core.locks import LockManager
from repro.core.problem import CCMParams, Phase, same_topology
from repro.core.quiesce import QuiesceTracker, phase_values_equal
from repro.core.spec import SpecInstance, event_sequence, run_spec
from repro.core.transfer import (approx_best_diff, select_best,
                                 shortlist_pairs, try_transfer)


@dataclasses.dataclass
class CCMLBResult:
    assignment: np.ndarray
    state: CCMState
    max_work: List[float]          # per iteration (incl. initial)
    total_work: List[float]
    imbalance: List[float]
    transfers: int
    lock_conflicts: int
    engine_used: bool = True
    # §IV-B protocol counters (uniform accounting via ProtocolStats; all of
    # them — lock_conflicts included — are structurally zero on the
    # synchronous drivers and only become meaningful under the async
    # event-loop driver, repro/core/async_sim.py)
    yields: int = 0
    grant_chains: int = 0
    max_grant_chain: int = 0
    # async-only observability (zero / None on the synchronous drivers)
    messages: int = 0              # protocol + gossip messages delivered
    sim_time: float = 0.0          # final simulated clock
    gossip_dropped: int = 0        # deliveries past the gossip deadline
    events: Optional[list] = None  # (time, seq, kind, src, dst) event trace
    # every state mutation in execution order: (task-id tuple, r_from,
    # r_to); replaying it onto the initial assignment reproduces
    # ``assignment`` exactly (asserted by the async protocol-safety suite)
    transfer_log: Optional[list] = None
    # fault-injection observability (async driver with an active FaultSpec
    # only; zero / empty everywhere else — see repro/core/async_sim.py)
    timeouts: int = 0              # lock-request timeouts fired
    retries_exhausted: int = 0     # work items dropped at the retry cap
    fault_stats: Optional[object] = None    # FaultStats when fault active
    recovery_log: Optional[list] = None     # crash-recovery migrations
    dead_ranks: Optional[list] = None       # ranks killed mid-run
    joined_ranks: Optional[list] = None     # ranks joined mid-run
    # (membership events; ``state.phase`` is the final, expanded phase)
    # speculative-scan observability (zero/None off the spec driver)
    spec_rollbacks: int = 0        # window events rolled back + re-queued
    spec_windows: int = 0          # compiled window launches
    spec_trace: Optional[list] = None   # (window, kind, r, p) commit trace
    # the live engine + whether it was carried in from a previous phase's
    # result (ccm_lb_pipeline carry_engine=True) instead of built fresh
    engine: Optional[PhaseEngine] = None
    engine_carried: bool = False
    # quiescence observability (repro/core/quiesce.py): per-iteration
    # transfer counts, optional per-iteration stage timing dicts
    # (``profile=True``), cumulative tracker-counter snapshots, and the
    # live tracker itself (carried alongside the engine by
    # ``ccm_lb(carry=...)`` so quiet phases stay amortized)
    iter_transfers: Optional[List[int]] = None
    stage_timings: Optional[List[dict]] = None
    quiesce_counters: Optional[List[dict]] = None
    memo_hits: int = 0
    gossip_noop_merges: int = 0
    tracker: Optional[QuiesceTracker] = None


@dataclasses.dataclass
class ProtocolStats:
    """Uniform accounting of the §IV-B lock protocol, shared by the
    synchronous round-robin drivers and the async event-loop driver.

    On the synchronous drivers every lock is released within the turn that
    took it, so ``conflicts`` / ``yields`` / chain counters can only ever
    be zero THERE — by construction, not because the branches are tested
    to be dead (the async driver reaches all of them; the coverage test in
    tests/test_async_protocol.py pins that down).  ``conflicts`` counts
    both queued lock requests and deadlock-avoidance yields, matching the
    seed's synchronous accounting; ``yields`` separates the Fig. 1 line 45
    releases.  A *grant chain* is a maximal run of queue handoffs on one
    target (release -> grant to next queued requester); ``max_grant_chain``
    is the longest such run's handoff count.
    """

    conflicts: int = 0
    yields: int = 0
    grant_chains: int = 0
    max_grant_chain: int = 0
    transfers: int = 0
    # fault-injection counters (async driver under an active FaultSpec;
    # ``retries_exhausted`` also counts the fault-free async driver's
    # yield-retry cap drops — the house "no silent caps" rule)
    timeouts: int = 0
    retries_exhausted: int = 0
    # speculative-scan counters (core/spec.py; zero on the other drivers)
    spec_rollbacks: int = 0
    spec_windows: int = 0
    # failed-evaluation memo (repro/core/quiesce.py): (r, p) -> the
    # ``state.version`` at which the pair's exact evaluation last failed.
    # A hit at the CURRENT version proves nothing has mutated since, so
    # the evaluation is skipped — bitwise-neutral, because the skipped
    # path's only effect would be returning False again.  ``None`` (the
    # rebuild reference and the scalar path) disables the memo.  The
    # lock dance is NEVER skipped: the memo is consulted only after the
    # grant, so conflict/yield/grant-chain patterns are unchanged.
    memo: Optional[Dict[tuple, int]] = None
    memo_hits: int = 0
    # per-iteration stage-timing dict (``ccm_lb(profile=True)``): the
    # stage-2 drivers split their time into "score" (exact evaluation)
    # and "commit" (state mutation + cluster rebuild) buckets
    timings: Optional[dict] = None
    # target -> current consecutive queue-handoff count (internal)
    _chain_run: Dict[int, int] = dataclasses.field(default_factory=dict)


# --------------------------------------------------------------------------
# Shared §IV-B protocol handlers — the ONLY code paths through which either
# driver touches the lock manager or executes a transfer, so the two
# drivers cannot drift apart in semantics or accounting.

def lock_request(locks: LockManager, stats: ProtocolStats, r: int,
                 p: int, req_id: Optional[int] = None) -> bool:
    """Fig. 1 line 42: rank ``r`` requests ``p``'s lock.  A busy target
    queues the request FIFO (granted later through a release handoff) and
    counts one conflict.  ``req_id`` is the grant token the async driver
    threads through under fault injection (see repro/core/locks.py); the
    synchronous drivers never pass one."""
    granted = locks.request(r, p, req_id)
    if not granted:
        stats.conflicts += 1
    return granted


def note_yield(stats: ProtocolStats) -> None:
    """Fig. 1 line 45 fired: the holder is itself locked by r_x <= target,
    so it releases the lock unused and retries later."""
    stats.conflicts += 1
    stats.yields += 1


def lock_release(locks: LockManager, stats: ProtocolStats, holder: int,
                 target: int) -> Optional[int]:
    """Fig. 1 line 49: release ``target``; a queued requester (returned)
    receives the lock — one handoff link of ``target``'s grant chain."""
    nxt = locks.release(holder, target)
    if nxt is None:
        stats._chain_run.pop(target, None)     # chain episode over
    else:
        run = stats._chain_run.get(target, 0) + 1
        stats._chain_run[target] = run
        if run == 1:
            stats.grant_chains += 1
        if run > stats.max_grant_chain:
            stats.max_grant_chain = run
    return nxt


def execute_transfer(state, clusters, engine, stats: ProtocolStats, r: int,
                     p: int, max_candidates: int,
                     max_clusters_per_rank, replicate: bool = False) -> bool:
    """Fig. 1 lines 46–48 (recvUpdate / TryTransfer / sendUpdate): exact
    evaluation with fresh info, execute the best positive exchange, rebuild
    the two touched ranks' clusters.  Returns True iff a transfer ran.

    ``stats.memo`` (when enabled) short-circuits a pair whose exact
    evaluation already failed at the current ``state.version`` — the
    dominant cost of a converged iteration, where every candidate scores
    positive on stale info and fails the fresh-info evaluation again.
    (The memo stays valid with ``replicate``: the extra candidates are a
    pure function of the state, so a failed evaluation at a version fails
    again at the same version.)"""
    memo = stats.memo
    if memo is not None and memo.get((r, p)) == state.version:
        stats.memo_hits += 1
        return False
    tm = stats.timings
    t0 = perf_counter() if tm is not None else 0.0
    best = try_transfer(state, clusters[r], clusters[p], r, p,
                        max_candidates, engine=engine, replicate=replicate)
    if tm is not None:
        tm["score"] += perf_counter() - t0
    if best is None:
        if memo is not None:
            memo[(r, p)] = state.version
        return False
    stats.transfers += 1
    t0 = perf_counter() if tm is not None else 0.0
    _rebuild_local(state, clusters, engine, max_clusters_per_rank, r, p)
    if tm is not None:
        tm["commit"] += perf_counter() - t0
    return True


def iteration_summaries(state, phase, max_clusters_per_rank,
                        replicate=False):
    """Per-iteration prologue shared by both drivers: cluster every rank
    and summarize (rank + cluster summaries are this iteration's gossip
    payloads).  With ``replicate`` the cluster summaries carry virtual
    half-split entries so stage 1 can score replication moves."""
    clusters = build_clusters(state,
                              max_clusters_per_rank=max_clusters_per_rank)
    csum = summarize_clusters(state, clusters, replicate=replicate)
    summaries = {r: summarize_rank(state, r, csum[r])
                 for r in range(phase.num_ranks)}
    return clusters, summaries


def build_work_lists(phase, summaries, info, params,
                     engine) -> Dict[int, deque]:
    """Stage 1 (Fig. 1 lines 31–40): every rank scores its gossip-known
    peers with the stale-info approximation and sorts a best-first work
    list (ties broken by peer id, so the lists depend only on the known-
    peer SETS, not dict insertion order).  Shared by both drivers — the
    async zero-latency parity bar starts from identical lists.

    The batched path reads the global summary tables — valid because
    gossip payloads are references to this iteration's summary objects, so
    only the known-peer SETS are stale, never the values (see
    batch_peer_diffs).
    """
    work_lists: Dict[int, deque] = {}
    tables = (build_summary_tables(summaries, params)
              if engine is not None else None)
    for r in range(phase.num_ranks):
        scored: List[Tuple[float, int]] = []
        if engine is not None:
            peers = np.array([p for p in info[r] if p != r], np.int64)
            # the tables are valid stand-ins for the gossip payloads
            # only while payloads alias this iteration's summaries
            assert all(info[r][int(p)] is summaries[int(p)]
                       for p in peers), \
                "gossip payloads must alias current summaries"
            diffs = batch_peer_diffs(tables, r, peers, params)
            scored = [(float(d), int(p)) for d, p in zip(diffs, peers)
                      if d > 0]
        else:
            for p, psum in info[r].items():
                if p == r:
                    continue
                diff = approx_best_diff(summaries[r], psum, params)
                if diff > 0:
                    scored.append((diff, p))
        scored.sort(key=lambda t: (-t[0], t[1]))
        work_lists[r] = deque(scored)
    return work_lists


def ccm_lb(phase: Phase, assignment: np.ndarray, params: CCMParams, *,
           n_iter: int = 4, k_rounds: int = 2, fanout: int = 4,
           seed: int = 0, max_candidates: int = 12,
           max_clusters_per_rank: Optional[int] = None,
           use_engine: bool = True, backend: str = "numpy",
           batch_lock_events: int = 1, incremental: bool = True,
           csr=None, spec_window: int = 1, spec_mode: str = "scan",
           spec_fill: str = "disjoint", spec_trace: bool = False,
           carry=None, quiesce_after: Optional[int] = None,
           profile: bool = False, replicate: bool = False) -> CCMLBResult:
    """``incremental`` keeps the engine's per-rank segments current via the
    transfer hook (default; ``False`` re-gathers per event — the rebuild
    reference).  ``csr`` is an optional prebuilt ``PhaseCSR`` for this
    phase's topology (multi-phase pipelines amortize it).

    ``incremental`` also enables the quiescence caches
    (repro/core/quiesce.py): dirty-rank gossip replay, patched cluster/
    rank summaries and summary tables, cached sorted work lists, and the
    failed-evaluation memo — bitwise-identical trajectories to the
    ``incremental=False`` rebuild reference (tests/test_quiesce.py), with
    converged iterations costing O(dirty ranks) instead of
    O(ranks + tasks + edges).

    ``quiesce_after=k`` stops the iteration loop after ``k`` consecutive
    zero-transfer iterations (the paper's algorithm converges in a
    handful of iterations and then only confirms quiescence); ``None``
    (default) always runs ``n_iter``.  ``profile=True`` records a
    per-iteration host-cost breakdown (clusters / gossip / work_lists /
    score / commit seconds) in ``CCMLBResult.stage_timings``.

    ``spec_window > 1`` routes stage 2 through the speculative-scan driver
    (core/spec.py): windows of up to ``spec_window`` lock events score in
    one compiled launch (``spec_mode`` "scan" or "vmap"), with host-side
    rollback of invalidated speculations.  Compiled-vs-host parity tier —
    empirically identical trajectories, not bitwise (see
    kernels/ccm_scorer/README.md).  ``spec_fill`` picks the speculation
    policy — ``"disjoint"`` (default) takes only rank-disjoint event
    prefixes per window, making rollback structurally impossible;
    ``"greedy"`` fills blindly and rolls back invalidated speculations
    (see ``repro.core.spec.run_spec``).  ``spec_trace=True`` records the
    per-event commit/rollback trace in ``CCMLBResult.spec_trace``.

    ``replicate=True`` extends every lock event's candidate set with block
    replication splits and de-replication consolidations
    (``repro.core.transfer.memory_move_candidates``) — the paper's
    parallelism-for-memory trade as first-class moves.  Scored through the
    scalar reference evaluator after the base vocabulary, accepted only on
    a strictly greater work diff, so instances where the extras never win
    stay bitwise-identical to ``replicate=False``.  Incompatible with the
    deferred/speculative stage-2 drivers (``batch_lock_events > 1``,
    ``spec_window > 1``), which can only score the engine's cluster
    vocabulary.

    ``carry``: a previous phase's ``CCMLBResult`` whose state/engine should
    be reused.  Accepted only when the phases share topology
    (``same_topology``), rank count, backend/incremental knobs AND the
    start assignment equals the carried final assignment — then the state
    is :meth:`CCMState.retarget`-ed in place (bitwise-equal to a fresh
    build) and the engine's caches revalidate via the version bump;
    otherwise a fresh state is built silently (``engine_carried`` reports
    which happened).
    """
    if batch_lock_events < 1:
        raise ValueError("batch_lock_events must be >= 1")
    if batch_lock_events > 1 and not use_engine:
        raise ValueError("batch_lock_events > 1 requires use_engine=True")
    if spec_window < 1:
        raise ValueError("spec_window must be >= 1")
    if spec_window > 1 and not use_engine:
        raise ValueError("spec_window > 1 requires use_engine=True")
    if spec_window > 1 and batch_lock_events > 1:
        raise ValueError("spec_window and batch_lock_events are mutually "
                         "exclusive stage-2 drivers")
    if quiesce_after is not None and quiesce_after < 1:
        raise ValueError("quiesce_after must be >= 1 (or None)")
    if replicate and batch_lock_events > 1:
        raise ValueError("replicate requires the scalar stage-2 loop — "
                         "incompatible with batch_lock_events > 1")
    if replicate and spec_window > 1:
        raise ValueError("replicate requires the scalar stage-2 loop — "
                         "incompatible with spec_window > 1")
    state = engine = tracker = None
    engine_carried = False
    if carry is not None:
        cstate = getattr(carry, "state", None)
        cengine = getattr(carry, "engine", None)
        if (use_engine and cstate is not None and cengine is not None
                and cengine.backend == backend
                and cengine.incremental == incremental
                and cstate.phase.num_ranks == phase.num_ranks
                and np.array_equal(cstate.assignment,
                                   np.asarray(assignment, np.int64))
                and same_topology(cstate.phase, phase)):
            old_phase, old_params = cstate.phase, cstate.params
            cstate.retarget(phase, params)
            state, engine, engine_carried = cstate, cengine, True
            ctracker = getattr(carry, "tracker", None)
            if (ctracker is not None and ctracker.state is state
                    and ctracker.engine is engine
                    and ctracker.k_rounds == k_rounds
                    and ctracker.fanout == fanout
                    and ctracker.mcpr == max_clusters_per_rank
                    and ctracker.caching == bool(incremental)):
                # caches stay bitwise-valid only when the new phase's
                # value arrays and params equal the old ones (then the
                # carried summaries/reach sets are exactly what a fresh
                # build computes); otherwise the rebind resets to
                # all-dirty.  Epochs restart at 0 either way — identical
                # to a fresh run, which is the pipeline parity contract.
                ctracker.rebind(seed=seed, params=params,
                                keep=(old_params == params
                                      and phase_values_equal(old_phase,
                                                             phase)))
                tracker = ctracker
    if state is None:
        state = CCMState.build(phase, assignment, params, csr=csr)
        engine = (PhaseEngine(state, backend=backend,
                              incremental=incremental)
                  if use_engine else None)
    if tracker is None:
        tracker = QuiesceTracker(state, engine, params, seed=seed,
                                 k_rounds=k_rounds, fanout=fanout,
                                 max_clusters_per_rank=max_clusters_per_rank,
                                 caching=incremental, replicate=replicate)
    transfer_log: list = []

    def _log_cb(t, a, b):
        transfer_log.append((tuple(int(x) for x in t), int(a), int(b)))

    state.add_transfer_listener(_log_cb)
    state.add_transfer_listener(tracker.note_transfer)
    trace_max = [state.max_work()]
    trace_tot = [state.total_work()]
    trace_imb = [state.imbalance()]
    stats = ProtocolStats()
    stats.memo = tracker.memo if tracker.caching else None
    strace: Optional[list] = [] if spec_trace else None
    stage_timings: Optional[List[dict]] = [] if profile else None
    iter_transfers: List[int] = []
    quiet = 0

    try:
        for it in range(n_iter):
            tm = ({"clusters": 0.0, "gossip": 0.0, "work_lists": 0.0,
                   "score": 0.0, "commit": 0.0} if profile else None)
            stats.timings = tm
            tracker.begin_iteration(it)
            t0 = perf_counter() if profile else 0.0
            clusters, summaries = tracker.update_summaries()
            if profile:
                t1 = perf_counter()
                tm["clusters"] = t1 - t0
                t0 = t1
            info = tracker.update_gossip()
            if profile:
                t1 = perf_counter()
                tm["gossip"] = t1 - t0
                t0 = t1
            if tracker.caching:
                work_lists = tracker.update_work_lists(info)
            else:
                work_lists = build_work_lists(phase, summaries, info, params,
                                              engine)
            if profile:
                tm["work_lists"] = perf_counter() - t0
            before = stats.transfers

            # stage 2: lock/transfer event loop
            if spec_window > 1:
                _stage2_spec(phase, state, clusters, work_lists, engine,
                             max_candidates, max_clusters_per_rank,
                             spec_window, spec_mode, spec_fill, stats,
                             strace)
            elif batch_lock_events > 1:
                _stage2_batched(phase, state, clusters, work_lists, engine,
                                max_candidates, max_clusters_per_rank,
                                batch_lock_events, stats)
            else:
                _stage2(phase, state, clusters, work_lists, engine,
                        max_candidates, max_clusters_per_rank, stats,
                        replicate=replicate)

            delta = stats.transfers - before
            iter_transfers.append(delta)
            tracker.end_iteration()
            trace_max.append(state.max_work())
            trace_tot.append(state.total_work())
            trace_imb.append(state.imbalance())
            if profile:
                stage_timings.append(tm)
            if quiesce_after is not None:
                quiet = quiet + 1 if delta == 0 else 0
                if quiet >= quiesce_after:
                    break
    finally:
        # a carried state outlives this run — the log listener must not
        # keep appending into a dead list on the next phase's transfers,
        # and the tracker must not double-fire once the next phase
        # re-registers it (ccm_lb(carry=...) re-adds the carried one)
        state.remove_transfer_listener(_log_cb)
        state.remove_transfer_listener(tracker.note_transfer)

    return CCMLBResult(state.assignment.copy(), state, trace_max, trace_tot,
                       trace_imb, stats.transfers, stats.conflicts,
                       engine_used=engine is not None, yields=stats.yields,
                       grant_chains=stats.grant_chains,
                       max_grant_chain=stats.max_grant_chain,
                       transfer_log=transfer_log,
                       spec_rollbacks=stats.spec_rollbacks,
                       spec_windows=stats.spec_windows,
                       spec_trace=strace, engine=engine,
                       engine_carried=engine_carried,
                       iter_transfers=iter_transfers,
                       stage_timings=stage_timings,
                       quiesce_counters=tracker.iter_counters,
                       memo_hits=stats.memo_hits,
                       gossip_noop_merges=tracker.counters.get(
                           "gossip_noop_merges", 0),
                       tracker=tracker)


def _stage2_spec(phase, state, clusters, work_lists, engine, max_candidates,
                 max_clusters_per_rank, window, mode, fill,
                 stats: ProtocolStats, trace: Optional[list]) -> None:
    """Stage 2 through the speculative-scan driver: derive the reference
    event sequence up front (deterministic on this driver — see
    core/spec.py), then drain it through windowed compiled launches with
    strict-prefix commit/rollback."""
    seq = event_sequence(phase.num_ranks, work_lists)
    if not seq:
        return
    inst = SpecInstance(
        state=state, engine=engine, clusters=clusters, stats=stats,
        rebuild=lambda r, p: _rebuild_local(state, clusters, engine,
                                            max_clusters_per_rank, r, p),
        queue=deque(seq), max_candidates=max_candidates, trace=trace)
    run_spec([inst], state.params, window=window, mode=mode, fill=fill)


def _rebuild_local(state, clusters, engine, max_clusters_per_rank, r, p):
    """Post-transfer cluster rebuild for the two touched ranks, fed from the
    engine's incremental segments when available."""
    rt = (engine.rank_tasks
          if engine is not None and engine.incremental else None)
    local = build_clusters(state, max_clusters_per_rank=max_clusters_per_rank,
                           only_ranks=[r, p], rank_tasks=rt)
    clusters[r] = local[r]
    clusters[p] = local[p]


def _stage2(phase, state, clusters, work_lists, engine, max_candidates,
            max_clusters_per_rank, stats: ProtocolStats,
            replicate: bool = False) -> None:
    """One-event-at-a-time lock/transfer loop (the reference event order).

    Every lock taken here is released before the turn ends and queued
    requests are drained synchronously on release (_handle_grant), so the
    not-granted and must-yield branches are structurally unreachable
    through this driver — they exist for protocol fidelity and are
    load-bearing under the async driver, which shares the handlers.
    """
    locks = LockManager(phase.num_ranks)
    # round-robin over ranks for fairness; each "turn" a rank either
    # requests its best remaining peer or is idle.  Queued lock requests
    # are drained synchronously on release (_handle_grant), so a
    # non-empty active deque is the only liveness condition.
    active = deque(r for r in range(phase.num_ranks) if work_lists[r])
    spins = 0
    max_spins = 50 * phase.num_ranks + 1000
    while active and spins < max_spins:
        spins += 1
        r = active.popleft()
        if not work_lists[r]:
            continue
        diff, p = work_lists[r].popleft()
        if not lock_request(locks, stats, r, p):
            # re-queue the attempt at the back (retry later)
            work_lists[r].append((diff * 0.5, p))
            if work_lists[r]:
                active.append(r)
            continue
        # granted: deadlock-avoidance check (Fig.1 line 45)
        if locks.must_yield(r, p):
            note_yield(stats)
            nxt = lock_release(locks, stats, r, p)
            work_lists[r].append((diff, p))
            active.append(r)
            if nxt is not None:
                _handle_grant(nxt, p, state, clusters, locks, work_lists,
                              active, max_candidates, max_clusters_per_rank,
                              engine, stats, replicate=replicate)
            continue
        # fresh info exchange + exact transfer (recvUpdate/TryTransfer)
        execute_transfer(state, clusters, engine, stats, r, p,
                         max_candidates, max_clusters_per_rank,
                         replicate=replicate)
        nxt = lock_release(locks, stats, r, p)
        if nxt is not None:
            _handle_grant(nxt, p, state, clusters, locks, work_lists, active,
                          max_candidates, max_clusters_per_rank, engine,
                          stats, replicate=replicate)
        if work_lists[r]:
            active.append(r)


@dataclasses.dataclass
class _PendingEvent:
    """An executable lock event whose scoring has been deferred."""

    r: int
    p: int
    cand_a: list
    cand_b: list
    pairs: np.ndarray       # (P, 2) shortlist rows
    agg_a: object
    agg_b: object
    w_before: float


def _stage2_batched(phase, state, clusters, work_lists, engine,
                    max_candidates, max_clusters_per_rank,
                    batch: int, stats: ProtocolStats) -> None:
    """Lock/transfer loop with deferred, batched event scoring.

    Identical turn order to :func:`_stage2` (lock state never outlives a
    turn, so request/grant outcomes cannot differ); only the try_transfer
    evaluation of up to ``batch`` pairwise-disjoint events is deferred and
    executed at flush points in original event order.  Flushes happen
    before any turn that touches a deferred rank, on a full batch, and at
    stage end — exactly the moments the sequential loop would have
    interleaved state mutations.  Grant-chain handoffs go through
    :func:`_handle_grant_deferred`: each chain event joins the pending
    batch as a single-event entry (it may share a flush with
    already-deferred DISJOINT events; the chain's shared rank ``p`` forces
    a flush before the next chain element scores), so chains ride the same
    deferred-scoring machinery with the same trajectory argument.
    """
    locks = LockManager(phase.num_ranks)
    active = deque(r for r in range(phase.num_ranks) if work_lists[r])
    pending: List[_PendingEvent] = []
    busy: set = set()

    def flush():
        if not pending:
            return
        tm = stats.timings
        t0 = perf_counter() if tm is not None else 0.0
        results = engine.batch_exchange_eval_multi([
            ExchangeEvent(e.r, e.p, e.cand_a, e.cand_b, e.pairs,
                          e.agg_a, e.agg_b) for e in pending])
        if tm is not None:
            t1 = perf_counter()
            tm["score"] += t1 - t0
            t0 = t1
        # commit bookkeeping is batched: swaps run per event in original
        # order (their float accumulation order is load-bearing), the
        # cluster rebuilds fold into ONE build_clusters call over all
        # touched ranks.  Valid because the flushed events are pairwise
        # rank-disjoint and nothing reads the cluster lists before the
        # flush returns; bitwise because build_clusters is per-rank local
        # (same labels, caps and thresholds either way).
        touched: List[int] = []
        for e, (wa, wb, feas) in zip(pending, results):
            best = select_best(e.cand_a, e.cand_b, e.pairs, wa, wb, feas,
                               e.w_before)
            if best is not None:
                state.swap(best.tasks_ab, e.r, best.tasks_ba, e.p)
                stats.transfers += 1
                touched.extend((e.r, e.p))
            elif stats.memo is not None:
                # record at the current version — exactly what the
                # sequential path would have recorded at this event's
                # turn (earlier flush commits already bumped it)
                stats.memo[(e.r, e.p)] = state.version
        if touched:
            rt = (engine.rank_tasks
                  if engine is not None and engine.incremental else None)
            local = build_clusters(state,
                                   max_clusters_per_rank=max_clusters_per_rank,
                                   only_ranks=touched, rank_tasks=rt)
            for r in touched:
                clusters[r] = local[r]
        if tm is not None:
            tm["commit"] += perf_counter() - t0
        pending.clear()
        busy.clear()

    def defer(r, p):
        # the memo short-circuit mirrors execute_transfer's: a pair whose
        # evaluation failed at the current version cannot succeed now
        # (pending deferred events haven't mutated anything yet), so the
        # event is dropped without joining the batch — the sequential
        # path returns the same False
        if stats.memo is not None and stats.memo.get((r, p)) == state.version:
            stats.memo_hits += 1
            return
        # capture candidates/shortlist now (invariant under the other
        # deferred events' transfers — disjoint ranks), score at flush
        cand_a, cand_b, pairs, agg_a, agg_b = shortlist_pairs(
            state, clusters[r], clusters[p], r, p, max_candidates,
            engine=engine)
        w_before = max(state.work(r), state.work(p))
        pending.append(_PendingEvent(r, p, cand_a, cand_b, pairs,
                                     agg_a, agg_b, w_before))
        busy.update((r, p))
        if len(pending) >= batch:
            flush()

    spins = 0
    max_spins = 50 * phase.num_ranks + 1000
    while active and spins < max_spins:
        spins += 1
        r = active.popleft()
        if not work_lists[r]:
            continue
        if r in busy or work_lists[r][0][1] in busy:
            flush()     # this turn reads/mutates a deferred rank
        diff, p = work_lists[r].popleft()
        if not lock_request(locks, stats, r, p):
            work_lists[r].append((diff * 0.5, p))
            if work_lists[r]:
                active.append(r)
            continue
        if locks.must_yield(r, p):
            note_yield(stats)
            nxt = lock_release(locks, stats, r, p)
            work_lists[r].append((diff, p))
            active.append(r)
            if nxt is not None:
                _handle_grant_deferred(nxt, p, state, locks, work_lists,
                                       active, busy, defer, flush, stats)
            continue
        defer(r, p)
        nxt = lock_release(locks, stats, r, p)
        if nxt is not None:
            _handle_grant_deferred(nxt, p, state, locks, work_lists, active,
                                   busy, defer, flush, stats)
        if work_lists[r]:
            active.append(r)
    flush()


def _handle_grant_deferred(r: int, p: int, state, locks, work_lists, active,
                           busy, defer, flush,
                           stats: ProtocolStats) -> None:
    """Grant-chain drain for the batched path: chain events are deferred
    through the same single-flush machinery instead of scored scalarly.

    Mirrors :func:`_handle_grant`'s control flow exactly — the chain
    structure (who yields, who releases to whom, re-activation order) never
    depends on scoring outcomes, so deferring the evaluations preserves the
    sequential trajectory: an event only joins the pending batch when its
    ranks are disjoint from every deferred event (otherwise ``flush()``
    first), and consecutive chain elements share ``p``, so each forces the
    previous element's flush before it captures its shortlist.
    """
    post: List[int] = []
    cur: Optional[int] = r
    while cur is not None:
        if locks.must_yield(cur, p):
            note_yield(stats)
            nxt = lock_release(locks, stats, cur, p)
            active.append(cur)
            cur = nxt
            continue
        if cur in busy or p in busy:
            flush()     # chain event must see the deferred swaps it touches
        defer(cur, p)
        nxt = lock_release(locks, stats, cur, p)
        post.append(cur)
        cur = nxt
    for rr in reversed(post):
        if work_lists[rr]:
            active.append(rr)


def _handle_grant(r: int, p: int, state, clusters, locks, work_lists, active,
                  max_candidates, max_clusters_per_rank, engine,
                  stats: ProtocolStats, replicate: bool = False) -> int:
    """Drain the lock-release handoff chain on ``p`` starting at requester
    ``r``.  Iterative (a long chain of queued requesters must not hit the
    Python recursion limit at large rank counts); the re-activation order
    matches the original recursive formulation: yielding ranks re-activate
    immediately, transferring ranks re-activate after everyone deeper in the
    chain.  Returns the number of executed transfers.
    """
    before = stats.transfers
    post: List[int] = []  # ranks to re-activate after the chain, innermost first
    cur: Optional[int] = r
    while cur is not None:
        if locks.must_yield(cur, p):
            note_yield(stats)
            nxt = lock_release(locks, stats, cur, p)
            active.append(cur)
            cur = nxt
            continue
        execute_transfer(state, clusters, engine, stats, cur, p,
                         max_candidates, max_clusters_per_rank,
                         replicate=replicate)
        nxt = lock_release(locks, stats, cur, p)
        post.append(cur)
        cur = nxt
    for rr in reversed(post):
        if work_lists[rr]:
            active.append(rr)
    return stats.transfers - before
