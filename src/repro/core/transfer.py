"""FindBestCCM / TryTransfer (paper Fig. 1, lines 6–23).

Two evaluation layers:
  * ``approx_best_diff`` — stage 1 (peer ranking): only gossip summaries are
    available (possibly stale), so the work after a transfer is approximated
    at cluster granularity.
  * ``find_best_exchange`` — stage 2 (after locking a peer): exact evaluation
    with the CCM update formulae over cluster give/swap candidates.

Each layer has a scalar reference path (this module's per-candidate loops)
and a batched production path (``engine=`` / ``repro.core.engine``): pass a
:class:`~repro.core.engine.PhaseEngine` to ``find_best_exchange`` /
``try_transfer`` and every shortlisted candidate pair is scored in one
vectorized pass; stage-1 batching lives in ``engine.batch_peer_diffs``.
Candidate enumeration, shortlisting, and the selection rule are shared by
both paths, so they pick the same exchange.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro.core.ccm import CCMState, ExchangeEval, exchange_eval
from repro.core.clusters import ClusterSummary, RankSummary


def _w_of(summary: RankSummary, params) -> float:
    return (params.alpha * summary.load / summary.speed
            + params.beta * summary.vol_off
            + params.gamma * summary.vol_on
            + params.delta * summary.homing)


def approx_transfer(me: RankSummary, peer: RankSummary, c: ClusterSummary,
                    params) -> Optional[Tuple[float, float]]:
    """Approximate (W_me_after, W_peer_after) when cluster c moves me->peer.

    Approximations (documented; stage 2 re-checks exactly): the cluster's
    external volume becomes off-rank for the peer and stops counting against
    me; its intra volume stays on-rank; its blocks land off-home on the peer
    unless the peer is their home (unknowable from summaries for sure — we
    assume off-home, the conservative direction).
    """
    if me.rank == peer.rank:
        return None
    # memory feasibility on the receiving side
    if peer.mem_used + c.mem + c.block_bytes > peer.mem_cap:
        return None
    w_me = (params.alpha * (me.load - c.load) / me.speed
            + params.beta * max(me.vol_off - c.vol_ext, 0.0)
            + params.gamma * max(me.vol_on - c.vol_intra, 0.0)
            + params.delta * me.homing)
    w_peer = (params.alpha * (peer.load + c.load) / peer.speed
              + params.beta * (peer.vol_off + c.vol_ext)
              + params.gamma * (peer.vol_on + c.vol_intra)
              + params.delta * (peer.homing + c.block_bytes))
    return w_me, w_peer


def approx_best_diff(me: RankSummary, peer: RankSummary, params) -> float:
    """Stage-1 criterion: best max-work reduction over my clusters -> peer."""
    w_me, w_peer = _w_of(me, params), _w_of(peer, params)
    max_before = max(w_me, w_peer)
    best = -np.inf
    for c in me.clusters:
        res = approx_transfer(me, peer, c, params)
        if res is None:
            continue
        diff = max_before - max(res)
        best = max(best, diff)
    # also consider pulling the peer's clusters here (peer may be overloaded)
    for c in peer.clusters:
        res = approx_transfer(peer, me, c, params)
        if res is None:
            continue
        diff = max_before - max(res)
        best = max(best, diff)
    return float(best)


@dataclasses.dataclass
class BestExchange:
    tasks_ab: np.ndarray   # move a -> b
    tasks_ba: np.ndarray   # move b -> a
    work_diff: float
    eval: ExchangeEval


def shortlist_pairs(state: CCMState, clusters_a: List[np.ndarray],
                    clusters_b: List[np.ndarray], r_a: int, r_b: int,
                    max_candidates: int = 12, shortlist: int = 32,
                    engine=None):
    """Candidate enumeration + load-only shortlist, shared by
    ``find_best_exchange`` and ccm_lb's batched lock events.

    Beyond-paper speedup: a vectorized load-only estimate shortlists the
    most promising ``shortlist`` pairs; only those get the exact CCM
    update-formula evaluation (alpha dominates realistic instances, so the
    shortlist rarely excludes the true best; the final choice is exact).
    Depends only on the two ranks' own loads and cluster lists, so the
    shortlist of a lock event is invariant under transfers between OTHER
    (disjoint) rank pairs — the property batched lock events rest on.

    Returns ``(cand_a, cand_b, pairs, agg_a, agg_b)``; the aggregates are
    None on the scalar path.
    """
    empty = np.zeros((0,), np.int64)
    cand_a = [empty] + clusters_a[:max_candidates]
    cand_b = [empty] + clusters_b[:max_candidates]
    agg_a = agg_b = None
    if engine is not None:
        agg_a = engine.cluster_aggregates(r_a, clusters_a)
        agg_b = engine.cluster_aggregates(r_b, clusters_b)

    pairs = [(ia, ib) for ia in range(len(cand_a))
             for ib in range(len(cand_b)) if ia or ib]
    if len(pairs) > shortlist:
        ph = state.phase
        if engine is not None:  # cached, bitwise-equal per-cluster sums
            la = np.concatenate([[0.0], agg_a.loads[:max_candidates]])
            lb = np.concatenate([[0.0], agg_b.loads[:max_candidates]])
        else:
            la = np.array([ph.task_load[c].sum() for c in cand_a])
            lb = np.array([ph.task_load[c].sum() for c in cand_b])
        ia = np.array([p[0] for p in pairs])
        ib = np.array([p[1] for p in pairs])
        after_a = (state.load[r_a] - la[ia] + lb[ib]) / ph.rank_speed[r_a]
        after_b = (state.load[r_b] + la[ia] - lb[ib]) / ph.rank_speed[r_b]
        score = np.maximum(after_a, after_b)
        order = np.argsort(score)[:shortlist]
        pairs = [pairs[i] for i in order]
    return cand_a, cand_b, pairs, agg_a, agg_b


def select_best(cand_a, cand_b, pairs, wa, wb, feas,
                w_before: float) -> Optional[BestExchange]:
    """Selection rule over batched scores — shared by the engine path of
    ``find_best_exchange`` and ccm_lb's batched lock events, so deferred
    scoring picks the exact same exchange."""
    best: Optional[BestExchange] = None
    for k, (ia, ib) in enumerate(pairs):
        if not feas[k]:
            continue
        ev = ExchangeEval(float(wa[k]), float(wb[k]), True)
        diff = w_before - ev.max_after
        if diff > 1e-12 and (best is None or diff > best.work_diff):
            best = BestExchange(cand_a[ia], cand_b[ib], float(diff), ev)
    return best


def find_best_exchange(state: CCMState, clusters_a: List[np.ndarray],
                       clusters_b: List[np.ndarray], r_a: int, r_b: int,
                       max_candidates: int = 12,
                       shortlist: int = 32,
                       engine=None) -> Optional[BestExchange]:
    """Exact FindBestCCM: best give/swap among cluster pairs (incl. one-sided
    gives via the empty cluster).  ``max_candidates`` bounds each side
    (clusters come sorted by load) — the paper's quality/cost tunable.

    ``engine``: a :class:`~repro.core.engine.PhaseEngine` scores every
    shortlisted pair in one batched pass; ``None`` falls back to one
    ``exchange_eval`` call per pair (reference path).
    """
    cand_a, cand_b, pairs, agg_a, agg_b = shortlist_pairs(
        state, clusters_a, clusters_b, r_a, r_b, max_candidates, shortlist,
        engine)
    w_before = max(state.work(r_a), state.work(r_b))

    if engine is not None:
        wa, wb, feas = engine.batch_exchange_eval(r_a, r_b, cand_a, cand_b,
                                                  pairs, agg_a, agg_b)
        return select_best(cand_a, cand_b, pairs, wa, wb, feas, w_before)

    best: Optional[BestExchange] = None
    for ia, ib in pairs:
        ca, cb = cand_a[ia], cand_b[ib]
        ev = exchange_eval(state, ca, cb, r_a, r_b)
        if not ev.feasible:
            continue
        diff = w_before - ev.max_after
        if diff > 1e-12 and (best is None or diff > best.work_diff):
            best = BestExchange(ca, cb, float(diff), ev)
    return best


def try_transfer(state: CCMState, clusters_a, clusters_b, r_a: int, r_b: int,
                 max_candidates: int = 12,
                 engine=None) -> Optional[BestExchange]:
    """TryTransfer: execute the best positive exchange, if any (mutates)."""
    best = find_best_exchange(state, clusters_a, clusters_b, r_a, r_b,
                              max_candidates, engine=engine)
    if best is None:
        return None
    state.swap(best.tasks_ab, r_a, best.tasks_ba, r_b)
    return best
