"""FindBestCCM / TryTransfer (paper Fig. 1, lines 6–23).

Two evaluation layers:
  * ``approx_best_diff`` — stage 1 (peer ranking): only gossip summaries are
    available (possibly stale), so the work after a transfer is approximated
    at cluster granularity.
  * ``find_best_exchange`` — stage 2 (after locking a peer): exact evaluation
    with the CCM update formulae over cluster give/swap candidates.

Each layer has a scalar reference path (this module's per-candidate loops)
and a batched production path (``engine=`` / ``repro.core.engine``): pass a
:class:`~repro.core.engine.PhaseEngine` to ``find_best_exchange`` /
``try_transfer`` and every shortlisted candidate pair is scored in one
vectorized pass; stage-1 batching lives in ``engine.batch_peer_diffs``.
Candidate enumeration, shortlisting, and the selection rule are shared by
both paths, so they pick the same exchange.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro.core.ccm import (INF, CCMState, ExchangeEval, effective_mem_cap,
                            exchange_eval)
from repro.core.clusters import (ClusterSummary, RankSummary,  # noqa: F401
                                 _half_split)


def _w_of(summary: RankSummary, params) -> float:
    # eq. 9 barrier against the soft cap (effective_mem_cap): a rank over
    # its (headroom-shrunk) capacity carries infinite work, so stage 1
    # ranks any feasibility-restoring peer ahead of every balance move.
    # Mirrored bitwise by engine.build_summary_tables' work column and the
    # QuiesceTracker work-list patch.
    if (params.memory_constraint
            and summary.mem_used > effective_mem_cap(summary.mem_cap,
                                                     params)):
        return INF
    return (params.alpha * summary.load / summary.speed
            + params.beta * summary.vol_off
            + params.gamma * summary.vol_on
            + params.delta * summary.homing)


def approx_transfer(me: RankSummary, peer: RankSummary, c: ClusterSummary,
                    params) -> Optional[Tuple[float, float]]:
    """Approximate (W_me_after, W_peer_after) when cluster c moves me->peer.

    Approximations (documented; stage 2 re-checks exactly): the cluster's
    external volume becomes off-rank for the peer and stops counting against
    me; its intra volume stays on-rank; its blocks land off-home on the peer
    unless the peer is their home (unknowable from summaries for sure — we
    assume off-home, the conservative direction).
    """
    if me.rank == peer.rank:
        return None
    # memory feasibility on the receiving side (soft cap, matched with
    # engine.batch_peer_diffs)
    if peer.mem_used + c.mem + c.block_bytes > effective_mem_cap(
            peer.mem_cap, params):
        return None
    w_me = (params.alpha * (me.load - c.load) / me.speed
            + params.beta * max(me.vol_off - c.vol_ext, 0.0)
            + params.gamma * max(me.vol_on - c.vol_intra, 0.0)
            + params.delta * me.homing)
    w_peer = (params.alpha * (peer.load + c.load) / peer.speed
              + params.beta * (peer.vol_off + c.vol_ext)
              + params.gamma * (peer.vol_on + c.vol_intra)
              + params.delta * (peer.homing + c.block_bytes))
    return w_me, w_peer


def approx_best_diff(me: RankSummary, peer: RankSummary, params) -> float:
    """Stage-1 criterion: best max-work reduction over my clusters -> peer."""
    w_me, w_peer = _w_of(me, params), _w_of(peer, params)
    max_before = max(w_me, w_peer)
    best = -np.inf
    for c in me.clusters:
        res = approx_transfer(me, peer, c, params)
        if res is None:
            continue
        diff = max_before - max(res)
        best = max(best, diff)
    # also consider pulling the peer's clusters here (peer may be overloaded)
    for c in peer.clusters:
        res = approx_transfer(peer, me, c, params)
        if res is None:
            continue
        diff = max_before - max(res)
        best = max(best, diff)
    return float(best)


@dataclasses.dataclass
class BestExchange:
    tasks_ab: np.ndarray   # move a -> b
    tasks_ba: np.ndarray   # move b -> a
    work_diff: float
    eval: ExchangeEval


_EMPTY = np.zeros(0, np.int64)


def memory_move_candidates(state: CCMState, r_from: int, r_to: int,
                           clusters_from: Sequence[np.ndarray],
                           max_candidates: int = 12) -> List[np.ndarray]:
    """Extra one-sided move candidates (r_from -> r_to) that trade memory
    against parallelism — the paper's replication trade-off (§III-A4) made
    an explicit part of the move vocabulary:

      * **replication splits** — a block-affine cluster (>= 2 tasks, all
        sharing one block) is bipartitioned by :func:`_half_split`; moving
        the lighter half materializes the block on ``r_to`` while the
        heavier half keeps it live on ``r_from``, i.e. deliberate
        replication buying load parallelism for block bytes;
      * **de-replication consolidations** — for each block replicated on
        BOTH ranks, ALL of ``r_from``'s tasks of that block move to
        ``r_to``: the move evicts ``r_from``'s copy (frees its bytes)
        without adding block bytes on ``r_to``, the eviction half of the
        pressure policy.

    Both shapes are plain task-set transfers, so they ride
    ``apply_transfer`` unchanged — transfer log, listeners, quiesce
    dirty-marking and the replay invariant all cover them for free — and
    they are scored through the same eq. 4 work model as every other
    candidate (``exchange_eval``), so the optimizer, not a rule, decides
    between migration, replication, eviction, or refusal.  Deterministic
    order: splits in cluster order, then consolidations in ascending block
    id, each capped at ``max_candidates``.
    """
    ph = state.phase
    out: List[np.ndarray] = []
    for c in clusters_from[:max_candidates]:
        c = np.asarray(c, np.int64)
        if c.shape[0] < 2:
            continue
        blocks = ph.task_block[c]
        if blocks[0] < 0 or not (blocks == blocks[0]).all():
            continue
        out.append(_half_split(ph.task_load, c))
    both = np.flatnonzero((state.block_count[r_from] > 0)
                          & (state.block_count[r_to] > 0))
    if both.size:
        mine = np.flatnonzero(state.assignment == r_from)
        tb = ph.task_block[mine]
        for b in both[:max_candidates]:
            cand = mine[tb == b]
            if cand.size:
                out.append(cand)
    return out


_PAIRS_CACHE: dict = {}


def _pairs_template(n_a: int, n_b: int) -> np.ndarray:
    """The full (n_a * n_b - 1, 2) candidate-pair index grid, cached per
    shape.  The grid is hot-path-invariant and the cached array is marked
    read-only, so sharing it is safe: consumers only read it, and the one
    mutation-shaped use (``pairs[order]`` fancy indexing) copies.  Anyone
    needing a writable grid must copy explicitly."""
    pairs = _PAIRS_CACHE.get((n_a, n_b))
    if pairs is None:
        ia, ib = np.divmod(np.arange(1, n_a * n_b, dtype=np.int64), n_b)
        pairs = np.stack([ia, ib], axis=1)
        pairs.setflags(write=False)
        _PAIRS_CACHE[(n_a, n_b)] = pairs
    return pairs


def shortlist_pairs(state: CCMState, clusters_a: List[np.ndarray],
                    clusters_b: List[np.ndarray], r_a: int, r_b: int,
                    max_candidates: int = 12, shortlist: int = 32,
                    engine=None):
    """Candidate enumeration + load-only shortlist, shared by
    ``find_best_exchange`` and ccm_lb's batched lock events.

    Beyond-paper speedup: a vectorized load-only estimate shortlists the
    most promising ``shortlist`` pairs; only those get the exact CCM
    update-formula evaluation (alpha dominates realistic instances, so the
    shortlist rarely excludes the true best; the final choice is exact).
    Depends only on the two ranks' own loads and cluster lists, so the
    shortlist of a lock event is invariant under transfers between OTHER
    (disjoint) rank pairs — the property batched lock events rest on.

    Returns ``(cand_a, cand_b, pairs, agg_a, agg_b)`` with ``pairs`` a
    (P, 2) int64 array of (ia, ib) rows; the aggregates are None on the
    scalar path (and capped at ``max_candidates`` clusters on the engine
    path — nothing past the candidate cut is ever scored).
    """
    empty = np.zeros((0,), np.int64)
    cand_a = [empty] + clusters_a[:max_candidates]
    cand_b = [empty] + clusters_b[:max_candidates]
    agg_a = agg_b = None
    if engine is not None:
        agg_a = engine.cluster_aggregates(r_a, clusters_a,
                                          limit=max_candidates)
        agg_b = engine.cluster_aggregates(r_b, clusters_b,
                                          limit=max_candidates)

    n_a, n_b = len(cand_a), len(cand_b)
    pairs = _pairs_template(n_a, n_b)           # (ia, ib) != (0, 0)
    if pairs.shape[0] > shortlist:
        ph = state.phase
        if engine is not None:  # cached, bitwise-equal per-cluster sums
            la = np.concatenate([[0.0], agg_a.loads[:max_candidates]])
            lb = np.concatenate([[0.0], agg_b.loads[:max_candidates]])
        else:
            la = np.array([ph.task_load[c].sum() for c in cand_a])
            lb = np.array([ph.task_load[c].sum() for c in cand_b])
        ia, ib = pairs[:, 0], pairs[:, 1]
        after_a = (state.load[r_a] - la[ia] + lb[ib]) / ph.rank_speed[r_a]
        after_b = (state.load[r_b] + la[ia] - lb[ib]) / ph.rank_speed[r_b]
        score = np.maximum(after_a, after_b)
        order = np.argsort(score)[:shortlist]
        pairs = pairs[order]
    return cand_a, cand_b, pairs, agg_a, agg_b


def select_best(cand_a, cand_b, pairs, wa, wb, feas,
                w_before: float) -> Optional[BestExchange]:
    """Selection rule over batched scores — shared by the engine path of
    ``find_best_exchange`` and ccm_lb's batched lock events, so deferred
    scoring picks the exact same exchange.

    Vectorized, selection-identical to the scalar scan it replaces: the
    scan kept the FIRST pair (in ``pairs`` order) whose positive diff was
    strictly greater than every earlier one — i.e. the first occurrence of
    the maximum, which is what ``argmax`` returns.
    """
    pairs = np.asarray(pairs, np.int64).reshape(-1, 2)
    wa, wb = np.asarray(wa), np.asarray(wb)
    ok = np.flatnonzero(np.asarray(feas, bool))  # before diff: infeasible
    if ok.size == 0:                             # rows hold inf - inf = nan
        return None
    diff = w_before - np.maximum(wa[ok], wb[ok])
    pos = np.flatnonzero(diff > 1e-12)
    if pos.size == 0:
        return None
    j = pos[np.argmax(diff[pos])]
    k = int(ok[j])
    ia, ib = int(pairs[k, 0]), int(pairs[k, 1])
    ev = ExchangeEval(float(wa[k]), float(wb[k]), True)
    return BestExchange(cand_a[ia], cand_b[ib], float(diff[j]), ev)


def find_best_exchange(state: CCMState, clusters_a: List[np.ndarray],
                       clusters_b: List[np.ndarray], r_a: int, r_b: int,
                       max_candidates: int = 12,
                       shortlist: int = 32,
                       engine=None,
                       replicate: bool = False) -> Optional[BestExchange]:
    """Exact FindBestCCM: best give/swap among cluster pairs (incl. one-sided
    gives via the empty cluster).  ``max_candidates`` bounds each side
    (clusters come sorted by load) — the paper's quality/cost tunable.

    ``engine``: a :class:`~repro.core.engine.PhaseEngine` scores every
    shortlisted pair in one batched pass; ``None`` falls back to one
    ``exchange_eval`` call per pair (reference path).

    ``replicate`` extends the candidate set with
    :func:`memory_move_candidates` (replication splits + de-replication
    consolidations, both directions).  The extras are scored through the
    scalar ``exchange_eval`` — even on the engine path — because they are
    one-sided gives outside the engine's cached cluster-aggregate space;
    an extra wins only on a STRICTLY greater work diff, so a run where no
    extra ever beats the base vocabulary is bitwise-identical to
    ``replicate=False``.
    """
    cand_a, cand_b, pairs, agg_a, agg_b = shortlist_pairs(
        state, clusters_a, clusters_b, r_a, r_b, max_candidates, shortlist,
        engine)
    w_before = max(state.work(r_a), state.work(r_b))

    if engine is not None:
        wa, wb, feas = engine.batch_exchange_eval(r_a, r_b, cand_a, cand_b,
                                                  pairs, agg_a, agg_b)
        best = select_best(cand_a, cand_b, pairs, wa, wb, feas, w_before)
    else:
        best = None
        for ia, ib in pairs:
            ca, cb = cand_a[ia], cand_b[ib]
            ev = exchange_eval(state, ca, cb, r_a, r_b)
            if not ev.feasible:
                continue
            diff = w_before - ev.max_after
            if diff > 1e-12 and (best is None or diff > best.work_diff):
                best = BestExchange(ca, cb, float(diff), ev)
    if not replicate:
        return best
    extras = [(c, _EMPTY) for c in memory_move_candidates(
        state, r_a, r_b, clusters_a, max_candidates)]
    extras += [(_EMPTY, c) for c in memory_move_candidates(
        state, r_b, r_a, clusters_b, max_candidates)]
    for ca, cb in extras:
        ev = exchange_eval(state, ca, cb, r_a, r_b)
        if not ev.feasible:
            continue
        diff = w_before - ev.max_after
        if diff > 1e-12 and (best is None or diff > best.work_diff):
            best = BestExchange(ca, cb, float(diff), ev)
    return best


def try_transfer(state: CCMState, clusters_a, clusters_b, r_a: int, r_b: int,
                 max_candidates: int = 12,
                 engine=None, replicate: bool = False
                 ) -> Optional[BestExchange]:
    """TryTransfer: execute the best positive exchange, if any (mutates)."""
    best = find_best_exchange(state, clusters_a, clusters_b, r_a, r_b,
                              max_candidates, engine=engine,
                              replicate=replicate)
    if best is None:
        return None
    state.swap(best.tasks_ab, r_a, best.tasks_ba, r_b)
    return best
