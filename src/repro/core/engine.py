"""Vectorized, incrementally-maintained CCM evaluation engine.

The CCM-LB optimizer's cost at scale is NOT the model — it is the number of
times the model is evaluated.  The seed evaluated each candidate cluster
give/swap with one :func:`repro.core.ccm.exchange_eval` call (a Python loop
over the touched edges and a dict of volume deltas); at 256 ranks that is
~400k calls and >80 % of wall-clock.  This module evaluates *all* candidate
moves of a lock event (and all stage-1 peer scores of a rank) in single
vectorized passes over flat arrays — and, since PR 2, all candidate moves
of SEVERAL disjoint lock events in one batched scoring pass that can run on
the Pallas ``ccm_scorer`` kernel.

Incremental state (PR 3)
------------------------
:class:`PhaseEngine` is a LONG-LIVED object that owns mutable per-rank
state and keeps it current across transfers instead of re-deriving it per
lock event:

  * ``rank segments`` — each rank's member-task id array, sorted ascending
    (bitwise what ``np.nonzero(assignment == r)[0]`` would return).  The
    engine registers a transfer listener on the wrapped ``CCMState``
    (:meth:`CCMState.add_transfer_listener`), so EVERY mutation — direct
    ``try_transfer`` swaps, grant-chain handoffs, batched deferred flushes —
    updates the segments in place in O(|segment| + |moved|); nothing is
    re-gathered from the (num_tasks,) assignment on the per-event path.
    ``rank_tasks(r)`` serves the segments to stage-2 flow assembly and to
    ``build_clusters(only_ranks=..., rank_tasks=...)`` incremental rebuilds.
  * ``cluster aggregates`` — per-cluster loads/mems/overheads and (block,
    count) tables, cached per cluster-list identity and capped at the
    caller's candidate limit (``ccm_lb`` only ever scores the first
    ``max_candidates`` clusters, so the tail is never aggregated).
  * per-rank block counters and shared/homing byte caches live on the
    wrapped ``CCMState`` and were already incremental (update formulae).

Invalidation contract: segments are invalidated by nothing (the listener
keeps them exact); aggregate caches are invalidated by cluster-list
IDENTITY (``ccm_lb`` installs a new list object when a rank's clusters are
rebuilt after a transfer, so stale aggregates are unreachable); everything
read from ``CCMState`` (vol/load/block_count/caches) is maintained by the
update formulae themselves.  ``PhaseEngine(..., incremental=False)`` keeps
the full re-gather path as the parity reference — tests/test_incremental.py
asserts segments and end-to-end trajectories are bitwise-identical between
the two.

Contract with the scalar path
-----------------------------
``exchange_eval`` (scalar) stays as the REFERENCE implementation.  The
batched scorer computes exactly the same model:

  * stage-1 (``batch_peer_diffs``) is arithmetic-identical to
    ``approx_best_diff`` — same IEEE operations in the same order, so the
    scores are bitwise-equal and the work lists (hence the whole CCM-LB
    trajectory) cannot diverge;
  * stage-2 (``batch_exchange_eval``) aggregates edge volumes through a
    group-flow matrix instead of a per-edge dict, so individual scores can
    differ from the scalar path by summation-order rounding (<= a few ulp);
    both paths start from the same incrementally-maintained ``CCMState``
    base quantities, and the parity suite (tests/test_engine.py) asserts
    score agreement to 1e-9 and identical end-to-end assignments.  The
    identical-trajectory guarantee is therefore empirical, not absolute: a
    phase where two candidate pairs' exact scores differ by less than the
    comm/block summation rounding could in principle make the two paths
    pick different (equally good) exchanges.  Exact ties DO break
    identically — per-cluster load/mem/overhead reductions are bitwise-
    shared with the scalar path and candidate pairs are compared in the
    same order — so the degenerate comm-free instances where ties actually
    occur (equal integer-ish loads, beta=gamma=delta=0) stay in lockstep;
    with continuous comm volumes, sub-ulp near-ties have measure zero.
  * the f64 engine backends (``backend="numpy"``, ``backend="jit"`` — the
    bucketed compiled pipeline — and ``backend="pallas"`` in interpret
    mode) are BITWISE-equal on scores and feasibility: all consume the
    same packed feature tiles (built here, reductions on the host) and
    evaluate the same multiplication-free expression tree (see
    repro/kernels/ccm_scorer; the numpy and jit paths literally share it
    via ``ref.score_tiles_xp``), then share one host-side work combine
    applied to the gathered shortlist pairs (``ops.combine_work_pairs`` —
    elementwise, so gather-then-combine equals combine-then-gather bit for
    bit).  tests/test_ccm_scorer.py and tests/test_scorer_jit.py assert
    it.  ``backend="pallas_compiled"`` scores in f32 on 128-lane tiles and
    sits in the weaker assignment-identity parity tier.

Stage-2 decomposition
---------------------
For a lock event on ranks (a, b) with candidate clusters A_1..A_na on a and
B_1..B_nb on b, label every task with a *group*:

  0 = other rank, 1 = stays on a, 2 = stays on b, 3+i = A_i, 3+na+j = B_j

and accumulate the group-to-group flow matrix F[g, h] = sum of edge volumes
src-group g -> dst-group h over the edges incident to a or b (one bincount
over a CSR gather).  Every sent/recv/on-rank volume before AND after any
exchange pair (A_i, B_j) is a small linear combination of F entries, so all
(na+1) x (nb+1) candidate pairs are scored with a handful of broadcast
ops.  Homing/shared-memory transitions (Thm III.1) decompose the same way:
per-cluster block leave/arrive terms plus a sparse pairwise correction for
blocks shared between A_i and B_j.

Batched lock events extend this to E pairwise-disjoint rank pairs: each
event keeps its own group id space (a block-diagonal flow matrix), all
blocks are accumulated with ONE flat bincount whose per-event bin segments
see exactly the per-event edge lists in the per-event order — so each
event's F is bitwise-identical to what a solo evaluation would build — and
the E score tiles go through the scorer in one call (one Pallas launch).
A transfer between ranks (a, b) never changes the TRUE score of a disjoint
pair (c, d): loads, blocks and memory of c/d are untouched, and c/d's
row/column sums of the volume matrix are preserved (moved edges only
relabel a<->b endpoints), which is what makes deferred batch scoring
trajectory-exact in exact arithmetic.  In floating point the preserved row
sums are re-summed from relabelled entries, so deferred scores can differ
from sequential post-swap scores by summation-order ulps — the same
empirical-not-absolute caveat as the engine-vs-scalar contract above.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.ccm import CCMState, INF, effective_mem_cap
from repro.core.csr import CSR, PhaseCSR, rank_segments
from repro.kernels.ccm_scorer import jit as scorer_jit
from repro.kernels.ccm_scorer import layout as L
from repro.kernels.ccm_scorer import ops as scorer_ops

__all__ = ["PhaseEngine", "ExchangeEvent", "SummaryTables",
           "build_summary_tables", "batch_peer_diffs"]


@dataclasses.dataclass
class ClusterAggregates:
    """Per-cluster scalar/block aggregates for one rank's cluster list.

    Everything here depends only on the cluster task sets (NOT on the
    current assignment or block counters), so it is cached per cluster list
    and reused across every lock event until the rank's clusters are
    rebuilt.  ``loads``/``mems``/``overheads`` use the same numpy reductions
    as the scalar path, so downstream arithmetic stays bitwise-compatible.
    """

    loads: np.ndarray       # (C,) task_load[c].sum() per cluster
    mems: np.ndarray        # (C,)
    overheads: np.ndarray   # (C,) max task overhead (0 for empty)
    blk_ci: np.ndarray      # (B,) cluster index per (cluster, block) pair
    blk_ids: np.ndarray     # (B,) block id
    blk_cnts: np.ndarray    # (B,) member tasks of that block in the cluster
    blk_sizes: np.ndarray   # (B,)
    blk_home: np.ndarray    # (B,) home rank of the block
    blk_map: Dict[int, List[Tuple[int, int]]]  # block -> [(ci, cnt)]


@dataclasses.dataclass
class ExchangeEvent:
    """One lock event to score: candidate cluster lists of a rank pair.

    ``cand_a[0]``/``cand_b[0]`` must be the empty cluster; ``pairs`` is the
    (ia, ib) shortlist to return scores for — a (P, 2) int64 array (what
    ``shortlist_pairs`` produces) or an equivalent sequence of tuples.
    ``agg_*`` are the cached aggregates of the rank's cluster lists
    (``cand_*[1:]`` must be a prefix of them; tables capped at the
    candidate cut are sufficient); omitted, they are computed on the fly.
    """

    r_a: int
    r_b: int
    cand_a: Sequence[np.ndarray]
    cand_b: Sequence[np.ndarray]
    pairs: Sequence  # (P, 2) int64 array or sequence of (ia, ib) tuples
    agg_a: Optional[ClusterAggregates] = None
    agg_b: Optional[ClusterAggregates] = None


class PhaseEngine:
    """Batched (vectorizable, JAX-friendly) move scoring over a CCMState.

    Long-lived: owns phase-static structure (the CSR view, reusable label
    buffers), per-cluster-list aggregate caches validated by list identity,
    and — with ``incremental=True`` (default) — per-rank member-task
    segments kept exact across transfers via a ``CCMState`` transfer
    listener (see the module docstring for the invalidation contract).
    ``incremental=False`` re-gathers rank membership from the assignment on
    every use: the full-rebuild parity reference.

    ``backend`` selects the stage-2 tile scorer (all four route through the
    shape-bucketed launcher, repro/kernels/ccm_scorer/jit.py):
    ``"numpy"`` (the reference, repro/kernels/ccm_scorer/ref.py), ``"jit"``
    (bucketed compiled f64 pipeline — one XLA compile per shape bucket,
    bitwise-equal to numpy on every score), ``"pallas"`` (the kernel;
    ``interpret=True`` runs it through the Pallas interpreter on CPU, where
    it is bitwise-equal to numpy — the CI-exercised path) and
    ``"pallas_compiled"`` (f32 tiles on the 128-lane boundary,
    ``interpret=False`` where a compile target exists, f32-interpret
    fallback otherwise; assignment-identity parity tier, not bitwise).
    """

    def __init__(self, state: CCMState, backend: str = "numpy",
                 interpret: bool = True, incremental: bool = True):
        if backend not in scorer_ops.BACKENDS:
            raise ValueError(f"unknown engine backend: {backend!r}")
        self.state = state
        self.csr: PhaseCSR = state.csr
        self.backend = backend
        self.interpret = interpret
        self.incremental = incremental
        self._glab = np.zeros(self.phase.num_tasks, np.int64)
        self._elab = np.full(self.phase.num_tasks, -1, np.int64)
        # spec_raw's label scratch: stamp-validated (a task's group label
        # only counts when its stamp equals the current call's tick), so
        # per-call resets are unnecessary — stale labels are masked out
        self._sp_g = np.zeros(self.phase.num_tasks, np.int64)
        self._sp_stamp = np.zeros(self.phase.num_tasks, np.int64)
        self._sp_tick = 0
        # rank -> (cluster list reference, aggregates, limit); holding the
        # list reference both validates the cache (ccm_lb installs a NEW
        # list when a rank's clusters are rebuilt) and pins its id.
        self._agg: Dict[int, Tuple[list, ClusterAggregates,
                                   Optional[int]]] = {}
        # version-validated caches of per-event quantities that only change
        # when a transfer mutates the state: cached values are the arrays a
        # recompute would return (same inputs, same ops), so hits are
        # bitwise-neutral.  Keyed by state.version (one int compare).
        self._blk_cache: Dict[Tuple[int, int], tuple] = {}
        self._vol_cache: Dict[int, Tuple[int, float, float]] = {}
        # rank-touch stamps: _rank_touch[r] = state version of the last
        # transfer that moved tasks in or out of r (stamped by the transfer
        # hook).  _incident entries are validated against the touch stamps
        # of THEIR two ranks instead of the global version, so transfers
        # between other ranks no longer invalidate them.  _touch_seen
        # detects out-of-band version bumps (retarget, non-incremental
        # engines where the hook never fires): those invalidate every rank.
        self._rank_touch = np.full(self.phase.num_ranks, state.version,
                                   np.int64)
        self._touch_seen = state.version
        self._eids_cache: Dict[int, Tuple[int, np.ndarray]] = {}
        self._edge_cache: Dict[Tuple[int, int], tuple] = {}
        self._segments: Optional[List[np.ndarray]] = None
        if incremental:
            segs = rank_segments(state.assignment, self.phase.num_ranks)
            self._segments = [segs.row(r)
                              for r in range(self.phase.num_ranks)]
            state.add_transfer_listener(self._on_transfer)

    @property
    def phase(self):
        """The CURRENT phase of the wrapped state — read through on every
        access, so an engine carried across ``CCMState.retarget`` (pipeline
        phase carry-over) follows the new phase's value arrays instead of
        pinning the build-time ones.  The retarget also bumps the state
        version, which invalidates every version-validated cache below."""
        return self.state.phase

    # ------------------------------------------------- incremental segments
    def _on_transfer(self, tasks: np.ndarray, r_from: int, r_to: int):
        """Transfer hook: splice the moved ids out of ``r_from``'s segment
        and merge them into ``r_to``'s, keeping both sorted — O(|segment| +
        |moved|), vs the O(num_tasks) assignment scan it replaces."""
        t = np.sort(np.asarray(tasks, np.int64))
        seg = self._segments[r_from]
        # every moved id is present in seg (transfer precondition), so the
        # searchsorted positions are exactly the entries to drop
        self._segments[r_from] = np.delete(seg, np.searchsorted(seg, t))
        seg = self._segments[r_to]
        self._segments[r_to] = np.insert(seg, np.searchsorted(seg, t), t)
        # the hook runs after apply_transfer's version bump (one bump per
        # transfer), so when every bump since the last stamp was a hooked
        # transfer, stamping the two ranks marks exactly this transfer;
        # a gap in the version sequence means unobserved bumps (retarget)
        # happened in between — then every rank may have changed
        v = self.state.version
        if self._touch_seen == v - 1:
            self._rank_touch[r_from] = self._rank_touch[r_to] = v
        else:
            self._rank_touch[:] = v
        self._touch_seen = v

    def rank_tasks(self, r: int) -> np.ndarray:
        """Member-task ids of rank ``r``, ascending — bitwise what
        ``np.nonzero(assignment == r)[0]`` returns, served from the
        incrementally-maintained segment (or gathered fresh when
        ``incremental=False``).  Callers must not mutate the array."""
        if self._segments is not None:
            return self._segments[r]
        return np.nonzero(self.state.assignment == r)[0]

    def cluster_aggregates(self, r: int, clusters: List[np.ndarray],
                           limit: Optional[int] = None) -> ClusterAggregates:
        """Aggregates of ``clusters[:limit]`` (all of them when ``limit`` is
        None), cached by cluster-list identity.  A cached full table serves
        any limited request; a cached limited table serves requests up to
        its limit and is recomputed otherwise."""
        cached = self._agg.get(r)
        if cached is not None and cached[0] is clusters:
            have = cached[2]
            if have is None or (limit is not None and have >= limit):
                return cached[1]
        agg = self._compute_aggregates(
            clusters if limit is None else clusters[:limit])
        self._agg[r] = (clusters, agg, limit)
        return agg

    def _compute_aggregates(self, clusters: List[np.ndarray]
                            ) -> ClusterAggregates:
        ph = self.phase
        loads = np.array([ph.task_load[c].sum() for c in clusters])
        mems = np.array([ph.task_mem[c].sum() for c in clusters])
        overheads = np.array([ph.task_overhead[c].max() if len(c) else 0.0
                              for c in clusters])
        # (cluster, block, count) table in one lexsorted run-length pass —
        # identical rows (ascending block within ascending cluster, integer
        # counts) to the per-cluster np.unique loop it replaces
        if clusters:
            ci = np.repeat(np.arange(len(clusters), dtype=np.int64),
                           [len(c) for c in clusters])
            tb = ph.task_block[np.concatenate(clusters)]
            has = tb >= 0
            ci, tb = ci[has], tb[has]
            order = np.lexsort((tb, ci))
            ci, tb = ci[order], tb[order]
            new = np.ones(ci.shape[0], bool)
            new[1:] = (ci[1:] != ci[:-1]) | (tb[1:] != tb[:-1])
            starts = np.nonzero(new)[0]
            blk_ci = ci[starts]
            blk_ids = tb[starts]
            blk_cnts = np.diff(np.append(starts, ci.shape[0]))
        else:
            blk_ci = blk_ids = blk_cnts = np.zeros(0, np.int64)
        blk_map: Dict[int, List[Tuple[int, int]]] = {}
        for i, blk, cnt in zip(blk_ci.tolist(), blk_ids.tolist(),
                               blk_cnts.tolist()):
            blk_map.setdefault(blk, []).append((i, cnt))
        return ClusterAggregates(
            loads=loads, mems=mems, overheads=overheads,
            blk_ci=blk_ci, blk_ids=blk_ids, blk_cnts=blk_cnts,
            blk_sizes=ph.block_size[blk_ids], blk_home=ph.block_home[blk_ids],
            blk_map=blk_map)

    # ------------------------------------------------------------- stage 2
    def batch_exchange_eval(
            self, r_a: int, r_b: int,
            cand_a: Sequence[np.ndarray], cand_b: Sequence[np.ndarray],
            pairs: Sequence[Tuple[int, int]],
            agg_a: ClusterAggregates = None, agg_b: ClusterAggregates = None,
    ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Score every candidate pair ``(cand_a[ia] a->b, cand_b[ib] b->a)``.

        Returns ``(work_a_after, work_b_after, feasible)`` arrays aligned
        with ``pairs``; infeasible pairs get ``inf`` work, matching the
        scalar ``exchange_eval``.  One-event convenience wrapper around
        :meth:`batch_exchange_eval_multi`.
        """
        [res] = self.batch_exchange_eval_multi([
            ExchangeEvent(r_a, r_b, cand_a, cand_b, pairs, agg_a, agg_b)])
        return res

    def batch_exchange_eval_multi(
            self, events: Sequence[ExchangeEvent],
    ) -> List[Tuple[np.ndarray, np.ndarray, np.ndarray]]:
        """Score a batched lock event: E pairwise-disjoint rank pairs.

        All events' block-diagonal flow matrices come from one flat
        bincount and all score tiles from one scorer call (one Pallas
        launch under ``backend="pallas"``).  Returns per-event
        ``(work_a_after, work_b_after, feasible)`` aligned with each
        event's ``pairs``.
        """
        if not events:
            return []
        events = [dataclasses.replace(
            e,
            agg_a=(e.agg_a if e.agg_a is not None
                   else self._compute_aggregates(list(e.cand_a[1:]))),
            agg_b=(e.agg_b if e.agg_b is not None
                   else self._compute_aggregates(list(e.cand_b[1:]))))
            for e in events]
        flows = self._flow_matrices(events)
        feats = [self._event_features(e, F) for e, F in zip(events, flows)]
        pairs_list = [np.asarray(e.pairs, np.int64).reshape(-1, 2)
                      for e in events]
        return scorer_jit.score_events(feats, pairs_list, self.state.params,
                                       backend=self.backend,
                                       interpret=self.interpret)

    def _rank_eids(self, r: int, touch: int) -> np.ndarray:
        """Ascending unique incident edge ids of rank ``r``, cached per
        rank-touch stamp — ``np.unique(task_edges.gather(rank_tasks(r)))``
        exactly, recomputed only when a transfer touches ``r``."""
        hit = self._eids_cache.get(r)
        if hit is not None and hit[0] == touch:
            return hit[1]
        eids = np.unique(self.csr.task_edges.gather(self.rank_tasks(r)))
        self._eids_cache[r] = (touch, eids)
        return eids

    def _incident(self, r_a: int, r_b: int):
        """``(both, n_a, src, dst, vol)`` for the edges incident to the two
        ranks: the concatenated member-task ids (``both[:n_a]`` = rank a's),
        and the endpoint/volume columns gathered at the ascending unique
        incident edge ids.  Both the batched flow assembly and the
        speculative-scan raws re-read these per event; entries are
        validated against the TOUCH STAMPS of their two ranks, so only a
        transfer in or out of ``r_a``/``r_b`` (not anywhere else) forces a
        recompute, and a hit returns exactly the arrays the gathers
        produced (bitwise-neutral).  The per-rank edge sets are cached the
        same way and merged — a stable sort of two ascending unique arrays
        deduped adjacently IS ``np.unique`` of their concatenation, so the
        result is bitwise what the direct gather produced.  Callers must
        not mutate the returned arrays."""
        st = self.state
        if st.version != self._touch_seen:
            # version bumps the transfer hook never saw (retarget, or a
            # non-incremental engine with no hook at all): every rank may
            # have changed, and the phase value arrays may differ too
            self._rank_touch[:] = st.version
            self._touch_seen = st.version
            self._eids_cache.clear()
            self._edge_cache.clear()
        ta = self._rank_touch[r_a]
        tb = self._rank_touch[r_b]
        cached = self._edge_cache.get((r_a, r_b))
        if cached is not None and cached[0] == ta and cached[1] == tb:
            return cached[2:]
        tasks_a = self.rank_tasks(r_a)
        n_a = tasks_a.shape[0]
        both = np.concatenate([tasks_a, self.rank_tasks(r_b)])
        m = np.sort(np.concatenate([self._rank_eids(r_a, ta),
                                    self._rank_eids(r_b, tb)]),
                    kind="stable")
        if m.shape[0]:
            eids = m[np.concatenate([[True], m[1:] != m[:-1]])]
        else:
            eids = m
        ph = self.phase
        entry = (both, n_a, ph.comm_src[eids], ph.comm_dst[eids],
                 ph.comm_vol[eids])
        self._edge_cache[(r_a, r_b)] = (ta, tb) + entry
        return entry

    def _flow_matrices(self, events: Sequence[ExchangeEvent]
                       ) -> List[np.ndarray]:
        """Per-event group-flow matrices via ONE flat bincount.

        Event k's bins only ever receive edges incident to event k's ranks,
        gathered in ascending edge-id order — exactly the edge list and
        order a solo evaluation uses — so each returned F is bitwise-equal
        to the single-event construction.  Tasks of other events read as
        group 0 ("other rank") through the event-id mask.
        """
        g, ev = self._glab, self._elab
        metas = []      # (tasks_both, cand_flat, src, dst, vol, G, offset)
        bins_l, w_l = [], []
        offset = 0

        def _reset_labels(upto):
            # candidate ids are reset too: a direct caller may pass arrays
            # with tasks no longer assigned to the event's ranks (a stale
            # label here would corrupt every later evaluation)
            for m in metas[:upto]:
                both_, cflat_ = m[0], m[1]
                g[both_] = 0
                ev[both_] = -1
                g[cflat_] = 0
                ev[cflat_] = -1

        for k, e in enumerate(events):
            na, nb = len(e.cand_a) - 1, len(e.cand_b) - 1
            G = 3 + na + nb
            both, n_a, src, dst, vol = self._incident(e.r_a, e.r_b)
            if (ev[both] != -1).any():
                # detected BEFORE this event touches the buffers: roll back
                # the earlier events' labels so the engine stays usable
                _reset_labels(k)
                raise ValueError(
                    "batched lock events must have pairwise-disjoint rank "
                    f"sets (event {k} on ranks ({e.r_a}, {e.r_b}) overlaps "
                    "an earlier event)")
            cl = list(e.cand_a[1:]) + list(e.cand_b[1:])
            if cl:
                cflat = np.concatenate(cl)
                cg = np.repeat(np.arange(3, 3 + na + nb, dtype=np.int64),
                               [len(c) for c in cl])
            else:
                cflat = cg = np.zeros(0, np.int64)
            g[both[:n_a]] = 1
            g[both[n_a:]] = 2
            ev[both] = k
            g[cflat] = cg       # duplicate ids resolve to the LAST write,
            ev[cflat] = k       # matching the per-cluster loop order
            metas.append((both, cflat, src, dst, vol, G, offset))
            offset += G * G
        for k, (both, cflat, src, dst, vol, G, off) in enumerate(metas):
            gs = np.where(ev[src] == k, g[src], 0)
            gd = np.where(ev[dst] == k, g[dst], 0)
            bins_l.append(off + gs * G + gd)
            w_l.append(vol)
        flat = np.bincount(
            np.concatenate(bins_l) if bins_l else np.zeros(0, np.int64),
            weights=np.concatenate(w_l) if w_l else None,
            minlength=offset)
        _reset_labels(len(metas))
        return [flat[off:off + G * G].reshape(G, G)
                for _, _, _, _, _, G, off in metas]

    def _event_features(self, e: ExchangeEvent, F: np.ndarray
                        ) -> Tuple[np.ndarray, np.ndarray, np.ndarray,
                                   np.ndarray]:
        """Feature planes of one event (see repro/kernels/ccm_scorer/ops.py
        for the layout) — host-side reductions only; everything downstream
        is elementwise and backend-shared."""
        st, ph = self.state, self.phase
        r_a, r_b = e.r_a, e.r_b
        agg_a, agg_b = e.agg_a, e.agg_b
        na, nb = len(e.cand_a) - 1, len(e.cand_b) - 1
        G = 3 + na + nb

        # group layout is contiguous (1 | 2 | a-clusters | b-clusters), so
        # every flow aggregate reduces to slice sums of F:
        # row_to_a[g] = v(g -> Ra), col_from_a[g] = v(Ra -> g), etc.
        sa, sb = 3, 3 + na
        row_to_a = F[:, 1] + F[:, sa:sb].sum(1)
        row_to_b = F[:, 2] + F[:, sb:].sum(1)
        col_from_a = F[1, :] + F[sa:sb, :].sum(0)
        col_from_b = F[2, :] + F[sb:, :].sum(0)

        ar = np.arange(sa, sb)
        br = np.arange(sb, G)

        # column 0 is the empty candidate (stays zero); writes go straight
        # into the [1:] slice
        av = np.zeros((L.N_AV, na + 1))
        av[L.AV.intra, 1:] = F[ar, ar]
        av[L.AV.out_own, 1:] = row_to_a[sa:sb]    # v(A -> Ra)
        av[L.AV.in_own, 1:] = col_from_a[sa:sb]   # v(Ra -> A)
        av[L.AV.out_peer, 1:] = row_to_b[sa:sb]   # v(A -> Rb)
        av[L.AV.in_peer, 1:] = col_from_b[sa:sb]  # v(Rb -> A)
        av[L.AV.out_other, 1:] = F[sa:sb, 0]
        av[L.AV.in_other, 1:] = F[0, sa:sb]
        av[L.AV.load, 1:] = agg_a.loads[:na]
        av[L.AV.mem, 1:] = agg_a.mems[:na]
        av[L.AV.ovh, 1:] = agg_a.overheads[:na]
        (av[L.AV.s_rm], av[L.AV.h_rm], av[L.AV.s_add_peer],
         av[L.AV.h_add_peer]) = self._block_terms(agg_a, na, r_a, r_b)

        bv = np.zeros((L.N_AV, nb + 1))
        bv[L.AV.intra, 1:] = F[br, br]
        bv[L.AV.out_own, 1:] = row_to_b[sb:]
        bv[L.AV.in_own, 1:] = col_from_b[sb:]
        bv[L.AV.out_peer, 1:] = row_to_a[sb:]
        bv[L.AV.in_peer, 1:] = col_from_a[sb:]
        bv[L.AV.out_other, 1:] = F[sb:, 0]
        bv[L.AV.in_other, 1:] = F[0, sb:]
        bv[L.AV.load, 1:] = agg_b.loads[:nb]
        bv[L.AV.mem, 1:] = agg_b.mems[:nb]
        bv[L.AV.ovh, 1:] = agg_b.overheads[:nb]
        (bv[L.AV.s_rm], bv[L.AV.h_rm], bv[L.AV.s_add_peer],
         bv[L.AV.h_add_peer]) = self._block_terms(agg_b, nb, r_b, r_a)

        pm = np.zeros((L.N_PM, na + 1, nb + 1))
        if na and nb:
            pm[L.PM.x_ab, 1:, 1:] = F[sa:sb, sb:]       # v(A_i -> B_j)
            pm[L.PM.x_ba, 1:, 1:] = F[sb:, sa:sb].T     # v(B_j -> A_i)
        pm[L.PM.cs_a:] = self._pm_corrections(e, na, nb)

        # one literal in layout.SC index order (0..31) — a single array
        # construction instead of 32 scalar __setitem__ calls on the hot
        # path; the deltas are applied to the incrementally-maintained
        # bases, mirroring the scalar path's base-plus-dvol structure so
        # both paths share any drift in vol.
        vol_aa, vol_bb = st.vol[r_a, r_a], st.vol[r_b, r_b]
        row_a, col_a = self._vol_sums(r_a)
        row_b, col_b = self._vol_sums(r_b)
        sc = np.array([
            row_to_b[1] + row_to_b[sa:sb].sum(),   # f_ab: v(Ra -> Rb)
            row_to_a[2] + row_to_a[sb:].sum(),     # f_ba
            row_to_a[1] + row_to_a[sa:sb].sum(),   # f_aa
            row_to_b[2] + row_to_b[sb:].sum(),     # f_bb
            F[1, 0] + F[sa:sb, 0].sum(),           # f_ao
            F[0, 1] + F[0, sa:sb].sum(),           # f_oa
            F[2, 0] + F[sb:, 0].sum(),             # f_bo
            F[0, 2] + F[0, sb:].sum(),             # f_ob
            row_a - vol_aa,                        # base_sent_a
            col_a - vol_aa,                        # base_recv_a
            row_b - vol_bb,                        # base_sent_b
            col_b - vol_bb,                        # base_recv_b
            vol_aa,                                # vol_aa
            vol_bb,                                # vol_bb
            st.load[r_a],                          # load_a
            st.load[r_b],                          # load_b
            st.shared_cache[r_a],                  # shared_a
            st.shared_cache[r_b],                  # shared_b
            st.hom_cache[r_a],                     # hom_a
            st.hom_cache[r_b],                     # hom_b
            ph.rank_mem_base[r_a],                 # mem_base_a
            st.mem_task[r_a],                      # mem_task_a
            st.mem_overhead_max[r_a],              # ovh_a
            ph.rank_mem_base[r_b],                 # mem_base_b
            st.mem_task[r_b],                      # mem_task_b
            st.mem_overhead_max[r_b],              # ovh_b
            float(na),                             # na
            float(nb),                             # nb
            ph.rank_speed[r_a],                    # speed_a
            ph.rank_speed[r_b],                    # speed_b
            # caps packed pre-scaled through the soft-cap helper: the
            # compiled combines compare plain <=, so the feasibility bit
            # matches the scalar exchange_eval exactly
            effective_mem_cap(ph.rank_mem_cap[r_a], st.params),  # mem_cap_a
            effective_mem_cap(ph.rank_mem_cap[r_b], st.params),  # mem_cap_b
        ])
        assert sc.shape[0] == L.N_SC
        return av, bv, pm, sc

    def _pm_corrections(self, e: ExchangeEvent, na: int, nb: int
                        ) -> np.ndarray:
        """The sparse pairwise shared-block correction planes (cs_a, ch_a,
        cs_b, ch_b) as a dense (4, na+1, nb+1) stack: blocks present in
        BOTH moving clusters, where the independent leave terms over-fire
        because the counter-flow keeps the block present (Thm III.1).
        Shared by the full-tile feature packer and the speculative-scan
        raws; the loop is the exact code (same adds, same order) the packer
        ran in place, so the factoring is bitwise-neutral."""
        st, ph = self.state, self.phase
        agg_a, agg_b = e.agg_a, e.agg_b
        r_a, r_b = e.r_a, e.r_b
        pm = np.zeros((4, na + 1, nb + 1))
        for blk, lst_a in agg_a.blk_map.items():
            lst_b = agg_b.blk_map.get(blk)
            if not lst_b:
                continue
            size = ph.block_size[blk]
            off_home_a = ph.block_home[blk] != r_a
            off_home_b = ph.block_home[blk] != r_b
            for i, cnt_a in lst_a:
                if i >= na:
                    continue
                for j, cnt_b in lst_b:
                    if j >= nb:
                        continue
                    if st.block_count[r_a, blk] == cnt_a:
                        pm[0, i + 1, j + 1] += size
                        if off_home_a:
                            pm[1, i + 1, j + 1] += size
                    if st.block_count[r_b, blk] == cnt_b:
                        pm[2, i + 1, j + 1] += size
                        if off_home_b:
                            pm[3, i + 1, j + 1] += size
        return pm

    # -------------------------------------------- speculative-scan raws
    def spec_raw(self, e: ExchangeEvent, a_lanes: int, b_lanes: int,
                 p_n: int) -> Tuple[np.ndarray, int]:
        """One complete flat launch row for the speculative-scan compiled
        path (``kernels/ccm_scorer/jit.py`` kind="spec"): everything the
        traced pipeline needs to assemble the flow matrix and score the
        shortlist IN-TRACE, gathered from the CURRENT (speculative) state.

        Unlike :meth:`_flow_matrices`' per-event-sized group space, the
        label layout here is FIXED by the lane buckets so one compiled
        function serves every event of a run: group 0 = other ranks, 1 =
        stays on a, 2 = stays on b, a-candidate i at ``3 + (i-1)``,
        b-candidate j at ``3 + (a_lanes-1) + (j-1)``; ``G = 3 +
        (a_lanes-1) + (b_lanes-1)``.  Unused candidate groups receive no
        edges, so the traced slice sums see exact zeros there.

        Returns ``(row, eb)``: ``row`` is a ready-to-stack launch row in
        the ``_spec_offsets(eb, a_lanes, b_lanes, p_n)`` layout
        ``[bins | w | avh | bvh | pmh | sch | iaf | ibf | misc]`` with the
        params columns (alpha..delta, the memory-constraint cap masking)
        and the shortlist pair count already baked in; ``eb`` is the edge
        bucket the bins/w slots were sized to.  The driver fills only
        ``row[-2]`` (the pre-exchange work bound) before the launch;
        ``score_spec`` stacks rows verbatim.  Emitting the final layout
        here — feature sections written through reshape views of the row —
        avoids a second per-event assemble-then-copy pass at launch time.
        """
        st, ph = self.state, self.phase
        r_a, r_b = e.r_a, e.r_b
        agg_a, agg_b = e.agg_a, e.agg_b
        na, nb = len(e.cand_a) - 1, len(e.cand_b) - 1
        if na >= a_lanes or nb >= b_lanes:
            raise ValueError("candidate count exceeds the spec lane bucket")
        sa, sb = 3, 3 + (a_lanes - 1)
        g_n = sb + (b_lanes - 1)
        g, stamp = self._sp_g, self._sp_stamp
        tick = self._sp_tick = self._sp_tick + 1
        both, n_a, src, dst, vol = self._incident(r_a, r_b)
        cl = list(e.cand_a[1:]) + list(e.cand_b[1:])
        if cl:
            cflat = np.concatenate(cl)
            cg = np.repeat(
                np.concatenate([np.arange(sa, sa + na, dtype=np.int64),
                                np.arange(sb, sb + nb, dtype=np.int64)]),
                [len(c) for c in cl])
        else:
            cflat = cg = np.zeros(0, np.int64)
        g[both[:n_a]] = 1
        g[both[n_a:]] = 2
        stamp[both] = tick
        g[cflat] = cg       # duplicate ids: LAST write wins, matching
        stamp[cflat] = tick     # the per-cluster loop order
        # stale labels from earlier calls fail the stamp test, so no reset
        # scatters are needed between events
        gs = np.where(stamp[src] == tick, g[src], 0)
        gd = np.where(stamp[dst] == tick, g[dst], 0)

        ne = src.shape[0]
        eb = scorer_jit.bucket_edges(ne)
        (o_w, o_av, o_bv, o_pm, o_sc, o_ia, o_ib, o_ms,
         row_len) = scorer_jit._spec_offsets(eb, a_lanes, b_lanes, p_n)
        row = np.zeros(row_len)
        row[:ne] = gs * g_n + gd            # pad edges land in bin (0, 0),
        row[o_w:o_w + ne] = vol             # which no feature reads

        avh = row[o_av:o_bv].reshape(7, a_lanes)
        avh[0, 1:na + 1] = agg_a.loads[:na]
        avh[1, 1:na + 1] = agg_a.mems[:na]
        avh[2, 1:na + 1] = agg_a.overheads[:na]
        avh[3:7, :na + 1] = self._block_terms(agg_a, na, r_a, r_b)
        bvh = row[o_bv:o_pm].reshape(7, b_lanes)
        bvh[0, 1:nb + 1] = agg_b.loads[:nb]
        bvh[1, 1:nb + 1] = agg_b.mems[:nb]
        bvh[2, 1:nb + 1] = agg_b.overheads[:nb]
        bvh[3:7, :nb + 1] = self._block_terms(agg_b, nb, r_b, r_a)

        pr = np.asarray(e.pairs, np.int64).reshape(-1, 2)
        p = pr.shape[0]
        if p > p_n:
            raise ValueError("shortlist exceeds the spec pair bucket")
        ia, ib = pr[:, 0], pr[:, 1]
        row[o_pm:o_sc].reshape(4, p_n)[:, :p] = \
            self._pm_corrections(e, na, nb)[:, ia, ib]

        params = st.params
        mc = params.memory_constraint
        vol_aa, vol_bb = st.vol[r_a, r_a], st.vol[r_b, r_b]
        row_a, col_a = self._vol_sums(r_a)
        row_b, col_b = self._vol_sums(r_b)
        # the scalar row: the 8 f_* flow slots stay zero (derived in-trace)
        row[o_sc + L.SC.base_sent_a:o_ia] = (
            row_a - vol_aa, col_a - vol_aa,        # base_sent/recv_a
            row_b - vol_bb, col_b - vol_bb,        # base_sent/recv_b
            vol_aa, vol_bb,
            st.load[r_a], st.load[r_b],
            st.shared_cache[r_a], st.shared_cache[r_b],
            st.hom_cache[r_a], st.hom_cache[r_b],
            ph.rank_mem_base[r_a], st.mem_task[r_a],
            st.mem_overhead_max[r_a],
            ph.rank_mem_base[r_b], st.mem_task[r_b],
            st.mem_overhead_max[r_b],
            float(na), float(nb),
            ph.rank_speed[r_a], ph.rank_speed[r_b],
            effective_mem_cap(ph.rank_mem_cap[r_a], params)
            if mc else np.inf,                         # mem_cap_a
            effective_mem_cap(ph.rank_mem_cap[r_b], params)
            if mc else np.inf,                         # mem_cap_b
        )
        row[o_ia:o_ia + p] = ia             # pad pair slots read pair
        row[o_ib:o_ib + p] = ib             # (0, 0); p_count masks them
        row[o_ms + 0] = params.alpha
        row[o_ms + 1] = params.beta
        row[o_ms + 2] = params.gamma
        row[o_ms + 3] = params.delta
        row[o_ms + 5] = p                   # row[o_ms + 4] = driver's
        return row, eb                      # w_before

    def _vol_sums(self, r: int) -> Tuple[float, float]:
        """(row sum, column sum) of the vol matrix for rank ``r``, cached
        per state version — transfers between ANY ranks relabel entries of
        third ranks' rows/columns, so the cache is version-global; a hit
        returns exactly what the two ``np.sum`` calls produced."""
        st = self.state
        hit = self._vol_cache.get(r)
        if hit is not None and hit[0] == st.version:
            return hit[1], hit[2]
        row, col = st.vol[r].sum(), st.vol[:, r].sum()
        self._vol_cache[r] = (st.version, row, col)
        return row, col

    def _block_terms(self, agg: ClusterAggregates, n: int, r_src: int,
                     r_dst: int):
        """Independent (one-sided) block transition terms for the first
        ``n`` clusters: bytes leaving ``r_src``'s shared/homing caches and
        arriving at ``r_dst``'s (index 0 = empty candidate).  Uses the
        CURRENT block counters — cached per (src, dst) direction and
        invalidated by the state version, so repeat events between
        transfers skip the recompute (the cached arrays ARE what the
        recompute would return)."""
        st = self.state
        key = (r_src, r_dst)
        hit = self._blk_cache.get(key)
        if hit is not None and hit[0] == st.version and hit[1] is agg \
                and hit[2] == n:
            return hit[3]
        hi = np.searchsorted(agg.blk_ci, n)  # blk_ci ascending -> prefix
        ci = agg.blk_ci[:hi] + 1
        ids = agg.blk_ids[:hi]
        sizes = agg.blk_sizes[:hi]
        leaves = st.block_count[r_src, ids] == agg.blk_cnts[:hi]
        arrives = st.block_count[r_dst, ids] == 0
        # the four per-cluster sums share one index vector, so one fused
        # bincount over four shifted copies replaces four calls; each
        # output bin still receives its addends in the same ascending-ci
        # order, so every row is bitwise the separate bincount it replaces
        m = n + 1
        t = np.bincount(
            np.concatenate([ci, ci + m, ci + 2 * m, ci + 3 * m]),
            weights=np.concatenate([
                sizes * leaves,
                sizes * (leaves & (agg.blk_home[:hi] != r_src)),
                sizes * arrives,
                sizes * (arrives & (agg.blk_home[:hi] != r_dst)),
            ]),
            minlength=4 * m).reshape(4, m)
        terms = (t[0], t[1], t[2], t[3])
        self._blk_cache[key] = (st.version, agg, n, terms)
        return terms


# ---------------------------------------------------------------- stage 1
@dataclasses.dataclass
class SummaryTables:
    """SoA mirror of one iteration's Rank/ClusterSummary objects.

    Per-rank arrays are indexed by rank id; per-cluster arrays are flat with
    ``c_indptr`` rank segments (same order as ``RankSummary.clusters``).
    """

    load: np.ndarray
    vol_on: np.ndarray
    vol_off: np.ndarray
    homing: np.ndarray
    mem_used: np.ndarray
    mem_cap: np.ndarray
    speed: np.ndarray
    work: np.ndarray          # _w_of(summary) per rank
    c_ids: CSR                # rank -> flat cluster ids (indptr is (I+1,))
    c_load: np.ndarray
    c_mem: np.ndarray
    c_block_bytes: np.ndarray
    c_vol_intra: np.ndarray
    c_vol_ext: np.ndarray


def build_summary_tables(summaries: Dict, params) -> SummaryTables:
    n = len(summaries)
    ranks = [summaries[r] for r in range(n)]
    load = np.array([s.load for s in ranks])
    vol_on = np.array([s.vol_on for s in ranks])
    vol_off = np.array([s.vol_off for s in ranks])
    homing = np.array([s.homing for s in ranks])
    speed = np.array([s.speed for s in ranks])
    work = (params.alpha * load / speed + params.beta * vol_off
            + params.gamma * vol_on + params.delta * homing)
    mem_used = np.array([s.mem_used for s in ranks])
    mem_cap = np.array([s.mem_cap for s in ranks])
    if params.memory_constraint:
        # eq. 9 barrier, mirrored bitwise with the scalar ``_w_of`` and the
        # quiesce work-list patch: a rank over its soft cap carries
        # infinite work, so stage 1 ranks feasibility-restoring peers first
        # (the np.where is the identity when every rank fits)
        work = np.where(mem_used <= effective_mem_cap(mem_cap, params),
                        work, INF)
    c_indptr = np.zeros(n + 1, np.int64)
    np.cumsum([len(s.clusters) for s in ranks], out=c_indptr[1:])
    flat = [c for s in ranks for c in s.clusters]
    c_ids = CSR(c_indptr, np.arange(len(flat), dtype=np.int64))
    return SummaryTables(
        load=load, vol_on=vol_on, vol_off=vol_off, homing=homing,
        mem_used=mem_used, mem_cap=mem_cap,
        speed=speed, work=work, c_ids=c_ids,
        c_load=np.array([c.load for c in flat]),
        c_mem=np.array([c.mem for c in flat]),
        c_block_bytes=np.array([c.block_bytes for c in flat]),
        c_vol_intra=np.array([c.vol_intra for c in flat]),
        c_vol_ext=np.array([c.vol_ext for c in flat]),
    )


def _seg_gather(t: SummaryTables, ranks: np.ndarray):
    """(owner index, flat cluster ids) for all clusters of ``ranks``."""
    idx = t.c_ids.gather(ranks)
    counts = t.c_ids.indptr[ranks + 1] - t.c_ids.indptr[ranks]
    owner = np.repeat(np.arange(ranks.shape[0]), counts)
    return owner, idx


def batch_peer_diffs(t: SummaryTables, r: int, peers: np.ndarray,
                     params) -> np.ndarray:
    """Stage-1 peer scores for rank ``r`` against ``peers`` in one pass.

    Arithmetic-identical to ``approx_best_diff(summaries[r], summaries[p])``
    per peer: same expressions, same IEEE evaluation order, with the scalar
    max-over-candidates rewritten as ``max_before - min(after)`` (exactly
    equal for finite IEEE values since x -> M - x is antitone).

    ASSUMPTION: the tables hold THIS iteration's summaries and gossip
    payloads are references to those same objects (``info[r][p] is
    summaries[p]``, true of ``build_peer_networks`` AND of the async
    event-loop driver's gossip stage — repro/core/async_sim.py snapshots
    ``info_known`` dicts whose VALUES alias the iteration's summaries, and
    its gossip deadline only drops whole deliveries) — staleness is only
    in WHICH peers a rank knows, never in the values.  If gossip ever
    carries summaries from older iterations, the scalar path would score
    from what rank ``r`` actually received while this path scores from the
    global tables, and the identical-trajectory contract breaks; the tables
    would then need to be built per recipient from ``info[r]``.
    """
    peers = np.asarray(peers, np.int64)
    n_p = peers.shape[0]
    if n_p == 0:
        return np.zeros(0)
    a, b, g, d = params.alpha, params.beta, params.gamma, params.delta
    max_before = np.maximum(t.work[r], t.work[peers])

    # my clusters -> each peer (give direction)
    sl = slice(t.c_ids.indptr[r], t.c_ids.indptr[r + 1])
    cl, cm = t.c_load[sl], t.c_mem[sl]
    cbb, cvi, cve = t.c_block_bytes[sl], t.c_vol_intra[sl], t.c_vol_ext[sl]
    after_give = np.full(n_p, np.inf)
    if cl.shape[0]:
        feas = ~((t.mem_used[peers][None, :] + cm[:, None] + cbb[:, None])
                 > effective_mem_cap(t.mem_cap[peers], params)[None, :])
        w_me = (a * (t.load[r] - cl) / t.speed[r]
                + b * np.maximum(t.vol_off[r] - cve, 0.0)
                + g * np.maximum(t.vol_on[r] - cvi, 0.0)
                + d * t.homing[r])
        w_peer = (a * (t.load[peers][None, :] + cl[:, None])
                  / t.speed[peers][None, :]
                  + b * (t.vol_off[peers][None, :] + cve[:, None])
                  + g * (t.vol_on[peers][None, :] + cvi[:, None])
                  + d * (t.homing[peers][None, :] + cbb[:, None]))
        after = np.where(feas, np.maximum(w_me[:, None], w_peer), np.inf)
        after_give = after.min(axis=0)

    # each peer's clusters -> me (pull direction)
    owner, idx = _seg_gather(t, peers)
    after_pull = np.full(n_p, np.inf)
    if idx.shape[0]:
        own = peers[owner]
        pl, pm = t.c_load[idx], t.c_mem[idx]
        pbb, pvi, pve = (t.c_block_bytes[idx], t.c_vol_intra[idx],
                         t.c_vol_ext[idx])
        feas = ~((t.mem_used[r] + pm + pbb)
                 > effective_mem_cap(t.mem_cap[r], params))
        w_src = (a * (t.load[own] - pl) / t.speed[own]
                 + b * np.maximum(t.vol_off[own] - pve, 0.0)
                 + g * np.maximum(t.vol_on[own] - pvi, 0.0)
                 + d * t.homing[own])
        w_me = (a * (t.load[r] + pl) / t.speed[r]
                + b * (t.vol_off[r] + pve)
                + g * (t.vol_on[r] + pvi)
                + d * (t.homing[r] + pbb))
        after = np.where(feas, np.maximum(w_src, w_me), np.inf)
        np.minimum.at(after_pull, owner, after)

    with np.errstate(invalid="ignore"):
        # inf - inf (both sides pressure-barriered) -> nan, dropped by
        # the caller's d > 0 filter
        return max_before - np.minimum(after_give, after_pull)
