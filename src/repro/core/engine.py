"""Vectorized CCM evaluation engine.

The CCM-LB optimizer's cost at scale is NOT the model — it is the number of
times the model is evaluated.  The seed evaluated each candidate cluster
give/swap with one :func:`repro.core.ccm.exchange_eval` call (a Python loop
over the touched edges and a dict of volume deltas); at 256 ranks that is
~400k calls and >80 % of wall-clock.  This module evaluates *all* candidate
moves of a lock event (and all stage-1 peer scores of a rank) in single
vectorized passes over flat arrays.

Contract with the scalar path
-----------------------------
``exchange_eval`` (scalar) stays as the REFERENCE implementation.  The
batched scorer computes exactly the same model:

  * stage-1 (``batch_peer_diffs``) is arithmetic-identical to
    ``approx_best_diff`` — same IEEE operations in the same order, so the
    scores are bitwise-equal and the work lists (hence the whole CCM-LB
    trajectory) cannot diverge;
  * stage-2 (``batch_exchange_eval``) aggregates edge volumes through a
    group-flow matrix instead of a per-edge dict, so individual scores can
    differ from the scalar path by summation-order rounding (<= a few ulp);
    both paths start from the same incrementally-maintained ``CCMState``
    base quantities, and the parity suite (tests/test_engine.py) asserts
    score agreement to 1e-9 and identical end-to-end assignments.  The
    identical-trajectory guarantee is therefore empirical, not absolute: a
    phase where two candidate pairs' exact scores differ by less than the
    comm/block summation rounding could in principle make the two paths
    pick different (equally good) exchanges.  Exact ties DO break
    identically — per-cluster load/mem/overhead reductions are bitwise-
    shared with the scalar path and candidate pairs are compared in the
    same order — so the degenerate comm-free instances where ties actually
    occur (equal integer-ish loads, beta=gamma=delta=0) stay in lockstep;
    with continuous comm volumes, sub-ulp near-ties have measure zero.

Stage-2 decomposition
---------------------
For a lock event on ranks (a, b) with candidate clusters A_1..A_na on a and
B_1..B_nb on b, label every task with a *group*:

  0 = other rank, 1 = stays on a, 2 = stays on b, 3+i = A_i, 3+na+j = B_j

and accumulate the group-to-group flow matrix F[g, h] = sum of edge volumes
src-group g -> dst-group h over the edges incident to a or b (one bincount
over a CSR gather).  Every sent/recv/on-rank volume before AND after any
exchange pair (A_i, B_j) is a small linear combination of F entries, so all
(na+1) x (nb+1) candidate pairs are scored with a handful of broadcast
ops.  Homing/shared-memory transitions (Thm III.1) decompose the same way:
per-cluster block leave/arrive terms plus a sparse pairwise correction for
blocks shared between A_i and B_j.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Sequence, Tuple

import numpy as np

from repro.core.ccm import CCMState, INF
from repro.core.csr import CSR, PhaseCSR

__all__ = ["PhaseEngine", "SummaryTables", "build_summary_tables",
           "batch_peer_diffs"]


@dataclasses.dataclass
class ClusterAggregates:
    """Per-cluster scalar/block aggregates for one rank's cluster list.

    Everything here depends only on the cluster task sets (NOT on the
    current assignment or block counters), so it is cached per cluster list
    and reused across every lock event until the rank's clusters are
    rebuilt.  ``loads``/``mems``/``overheads`` use the same numpy reductions
    as the scalar path, so downstream arithmetic stays bitwise-compatible.
    """

    loads: np.ndarray       # (C,) task_load[c].sum() per cluster
    mems: np.ndarray        # (C,)
    overheads: np.ndarray   # (C,) max task overhead (0 for empty)
    blk_ci: np.ndarray      # (B,) cluster index per (cluster, block) pair
    blk_ids: np.ndarray     # (B,) block id
    blk_cnts: np.ndarray    # (B,) member tasks of that block in the cluster
    blk_sizes: np.ndarray   # (B,)
    blk_home: np.ndarray    # (B,) home rank of the block
    blk_map: Dict[int, List[Tuple[int, int]]]  # block -> [(ci, cnt)]


class PhaseEngine:
    """Batched (vectorizable, JAX-friendly) move scoring over a CCMState.

    Holds only *phase-static* structure (the CSR view, a reusable label
    buffer) plus per-cluster-list aggregate caches validated by list
    identity; all mutable state stays in the wrapped ``CCMState``, so the
    engine remains valid across transfers.
    """

    def __init__(self, state: CCMState):
        self.state = state
        self.phase = state.phase
        self.csr: PhaseCSR = state.csr
        self._glab = np.zeros(self.phase.num_tasks, np.int64)
        # rank -> (cluster list reference, aggregates); holding the list
        # reference both validates the cache (ccm_lb installs a NEW list
        # when a rank's clusters are rebuilt) and pins its id.
        self._agg: Dict[int, Tuple[list, ClusterAggregates]] = {}

    def cluster_aggregates(self, r: int,
                           clusters: List[np.ndarray]) -> ClusterAggregates:
        cached = self._agg.get(r)
        if cached is not None and cached[0] is clusters:
            return cached[1]
        agg = self._compute_aggregates(clusters)
        self._agg[r] = (clusters, agg)
        return agg

    def _compute_aggregates(self, clusters: List[np.ndarray]
                            ) -> ClusterAggregates:
        ph = self.phase
        loads = np.array([ph.task_load[c].sum() for c in clusters])
        mems = np.array([ph.task_mem[c].sum() for c in clusters])
        overheads = np.array([ph.task_overhead[c].max() if len(c) else 0.0
                              for c in clusters])
        ci_l, ids_l, cnt_l = [], [], []
        blk_map: Dict[int, List[Tuple[int, int]]] = {}
        for i, c in enumerate(clusters):
            tb = ph.task_block[c]
            tb = tb[tb >= 0]
            if tb.size == 0:
                continue
            bs, cnts = np.unique(tb, return_counts=True)
            ci_l.append(np.full(bs.shape[0], i, np.int64))
            ids_l.append(bs)
            cnt_l.append(cnts)
            for blk, cnt in zip(bs, cnts):
                blk_map.setdefault(int(blk), []).append((i, int(cnt)))
        if ci_l:
            blk_ci = np.concatenate(ci_l)
            blk_ids = np.concatenate(ids_l)
            blk_cnts = np.concatenate(cnt_l)
        else:
            blk_ci = blk_ids = blk_cnts = np.zeros(0, np.int64)
        return ClusterAggregates(
            loads=loads, mems=mems, overheads=overheads,
            blk_ci=blk_ci, blk_ids=blk_ids, blk_cnts=blk_cnts,
            blk_sizes=ph.block_size[blk_ids], blk_home=ph.block_home[blk_ids],
            blk_map=blk_map)

    # ------------------------------------------------------------- stage 2
    def batch_exchange_eval(
            self, r_a: int, r_b: int,
            cand_a: Sequence[np.ndarray], cand_b: Sequence[np.ndarray],
            pairs: Sequence[Tuple[int, int]],
            agg_a: ClusterAggregates = None, agg_b: ClusterAggregates = None,
    ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Score every candidate pair ``(cand_a[ia] a->b, cand_b[ib] b->a)``.

        ``cand_a[0]``/``cand_b[0]`` must be the empty cluster (one-sided
        gives).  ``agg_*`` are the cached aggregates of the rank's FULL
        cluster lists (``cand_*[1:]`` must be a prefix of them); omitted,
        they are computed on the fly.  Returns ``(work_a_after,
        work_b_after, feasible)`` arrays aligned with ``pairs``; infeasible
        pairs get ``inf`` work, matching the scalar ``exchange_eval``.
        """
        st, ph, p = self.state, self.phase, self.state.params
        na, nb = len(cand_a) - 1, len(cand_b) - 1
        G = 3 + na + nb
        assignment = st.assignment
        tasks_a = np.nonzero(assignment == r_a)[0]
        tasks_b = np.nonzero(assignment == r_b)[0]
        if agg_a is None:  # direct call: compute without touching the cache
            agg_a = self._compute_aggregates(list(cand_a[1:]))
        if agg_b is None:
            agg_b = self._compute_aggregates(list(cand_b[1:]))

        # --- group labels + group-flow matrix F --------------------------
        g = self._glab
        g[tasks_a] = 1
        g[tasks_b] = 2
        for i, c in enumerate(cand_a[1:]):
            g[c] = 3 + i
        for j, c in enumerate(cand_b[1:]):
            g[c] = 3 + na + j
        both = np.concatenate([tasks_a, tasks_b])
        eids = np.unique(self.csr.task_edges.gather(both))
        gs = g[ph.comm_src[eids]]
        gd = g[ph.comm_dst[eids]]
        F = np.bincount(gs * G + gd, weights=ph.comm_vol[eids],
                        minlength=G * G).reshape(G, G)
        # reset the shared buffer — including the candidate arrays, which a
        # direct caller may pass with tasks no longer assigned to r_a/r_b
        # (a stale label here would corrupt every later evaluation)
        g[both] = 0
        for c in cand_a[1:]:
            g[c] = 0
        for c in cand_b[1:]:
            g[c] = 0

        def col(x):         # per-a-candidate -> column vector (na+1, 1)
            return x[:, None]

        def row(x):         # per-b-candidate -> row vector (1, nb+1)
            return x[None, :]

        # group layout is contiguous (1 | 2 | a-clusters | b-clusters), so
        # every flow aggregate reduces to slice sums of F:
        # row_to_a[g] = v(g -> Ra), col_from_a[g] = v(Ra -> g), etc.
        sa, sb = 3, 3 + na
        row_to_a = F[:, 1] + F[:, sa:sb].sum(1)
        row_to_b = F[:, 2] + F[:, sb:].sum(1)
        col_from_a = F[1, :] + F[sa:sb, :].sum(0)
        col_from_b = F[2, :] + F[sb:, :].sum(0)

        def with_empty(x):
            out = np.zeros(x.shape[0] + 1)
            out[1:] = x
            return out

        ar = np.arange(sa, sb)
        br = np.arange(sb, G)
        a_intra = with_empty(F[ar, ar])
        a_out_own = with_empty(row_to_a[sa:sb])    # v(A -> Ra)
        a_in_own = with_empty(col_from_a[sa:sb])   # v(Ra -> A)
        a_out_peer = with_empty(row_to_b[sa:sb])   # v(A -> Rb)
        a_in_peer = with_empty(col_from_b[sa:sb])  # v(Rb -> A)
        a_out_o = with_empty(F[sa:sb, 0])
        a_in_o = with_empty(F[0, sa:sb])
        b_intra = with_empty(F[br, br])
        b_out_own = with_empty(row_to_b[sb:])
        b_in_own = with_empty(col_from_b[sb:])
        b_out_peer = with_empty(row_to_a[sb:])
        b_in_peer = with_empty(col_from_a[sb:])
        b_out_o = with_empty(F[sb:, 0])
        b_in_o = with_empty(F[0, sb:])

        x_ab = np.zeros((na + 1, nb + 1))    # v(A_i -> B_j)
        x_ba = np.zeros((na + 1, nb + 1))    # v(B_j -> A_i)
        if na and nb:
            x_ab[1:, 1:] = F[sa:sb, sb:]
            x_ba[1:, 1:] = F[sb:, sa:sb].T

        f_ab = row_to_b[1] + row_to_b[sa:sb].sum()   # v(Ra -> Rb)
        f_ba = row_to_a[2] + row_to_a[sb:].sum()
        f_aa = row_to_a[1] + row_to_a[sa:sb].sum()
        f_bb = row_to_b[2] + row_to_b[sb:].sum()
        f_ao = F[1, 0] + F[sa:sb, 0].sum()
        f_oa = F[0, 1] + F[0, sa:sb].sum()
        f_bo = F[2, 0] + F[sb:, 0].sum()
        f_ob = F[0, 2] + F[0, sb:].sum()

        # --- flows after the exchange, per pair (broadcast na+1 x nb+1) --
        # Endpoint classes after moving A a->b and B b->a:
        #   rank a holds Sa (=Ra\A) and B;  rank b holds Sb (=Rb\B) and A.
        sent_a = (x_ba + row(b_out_own - b_intra + b_out_o)
                  + col(a_in_own - a_intra)
                  + (f_ab - col(a_out_peer) - row(b_in_peer) + x_ab)
                  + (f_ao - col(a_out_o)))
        recv_a = (x_ab + row(b_in_own - b_intra + b_in_o)
                  + col(a_out_own - a_intra)
                  + (f_ba - row(b_out_peer) - col(a_in_peer) + x_ba)
                  + (f_oa - col(a_in_o)))
        on_a = (row(b_intra) + (row(b_out_peer) - x_ba)
                + (row(b_in_peer) - x_ab)
                + (f_aa - col(a_out_own + a_in_own - a_intra)))
        sent_b = (x_ab + col(a_out_own - a_intra + a_out_o)
                  + row(b_in_own - b_intra)
                  + (f_ba - row(b_out_peer) - col(a_in_peer) + x_ba)
                  + (f_bo - row(b_out_o)))
        recv_b = (x_ba + col(a_in_own - a_intra + a_in_o)
                  + row(b_out_own - b_intra)
                  + (f_ab - col(a_out_peer) - row(b_in_peer) + x_ab)
                  + (f_ob - row(b_in_o)))
        on_b = (col(a_intra) + (col(a_out_peer) - x_ab)
                + (col(a_in_peer) - x_ba)
                + (f_bb - row(b_out_own + b_in_own - b_intra)))

        # deltas vs the same F-derived "before" flows, applied to the
        # incrementally-maintained bases — mirrors the scalar path's
        # base-plus-dvol structure so both paths share any drift in vol.
        base_sent_a = st.vol[r_a].sum() - st.vol[r_a, r_a]
        base_recv_a = st.vol[:, r_a].sum() - st.vol[r_a, r_a]
        base_sent_b = st.vol[r_b].sum() - st.vol[r_b, r_b]
        base_recv_b = st.vol[:, r_b].sum() - st.vol[r_b, r_b]
        off_a = np.maximum(base_sent_a + (sent_a - (f_ab + f_ao)),
                           base_recv_a + (recv_a - (f_ba + f_oa)))
        off_b = np.maximum(base_sent_b + (sent_b - (f_ba + f_bo)),
                           base_recv_b + (recv_b - (f_ab + f_ob)))
        on_a = st.vol[r_a, r_a] + (on_a - f_aa)
        on_b = st.vol[r_b, r_b] + (on_b - f_bb)

        # --- per-candidate scalar aggregates (cached; same numpy reductions
        # as the scalar path -> bitwise-equal loads/mem/overhead) ----------
        la = with_empty(agg_a.loads[:na])
        lb = with_empty(agg_b.loads[:nb])
        ma = with_empty(agg_a.mems[:na])
        mb = with_empty(agg_b.mems[:nb])
        oa = with_empty(agg_a.overheads[:na])
        ob = with_empty(agg_b.overheads[:nb])
        load_a = st.load[r_a] - col(la) + row(lb)
        load_b = st.load[r_b] + col(la) - row(lb)

        # --- homing / shared-memory transitions (Thm III.1) --------------
        s_rm_a, h_rm_a, s_add_b, h_add_b = \
            self._block_terms(agg_a, na, r_a, r_b)
        s_rm_b, h_rm_b, s_add_a, h_add_a = \
            self._block_terms(agg_b, nb, r_b, r_a)
        cs_a = np.zeros((na + 1, nb + 1))
        ch_a = np.zeros((na + 1, nb + 1))
        cs_b = np.zeros((na + 1, nb + 1))
        ch_b = np.zeros((na + 1, nb + 1))
        for blk, lst_a in agg_a.blk_map.items():
            lst_b = agg_b.blk_map.get(blk)
            if not lst_b:
                continue
            # block in both moving clusters: the independent leave terms
            # over-fire when the counter-flow keeps the block present.
            size = ph.block_size[blk]
            off_home_a = ph.block_home[blk] != r_a
            off_home_b = ph.block_home[blk] != r_b
            for i, cnt_a in lst_a:
                if i >= na:
                    continue
                for j, cnt_b in lst_b:
                    if j >= nb:
                        continue
                    if st.block_count[r_a, blk] == cnt_a:
                        cs_a[i + 1, j + 1] += size
                        if off_home_a:
                            ch_a[i + 1, j + 1] += size
                    if st.block_count[r_b, blk] == cnt_b:
                        cs_b[i + 1, j + 1] += size
                        if off_home_b:
                            ch_b[i + 1, j + 1] += size
        shared_a = st.shared_cache[r_a] - col(s_rm_a) + row(s_add_a) + cs_a
        shared_b = st.shared_cache[r_b] - row(s_rm_b) + col(s_add_b) + cs_b
        hom_a = st.hom_cache[r_a] - col(h_rm_a) + row(h_add_a) + ch_a
        hom_b = st.hom_cache[r_b] - row(h_rm_b) + col(h_add_b) + ch_b

        # --- memory feasibility (eq. 9) -----------------------------------
        mem_a = (ph.rank_mem_base[r_a] + st.mem_task[r_a] - col(ma) + row(mb)
                 + shared_a + np.maximum(st.mem_overhead_max[r_a], row(ob)))
        mem_b = (ph.rank_mem_base[r_b] + st.mem_task[r_b] + col(ma) - row(mb)
                 + shared_b + np.maximum(st.mem_overhead_max[r_b], col(oa)))
        if p.memory_constraint:
            feas = ((mem_a <= ph.rank_mem_cap[r_a] + 1e-6)
                    & (mem_b <= ph.rank_mem_cap[r_b] + 1e-6))
        else:
            feas = np.ones((na + 1, nb + 1), bool)

        w_a = (p.alpha * load_a / ph.rank_speed[r_a] + p.beta * off_a
               + p.gamma * on_a + p.delta * hom_a)
        w_b = (p.alpha * load_b / ph.rank_speed[r_b] + p.beta * off_b
               + p.gamma * on_b + p.delta * hom_b)
        w_a = np.where(feas, w_a, INF)
        w_b = np.where(feas, w_b, INF)

        ia = np.fromiter((q[0] for q in pairs), np.int64, len(pairs))
        ib = np.fromiter((q[1] for q in pairs), np.int64, len(pairs))
        return w_a[ia, ib], w_b[ia, ib], feas[ia, ib]

    def _block_terms(self, agg: ClusterAggregates, n: int, r_src: int,
                     r_dst: int):
        """Independent (one-sided) block transition terms for the first
        ``n`` clusters: bytes leaving ``r_src``'s shared/homing caches and
        arriving at ``r_dst``'s (index 0 = empty candidate).  Uses the
        CURRENT block counters, so it must run per lock event even though
        the (block, count) pairs themselves are cached."""
        st = self.state
        hi = np.searchsorted(agg.blk_ci, n)  # blk_ci ascending -> prefix
        ci = agg.blk_ci[:hi] + 1
        ids = agg.blk_ids[:hi]
        sizes = agg.blk_sizes[:hi]
        leaves = st.block_count[r_src, ids] == agg.blk_cnts[:hi]
        arrives = st.block_count[r_dst, ids] == 0
        s_rm = np.bincount(ci, weights=sizes * leaves, minlength=n + 1)
        h_rm = np.bincount(
            ci, weights=sizes * (leaves & (agg.blk_home[:hi] != r_src)),
            minlength=n + 1)
        s_add = np.bincount(ci, weights=sizes * arrives, minlength=n + 1)
        h_add = np.bincount(
            ci, weights=sizes * (arrives & (agg.blk_home[:hi] != r_dst)),
            minlength=n + 1)
        return s_rm, h_rm, s_add, h_add


# ---------------------------------------------------------------- stage 1
@dataclasses.dataclass
class SummaryTables:
    """SoA mirror of one iteration's Rank/ClusterSummary objects.

    Per-rank arrays are indexed by rank id; per-cluster arrays are flat with
    ``c_indptr`` rank segments (same order as ``RankSummary.clusters``).
    """

    load: np.ndarray
    vol_on: np.ndarray
    vol_off: np.ndarray
    homing: np.ndarray
    mem_used: np.ndarray
    mem_cap: np.ndarray
    speed: np.ndarray
    work: np.ndarray          # _w_of(summary) per rank
    c_ids: CSR                # rank -> flat cluster ids (indptr is (I+1,))
    c_load: np.ndarray
    c_mem: np.ndarray
    c_block_bytes: np.ndarray
    c_vol_intra: np.ndarray
    c_vol_ext: np.ndarray


def build_summary_tables(summaries: Dict, params) -> SummaryTables:
    n = len(summaries)
    ranks = [summaries[r] for r in range(n)]
    load = np.array([s.load for s in ranks])
    vol_on = np.array([s.vol_on for s in ranks])
    vol_off = np.array([s.vol_off for s in ranks])
    homing = np.array([s.homing for s in ranks])
    speed = np.array([s.speed for s in ranks])
    work = (params.alpha * load / speed + params.beta * vol_off
            + params.gamma * vol_on + params.delta * homing)
    c_indptr = np.zeros(n + 1, np.int64)
    np.cumsum([len(s.clusters) for s in ranks], out=c_indptr[1:])
    flat = [c for s in ranks for c in s.clusters]
    c_ids = CSR(c_indptr, np.arange(len(flat), dtype=np.int64))
    return SummaryTables(
        load=load, vol_on=vol_on, vol_off=vol_off, homing=homing,
        mem_used=np.array([s.mem_used for s in ranks]),
        mem_cap=np.array([s.mem_cap for s in ranks]),
        speed=speed, work=work, c_ids=c_ids,
        c_load=np.array([c.load for c in flat]),
        c_mem=np.array([c.mem for c in flat]),
        c_block_bytes=np.array([c.block_bytes for c in flat]),
        c_vol_intra=np.array([c.vol_intra for c in flat]),
        c_vol_ext=np.array([c.vol_ext for c in flat]),
    )


def _seg_gather(t: SummaryTables, ranks: np.ndarray):
    """(owner index, flat cluster ids) for all clusters of ``ranks``."""
    idx = t.c_ids.gather(ranks)
    counts = t.c_ids.indptr[ranks + 1] - t.c_ids.indptr[ranks]
    owner = np.repeat(np.arange(ranks.shape[0]), counts)
    return owner, idx


def batch_peer_diffs(t: SummaryTables, r: int, peers: np.ndarray,
                     params) -> np.ndarray:
    """Stage-1 peer scores for rank ``r`` against ``peers`` in one pass.

    Arithmetic-identical to ``approx_best_diff(summaries[r], summaries[p])``
    per peer: same expressions, same IEEE evaluation order, with the scalar
    max-over-candidates rewritten as ``max_before - min(after)`` (exactly
    equal for finite IEEE values since x -> M - x is antitone).

    ASSUMPTION: the tables hold THIS iteration's summaries and gossip
    payloads are references to those same objects (``info[r][p] is
    summaries[p]``, true of ``build_peer_networks`` today) — staleness is
    only in WHICH peers a rank knows, never in the values.  If gossip ever
    carries summaries from older iterations, the scalar path would score
    from what rank ``r`` actually received while this path scores from the
    global tables, and the identical-trajectory contract breaks; the tables
    would then need to be built per recipient from ``info[r]``.
    """
    peers = np.asarray(peers, np.int64)
    n_p = peers.shape[0]
    if n_p == 0:
        return np.zeros(0)
    a, b, g, d = params.alpha, params.beta, params.gamma, params.delta
    max_before = np.maximum(t.work[r], t.work[peers])

    # my clusters -> each peer (give direction)
    sl = slice(t.c_ids.indptr[r], t.c_ids.indptr[r + 1])
    cl, cm = t.c_load[sl], t.c_mem[sl]
    cbb, cvi, cve = t.c_block_bytes[sl], t.c_vol_intra[sl], t.c_vol_ext[sl]
    after_give = np.full(n_p, np.inf)
    if cl.shape[0]:
        feas = ~((t.mem_used[peers][None, :] + cm[:, None] + cbb[:, None])
                 > t.mem_cap[peers][None, :])
        w_me = (a * (t.load[r] - cl) / t.speed[r]
                + b * np.maximum(t.vol_off[r] - cve, 0.0)
                + g * np.maximum(t.vol_on[r] - cvi, 0.0)
                + d * t.homing[r])
        w_peer = (a * (t.load[peers][None, :] + cl[:, None])
                  / t.speed[peers][None, :]
                  + b * (t.vol_off[peers][None, :] + cve[:, None])
                  + g * (t.vol_on[peers][None, :] + cvi[:, None])
                  + d * (t.homing[peers][None, :] + cbb[:, None]))
        after = np.where(feas, np.maximum(w_me[:, None], w_peer), np.inf)
        after_give = after.min(axis=0)

    # each peer's clusters -> me (pull direction)
    owner, idx = _seg_gather(t, peers)
    after_pull = np.full(n_p, np.inf)
    if idx.shape[0]:
        own = peers[owner]
        pl, pm = t.c_load[idx], t.c_mem[idx]
        pbb, pvi, pve = (t.c_block_bytes[idx], t.c_vol_intra[idx],
                         t.c_vol_ext[idx])
        feas = ~((t.mem_used[r] + pm + pbb) > t.mem_cap[r])
        w_src = (a * (t.load[own] - pl) / t.speed[own]
                 + b * np.maximum(t.vol_off[own] - pve, 0.0)
                 + g * np.maximum(t.vol_on[own] - pvi, 0.0)
                 + d * t.homing[own])
        w_me = (a * (t.load[r] + pl) / t.speed[r]
                + b * (t.vol_off[r] + pve)
                + g * (t.vol_on[r] + pvi)
                + d * (t.homing[r] + pbb))
        after = np.where(feas, np.maximum(w_src, w_me), np.inf)
        np.minimum.at(after_pull, owner, after)

    return max_before - np.minimum(after_give, after_pull)
