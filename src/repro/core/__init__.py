"""The paper's primary contribution: the CCM work model and the CCM-LB
distributed load balancer, plus the MILP certification path (core/milp) and
the vectorized evaluation engine (core/csr + core/engine)."""
from repro.core.async_sim import (FaultSpec, FaultStats,  # noqa: F401
                                  LivelockError, RankJoin, ccm_lb_async,
                                  make_latency, run_ccm_lb)
from repro.core.ccm import CCMState, ExchangeEval, exchange_eval  # noqa: F401
from repro.core.ccmlb import CCMLBResult, ProtocolStats, ccm_lb  # noqa: F401
from repro.core.csr import CSR, PhaseCSR, rank_segments  # noqa: F401
from repro.core.engine import (PhaseEngine, SummaryTables,  # noqa: F401
                               batch_peer_diffs, build_summary_tables)
from repro.core.fleet import ccm_lb_many  # noqa: F401
from repro.core.pipeline import (PipelinePhase, PipelineResult,  # noqa: F401
                                 ccm_lb_pipeline, same_topology,
                                 warm_start_assignment)
from repro.core.problem import (CCMParams, Phase, initial_assignment,  # noqa: F401
                                random_phase)
from repro.core.spec import (SpecInstance, event_sequence,  # noqa: F401
                             run_spec)
