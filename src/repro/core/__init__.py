"""The paper's primary contribution: the CCM work model and the CCM-LB
distributed load balancer, plus the MILP certification path (core/milp)."""
from repro.core.ccm import CCMState, ExchangeEval, exchange_eval  # noqa: F401
from repro.core.ccmlb import CCMLBResult, ccm_lb  # noqa: F401
from repro.core.problem import (CCMParams, Phase, initial_assignment,  # noqa: F401
                                random_phase)
