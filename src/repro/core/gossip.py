"""Augmented inform stage (paper §IV-A, Fig. 1 BuildPeerNetwork).

Epidemic propagation: over ``k_rounds`` asynchronous rounds each rank sends
its accumulated ``info_known`` to ``fanout`` randomly selected peers; a
recipient merges the payload and, if the message's round is below k_rounds,
forwards to ``fanout`` peers the message has not visited.

This is a deterministic discrete-event simulation of R ranks: messages sent
in round k are delivered at round k+1; randomness is seeded per
(iteration, rank, message) so runs are reproducible.  Payload entries are
``RankSummary`` objects (rank info + cluster summaries) — the augmentation
over load-only gossip [22] that CCM requires.

Delivery dedupe: the message count grows roughly ``fanout**k_rounds`` and
most late-round deliveries carry only already-known summaries.  A delivery
whose payload keys are a subset of the destination's ``info_known`` is
dropped (no merge, no forward) — it cannot change the destination's
knowledge, and any forward it would have generated carries exactly the
destination's current knowledge, which the destination's OWN earlier
forwards already propagate.  Forward payload snapshots are also shared
across the fanout peers of one delivery (payloads are read-only once
enqueued) instead of copied per peer.  This changes which peers end up
known vs the seed's flood (fewer redundant paths), but stays a valid,
deterministic epidemic under the same seed.
"""
from __future__ import annotations

from typing import Dict, List, Set

import numpy as np

from repro.core.clusters import RankSummary


def gossip_seed(seed: int, it: int) -> list:
    """Collision-free per-iteration gossip stream key.

    ``default_rng`` accepts a sequence seed, which SeedSequence mixes
    entropy-pool style — distinct ``(seed, it)`` pairs give distinct
    streams, unlike the old ``seed * 1000 + it`` arithmetic where e.g.
    ``(seed=1, it=1000)`` and ``(seed=2, it=0)`` collided.  Every driver
    (sync ``ccm_lb``, async ``ccm_lb_async``, vmapped ``ccm_lb_many``)
    derives its per-iteration gossip stream through this one helper so
    the cross-driver bitwise parity bars stay aligned.
    """
    return [int(seed), int(it)]


def gossip_deliver(known: Dict[int, RankSummary],
                   payload: Dict[int, RankSummary]) -> bool:
    """Deliver one gossip payload into a rank's ``info_known`` map.

    Returns False when the payload carries nothing new (the dedupe rule:
    no merge, and the caller must not forward — see the module docstring);
    True after merging at least one new summary.  Shared by the
    synchronous round-driven :func:`build_peer_networks` and the async
    event-loop driver (repro/core/async_sim.py), so both epidemics apply
    the exact same merge/dedupe semantics.
    """
    if payload.keys() <= known.keys():
        return False
    for k, v in payload.items():
        known.setdefault(k, v)
    return True


def build_peer_networks(summaries: Dict[int, RankSummary], *, k_rounds: int,
                        fanout: int, seed: int,
                        ) -> Dict[int, Dict[int, RankSummary]]:
    """Returns per-rank ``info_known``: rank -> {peer -> RankSummary}."""
    ranks = sorted(summaries)
    n = len(ranks)
    rng = np.random.default_rng(seed)
    info_known: Dict[int, Dict[int, RankSummary]] = {
        r: {r: summaries[r]} for r in ranks}

    # message = (round, visited set, payload snapshot keys)
    # round k messages, delivered synchronously at round boundary (async in
    # the real runtime; the simulation just needs *an* admissible ordering —
    # repro/core/async_sim.py delivers the SAME messages through a latency-
    # aware event queue and degenerates to this order at zero latency).
    msgs: List[tuple] = []
    for r in ranks:
        peers = pick_peers(rng, n, r, fanout, visited={r})
        snap = dict(info_known[r])      # shared: payloads are read-only
        for p in peers:
            msgs.append((1, p, frozenset([r]) | {p}, snap))

    for _ in range(k_rounds):
        nxt: List[tuple] = []
        for rnd, dst, visited, payload in msgs:
            if not gossip_deliver(info_known[dst], payload):
                continue    # dedupe: nothing new — skip merge AND forward
            if rnd < k_rounds:
                peers = pick_peers(rng, n, dst, fanout, visited=set(visited))
                snap = dict(info_known[dst])
                for p in peers:
                    nxt.append((rnd + 1, p, frozenset(visited) | {p}, snap))
        msgs = nxt
    return info_known


def pick_peers(rng, n: int, me: int, fanout: int, visited: Set[int]):
    """``fanout`` forward targets excluding ``visited`` — the epidemic's
    only source of randomness; consumption order must match between the
    two drivers for the zero-latency parity bar (it does: both pick at
    delivery time, and zero latency reproduces the round order)."""
    candidates = [r for r in range(n) if r != me and r not in visited]
    if not candidates:
        return []
    k = min(fanout, len(candidates))
    return list(rng.choice(candidates, size=k, replace=False))
