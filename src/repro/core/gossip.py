"""Augmented inform stage (paper §IV-A, Fig. 1 BuildPeerNetwork).

Epidemic propagation: each rank ROOTS one epidemic that floods its own
``RankSummary`` (rank info + cluster summaries — the augmentation over
load-only gossip [22] that CCM requires) over ``k_rounds`` rounds of
``fanout`` randomly selected peers.  A recipient that learns the root's
summary forwards the message; one that already knows it drops it (dedupe:
the delivery cannot change the destination's knowledge).

**Per-root streams.**  Every root draws its forward targets from its OWN
``default_rng`` stream, keyed ``[seed, iteration, root]`` via
:func:`gossip_root_key` (SeedSequence mixes the tuple, so distinct keys
give distinct, collision-free streams).  Because roots never share a
stream, one root's epidemic is completely independent of every other's —
this is what makes the amortized ("quiescence") path possible: a rank
whose summary did not change since iteration ``e`` keeps the key
``[seed, e, root]``, so its epidemic is *bitwise the same draw* whether it
is re-run from scratch (the rebuild reference) or replayed from a cached
reach set (:func:`update_peer_networks`).  Only roots whose summary
actually changed advance their iteration stamp and re-draw.

The payload of a root's epidemic is exactly ``{root: summaries[root]}``
and is never copied or merged with other roots' knowledge: a rank's
``info_known`` map is the set-union of the roots whose floods reached it
(plus itself).  The union is order-independent, so the incremental and
full paths produce identical maps even though they assemble them in
different orders; downstream work-list scoring canonicalizes by sorting
on ``(-diff, peer)``.

This is a deterministic discrete-event simulation of R ranks: messages
sent in round k are delivered at round k+1.  repro/core/async_sim.py
delivers the SAME messages through a latency-aware event queue and
degenerates to this per-root order at zero latency.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Set, Tuple

import numpy as np

from repro.core.clusters import RankSummary

GossipKey = Tuple[int, ...]


def gossip_seed(seed: int, it: int) -> list:
    """Collision-free per-iteration gossip stream key.

    ``default_rng`` accepts a sequence seed, which SeedSequence mixes
    entropy-pool style — distinct ``(seed, it)`` pairs give distinct
    streams, unlike the old ``seed * 1000 + it`` arithmetic where e.g.
    ``(seed=1, it=1000)`` and ``(seed=2, it=0)`` collided.  Every driver
    (sync ``ccm_lb``, async ``ccm_lb_async``, vmapped ``ccm_lb_many``)
    derives its per-iteration gossip stream through this one helper so
    the cross-driver bitwise parity bars stay aligned.
    """
    return [int(seed), int(it)]


def gossip_root_key(seed, root: int) -> list:
    """Per-root epidemic stream key: ``seed`` (an int, or the
    ``gossip_seed(seed, it)`` pair) extended with the root rank."""
    base = list(seed) if isinstance(seed, (list, tuple)) else [int(seed)]
    return base + [int(root)]


def summary_checksum(s: RankSummary) -> int:
    """Deterministic integer checksum over a summary's numeric content.

    Covers every field the work-list scorer reads (rank scalars plus the
    per-cluster summary scalars), so any in-flight mutation the fault
    harness can make (repro/core/async_sim.py, ``FaultSpec.corrupt``)
    changes the value.  Built on ``hash()`` of int/float tuples, which is
    deterministic across processes (only str/bytes hashing is seeded) —
    the receiver recomputes it over the delivered payload and quarantines
    on mismatch.
    """
    clusters = tuple(
        (c.rank, c.local_id, c.load, c.mem, c.overhead, c.block_bytes,
         c.vol_intra, c.vol_ext, c.size) for c in s.clusters)
    return hash((s.rank, s.load, s.vol_on, s.vol_off, s.homing,
                 s.mem_used, s.mem_cap, s.speed, clusters))


def gossip_deliver(known: Dict[int, RankSummary],
                   payload: Dict[int, RankSummary],
                   stats: Optional[dict] = None) -> bool:
    """Deliver one gossip payload into a rank's ``info_known`` map.

    Returns False when the payload carries nothing new (the dedupe rule:
    no merge, and the caller must not forward); True after merging at
    least one new summary.  No-op merges never allocate — the payload
    object is shared, read-only, and simply dropped — and are counted in
    ``stats['gossip_noop_merges']`` when a stats dict is supplied.
    Shared by the synchronous :func:`root_epidemic` flood and the async
    event-loop driver (repro/core/async_sim.py), so both epidemics apply
    the exact same merge/dedupe semantics.
    """
    if payload.keys() <= known.keys():
        if stats is not None:
            stats["gossip_noop_merges"] = stats.get("gossip_noop_merges", 0) + 1
        return False
    for k, v in payload.items():
        known.setdefault(k, v)
    return True


def root_epidemic(n: int, root: int, *, k_rounds: int, fanout: int,
                  key, exclude: Set[int] = frozenset(),
                  stats: Optional[dict] = None) -> List[int]:
    """Flood one root's summary; returns the reached ranks in delivery
    order (root excluded).

    Deterministic in ``(n, root, k_rounds, fanout, key, exclude)`` alone —
    the root's rng stream is private, so re-running with the same key
    reproduces the same reach bitwise no matter what other roots do.
    ``exclude`` removes ranks (e.g. dead ones under the async fault
    harness) from the candidate peer sets.
    """
    rng = np.random.default_rng(key)
    reached = {root}
    order: List[int] = []
    base_visited = {root} | set(exclude)
    msgs: List[tuple] = [
        (1, p, frozenset([root, p]))
        for p in pick_peers(rng, n, root, fanout, visited=base_visited)]
    while msgs:
        nxt: List[tuple] = []
        for rnd, dst, visited in msgs:
            if dst in reached:      # dedupe: no merge, no forward
                if stats is not None:
                    stats["gossip_noop_merges"] = \
                        stats.get("gossip_noop_merges", 0) + 1
                continue
            reached.add(dst)
            order.append(dst)
            if rnd < k_rounds:
                for p in pick_peers(rng, n, dst, fanout,
                                    visited=set(visited) | set(exclude)):
                    nxt.append((rnd + 1, p, frozenset(visited) | {p}))
        msgs = nxt
    return order


def build_peer_networks(summaries: Dict[int, RankSummary], *, k_rounds: int,
                        fanout: int, seed=0,
                        root_seeds: Optional[Dict[int, list]] = None,
                        reach_out: Optional[Dict[int, List[int]]] = None,
                        stats: Optional[dict] = None,
                        ) -> Dict[int, Dict[int, RankSummary]]:
    """Returns per-rank ``info_known``: rank -> {peer -> RankSummary}.

    The full (rebuild) path: every root's epidemic is re-run.  ``seed``
    may be an int or a ``gossip_seed(seed, it)`` pair; ``root_seeds``
    overrides the per-root key outright (the drivers pass
    ``gossip_root_key(gossip_seed(seed, epoch[root]), root)`` so a quiet
    root replays the iteration it last changed in).  ``reach_out``, when
    given, receives each root's delivery-order reach list — the cacheable
    artifact :func:`update_peer_networks` patches incrementally.
    """
    ranks = sorted(summaries)
    n = len(ranks)
    info_known: Dict[int, Dict[int, RankSummary]] = {
        r: {r: summaries[r]} for r in ranks}
    for root in ranks:
        key = (root_seeds[root] if root_seeds is not None
               else gossip_root_key(seed, root))
        order = root_epidemic(n, root, k_rounds=k_rounds, fanout=fanout,
                              key=key, stats=stats)
        if reach_out is not None:
            reach_out[root] = order
        payload = summaries[root]
        for dst in order:
            info_known[dst][root] = payload
    return info_known


def update_peer_networks(summaries: Dict[int, RankSummary],
                         info_known: Dict[int, Dict[int, RankSummary]],
                         reach: Dict[int, List[int]], *,
                         k_rounds: int, fanout: int,
                         root_seeds: Dict[int, list],
                         dirty_roots: Sequence[int],
                         stats: Optional[dict] = None) -> Set[int]:
    """Patch a peer network in place: re-run ONLY the epidemics rooted at
    ``dirty_roots`` (roots whose summary — and hence key — changed),
    splicing their old reach out of and new reach into the per-rank maps.

    Returns the set of ranks whose ``info_known`` content changed (union
    of old and new reach of every dirty root, plus the dirty roots
    themselves) — exactly the ranks whose work lists need re-scoring.
    Bitwise-equal to a full :func:`build_peer_networks` under the same
    ``root_seeds`` because clean roots' epidemics are pure functions of
    their unchanged keys.
    """
    n = len(summaries)
    affected: Set[int] = set()
    for root in sorted(dirty_roots):
        root = int(root)
        affected.add(root)
        old = reach.get(root, [])
        for dst in old:
            info_known[dst].pop(root, None)
            affected.add(dst)
        order = root_epidemic(n, root, k_rounds=k_rounds, fanout=fanout,
                              key=root_seeds[root], stats=stats)
        reach[root] = order
        payload = summaries[root]
        info_known[root][root] = payload    # re-bind the fresh summary
        for dst in order:
            info_known[dst][root] = payload
            affected.add(dst)
        if stats is not None:
            stats["gossip_redraws"] = stats.get("gossip_redraws", 0) + 1
    return affected


def pick_peers(rng, n: int, me: int, fanout: int, visited: Set[int]):
    """``fanout`` forward targets excluding ``visited`` — the epidemic's
    only source of randomness; consumption order must match between the
    two drivers for the zero-latency parity bar (it does: both pick at
    delivery time from the root's private stream, and zero latency
    reproduces each root's round order)."""
    candidates = [r for r in range(n) if r != me and r not in visited]
    if not candidates:
        return []
    k = min(fanout, len(candidates))
    return list(rng.choice(candidates, size=k, replace=False))
