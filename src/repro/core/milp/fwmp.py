"""Full Work Model Problem (FWMP) builder — paper §V-C.

Decision vector x = vec(chi (IxK), phi (IxN), psi (IxIxM), W_max), with:
  (14) task assignment consistency (eq),
  (17)/(18) integer shared-block relations (Thm V.2),
  (19) per-rank memory capacity,
  (25)-(27) integer communication-tensor relations (Thm V.4),
  (30) makespan work rows (both send/recv permutations of the beta term),
  [0,1] bounds on all binary variables.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional

import numpy as np

from repro.core.ccm import effective_mem_cap
from repro.core.problem import CCMParams, Phase


@dataclasses.dataclass
class MILP:
    c: np.ndarray
    A_eq: np.ndarray
    b_eq: np.ndarray
    A_ub: np.ndarray
    b_ub: np.ndarray
    integer_vars: np.ndarray          # indices to branch on (the chi block)
    n_vars: int
    meta: dict

    def chi(self, i: int, k: int) -> int:
        return i * self.meta["K"] + k

    def decode_assignment(self, x: np.ndarray) -> np.ndarray:
        i_n, k_n = self.meta["I"], self.meta["K"]
        chi = x[: i_n * k_n].reshape(i_n, k_n)
        return np.argmax(chi, axis=0).astype(np.int64)


def build_fwmp(phase: Phase, params: CCMParams) -> MILP:
    I, K = phase.num_ranks, phase.num_tasks
    N, M = phase.num_blocks, phase.num_comms
    n_chi, n_phi, n_psi = I * K, I * N, I * I * M
    n = n_chi + n_phi + n_psi + 1
    W = n - 1

    def chi(i, k):
        return i * K + k

    def phi(i, b):
        return n_chi + i * N + b

    def psi(i, j, m):
        return n_chi + n_phi + (i * I + j) * M + m

    c = np.zeros(n)
    c[W] = 1.0

    # (14) equality: sum_i chi_ik = 1
    A_eq = np.zeros((K, n))
    for k in range(K):
        for i in range(I):
            A_eq[k, chi(i, k)] = 1.0
    b_eq = np.ones(K)

    rows: List[np.ndarray] = []
    rhs: List[float] = []

    def add(row, b):
        rows.append(row)
        rhs.append(b)

    # (17): chi_ik - phi_i,b(k) <= 0 for tasks with a block
    for k in range(K):
        bk = phase.task_block[k]
        if bk < 0:
            continue
        for i in range(I):
            row = np.zeros(n)
            row[chi(i, k)] = 1.0
            row[phi(i, bk)] = -1.0
            add(row, 0.0)

    # (18): phi_ib - sum_{k in block b} chi_ik <= 0
    for b in range(N):
        members = np.nonzero(phase.task_block == b)[0]
        for i in range(I):
            row = np.zeros(n)
            row[phi(i, b)] = 1.0
            for k in members:
                row[chi(i, k)] = -1.0
            add(row, 0.0)

    # (19) memory, per (i, k).  The RHS goes through the same
    # effective_mem_cap soft cap the heuristic feasibility layer tests
    # against (relative tolerance + optional pressure headroom), so
    # MILP-feasible chi always decode to CCMState.memory_feasible
    # assignments and the two sides agree on eq. 7 to the bit.
    if params.memory_constraint:
        for i in range(I):
            cap = (effective_mem_cap(phase.rank_mem_cap[i], params)
                   - phase.rank_mem_base[i])
            for k in range(K):
                row = np.zeros(n)
                for l in range(K):
                    row[chi(i, l)] += phase.task_mem[l]
                row[chi(i, k)] += phase.task_overhead[k]
                for b in range(N):
                    row[phi(i, b)] += phase.block_size[b]
                add(row, cap)

    # (25)-(27) communication tensor relations
    for m in range(M):
        km, lm = int(phase.comm_src[m]), int(phase.comm_dst[m])
        for i in range(I):
            for j in range(I):
                r1 = np.zeros(n)   # psi <= chi_i,km
                r1[psi(i, j, m)] = 1.0
                r1[chi(i, km)] = -1.0
                add(r1, 0.0)
                r2 = np.zeros(n)   # psi <= chi_j,lm
                r2[psi(i, j, m)] = 1.0
                r2[chi(j, lm)] = -1.0
                add(r2, 0.0)
                r3 = np.zeros(n)   # chi_i,km + chi_j,lm - psi <= 1
                r3[chi(i, km)] += 1.0
                r3[chi(j, lm)] += 1.0
                r3[psi(i, j, m)] = -1.0
                add(r3, 1.0)

    # (30) work rows (two permutations of the off-rank term)
    for i in range(I):
        for direction in ("send", "recv"):
            row = np.zeros(n)
            for k in range(K):
                row[chi(i, k)] += params.alpha * phase.task_load[k]
            for m in range(M):
                v = phase.comm_vol[m]
                for j in range(I):
                    if j == i:
                        continue
                    if direction == "send":
                        row[psi(i, j, m)] += params.beta * v
                    else:
                        row[psi(j, i, m)] += params.beta * v
                row[psi(i, i, m)] += params.gamma * v
            for b in range(N):
                if phase.block_home[b] != i:
                    row[phi(i, b)] += params.delta * phase.block_size[b]
            row[W] = -1.0
            add(row, 0.0)

    # [0,1] bounds on the binaries
    for v in range(n - 1):
        row = np.zeros(n)
        row[v] = 1.0
        add(row, 1.0)

    return MILP(
        c=c, A_eq=A_eq, b_eq=b_eq,
        A_ub=np.array(rows), b_ub=np.array(rhs),
        integer_vars=np.arange(n_chi),
        n_vars=n,
        meta={"I": I, "K": K, "N": N, "M": M, "kind": "fwmp"},
    )
