from repro.core.milp.bnb import MILPResult, solve_milp  # noqa: F401
from repro.core.milp.comcp import build_comcp  # noqa: F401
from repro.core.milp.fwmp import build_fwmp  # noqa: F401
from repro.core.milp.fwmp_reduced import build_fwmp_reduced  # noqa: F401
from repro.core.milp.lp import LPResult, simplex_solve  # noqa: F401
