"""Reduced FWMP (beyond-paper formulation improvement).

The paper's FWMP (§V-C) carries the full IxIxM communication tensor psi.
For the CCM objective only three aggregates per rank matter, and the task
consistency rows (14) give  sum_j chi_{j,l} = 1,  so with

    y_{i,m} := chi_{i,k_m} * chi_{i,l_m}        (both endpoints on rank i)

we get exactly:
    sent_off(i) = sum_m V_m (chi_{i,k_m} - y_{i,m})
    recv_off(i) = sum_m V_m (chi_{i,l_m} - y_{i,m})
    on_rank(i)  = sum_m V_m y_{i,m}

with the usual product linearization (y <= chi_a, y <= chi_b,
y >= chi_a + chi_b - 1, y >= 0).  Both bounds of y are active in the
directions the objective pushes (beta wants y large -> upper bounds bind;
gamma wants y small -> lower bound binds), so the optimum equals the paper's
formulation — verified against it in tests — with I*M variables instead of
I^2*M and 3*I*M rows instead of 3*I^2*M.
"""
from __future__ import annotations

from typing import List

import numpy as np

from repro.core.ccm import effective_mem_cap
from repro.core.milp.fwmp import MILP
from repro.core.problem import CCMParams, Phase


def build_fwmp_reduced(phase: Phase, params: CCMParams) -> MILP:
    I, K = phase.num_ranks, phase.num_tasks
    N, M = phase.num_blocks, phase.num_comms
    n_chi, n_phi, n_y = I * K, I * N, I * M
    n = n_chi + n_phi + n_y + 1
    W = n - 1

    def chi(i, k):
        return i * K + k

    def phi(i, b):
        return n_chi + i * N + b

    def y(i, m):
        return n_chi + n_phi + i * M + m

    c = np.zeros(n)
    c[W] = 1.0

    A_eq = np.zeros((K, n))
    for k in range(K):
        for i in range(I):
            A_eq[k, chi(i, k)] = 1.0
    b_eq = np.ones(K)

    rows: List[np.ndarray] = []
    rhs: List[float] = []

    def add(row, b):
        rows.append(row)
        rhs.append(b)

    for k in range(K):               # (17)
        bk = phase.task_block[k]
        if bk < 0:
            continue
        for i in range(I):
            row = np.zeros(n)
            row[chi(i, k)] = 1.0
            row[phi(i, bk)] = -1.0
            add(row, 0.0)

    for b in range(N):               # (18)
        members = np.nonzero(phase.task_block == b)[0]
        for i in range(I):
            row = np.zeros(n)
            row[phi(i, b)] = 1.0
            for k in members:
                row[chi(i, k)] = -1.0
            add(row, 0.0)

    if params.memory_constraint:     # (19), RHS on the heuristic's
        for i in range(I):           # effective_mem_cap soft cap
            cap = (effective_mem_cap(phase.rank_mem_cap[i], params)
                   - phase.rank_mem_base[i])
            for k in range(K):
                row = np.zeros(n)
                for l in range(K):
                    row[chi(i, l)] += phase.task_mem[l]
                row[chi(i, k)] += phase.task_overhead[k]
                for b in range(N):
                    row[phi(i, b)] += phase.block_size[b]
                add(row, cap)

    # y linearization
    for m in range(M):
        km, lm = int(phase.comm_src[m]), int(phase.comm_dst[m])
        for i in range(I):
            r1 = np.zeros(n)
            r1[y(i, m)] = 1.0
            r1[chi(i, km)] = -1.0
            add(r1, 0.0)
            r2 = np.zeros(n)
            r2[y(i, m)] = 1.0
            r2[chi(i, lm)] = -1.0
            add(r2, 0.0)
            r3 = np.zeros(n)
            r3[chi(i, km)] += 1.0
            r3[chi(i, lm)] += 1.0
            r3[y(i, m)] = -1.0
            add(r3, 1.0)

    # work rows: send / recv variants
    for i in range(I):
        for direction in ("send", "recv"):
            row = np.zeros(n)
            for k in range(K):
                row[chi(i, k)] += params.alpha * phase.task_load[k]
            for m in range(M):
                v = phase.comm_vol[m]
                km, lm = int(phase.comm_src[m]), int(phase.comm_dst[m])
                endpoint = km if direction == "send" else lm
                row[chi(i, endpoint)] += params.beta * v
                row[y(i, m)] += (params.gamma - params.beta) * v
            for b in range(N):
                if phase.block_home[b] != i:
                    row[phi(i, b)] += params.delta * phase.block_size[b]
            row[W] = -1.0
            add(row, 0.0)

    for v_i in range(n - 1):         # [0,1] bounds
        row = np.zeros(n)
        row[v_i] = 1.0
        add(row, 1.0)

    return MILP(
        c=c, A_eq=A_eq, b_eq=b_eq,
        A_ub=np.array(rows), b_ub=np.array(rhs),
        integer_vars=np.arange(n_chi),
        n_vars=n,
        meta={"I": I, "K": K, "N": N, "M": M, "kind": "fwmp_reduced"},
    )
