"""Compute-Only Memory-Constrained Problem (COMCP) builder — paper §V-B.

alpha=1, beta=gamma=delta=0 in (13): variables chi, phi, W_max with
constraints (14), (17), (18), (19) and makespan rows (20).
"""
from __future__ import annotations

from typing import List

import numpy as np

from repro.core.ccm import effective_mem_cap
from repro.core.milp.fwmp import MILP
from repro.core.problem import CCMParams, Phase


def build_comcp(phase: Phase, params: CCMParams = None) -> MILP:
    params = params or CCMParams()
    I, K, N = phase.num_ranks, phase.num_tasks, phase.num_blocks
    n_chi, n_phi = I * K, I * N
    n = n_chi + n_phi + 1
    W = n - 1

    def chi(i, k):
        return i * K + k

    def phi(i, b):
        return n_chi + i * N + b

    c = np.zeros(n)
    c[W] = 1.0

    A_eq = np.zeros((K, n))
    for k in range(K):
        for i in range(I):
            A_eq[k, chi(i, k)] = 1.0
    b_eq = np.ones(K)

    rows: List[np.ndarray] = []
    rhs: List[float] = []

    def add(row, b):
        rows.append(row)
        rhs.append(b)

    for k in range(K):               # (17)
        bk = phase.task_block[k]
        if bk < 0:
            continue
        for i in range(I):
            row = np.zeros(n)
            row[chi(i, k)] = 1.0
            row[phi(i, bk)] = -1.0
            add(row, 0.0)

    for b in range(N):               # (18)
        members = np.nonzero(phase.task_block == b)[0]
        for i in range(I):
            row = np.zeros(n)
            row[phi(i, b)] = 1.0
            for k in members:
                row[chi(i, k)] = -1.0
            add(row, 0.0)

    if params.memory_constraint:     # (19), RHS on the heuristic's
        for i in range(I):           # effective_mem_cap soft cap
            cap = (effective_mem_cap(phase.rank_mem_cap[i], params)
                   - phase.rank_mem_base[i])
            for k in range(K):
                row = np.zeros(n)
                for l in range(K):
                    row[chi(i, l)] += phase.task_mem[l]
                row[chi(i, k)] += phase.task_overhead[k]
                for b in range(N):
                    row[phi(i, b)] += phase.block_size[b]
                add(row, cap)

    for i in range(I):               # (20)
        row = np.zeros(n)
        for k in range(K):
            row[chi(i, k)] = phase.task_load[k]
        row[W] = -1.0
        add(row, 0.0)

    for v in range(n - 1):           # [0,1]
        row = np.zeros(n)
        row[v] = 1.0
        add(row, 1.0)

    return MILP(
        c=c, A_eq=A_eq, b_eq=b_eq,
        A_ub=np.array(rows), b_ub=np.array(rhs),
        integer_vars=np.arange(n_chi),
        n_vars=n,
        meta={"I": I, "K": K, "N": N, "M": 0, "kind": "comcp"},
    )
