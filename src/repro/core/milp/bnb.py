"""Branch & bound over the chi (task-assignment) binaries.

Structure exploited (see paper §V remarks):
  * SOS1 branching on TASKS: the consistency rows (14) make each task's chi
    row a one-hot — a node branches a fractional task into one child per
    candidate rank, fixing chi_ik=1 and chi_jk=0 for j != i.  Much stronger
    than 0/1 branching on single entries.
  * fixed variables are ELIMINATED by substitution (columns removed, RHS
    adjusted, empty rows dropped), so node LPs shrink as the tree deepens;
  * with chi integral, minimization + Thm V.2/V.4 force phi/psi to their
    Boolean values wherever they carry cost, so an all-integral-chi LP
    optimum is a valid MILP solution;
  * a heuristic incumbent (e.g. CCM-LB's W_max) can seed pruning.

The root LP relaxation is the continuous lower bound used for the paper's
"gap" = (W_int - W_lp) / W_lp (§VII-A).
"""
from __future__ import annotations

import dataclasses
import heapq
import time
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.milp.fwmp import MILP
from repro.core.milp.lp import LPResult, simplex_solve

_INT_TOL = 1e-5


@dataclasses.dataclass
class MILPResult:
    status: str        # "optimal" | "node_limit" | "infeasible"
    x: Optional[np.ndarray]
    objective: float
    lp_bound: float    # root relaxation (continuous lower bound)
    best_bound: float  # best proven lower bound at termination
    nodes: int
    gap: float         # (objective - lp_bound) / lp_bound
    wall_s: float


def _solve_node(milp: MILP, fixed: Dict[int, float]) -> LPResult:
    """LP relaxation with variables in ``fixed`` eliminated by substitution."""
    n = milp.n_vars
    if not fixed:
        return simplex_solve(milp.c, milp.A_eq, milp.b_eq, milp.A_ub,
                             milp.b_ub)
    fixed_idx = np.fromiter(fixed.keys(), np.int64)
    fixed_val = np.fromiter(fixed.values(), np.float64)
    free = np.ones(n, bool)
    free[fixed_idx] = False
    free_idx = np.nonzero(free)[0]

    b_eq = milp.b_eq - milp.A_eq[:, fixed_idx] @ fixed_val
    A_eq = milp.A_eq[:, free_idx]
    keep = np.abs(A_eq).sum(1) > 1e-12
    if np.any(np.abs(b_eq[~keep]) > 1e-9):
        return LPResult("infeasible", None, np.nan)
    A_eq, b_eq = A_eq[keep], b_eq[keep]

    b_ub = milp.b_ub - milp.A_ub[:, fixed_idx] @ fixed_val
    A_ub = milp.A_ub[:, free_idx]
    keep = np.abs(A_ub).sum(1) > 1e-12
    if np.any(b_ub[~keep] < -1e-9):
        return LPResult("infeasible", None, np.nan)
    A_ub, b_ub = A_ub[keep], b_ub[keep]

    res = simplex_solve(milp.c[free_idx], A_eq, b_eq, A_ub, b_ub)
    if res.status != "optimal":
        return res
    x = np.zeros(n)
    x[free_idx] = res.x
    x[fixed_idx] = fixed_val
    return LPResult("optimal", x, res.objective + float(
        milp.c[fixed_idx] @ fixed_val))


def _fix_task(milp: MILP, fixed: Dict[int, float], k: int, rank: int):
    """chi_{rank,k}=1, chi_{j,k}=0 for j != rank."""
    out = dict(fixed)
    for i in range(milp.meta["I"]):
        out[milp.chi(i, k)] = 1.0 if i == rank else 0.0
    return out


def solve_milp(milp: MILP, *, incumbent_obj: float = np.inf,
               incumbent_x: Optional[np.ndarray] = None,
               max_nodes: int = 3000, gap_tol: float = 1e-4,
               time_limit_s: float = 300.0) -> MILPResult:
    t0 = time.time()
    i_n, k_n = milp.meta["I"], milp.meta["K"]
    root = _solve_node(milp, {})
    if root.status != "optimal":
        return MILPResult("infeasible", None, np.inf, np.inf, np.inf, 1,
                          np.inf, time.time() - t0)
    lp_bound = root.objective

    best_obj = incumbent_obj
    best_x = incumbent_x
    counter = 0
    # node = (lp_obj, tiebreak, fixed, x)
    heap: List[Tuple[float, int, Dict[int, float], np.ndarray]] = []
    heapq.heappush(heap, (root.objective, counter, {}, root.x))
    nodes = 0
    status = "optimal"

    while heap:
        if nodes >= max_nodes or (time.time() - t0) > time_limit_s:
            status = "node_limit"
            break
        bound, _, fixed, x = heapq.heappop(heap)
        if bound >= best_obj - gap_tol * max(abs(best_obj), 1.0):
            continue
        nodes += 1
        chi = x[: i_n * k_n].reshape(i_n, k_n)
        frac = np.abs(chi - np.round(chi)).max(axis=0)   # per task
        k_branch = int(np.argmax(frac))
        if frac[k_branch] <= _INT_TOL:
            if bound < best_obj:
                best_obj = bound
                best_x = x
            continue
        # SOS1 branch on task k_branch: one child per candidate rank,
        # largest LP weight first.
        order = np.argsort(-chi[:, k_branch])
        for i in order:
            if chi[i, k_branch] < 1e-9 and i != order[0]:
                continue  # keep at least the top candidate
            child = _fix_task(milp, fixed, k_branch, int(i))
            res = _solve_node(milp, child)
            if res.status != "optimal":
                continue
            if res.objective >= best_obj - gap_tol * max(abs(best_obj), 1.0):
                continue
            counter += 1
            heapq.heappush(heap, (res.objective, counter, child, res.x))

    best_bound = min([h[0] for h in heap], default=best_obj)
    best_bound = min(best_bound, best_obj)
    gap = ((best_obj - lp_bound) / lp_bound) if np.isfinite(best_obj) \
        and lp_bound > 0 else np.inf
    if best_x is None:
        return MILPResult("infeasible" if status == "optimal" else status,
                          None, np.inf, lp_bound, best_bound, nodes, np.inf,
                          time.time() - t0)
    final_status = status if status == "node_limit" else "optimal"
    return MILPResult(final_status, best_x, float(best_obj), float(lp_bound),
                      float(best_bound), nodes, float(gap), time.time() - t0)
