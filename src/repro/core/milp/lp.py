"""Dense two-phase primal simplex (numpy).  No external solver is available
offline, so the MILP path (paper §V) runs on this.

Solves::

    min c.x   s.t.  A_eq x = b_eq,  A_ub x <= b_ub,  x >= 0

Anti-cycling: Dantzig pricing with a switch to Bland's rule after a stall
budget.  Sizes here are small (FWMP instances used for certification are a
few hundred variables / ~1-2k rows), so a dense tableau is appropriate.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

_TOL = 1e-9


@dataclasses.dataclass
class LPResult:
    status: str            # "optimal" | "infeasible" | "unbounded" | "maxiter"
    x: Optional[np.ndarray]
    objective: float


def _pivot(t: np.ndarray, basis: np.ndarray, row: int, col: int):
    t[row] /= t[row, col]
    factor = t[:, col].copy()
    factor[row] = 0.0
    t -= np.outer(factor, t[row])
    basis[row] = col


def _run_simplex(t: np.ndarray, basis: np.ndarray, ncols: int,
                 maxiter: int) -> str:
    """Minimize the objective in the last row of tableau ``t`` over columns
    [0, ncols).  Last column is RHS.  Returns status."""
    m = t.shape[0] - 1
    bland_after = max(200, 4 * (m + ncols))
    for it in range(maxiter):
        obj = t[-1, :ncols]
        if it < bland_after:
            col = int(np.argmin(obj))
            if obj[col] >= -_TOL:
                return "optimal"
        else:  # Bland
            neg = np.nonzero(obj < -_TOL)[0]
            if neg.size == 0:
                return "optimal"
            col = int(neg[0])
        ratios = np.full(m, np.inf)
        pos = t[:m, col] > _TOL
        ratios[pos] = t[:m, -1][pos] / t[:m, col][pos]
        if not np.isfinite(ratios).any():
            return "unbounded"
        row = int(np.argmin(ratios))
        if it >= bland_after:
            # Bland: smallest basis index among ties
            best = ratios[row]
            ties = np.nonzero(np.isclose(ratios, best, atol=1e-12))[0]
            row = int(min(ties, key=lambda r: basis[r]))
        _pivot(t, basis, row, col)
    return "maxiter"


def simplex_solve(c, A_eq=None, b_eq=None, A_ub=None, b_ub=None,
                  maxiter: int = 50000) -> LPResult:
    c = np.asarray(c, np.float64)
    n = c.shape[0]
    A_eq = np.zeros((0, n)) if A_eq is None else np.asarray(A_eq, np.float64)
    b_eq = np.zeros(0) if b_eq is None else np.asarray(b_eq, np.float64)
    A_ub = np.zeros((0, n)) if A_ub is None else np.asarray(A_ub, np.float64)
    b_ub = np.zeros(0) if b_ub is None else np.asarray(b_ub, np.float64)
    m_eq, m_ub = A_eq.shape[0], A_ub.shape[0]
    m = m_eq + m_ub

    # standard form with slacks on <= rows
    A = np.zeros((m, n + m_ub))
    A[:m_eq, :n] = A_eq
    A[m_eq:, :n] = A_ub
    A[m_eq:, n:] = np.eye(m_ub)
    b = np.concatenate([b_eq, b_ub])

    # make b >= 0
    neg = b < 0
    A[neg] *= -1.0
    b[neg] *= -1.0

    # rows with a usable identity column (non-negated slack rows) need no
    # artificial; all others do.
    slack_ok = np.zeros(m, bool)
    slack_ok[m_eq:] = ~neg[m_eq:]
    art_rows = np.nonzero(~slack_ok)[0]
    n_art = art_rows.size
    ncols = n + m_ub
    total = ncols + n_art

    t = np.zeros((m + 1, total + 1))
    t[:m, :ncols] = A
    t[:m, -1] = b
    basis = np.zeros(m, np.int64)
    for j, r in enumerate(art_rows):
        t[r, ncols + j] = 1.0
        basis[r] = ncols + j
    for r in np.nonzero(slack_ok)[0]:
        basis[r] = n + (r - m_eq)

    # ---- phase 1: minimize sum of artificials --------------------------------
    if n_art:
        t[-1, ncols:total] = 1.0
        # price out basic artificials
        for r in art_rows:
            t[-1] -= t[r]
        status = _run_simplex(t, basis, total, maxiter)
        if status != "optimal":
            return LPResult(status, None, np.nan)
        phase1_obj = -t[-1, -1]
        if phase1_obj > 1e-6:
            return LPResult("infeasible", None, np.nan)
        # drive remaining basic artificials out where possible
        for r in range(m):
            if basis[r] >= ncols:
                cand = np.nonzero(np.abs(t[r, :ncols]) > 1e-7)[0]
                if cand.size:
                    _pivot(t, basis, r, int(cand[0]))
        # degenerate artificial rows (all-zero) are redundant; keep, they
        # stay basic at 0 and never pivot (their columns are zeroed below).
        t[:, ncols:total] = 0.0

    # ---- phase 2 --------------------------------------------------------------
    t[-1, :] = 0.0
    t[-1, :n] = c
    for r in range(m):
        if basis[r] < ncols and np.abs(t[-1, basis[r]]) > 0:
            t[-1] -= t[-1, basis[r]] * t[r]
    status = _run_simplex(t, basis, ncols, maxiter)
    if status != "optimal":
        return LPResult(status, None, np.nan)
    x = np.zeros(ncols)
    for r in range(m):
        if basis[r] < ncols:
            x[basis[r]] = t[r, -1]
    return LPResult("optimal", x[:n], float(np.dot(c, x[:n])))
