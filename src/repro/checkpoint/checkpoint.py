"""Atomic, manifest-driven checkpointing with async write-behind.

Layout: <dir>/step_<n>/ with one .npy per flattened leaf + manifest.json
(tree structure, shapes, dtypes, step, extra metadata).  Writes go to a
temp dir that is os.rename'd into place — a crashed writer can never corrupt
the latest checkpoint, which is what the fault-tolerance restart loop
(repro.runtime.fault) depends on.  Restore re-places leaves with a target
sharding tree, which is also the elastic re-mesh path: the same checkpoint
restores onto a different mesh by passing different shardings.

Async saves are tracked in a module-level in-flight registry keyed by the
checkpoint directory: readers (``latest_step``/``restore``) join any
pending writer threads for that directory before listing or loading.  This
is what makes restart-after-failure correct with ``async_write=True`` — the
restart loop constructs a FRESH ``CheckpointManager`` that cannot join the
crashed run's writer thread through ``self._thread``, and without the
registry it would read the directory mid-write and silently replay from
step 0 (observed: an injected step-5 failure ~2 fast steps after the step-3
save consistently beat the writer to the rename).
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from pathlib import Path
from typing import Any, Optional

import jax
import ml_dtypes
import numpy as np

# numpy can't roundtrip ml_dtypes through np.save: store a raw-integer view
# and keep the true dtype in the manifest.
_EXOTIC = {
    "bfloat16": (ml_dtypes.bfloat16, np.uint16),
    "float8_e4m3fn": (ml_dtypes.float8_e4m3fn, np.uint8),
    "float8_e5m2": (ml_dtypes.float8_e5m2, np.uint8),
}


def _to_savable(arr: np.ndarray):
    name = arr.dtype.name
    if name in _EXOTIC:
        return arr.view(_EXOTIC[name][1]), name
    return arr, name


def _from_saved(arr: np.ndarray, name: str) -> np.ndarray:
    if name in _EXOTIC:
        return arr.view(_EXOTIC[name][0])
    return arr


def _flatten_with_names(tree):
    flat, treedef = jax.tree.flatten(tree)
    names = [f"leaf_{i:05d}" for i in range(len(flat))]
    return flat, names, treedef


def save(ckpt_dir: str, step: int, tree: Any, *, extra: Optional[dict] = None,
         sync: bool = True) -> Path:
    ckpt_dir = Path(ckpt_dir)
    final = ckpt_dir / f"step_{step:08d}"
    tmp = ckpt_dir / f".tmp_step_{step:08d}"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)
    flat, names, treedef = _flatten_with_names(tree)
    manifest = {"step": step, "leaves": [], "extra": extra or {}}
    for name, leaf in zip(names, flat):
        arr = np.asarray(leaf)
        savable, dtype_name = _to_savable(arr)
        np.save(tmp / f"{name}.npy", savable)
        manifest["leaves"].append({
            "name": name, "shape": list(arr.shape), "dtype": dtype_name})
    (tmp / "manifest.json").write_text(json.dumps(manifest))
    if final.exists():
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


# directory -> in-flight async writer threads; readers join them so a save
# started by one CheckpointManager is never invisible to another (or to the
# module-level functions) in the same process
_INFLIGHT: dict = {}
_INFLIGHT_LOCK = threading.Lock()


def _register_and_start(ckpt_dir, thread: threading.Thread):
    """Register an async writer and start it under the registry lock, so a
    reader snapshotting the registry can never observe a registered-but-
    unstarted thread (join() on one raises) nor miss a started one.  Dead
    writers are pruned here, keeping the registry bounded over long runs."""
    key = str(Path(ckpt_dir).resolve())
    with _INFLIGHT_LOCK:
        alive = [t for t in _INFLIGHT.get(key, ()) if t.is_alive()]
        alive.append(thread)
        _INFLIGHT[key] = alive
        thread.start()


def wait_for_inflight(ckpt_dir):
    """Block until every pending async save targeting ``ckpt_dir`` (from any
    CheckpointManager in this process) has completed."""
    key = str(Path(ckpt_dir).resolve())
    with _INFLIGHT_LOCK:
        threads = list(_INFLIGHT.get(key, ()))
    for t in threads:
        t.join()
    with _INFLIGHT_LOCK:
        alive = [t for t in _INFLIGHT.get(key, ()) if t.is_alive()]
        if key in _INFLIGHT:
            _INFLIGHT[key] = alive


def latest_step(ckpt_dir: str) -> Optional[int]:
    wait_for_inflight(ckpt_dir)
    d = Path(ckpt_dir)
    if not d.exists():
        return None
    steps = [int(p.name.split("_")[1]) for p in d.glob("step_*")
             if (p / "manifest.json").exists()]
    return max(steps) if steps else None


def restore(ckpt_dir: str, step: int, example_tree: Any,
            shardings: Any = None) -> Any:
    """Restore into the structure of ``example_tree``; if ``shardings`` is
    given (a matching tree of NamedShardings), leaves are placed accordingly
    — pass shardings built on a DIFFERENT mesh to elastically re-shard."""
    wait_for_inflight(ckpt_dir)
    d = Path(ckpt_dir) / f"step_{step:08d}"
    manifest = json.loads((d / "manifest.json").read_text())
    flat, names, treedef = _flatten_with_names(example_tree)
    assert len(flat) == len(manifest["leaves"]), "tree structure changed"
    loaded = []
    sh_flat = (treedef.flatten_up_to(shardings) if shardings is not None
               else [None] * len(flat))
    for meta, example, sh in zip(manifest["leaves"], flat, sh_flat):
        arr = _from_saved(np.load(d / f"{meta['name']}.npy"), meta["dtype"])
        if sh is not None:
            loaded.append(jax.device_put(arr, sh))
        else:
            loaded.append(jax.numpy.asarray(arr))
    return treedef.unflatten(loaded)


class CheckpointManager:
    """Keeps the last ``keep`` checkpoints; optional async write-behind."""

    def __init__(self, ckpt_dir: str, keep: int = 3, async_write: bool = True):
        self.dir = Path(ckpt_dir)
        self.keep = keep
        self.async_write = async_write
        self._thread: Optional[threading.Thread] = None

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def save(self, step: int, tree: Any, extra: Optional[dict] = None):
        # materialize on host BEFORE handing off (donated buffers may die)
        host_tree = jax.tree.map(lambda x: np.asarray(x), tree)

        def work():
            save(self.dir, step, host_tree, extra=extra)
            self._gc()

        self.wait()
        if self.async_write:
            self._thread = threading.Thread(target=work, daemon=True)
            _register_and_start(self.dir, self._thread)
        else:
            work()

    def _gc(self):
        steps = sorted(int(p.name.split("_")[1])
                       for p in self.dir.glob("step_*"))
        for s in steps[: -self.keep]:
            shutil.rmtree(self.dir / f"step_{s:08d}", ignore_errors=True)

    def latest(self) -> Optional[int]:
        return latest_step(self.dir)

    def restore(self, example_tree: Any, shardings: Any = None,
                step: Optional[int] = None):
        self.wait()
        step = step if step is not None else self.latest()
        assert step is not None, "no checkpoint to restore"
        return restore(self.dir, step, example_tree, shardings), step
