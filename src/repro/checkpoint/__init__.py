from repro.checkpoint.checkpoint import (CheckpointManager,  # noqa: F401
                                         latest_step, restore, save)
