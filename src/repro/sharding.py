"""Logical-axis -> mesh-axis mapping (MaxText-style rules).

Models annotate parameters with *logical* axes; this module resolves them to
mesh ``PartitionSpec``s with divisibility-aware fallback (a dimension that
does not divide its target mesh axis is replicated instead — e.g. kv_heads=8
on a 16-way model axis).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.layers import LP, is_lp


@dataclasses.dataclass(frozen=True)
class MeshAxes:
    """Which mesh axes play which role."""

    batch: Tuple[str, ...]        # batch / fsdp data axes, e.g. ("pod","data")
    data: str = "data"            # fsdp weight axis
    model: str = "model"          # tensor/expert-parallel axis

    @staticmethod
    def for_mesh(mesh: Mesh) -> "MeshAxes":
        names = mesh.axis_names
        if "pod" in names:
            return MeshAxes(batch=("pod", "data"))
        return MeshAxes(batch=("data",))


# Logical axis -> mesh axis role. Resolved against a MeshAxes instance.
LOGICAL_RULES = {
    "vocab": "model",
    "embed": "data",        # fsdp on the d_model dim of weight matrices
    "heads": "model",
    "kv_heads": "model",
    "head_dim": None,
    "mlp": "model",
    "rnn": "model",         # recurrent-width dim (rwkv / rg-lru)
    "expert": "model",      # expert parallelism
    "expert_mlp": "data",   # fsdp on per-expert hidden dim
    "layers": None,
    "conv": None,
    "lora": None,
    None: None,
}


def _axis_size(mesh: Mesh, name: Optional[str]) -> int:
    if name is None:
        return 1
    return mesh.shape[name]


def spec_for(mesh: Mesh, axes: MeshAxes, logical: Tuple[Optional[str], ...],
             shape: Tuple[int, ...]) -> P:
    """Resolve logical axes to a PartitionSpec.

    Rules: non-divisible dims are replicated; if two dims resolve to the same
    mesh axis (e.g. a (layers, E, d, f) expert weight mapping both d and f to
    the fsdp axis, or a square (d, d) projection), only the largest dim keeps
    the mesh axis — a mesh axis may shard at most one dim.
    """
    entries = []
    for dim, name in zip(shape, logical):
        target = LOGICAL_RULES.get(name)
        if target is None:
            entries.append(None)
            continue
        mesh_axis = axes.model if target == "model" else axes.data
        if mesh_axis in mesh.axis_names and dim % _axis_size(mesh, mesh_axis) == 0:
            entries.append(mesh_axis)
        else:
            entries.append(None)
    # dedupe: keep the largest dim per mesh axis
    for axis in set(e for e in entries if e is not None):
        idxs = [i for i, e in enumerate(entries) if e == axis]
        if len(idxs) > 1:
            keep = max(idxs, key=lambda i: shape[i])
            for i in idxs:
                if i != keep:
                    entries[i] = None
    return P(*entries)


def shardings_for_lp_tree(mesh: Mesh, axes: MeshAxes, lp_tree):
    """LP tree -> matching tree of NamedShardings."""
    def one(p: LP):
        return NamedSharding(mesh, spec_for(mesh, axes, p.axes, p.value.shape))
    return jax.tree.map(one, lp_tree, is_leaf=is_lp)


def specs_for_lp_tree(mesh: Mesh, axes: MeshAxes, lp_tree):
    def one(p: LP):
        return spec_for(mesh, axes, p.axes, p.value.shape)
    return jax.tree.map(one, lp_tree, is_leaf=is_lp)


def batch_spec(axes: MeshAxes, ndim: int, batch_dim: int = 0) -> P:
    entries = [None] * ndim
    entries[batch_dim] = axes.batch if len(axes.batch) > 1 else axes.batch[0]
    return P(*entries)


def constrain(x, mesh: Mesh, spec: P):
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def batch_size_divisor(mesh: Mesh, axes: MeshAxes) -> int:
    return int(np.prod([mesh.shape[a] for a in axes.batch]))
