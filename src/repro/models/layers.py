"""Shared layer primitives and the logical-parameter convention.

Every parameter leaf is created as an ``LP(value, axes)`` — a value plus a
tuple of *logical* axis names ("embed", "heads", "mlp", "expert", ...).  The
launcher maps logical axes onto mesh axes (see launch/shardings.py); models
never hardcode mesh names, so the same code serves the 1-device smoke tests,
the (16,16) single-pod mesh and the (2,16,16) multi-pod mesh.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class LP:
    """Logical param: array (or ShapeDtypeStruct) + logical axis names."""

    value: Any
    axes: Tuple[Optional[str], ...]

    def __post_init__(self):
        shape = getattr(self.value, "shape", None)
        if shape is not None:
            assert len(self.axes) == len(shape), (self.axes, shape)


# Registered as a pytree node so jax.eval_shape / vmap can trace through LP
# trees; axes ride along as static aux data.
jax.tree_util.register_pytree_node(
    LP,
    lambda p: ((p.value,), p.axes),
    lambda axes, children: LP(children[0], axes),
)


def is_lp(x) -> bool:
    return isinstance(x, LP)


def lp_map(fn, tree):
    return jax.tree.map(fn, tree, is_leaf=is_lp)


def split_lp_tree(tree):
    """LP tree -> (values tree, logical-axes tree)."""
    values = lp_map(lambda p: p.value, tree)
    axes = lp_map(lambda p: p.axes, tree)
    return values, axes


def merge_lp_tree(values, axes):
    return jax.tree.map(LP, values, axes,
                        is_leaf=lambda x: isinstance(x, tuple) and all(
                            a is None or isinstance(a, str) for a in x))


# --------------------------------------------------------------------- init
def dense_init(key, shape, axes, in_axis=0, scale=1.0, dtype=jnp.bfloat16) -> LP:
    """Truncated-normal fan-in init (LeCun-ish)."""
    fan_in = int(np.prod([shape[i] for i in np.atleast_1d(in_axis)]))
    std = scale / np.sqrt(max(fan_in, 1))
    v = std * jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32)
    return LP(v.astype(dtype), axes)


def zeros_init(shape, axes, dtype=jnp.bfloat16) -> LP:
    return LP(jnp.zeros(shape, dtype), axes)


def ones_init(shape, axes, dtype=jnp.bfloat16) -> LP:
    return LP(jnp.ones(shape, dtype), axes)


def const_init(value, axes, dtype=jnp.float32) -> LP:
    return LP(jnp.asarray(value, dtype), axes)


# --------------------------------------------------------------------- norms
def rms_norm(x, weight, eps: float = 1e-6, offset: float = 1.0):
    """RMSNorm in f32 (gemma convention: weight is a delta around 1)."""
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    normed = xf * jax.lax.rsqrt(var + eps)
    return (normed * (offset + weight.astype(jnp.float32))).astype(x.dtype)


def layer_norm(x, weight, bias, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    normed = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (normed * weight.astype(jnp.float32)
            + bias.astype(jnp.float32)).astype(x.dtype)


def group_norm(x, weight, bias, num_groups: int, eps: float = 1e-5):
    """GroupNorm over the last dim (used by RWKV6 output)."""
    *lead, d = x.shape
    xf = x.astype(jnp.float32).reshape(*lead, num_groups, d // num_groups)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    normed = ((xf - mu) * jax.lax.rsqrt(var + eps)).reshape(*lead, d)
    return (normed * weight.astype(jnp.float32)
            + bias.astype(jnp.float32)).astype(x.dtype)


# ---------------------------------------------------------------- activations
def activation(name: str):
    return {
        "silu": jax.nn.silu,
        "gelu": lambda x: jax.nn.gelu(x, approximate=True),
        "relu": jax.nn.relu,
        "relu2": lambda x: jnp.square(jax.nn.relu(x)),
    }[name]


def softcap(x, cap: float):
    if cap and cap > 0.0:
        return cap * jnp.tanh(x / cap)
    return x


# --------------------------------------------------------------------- RoPE
def rope_freqs(head_dim: int, theta: float):
    exponent = jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim
    return 1.0 / (theta ** exponent)  # (head_dim/2,)


def apply_rope(x, positions, theta: float):
    """x: (..., seq, heads, head_dim); positions: (..., seq)."""
    head_dim = x.shape[-1]
    freqs = rope_freqs(head_dim, theta)
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # (..., S, hd/2)
    angles = angles[..., None, :]  # broadcast over heads
    sin, cos = jnp.sin(angles), jnp.cos(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ----------------------------------------------------------------- gated MLP
def init_mlp(key, d_model: int, d_ff: int, dtype=jnp.bfloat16):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w_gate": dense_init(k1, (d_model, d_ff), ("embed", "mlp"), dtype=dtype),
        "w_up": dense_init(k2, (d_model, d_ff), ("embed", "mlp"), dtype=dtype),
        "w_down": dense_init(k3, (d_ff, d_model), ("mlp", "embed"), dtype=dtype),
    }


def mlp_forward(params, x, act_name: str):
    act = activation(act_name)
    gate = act(jnp.einsum("bsd,df->bsf", x, params["w_gate"]))
    up = jnp.einsum("bsd,df->bsf", x, params["w_up"])
    return jnp.einsum("bsf,fd->bsd", gate * up, params["w_down"])
