"""Uniform model API over all assigned architectures.

``build_model(cfg, mesh)`` returns a ``Model`` with init / loss / prefill /
decode closures, plus ``input_specs`` and ``cache_specs`` used by the
multi-pod dry-run (ShapeDtypeStruct stand-ins — no device allocation).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import (BLOCK_ATTN, BLOCK_LOCAL, BLOCK_MOE, BLOCK_REC,
                                BLOCK_RWKV, ModelConfig, ShapeConfig)
from repro.models import encdec as encdec_lib
from repro.models import transformer as tf_lib
from repro.sharding import MeshAxes, batch_size_divisor, spec_for


@dataclasses.dataclass
class Model:
    cfg: ModelConfig
    mesh: Mesh
    axes: MeshAxes
    init: Callable            # key -> LP tree
    loss_fn: Callable         # (params, batch) -> (loss, metrics)
    prefill_fn: Callable      # (params, batch) -> (cache, logits)
    decode_fn: Callable       # (params, cache, token, pos) -> (cache, logits)


def build_model(cfg: ModelConfig, mesh: Mesh,
                axes: Optional[MeshAxes] = None) -> Model:
    axes = axes or MeshAxes.for_mesh(mesh)
    if cfg.arch_type == "encdec":
        return Model(
            cfg, mesh, axes,
            init=functools.partial(encdec_lib.init_encdec, cfg=cfg),
            loss_fn=lambda p, b: encdec_lib.encdec_loss(p, b, cfg, mesh, axes),
            prefill_fn=lambda p, b: encdec_lib.encdec_prefill(p, b, cfg, mesh, axes),
            decode_fn=lambda p, c, t, pos: encdec_lib.encdec_decode(
                p, c, t, pos, cfg, mesh, axes),
        )
    return Model(
        cfg, mesh, axes,
        init=functools.partial(tf_lib.init_lm, cfg=cfg),
        loss_fn=lambda p, b: tf_lib.lm_loss(p, b, cfg, mesh, axes),
        prefill_fn=lambda p, b: tf_lib.lm_prefill(p, b, cfg, mesh, axes),
        decode_fn=lambda p, c, t, pos: tf_lib.lm_decode(
            p, c, t, pos, cfg, mesh, axes),
    )


# -------------------------------------------------------------- input specs
def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def _bspec(axes: MeshAxes, b: int, mesh: Mesh):
    if b % batch_size_divisor(mesh, axes) == 0:
        return axes.batch if len(axes.batch) > 1 else axes.batch[0]
    return None


def batch_specs(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh,
                axes: MeshAxes, kind: str):
    """(ShapeDtypeStruct tree, PartitionSpec tree) for a step's data batch.

    kind: "train" | "prefill" — decode inputs are built separately.
    """
    b, s = shape.global_batch, shape.seq_len
    bs = _bspec(axes, b, mesh)
    dt = jnp.bfloat16
    if cfg.arch_type == "encdec":
        s_dec = encdec_lib.decoder_len(cfg, s)
        batch = {"audio_embed": _sds((b, s, cfg.d_model), dt),
                 "tokens": _sds((b, s_dec), jnp.int32)}
        specs = {"audio_embed": P(bs, None, None), "tokens": P(bs, None)}
        if kind == "train":
            batch["targets"] = _sds((b, s_dec), jnp.int32)
            specs["targets"] = P(bs, None)
        return batch, specs
    if cfg.frontend == "vision":
        p_media = cfg.num_media_positions
        s_text = s - p_media
        batch = {"media_embed": _sds((b, p_media, cfg.d_model), dt),
                 "tokens": _sds((b, s_text), jnp.int32)}
        specs = {"media_embed": P(bs, None, None), "tokens": P(bs, None)}
        if kind == "train":
            batch["targets"] = _sds((b, s_text), jnp.int32)
            specs["targets"] = P(bs, None)
        return batch, specs
    batch = {"tokens": _sds((b, s), jnp.int32)}
    specs = {"tokens": P(bs, None)}
    if kind == "train":
        batch["targets"] = _sds((b, s), jnp.int32)
        specs["targets"] = P(bs, None)
    return batch, specs


def _seq_shard(axes: MeshAxes, b: int, s: int, mesh: Mesh):
    """(batch_entry, seq_entry) for KV caches: batch over the batch axes when
    divisible, else shard the sequence dim as hard as divisibility allows."""
    if b % batch_size_divisor(mesh, axes) == 0:
        bspec = axes.batch if len(axes.batch) > 1 else axes.batch[0]
        seq = axes.model if s % mesh.shape[axes.model] == 0 else None
        return bspec, seq
    combo = (axes.data, axes.model)
    size = mesh.shape[axes.data] * mesh.shape[axes.model]
    if s % size == 0:
        return None, combo
    return None, (axes.data if s % mesh.shape[axes.data] == 0 else None)


def cache_specs(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh,
                axes: MeshAxes):
    """(ShapeDtypeStruct cache tree, PartitionSpec tree) for decode cells."""
    b, s = shape.global_batch, shape.seq_len
    hkv, hd, d = cfg.num_kv_heads, cfg.head_dim, cfg.d_model
    cb, cs = _seq_shard(axes, b, s, mesh)

    if cfg.arch_type == "encdec":
        ldec = cfg.num_decoder_layers
        s_dec = 448
        cache = {"sk": _sds((ldec, b, s_dec, hkv, hd), jnp.bfloat16),
                 "sv": _sds((ldec, b, s_dec, hkv, hd), jnp.bfloat16),
                 "ck": _sds((ldec, b, s, hkv, hd), jnp.bfloat16),
                 "cv": _sds((ldec, b, s, hkv, hd), jnp.bfloat16)}
        sspec = P(None, cb, None, None, None)
        cspec = P(None, cb, cs, None, None)
        specs = {"sk": sspec, "sv": sspec, "ck": cspec, "cv": cspec}
        return cache, specs

    n_periods, tail_kinds = tf_lib.split_layers(cfg)
    h_rwkv = d // cfg.rwkv_head_dim
    rhd = cfg.rwkv_head_dim
    model_ok = lambda dim: axes.model if dim % mesh.shape[axes.model] == 0 else None

    def entry(kind: str, lead: Tuple[int, ...], lead_spec):
        if kind in (BLOCK_ATTN, BLOCK_LOCAL, BLOCK_MOE):
            s_eff = s
            cb_e, cs_e = cb, cs
            if kind == BLOCK_LOCAL and cfg.window_kv_cache:
                s_eff = min(cfg.window_size, s)       # ring cache (§Perf)
                cb_e, cs_e = _seq_shard(axes, b, s_eff, mesh)
            sds = _sds(lead + (b, s_eff, hkv, hd), jnp.bfloat16)
            spec = P(*lead_spec, cb_e, cs_e, None, None)
            return {"k": sds, "v": sds}, {"k": spec, "v": spec}
        if kind == BLOCK_RWKV:
            return (
                {"wkv": _sds(lead + (b, h_rwkv, rhd, rhd), jnp.float32),
                 "tm_shift": _sds(lead + (b, d), jnp.bfloat16),
                 "cm_shift": _sds(lead + (b, d), jnp.bfloat16)},
                {"wkv": P(*lead_spec, cb, model_ok(h_rwkv), None, None),
                 "tm_shift": P(*lead_spec, cb, model_ok(d)),
                 "cm_shift": P(*lead_spec, cb, model_ok(d))})
        if kind == BLOCK_REC:
            w = cfg.rglru_conv_width
            return (
                {"h": _sds(lead + (b, d), jnp.float32),
                 "conv": _sds(lead + (b, w - 1, d), jnp.bfloat16)},
                {"h": P(*lead_spec, cb, model_ok(d)),
                 "conv": P(*lead_spec, cb, None, model_ok(d))})
        raise ValueError(kind)

    scan_c, scan_s = {}, {}
    for i, kind in enumerate(cfg.block_pattern):
        scan_c[f"b{i}"], scan_s[f"b{i}"] = entry(kind, (n_periods,), (None,))
    tail_c, tail_s = {}, {}
    for i, kind in enumerate(tail_kinds):
        tail_c[f"t{i}"], tail_s[f"t{i}"] = entry(kind, (), ())
    return ({"scan": scan_c, "tail": tail_c},
            {"scan": scan_s, "tail": tail_s})


def decode_token_specs(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh,
                       axes: MeshAxes):
    b = shape.global_batch
    bs = _bspec(axes, b, mesh)
    return (_sds((b, 1), jnp.int32), P(bs, None),
            _sds((), jnp.int32), P())
