"""Mixture-of-Experts FFN with explicit expert parallelism (shard_map).

Baseline collective schedule ("replicated-token EP"): activations are batch-
sharded over the data axes and replicated over the model axis (standard TP
layout between blocks), experts are sharded over the model axis, and each
model-shard processes the tokens routed to *its* experts via per-expert
top-capacity gather -> GEMM -> scatter; results combine with a single psum
over the model axis.  Expert weights are FSDP-sharded over the data axis on
the hidden dim and all-gathered at use.

Router statistics (tokens-per-expert) are returned so the CCM load balancer
(repro.balance.expert_placement) can re-plan expert placement: experts are CCM
*shared blocks*, per-expert token loads are task loads, and dispatch volume is
the communication term.
"""
from __future__ import annotations

import functools
import inspect
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

try:  # jax >= 0.6: top-level API
    _shard_map = jax.shard_map
except AttributeError:  # jax 0.4/0.5
    from jax.experimental.shard_map import shard_map as _shard_map
# the replication-check kwarg was renamed check_rep -> check_vma on a
# different release than the jax.shard_map promotion, so key on the
# signature rather than the API location
_sig = inspect.signature(_shard_map).parameters
_SHARD_MAP_KW = ({"check_vma": False} if "check_vma" in _sig
                 else {"check_rep": False} if "check_rep" in _sig else {})
del _sig

from repro.configs.base import ModelConfig
from repro.models.layers import LP, activation, dense_init
from repro.sharding import MeshAxes


def init_moe(key, cfg: ModelConfig, dtype=jnp.bfloat16):
    kr, k1, k2, k3, ks = jax.random.split(key, 5)
    d, e, f = cfg.d_model, cfg.num_experts, cfg.moe_d_ff
    params = {
        "router": dense_init(kr, (d, e), ("embed", None), dtype=jnp.float32),
        "w_gate": dense_init(k1, (e, d, f), ("expert", "embed", "expert_mlp"),
                             in_axis=1, dtype=dtype),
        "w_up": dense_init(k2, (e, d, f), ("expert", "embed", "expert_mlp"),
                           in_axis=1, dtype=dtype),
        "w_down": dense_init(k3, (e, f, d), ("expert", "expert_mlp", "embed"),
                             in_axis=1, dtype=dtype),
    }
    if cfg.num_shared_experts:
        from repro.models.layers import init_mlp
        params["shared"] = init_mlp(ks, d, cfg.d_ff * cfg.num_shared_experts,
                                    dtype=dtype)
    return params


def _capacity(cfg: ModelConfig, tokens: int) -> int:
    c = int(cfg.capacity_factor * tokens * cfg.top_k / cfg.num_experts) + 1
    c = (c + 7) // 8 * 8
    return max(1, min(c, tokens))


def _local_moe(router_w, w_gate, w_up, w_down, x, *, cfg: ModelConfig,
               axes: MeshAxes, act_name: str, model_size: int, data_size: int):
    """Per-device body under shard_map.

    x: (B_loc, S, d) — identical across the model axis, sharded over batch.
    w_*: (E_loc, d, f_loc) — expert-sharded over model, fsdp over data.
    """
    b, s, d = x.shape
    t = b * s
    x_flat = x.reshape(t, d)
    e = cfg.num_experts
    e_loc = e // model_size
    assert e % model_size == 0, (e, model_size)

    # FSDP all-gather of this shard's expert weights over the data axis.
    if data_size > 1:
        w_gate = jax.lax.all_gather(w_gate, axes.data, axis=2, tiled=True)
        w_up = jax.lax.all_gather(w_up, axes.data, axis=2, tiled=True)
        w_down = jax.lax.all_gather(w_down, axes.data, axis=1, tiled=True)

    logits = (x_flat.astype(jnp.float32) @ router_w)  # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_vals, top_idx = jax.lax.top_k(probs, cfg.top_k)  # (T, k)
    top_vals = top_vals / jnp.maximum(top_vals.sum(-1, keepdims=True), 1e-9)

    cap = _capacity(cfg, t)
    act = activation(act_name)
    out = jnp.zeros((t, d), jnp.float32)
    offset = jax.lax.axis_index(axes.model) * e_loc
    for e_local in range(e_loc):
        e_id = offset + e_local
        w_e = jnp.where(top_idx == e_id, top_vals, 0.0).sum(-1)  # (T,)
        sel_w, sel_i = jax.lax.top_k(jnp.where(w_e > 0, w_e, -1.0), cap)
        valid = (sel_w > 0).astype(jnp.float32)
        xg = x_flat[sel_i]  # (C, d)
        g = act(xg @ w_gate[e_local])
        u = xg @ w_up[e_local]
        h = ((g * u) @ w_down[e_local]).astype(jnp.float32)
        h = h * (sel_w * valid)[:, None]
        out = out.at[sel_i].add(h)

    out = jax.lax.psum(out, axes.model)

    # Router stats: tokens-per-expert counts + Switch-style aux loss.
    assign = jax.nn.one_hot(top_idx[:, 0], e, dtype=jnp.float32)  # top-1 frac
    f_frac = assign.mean(0)
    p_mean = probs.mean(0)
    aux = e * jnp.sum(f_frac * p_mean)
    counts = jnp.zeros((e,), jnp.float32)
    for k in range(cfg.top_k):
        counts = counts + jax.nn.one_hot(top_idx[:, k], e,
                                         dtype=jnp.float32).sum(0)
    aux = jax.lax.pmean(aux, axes.batch)
    counts = jax.lax.psum(counts, axes.batch)
    return out.reshape(b, s, d).astype(x.dtype), aux, counts


def moe_forward(params, x, cfg: ModelConfig, mesh: Mesh, axes: MeshAxes,
                act_name: str):
    """Returns (y, stats) where stats = {'aux_loss','expert_counts'}."""
    bspec = axes.batch if len(axes.batch) > 1 else axes.batch[0]
    fn = functools.partial(
        _local_moe, cfg=cfg, axes=axes, act_name=act_name,
        model_size=int(mesh.shape[axes.model]),
        data_size=int(mesh.shape[axes.data]))
    y, aux, counts = _shard_map(
        fn,
        mesh=mesh,
        in_specs=(
            P(None, None),                       # router (d, E) replicated
            P(axes.model, None, axes.data),      # w_gate (E, d, f)
            P(axes.model, None, axes.data),      # w_up
            P(axes.model, axes.data, None),      # w_down (E, f, d)
            P(bspec, None, None),                # x
        ),
        out_specs=(P(bspec, None, None), P(), P()),
        **_SHARD_MAP_KW,
    )(params["router"], params["w_gate"], params["w_up"], params["w_down"], x)

    if cfg.num_shared_experts:
        from repro.models.layers import mlp_forward
        y = y + mlp_forward(params["shared"], x, act_name)
    return y, {"aux_loss": aux, "expert_counts": counts}
