"""Encoder-decoder assembly (whisper-large-v3 backbone).

The audio frontend is a STUB per the assignment: ``input_specs`` provides
precomputed frame embeddings (B, S_enc, d_model); the encoder is a
bidirectional transformer stack over frames; the decoder is a causal stack
with cross-attention.  ``seq_len`` of a shape cell = encoder frame count;
decoder length = min(448, seq_len // 8) (whisper's 448-token label budget).
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp
from jax.sharding import Mesh

from repro.configs.base import ModelConfig
from repro.models import attention as attn
from repro.models.layers import init_mlp, mlp_forward, rms_norm, zeros_init
from repro.models.transformer import (Ctx, _remat, dense_init, embed_tokens,
                                      masked_cross_entropy, stack_periods,
                                      unembed)
from repro.sharding import MeshAxes


def decoder_len(cfg: ModelConfig, seq_len: int) -> int:
    return max(8, min(448, seq_len // 8))


def init_enc_block(key, cfg: ModelConfig, dtype=jnp.bfloat16):
    k1, k2 = jax.random.split(key)
    return {
        "norm_attn": zeros_init((cfg.d_model,), ("embed",), dtype=jnp.float32),
        "attn": attn.init_attention(k1, cfg, dtype=dtype),
        "norm_mlp": zeros_init((cfg.d_model,), ("embed",), dtype=jnp.float32),
        "mlp": init_mlp(k2, cfg.d_model, cfg.d_ff, dtype=dtype),
    }


def init_dec_block(key, cfg: ModelConfig, dtype=jnp.bfloat16):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "norm_self": zeros_init((cfg.d_model,), ("embed",), dtype=jnp.float32),
        "self_attn": attn.init_attention(k1, cfg, dtype=dtype),
        "norm_cross": zeros_init((cfg.d_model,), ("embed",), dtype=jnp.float32),
        "cross_attn": attn.init_attention(k2, cfg, dtype=dtype),
        "norm_mlp": zeros_init((cfg.d_model,), ("embed",), dtype=jnp.float32),
        "mlp": init_mlp(k3, cfg.d_model, cfg.d_ff, dtype=dtype),
    }


def init_encdec(key, cfg: ModelConfig, dtype=jnp.bfloat16):
    keys = jax.random.split(key, 4)
    ekeys = jax.random.split(keys[0], cfg.num_layers)
    dkeys = jax.random.split(keys[1], cfg.num_decoder_layers)
    return {
        "embed": dense_init(keys[2], (cfg.vocab_size, cfg.d_model),
                            ("vocab", "embed"), in_axis=1, dtype=dtype),
        "enc_scan": stack_periods(
            [{"b0": init_enc_block(k, cfg, dtype)} for k in ekeys]),
        "enc_norm": zeros_init((cfg.d_model,), ("embed",), dtype=jnp.float32),
        "dec_scan": stack_periods(
            [{"b0": init_dec_block(k, cfg, dtype)} for k in dkeys]),
        "final_norm": zeros_init((cfg.d_model,), ("embed",), dtype=jnp.float32),
        "lm_head": dense_init(keys[3], (cfg.d_model, cfg.vocab_size),
                              ("embed", "vocab"), dtype=dtype),
    }


def run_encoder(params, audio_embed, cfg: ModelConfig, ctx: Ctx):
    x = ctx.bconstrain(audio_embed)
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s)[None, :], (b, s))

    def period_fn(x, p):
        blk = p["b0"]
        h = rms_norm(x, blk["norm_attn"], cfg.norm_eps)
        a = attn.attention_forward(blk["attn"], h, cfg, mask_kind="none",
                                   positions=positions)
        x = x + a
        h = rms_norm(x, blk["norm_mlp"], cfg.norm_eps)
        x = ctx.bconstrain(x + mlp_forward(blk["mlp"], h, cfg.act))
        return x, None

    body = _remat(period_fn, cfg)
    if cfg.unroll_stack:
        from repro.models.transformer import _unrolled_scan
        x, _ = _unrolled_scan(lambda c, p: (body(c, p)[0], 0),
                              x, params["enc_scan"], cfg.num_layers)
    else:
        x, _ = jax.lax.scan(lambda c, p: body(c, p), x, params["enc_scan"])
    return rms_norm(x, params["enc_norm"], cfg.norm_eps)


def run_decoder(params, tokens, enc_out, cfg: ModelConfig, ctx: Ctx,
                collect_cache: bool = False):
    x = embed_tokens(params, tokens, cfg)
    x = ctx.bconstrain(x)
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s)[None, :], (b, s))
    b_e, s_e, _ = enc_out.shape
    kv_positions = jnp.broadcast_to(jnp.arange(s_e)[None, :], (b_e, s_e))

    def period_fn(x, p):
        blk = p["b0"]
        h = rms_norm(x, blk["norm_self"], cfg.norm_eps)
        a, sk, sv = attn.attention_forward_kv(
            blk["self_attn"], h, cfg, mask_kind="causal", positions=positions)
        x = x + a
        h = rms_norm(x, blk["norm_cross"], cfg.norm_eps)
        a, ck, cv = attn.attention_forward_kv(
            blk["cross_attn"], h, cfg, mask_kind="none", positions=positions,
            kv_x=enc_out, kv_positions=kv_positions)
        x = x + a
        h = rms_norm(x, blk["norm_mlp"], cfg.norm_eps)
        x = ctx.bconstrain(x + mlp_forward(blk["mlp"], h, cfg.act))
        cache = ({"sk": sk, "sv": sv, "ck": ck, "cv": cv}
                 if collect_cache else None)
        return x, cache

    body = _remat(period_fn, cfg)
    if cfg.unroll_stack:
        from repro.models.transformer import _unrolled_scan
        x, caches = _unrolled_scan(body, x, params["dec_scan"],
                                   cfg.num_decoder_layers)
        if not collect_cache:
            caches = None
    else:
        x, caches = jax.lax.scan(lambda c, p: body(c, p), x,
                                 params["dec_scan"])
    return rms_norm(x, params["final_norm"], cfg.norm_eps), caches


def encdec_loss(params, batch, cfg: ModelConfig, mesh: Mesh, axes: MeshAxes):
    ctx = Ctx(cfg, mesh, axes)
    enc_out = run_encoder(params, batch["audio_embed"], cfg, ctx)
    x, _ = run_decoder(params, batch["tokens"], enc_out, cfg, ctx)
    loss, denom = masked_cross_entropy(params, x, batch["targets"], cfg, ctx)
    return loss, {"ce_loss": loss, "tokens": denom}


def encdec_prefill(params, batch, cfg: ModelConfig, mesh: Mesh, axes: MeshAxes):
    ctx = Ctx(cfg, mesh, axes)
    enc_out = run_encoder(params, batch["audio_embed"], cfg, ctx)
    x, caches = run_decoder(params, batch["tokens"], enc_out, cfg, ctx,
                            collect_cache=True)
    logits = unembed(params, x[:, -1:], cfg)
    return caches, logits


def encdec_decode(params, caches, token, pos, cfg: ModelConfig, mesh: Mesh,
                  axes: MeshAxes):
    """token: (B,1).  caches: stacked {'sk','sv','ck','cv'} over layers."""
    ctx = Ctx(cfg, mesh, axes)
    x = embed_tokens(params, token, cfg)

    def body(x, scanned):
        p, cache = scanned
        blk = p["b0"]
        h = rms_norm(x, blk["norm_self"], cfg.norm_eps)
        a, sk, sv = attn.attention_decode(blk["self_attn"], h, cache["sk"],
                                          cache["sv"], pos, cfg,
                                          mask_kind="causal")
        x = x + a
        h = rms_norm(x, blk["norm_cross"], cfg.norm_eps)
        a, _, _ = attn.attention_decode(blk["cross_attn"], h, cache["ck"],
                                        cache["cv"], pos, cfg,
                                        mask_kind="none", cross=True)
        x = x + a
        h = rms_norm(x, blk["norm_mlp"], cfg.norm_eps)
        x = x + mlp_forward(blk["mlp"], h, cfg.act)
        return x, {"sk": sk, "sv": sv, "ck": cache["ck"], "cv": cache["cv"]}

    if cfg.unroll_stack:
        from repro.models.transformer import _unrolled_scan
        x, new_caches = _unrolled_scan(body, x, (params["dec_scan"], caches),
                                       cfg.num_decoder_layers)
    else:
        x, new_caches = jax.lax.scan(body, x, (params["dec_scan"], caches))
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    return new_caches, unembed(params, x, cfg)
