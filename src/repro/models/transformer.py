"""Decoder-only LM assembly shared by all assigned architectures.

Layer stacks are ``jax.lax.scan``s over stacked period params (period = the
repeating ``block_pattern``; gemma2 = (local, full), recurrentgemma =
(rglru, rglru, local)); layers beyond the last full period are unrolled
("tail").  This keeps HLO size O(1) in depth, which matters for both compile
time and the dry-run.

Three paths per architecture: ``lm_loss`` (training), ``lm_prefill`` and
``lm_decode`` (serving with per-family state: KV cache for attention blocks,
(B,H,hd,hd) WKV state for rwkv6, (h, conv-tail) for rglru).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.configs.base import (BLOCK_ATTN, BLOCK_LOCAL, BLOCK_MOE, BLOCK_REC,
                                BLOCK_RWKV, ModelConfig)
from repro.models import attention as attn
from repro.models import moe as moe_lib
from repro.models import rglru as rglru_lib
from repro.models import rwkv6 as rwkv_lib
from repro.models.layers import (LP, dense_init, init_mlp, is_lp, mlp_forward,
                                 rms_norm, softcap, zeros_init)
from repro.sharding import MeshAxes, constrain


@dataclasses.dataclass
class Ctx:
    cfg: ModelConfig
    mesh: Mesh
    axes: MeshAxes

    @property
    def bspec(self):
        return self.axes.batch if len(self.axes.batch) > 1 else self.axes.batch[0]

    def bconstrain(self, x):
        """Constrain (B, S, d) activations: batch-sharded, rest replicated."""
        return constrain(x, self.mesh, P(self.bspec, *([None] * (x.ndim - 1))))


# ---------------------------------------------------------------------- init
def init_block(key, kind: str, cfg: ModelConfig, dtype=jnp.bfloat16):
    k1, k2, k3, k4 = jax.random.split(key, 4)
    p: Dict[str, Any] = {
        "norm_attn": zeros_init((cfg.d_model,), ("embed",), dtype=jnp.float32),
        "norm_mlp": zeros_init((cfg.d_model,), ("embed",), dtype=jnp.float32),
    }
    if kind in (BLOCK_ATTN, BLOCK_LOCAL, BLOCK_MOE):
        p["attn"] = attn.init_attention(k1, cfg, dtype=dtype)
    if kind == BLOCK_MOE:
        p["moe"] = moe_lib.init_moe(k2, cfg, dtype=dtype)
    elif kind == BLOCK_RWKV:
        p["time_mix"] = rwkv_lib.init_time_mix(k1, cfg, dtype=dtype)
        p["channel_mix"] = rwkv_lib.init_channel_mix(k2, cfg, dtype=dtype)
    elif kind == BLOCK_REC:
        p["rec"] = rglru_lib.init_rglru_block(k3, cfg, dtype=dtype)
        p["mlp"] = init_mlp(k4, cfg.d_model, cfg.d_ff, dtype=dtype)
    else:
        p["mlp"] = init_mlp(k4, cfg.d_model, cfg.d_ff, dtype=dtype)
    return p


def init_period(key, kinds, cfg: ModelConfig, dtype=jnp.bfloat16):
    keys = jax.random.split(key, len(kinds))
    return {f"b{i}": init_block(k, kind, cfg, dtype=dtype)
            for i, (k, kind) in enumerate(zip(keys, kinds))}


def stack_periods(trees):
    """List of per-period LP trees -> single tree with leading 'layers' axis."""
    def stack_lp(*lps):
        vals = jnp.stack([p.value for p in lps])
        return LP(vals, ("layers",) + lps[0].axes)
    return jax.tree.map(stack_lp, *trees, is_leaf=is_lp)


def split_layers(cfg: ModelConfig, num_layers: Optional[int] = None):
    n = num_layers if num_layers is not None else cfg.num_layers
    period = cfg.pattern_period
    n_periods = n // period
    tail_kinds = cfg.layer_kinds(n)[n_periods * period:]
    return n_periods, tuple(tail_kinds)


def init_lm(key, cfg: ModelConfig, dtype=jnp.bfloat16):
    """Full LM param LP-tree (decoder-only archs)."""
    keys = jax.random.split(key, 8)
    n_periods, tail_kinds = split_layers(cfg)
    period_keys = jax.random.split(keys[0], n_periods)
    params: Dict[str, Any] = {
        "embed": dense_init(keys[1], (cfg.vocab_size, cfg.d_model),
                            ("vocab", "embed"), in_axis=1, scale=1.0,
                            dtype=dtype),
        "scan": stack_periods([
            init_period(k, cfg.block_pattern, cfg, dtype) for k in period_keys]),
        "final_norm": zeros_init((cfg.d_model,), ("embed",), dtype=jnp.float32),
    }
    if tail_kinds:
        tkeys = jax.random.split(keys[2], len(tail_kinds))
        params["tail"] = {f"t{i}": init_block(k, kind, cfg, dtype)
                          for i, (k, kind) in enumerate(zip(tkeys, tail_kinds))}
    if not cfg.tie_embeddings:
        params["lm_head"] = dense_init(keys[3], (cfg.d_model, cfg.vocab_size),
                                       ("embed", "vocab"), dtype=dtype)
    return params


# ------------------------------------------------------------------- forward
def block_train(p, kind: str, x, positions, ctx: Ctx, return_kv=False):
    """One block, full-sequence.  Returns (x, stats, kv_or_None)."""
    cfg = ctx.cfg
    stats = {}
    kv = None
    if kind in (BLOCK_ATTN, BLOCK_LOCAL, BLOCK_MOE):
        mask_kind = "local" if kind == BLOCK_LOCAL else "causal"
        h = rms_norm(x, p["norm_attn"], cfg.norm_eps)
        a, k_c, v_c = attn.attention_forward_kv(
            p["attn"], h, cfg, mask_kind=mask_kind, positions=positions)
        if return_kv:
            kv = (k_c, v_c)
        x = x + a
        h = rms_norm(x, p["norm_mlp"], cfg.norm_eps)
        if kind == BLOCK_MOE:
            y, stats = moe_lib.moe_forward(p["moe"], h, cfg, ctx.mesh, ctx.axes,
                                           cfg.act)
        else:
            y = mlp_forward(p["mlp"], h, cfg.act)
        x = x + y
    elif kind == BLOCK_RWKV:
        h = rms_norm(x, p["norm_attn"], cfg.norm_eps)
        y, (wkv_state, tm_last) = rwkv_lib.time_mix_forward(p["time_mix"], h, cfg)
        x = x + y
        h = rms_norm(x, p["norm_mlp"], cfg.norm_eps)
        y, cm_last = rwkv_lib.channel_mix_forward(p["channel_mix"], h)
        x = x + y
        if return_kv:
            kv = (wkv_state, tm_last, cm_last)
    elif kind == BLOCK_REC:
        h = rms_norm(x, p["norm_attn"], cfg.norm_eps)
        y, (h_last, conv_tail) = rglru_lib.rglru_block_forward(p["rec"], h, cfg)
        x = x + y
        h = rms_norm(x, p["norm_mlp"], cfg.norm_eps)
        x = x + mlp_forward(p["mlp"], h, cfg.act)
        if return_kv:
            kv = (h_last, conv_tail)
    else:
        raise ValueError(kind)
    return ctx.bconstrain(x), stats, kv


def block_decode(p, kind: str, x, cache, pos, ctx: Ctx):
    """One block, one-token decode.  cache is the per-block state entry."""
    cfg = ctx.cfg
    if kind in (BLOCK_ATTN, BLOCK_LOCAL, BLOCK_MOE):
        mask_kind = "local" if kind == BLOCK_LOCAL else "causal"
        ring = kind == BLOCK_LOCAL and cfg.window_kv_cache
        h = rms_norm(x, p["norm_attn"], cfg.norm_eps)
        a, ck, cv = attn.attention_decode(p["attn"], h, cache["k"], cache["v"],
                                          pos, cfg, mask_kind=mask_kind,
                                          ring=ring)
        new_cache = {"k": ck, "v": cv}
        x = x + a
        h = rms_norm(x, p["norm_mlp"], cfg.norm_eps)
        if kind == BLOCK_MOE:
            y, _ = moe_lib.moe_forward(p["moe"], h, cfg, ctx.mesh, ctx.axes,
                                       cfg.act)
        else:
            y = mlp_forward(p["mlp"], h, cfg.act)
        x = x + y
    elif kind == BLOCK_RWKV:
        h = rms_norm(x, p["norm_attn"], cfg.norm_eps)
        y, (wkv, tm_last) = rwkv_lib.time_mix_step(
            p["time_mix"], h, cache["wkv"], cache["tm_shift"], cfg)
        x = x + y
        h = rms_norm(x, p["norm_mlp"], cfg.norm_eps)
        y, cm_last = rwkv_lib.channel_mix_forward(p["channel_mix"], h,
                                                  prev_x=cache["cm_shift"])
        x = x + y
        new_cache = {"wkv": wkv, "tm_shift": tm_last, "cm_shift": cm_last}
    elif kind == BLOCK_REC:
        h = rms_norm(x, p["norm_attn"], cfg.norm_eps)
        y, (h_last, tail) = rglru_lib.rglru_block_forward(
            p["rec"], h, cfg, state=(cache["h"], cache["conv"]))
        x = x + y
        h = rms_norm(x, p["norm_mlp"], cfg.norm_eps)
        x = x + mlp_forward(p["mlp"], h, cfg.act)
        new_cache = {"h": h_last, "conv": tail}
    else:
        raise ValueError(kind)
    return x, new_cache


def _merge_stats(stats_list):
    out: Dict[str, Any] = {}
    for st in stats_list:
        for k, v in st.items():
            out[k] = out[k] + v if k in out else v
    return out


def _remat(fn, cfg: ModelConfig):
    if not cfg.remat or cfg.remat_policy == "none":
        return fn
    if cfg.remat_policy == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    return jax.checkpoint(fn)


def _unrolled_scan(body, carry, xs, n_steps: int):
    """lax.scan semantics with a python loop (dry-run flop-count accuracy:
    XLA's cost analysis visits a while body once, see ModelConfig)."""
    ys = []
    for i in range(n_steps):
        x_i = jax.tree.map(lambda a: a[i], xs)
        carry, y = body(carry, x_i)
        ys.append(y)
    stacked = jax.tree.map(lambda *ls: jnp.stack(ls), *ys) if ys else {}
    return carry, stacked


def run_stack(params, x, positions, ctx: Ctx, kinds, n_periods, tail_kinds,
              collect_cache: bool = False):
    """Scan over periods + unrolled tail.  Returns (x, stats, caches)."""
    def period_fn(x, p_period):
        stats, caches = [], {}
        for i, kind in enumerate(kinds):
            x, st, kv = block_train(p_period[f"b{i}"], kind, x, positions, ctx,
                                    return_kv=collect_cache)
            stats.append(st)
            if collect_cache:
                caches[f"b{i}"] = _pack_cache(kind, kv)
        return x, (_merge_stats(stats), caches)

    body = _remat(period_fn, ctx.cfg)
    if ctx.cfg.unroll_stack:
        x, (stats, caches) = _unrolled_scan(body, x, params["scan"], n_periods)
    else:
        x, (stats, caches) = jax.lax.scan(
            lambda c, p: body(c, p), x, params["scan"])
    # scan stacks stats over periods: total the aux loss, keep per-layer counts.
    if "aux_loss" in stats:
        stats = {"aux_loss": stats["aux_loss"].sum(),
                 "expert_counts": stats["expert_counts"]}
    tail_caches = {}
    for i, kind in enumerate(tail_kinds):
        x, st, kv = block_train(params["tail"][f"t{i}"], kind, x, positions,
                                ctx, return_kv=collect_cache)
        stats = _merge_stats([stats, st])
        if collect_cache:
            tail_caches[f"t{i}"] = _pack_cache(kind, kv)
    return x, stats, {"scan": caches, "tail": tail_caches}


def _pack_cache(kind: str, kv):
    if kind in (BLOCK_ATTN, BLOCK_LOCAL, BLOCK_MOE):
        return {"k": kv[0], "v": kv[1]}
    if kind == BLOCK_RWKV:
        return {"wkv": kv[0], "tm_shift": kv[1], "cm_shift": kv[2]}
    if kind == BLOCK_REC:
        return {"h": kv[0], "conv": kv[1]}
    raise ValueError(kind)


# ----------------------------------------------------------------- embedding
def embed_tokens(params, tokens, cfg: ModelConfig):
    x = jnp.take(params["embed"], tokens, axis=0)
    if cfg.tie_embeddings:
        x = x * jnp.sqrt(jnp.float32(cfg.d_model)).astype(x.dtype)
    return x


def unembed(params, x, cfg: ModelConfig):
    table = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = jnp.einsum("bsd,dv->bsv", x, table).astype(jnp.float32)
    return softcap(logits, cfg.final_softcap)


def lm_inputs(params, batch, cfg: ModelConfig):
    """Token (+ stub-frontend media/audio) embedding -> (x, positions)."""
    tokens = batch["tokens"]
    x = embed_tokens(params, tokens, cfg)
    if cfg.frontend == "vision" and "media_embed" in batch:
        x = jnp.concatenate([batch["media_embed"].astype(x.dtype), x], axis=1)
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s)[None, :], (b, s))
    return x, positions


# -------------------------------------------------------------------- losses
def _ce_piece(x, targets, table, cfg: ModelConfig, ctx: Ctx):
    """(nll_sum, token_count) over one sequence piece."""
    logits = jnp.einsum("bsd,dv->bsv", x, table).astype(jnp.float32)
    logits = softcap(logits, cfg.final_softcap)
    logits = constrain(logits, ctx.mesh, P(ctx.bspec, None, "model"))
    lse = jax.nn.logsumexp(logits, axis=-1)  # (B,S)
    mask = (targets >= 0)
    safe = jnp.maximum(targets, 0)
    lbl_w = jnp.take(table, safe, axis=1)            # (d, B, S)
    lbl_logit = jnp.einsum("bsd,dbs->bs", x, lbl_w).astype(jnp.float32)
    lbl_logit = softcap(lbl_logit, cfg.final_softcap)
    nll = (lse - lbl_logit) * mask
    return nll.sum(), mask.sum()


def masked_cross_entropy(params, x, targets, cfg: ModelConfig, ctx: Ctx):
    """CE over the vocab without materializing a one-hot: logsumexp - label
    logit (label logits via an lm_head gather, SPMD-friendly).

    With cfg.ce_chunk > 0 the sequence is processed in chunks so the f32
    (B, chunk, V) logits tile replaces the full (B, S, V) residency (§Perf
    memory-term optimization)."""
    table = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    s = x.shape[1]
    if cfg.ce_chunk and s > cfg.ce_chunk:
        nll_total = jnp.float32(0.0)
        count = jnp.int32(0)
        for lo in range(0, s, cfg.ce_chunk):
            hi = min(lo + cfg.ce_chunk, s)
            nll, cnt = _ce_piece(x[:, lo:hi], targets[:, lo:hi], table, cfg,
                                 ctx)
            nll_total = nll_total + nll
            count = count + cnt
        denom = jnp.maximum(count, 1)
        return nll_total / denom, denom
    nll, cnt = _ce_piece(x, targets, table, cfg, ctx)
    denom = jnp.maximum(cnt, 1)
    return nll / denom, denom


def lm_loss(params, batch, cfg: ModelConfig, mesh: Mesh, axes: MeshAxes):
    ctx = Ctx(cfg, mesh, axes)
    x, positions = lm_inputs(params, batch, cfg)
    x = ctx.bconstrain(x)
    n_periods, tail_kinds = split_layers(cfg)
    x, stats, _ = run_stack(params, x, positions, ctx, cfg.block_pattern,
                            n_periods, tail_kinds)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    targets = batch["targets"]
    if cfg.frontend == "vision" and "media_embed" in batch:
        pad = -jnp.ones((targets.shape[0], batch["media_embed"].shape[1]),
                        targets.dtype)
        targets = jnp.concatenate([pad, targets], axis=1)
    loss, denom = masked_cross_entropy(params, x, targets, cfg, ctx)
    metrics = {"ce_loss": loss, "tokens": denom}
    if "aux_loss" in stats:
        aux = 0.01 * stats["aux_loss"]
        metrics["moe_aux_loss"] = stats["aux_loss"]
        metrics["expert_counts"] = stats["expert_counts"]
        loss = loss + aux
    return loss, metrics


# ------------------------------------------------------------------- serving
def lm_prefill(params, batch, cfg: ModelConfig, mesh: Mesh, axes: MeshAxes):
    """Prompt pass: returns (cache, last-position logits)."""
    ctx = Ctx(cfg, mesh, axes)
    x, positions = lm_inputs(params, batch, cfg)
    x = ctx.bconstrain(x)
    n_periods, tail_kinds = split_layers(cfg)
    x, _, caches = run_stack(params, x, positions, ctx, cfg.block_pattern,
                             n_periods, tail_kinds, collect_cache=True)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = unembed(params, x[:, -1:], cfg)
    return caches, logits


def lm_decode(params, caches, token, pos, cfg: ModelConfig, mesh: Mesh,
              axes: MeshAxes):
    """One-token decode.  token: (B,1) int32; pos: int32 scalar."""
    ctx = Ctx(cfg, mesh, axes)
    x = embed_tokens(params, token, cfg)
    n_periods, tail_kinds = split_layers(cfg)

    def body(x, scanned):
        p_period, cache_period = scanned
        new_caches = {}
        for i, kind in enumerate(cfg.block_pattern):
            x, nc = block_decode(p_period[f"b{i}"], kind, x,
                                 cache_period[f"b{i}"], pos, ctx)
            new_caches[f"b{i}"] = nc
        return x, new_caches

    if cfg.unroll_stack:
        x, new_scan = _unrolled_scan(body, x, (params["scan"], caches["scan"]),
                                     n_periods)
    else:
        x, new_scan = jax.lax.scan(body, x, (params["scan"], caches["scan"]))
    new_tail = {}
    for i, kind in enumerate(tail_kinds):
        x, nc = block_decode(params["tail"][f"t{i}"], kind, x,
                             caches["tail"][f"t{i}"], pos, ctx)
        new_tail[f"t{i}"] = nc
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = unembed(params, x, cfg)
    return {"scan": new_scan, "tail": new_tail}, logits
