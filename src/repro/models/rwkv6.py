"""RWKV6 ("Finch") block: data-dependent token-shift + WKV6 recurrence with
per-channel data-dependent decay, plus squared-ReLU channel mix.
[arXiv:2404.05892]

Training uses a chunked form (lax.scan over chunks; within-chunk pairwise
contraction in f32 log-decay space) so the HLO stays compact and stable; the
Pallas kernel (repro.kernels.rwkv6) mirrors the same chunking for TPU.  Decode
is the O(1)-state recurrence — the "KV cache" of this family is a constant
(B, H, hd, hd) state regardless of sequence length, which is why rwkv6 runs
the long_500k cell.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import LP, dense_init, group_norm, zeros_init

MIX_LORA = 32
DECAY_LORA = 64
_MIX_NAMES = ("r", "k", "v", "w", "g")


def init_time_mix(key, cfg: ModelConfig, dtype=jnp.bfloat16):
    d = cfg.d_model
    h = d // cfg.rwkv_head_dim
    hd = cfg.rwkv_head_dim
    rnn = "rnn" if cfg.shard_rnn else None  # §Perf: collective/compute trade
    ks = jax.random.split(key, 12)
    return {
        "mu_x": zeros_init((d,), ("embed",), dtype=jnp.float32),
        "mu": zeros_init((5, d), (None, "embed"), dtype=jnp.float32),
        "mix_a": dense_init(ks[0], (d, 5 * MIX_LORA), ("embed", "lora"),
                            scale=0.1, dtype=jnp.float32),
        "mix_b": zeros_init((5, MIX_LORA, d), (None, "lora", "embed"),
                            dtype=jnp.float32),
        "w0": LP(jnp.full((h, hd), -6.0, jnp.float32), (rnn, "head_dim")),
        "w_a": dense_init(ks[1], (d, DECAY_LORA), ("embed", "lora"),
                          scale=0.1, dtype=jnp.float32),
        "w_b": zeros_init((DECAY_LORA, d), ("lora", "embed"), dtype=jnp.float32),
        "u": zeros_init((h, hd), (rnn, "head_dim"), dtype=jnp.float32),
        "w_r": dense_init(ks[2], (d, d), ("embed", rnn), dtype=dtype),
        "w_k": dense_init(ks[3], (d, d), ("embed", rnn), dtype=dtype),
        "w_v": dense_init(ks[4], (d, d), ("embed", rnn), dtype=dtype),
        "w_g": dense_init(ks[5], (d, d), ("embed", rnn), dtype=dtype),
        "w_o": dense_init(ks[6], (d, d), (rnn, "embed"), dtype=dtype),
        "ln_w": LP(jnp.ones((d,), jnp.float32), (rnn,)),
        "ln_b": zeros_init((d,), (rnn,), dtype=jnp.float32),
    }


def init_channel_mix(key, cfg: ModelConfig, dtype=jnp.bfloat16):
    d, f = cfg.d_model, cfg.d_ff
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "mu_k": zeros_init((d,), ("embed",), dtype=jnp.float32),
        "mu_r": zeros_init((d,), ("embed",), dtype=jnp.float32),
        "w_k": dense_init(k1, (d, f), ("embed", "mlp"), dtype=dtype),
        "w_v": dense_init(k2, (f, d), ("mlp", "embed"), dtype=dtype),
        "w_r": dense_init(k3, (d, d), ("embed", "embed"), dtype=dtype),
    }


def _token_shift(x, prev=None):
    """Shift sequence right by one; ``prev`` (B, d) fills slot 0 (decode carry)."""
    if prev is None:
        pad = jnp.zeros_like(x[:, :1])
    else:
        pad = prev[:, None, :].astype(x.dtype)
    return jnp.concatenate([pad, x[:, :-1]], axis=1)


def _ddlerp(p, x, shifted):
    """RWKV6 data-dependent interpolation -> (5, B, S, d) mixed inputs."""
    dx = (shifted - x).astype(jnp.float32)
    xf = x.astype(jnp.float32)
    xxx = xf + dx * p["mu_x"]
    lora = jnp.tanh(xxx @ p["mix_a"])  # (B,S,5*r)
    b, s, _ = lora.shape
    lora = lora.reshape(b, s, 5, MIX_LORA)
    delta = jnp.einsum("bsnr,nrd->nbsd", lora, p["mix_b"])
    mixed = xf[None] + dx[None] * (p["mu"][:, None, None, :] + delta)
    return mixed  # f32


def _projections(p, x, shifted, cfg: ModelConfig):
    mixed = _ddlerp(p, x, shifted)
    xr, xk, xv, xw, xg = [mixed[i].astype(x.dtype) for i in range(5)]
    b, s, d = x.shape
    h, hd = d // cfg.rwkv_head_dim, cfg.rwkv_head_dim
    r = (xr @ p["w_r"]).reshape(b, s, h, hd)
    k = (xk @ p["w_k"]).reshape(b, s, h, hd)
    v = (xv @ p["w_v"]).reshape(b, s, h, hd)
    g = jax.nn.silu(xg @ p["w_g"])
    # data-dependent log-decay, guaranteed < 0 (w = exp(-exp(z)))
    z = p["w0"].reshape(-1) + (jnp.tanh(xw @ p["w_a"]) @ p["w_b"])
    log_w = -jnp.exp(jnp.clip(z, -20.0, 8.0)).reshape(b, s, h, hd)
    return r, k, v, g, log_w


def _pick_chunk(s: int, target: int) -> int:
    """Largest divisor of s that is <= target (sequence lengths are usually
    powers of two; odd prompt lengths degrade gracefully)."""
    c = min(target, s)
    while s % c:
        c -= 1
    return max(c, 1)


def wkv6_chunked(r, k, v, log_w, u, chunk: int = 16):
    """Chunked WKV6.  r,k,v,log_w: (B,S,H,hd) — returns (B,S,H,hd), final state.

    Within a chunk all decay factors appear as exp(non-positive) ratios, so the
    computation is stable in f32 without log-space matmuls.
    """
    b, s, h, hd = r.shape
    chunk = _pick_chunk(s, chunk)
    nc = s // chunk
    rf = r.astype(jnp.float32).reshape(b, nc, chunk, h, hd)
    kf = k.astype(jnp.float32).reshape(b, nc, chunk, h, hd)
    vf = v.astype(jnp.float32).reshape(b, nc, chunk, h, hd)
    lw = log_w.astype(jnp.float32).reshape(b, nc, chunk, h, hd)

    state0 = jnp.zeros((b, h, hd, hd), jnp.float32)

    def step(state, inputs):
        rc, kc, vc, lwc = inputs  # (B, c, H, hd)
        cs = jnp.cumsum(lwc, axis=1)            # inclusive (B,c,H,hd)
        cse = cs - lwc                          # exclusive
        # inter-chunk: y1[t] = (r_t * exp(cse_t)) @ state
        q1 = rc * jnp.exp(cse)
        y1 = jnp.einsum("bthk,bhkv->bthv", q1, state)
        # intra-chunk: pair[t,s,i] = r_t[i] k_s[i] exp(cse_t - cs_s), s<t
        ratio = cse[:, :, None] - cs[:, None, :]          # (B,t,s,H,hd)
        tri = jnp.tril(jnp.ones((chunk, chunk), jnp.float32), -1)
        pair = rc[:, :, None] * kc[:, None, :] * jnp.exp(
            jnp.minimum(ratio, 0.0))
        scores = pair.sum(-1) * tri[None, :, :, None]     # (B,t,s,H)
        y2 = jnp.einsum("btsh,bshv->bthv", scores, vc)
        # diagonal (current-token bonus u)
        diag = (rc * u[None, None] * kc).sum(-1, keepdims=True) * vc
        # state update
        decay_to_end = jnp.exp(cs[:, -1:] - cs)           # (B,c,H,hd)
        new_state = state * jnp.exp(cs[:, -1])[:, :, :, None] + jnp.einsum(
            "bshk,bshv->bhkv", kc * decay_to_end, vc)
        return new_state, y1 + y2 + diag

    inputs = tuple(jnp.moveaxis(t, 1, 0) for t in (rf, kf, vf, lw))
    state, y = jax.lax.scan(step, state0, inputs)
    y = jnp.moveaxis(y, 0, 1).reshape(b, s, h, hd)
    return y, state


def wkv6_step(state, r, k, v, log_w, u):
    """O(1) decode step.  state: (B,H,hd,hd); r,k,v,log_w: (B,H,hd)."""
    sf = state
    rf, kf, vf = (t.astype(jnp.float32) for t in (r, k, v))
    # y[j] = sum_i r_i (S[i,j] + u_i k_i v_j)
    y = jnp.einsum("bhk,bhkv->bhv", rf, sf) + (
        (rf * u[None] * kf).sum(-1, keepdims=True) * vf)
    new_state = sf * jnp.exp(log_w.astype(jnp.float32))[..., None] + (
        kf[..., :, None] * vf[..., None, :])
    return new_state, y


def time_mix_forward(p, x, cfg: ModelConfig, chunk: int = 16):
    """Training/prefill path.  x: (B,S,d) -> (B,S,d), final (state, last_x)."""
    b, s, d = x.shape
    h, hd = d // cfg.rwkv_head_dim, cfg.rwkv_head_dim
    shifted = _token_shift(x)
    r, k, v, g, log_w = _projections(p, x, shifted, cfg)
    y, state = wkv6_chunked(r, k, v, log_w, p["u"], chunk=chunk)
    y = y.reshape(b, s, d)
    y = group_norm(y.astype(x.dtype), p["ln_w"], p["ln_b"], num_groups=h)
    y = (y.astype(jnp.float32) * g).astype(x.dtype)
    return y @ p["w_o"], (state, x[:, -1, :])


def time_mix_step(p, x, state, prev_x, cfg: ModelConfig):
    """Decode step.  x: (B,1,d); state: (B,H,hd,hd); prev_x: (B,d)."""
    b, _, d = x.shape
    h = d // cfg.rwkv_head_dim
    shifted = _token_shift(x, prev=prev_x)
    r, k, v, g, log_w = _projections(p, x, shifted, cfg)
    new_state, y = wkv6_step(state, r[:, 0], k[:, 0], v[:, 0], log_w[:, 0],
                             p["u"])
    y = y.reshape(b, 1, d)
    y = group_norm(y.astype(x.dtype), p["ln_w"], p["ln_b"], num_groups=h)
    y = (y.astype(jnp.float32) * g).astype(x.dtype)
    return y @ p["w_o"], (new_state, x[:, -1, :])


def channel_mix_forward(p, x, prev_x=None):
    """Squared-relu channel mix.  Returns (out, last_x carry)."""
    shifted = _token_shift(x, prev=prev_x)
    dx = (shifted - x).astype(jnp.float32)
    xf = x.astype(jnp.float32)
    xk = (xf + dx * p["mu_k"]).astype(x.dtype)
    xr = (xf + dx * p["mu_r"]).astype(x.dtype)
    kk = jnp.square(jax.nn.relu(xk @ p["w_k"]))
    out = jax.nn.sigmoid((xr @ p["w_r"]).astype(jnp.float32)).astype(x.dtype) \
        * (kk @ p["w_v"])
    return out, x[:, -1, :]
