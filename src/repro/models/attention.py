"""GQA attention: full/sliding-window masks, logit softcap, cross-attention,
and decode with an updatable KV cache.

The jnp path here is the lowering used by the dry-run and CPU smoke tests; the
Pallas flash kernel (repro.kernels.flash) implements the same math for TPU and
is validated against it in tests.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import LP, apply_rope, dense_init, softcap


def init_attention(key, cfg: ModelConfig, dtype=jnp.bfloat16):
    kq, kk, kv, ko = jax.random.split(key, 4)
    d, h, hkv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    return {
        "w_q": dense_init(kq, (d, h, hd), ("embed", "heads", "head_dim"), dtype=dtype),
        "w_k": dense_init(kk, (d, hkv, hd), ("embed", "kv_heads", "head_dim"), dtype=dtype),
        "w_v": dense_init(kv, (d, hkv, hd), ("embed", "kv_heads", "head_dim"), dtype=dtype),
        "w_o": dense_init(ko, (h, hd, d), ("heads", "head_dim", "embed"),
                          in_axis=(0, 1), dtype=dtype),
    }


def _mask_bias(q_pos, k_pos, kind: str, window: int):
    """(q, k) additive mask bias in f32.  q_pos: (...,Sq), k_pos: (...,Sk)."""
    q = q_pos[..., :, None]
    k = k_pos[..., None, :]
    if kind == "causal":
        ok = k <= q
    elif kind == "local":
        ok = (k <= q) & (k > q - window)
    elif kind == "none":
        ok = jnp.ones(jnp.broadcast_shapes(q.shape, k.shape), bool)
    else:
        raise ValueError(kind)
    return jnp.where(ok, 0.0, -1e30).astype(jnp.float32)


def _sdpa(q, k, v, bias, logit_cap: float):
    """q: (B,Sq,H,hd)  k,v: (B,Sk,Hkv,hd)  bias: broadcastable (B,1,Sq,Sk)."""
    b, sq, h, hd = q.shape
    hkv = k.shape[2]
    g = h // hkv
    q = q.reshape(b, sq, hkv, g, hd)
    scores = jnp.einsum("bqhgd,bkhd->bhgqk", q, k).astype(jnp.float32)
    scores = scores / jnp.sqrt(jnp.float32(hd))
    scores = softcap(scores, logit_cap)
    scores = scores + bias[:, :, None, :, :]
    probs = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", probs, v)
    return out.reshape(b, sq, h, hd)


def _sdpa_chunked(q, k, v, bias, logit_cap: float, kv_chunk: int):
    """Flash-style online-softmax over KV chunks in the XLA path (§Perf:
    the (Sq, Sk) score tile never exceeds (Sq, kv_chunk)).  Python loop so
    the dry-run cost accounting stays exact (see ModelConfig.unroll_stack).
    """
    b, sq, h, hd = q.shape
    sk = k.shape[1]
    hkv = k.shape[2]
    g = h // hkv
    qf = q.reshape(b, sq, hkv, g, hd)
    scale = 1.0 / jnp.sqrt(jnp.float32(hd))
    m = jnp.full((b, hkv, g, sq, 1), -1e30, jnp.float32)
    l = jnp.zeros((b, hkv, g, sq, 1), jnp.float32)
    acc = jnp.zeros((b, hkv, g, sq, hd), jnp.float32)
    n_chunks = (sk + kv_chunk - 1) // kv_chunk
    for ci in range(n_chunks):
        lo = ci * kv_chunk
        hi = min(lo + kv_chunk, sk)
        kc = k[:, lo:hi]
        vc = v[:, lo:hi]
        bias_c = bias[:, :, :, lo:hi]
        s = jnp.einsum("bqhgd,bkhd->bhgqk", qf, kc).astype(jnp.float32)
        s = softcap(s * scale, logit_cap) + bias_c[:, :, None, :, :]
        m_cur = s.max(-1, keepdims=True)
        m_new = jnp.maximum(m, m_cur)
        p = jnp.exp(s - m_new)
        p = jnp.where(s <= -1e29, 0.0, p)
        corr = jnp.exp(m - m_new)
        l = corr * l + p.sum(-1, keepdims=True)
        acc = acc * corr + jnp.einsum("bhgqk,bkhd->bhgqd", p,
                                      vc.astype(jnp.float32))
        m = m_new
    out = acc / jnp.where(l == 0.0, 1.0, l)
    out = jnp.moveaxis(out, 3, 1)  # (b, sq, hkv, g, hd)
    return out.reshape(b, sq, h, hd).astype(v.dtype)


def attention_forward_kv(params, x, cfg: ModelConfig, *, mask_kind: str,
                         positions, kv_x=None, kv_positions=None):
    """Training/prefill attention.  ``kv_x`` set => cross-attention.

    Returns (out, k, v) so prefill can populate the KV cache for free.
    """
    kv_in = x if kv_x is None else kv_x
    q = jnp.einsum("bsd,dhe->bshe", x, params["w_q"])
    k = jnp.einsum("bsd,dhe->bshe", kv_in, params["w_k"])
    v = jnp.einsum("bsd,dhe->bshe", kv_in, params["w_v"])
    if kv_x is None:  # self-attention -> RoPE
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
        kv_pos = positions
    else:
        kv_pos = kv_positions
    bias = _mask_bias(positions, kv_pos, mask_kind, cfg.window_size)[:, None]
    if cfg.attn_kv_chunk and k.shape[1] > cfg.attn_kv_chunk:
        out = _sdpa_chunked(q, k, v, bias, cfg.logit_softcap,
                            cfg.attn_kv_chunk)
    else:
        out = _sdpa(q, k, v, bias, cfg.logit_softcap)
    return jnp.einsum("bshe,hed->bsd", out, params["w_o"]), k, v


def attention_forward(params, x, cfg: ModelConfig, *, mask_kind: str,
                      positions, kv_x=None, kv_positions=None):
    out, _, _ = attention_forward_kv(params, x, cfg, mask_kind=mask_kind,
                                     positions=positions, kv_x=kv_x,
                                     kv_positions=kv_positions)
    return out


# ------------------------------------------------------------------- decode
def init_kv_cache(cfg: ModelConfig, num_layers: int, batch: int, max_len: int,
                  dtype=jnp.bfloat16):
    shape = (num_layers, batch, max_len, cfg.num_kv_heads, cfg.head_dim)
    return {
        "k": jnp.zeros(shape, dtype),
        "v": jnp.zeros(shape, dtype),
    }


def kv_cache_spec(cfg: ModelConfig, num_layers: int, batch: int, max_len: int,
                  dtype=jnp.bfloat16):
    shape = (num_layers, batch, max_len, cfg.num_kv_heads, cfg.head_dim)
    return {
        "k": jax.ShapeDtypeStruct(shape, dtype),
        "v": jax.ShapeDtypeStruct(shape, dtype),
    }


def attention_decode(params, x, cache_k, cache_v, pos, cfg: ModelConfig, *,
                     mask_kind: str, cross: bool = False, ring: bool = False):
    """One-token decode.  x: (B,1,d); cache_{k,v}: (B,S,Hkv,hd); pos: scalar.

    For ``cross=True`` the caches hold precomputed encoder K/V and are not
    updated; ``pos`` masks nothing (full visibility).

    ``ring=True`` (local_attn + cfg.window_kv_cache, §Perf): the cache holds
    only ``window`` slots; position p lives in slot p % window.  K is stored
    with RoPE already applied at its true position, so ring indexing only
    changes the masking: slot s currently holds position
    pos - ((pos - s) mod window), masked out while still negative.

    Returns (out, new_cache_k, new_cache_v).
    """
    b = x.shape[0]
    s_max = cache_k.shape[1]
    q = jnp.einsum("bsd,dhe->bshe", x, params["w_q"])
    if not cross:
        k_new = jnp.einsum("bsd,dhe->bshe", x, params["w_k"])
        v_new = jnp.einsum("bsd,dhe->bshe", x, params["w_v"])
        q = apply_rope(q, jnp.full((b, 1), pos), cfg.rope_theta)
        k_new = apply_rope(k_new, jnp.full((b, 1), pos), cfg.rope_theta)
        write_at = jnp.mod(pos, s_max) if ring else pos
        cache_k = jax.lax.dynamic_update_slice_in_dim(
            cache_k, k_new.astype(cache_k.dtype), write_at, axis=1)
        cache_v = jax.lax.dynamic_update_slice_in_dim(
            cache_v, v_new.astype(cache_v.dtype), write_at, axis=1)
    if cross:
        bias = jnp.zeros((b, 1, 1, s_max), jnp.float32)
    elif ring:
        slots = jnp.arange(s_max)[None, :]
        k_pos = pos - jnp.mod(pos - slots, s_max)   # true position per slot
        ok = k_pos >= 0
        bias = jnp.where(ok, 0.0, -1e30).astype(jnp.float32)[:, None, None, :]
        bias = jnp.broadcast_to(bias, (b, 1, 1, s_max))
    else:
        q_pos = jnp.full((b, 1), pos)
        k_pos = jnp.arange(s_max)[None, :]
        bias = _mask_bias(q_pos, k_pos,
                          "local" if mask_kind == "local" else "causal",
                          cfg.window_size)[:, None]
    out = _sdpa(q, cache_k, cache_v, bias, cfg.logit_softcap)
    out = jnp.einsum("bshe,hed->bsd", out, params["w_o"])
    return out, cache_k, cache_v
