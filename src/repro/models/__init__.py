# Intentionally empty: import submodules directly (repro.models.model, ...).
# Keeping this module side-effect-free avoids circular imports between
# repro.sharding (needs models.layers.LP) and model assembly code.
