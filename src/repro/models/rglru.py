"""Griffin / RecurrentGemma recurrent block: temporal conv1d + RG-LRU gated
diagonal linear recurrence.  [arXiv:2402.19427]

Training uses ``jax.lax.associative_scan`` (the recurrence is diagonal, so the
(a, b) affine composition is elementwise and cheap); decode is an O(1)-state
step.  State = (B, d_rnn) h-state + (B, conv_width-1, d_rnn) conv tail — O(1)
in sequence length, which is why recurrentgemma runs the long_500k cell.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import LP, dense_init, zeros_init


def init_rglru_block(key, cfg: ModelConfig, dtype=jnp.bfloat16):
    d = cfg.d_model
    dr = d  # rnn width = d_model
    rnn = "rnn" if cfg.shard_rnn else None  # §Perf: collective/compute trade
    ks = jax.random.split(key, 6)
    lam = jax.random.uniform(ks[5], (dr,), jnp.float32, 0.9 ** 2, 0.999 ** 2)
    # parameterize a = sigmoid(lambda_p); init so sigmoid(lambda_p)=lam^(1/c) —
    # standard Griffin init: a ~ uniform in [0.9, 0.999].
    lambda_p = jnp.log(lam ** (1.0 / cfg.rglru_c) /
                       (1.0 - lam ** (1.0 / cfg.rglru_c)))
    return {
        "w_in_x": dense_init(ks[0], (d, dr), ("embed", rnn), dtype=dtype),
        "w_in_gate": dense_init(ks[1], (d, dr), ("embed", rnn), dtype=dtype),
        "conv_w": zeros_init((cfg.rglru_conv_width, dr), ("conv", rnn),
                             dtype=jnp.float32),
        "conv_b": zeros_init((dr,), (rnn,), dtype=jnp.float32),
        "w_a": dense_init(ks[2], (dr, dr), (rnn, rnn), dtype=dtype),
        "b_a": zeros_init((dr,), (rnn,), dtype=jnp.float32),
        "w_x": dense_init(ks[3], (dr, dr), (rnn, rnn), dtype=dtype),
        "b_x": zeros_init((dr,), (rnn,), dtype=jnp.float32),
        "lambda_p": LP(lambda_p, (rnn,)),
        "w_out": dense_init(ks[4], (dr, d), (rnn, "embed"), dtype=dtype),
    }


def _conv1d(p, y, tail=None):
    """Causal depthwise conv, width W.  y: (B,S,dr); tail: (B,W-1,dr)."""
    w = p["conv_w"]
    width = w.shape[0]
    if tail is None:
        tail = jnp.zeros((y.shape[0], width - 1, y.shape[2]), y.dtype)
    ypad = jnp.concatenate([tail.astype(y.dtype), y], axis=1)
    out = sum(ypad[:, i:i + y.shape[1]] * w[i].astype(y.dtype)
              for i in range(width))
    new_tail = ypad[:, ypad.shape[1] - (width - 1):]
    return out + p["conv_b"].astype(y.dtype), new_tail


def _gates(p, y, cfg: ModelConfig):
    """RG-LRU gate computation in f32.  y: (..., dr)."""
    yf = y.astype(jnp.float32)
    r = jax.nn.sigmoid(yf @ p["w_a"].astype(jnp.float32) + p["b_a"])
    i = jax.nn.sigmoid(yf @ p["w_x"].astype(jnp.float32) + p["b_x"])
    log_a0 = jax.nn.log_sigmoid(p["lambda_p"])  # log a in (-inf, 0)
    log_a = cfg.rglru_c * r * log_a0            # a_t = a^(c*r_t)
    a = jnp.exp(log_a)
    mult = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12))
    b = mult * (i * yf)
    return a, b


def rglru_scan(p, y, cfg: ModelConfig, h0=None):
    """Full-sequence RG-LRU via associative scan.  y: (B,S,dr) -> (B,S,dr)."""
    a, b = _gates(p, y, cfg)
    if h0 is not None:
        b = b.at[:, 0].add(a[:, 0] * h0.astype(jnp.float32))
    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, a2 * b1 + b2
    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    return h.astype(y.dtype), h[:, -1]


def rglru_block_forward(p, x, cfg: ModelConfig, state=None):
    """Griffin recurrent block.  x: (B,S,d).  state=(h, conv_tail) or None.

    Returns (out, new_state).
    """
    h0, tail = state if state is not None else (None, None)
    y = x @ p["w_in_x"]
    gate = jax.nn.gelu((x @ p["w_in_gate"]).astype(jnp.float32))
    y, new_tail = _conv1d(p, y, tail)
    h, h_last = rglru_scan(p, y, cfg, h0=h0)
    out = (h.astype(jnp.float32) * gate).astype(x.dtype)
    return out @ p["w_out"], (h_last, new_tail)
