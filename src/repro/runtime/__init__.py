from repro.runtime.fault import (FaultInjector, RankDeath,  # noqa: F401
                                 run_with_restarts)
from repro.runtime.straggler import StragglerTracker  # noqa: F401
