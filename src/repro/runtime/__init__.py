from repro.runtime.fault import FaultInjector, run_with_restarts  # noqa: F401
from repro.runtime.straggler import StragglerTracker  # noqa: F401
