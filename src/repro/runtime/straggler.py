"""Straggler detection: EWMA of per-rank (per-host) step times -> relative
speed factors consumed by the CCM model (task_load / rank_speed), so both
CCM-LB applications (expert placement, sequence packing) shift work away
from slow hosts rather than just balancing nominal load.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class StragglerTracker:
    n_ranks: int
    alpha: float = 0.2           # EWMA weight of the newest sample
    floor: float = 0.25          # clamp: never assume a rank slower than 4x

    def __post_init__(self):
        self.ewma = np.zeros(self.n_ranks)
        self.count = 0

    def update(self, step_times: np.ndarray):
        step_times = np.asarray(step_times, np.float64)
        if self.count == 0:
            self.ewma = step_times.copy()
        else:
            self.ewma = (1 - self.alpha) * self.ewma + self.alpha * step_times
        self.count += 1

    def speed_factors(self) -> np.ndarray:
        """1.0 = median speed; <1 = slower (scales CCM load up)."""
        if self.count == 0:
            return np.ones(self.n_ranks)
        med = np.median(self.ewma)
        speed = med / np.maximum(self.ewma, 1e-12)
        return np.clip(speed, self.floor, 1.0 / self.floor)

    def stragglers(self, threshold: float = 0.8) -> np.ndarray:
        return np.nonzero(self.speed_factors() < threshold)[0]
