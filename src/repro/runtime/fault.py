"""Fault tolerance: restart-from-checkpoint loop + deterministic fault
injection for tests.

``run_with_restarts`` wraps a training driver whose contract is: it restores
from the latest checkpoint on entry and raises on (injected or real) node
failure.  The loop restarts it up to ``max_restarts`` times; because the
data pipeline is a pure function of (seed, step) and checkpoints are atomic,
a restarted run is bit-identical to an uninterrupted one from the restored
step — asserted in tests/test_fault.py.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional


class NodeFailure(RuntimeError):
    pass


class RankDeath(NodeFailure):
    """A balancer rank (or the whole survivor set) died mid-run.

    Raised by the async fault harness (repro/core/async_sim.py) when a
    ``FaultSpec.kill`` leaves no live rank to continue on — the balancer
    cannot recover in-process and the caller's restart loop
    (:func:`run_with_restarts`) is the right layer to handle it, which is
    why this subclasses :class:`NodeFailure`: existing restart policies
    apply unchanged."""


@dataclasses.dataclass
class FaultInjector:
    """Deterministically raise NodeFailure at the given global steps."""

    fail_at_steps: tuple = ()
    _raised: set = dataclasses.field(default_factory=set)

    def maybe_fail(self, step: int):
        if step in self.fail_at_steps and step not in self._raised:
            self._raised.add(step)
            raise NodeFailure(f"injected node failure at step {step}")


@dataclasses.dataclass
class RestartStats:
    restarts: int
    wall_s: float
    completed: bool


def run_with_restarts(train_once: Callable[[], None], *,
                      max_restarts: int = 5,
                      backoff_s: float = 0.0) -> RestartStats:
    t0 = time.time()
    restarts = 0
    while True:
        try:
            train_once()
            return RestartStats(restarts, time.time() - t0, True)
        except NodeFailure:
            restarts += 1
            if restarts > max_restarts:
                return RestartStats(restarts, time.time() - t0, False)
            if backoff_s:
                time.sleep(backoff_s)
