"""Elastic scaling: restore a checkpoint onto a DIFFERENT mesh, and the
rank-renumbering frame for shrinking a balancer onto its survivor set.

Because parameters are saved as full logical arrays with their logical axes
derivable from the model config (repro.sharding rules), growing or shrinking
the mesh is just: build the model on the new mesh -> derive new
NamedShardings -> restore() with them.  Divisibility-aware rules fall back
to replication, so any mesh whose axes divide the big dims works — e.g. a
16x16 run resumes on 8x16 after losing a slice, or on 2x16x16 when a second
pod joins.

:func:`survivor_resize` is the balancer-side counterpart: when ranks die
mid-run (the async fault harness, repro/core/async_sim.py), the survivor
set is renumbered contiguously so the CCM-LB problem can be restated at
the smaller rank count and warm-started via
``repro.core.pipeline.warm_start_assignment`` — same framing as a mesh
shrink, one level down.  :func:`expand_phase` / :class:`RankJoin` are the
join/expand counterpart: fresh ranks appended to a phase's rank set
mid-stream (a pod joins), defaulting to the median capacity/speed of the
existing ranks so a join never manufactures an outlier.  Both are pure
numpy on purpose: the async simulator imports them without pulling jax
(the jax-heavy checkpoint/model imports below are deferred into
:func:`resume_on_mesh`).
"""
from __future__ import annotations

import dataclasses
from typing import Iterable, Optional, Tuple

import numpy as np

from repro.core.problem import Phase


def resume_on_mesh(cfg, mesh, ckpt_dir: str, with_opt: bool = True) -> Tuple:
    """Returns (model, params, opt_state_or_None, step) placed on ``mesh``."""
    from repro.checkpoint import CheckpointManager
    from repro.launch.steps import abstract_opt, abstract_params
    from repro.models.model import build_model

    model = build_model(cfg, mesh)
    params_sds, p_sh = abstract_params(model)
    mgr = CheckpointManager(ckpt_dir)
    if with_opt:
        opt_sds, o_sh = abstract_opt(params_sds, p_sh)
        (params, opt_state), step = mgr.restore((params_sds, opt_sds),
                                                (p_sh, o_sh))
        return model, params, opt_state, step
    params, step = mgr.restore(params_sds, p_sh)
    return model, params, None, step


@dataclasses.dataclass(frozen=True)
class SurvivorResize:
    """Contiguous renumbering of a rank set after deaths.

    ``survivors[j]`` is the ORIGINAL id of new rank ``j`` (sorted
    ascending, so relative order is preserved); ``old_to_new[r]`` maps an
    original id to its new id, with dead ranks mapped to ``n_new`` — one
    PAST the last valid new rank, so ``old_to_new[assignment]`` feeds
    straight into ``warm_start_assignment``'s out-of-range clipping
    (``prev < next.num_ranks``): tasks stranded on dead ranks are exactly
    the ones that fall back to the fresh initial placement.
    """

    survivors: np.ndarray     # (n_new,) original ids of the live ranks
    old_to_new: np.ndarray    # (n_old,) original id -> new id (dead -> n_new)

    @property
    def n_new(self) -> int:
        return int(self.survivors.size)


def survivor_resize(n_ranks: int, dead: Iterable[int]) -> SurvivorResize:
    """Build the renumbering frame for ``n_ranks`` minus the ``dead`` set."""
    dead = set(int(d) for d in dead)
    if not all(0 <= d < n_ranks for d in dead):
        raise ValueError(f"dead ranks out of range [0, {n_ranks})")
    survivors = np.array([r for r in range(n_ranks) if r not in dead],
                         np.int64)
    if survivors.size == 0:
        raise ValueError("no survivors to resize onto")
    old_to_new = np.full(n_ranks, survivors.size, np.int64)
    old_to_new[survivors] = np.arange(survivors.size, dtype=np.int64)
    return SurvivorResize(survivors, old_to_new)


@dataclasses.dataclass(frozen=True)
class RankJoin:
    """A membership event: ``count`` fresh ranks join before iteration
    ``iteration`` of a balancing run (async driver) or before phase
    ``iteration`` of a pipeline (``ccm_lb_pipeline(membership=...)``).

    ``mem_base`` / ``mem_cap`` / ``speed`` override the new ranks' rows;
    left ``None`` they default to the median of the phase they join
    (:func:`expand_phase`).  Joined ranks take the next ids past the
    current rank count, start empty, participate in gossip from their
    first iteration — inheriting peer state through the ordinary epidemic
    flood — and attract transfers like any underloaded rank: the
    rebalance IS the protocol, no side channel.
    """

    iteration: int
    count: int = 1
    mem_base: Optional[float] = None
    mem_cap: Optional[float] = None
    speed: Optional[float] = None

    def __post_init__(self):
        if self.iteration < 0:
            raise ValueError("RankJoin.iteration must be >= 0")
        if self.count < 1:
            raise ValueError("RankJoin.count must be >= 1")


def expand_phase(phase: Phase, count: int = 1, *,
                 mem_base: Optional[float] = None,
                 mem_cap: Optional[float] = None,
                 speed: Optional[float] = None) -> Phase:
    """Append ``count`` fresh ranks to a phase's rank set (the join/expand
    counterpart of :func:`survivor_resize`).

    Only the rank-indexed arrays grow; the task/block/comm structure is
    shared by object, so ``same_topology(phase, expanded)`` holds and a
    prebuilt :class:`~repro.core.csr.PhaseCSR` (task/block adjacency —
    rank-independent by construction) stays valid.  Unspecified
    capacities/speeds default to the median of the existing ranks.
    """
    if count < 1:
        raise ValueError("expand_phase needs count >= 1")
    mb = float(np.median(phase.rank_mem_base)) if mem_base is None \
        else float(mem_base)
    mc = float(np.median(phase.rank_mem_cap)) if mem_cap is None \
        else float(mem_cap)
    new_mb = np.concatenate([phase.rank_mem_base, np.full(count, mb)])
    new_mc = np.concatenate([phase.rank_mem_cap, np.full(count, mc)])
    sp = float(np.median(phase.rank_speed)) if speed is None \
        else float(speed)
    new_speed = np.concatenate([phase.rank_speed, np.full(count, sp)])
    return Phase(
        task_load=phase.task_load, task_mem=phase.task_mem,
        task_overhead=phase.task_overhead, task_block=phase.task_block,
        block_size=phase.block_size, block_home=phase.block_home,
        comm_src=phase.comm_src, comm_dst=phase.comm_dst,
        comm_vol=phase.comm_vol,
        rank_mem_base=new_mb, rank_mem_cap=new_mc, rank_speed=new_speed)
