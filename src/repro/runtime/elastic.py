"""Elastic scaling: restore a checkpoint onto a DIFFERENT mesh.

Because parameters are saved as full logical arrays with their logical axes
derivable from the model config (repro.sharding rules), growing or shrinking
the mesh is just: build the model on the new mesh -> derive new
NamedShardings -> restore() with them.  Divisibility-aware rules fall back
to replication, so any mesh whose axes divide the big dims works — e.g. a
16x16 run resumes on 8x16 after losing a slice, or on 2x16x16 when a second
pod joins.
"""
from __future__ import annotations

from typing import Tuple

import jax
from jax.sharding import Mesh

from repro.checkpoint import CheckpointManager
from repro.configs.base import ModelConfig
from repro.launch.steps import abstract_opt, abstract_params
from repro.models.model import build_model


def resume_on_mesh(cfg: ModelConfig, mesh: Mesh, ckpt_dir: str,
                   with_opt: bool = True) -> Tuple:
    """Returns (model, params, opt_state_or_None, step) placed on ``mesh``."""
    model = build_model(cfg, mesh)
    params_sds, p_sh = abstract_params(model)
    mgr = CheckpointManager(ckpt_dir)
    if with_opt:
        opt_sds, o_sh = abstract_opt(params_sds, p_sh)
        (params, opt_state), step = mgr.restore((params_sds, opt_sds),
                                                (p_sh, o_sh))
        return model, params, opt_state, step
    params, step = mgr.restore(params_sds, p_sh)
    return model, params, None, step
