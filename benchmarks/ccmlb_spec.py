"""Speculative-scan stage-2 driver: wall time vs window size, against the
host engine and batched(8) baselines on the ``scaling_phase`` family.

PR 4 established the per-event dispatch wall (one XLA dispatch+sync costs
about as much as the whole 80-op numpy scoring tree at the default tiles);
``batch_lock_events`` amortized it over disjoint event batches.  The
speculative scan (core/spec.py) amortizes further: a window of W upcoming
lock events — derived up front from the deterministic synchronous event
order — is scored in ONE compiled launch (flow assembly, feature
derivation, scoring and selection all in-trace; kernels/ccm_scorer/jit.py
kind="spec"), with host-side rollback of speculations an earlier commit
invalidated.

Every config is asserted assignment-identical to the host engine run
(compiled-vs-host parity tier), and each record carries the rollback /
window-launch / trace counters, so both the perf and the speculation waste
are tracked PR to PR.

Timing: this machine is a single-core VM with 30-40%% wall-clock drift
between back-to-back identical runs (host steal / frequency scaling), so
a single-shot A-then-B comparison is noise.  Every config is primed once
untimed (compiles every shape bucket it needs — compile latency stays
visible through ``trace_count``), then timed over REPS INTERLEAVED sweeps
(config order rotates inside each sweep) and scored by its minimum, the
standard noise-floor estimator.

Bars: the headline ``spec_speedup_over_batched_best`` (best scan window
>= 8 vs batched(8)) is hard-asserted to beat 1.0x in full mode — the
speculative scan must not lose to the batched driver it replaces.  The
SPEC_TARGET of 1.3x from the PR brief is recorded and warned on when
missed: on this CPU-only host the XLA in-trace flow scatter costs about
what the host numpy bincount costs, so once the dispatch is amortized
(window >= 8) the two paths converge and the measured steady ratio sits
near 1.1x (see kernels/ccm_scorer/README.md).  Quick mode (CI) asserts
identity but only warns on both bars (shared runners make wall-time
ratios unreliable).

Usage:  PYTHONPATH=src python benchmarks/ccmlb_spec.py [--quick]
"""
from __future__ import annotations

import json
import os
import sys
import time

import numpy as np

from repro.core import CCMParams, ccm_lb
from repro.core.problem import initial_assignment, scaling_phase
from repro.kernels.ccm_scorer import jit as scorer_jit

JSON_PATH = os.environ.get("BENCH_CCMLB_SPEC_JSON", "BENCH_ccmlb_spec.json")
WINDOWS = (4, 8, 16, 32)
QUICK_WINDOWS = (8, 16)
BATCH_EVENTS = 8
N_ITER = 4
REPS = 3
QUICK_REPS = 1
SPEC_FLOOR = 1.0    # hard bar: spec(scan, window >= 8) must beat batched(8)
SPEC_TARGET = 1.3   # PR-brief target: recorded, warned on when missed


def _run(phase, a0, params, **kw):
    return ccm_lb(phase, a0, params, n_iter=N_ITER, k_rounds=2, fanout=4,
                  seed=0, **kw)


def run(report, quick: bool = False):
    quick = quick or os.environ.get("BENCH_QUICK") == "1"
    ranks = 64 if quick else 256
    windows = QUICK_WINDOWS if quick else WINDOWS
    reps = QUICK_REPS if quick else REPS
    params = CCMParams(delta=1e-9)
    phase = scaling_phase(ranks)
    a0 = initial_assignment(phase)

    t0 = time.perf_counter()
    scorer_jit.warmup(max_batch=BATCH_EVENTS)
    scorer_jit.spec_warmup(window=max(windows))
    warmup_seconds = time.perf_counter() - t0

    # (tag, ccm_lb kwargs) — engine and batched are the baselines; the
    # window sweep runs fill="disjoint" (the default: rollback-free by
    # construction), plus one greedy point (speculation waste made
    # load-bearing) and one vmap point (fleet-mode wrapper comparison)
    configs = [("engine", dict(use_engine=True)),
               ("batched", dict(use_engine=True,
                                batch_lock_events=BATCH_EVENTS))]
    for w in windows:
        configs.append((f"spec_w{w}", dict(use_engine=True, spec_window=w)))
    configs.append(("spec_greedy_w8",
                    dict(use_engine=True, spec_window=8,
                         spec_fill="greedy")))
    configs.append((f"spec_vmap_w{windows[0]}",
                    dict(use_engine=True, spec_window=windows[0],
                         spec_mode="vmap")))

    # prime: one untimed run per config compiles every shape bucket the
    # config touches and pins the parity tier (assignment identity)
    results, compiles = {}, {}
    ref = None
    for tag, kw in configs:
        tc0 = scorer_jit.trace_count()
        res = _run(phase, a0, params, **kw)
        compiles[tag] = scorer_jit.trace_count() - tc0
        if ref is None:
            ref = res
        assert np.array_equal(ref.assignment, res.assignment), \
            f"{tag} diverged from the host engine"
        results[tag] = res

    # timed: REPS interleaved sweeps, min per config; rotate the order so
    # slow machine epochs hit every config equally
    times = {tag: [] for tag, _ in configs}
    tc0 = scorer_jit.trace_count()
    for rep in range(reps):
        order = configs[rep % len(configs):] + configs[:rep % len(configs)]
        for tag, kw in order:
            t0 = time.perf_counter()
            _run(phase, a0, params, **kw)
            times[tag].append(time.perf_counter() - t0)
    timed_compiles = scorer_jit.trace_count() - tc0

    engine_dt = min(times["engine"])
    batched_dt = min(times["batched"])
    records = []
    best = 0.0
    for tag, kw in configs:
        dt = min(times[tag])
        res = results[tag]
        rec = {
            "ranks": ranks, "config": tag, "n_iter": N_ITER,
            "seconds": dt, "seconds_reps": [round(t, 4) for t in times[tag]],
            "transfers": int(res.transfers),
            "compiles_prime_run": compiles[tag],
            "identical_assignments": True,
        }
        derived = ""
        if tag == "batched":
            rec["batch_lock_events"] = BATCH_EVENTS
            derived = f"{engine_dt / dt:.2f}x vs engine"
        elif tag.startswith("spec"):
            rec.update(window=kw["spec_window"],
                       mode=kw.get("spec_mode", "scan"),
                       fill=kw.get("spec_fill", "disjoint"),
                       spec_rollbacks=int(res.spec_rollbacks),
                       spec_windows=int(res.spec_windows),
                       speedup_vs_batched=batched_dt / dt,
                       speedup_vs_engine=engine_dt / dt)
            derived = (f"{batched_dt / dt:.2f}x vs batched({BATCH_EVENTS}), "
                       f"{engine_dt / dt:.2f}x vs engine, "
                       f"rollbacks={res.spec_rollbacks} "
                       f"launches={res.spec_windows} "
                       f"compiles={compiles[tag]}")
            if (kw.get("spec_mode", "scan") == "scan"
                    and kw.get("spec_fill", "disjoint") == "disjoint"
                    and kw["spec_window"] >= 8):
                best = max(best, batched_dt / dt)
        records.append(rec)
        report(f"ccmlb_spec_ranks_{ranks}_{tag}", dt * 1e6, derived)

    payload = {
        "benchmark": "ccmlb_spec",
        "quick": quick,
        "ranks": ranks,
        "reps": reps,
        "numpy": np.__version__,
        "results": records,
        "engine_seconds": engine_dt,
        "batched_seconds": batched_dt,
        "spec_speedup_over_batched_best": best,
        "spec_floor": SPEC_FLOOR,
        "spec_target": SPEC_TARGET,
        "spec_target_met": best >= SPEC_TARGET,
        "warmup_seconds": warmup_seconds,
        "compiles_timed_runs": timed_compiles,
        "trace_count": scorer_jit.trace_count(),
        "jit_buckets_compiled": scorer_jit.bucket_cache_size(),
        "jit_bucket_keys": scorer_jit.bucket_keys(),
    }
    with open(JSON_PATH, "w") as f:
        json.dump(payload, f, indent=2)
    report("ccmlb_spec_json", 0.0, f"written to {JSON_PATH}")
    if best < SPEC_TARGET:
        report("ccmlb_spec_TARGET", 0.0,
               f"best scan speedup {best:.2f}x under the {SPEC_TARGET}x "
               "target (XLA in-trace scatter ~ host numpy bincount on this "
               "CPU-only host; see kernels/ccm_scorer/README.md)")
    if best < SPEC_FLOOR:
        msg = (f"spec scan best speedup {best:.2f}x vs "
               f"batched({BATCH_EVENTS}) under the {SPEC_FLOOR}x floor")
        if quick:
            report("ccmlb_spec_WARN", 0.0, f"{msg} (quick mode: warning "
                   "only — shared-runner wall times)")
        else:
            raise AssertionError(msg)


def main():
    quick = "--quick" in sys.argv
    print("name,us_per_call,derived")

    def report(name, us, derived=""):
        print(f"{name},{us:.1f},{derived}", flush=True)

    run(report, quick=quick)


if __name__ == "__main__":
    main()
