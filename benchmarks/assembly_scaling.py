"""Paper Fig. 5: weak-scaled assembly speedup — A baseline vs B
overdecomposed vs C overdecomposed + CCM-LB, at 3 rank counts."""
from __future__ import annotations


from repro.assembly import run_assembly_comparison


def run(report):
    for n_unknowns, ranks in ((2048, 8), (4096, 16), (8192, 32)):
        r = run_assembly_comparison(n_unknowns=n_unknowns, num_ranks=ranks,
                                    durations="analytic", seed=0)
        report(f"fig5_ranks_{ranks}", r.makespan_ccmlb * 1e6,
               f"unknowns={n_unknowns} tasks={r.problem.num_tasks} "
               f"speedup_B={r.speedup_overdecomposed:.2f}x "
               f"speedup_C={r.speedup_ccmlb:.2f}x "
               f"imb {r.imbalance_before:.2f}->{r.imbalance_after:.3f}")
