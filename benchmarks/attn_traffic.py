"""§Perf evidence: measure how much of a cell's HLO byte traffic is
attention-score-shaped — i.e. tensors with an (S, S) trailing pair — and
project the memory term with the Pallas flash kernel substituted (the kernel
keeps score tiles in VMEM; its HBM traffic is Q+K+V+O only).

  PYTHONPATH=src python -m benchmarks.attn_traffic --arch smollm-360m
"""
from __future__ import annotations

import os

if __name__ == "__main__":
    # script-only: the 512-virtual-device mesh needs the flag set before
    # JAX initializes.  Must NOT run on plain import — benchmarks.run
    # auto-imports every benchmarks module, and leaking this flag would
    # distort the other benches' timings (and their BENCH_*.json records)
    os.environ.setdefault("XLA_FLAGS",
                          "--xla_force_host_platform_device_count=512")

import argparse  # noqa: E402
import dataclasses  # noqa: E402
import re  # noqa: E402

from repro import configs  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.launch.steps import lower_cell  # noqa: E402
from repro.roofline import _DTYPE_BYTES, HBM_BW  # noqa: E402

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([\d,]+)\]")


def score_shaped_bytes(hlo_text: str, seq: int) -> tuple:
    """(total op-output bytes, score-shaped op-output bytes)."""
    total = 0
    score = 0
    for line in hlo_text.splitlines():
        m = re.match(r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*"
                     r"\(?([a-z0-9]+)\[([\d,]+)\]", line)
        if not m:
            continue
        dtype, dims = m.groups()
        if dtype not in _DTYPE_BYTES:
            continue
        sizes = [int(x) for x in dims.split(",")]
        n = 1
        for s in sizes:
            n *= s
        nbytes = n * _DTYPE_BYTES[dtype]
        total += nbytes
        # score-shaped: the last two dims are both >= seq/64 fractions of the
        # sequence (covers sharded (S, S/16) layouts too)
        if len(sizes) >= 2 and sizes[-1] * sizes[-2] >= (seq * seq) // 32:
            score += nbytes
    return total, score


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--shape", default="train_4k")
    args = ap.parse_args()
    cfg = dataclasses.replace(configs.get_config(args.arch),
                              unroll_stack=True)
    shape = configs.get_shape(args.shape)
    mesh = make_production_mesh()
    lowered = lower_cell(cfg, shape, mesh)
    compiled = lowered.compile()
    text = compiled.as_text()
    ca = compiled.cost_analysis()
    bytes_accessed = float(ca.get("bytes accessed", 0.0))
    total, score = score_shaped_bytes(text, shape.seq_len)
    frac = score / max(total, 1)
    # flash substitution: per (layer, direction) q/k/v/o streams only
    b_loc = shape.global_batch // int(mesh.shape["data"])
    flash_bytes = (4 * b_loc * shape.seq_len * cfg.num_heads * cfg.head_dim
                   * 2 * cfg.num_layers * 3)  # fwd+bwd+remat
    projected = bytes_accessed * (1 - frac) + flash_bytes
    print(f"arch={args.arch} shape={args.shape}")
    print(f"bytes_accessed/dev           : {bytes_accessed:.3e}")
    print(f"score-shaped fraction of HLO : {frac:.2%}")
    print(f"flash-kernel attn bytes/dev  : {flash_bytes:.3e}")
    print(f"projected bytes w/ kernel    : {projected:.3e}")
    print(f"memory term: {bytes_accessed / HBM_BW:.2f}s -> "
          f"{projected / HBM_BW:.2f}s "
          f"({bytes_accessed / projected:.1f}x)")


if __name__ == "__main__":
    main()
