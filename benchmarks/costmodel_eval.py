"""Paper §VI-D: cost-model prediction quality, with/without Algorithm 1
data reduction and the under-penalized loss — plus the downstream check the
model actually exists for: feeding its predicted durations into CCM-LB and
measuring the balance quality achieved on the TRUE durations (engine and
scalar evaluation paths timed side by side)."""
from __future__ import annotations

import time

import numpy as np

from repro.assembly import build_problem
from repro.assembly.driver import run_assembly_comparison
from repro.assembly.execute import analytic_durations
from repro.costmodel import train_cost_model
from repro.costmodel.train import evaluate_cost_model


def run(report):
    rng = np.random.default_rng(0)
    n_ranks = 8
    train_p = build_problem(2048, n_ranks, seed=1, task_limit_u=32)
    test_p = build_problem(2048, n_ranks, seed=2, task_limit_u=32)
    x, y = train_p.features(), analytic_durations(train_p)
    y = y * rng.lognormal(0, 0.08, y.shape)   # machine noise
    xt, yt = test_p.features(), analytic_durations(test_p)
    first_model = None
    for name, kwargs in (
        ("underpen_reduced", dict(alpha=0.3, reduce_to=int(0.6 * len(y)))),
        ("underpen_full", dict(alpha=0.3)),
        ("plain_rmse", dict(alpha=1.0)),
    ):
        t0 = time.perf_counter()
        model, _ = train_cost_model(x, y, epochs=80, batch_size=128, seed=0,
                                    **kwargs)
        dt = time.perf_counter() - t0
        if first_model is None:
            first_model = model
        m = evaluate_cost_model(model, xt, yt)
        report(f"costmodel_{name}", dt * 1e6,
               f"rel_err_med={m['rel_err_median']:.3f} "
               f"over_frac={m['over_predict_frac']:.2f} "
               f"rmse={m['rmse']:.2e}")

    # downstream consumer: the paper's pipeline (cost model -> CCM-LB ->
    # makespan on TRUE durations), via the shared assembly driver
    for use_engine in (False, True):
        t0 = time.perf_counter()
        run_c = run_assembly_comparison(
            2048, n_ranks, cost_model=first_model, seed=2,
            task_limit_u=32, use_engine=use_engine)
        dt = time.perf_counter() - t0
        tag = "engine" if use_engine else "scalar"
        report(f"costmodel_ccmlb_plan_{tag}", dt * 1e6,
               f"true_makespan {run_c.makespan_overdecomposed:.3f}->"
               f"{run_c.makespan_ccmlb:.3f} "
               f"speedup_vs_baseline={run_c.speedup_ccmlb:.2f}x")
