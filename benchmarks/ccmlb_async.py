"""Async event-loop driver: latency/fanout sweep + the parity/contention
bars (§IV-B made load-bearing).

Per rank count this runs the ccmlb_scaling instance through

  * ``sync``        — the synchronous reference (``ccm_lb``, engine path);
  * ``async_zero``  — the event-loop driver at zero latency, ASSERTED
    bitwise-identical to ``sync`` (assignment + transfer sequence + work
    traces): the serialized-schedule parity bar;
  * ``async_const`` / ``async_uniform`` — contended interleavings under a
    constant and a uniform message-latency distribution: the §IV-B
    conflict/yield/grant-chain counters become nonzero, and the JSON
    records them next to quality (final imbalance, Wmax/mean) and cost
    (wall seconds, simulated time, delivered messages);

then a *contended* configuration (half the ranks start empty, so many
loaded ranks race for the same underloaded peers) on which the run MUST
produce ``lock_conflicts > 0`` and a grant chain >= 2 — the same coverage
pin tests/test_async_protocol.py enforces — and a fanout sweep under
latency (message volume vs achieved balance).

Results land in ``BENCH_ccmlb_async.json``.

Standalone:  PYTHONPATH=src python benchmarks/ccmlb_async.py [--quick]
(--quick runs the 16-rank configs for CI; also wired into
benchmarks/run.py as ``ccmlb_async``.)
"""
from __future__ import annotations

import json
import os
import sys
import time

import numpy as np

from repro.core import CCMParams, ccm_lb, ccm_lb_async
from repro.core.problem import initial_assignment, scaling_phase

JSON_PATH = os.environ.get("BENCH_CCMLB_ASYNC_JSON", "BENCH_ccmlb_async.json")
N_ITER = 4
LATENCIES = (("async_zero", 0.0),
             ("async_const", 0.5),
             ("async_uniform", ("uniform", 0.5, 1.5)))


_instance = scaling_phase    # the parity bar is defined on THESE instances


def _record(records, tag, ranks, phase, res, seconds, parity=None, **extra):
    mean = phase.task_load.sum() / ranks
    records.append({
        "config": tag,
        "ranks": ranks,
        "tasks": phase.num_tasks,
        "comms": phase.num_comms,
        "n_iter": N_ITER,
        "seconds": seconds,
        "imbalance_after": float(res.imbalance[-1]),
        "max_work_over_mean": float(res.max_work[-1] / mean),
        "transfers": int(res.transfers),
        "lock_conflicts": int(res.lock_conflicts),
        "yields": int(res.yields),
        "grant_chains": int(res.grant_chains),
        "max_grant_chain": int(res.max_grant_chain),
        "messages": int(res.messages),
        "sim_time": float(res.sim_time),
        "timeouts": int(res.timeouts),
        "retries_exhausted": int(res.retries_exhausted),
        **({} if parity is None else {"bitwise_identical_to_sync": parity}),
        **extra,
    })


def _sweep_ranks(report, records, ranks: int):
    phase = _instance(ranks)
    a0 = initial_assignment(phase)
    lb = dict(n_iter=N_ITER, k_rounds=2, fanout=4, seed=0)

    t0 = time.perf_counter()
    ref = ccm_lb(phase, a0, CCMParams(delta=1e-9), **lb)
    sync_s = time.perf_counter() - t0
    _record(records, "sync", ranks, phase, ref, sync_s)
    report(f"ccmlb_async_ranks_{ranks}_sync", sync_s * 1e6,
           f"imb_after={ref.imbalance[-1]:.4f} transfers={ref.transfers}")

    for tag, latency in LATENCIES:
        t0 = time.perf_counter()
        res = ccm_lb_async(phase, a0, CCMParams(delta=1e-9), latency=latency,
                           **lb)
        dt = time.perf_counter() - t0
        parity = None
        if tag == "async_zero":
            # acceptance bar: serialized zero-latency async == sync,
            # assignment AND transfer sequence AND work traces
            parity = bool(np.array_equal(res.assignment, ref.assignment)
                          and res.transfer_log == ref.transfer_log
                          and res.max_work == ref.max_work)
            assert parity, f"zero-latency async diverged from sync @{ranks}"
        _record(records, tag, ranks, phase, res, dt, parity=parity)
        report(f"ccmlb_async_ranks_{ranks}_{tag}", dt * 1e6,
               f"imb_after={res.imbalance[-1]:.4f} "
               f"conflicts={res.lock_conflicts} yields={res.yields} "
               f"max_chain={res.max_grant_chain} msgs={res.messages}"
               + (" bitwise==sync" if parity else ""))


def _contended(report, records, ranks: int):
    """Half the ranks start empty: stage 1 points many loaded ranks at the
    same underloaded peers, latency overlaps their requests — the §IV-B
    branches must fire (asserted; the bench-level coverage pin)."""
    phase = _instance(ranks)
    a0 = (np.arange(phase.num_tasks) % (ranks // 2)).astype(np.int64)
    t0 = time.perf_counter()
    res = ccm_lb_async(phase, a0, CCMParams(delta=1e-9), n_iter=N_ITER,
                       seed=3, fanout=6, latency=("uniform", 0.5, 1.5))
    dt = time.perf_counter() - t0
    assert res.lock_conflicts > 0, "contended run produced no conflicts"
    assert res.max_grant_chain >= 2, "contended run produced no chain >= 2"
    _record(records, "contended_uniform", ranks, phase, res, dt,
            initial="half_empty")
    report(f"ccmlb_async_contended_{ranks}", dt * 1e6,
           f"conflicts={res.lock_conflicts} yields={res.yields} "
           f"chains={res.grant_chains} max_chain={res.max_grant_chain} "
           f"imb {res.imbalance[0]:.2f}->{res.imbalance[-1]:.4f}")


def _fanout_sweep(report, records, ranks: int):
    phase = _instance(ranks)
    a0 = initial_assignment(phase)
    for fanout in (2, 4, 8):
        t0 = time.perf_counter()
        res = ccm_lb_async(phase, a0, CCMParams(delta=1e-9), n_iter=3,
                           k_rounds=2, fanout=fanout, seed=0,
                           latency=("uniform", 0.5, 1.5))
        dt = time.perf_counter() - t0
        _record(records, f"fanout_{fanout}", ranks, phase, res, dt,
                fanout=fanout)
        report(f"ccmlb_async_f{fanout}_ranks_{ranks}", dt * 1e6,
               f"msgs={res.messages} imb_after={res.imbalance[-1]:.4f} "
               f"conflicts={res.lock_conflicts}")


def run(report, quick: bool = False):
    records = []
    for ranks in ((16,) if quick else (16, 64, 256)):
        _sweep_ranks(report, records, ranks)
    for ranks in ((16,) if quick else (16, 64)):
        _contended(report, records, ranks)
    _fanout_sweep(report, records, 16 if quick else 64)

    contended = [r for r in records if r["config"] == "contended_uniform"]
    payload = {
        "benchmark": "ccmlb_async",
        "quick": quick,
        "numpy": np.__version__,
        "n_iter": N_ITER,
        "results": records,
        "parity_configs_ok": all(
            r.get("bitwise_identical_to_sync", True) for r in records),
        "max_conflicts": max(r["lock_conflicts"] for r in records),
        "max_grant_chain": max(r["max_grant_chain"] for r in records),
        "contended_conflicts_largest": contended[-1]["lock_conflicts"],
    }
    with open(JSON_PATH, "w") as f:
        json.dump(payload, f, indent=2)
    report("ccmlb_async_json", 0.0, f"written to {JSON_PATH}")


def main():
    quick = "--quick" in sys.argv
    print("name,us_per_call,derived")

    def report(name, us, derived=""):
        print(f"{name},{us:.1f},{derived}", flush=True)

    run(report, quick=quick)
    # CI smoke assertions over the emitted JSON (parity is asserted
    # in-bench; these pin the protocol-coverage and quality floors)
    with open(JSON_PATH) as f:
        payload = json.load(f)
    assert payload["parity_configs_ok"]
    assert payload["max_conflicts"] > 0
    assert payload["max_grant_chain"] >= 2
    for rec in payload["results"]:
        assert rec["imbalance_after"] < 0.5, rec
    print("ccmlb_async_ok,0.0,parity+coverage+quality checks passed",
          flush=True)


if __name__ == "__main__":
    main()
