"""Beyond paper: Pallas kernels vs jnp reference — interpret-mode correctness
timing is meaningless on CPU, so we report HLO cost-model FLOPs/bytes of the
kernel lowering vs the reference lowering plus wall time of the jnp oracle
(the portable path the dry-run uses)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np


def _time(fn, *args, reps=3):
    fn(*args)  # compile
    best = np.inf
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        best = min(best, time.perf_counter() - t0)
    return best


def run(report):
    key = jax.random.key(0)
    # flash attention oracle cost at a train_4k-like per-device shape
    from repro.kernels.flash.ref import reference_attention
    b, s, h, hd = 4, 1024, 8, 64
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (b * h, s, hd), jnp.float32)
    k = jax.random.normal(ks[1], (b * h, s, hd), jnp.float32)
    v = jax.random.normal(ks[2], (b * h, s, hd), jnp.float32)
    ref = jax.jit(lambda q, k, v: reference_attention(q, k, v, causal=True))
    dt = _time(ref, q, k, v)
    lowered = jax.jit(ref).lower(q, k, v).compile()
    ca = lowered.cost_analysis()
    report("flash_ref_b4s1024", dt * 1e6,
           f"hlo_flops={ca.get('flops', 0):.3e} "
           f"bytes={ca.get('bytes accessed', 0):.3e}")

    from repro.kernels.rwkv6.ref import reference_wkv6
    bh, s2, hd2 = 8, 512, 64
    ks = jax.random.split(key, 4)
    r_ = jax.random.normal(ks[0], (bh, s2, hd2)) * 0.5
    k_ = jax.random.normal(ks[1], (bh, s2, hd2)) * 0.5
    v_ = jax.random.normal(ks[2], (bh, s2, hd2))
    lw = -jnp.exp(jax.random.normal(ks[3], (bh, s2, hd2)))
    u = jnp.zeros((bh, hd2))
    ref2 = jax.jit(reference_wkv6)
    dt = _time(ref2, r_, k_, v_, lw, u)
    report("wkv6_ref_seqscan", dt * 1e6, f"bh={bh} s={s2} hd={hd2}")

    from repro.assembly.execute import tile_kernel
    pr = jax.random.uniform(ks[0], (96, 3))
    pc = jax.random.uniform(ks[1], (96, 3))
    couple = jnp.ones((96, 96), bool)
    for qo in (4, 64, 192):
        dt = _time(lambda a, b, c: tile_kernel(a, b, c, qo), pr, pc, couple)
        report(f"assembly_tile_q{qo}", dt * 1e6,
               f"flops~{96*96*qo*8:.2e}")
