"""Render EXPERIMENTS.md sections from benchmarks/results/dryrun.json.

  PYTHONPATH=src python -m benchmarks.render_experiments
"""
from __future__ import annotations

import json
from pathlib import Path

RESULTS = Path(__file__).resolve().parent / "results" / "dryrun.json"


def fmt_bytes(n):
    for unit in ("B", "KB", "MB", "GB", "TB"):
        if abs(n) < 1024:
            return f"{n:.1f}{unit}"
        n /= 1024
    return f"{n:.1f}PB"


def dryrun_table(data, mesh_filter):
    lines = [
        "| arch | shape | kind | compile_s | HLO GFLOPs/dev | bytes/dev | "
        "collective bytes/dev (AR/AG/RS/A2A/CP) | temp bytes/dev |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for key in sorted(data):
        rec = data[key]
        if not rec.get("ok") or rec["mesh"] != mesh_filter:
            continue
        s = rec["stats"]
        cb = s["collective_bytes"]
        coll = "/".join(fmt_bytes(cb.get(k, 0)) for k in (
            "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
            "collective-permute"))
        mem = s.get("memory", {})
        temp = fmt_bytes(mem.get("temp_bytes", 0)) if "temp_bytes" in mem \
            else "n/a"
        lines.append(
            f"| {rec['arch']} | {rec['shape']} | {rec['kind']} "
            f"| {rec['compile_s']} | {s['flops'] / 1e9:.1f} "
            f"| {fmt_bytes(s['bytes_accessed'])} | {coll} | {temp} |")
    return "\n".join(lines)


def roofline_table(data, mesh_filter):
    lines = [
        "| arch | shape | t_compute (s) | t_memory (s) | t_collective (s) | "
        "dominant | MODEL_FLOPS | useful ratio | roofline fraction | "
        "what moves the dominant term |",
        "|---|---|---|---|---|---|---|---|---|---|".replace(
            "|---|---|---|---|---|---|---|---|---|---|",
            "|---|---|---|---|---|---|---|---|---|---|"),
    ]
    notes = {
        ("train",): "fuse/stream attention scores + chunk the CE logits "
                    "(largest HBM residents)",
        ("prefill",): "stream attention scores (flash); shard sequence",
        ("decode",): "decode is weight/KV-bandwidth bound: shrink KV "
                     "(window cache), batch more requests per chip",
    }
    for key in sorted(data):
        rec = data[key]
        if not rec.get("ok") or rec["mesh"] != mesh_filter:
            continue
        r = rec["roofline"]
        note = notes[(rec["kind"],)]
        if r["dominant"] == "collective":
            note = "overlap/shrink collectives (reduce-scatter grads, " \
                   "fewer all-gathers)"
        lines.append(
            f"| {rec['arch']} | {rec['shape']} | {r['compute_s']:.3e} "
            f"| {r['memory_s']:.3e} | {r['collective_s']:.3e} "
            f"| {r['dominant']} | {r['model_flops']:.2e} "
            f"| {r['useful_flops_ratio']:.2f} | {r['roofline_fraction']:.4f} "
            f"| {note} |")
    return "\n".join(lines)


def main():
    data = json.loads(RESULTS.read_text())
    print("## Dry-run (scan lowering, production meshes)\n")
    print("### single pod 16x16\n")
    print(dryrun_table(data, "16x16"))
    print("\n### multi-pod 2x16x16\n")
    print(dryrun_table(data, "2x16x16"))
    print("\n## Roofline (unrolled lowering, exact counts, single pod)\n")
    print(roofline_table(data, "16x16-unrolled"))


if __name__ == "__main__":
    main()
