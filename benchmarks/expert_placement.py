"""Beyond paper: CCM-driven MoE expert placement — imbalance and modeled
all-to-all bytes before/after, for qwen3-style and llama4-style MoE."""
from __future__ import annotations

import time

import numpy as np

from repro import configs
from repro.balance import plan_expert_placement


def run(report):
    rng = np.random.default_rng(0)
    for arch, devices in (("qwen3-moe-30b-a3b", 16),
                          ("llama4-scout-17b-a16e", 16)):
        cfg = configs.get_config(arch)
        e = cfg.num_experts
        counts = rng.zipf(1.4, (4, e)).astype(np.float64)
        counts = counts / counts.sum(1, keepdims=True) * 32768
        t0 = time.perf_counter()
        plan = plan_expert_placement(counts, cfg, devices,
                                     hbm_budget_bytes=16e9, seed=0)
        dt = time.perf_counter() - t0
        report(f"expert_placement_{arch}", dt * 1e6,
               f"imb {plan.imbalance_before:.2f}->{plan.imbalance_after:.3f} "
               f"maxwork {plan.max_work_before:.2e}->"
               f"{plan.max_work_after:.2e} repl={plan.replicated_blocks}")

    # straggler-aware: one device at half speed
    cfg = configs.get_config("qwen3-moe-30b-a3b")
    counts = rng.zipf(1.4, (4, 128)).astype(np.float64)
    counts = counts / counts.sum(1, keepdims=True) * 32768
    speed = np.ones(16)
    speed[0] = 0.5
    plan = plan_expert_placement(counts, cfg, 16, hbm_budget_bytes=16e9,
                                 rank_speed=speed, seed=0)
    report("expert_placement_straggler", 0.0,
           f"maxwork_after={plan.max_work_after:.2e} "
           f"(slow dev offloaded: imb={plan.imbalance_after:.3f})")
