"""Vmapped multi-instance balancer (``ccm_lb_many``) vs a Python loop of
solo engine runs.

Fleet mode runs N independent CCM-LB instances in lock-step and scores
each sweep's lock events — one per instance, drawn round-robin — in a
single vmapped compiled launch (kernels/ccm_scorer/jit.py kind="spec",
mode="vmap"), instead of N separate per-event scoring passes.  Every
instance's trajectory is asserted identical (assignment AND transfer log)
to its solo ``ccm_lb(use_engine=True)`` run, so fleet mode is a pure
scheduling transform, not an algorithm change.

Timing: interleaved min-of-reps, same estimator as ccmlb_spec.py (this
single-core VM shows 30-40%% wall drift between identical runs).

Bars: the fleet must beat the solo loop (FLEET_FLOOR, hard-asserted in
full mode).  The FLEET_TARGET of 5x aggregate throughput from the PR
brief is recorded and warned on when missed: on this CPU-only host the
solo engine's numpy scoring costs about the same as the fleet's compiled
launch share, and the costs both sides must pay identically for
trajectory parity — gossip network construction, work lists, cluster
rebuilds and transfer commits — dominate the iteration, so the measured
ratio sits near 1.2-1.4x (see kernels/ccm_scorer/README.md).  Quick mode
(CI) asserts identity but only warns on both bars.

Usage:  PYTHONPATH=src python benchmarks/ccmlb_fleet.py [--quick]
"""
from __future__ import annotations

import json
import os
import sys
import time

import numpy as np

from repro.core import CCMParams, ccm_lb, ccm_lb_many
from repro.core.problem import initial_assignment, random_phase
from repro.kernels.ccm_scorer import jit as scorer_jit

JSON_PATH = os.environ.get("BENCH_CCMLB_FLEET_JSON", "BENCH_ccmlb_fleet.json")
INSTANCES = 64
QUICK_INSTANCES = 8
N_ITER = 8
QUICK_N_ITER = 4
REPS = 2
QUICK_REPS = 1
FLEET_FLOOR = 1.0   # hard bar: fleet must beat the solo loop
FLEET_TARGET = 5.0  # PR-brief target: recorded, warned on when missed


def run(report, quick: bool = False):
    quick = quick or os.environ.get("BENCH_QUICK") == "1"
    n = QUICK_INSTANCES if quick else INSTANCES
    n_iter = QUICK_N_ITER if quick else N_ITER
    reps = QUICK_REPS if quick else REPS
    tasks = 200 if quick else 400
    params = CCMParams(delta=1e-9)
    kw = dict(n_iter=n_iter, k_rounds=2, fanout=8, max_candidates=12)
    phases = [random_phase(1000 + i, num_ranks=16, num_tasks=tasks,
                           num_blocks=24, num_comms=4 * tasks, mem_cap=1e12)
              for i in range(n)]
    a0s = [initial_assignment(p) for p in phases]

    t0 = time.perf_counter()
    scorer_jit.spec_warmup(window=n)
    warmup_seconds = time.perf_counter() - t0

    # prime both sides untimed: compiles every vmap bucket the fleet
    # touches and pins the parity tier (per-instance assignment AND
    # transfer-log identity vs the solo engine trajectory)
    tc0 = scorer_jit.trace_count()
    fleet = ccm_lb_many(phases, a0s, params, seed=0, **kw)
    fleet_compiles = scorer_jit.trace_count() - tc0
    solos = [ccm_lb(phases[i], a0s[i], params, seed=i, use_engine=True, **kw)
             for i in range(n)]
    for i in range(n):
        assert np.array_equal(fleet[i].assignment, solos[i].assignment), \
            f"fleet instance {i} diverged from its solo engine run"
        assert fleet[i].transfer_log == solos[i].transfer_log, \
            f"fleet instance {i} transfer log diverged from solo"

    fleet_times, solo_times = [], []
    tc0 = scorer_jit.trace_count()
    for rep in range(reps):
        legs = [("fleet", None), ("solo", None)]
        if rep % 2:
            legs.reverse()
        for tag, _ in legs:
            t0 = time.perf_counter()
            if tag == "fleet":
                ccm_lb_many(phases, a0s, params, seed=0, **kw)
                fleet_times.append(time.perf_counter() - t0)
            else:
                for i in range(n):
                    ccm_lb(phases[i], a0s[i], params, seed=i,
                           use_engine=True, **kw)
                solo_times.append(time.perf_counter() - t0)
    timed_compiles = scorer_jit.trace_count() - tc0

    fleet_dt = min(fleet_times)
    solo_dt = min(solo_times)
    ratio = solo_dt / fleet_dt
    # aggregate throughput: balancer iterations completed per wall second,
    # summed over the fleet
    fleet_tput = n * n_iter / fleet_dt
    solo_tput = n * n_iter / solo_dt
    payload = {
        "benchmark": "ccmlb_fleet",
        "quick": quick,
        "instances": n,
        "ranks": 16,
        "tasks": tasks,
        "n_iter": n_iter,
        "reps": reps,
        "window": n,
        "mode": "vmap",
        "numpy": np.__version__,
        "fleet_seconds": fleet_dt,
        "fleet_seconds_reps": [round(t, 4) for t in fleet_times],
        "solo_seconds": solo_dt,
        "solo_seconds_reps": [round(t, 4) for t in solo_times],
        "fleet_iterations_per_second": fleet_tput,
        "solo_iterations_per_second": solo_tput,
        "fleet_speedup_over_solo": ratio,
        "transfers": int(sum(r.transfers for r in fleet)),
        "spec_rollbacks": int(sum(r.spec_rollbacks for r in fleet)),
        "spec_windows": int(sum(r.spec_windows for r in fleet)),
        "identical_trajectories": True,
        "fleet_floor": FLEET_FLOOR,
        "fleet_target": FLEET_TARGET,
        "fleet_target_met": ratio >= FLEET_TARGET,
        "fleet_compiles_prime_run": fleet_compiles,
        "compiles_timed_runs": timed_compiles,
        "trace_count": scorer_jit.trace_count(),
        "jit_buckets_compiled": scorer_jit.bucket_cache_size(),
        "warmup_seconds": warmup_seconds,
    }
    with open(JSON_PATH, "w") as f:
        json.dump(payload, f, indent=2)

    report(f"ccmlb_fleet_{n}x_fleet", fleet_dt * 1e6,
           f"{fleet_tput:.1f} iter/s, launches={payload['spec_windows']}")
    report(f"ccmlb_fleet_{n}x_solo_loop", solo_dt * 1e6,
           f"{solo_tput:.1f} iter/s")
    report("ccmlb_fleet_speedup", 0.0,
           f"{ratio:.2f}x aggregate throughput, trajectories identical")
    report("ccmlb_fleet_json", 0.0, f"written to {JSON_PATH}")
    if ratio < FLEET_TARGET:
        report("ccmlb_fleet_TARGET", 0.0,
               f"fleet speedup {ratio:.2f}x under the {FLEET_TARGET}x "
               "target (parity-shared host costs dominate on this CPU-only "
               "host; see kernels/ccm_scorer/README.md)")
    if ratio < FLEET_FLOOR:
        msg = (f"fleet speedup {ratio:.2f}x under the {FLEET_FLOOR}x floor "
               "vs the solo engine loop")
        if quick:
            report("ccmlb_fleet_WARN", 0.0, f"{msg} (quick mode: warning "
                   "only — shared-runner wall times)")
        else:
            raise AssertionError(msg)


def main():
    quick = "--quick" in sys.argv
    print("name,us_per_call,derived")

    def report(name, us, derived=""):
        print(f"{name},{us:.1f},{derived}", flush=True)

    run(report, quick=quick)


if __name__ == "__main__":
    main()
