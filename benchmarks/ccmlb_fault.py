"""Fault-injection sweep for the async CCM-LB protocol (robustness bars).

Per rank count this runs the ccmlb_scaling instance through the hardened
event-loop driver (``repro/core/async_sim.py``) under seeded faults:

  * ``fault_free`` / ``inactive_spec`` — the reference run and the same
    run with an all-zero :class:`FaultSpec`, ASSERTED bitwise-identical
    (assignment + transfer sequence + work traces): the harness itself
    costs nothing when no fault fires;
  * ``drop_*`` — a message-loss sweep.  For drop rates <= 1% the final
    balance quality (Wmax / mean) is ASSERTED within ``QUALITY_BAR`` =
    1.15x of the fault-free run; higher rates are recorded (timeouts,
    retries exhausted, wedged-lock reclaims, message overhead) without a
    quality bar;
  * ``dup`` / ``reorder`` / ``combined`` — duplication and reordering
    storms: the idempotence counters (duplicate requests ignored, stale
    grants/releases discarded) must fire and the run must stay safe;
  * ``pause`` — a rank frozen for a sim-time window mid-iteration
    (deferred deliveries, then catch-up);
  * ``crash`` / ``crash_lossy`` — ranks killed mid-iteration: locks
    reclaimed, work migrated off the dead ranks, survivors finish;
  * ``partition_*`` — split-brain windows: a gossip-stage split keeps
    work lists island-local (cross-island summaries never arrive), a
    stage-2 split exercises the partition-aware decision skip
    (``partition_skips``); healed partitions must re-merge, reach
    quiescence, and stay within ``QUALITY_BAR`` of fault-free; a
    never-healing partition is recorded without a bar;
  * ``corrupt_*`` — seeded gossip-payload mutation: every corrupted
    payload must be caught by the checksum/stamp validation
    (``corrupted == corrupt_quarantined`` asserted), and <= 1%
    corruption stays within ``QUALITY_BAR``;
  * ``crash_stage1`` — a root killed MID-EPIDEMIC: the flood must not
    wedge, the epoch-keyed quiesce caches are purged, survivors finish;
  * ``join`` / ``crash_then_join`` — membership growth: fresh ranks
    join mid-stream, inherit gossip state through the ordinary flood
    and end up owning tasks; combined with a crash, the mesh shrinks
    then re-grows within one run.

Every faulted record passes the same invariant gate: the transfer log
replays from the initial assignment to the final one, the final
assignment is memory-feasible on the FINAL (possibly expanded) phase,
and no task lands on a dead rank.

Results land in ``BENCH_ccmlb_fault.json``.

Standalone:  PYTHONPATH=src python benchmarks/ccmlb_fault.py [--quick]
[--fault-seed-offset N]
(--quick runs the 16-rank configs for CI; --fault-seed-offset shifts
every FaultSpec seed so CI can sweep fault randomness — the invariant
gate and quality bars are asserted for every offset; also wired into
benchmarks/run.py as ``ccmlb_fault``.)
"""
from __future__ import annotations

import json
import os
import sys
import time

import numpy as np

from repro.core import CCMParams, FaultSpec, RankJoin, ccm_lb_async
from repro.core.ccm import CCMState
from repro.core.problem import initial_assignment, scaling_phase

JSON_PATH = os.environ.get("BENCH_CCMLB_FAULT_JSON", "BENCH_ccmlb_fault.json")
N_ITER = 4
LAT = ("uniform", 0.5, 1.5)
QUALITY_BAR = 1.15          # faulted / fault-free Wmax ratio, low severity
DROP_SWEEP = (0.002, 0.005, 0.01, 0.02, 0.05)
CORRUPT_SWEEP = (0.005, 0.01, 0.05, 0.2)
SEED_OFFSET = 0             # --fault-seed-offset: shifts every fault seed

PARAMS = CCMParams(delta=1e-9)
_instance = scaling_phase   # same instances as the async/scaling benches


def _seed(base: int) -> int:
    return base + SEED_OFFSET


def _check_invariants(phase, a0, res, tag):
    """The safety gate every faulted run must pass: log replay, memory
    feasibility, nothing stranded on a dead rank.  Feasibility is
    checked on ``res.state.phase`` — the FINAL phase, which membership
    joins may have expanded past the input ``phase``."""
    replay = np.asarray(a0, np.int64).copy()
    for tasks, r_from, r_to in res.transfer_log:
        idx = np.asarray(tasks, np.int64)
        assert (replay[idx] == r_from).all(), f"{tag}: replay diverged"
        replay[idx] = r_to
    assert np.array_equal(replay, res.assignment), f"{tag}: log incomplete"
    fphase = res.state.phase
    final = CCMState.build(fphase, res.assignment, PARAMS)
    for r in range(fphase.num_ranks):
        assert final.memory_feasible(r), f"{tag}: rank {r} over memory"
    for r in (res.dead_ranks or ()):
        assert not (res.assignment == r).any(), \
            f"{tag}: tasks left on dead rank {r}"


def _quality(res, phase):
    return float(res.max_work[-1] / (phase.task_load.sum() / phase.num_ranks))


def _record(records, tag, ranks, phase, res, seconds, ref=None, **extra):
    fs = res.fault_stats
    records.append({
        "config": tag,
        "ranks": ranks,
        "n_iter": N_ITER,
        "seconds": seconds,
        "max_work_over_mean": _quality(res, phase),
        "imbalance_after": float(res.imbalance[-1]),
        "transfers": int(res.transfers),
        "messages": int(res.messages),
        "timeouts": int(res.timeouts),
        "retries_exhausted": int(res.retries_exhausted),
        **({} if ref is None else {
            "quality_vs_fault_free":
                _quality(res, phase) / _quality(ref, phase),
            "message_overhead": res.messages / max(ref.messages, 1),
        }),
        **({} if fs is None else {
            "dropped": fs.dropped,
            "duplicated": fs.duplicated,
            "reordered": fs.reordered,
            "dup_requests_ignored": fs.dup_requests,
            "stale_grants": fs.stale_grants,
            "stale_releases": fs.stale_releases,
            "wedged_reclaimed": fs.wedged_reclaimed,
            "paused_deferrals": fs.paused_deferrals,
            "killed": fs.killed,
            "recovered_tasks": fs.recovered_tasks,
            "partitioned_dropped": fs.partitioned_dropped,
            "partition_skips": fs.partition_skips,
            "corrupted": fs.corrupted,
            "corrupt_quarantined": fs.corrupt_quarantined,
        }),
        **({} if not res.dead_ranks else {"dead_ranks": res.dead_ranks}),
        **extra,
    })


def _run(phase, a0, fault, **over):
    kw = dict(n_iter=N_ITER, k_rounds=2, fanout=4, seed=0, latency=LAT)
    kw.update(over)
    t0 = time.perf_counter()
    res = ccm_lb_async(phase, a0, PARAMS, fault=fault, **kw)
    return res, time.perf_counter() - t0


def _sweep_ranks(report, records, ranks: int):
    phase = _instance(ranks)
    a0 = initial_assignment(phase)

    ref, ref_s = _run(phase, a0, None)
    _record(records, "fault_free", ranks, phase, ref, ref_s)
    report(f"ccmlb_fault_ranks_{ranks}_fault_free", ref_s * 1e6,
           f"wmax/mean={_quality(ref, phase):.4f} msgs={ref.messages}")

    # harness bar: an inactive spec is bitwise-identical to fault=None
    noop, noop_s = _run(phase, a0, FaultSpec())
    bitwise = bool(np.array_equal(noop.assignment, ref.assignment)
                   and noop.transfer_log == ref.transfer_log
                   and noop.max_work == ref.max_work)
    assert bitwise, f"inactive FaultSpec perturbed the run @{ranks}"
    _record(records, "inactive_spec", ranks, phase, noop, noop_s,
            bitwise_identical_to_fault_free=True)
    report(f"ccmlb_fault_ranks_{ranks}_inactive_spec", noop_s * 1e6,
           "bitwise==fault_free")

    for drop in DROP_SWEEP:
        spec = FaultSpec(drop=drop, req_timeout=4.0, seed=_seed(7))
        res, dt = _run(phase, a0, spec)
        _check_invariants(phase, a0, res, f"drop_{drop}@{ranks}")
        q_ratio = _quality(res, phase) / _quality(ref, phase)
        if drop <= 0.01:    # acceptance bar: modest loss, near-full quality
            assert q_ratio <= QUALITY_BAR, \
                f"drop={drop} quality {q_ratio:.3f}x > {QUALITY_BAR}x @{ranks}"
        _record(records, f"drop_{drop:g}", ranks, phase, res, dt, ref=ref,
                drop=drop, quality_bar=QUALITY_BAR if drop <= 0.01 else None)
        report(f"ccmlb_fault_ranks_{ranks}_drop_{drop:g}", dt * 1e6,
               f"quality={q_ratio:.3f}x dropped={res.fault_stats.dropped} "
               f"timeouts={res.timeouts} exhausted={res.retries_exhausted} "
               f"wedged={res.fault_stats.wedged_reclaimed}")

    for tag, spec in (
            ("dup", FaultSpec(dup=0.2, seed=_seed(11))),
            ("reorder", FaultSpec(reorder=0.2, reorder_scale=2.0, seed=_seed(12))),
            ("combined", FaultSpec(drop=0.01, dup=0.1, reorder=0.1,
                                   req_timeout=4.0, seed=_seed(13)))):
        res, dt = _run(phase, a0, spec)
        _check_invariants(phase, a0, res, f"{tag}@{ranks}")
        fs = res.fault_stats
        if tag in ("dup", "combined"):      # idempotence layer must fire
            assert fs.duplicated > 0 and (
                fs.dup_requests + fs.stale_grants + fs.stale_releases) > 0, \
                f"{tag}@{ranks}: no duplicate absorbed"
        if tag in ("reorder", "combined"):
            assert fs.reordered > 0, f"{tag}@{ranks}: nothing reordered"
        _record(records, tag, ranks, phase, res, dt, ref=ref)
        report(f"ccmlb_fault_ranks_{ranks}_{tag}", dt * 1e6,
               f"quality={_quality(res, phase) / _quality(ref, phase):.3f}x "
               f"dup={fs.duplicated} reord={fs.reordered} "
               f"stale_g={fs.stale_grants} stale_r={fs.stale_releases}")


def _pause_config(report, records, ranks: int):
    phase = _instance(ranks)
    a0 = initial_assignment(phase)
    ref, _ = _run(phase, a0, None)
    spec = FaultSpec(pause=((1, 1, 0.5, 6.0),), seed=_seed(17))
    res, dt = _run(phase, a0, spec)
    _check_invariants(phase, a0, res, f"pause@{ranks}")
    assert res.fault_stats.paused_deferrals > 0, "pause window never hit"
    _record(records, "pause", ranks, phase, res, dt, ref=ref)
    report(f"ccmlb_fault_pause_{ranks}", dt * 1e6,
           f"deferrals={res.fault_stats.paused_deferrals} "
           f"quality={_quality(res, phase) / _quality(ref, phase):.3f}x")


def _crash_configs(report, records, ranks: int):
    phase = _instance(ranks)
    a0 = initial_assignment(phase)
    ref, _ = _run(phase, a0, None)
    for tag, spec in (
            ("crash", FaultSpec(kill=((3, 1, 0.5),), seed=_seed(19))),
            ("crash_lossy", FaultSpec(drop=0.01, kill=((3, 1, 0.5),),
                                      req_timeout=4.0, seed=_seed(23)))):
        res, dt = _run(phase, a0, spec)
        _check_invariants(phase, a0, res, f"{tag}@{ranks}")
        assert res.dead_ranks == [3], f"{tag}@{ranks}: wrong dead set"
        assert res.fault_stats.recovered_tasks > 0, \
            f"{tag}@{ranks}: nothing migrated off the dead rank"
        _record(records, tag, ranks, phase, res, dt, ref=ref)
        report(f"ccmlb_fault_{tag}_{ranks}", dt * 1e6,
               f"dead={res.dead_ranks} "
               f"recovered={res.fault_stats.recovered_tasks} "
               f"reclaimed={res.fault_stats.reclaimed_locks} "
               f"quality={_quality(res, phase) / _quality(ref, phase):.3f}x")


def _bitwise_only(report, records, ranks: int):
    """The zero-fault bar at scale: no drop sweep (each faulted 256-rank
    run costs minutes), just fault_free vs inactive-spec bitwise."""
    phase = _instance(ranks)
    a0 = initial_assignment(phase)
    ref, ref_s = _run(phase, a0, None)
    noop, noop_s = _run(phase, a0, FaultSpec())
    assert (np.array_equal(noop.assignment, ref.assignment)
            and noop.transfer_log == ref.transfer_log
            and noop.max_work == ref.max_work), \
        f"inactive FaultSpec perturbed the run @{ranks}"
    _record(records, "fault_free", ranks, phase, ref, ref_s)
    _record(records, "inactive_spec", ranks, phase, noop, noop_s,
            bitwise_identical_to_fault_free=True)
    report(f"ccmlb_fault_ranks_{ranks}_inactive_spec", noop_s * 1e6,
           "bitwise==fault_free")


def _partition_configs(report, records, ranks: int):
    phase = _instance(ranks)
    a0 = initial_assignment(phase)
    ref, _ = _run(phase, a0, None, collect_trace=True)
    half = ranks // 2
    isl_a, isl_b = tuple(range(half)), tuple(range(half, ranks))

    # (a) gossip-stage split that heals: cross-island summaries never
    # arrive while severed, so each island balances locally; after the
    # window closes the mesh re-merges and must reach quiescence.
    spec = FaultSpec(partition=((isl_a, isl_b, 0, 0.0, 25.0),),
                     seed=_seed(29))
    res, dt = _run(phase, a0, spec, n_iter=N_ITER + 6, quiesce_after=2)
    _check_invariants(phase, a0, res, f"partition_healed@{ranks}")
    fs = res.fault_stats
    assert fs.partitioned_dropped > 0, \
        f"partition_healed@{ranks}: window never severed a message"
    q_ratio = _quality(res, phase) / _quality(ref, phase)
    assert q_ratio <= QUALITY_BAR, \
        f"partition_healed@{ranks}: quality {q_ratio:.3f}x > {QUALITY_BAR}x"
    assert list(res.iter_transfers[-2:]) == [0, 0], \
        f"partition_healed@{ranks}: no quiescence after heal " \
        f"(iter_transfers={res.iter_transfers})"
    _record(records, "partition_healed", ranks, phase, res, dt, ref=ref,
            quality_bar=QUALITY_BAR, quiesced_after_heal=True)
    report(f"ccmlb_fault_partition_healed_{ranks}", dt * 1e6,
           f"severed={fs.partitioned_dropped} quality={q_ratio:.3f}x "
           f"iters={len(res.iter_transfers)}")

    # (b) stage-2-only split that never heals: gossip drains first, so
    # the work lists are global and the DECIDE-time partition skip has
    # to fire.  Degraded quality is recorded without a bar.
    t_open = min(t for t, _, k, _, _ in ref.events if k == "DECIDE") - 0.01
    spec = FaultSpec(partition=((isl_a, isl_b, 0, t_open, 1e9),),
                     seed=_seed(5))
    res, dt = _run(phase, a0, spec)
    _check_invariants(phase, a0, res, f"partition_stage2@{ranks}")
    fs = res.fault_stats
    assert fs.partition_skips > 0, \
        f"partition_stage2@{ranks}: decision-time skip never fired"
    _record(records, "partition_stage2_unhealed", ranks, phase, res, dt,
            ref=ref, quality_bar=None)
    report(f"ccmlb_fault_partition_stage2_{ranks}", dt * 1e6,
           f"skips={fs.partition_skips} severed={fs.partitioned_dropped} "
           f"exhausted={res.retries_exhausted}")


def _corruption_configs(report, records, ranks: int):
    phase = _instance(ranks)
    a0 = initial_assignment(phase)
    ref, _ = _run(phase, a0, None)
    for rate in CORRUPT_SWEEP:
        spec = FaultSpec(corrupt=rate, seed=_seed(6))
        res, dt = _run(phase, a0, spec)
        _check_invariants(phase, a0, res, f"corrupt_{rate}@{ranks}")
        fs = res.fault_stats
        assert fs.corrupted > 0, \
            f"corrupt_{rate}@{ranks}: no payload ever mutated"
        assert fs.corrupted == fs.corrupt_quarantined, \
            f"corrupt_{rate}@{ranks}: {fs.corrupted} corrupted but only " \
            f"{fs.corrupt_quarantined} quarantined — validation leaked"
        q_ratio = _quality(res, phase) / _quality(ref, phase)
        if rate <= 0.01:
            assert q_ratio <= QUALITY_BAR, \
                f"corrupt={rate} quality {q_ratio:.3f}x > {QUALITY_BAR}x"
        _record(records, f"corrupt_{rate:g}", ranks, phase, res, dt, ref=ref,
                corrupt_rate=rate,
                quality_bar=QUALITY_BAR if rate <= 0.01 else None)
        report(f"ccmlb_fault_ranks_{ranks}_corrupt_{rate:g}", dt * 1e6,
               f"quality={q_ratio:.3f}x corrupted={fs.corrupted} "
               f"quarantined={fs.corrupt_quarantined}")


def _stage1_kill_config(report, records, ranks: int):
    phase = _instance(ranks)
    a0 = initial_assignment(phase)
    ref, _ = _run(phase, a0, None)
    spec = FaultSpec(kill=((3, 1, 0.5, 1),), seed=_seed(7))
    res, dt = _run(phase, a0, spec)
    _check_invariants(phase, a0, res, f"crash_stage1@{ranks}")
    assert res.dead_ranks == [3], f"crash_stage1@{ranks}: wrong dead set"
    assert res.fault_stats.recovered_tasks > 0, \
        f"crash_stage1@{ranks}: nothing migrated off the dead root"
    _record(records, "crash_stage1", ranks, phase, res, dt, ref=ref)
    report(f"ccmlb_fault_crash_stage1_{ranks}", dt * 1e6,
           f"dead={res.dead_ranks} "
           f"recovered={res.fault_stats.recovered_tasks} "
           f"quality={_quality(res, phase) / _quality(ref, phase):.3f}x")


def _join_configs(report, records, ranks: int):
    phase = _instance(ranks)
    a0 = initial_assignment(phase)

    # (a) two fresh ranks join mid-stream and must attract real work
    res, dt = _run(phase, a0, None,
                   membership=(RankJoin(iteration=1, count=2),))
    _check_invariants(phase, a0, res, f"join@{ranks}")
    assert res.joined_ranks == [ranks, ranks + 1], \
        f"join@{ranks}: wrong joined set {res.joined_ranks}"
    on_joined = int(sum((res.assignment == r).sum()
                        for r in res.joined_ranks))
    assert on_joined > 0, f"join@{ranks}: joiners attracted no tasks"
    _record(records, "join", ranks, res.state.phase, res, dt,
            joined_ranks=res.joined_ranks, tasks_on_joined=on_joined)
    report(f"ccmlb_fault_join_{ranks}", dt * 1e6,
           f"joined={res.joined_ranks} tasks_on_joined={on_joined} "
           f"wmax/mean={_quality(res, res.state.phase):.4f}")

    # (b) shrink then re-grow in one run: a crash at iteration 1, a
    # replacement rank joining at iteration 2
    spec = FaultSpec(kill=((3, 1, 0.5),), seed=_seed(31))
    res, dt = _run(phase, a0, spec,
                   membership=(RankJoin(iteration=2, count=1),))
    _check_invariants(phase, a0, res, f"crash_then_join@{ranks}")
    assert res.dead_ranks == [3], \
        f"crash_then_join@{ranks}: wrong dead set"
    assert res.joined_ranks == [ranks], \
        f"crash_then_join@{ranks}: wrong joined set {res.joined_ranks}"
    assert res.fault_stats.recovered_tasks > 0, \
        f"crash_then_join@{ranks}: nothing migrated off the dead rank"
    on_joined = int((res.assignment == ranks).sum())
    _record(records, "crash_then_join", ranks, res.state.phase, res, dt,
            joined_ranks=res.joined_ranks, tasks_on_joined=on_joined)
    report(f"ccmlb_fault_crash_then_join_{ranks}", dt * 1e6,
           f"dead={res.dead_ranks} joined={res.joined_ranks} "
           f"tasks_on_joined={on_joined} "
           f"recovered={res.fault_stats.recovered_tasks}")


def run(report, quick: bool = False):
    records = []
    for ranks in ((16,) if quick else (16, 64)):
        _sweep_ranks(report, records, ranks)
    if not quick:
        _bitwise_only(report, records, 256)
    _pause_config(report, records, 16)
    _crash_configs(report, records, 16 if quick else 64)
    _partition_configs(report, records, 16)
    _corruption_configs(report, records, 16)
    _stage1_kill_config(report, records, 16 if quick else 64)
    _join_configs(report, records, 16)

    drops = [r for r in records if r["config"].startswith("drop_")
             and r.get("drop", 1.0) <= 0.01]
    corrupts = [r for r in records if r["config"].startswith("corrupt_")]
    low_corrupts = [r for r in corrupts if r["corrupt_rate"] <= 0.01]
    joins = [r for r in records if "tasks_on_joined" in r]
    healed = [r for r in records if r["config"] == "partition_healed"]
    payload = {
        "benchmark": "ccmlb_fault",
        "quick": quick,
        "numpy": np.__version__,
        "n_iter": N_ITER,
        "quality_bar": QUALITY_BAR,
        "fault_seed_offset": SEED_OFFSET,
        "results": records,
        "corrupt_validation_ok": all(
            r["corrupted"] == r["corrupt_quarantined"] for r in corrupts),
        "low_corrupt_quality_worst": max(
            r["quality_vs_fault_free"] for r in low_corrupts),
        "low_corrupt_quality_ok": all(
            r["quality_vs_fault_free"] <= QUALITY_BAR for r in low_corrupts),
        "partition_heal_quality_worst": max(
            r["quality_vs_fault_free"] for r in healed),
        "partition_heal_quiesced": all(
            r.get("quiesced_after_heal", False) for r in healed),
        "partition_skips_exercised": any(
            r["partition_skips"] > 0 for r in records
            if "partition_skips" in r),
        "join_tasks_on_new_ranks": sum(
            r["tasks_on_joined"] for r in joins),
        "inactive_spec_bitwise_ok": all(
            r.get("bitwise_identical_to_fault_free", True) for r in records),
        "low_drop_quality_worst": max(
            r["quality_vs_fault_free"] for r in drops),
        "low_drop_quality_ok": all(
            r["quality_vs_fault_free"] <= QUALITY_BAR for r in drops),
        "max_timeouts": max(r["timeouts"] for r in records),
        "max_retries_exhausted": max(r["retries_exhausted"] for r in records),
        "total_recovered_tasks": sum(
            r.get("recovered_tasks", 0) for r in records),
    }
    with open(JSON_PATH, "w") as f:
        json.dump(payload, f, indent=2)
    report("ccmlb_fault_json", 0.0, f"written to {JSON_PATH}")


def main():
    global SEED_OFFSET
    quick = "--quick" in sys.argv
    if "--fault-seed-offset" in sys.argv:
        SEED_OFFSET = int(sys.argv[sys.argv.index("--fault-seed-offset") + 1])
    print("name,us_per_call,derived")

    def report(name, us, derived=""):
        print(f"{name},{us:.1f},{derived}", flush=True)

    run(report, quick=quick)
    # CI smoke assertions over the emitted JSON (the invariant gate and
    # quality bars are asserted in-bench; these pin the headline fields)
    with open(JSON_PATH) as f:
        payload = json.load(f)
    assert payload["inactive_spec_bitwise_ok"]
    assert payload["low_drop_quality_ok"]
    assert payload["low_drop_quality_worst"] <= payload["quality_bar"]
    assert payload["max_timeouts"] > 0          # loss really exercised retry
    assert payload["total_recovered_tasks"] > 0
    assert payload["corrupt_validation_ok"]
    assert payload["low_corrupt_quality_ok"]
    assert payload["partition_heal_quality_worst"] <= payload["quality_bar"]
    assert payload["partition_heal_quiesced"]
    assert payload["partition_skips_exercised"]
    assert payload["join_tasks_on_new_ranks"] > 0
    print("ccmlb_fault_ok,0.0,bitwise+quality+recovery+partition+corrupt"
          "+join checks passed", flush=True)


if __name__ == "__main__":
    main()
