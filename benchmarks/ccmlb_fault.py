"""Fault-injection sweep for the async CCM-LB protocol (robustness bars).

Per rank count this runs the ccmlb_scaling instance through the hardened
event-loop driver (``repro/core/async_sim.py``) under seeded faults:

  * ``fault_free`` / ``inactive_spec`` — the reference run and the same
    run with an all-zero :class:`FaultSpec`, ASSERTED bitwise-identical
    (assignment + transfer sequence + work traces): the harness itself
    costs nothing when no fault fires;
  * ``drop_*`` — a message-loss sweep.  For drop rates <= 1% the final
    balance quality (Wmax / mean) is ASSERTED within ``QUALITY_BAR`` =
    1.15x of the fault-free run; higher rates are recorded (timeouts,
    retries exhausted, wedged-lock reclaims, message overhead) without a
    quality bar;
  * ``dup`` / ``reorder`` / ``combined`` — duplication and reordering
    storms: the idempotence counters (duplicate requests ignored, stale
    grants/releases discarded) must fire and the run must stay safe;
  * ``pause`` — a rank frozen for a sim-time window mid-iteration
    (deferred deliveries, then catch-up);
  * ``crash`` / ``crash_lossy`` — ranks killed mid-iteration: locks
    reclaimed, work migrated off the dead ranks, survivors finish.

Every faulted record passes the same invariant gate: the transfer log
replays from the initial assignment to the final one, the final
assignment is memory-feasible, and no task lands on a dead rank.

Results land in ``BENCH_ccmlb_fault.json``.

Standalone:  PYTHONPATH=src python benchmarks/ccmlb_fault.py [--quick]
(--quick runs the 16-rank configs for CI; also wired into
benchmarks/run.py as ``ccmlb_fault``.)
"""
from __future__ import annotations

import json
import os
import sys
import time

import numpy as np

from repro.core import CCMParams, FaultSpec, ccm_lb_async
from repro.core.ccm import CCMState
from repro.core.problem import initial_assignment, scaling_phase

JSON_PATH = os.environ.get("BENCH_CCMLB_FAULT_JSON", "BENCH_ccmlb_fault.json")
N_ITER = 4
LAT = ("uniform", 0.5, 1.5)
QUALITY_BAR = 1.15          # faulted / fault-free Wmax ratio, drop <= 1%
DROP_SWEEP = (0.002, 0.005, 0.01, 0.02, 0.05)

PARAMS = CCMParams(delta=1e-9)
_instance = scaling_phase   # same instances as the async/scaling benches


def _check_invariants(phase, a0, res, tag):
    """The safety gate every faulted run must pass: log replay, memory
    feasibility, nothing stranded on a dead rank."""
    replay = np.asarray(a0, np.int64).copy()
    for tasks, r_from, r_to in res.transfer_log:
        idx = np.asarray(tasks, np.int64)
        assert (replay[idx] == r_from).all(), f"{tag}: replay diverged"
        replay[idx] = r_to
    assert np.array_equal(replay, res.assignment), f"{tag}: log incomplete"
    final = CCMState.build(phase, res.assignment, PARAMS)
    for r in range(phase.num_ranks):
        assert final.memory_feasible(r), f"{tag}: rank {r} over memory"
    for r in (res.dead_ranks or ()):
        assert not (res.assignment == r).any(), \
            f"{tag}: tasks left on dead rank {r}"


def _quality(res, phase):
    return float(res.max_work[-1] / (phase.task_load.sum() / phase.num_ranks))


def _record(records, tag, ranks, phase, res, seconds, ref=None, **extra):
    fs = res.fault_stats
    records.append({
        "config": tag,
        "ranks": ranks,
        "n_iter": N_ITER,
        "seconds": seconds,
        "max_work_over_mean": _quality(res, phase),
        "imbalance_after": float(res.imbalance[-1]),
        "transfers": int(res.transfers),
        "messages": int(res.messages),
        "timeouts": int(res.timeouts),
        "retries_exhausted": int(res.retries_exhausted),
        **({} if ref is None else {
            "quality_vs_fault_free":
                _quality(res, phase) / _quality(ref, phase),
            "message_overhead": res.messages / max(ref.messages, 1),
        }),
        **({} if fs is None else {
            "dropped": fs.dropped,
            "duplicated": fs.duplicated,
            "reordered": fs.reordered,
            "dup_requests_ignored": fs.dup_requests,
            "stale_grants": fs.stale_grants,
            "stale_releases": fs.stale_releases,
            "wedged_reclaimed": fs.wedged_reclaimed,
            "paused_deferrals": fs.paused_deferrals,
            "killed": fs.killed,
            "recovered_tasks": fs.recovered_tasks,
        }),
        **({} if not res.dead_ranks else {"dead_ranks": res.dead_ranks}),
        **extra,
    })


def _run(phase, a0, fault, **over):
    kw = dict(n_iter=N_ITER, k_rounds=2, fanout=4, seed=0, latency=LAT)
    kw.update(over)
    t0 = time.perf_counter()
    res = ccm_lb_async(phase, a0, PARAMS, fault=fault, **kw)
    return res, time.perf_counter() - t0


def _sweep_ranks(report, records, ranks: int):
    phase = _instance(ranks)
    a0 = initial_assignment(phase)

    ref, ref_s = _run(phase, a0, None)
    _record(records, "fault_free", ranks, phase, ref, ref_s)
    report(f"ccmlb_fault_ranks_{ranks}_fault_free", ref_s * 1e6,
           f"wmax/mean={_quality(ref, phase):.4f} msgs={ref.messages}")

    # harness bar: an inactive spec is bitwise-identical to fault=None
    noop, noop_s = _run(phase, a0, FaultSpec())
    bitwise = bool(np.array_equal(noop.assignment, ref.assignment)
                   and noop.transfer_log == ref.transfer_log
                   and noop.max_work == ref.max_work)
    assert bitwise, f"inactive FaultSpec perturbed the run @{ranks}"
    _record(records, "inactive_spec", ranks, phase, noop, noop_s,
            bitwise_identical_to_fault_free=True)
    report(f"ccmlb_fault_ranks_{ranks}_inactive_spec", noop_s * 1e6,
           "bitwise==fault_free")

    for drop in DROP_SWEEP:
        spec = FaultSpec(drop=drop, req_timeout=4.0, seed=7)
        res, dt = _run(phase, a0, spec)
        _check_invariants(phase, a0, res, f"drop_{drop}@{ranks}")
        q_ratio = _quality(res, phase) / _quality(ref, phase)
        if drop <= 0.01:    # acceptance bar: modest loss, near-full quality
            assert q_ratio <= QUALITY_BAR, \
                f"drop={drop} quality {q_ratio:.3f}x > {QUALITY_BAR}x @{ranks}"
        _record(records, f"drop_{drop:g}", ranks, phase, res, dt, ref=ref,
                drop=drop, quality_bar=QUALITY_BAR if drop <= 0.01 else None)
        report(f"ccmlb_fault_ranks_{ranks}_drop_{drop:g}", dt * 1e6,
               f"quality={q_ratio:.3f}x dropped={res.fault_stats.dropped} "
               f"timeouts={res.timeouts} exhausted={res.retries_exhausted} "
               f"wedged={res.fault_stats.wedged_reclaimed}")

    for tag, spec in (
            ("dup", FaultSpec(dup=0.2, seed=11)),
            ("reorder", FaultSpec(reorder=0.2, reorder_scale=2.0, seed=12)),
            ("combined", FaultSpec(drop=0.01, dup=0.1, reorder=0.1,
                                   req_timeout=4.0, seed=13))):
        res, dt = _run(phase, a0, spec)
        _check_invariants(phase, a0, res, f"{tag}@{ranks}")
        fs = res.fault_stats
        if tag in ("dup", "combined"):      # idempotence layer must fire
            assert fs.duplicated > 0 and (
                fs.dup_requests + fs.stale_grants + fs.stale_releases) > 0, \
                f"{tag}@{ranks}: no duplicate absorbed"
        if tag in ("reorder", "combined"):
            assert fs.reordered > 0, f"{tag}@{ranks}: nothing reordered"
        _record(records, tag, ranks, phase, res, dt, ref=ref)
        report(f"ccmlb_fault_ranks_{ranks}_{tag}", dt * 1e6,
               f"quality={_quality(res, phase) / _quality(ref, phase):.3f}x "
               f"dup={fs.duplicated} reord={fs.reordered} "
               f"stale_g={fs.stale_grants} stale_r={fs.stale_releases}")


def _pause_config(report, records, ranks: int):
    phase = _instance(ranks)
    a0 = initial_assignment(phase)
    ref, _ = _run(phase, a0, None)
    spec = FaultSpec(pause=((1, 1, 0.5, 6.0),), seed=17)
    res, dt = _run(phase, a0, spec)
    _check_invariants(phase, a0, res, f"pause@{ranks}")
    assert res.fault_stats.paused_deferrals > 0, "pause window never hit"
    _record(records, "pause", ranks, phase, res, dt, ref=ref)
    report(f"ccmlb_fault_pause_{ranks}", dt * 1e6,
           f"deferrals={res.fault_stats.paused_deferrals} "
           f"quality={_quality(res, phase) / _quality(ref, phase):.3f}x")


def _crash_configs(report, records, ranks: int):
    phase = _instance(ranks)
    a0 = initial_assignment(phase)
    ref, _ = _run(phase, a0, None)
    for tag, spec in (
            ("crash", FaultSpec(kill=((3, 1, 0.5),), seed=19)),
            ("crash_lossy", FaultSpec(drop=0.01, kill=((3, 1, 0.5),),
                                      req_timeout=4.0, seed=23))):
        res, dt = _run(phase, a0, spec)
        _check_invariants(phase, a0, res, f"{tag}@{ranks}")
        assert res.dead_ranks == [3], f"{tag}@{ranks}: wrong dead set"
        assert res.fault_stats.recovered_tasks > 0, \
            f"{tag}@{ranks}: nothing migrated off the dead rank"
        _record(records, tag, ranks, phase, res, dt, ref=ref)
        report(f"ccmlb_fault_{tag}_{ranks}", dt * 1e6,
               f"dead={res.dead_ranks} "
               f"recovered={res.fault_stats.recovered_tasks} "
               f"reclaimed={res.fault_stats.reclaimed_locks} "
               f"quality={_quality(res, phase) / _quality(ref, phase):.3f}x")


def _bitwise_only(report, records, ranks: int):
    """The zero-fault bar at scale: no drop sweep (each faulted 256-rank
    run costs minutes), just fault_free vs inactive-spec bitwise."""
    phase = _instance(ranks)
    a0 = initial_assignment(phase)
    ref, ref_s = _run(phase, a0, None)
    noop, noop_s = _run(phase, a0, FaultSpec())
    assert (np.array_equal(noop.assignment, ref.assignment)
            and noop.transfer_log == ref.transfer_log
            and noop.max_work == ref.max_work), \
        f"inactive FaultSpec perturbed the run @{ranks}"
    _record(records, "fault_free", ranks, phase, ref, ref_s)
    _record(records, "inactive_spec", ranks, phase, noop, noop_s,
            bitwise_identical_to_fault_free=True)
    report(f"ccmlb_fault_ranks_{ranks}_inactive_spec", noop_s * 1e6,
           "bitwise==fault_free")


def run(report, quick: bool = False):
    records = []
    for ranks in ((16,) if quick else (16, 64)):
        _sweep_ranks(report, records, ranks)
    if not quick:
        _bitwise_only(report, records, 256)
    _pause_config(report, records, 16)
    _crash_configs(report, records, 16 if quick else 64)

    drops = [r for r in records if r["config"].startswith("drop_")
             and r.get("drop", 1.0) <= 0.01]
    payload = {
        "benchmark": "ccmlb_fault",
        "quick": quick,
        "numpy": np.__version__,
        "n_iter": N_ITER,
        "quality_bar": QUALITY_BAR,
        "results": records,
        "inactive_spec_bitwise_ok": all(
            r.get("bitwise_identical_to_fault_free", True) for r in records),
        "low_drop_quality_worst": max(
            r["quality_vs_fault_free"] for r in drops),
        "low_drop_quality_ok": all(
            r["quality_vs_fault_free"] <= QUALITY_BAR for r in drops),
        "max_timeouts": max(r["timeouts"] for r in records),
        "max_retries_exhausted": max(r["retries_exhausted"] for r in records),
        "total_recovered_tasks": sum(
            r.get("recovered_tasks", 0) for r in records),
    }
    with open(JSON_PATH, "w") as f:
        json.dump(payload, f, indent=2)
    report("ccmlb_fault_json", 0.0, f"written to {JSON_PATH}")


def main():
    quick = "--quick" in sys.argv
    print("name,us_per_call,derived")

    def report(name, us, derived=""):
        print(f"{name},{us:.1f},{derived}", flush=True)

    run(report, quick=quick)
    # CI smoke assertions over the emitted JSON (the invariant gate and
    # quality bars are asserted in-bench; these pin the headline fields)
    with open(JSON_PATH) as f:
        payload = json.load(f)
    assert payload["inactive_spec_bitwise_ok"]
    assert payload["low_drop_quality_ok"]
    assert payload["low_drop_quality_worst"] <= payload["quality_bar"]
    assert payload["max_timeouts"] > 0          # loss really exercised retry
    assert payload["total_recovered_tasks"] > 0
    print("ccmlb_fault_ok,0.0,bitwise+quality+recovery checks passed",
          flush=True)


if __name__ == "__main__":
    main()
