"""§Roofline: summarize the dry-run results (benchmarks/results/dryrun.json)
into the per-(arch x shape x mesh) roofline table.  The dry-run itself runs
as a separate process (512 placeholder devices); this module only reads its
artifact so `python -m benchmarks.run` stays a 1-device program."""
from __future__ import annotations

import json
from pathlib import Path

RESULTS = Path(__file__).resolve().parent / "results" / "dryrun.json"


def run(report):
    if not RESULTS.exists():
        report("roofline_missing", 0.0,
               "run: PYTHONPATH=src python -m repro.launch.dryrun --all "
               "--mesh both")
        return
    data = json.loads(RESULTS.read_text())
    ok = {k: v for k, v in data.items() if v.get("ok")}
    for key in sorted(ok):
        rec = ok[key]
        r = rec["roofline"]
        name = f"roofline_{rec['arch']}_{rec['shape']}_{rec['mesh']}"
        bound_us = r["bound_step_s"] * 1e6
        report(name, bound_us,
               f"dom={r['dominant']} comp={r['compute_s']:.2e} "
               f"mem={r['memory_s']:.2e} coll={r['collective_s']:.2e} "
               f"useful={r['useful_flops_ratio']:.2f} "
               f"roofline_frac={r['roofline_fraction']:.3f}")
    n_fail = len(data) - len(ok)
    report("roofline_summary", 0.0,
           f"cells_ok={len(ok)} cells_failed={n_fail}")
