"""Per-event scorer-path latency: numpy vs jit vs pallas-interpret vs
pallas-compiled across candidate counts.

One *event* is a (rank a, rank b) lock negotiation: an (na+1) x (nb+1)
candidate-pair tile plus a 32-pair shortlist.  This benchmark times the
whole per-event scoring round trip through the bucketed launcher
(``jit.score_events``: pack -> score -> gather -> host combine) for each
backend at candidate counts {8, 32, 128, 512} and writes
``BENCH_scorer_paths.json``.

What it shows (and the CI assertion): the numpy reference's cost grows
with the tile area (~80 elementwise ops over (na+1)x(nb+1) lanes), while
the compiled jit path pays a roughly flat dispatch+sync latency — on CPU
the two cross between 8 and 32 candidates, so the jit path must beat
numpy at every count >= 32 (asserted below).  At the default
``max_candidates=12`` the two are near parity on CPU, which is why the
engine keeps ``backend="numpy"`` as its default there; the pallas-compiled
f32 path is the TPU deployment shape (B padded to 128 lanes) and runs here
through its interpret fallback for layout validation, not speed.

Usage:  PYTHONPATH=src python benchmarks/scorer_paths.py [--quick]
"""
from __future__ import annotations

import json
import os
import sys
import time

import numpy as np

from repro.core.problem import CCMParams
from repro.kernels.ccm_scorer import jit as scorer_jit
from repro.kernels.ccm_scorer.layout import N_AV, N_PM, N_SC, SC

JSON_PATH = os.environ.get("BENCH_SCORER_PATHS_JSON",
                           "BENCH_scorer_paths.json")
COUNTS = (8, 32, 128, 512)
QUICK_COUNTS = (8, 32)
SHORTLIST = 32
ASSERT_FROM = 32     # jit must beat numpy at every count >= this


def _event(rng, n):
    """Random feature tile of an event with na = nb = n candidates."""
    av = rng.uniform(0.1, 2.0, (N_AV, n + 1))
    bv = rng.uniform(0.1, 2.0, (N_AV, n + 1))
    pm = rng.uniform(0.0, 1.0, (N_PM, n + 1, n + 1))
    sc = rng.uniform(0.5, 3.0, N_SC)
    sc[SC.na] = sc[SC.nb] = n
    sc[SC.speed_a] = sc[SC.speed_b] = 1.0
    sc[SC.mem_cap_a] = sc[SC.mem_cap_b] = 1e12
    ia, ib = np.divmod(np.arange(1, SHORTLIST + 1, dtype=np.int64), n + 1)
    pairs = np.stack([ia % (n + 1), ib], axis=1)
    return (av, bv, pm, sc), pairs


def _time_backend(feats, pairs, params, backend, reps):
    call = lambda: scorer_jit.score_events(  # noqa: E731
        [feats], [pairs], params, backend=backend)
    call()                                   # warm (compiles its bucket)
    best = np.inf
    for _ in range(3):                       # best-of-3: shields the CI
        t0 = time.perf_counter()             # assertion from load spikes
        for _ in range(reps):
            call()
        best = min(best, (time.perf_counter() - t0) / reps * 1e6)
    return best


def run(report, quick: bool = False):
    quick = quick or os.environ.get("BENCH_QUICK") == "1"
    counts = QUICK_COUNTS if quick else COUNTS
    params = CCMParams(delta=1e-9)
    rng = np.random.default_rng(0)
    records = []
    violations = []
    for n in counts:
        feats, pairs = _event(rng, n)
        # pallas interpret walks every lane in the Python interpreter —
        # cap its reps so large tiles stay affordable
        reps = {8: 200, 32: 100, 128: 30, 512: 10}.get(n, 20)
        if quick:
            reps = max(5, reps // 4)
        per = {}
        for backend in ("numpy", "jit", "pallas", "pallas_compiled"):
            p_reps = reps if backend in ("numpy", "jit") else \
                max(2, reps // 10)
            tc0 = scorer_jit.trace_count()
            per[backend] = _time_backend(feats, pairs, params, backend,
                                         p_reps)
            records.append({
                "candidates": n,
                "backend": backend,
                "us_per_event": per[backend],
                "speedup_vs_numpy": per["numpy"] / per[backend],
                "compiles": scorer_jit.trace_count() - tc0,
            })
            report(f"scorer_{backend}_n{n}", per[backend],
                   f"{per['numpy'] / per[backend]:.2f}x vs numpy")
        if n >= ASSERT_FROM and per["jit"] >= per["numpy"]:
            # re-measure once with more reps before declaring a violation:
            # at the crossover count the margin is real but small, and a
            # shared-runner load spike can invert a single measurement
            re_np = _time_backend(feats, pairs, params, "numpy", 2 * reps)
            re_jit = _time_backend(feats, pairs, params, "jit", 2 * reps)
            if re_jit >= re_np:
                violations.append((n, re_np, re_jit))

    payload = {
        "benchmark": "scorer_paths",
        "quick": quick,
        "shortlist": SHORTLIST,
        "pallas_compiled_fallback": scorer_jit.pallas_compiled_fallback(),
        "jit_buckets_compiled": scorer_jit.bucket_cache_size(),
        "trace_count": scorer_jit.trace_count(),
        "jit_bucket_keys": scorer_jit.bucket_keys(),
        "results": records,
        "jit_beats_numpy_from": ASSERT_FROM,
    }
    with open(JSON_PATH, "w") as f:
        json.dump(payload, f, indent=2)
    report("scorer_paths_json", 0.0, f"written to {JSON_PATH}")
    if violations and quick:
        # quick mode runs on shared CI runners where a load spike spanning
        # both measurements can invert the narrow n=32 margin — surface
        # loudly, but only the full benchmark run enforces the bar
        report("scorer_paths_WARN", 0.0,
               f"jit did not beat numpy at (n, numpy_us, jit_us): "
               f"{violations} (quick mode: warning only)")
        return
    assert not violations, (
        "jit path must beat numpy per-event latency at every candidate "
        f"count >= {ASSERT_FROM}; got (n, numpy_us, jit_us): {violations}")


def main():
    quick = "--quick" in sys.argv
    print("name,us_per_call,derived")

    def report(name, us, derived=""):
        print(f"{name},{us:.1f},{derived}", flush=True)

    run(report, quick=quick)


if __name__ == "__main__":
    main()
