"""Quiescence amortization: converged-iteration cost, incremental vs the
full-rebuild reference (repro/core/quiesce.py).

CCM-LB converges in a handful of iterations and then mostly *confirms*
quiescence; the QuiesceTracker makes the four host cost centers of such
an iteration — cluster/summary rebuilds, gossip network construction,
work-list assembly, exact scoring — incremental in the number of dirty
ranks, with bitwise-identical trajectories as the bar.  This benchmark
runs long solo balances on the ``ccmlb_scaling`` instance family so the
tail is fully converged, and measures that tail both ways:

  * **tail stage cost** — per-iteration sum of the ``profile=True`` stage
    timings over the converged (zero-transfer) tail.  This is the direct
    measure of the four cost centers, immune to the warm-phase wall noise
    of a shared VM; the incremental tail must undercut the rebuild tail
    by ``TAIL_FLOOR`` (hard-asserted at >= 64 ranks in full mode —
    measured ratios sit in the hundreds, the floor is a regression trip
    wire, not the expectation);
  * **end-to-end wall** — min-of-reps full-run seconds.  The warm phase
    is identical work in both configs, so the ratio is diluted by
    design; the ``E2E_FLOOR`` bar is asserted at 256 ranks in full mode.

Every config pair is checked for bitwise identity (assignment AND
transfer log), the converged tail is checked for ZERO tracker activity
(no cluster builds, no gossip redraws, no work-list rescoring — diffed
from ``quiesce_counters``), and the ``quiesce_after`` early-exit knob is
checked lossless: under per-root epoch-keyed gossip a zero-transfer
iteration reproduces itself exactly (nothing dirty => same summaries,
same stream keys, same work lists), so quiescence is absorbing and
stopping early cannot change the answer.

Standalone:  PYTHONPATH=src python benchmarks/ccmlb_quiesce.py [--quick]
(--quick runs the small-rank configs for CI and downgrades the timing
bars to warnings — shared-runner wall times; also wired into
benchmarks/run.py).  Results land in ``BENCH_ccmlb_quiesce.json``.
"""
from __future__ import annotations

import json
import os
import sys
import time

import numpy as np

from repro.core import CCMParams, ccm_lb
from repro.core.problem import initial_assignment, scaling_phase

JSON_PATH = os.environ.get("BENCH_CCMLB_QUIESCE_JSON",
                           "BENCH_ccmlb_quiesce.json")
RANKS = (64, 256, 1024)
QUICK_RANKS = (16, 64)
# long enough that >= MIN_TAIL converged iterations exist at every size
N_TOTAL = {16: 16, 64: 24, 256: 32, 1024: 12}
QUICK_N_TOTAL = {16: 12, 64: 12}
MIN_TAIL = 5
REPS = 2
TAIL_FLOOR = 5.0    # converged-iteration stage cost: incremental vs rebuild
E2E_FLOOR = 1.3     # end-to-end solo wall at 256 ranks
ZERO_KEYS = ("cluster_rank_builds", "gossip_redraws", "worklist_rescored",
             "tables_rebuilds")


def _timed_run(phase, a0, params, n_iter, reps, **kw):
    """Min-of-reps wall seconds + the last run's result (trajectories are
    deterministic, so every rep returns the same result)."""
    best, res = float("inf"), None
    for _ in range(reps):
        t0 = time.perf_counter()
        res = ccm_lb(phase, a0, params, n_iter=n_iter, k_rounds=2, fanout=4,
                     seed=0, profile=True, **kw)
        best = min(best, time.perf_counter() - t0)
    return best, res


def _tail_stats(res):
    """(tail start, per-iteration stage seconds over the converged tail,
    tracker-activity deltas over the tail).

    The tail starts ONE past the first zero-transfer iteration: that
    iteration still folds in the dirt left by the last committed transfer
    (and iteration 0 always pays the initial full build), so the truly
    quiescent iterations — nothing dirty, caches replayed verbatim — begin
    at ``last_nonzero + 2``."""
    deltas = res.iter_transfers
    nz = [i for i, d in enumerate(deltas) if d]
    start = (nz[-1] + 2) if nz else 1
    tail = res.stage_timings[start:]
    per_iter = (sum(sum(tm.values()) for tm in tail) / len(tail)
                if tail else 0.0)
    qc = res.quiesce_counters
    activity = {k: qc[-1].get(k, 0) - qc[start - 1].get(k, 0)
                for k in ZERO_KEYS}
    return start, per_iter, activity


def run(report, quick: bool = False):
    quick = quick or os.environ.get("BENCH_QUICK") == "1"
    ranks_sweep = QUICK_RANKS if quick else RANKS
    totals = QUICK_N_TOTAL if quick else N_TOTAL
    params = CCMParams(delta=1e-9)
    records = []
    tail_ratio_256 = None
    e2e_ratio_256 = None

    def bar(ok: bool, msg: str):
        if ok:
            return
        if quick:
            report("ccmlb_quiesce_WARN", 0.0,
                   f"{msg} (quick mode: warning only — shared-runner "
                   "wall times)")
        else:
            raise AssertionError(msg)

    for ranks in ranks_sweep:
        phase = scaling_phase(ranks)
        a0 = initial_assignment(phase)
        n_iter = totals[ranks]
        walls, results = {}, {}
        for tag, kw in (("incremental", dict(incremental=True)),
                        ("rebuild", dict(incremental=False))):
            reps = 1 if ranks >= 1024 else REPS
            walls[tag], results[tag] = _timed_run(phase, a0, params, n_iter,
                                                  reps, **kw)
        ri, rr = results["incremental"], results["rebuild"]
        # the whole point: the amortized path IS the reference trajectory
        assert np.array_equal(ri.assignment, rr.assignment), \
            f"incremental/rebuild assignments diverged at {ranks} ranks"
        assert ri.transfer_log == rr.transfer_log, \
            f"incremental/rebuild transfer logs diverged at {ranks} ranks"
        start, tail_incr, activity = _tail_stats(ri)
        start_r, tail_reb, _ = _tail_stats(rr)
        assert start == start_r
        tail_len = n_iter - start
        assert tail_len >= MIN_TAIL, \
            (f"only {tail_len} converged iterations at {ranks} ranks — "
             f"raise N_TOTAL ({n_iter}) to keep the tail measurable")
        assert all(v == 0 for v in activity.values()), \
            (f"converged tail did work at {ranks} ranks: {activity} "
             "(expected zero cluster builds / gossip redraws / rescoring)")
        tail_ratio = tail_reb / tail_incr if tail_incr > 0 else float("inf")
        e2e_ratio = walls["rebuild"] / walls["incremental"]
        # quiesce_after is lossless: quiescence is absorbing (docstring)
        rq = ccm_lb(phase, a0, params, n_iter=n_iter, k_rounds=2, fanout=4,
                    seed=0, incremental=True, quiesce_after=1)
        assert np.array_equal(rq.assignment, ri.assignment), \
            f"quiesce_after changed the answer at {ranks} ranks"
        saved = n_iter - len(rq.iter_transfers)
        report(f"ccmlb_quiesce_{ranks}", walls["incremental"] * 1e6,
               f"tail {tail_incr*1e3:.2f}ms/iter vs rebuild "
               f"{tail_reb*1e3:.2f}ms/iter ({tail_ratio:.0f}x), e2e "
               f"{e2e_ratio:.2f}x, quiesce_after=1 saved {saved}/{n_iter} "
               "iterations, identical assignments")
        records.append({
            "ranks": ranks, "tasks": phase.num_tasks,
            "comms": phase.num_comms, "n_iter": n_iter,
            "converged_at": start, "tail_iterations": tail_len,
            "tail_seconds_per_iter_incremental": tail_incr,
            "tail_seconds_per_iter_rebuild": tail_reb,
            "tail_ratio": tail_ratio,
            "seconds_incremental": walls["incremental"],
            "seconds_rebuild": walls["rebuild"],
            "e2e_ratio": e2e_ratio,
            "memo_hits": int(ri.memo_hits),
            "gossip_noop_merges": int(ri.gossip_noop_merges),
            "quiesce_after_saved_iterations": saved,
            "identical_assignments": True,
        })
        if ranks >= 64:
            bar(tail_ratio >= TAIL_FLOOR,
                f"converged-tail ratio {tail_ratio:.1f}x under the "
                f"{TAIL_FLOOR}x floor at {ranks} ranks")
        if ranks == 256:
            tail_ratio_256 = tail_ratio
            e2e_ratio_256 = e2e_ratio
            bar(e2e_ratio >= E2E_FLOOR,
                f"end-to-end ratio {e2e_ratio:.2f}x under the "
                f"{E2E_FLOOR}x floor at 256 ranks")

    payload = {
        "benchmark": "ccmlb_quiesce",
        "numpy": np.__version__,
        "quick": quick,
        "results": records,
        "tail_ratio_256": tail_ratio_256,
        "e2e_ratio_256": e2e_ratio_256,
        "tail_floor": TAIL_FLOOR,
        "e2e_floor": E2E_FLOOR,
    }
    with open(JSON_PATH, "w") as f:
        json.dump(payload, f, indent=2)
    report("ccmlb_quiesce_json", 0.0, f"written to {JSON_PATH}")


def main():
    quick = "--quick" in sys.argv
    print("name,us_per_call,derived")

    def report(name, us, derived=""):
        print(f"{name},{us:.1f},{derived}", flush=True)

    run(report, quick=quick)


if __name__ == "__main__":
    main()
