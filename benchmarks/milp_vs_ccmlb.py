"""Paper Fig. 4a: MILP (B&B-certified) vs CCM-LB over a delta sweep.

Prints: delta, milp W_max, milp gap (vs LP relaxation), milp solve time,
CCM-LB min/max gap over 12 solves, W_max increase vs MILP, mean solve time.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import CCMParams, ccm_lb, random_phase
from repro.core.milp import build_fwmp_reduced, solve_milp
from repro.core.problem import initial_assignment


def run(report):
    phase = random_phase(7, num_ranks=4, num_tasks=14, num_blocks=4,
                         num_comms=16, mem_cap=5e8)
    a0 = initial_assignment(phase)
    for delta in (1e-9, 1e-10, 1e-11, 0.0):
        params = CCMParams(alpha=1.0, beta=1e-9, gamma=1e-11, delta=delta)
        gaps, works, times = [], [], []
        for s in range(12):
            t0 = time.perf_counter()
            r = ccm_lb(phase, a0, params, n_iter=4, fanout=3, seed=s)
            times.append(time.perf_counter() - t0)
            works.append(r.max_work[-1])
        t0 = time.perf_counter()
        res = solve_milp(build_fwmp_reduced(phase, params), max_nodes=3000,
                         time_limit_s=120)
        t_milp = time.perf_counter() - t0
        gaps = [(w - res.lp_bound) / res.lp_bound for w in works]
        incr = [(w - res.objective) / res.objective for w in works]
        report(f"fig4a_milp_delta_{delta:g}", t_milp * 1e6,
               f"W={res.objective:.4f} gap={res.gap:.1e} nodes={res.nodes} "
               f"status={res.status}")
        report(f"fig4a_ccmlb_delta_{delta:g}", np.mean(times) * 1e6,
               f"gap_min={min(gaps):.1e} gap_max={max(gaps):.1e} "
               f"Wmax_incr_min={100*min(incr):.2f}% "
               f"Wmax_incr_max={100*max(incr):.2f}%")
