"""Memory-pressure sweep: balance quality vs headroom, replication margin.

The memory-bind counterpart of ``ccmlb_fault``: every record passes a
post-hoc gate — the transfer/replication log replays from the initial
assignment to the final one, and a from-scratch :class:`CCMState` rebuild
of the final assignment satisfies eq. 7 against ``effective_mem_cap`` on
EVERY rank (zero cap violations, asserted, per config).

Configs, per pair count of the constructed hot-block instance (each pair
of ranks shares one replicable weight block whose cluster is atomic for
the replication-free balancer):

  * ``headroom_*`` — ``mem_headroom`` sweep with the replication move
    vocabulary enabled: at low headroom the block-split replication fires
    and max-work drops; past the pressure knee the replica no longer
    fits under ``cap * (1 - headroom)`` and the balancer must degrade
    gracefully to the replication-free plateau instead of violating a
    cap.  Quality (Wmax/mean), peak memory utilisation, replica counts
    and transfers are recorded at every point.
  * ``replication_margin`` — replicate=True vs replicate=False at zero
    headroom, same seed: the enabled run must beat the free run on
    max-work (the measured margin lands in the JSON and is asserted
    positive).
  * ``async_replicate`` — the event-loop driver at zero latency must be
    bitwise the sync driver (assignment + transfer log + work trace),
    and a latency run is recorded under the same replay gate.
  * ``pipeline_replicate`` — ``replicate`` threaded through the
    multi-phase driver's lb kwargs; per-phase feasibility gated.
  * ``crash_spill`` — a rank dies while the warm-start target has no
    memory room: recovery must spill to a feasible survivor
    (``recovery_spills`` counted) and end feasible.
  * ``join_relief`` — ranks inside the headroom band shed onto a
    mid-stream joiner with fresh capacity until every rank clears the
    soft cap.

Results land in ``BENCH_ccmlb_memory.json``.

Standalone:  PYTHONPATH=src python benchmarks/ccmlb_memory.py [--quick]
(--quick runs the 2-pair configs for CI; also wired into
benchmarks/run.py as ``ccmlb_memory``.)
"""
from __future__ import annotations

import json
import os
import sys
import time

import numpy as np

from repro.core import CCMParams, CCMState, ccm_lb
from repro.core.async_sim import FaultSpec, ccm_lb_async
from repro.core.ccm import effective_mem_cap
from repro.core.pipeline import ccm_lb_pipeline
from repro.core.problem import Phase
from repro.runtime.elastic import RankJoin

JSON_PATH = os.environ.get("BENCH_CCMLB_MEMORY_JSON",
                           "BENCH_ccmlb_memory.json")
N_ITER = 6
HEADROOM_SWEEP = (0.0, 0.1, 0.4)    # past 0.1 the replica no longer fits
MEM_CAP = 50.0


def _pressure_phase(pairs: int, mem_cap: float = MEM_CAP) -> Phase:
    """``pairs`` independent rank pairs (2p, 2p+1).  Even ranks hold one
    4-task cluster of block p (load 6.0 — exactly the cluster splitter's
    load cap, so the replication-free balancer cannot break it), three
    heavy singletons, and a tiny stage-1 trigger; odd ranks hold three
    heavy, memory-fat singletons plus their own tiny trigger.  Swapping
    heavies exactly cancels (6.0 both ways, no strict gain), so the
    replication-free balancer plateaus at ~24 while the block split
    reaches ~21.  Replicating block p onto the odd rank (mem after:
    21.2 + 6 + 10 = 37.2) fits under cap 50 at headroom <= 0.1 (soft
    cap 45) but not at 0.4 (soft cap 30).

    ``mem_cap``: at the default 50 memory binds once the fat heavies
    concentrate (at many pairs the underloaded ranks reach ~34.8 used, so
    a half-split landing — 6 task mem + a 10-byte block copy — is
    correctly refused); the margin config passes a roomy cap so it
    measures the pure move-vocabulary gain instead of the refusal."""
    load, mem, blk, a0 = [], [], [], []
    for p in range(pairs):
        load += [1.5] * 4 + [6.0] * 3 + [0.01] * 4 + [6.0] * 3 + [0.01] * 4
        mem += [3.0] * 4 + [0.1] * 3 + [0.1] * 4 + [7.0] * 3 + [0.1] * 4
        blk += [p] * 4 + [-1] * 14
        a0 += [2 * p] * 11 + [2 * p + 1] * 7
    k = len(load)
    ph = Phase(task_load=load, task_mem=mem,
               task_overhead=np.zeros(k), task_block=blk,
               block_size=np.full(pairs, 10.0),
               block_home=np.arange(pairs, dtype=np.int64) * 2,
               comm_src=[], comm_dst=[], comm_vol=[],
               rank_mem_base=np.zeros(2 * pairs),
               rank_mem_cap=np.full(2 * pairs, mem_cap))
    return ph, np.asarray(a0, np.int64)


def _check_zero_violations(phase, a0, res, params, tag) -> int:
    """Replay the transfer/replication log onto ``a0`` and rebuild: the
    final state must satisfy eq. 7 on every rank.  Returns the violation
    count (always 0 — asserted) so it can land in the record."""
    replay = np.asarray(a0, np.int64).copy()
    for tasks, r_from, r_to in res.transfer_log:
        idx = np.asarray(tasks, np.int64)
        assert (replay[idx] == r_from).all(), f"{tag}: replay diverged"
        replay[idx] = r_to
    assert np.array_equal(replay, res.assignment), f"{tag}: log incomplete"
    fphase = res.state.phase
    final = CCMState.build(fphase, res.assignment, params)
    bad = [r for r in range(fphase.num_ranks)
           if not final.memory_feasible(r)]
    assert not bad, f"{tag}: ranks {bad} over their memory cap"
    return 0


def _quality(res, phase):
    return float(res.max_work[-1] / (phase.task_load.sum() / phase.num_ranks))


def _mem_util(res, params):
    """Peak M_max(r) / effective cap over ranks, on the final state."""
    fphase = res.state.phase
    final = CCMState.build(fphase, res.assignment, params)
    caps = effective_mem_cap(fphase.rank_mem_cap, params)
    return float(max(final.max_memory(r) / caps[r]
                     for r in range(fphase.num_ranks)))


def _replicas(res):
    """Extra block copies beyond one residency per block."""
    present = (res.state.block_count > 0).sum(axis=0)
    return int(np.maximum(present - 1, 0).sum())


def _record(records, tag, pairs, phase, res, params, seconds, **extra):
    records.append({
        "config": tag,
        "pairs": pairs,
        "ranks": phase.num_ranks,
        "n_iter": N_ITER,
        "seconds": seconds,
        "max_work": float(res.max_work[-1]),
        "max_work_over_mean": _quality(res, phase),
        "imbalance_after": float(res.imbalance[-1]),
        "transfers": int(res.transfers),
        "replicas": _replicas(res),
        "peak_mem_utilization": _mem_util(res, params),
        "cap_violations": 0,
        **extra,
    })


def _headroom_sweep(report, records, pairs: int):
    phase, a0 = _pressure_phase(pairs)
    qualities = {}
    for h in HEADROOM_SWEEP:
        params = CCMParams(alpha=1.0, beta=0.0, gamma=0.0, delta=0.0,
                           mem_headroom=h)
        t0 = time.perf_counter()
        res = ccm_lb(phase, a0, params, n_iter=N_ITER, seed=0,
                     replicate=True)
        dt = time.perf_counter() - t0
        _check_zero_violations(phase, a0, res, params,
                               f"headroom_{h}@{pairs}")
        _record(records, f"headroom_{h:g}", pairs, phase, res, params, dt,
                mem_headroom=h)
        qualities[h] = float(res.max_work[-1])
        report(f"ccmlb_memory_pairs_{pairs}_headroom_{h:g}", dt * 1e6,
               f"wmax={res.max_work[-1]:.2f} replicas={_replicas(res)} "
               f"util={_mem_util(res, params):.3f}")
    # the knee: tight headroom must refuse the replica, not violate caps
    assert qualities[HEADROOM_SWEEP[0]] <= qualities[HEADROOM_SWEEP[-1]], \
        f"@{pairs}: loose headroom lost to tight"
    low = next(r for r in records
               if r["config"] == f"headroom_{HEADROOM_SWEEP[0]:g}"
               and r["pairs"] == pairs)
    high = next(r for r in records
                if r["config"] == f"headroom_{HEADROOM_SWEEP[-1]:g}"
                and r["pairs"] == pairs)
    assert low["replicas"] > 0, f"@{pairs}: replication never fired"
    assert high["replicas"] == 0, \
        f"@{pairs}: a replica slipped past the headroom band"


def _replication_margin(report, records, pairs: int):
    # roomy cap: memory must not bind here — the config measures what the
    # replication vocabulary alone buys on max-work (the sweep above is
    # where the caps bite)
    phase, a0 = _pressure_phase(pairs, mem_cap=200.0)
    params = CCMParams(alpha=1.0, beta=0.0, gamma=0.0, delta=0.0)
    t0 = time.perf_counter()
    base = ccm_lb(phase, a0, params, n_iter=N_ITER, seed=0)
    rep = ccm_lb(phase, a0, params, n_iter=N_ITER, seed=0, replicate=True)
    dt = time.perf_counter() - t0
    for tag, res in (("replication_free", base), ("replication_margin", rep)):
        _check_zero_violations(phase, a0, res, params, f"{tag}@{pairs}")
    margin = float((base.max_work[-1] - rep.max_work[-1])
                   / base.max_work[-1])
    assert margin > 0, \
        f"@{pairs}: replication did not beat the free run " \
        f"({rep.max_work[-1]} vs {base.max_work[-1]})"
    _record(records, "replication_free", pairs, phase, base, params, dt)
    _record(records, "replication_margin", pairs, phase, rep, params, dt,
            margin_vs_free=margin)
    report(f"ccmlb_memory_pairs_{pairs}_replication_margin", dt * 1e6,
           f"wmax {base.max_work[-1]:.2f} -> {rep.max_work[-1]:.2f} "
           f"(margin {margin:.1%})")
    return margin


def _async_and_pipeline(report, records, pairs: int):
    phase, a0 = _pressure_phase(pairs)
    params = CCMParams(alpha=1.0, beta=0.0, gamma=0.0, delta=0.0)
    sync = ccm_lb(phase, a0, params, n_iter=N_ITER, seed=0, replicate=True)

    t0 = time.perf_counter()
    res = ccm_lb_async(phase, a0, params, n_iter=N_ITER, seed=0,
                       replicate=True)
    dt = time.perf_counter() - t0
    bitwise = bool(np.array_equal(res.assignment, sync.assignment)
                   and res.transfer_log == sync.transfer_log
                   and res.max_work == sync.max_work)
    assert bitwise, f"async@{pairs}: zero-latency run diverged from sync"
    _check_zero_violations(phase, a0, res, params, f"async@{pairs}")
    _record(records, "async_replicate", pairs, phase, res, params, dt,
            bitwise_identical_to_sync=True)
    report(f"ccmlb_memory_pairs_{pairs}_async", dt * 1e6, "bitwise==sync")

    lat = ("uniform", 0.5, 1.5)
    t0 = time.perf_counter()
    res = ccm_lb_async(phase, a0, params, n_iter=N_ITER, seed=0,
                       replicate=True, latency=lat)
    dt = time.perf_counter() - t0
    _check_zero_violations(phase, a0, res, params, f"async_lat@{pairs}")
    _record(records, "async_replicate_latency", pairs, phase, res, params,
            dt)

    t0 = time.perf_counter()
    pipe = ccm_lb_pipeline([phase, phase], params, a0=a0, seed=0,
                           n_iter=N_ITER, replicate=True)
    dt = time.perf_counter() - t0
    start = a0
    for i, run_ in enumerate(pipe.runs):
        # identical topologies warm-start from the previous phase's final
        # assignment, so each phase's log replays from it
        _check_zero_violations(phase, start, run_.result, params,
                               f"pipeline_{i}@{pairs}")
        start = run_.result.assignment
    _record(records, "pipeline_replicate", pairs, phase,
            pipe.runs[-1].result, params, dt, phases=len(pipe.runs))
    report(f"ccmlb_memory_pairs_{pairs}_pipeline", dt * 1e6,
           f"phases={len(pipe.runs)} "
           f"wmax={pipe.runs[-1].result.max_work[-1]:.2f}")


def _crash_spill(report, records):
    """Rank 2 dies; the warm-start target (rank 0) has no memory room, so
    recovery must spill the stranded groups to rank 1 and stay feasible."""
    phase = Phase(task_load=[0.1, 1.0, 1.0, 1.0, 1.0],
                  task_mem=[0.05, 1.0, 1.0, 1.0, 1.0],
                  task_overhead=np.zeros(5), task_block=[-1] * 5,
                  block_size=[], block_home=[],
                  comm_src=[], comm_dst=[], comm_vol=[],
                  rank_mem_base=np.zeros(3),
                  rank_mem_cap=[0.1, 100.0, 100.0])
    a0 = np.array([0, 2, 2, 2, 2], np.int64)
    params = CCMParams(alpha=1.0, beta=0.0, gamma=0.0, delta=0.0)
    t0 = time.perf_counter()
    res = ccm_lb_async(phase, a0, params, n_iter=3, seed=0,
                       fault=FaultSpec(kill=((2, 0, 0.5),), seed=7))
    dt = time.perf_counter() - t0
    assert res.dead_ranks == [2]
    assert res.fault_stats.recovery_spills >= 1, "spill path never fired"
    assert not (res.assignment == 2).any()
    _check_zero_violations(phase, a0, res, params, "crash_spill")
    _record(records, "crash_spill", 0, phase, res, params, dt,
            recovery_spills=int(res.fault_stats.recovery_spills),
            recovered_tasks=int(res.fault_stats.recovered_tasks))
    report("ccmlb_memory_crash_spill", dt * 1e6,
           f"spills={res.fault_stats.recovery_spills} "
           f"recovered={res.fault_stats.recovered_tasks}")
    return int(res.fault_stats.recovery_spills)


def _join_relief(report, records):
    """Both ranks sit inside the headroom band; a mid-stream joiner with
    fresh capacity must absorb work until every rank clears the soft cap."""
    phase = Phase(task_load=[1.0] * 4, task_mem=[2.0] * 4,
                  task_overhead=np.zeros(4), task_block=[-1] * 4,
                  block_size=[], block_home=[],
                  comm_src=[], comm_dst=[], comm_vol=[],
                  rank_mem_base=np.zeros(2),
                  rank_mem_cap=[5.0, 5.0])
    a0 = np.array([0, 0, 1, 1], np.int64)
    params = CCMParams(alpha=1.0, beta=0.0, gamma=0.0, delta=0.0,
                       mem_headroom=0.3)      # soft cap 3.5 < used 4.0
    t0 = time.perf_counter()
    res = ccm_lb_async(phase, a0, params, n_iter=4, seed=0,
                       membership=(RankJoin(iteration=1, count=1,
                                            mem_cap=10.0),))
    dt = time.perf_counter() - t0
    assert res.joined_ranks == [2]
    on_joined = int((res.assignment == 2).sum())
    assert on_joined > 0, "joiner relieved no memory pressure"
    _check_zero_violations(phase, a0, res, params, "join_relief")
    _record(records, "join_relief", 0, res.state.phase, res, params, dt,
            tasks_on_joined=on_joined)
    report("ccmlb_memory_join_relief", dt * 1e6,
           f"tasks_on_joined={on_joined}")
    return on_joined


def run(report, quick: bool = False):
    records = []
    margins = []
    for pairs in ((2,) if quick else (2, 8)):
        _headroom_sweep(report, records, pairs)
        margins.append(_replication_margin(report, records, pairs))
    _async_and_pipeline(report, records, 2)
    spills = _crash_spill(report, records)
    joined = _join_relief(report, records)

    payload = {
        "benchmark": "ccmlb_memory",
        "quick": quick,
        "numpy": np.__version__,
        "n_iter": N_ITER,
        "headroom_sweep": list(HEADROOM_SWEEP),
        "results": records,
        "zero_cap_violations": all(r["cap_violations"] == 0
                                   for r in records),
        "replication_margin_worst": min(margins),
        "replication_margin_best": max(margins),
        "async_bitwise_ok": all(
            r.get("bitwise_identical_to_sync", True) for r in records),
        "recovery_spills": spills,
        "join_tasks_on_new_rank": joined,
    }
    with open(JSON_PATH, "w") as f:
        json.dump(payload, f, indent=2)
    report("ccmlb_memory_json", 0.0, f"written to {JSON_PATH}")


def main():
    quick = "--quick" in sys.argv
    print("name,us_per_call,derived")

    def report(name, us, derived=""):
        print(f"{name},{us:.1f},{derived}", flush=True)

    run(report, quick=quick)
    with open(JSON_PATH) as f:
        payload = json.load(f)
    assert payload["zero_cap_violations"]
    assert payload["replication_margin_worst"] > 0
    assert payload["async_bitwise_ok"]
    assert payload["recovery_spills"] > 0
    assert payload["join_tasks_on_new_rank"] > 0
    print("ccmlb_memory_ok,0.0,zero-violations+margin+async-bitwise"
          "+spill+join checks passed", flush=True)


if __name__ == "__main__":
    main()
