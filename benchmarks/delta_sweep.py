"""Paper Fig. 4b: effect of delta on blocks computed off-home, homing
transfer time, and compute time, on the assembly application."""
from __future__ import annotations

import numpy as np

from repro.assembly import run_assembly_comparison
from repro.core import CCMParams


def run(report):
    prev_off = None
    for delta in (1e-8, 1e-9, 1e-10, 0.0):
        params = CCMParams(alpha=1.0, beta=2e-10, gamma=1e-12, delta=delta)
        r = run_assembly_comparison(n_unknowns=2048, num_ranks=16,
                                    durations="analytic", ccm_params=params,
                                    seed=0)
        homing_t = r.homing.est_time_s if r.homing else 0.0
        waves = len(r.homing.waves) if r.homing else 0
        report(f"fig4b_delta_{delta:g}", r.makespan_ccmlb * 1e6,
               f"n_off_home={r.n_off_home_ranks} homing_s={homing_t:.2e} "
               f"waves={waves} imb={r.imbalance_after:.3f}")
        prev_off = r.n_off_home_ranks
