"""Multi-phase pipeline amortization (paper §III-B iterative executions).

An iterative application re-invokes the balancer over a sequence of phases
whose loads drift but whose adjacency topology is stable.  This benchmark
measures what the :func:`repro.core.pipeline.ccm_lb_pipeline` orchestrator
buys over replanning every phase from scratch:

  * **cold**  — every phase starts from the initial-assignment rule and
    builds its own PhaseCSR (``warm_start=False``, ``reuse_csr=False``);
  * **warm**  — phase ``k+1`` starts from phase ``k``'s balanced output and
    shares the CSR bundle (the pipeline default).

Per config it records per-phase seconds/transfers/imbalance and the
aggregate speedup + transfer reduction into ``BENCH_ccmlb_pipeline.json``.
Quality is tracked as each phase's final imbalance: a warm start repairs
drift with a fraction of the transfers but may settle a few hundredths of
imbalance away from the cold replan's endpoint (fewer positive stage-1
diffs from a near-balanced start) — the JSON records both trajectories and
the smoke assertion bounds the gap absolutely.

Standalone:  PYTHONPATH=src python benchmarks/ccmlb_pipeline.py [--quick]
(--quick runs a small-rank smoke config for CI; also wired into
benchmarks/run.py as ``ccmlb_pipeline``.)
"""
from __future__ import annotations

import dataclasses
import json
import os
import sys
import time

import numpy as np

from repro.core import CCMParams, ccm_lb_pipeline, random_phase

JSON_PATH = os.environ.get("BENCH_CCMLB_PIPELINE_JSON",
                           "BENCH_ccmlb_pipeline.json")
N_PHASES = 6
DRIFT = 0.08        # per-phase lognormal load drift (sigma)


def make_phases(seed: int, ranks: int, n_phases: int = N_PHASES):
    """A drifting phase sequence sharing one topology: task loads random-
    walk by ``DRIFT`` per phase; comm volumes and block structure stay."""
    base = random_phase(seed, num_ranks=ranks, num_tasks=25 * ranks,
                        num_blocks=3 * ranks, num_comms=50 * ranks,
                        mem_cap=1e12)
    rng = np.random.default_rng(seed + 1)
    phases = [base]
    for _ in range(n_phases - 1):
        prev = phases[-1]
        phases.append(dataclasses.replace(
            prev,
            task_load=prev.task_load * rng.lognormal(0.0, DRIFT,
                                                     prev.num_tasks)))
    return phases


def _run_config(report, records, ranks: int, n_iter: int,
                batch_lock_events: int):
    phases = make_phases(1, ranks)
    params = CCMParams(delta=1e-9)
    lb = dict(n_iter=n_iter, k_rounds=2, fanout=4, seed=0,
              batch_lock_events=batch_lock_events)

    t0 = time.perf_counter()
    cold = ccm_lb_pipeline(phases, params, warm_start=False, reuse_csr=False,
                           **lb)
    cold_s = time.perf_counter() - t0
    t0 = time.perf_counter()
    warm = ccm_lb_pipeline(phases, params, **lb)
    warm_s = time.perf_counter() - t0

    speedup = cold_s / warm_s if warm_s > 0 else float("inf")
    cold_tr, warm_tr = cold.total_transfers, warm.total_transfers
    report(f"ccmlb_pipeline_ranks_{ranks}_cold", cold_s * 1e6,
           f"{len(phases)} phases, transfers={cold_tr}")
    report(f"ccmlb_pipeline_ranks_{ranks}_warm", warm_s * 1e6,
           f"transfers={warm_tr} speedup={speedup:.2f}x "
           f"csr_reused={sum(r.csr_reused for r in warm.runs)}")
    records.append({
        "ranks": ranks,
        "tasks": phases[0].num_tasks,
        "comms": phases[0].num_comms,
        "n_phases": len(phases),
        "n_iter": n_iter,
        "batch_lock_events": batch_lock_events,
        "load_drift_sigma": DRIFT,
        "cold_seconds": cold_s,
        "warm_seconds": warm_s,
        "warm_speedup": speedup,
        "cold_transfers": int(cold_tr),
        "warm_transfers": int(warm_tr),
        "transfer_reduction": (1.0 - warm_tr / cold_tr) if cold_tr else 0.0,
        "csr_reused_phases": int(sum(r.csr_reused for r in warm.runs)),
        "warm_started_phases": int(sum(r.warm_started for r in warm.runs)),
        "cold_imbalance_after": [float(r.result.imbalance[-1])
                                 for r in cold.runs],
        "warm_imbalance_after": [float(r.result.imbalance[-1])
                                 for r in warm.runs],
        "cold_phase_seconds": [r.seconds for r in cold.runs],
        "warm_phase_seconds": [r.seconds for r in warm.runs],
    })


def run(report, quick: bool = False):
    records = []
    configs = ((16,) if quick else (64, 256))
    for ranks in configs:
        _run_config(report, records, ranks, n_iter=2 if quick else 4,
                    batch_lock_events=8)
    payload = {
        "benchmark": "ccmlb_pipeline",
        "quick": quick,
        "numpy": np.__version__,
        "n_phases": N_PHASES,
        "results": records,
        "warm_speedup_largest_config": records[-1]["warm_speedup"],
        "transfer_reduction_largest_config":
            records[-1]["transfer_reduction"],
    }
    with open(JSON_PATH, "w") as f:
        json.dump(payload, f, indent=2)
    report("ccmlb_pipeline_json", 0.0, f"written to {JSON_PATH}")


def main():
    quick = "--quick" in sys.argv
    print("name,us_per_call,derived")

    def report(name, us, derived=""):
        print(f"{name},{us:.1f},{derived}", flush=True)

    run(report, quick=quick)
    # CI smoke assertion: the warm path must not lose quality vs cold
    with open(JSON_PATH) as f:
        payload = json.load(f)
    for rec in payload["results"]:
        cold_i = rec["cold_imbalance_after"]
        warm_i = rec["warm_imbalance_after"]
        assert all(w <= c + 0.1 for w, c in zip(warm_i, cold_i)), \
            (cold_i, warm_i)
        assert rec["warm_transfers"] <= rec["cold_transfers"], rec
    print("ccmlb_pipeline_ok,0.0,quality+transfer checks passed", flush=True)


if __name__ == "__main__":
    main()
