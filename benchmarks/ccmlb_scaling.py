"""§IV scalability: CCM-LB solve time + quality vs rank count / fanout /
rounds (the paper reports <0.7 s at 14 ranks; we sweep up to 256).

Each rank-count config runs six times — scalar reference path
(``use_engine=False``), the engine with full per-event state re-gathering
(``incremental=False``, the rebuild reference), the incremental engine
(``use_engine=True``, the default), the compiled bucketed-jit scorer
(``backend="jit"``), the batched variants of both engine backends
(``batch_lock_events=BATCH_EVENTS``: up to that many disjoint rank pairs
scored per flush through one block-diagonal flow assembly / one compiled
launch), and the speculative scan driver (``spec_window=SPEC_WINDOW``:
windows of upcoming lock events scored in single compiled launches, see
core/spec.py) — and the results land in ``BENCH_ccmlb_scaling.json`` so the perf
trajectory (engine/jit/batched speedups AND the incremental-vs-rebuild
delta) is tracked from PR to PR.  The jit buckets are pre-compiled
(``scorer_jit.warmup``) so the timed region is the steady-state runtime;
XLA compile latency is reported separately as ``jit_warmup_seconds``.
Every run of a config is checked for assignment identity (recorded as
``identical_assignments`` and asserted here; see repro/core/engine.py for
the contract), so the speedup columns are apples to apples.

Each rank count also gets one UNTIMED ``profile=True`` run recording
where the host iteration spends its time (clusters / gossip / work lists
/ scoring / commit, summed per stage) — the breakdown that motivates the
quiescence caches measured in benchmarks/ccmlb_quiesce.py.
"""
from __future__ import annotations

import json
import os
import time

import numpy as np

from repro.core import CCMParams, CCMState, ccm_lb, random_phase
from repro.core.problem import initial_assignment, scaling_phase
from repro.kernels.ccm_scorer import jit as scorer_jit

JSON_PATH = os.environ.get("BENCH_CCMLB_JSON", "BENCH_ccmlb_scaling.json")
N_ITER = 4
BATCH_EVENTS = 8
SPEC_WINDOW = 16
# PR 3's recorded largest-config numbers (likely a different machine; the
# scalar config anchors the machine-speed comparison)
PR3_RECORDED = {"scalar": 65.0, "engine": 12.96, "batched": 8.76}


def run(report):
    params = CCMParams(delta=1e-9)
    records = []
    speedup_largest = None
    batched_speedup_largest = None
    incremental_delta_largest = None
    jit_seconds_largest = None
    batched_jit_seconds_largest = None
    spec_seconds_largest = None
    spec_over_batched_largest = None
    t0 = time.perf_counter()
    scorer_jit.warmup(max_batch=BATCH_EVENTS)
    scorer_jit.spec_warmup(window=SPEC_WINDOW)
    jit_warmup_seconds = time.perf_counter() - t0
    for ranks in (16, 64, 256):
        phase = scaling_phase(ranks)
        a0 = initial_assignment(phase)
        st0 = CCMState.build(phase, a0, params)
        mean = phase.task_load.sum() / ranks
        times = {}
        assignments = {}
        configs = (("scalar", dict(use_engine=False)),
                   ("rebuild", dict(use_engine=True, incremental=False)),
                   ("engine", dict(use_engine=True)),
                   ("jit", dict(use_engine=True, backend="jit")),
                   ("batched", dict(use_engine=True,
                                    batch_lock_events=BATCH_EVENTS)),
                   ("batched_jit", dict(use_engine=True, backend="jit",
                                        batch_lock_events=BATCH_EVENTS)),
                   ("spec", dict(use_engine=True, spec_window=SPEC_WINDOW)))
        for tag, kw in configs:
            t0 = time.perf_counter()
            res = ccm_lb(phase, a0, params, n_iter=N_ITER, k_rounds=2,
                         fanout=4, seed=0, **kw)
            dt = time.perf_counter() - t0
            times[tag] = dt
            assignments[tag] = res.assignment
            report(f"ccmlb_ranks_{ranks}_{tag}", dt * 1e6,
                   f"imb {st0.imbalance():.2f}->{res.imbalance[-1]:.4f} "
                   f"Wmax/mean={res.max_work[-1]/mean:.4f} "
                   f"transfers={res.transfers}")
            records.append({
                "ranks": ranks,
                "tasks": phase.num_tasks,
                "comms": phase.num_comms,
                "n_iter": N_ITER,
                "engine": kw.get("use_engine", True),
                "backend": kw.get("backend", "numpy"),
                "incremental": kw.get("incremental", True),
                "batch_lock_events": kw.get("batch_lock_events", 1),
                "spec_window": kw.get("spec_window", 1),
                "seconds": dt,
                "seconds_per_iteration": dt / N_ITER,
                "imbalance_after": float(res.imbalance[-1]),
                "max_work_over_mean": float(res.max_work[-1] / mean),
                "transfers": int(res.transfers),
            })
        # ratio goes in the derived column only — the us_per_call column
        # stays a call time so the CSV is uniformly parseable
        others = ("rebuild", "engine", "jit", "batched", "batched_jit",
                  "spec")
        identical = bool(all(
            np.array_equal(assignments[t], assignments["scalar"])
            for t in others))
        assert identical, \
            f"engine/jit/batched/scalar trajectories diverged at {ranks}"
        speedup = times["scalar"] / times["engine"]
        batched_speedup = times["scalar"] / times["batched"]
        incr_delta = times["rebuild"] / times["engine"]
        jit_speedup = times["scalar"] / times["jit"]
        batched_jit_speedup = times["scalar"] / times["batched_jit"]
        spec_over_batched = times["batched"] / times["spec"]
        report(f"ccmlb_ranks_{ranks}_speedup", 0.0,
               f"engine {speedup:.2f}x, jit {jit_speedup:.2f}x, "
               f"batched({BATCH_EVENTS}) {batched_speedup:.2f}x, "
               f"batched_jit {batched_jit_speedup:.2f}x over scalar, "
               f"spec(w{SPEC_WINDOW}) {spec_over_batched:.2f}x over "
               f"batched, incremental {incr_delta:.2f}x over rebuild, "
               "identical assignments")
        for k in range(-len(configs), 0):
            records[k]["identical_assignments"] = identical
        speedup_largest = speedup
        batched_speedup_largest = batched_speedup
        incremental_delta_largest = incr_delta
        jit_seconds_largest = times["jit"]
        batched_jit_seconds_largest = times["batched_jit"]
        spec_seconds_largest = times["spec"]
        spec_over_batched_largest = spec_over_batched

        # untimed profiled run: where the host iteration spends its time
        # (per-stage seconds summed over all iterations; profile=True adds
        # perf_counter calls, so this run is kept out of the timed configs
        # — benchmarks/ccmlb_quiesce.py owns the converged-tail assertions)
        resp = ccm_lb(phase, a0, params, n_iter=N_ITER, k_rounds=2,
                      fanout=4, seed=0, profile=True)
        stage_totals = {}
        for tm in resp.stage_timings:
            for stage, sec in tm.items():
                stage_totals[stage] = stage_totals.get(stage, 0.0) + sec
        report(f"ccmlb_ranks_{ranks}_stages", 0.0,
               " ".join(f"{s}={v*1e3:.1f}ms"
                        for s, v in sorted(stage_totals.items())))
        records.append({
            "ranks": ranks, "tasks": phase.num_tasks,
            "comms": phase.num_comms, "n_iter": N_ITER,
            "engine": True, "profiled": True,
            "stage_seconds": stage_totals,
            "memo_hits": int(resp.memo_hits),
            "gossip_noop_merges": int(resp.gossip_noop_merges),
        })

    # fanout/round sweep at 64 ranks (engine path — the default)
    phase = random_phase(2, num_ranks=64, num_tasks=1600, num_blocks=192,
                         num_comms=3200, mem_cap=1e12)
    a0 = initial_assignment(phase)
    for fanout, rounds in ((2, 1), (4, 2), (8, 3)):
        t0 = time.perf_counter()
        res = ccm_lb(phase, a0, params, n_iter=3, k_rounds=rounds,
                     fanout=fanout, seed=0)
        dt = time.perf_counter() - t0
        report(f"ccmlb_f{fanout}_k{rounds}", dt * 1e6,
               f"imb_after={res.imbalance[-1]:.4f} transfers={res.transfers}")
        records.append({
            "ranks": 64, "tasks": 1600, "comms": 3200, "n_iter": 3,
            "fanout": fanout, "k_rounds": rounds, "engine": True,
            "seconds": dt, "seconds_per_iteration": dt / 3,
            "imbalance_after": float(res.imbalance[-1]),
            "transfers": int(res.transfers),
        })

    payload = {
        "benchmark": "ccmlb_scaling",
        "numpy": np.__version__,
        "results": records,
        "engine_speedup_largest_config": speedup_largest,
        "batched_speedup_largest_config": batched_speedup_largest,
        "incremental_over_rebuild_largest_config": incremental_delta_largest,
        "jit_seconds_largest_config": jit_seconds_largest,
        "batched_jit_seconds_largest_config": batched_jit_seconds_largest,
        "spec_seconds_largest_config": spec_seconds_largest,
        "spec_speedup_over_batched": spec_over_batched_largest,
        "spec_window": SPEC_WINDOW,
        "jit_warmup_seconds": jit_warmup_seconds,
        "jit_buckets_compiled": scorer_jit.bucket_cache_size(),
        "trace_count": scorer_jit.trace_count(),
        "batch_lock_events": BATCH_EVENTS,
        # PR 3's recorded largest-config times; divide by this run's scalar
        # time over PR3_RECORDED["scalar"] to normalize machine speed
        "pr3_recorded_largest_config": PR3_RECORDED,
    }
    with open(JSON_PATH, "w") as f:
        json.dump(payload, f, indent=2)
    report("ccmlb_scaling_json", 0.0, f"written to {JSON_PATH}")
