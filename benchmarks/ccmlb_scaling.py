"""§IV scalability: CCM-LB solve time + quality vs rank count / fanout /
rounds (the paper reports <0.7 s at 14 ranks; we sweep up to 256).

Each rank-count config runs four times — scalar reference path
(``use_engine=False``), the engine with full per-event state re-gathering
(``incremental=False``, the rebuild reference), the incremental engine
(``use_engine=True``, the default), and the incremental engine with batched
lock events (``batch_lock_events=BATCH_EVENTS``: up to that many disjoint
rank pairs scored per flush through one block-diagonal flow assembly) —
and the results land in ``BENCH_ccmlb_scaling.json`` so the perf trajectory
(engine/batched speedups AND the incremental-vs-rebuild delta) is tracked
from PR to PR.  Every run of a config is checked for assignment identity
(recorded as ``identical_assignments`` and asserted here; see
repro/core/engine.py for the contract), so the speedup columns are apples
to apples.
"""
from __future__ import annotations

import json
import os
import time

import numpy as np

from repro.core import CCMParams, CCMState, ccm_lb, random_phase
from repro.core.problem import initial_assignment

JSON_PATH = os.environ.get("BENCH_CCMLB_JSON", "BENCH_ccmlb_scaling.json")
N_ITER = 4
BATCH_EVENTS = 8


def run(report):
    params = CCMParams(delta=1e-9)
    records = []
    speedup_largest = None
    batched_speedup_largest = None
    incremental_delta_largest = None
    for ranks in (16, 64, 256):
        phase = random_phase(1, num_ranks=ranks, num_tasks=25 * ranks,
                             num_blocks=3 * ranks, num_comms=50 * ranks,
                             mem_cap=1e12)
        a0 = initial_assignment(phase)
        st0 = CCMState.build(phase, a0, params)
        mean = phase.task_load.sum() / ranks
        times = {}
        assignments = {}
        configs = (("scalar", dict(use_engine=False)),
                   ("rebuild", dict(use_engine=True, incremental=False)),
                   ("engine", dict(use_engine=True)),
                   ("batched", dict(use_engine=True,
                                    batch_lock_events=BATCH_EVENTS)))
        for tag, kw in configs:
            t0 = time.perf_counter()
            res = ccm_lb(phase, a0, params, n_iter=N_ITER, k_rounds=2,
                         fanout=4, seed=0, **kw)
            dt = time.perf_counter() - t0
            times[tag] = dt
            assignments[tag] = res.assignment
            report(f"ccmlb_ranks_{ranks}_{tag}", dt * 1e6,
                   f"imb {st0.imbalance():.2f}->{res.imbalance[-1]:.4f} "
                   f"Wmax/mean={res.max_work[-1]/mean:.4f} "
                   f"transfers={res.transfers}")
            records.append({
                "ranks": ranks,
                "tasks": phase.num_tasks,
                "comms": phase.num_comms,
                "n_iter": N_ITER,
                "engine": kw.get("use_engine", True),
                "incremental": kw.get("incremental", True),
                "batch_lock_events": kw.get("batch_lock_events", 1),
                "seconds": dt,
                "seconds_per_iteration": dt / N_ITER,
                "imbalance_after": float(res.imbalance[-1]),
                "max_work_over_mean": float(res.max_work[-1] / mean),
                "transfers": int(res.transfers),
            })
        # ratio goes in the derived column only — the us_per_call column
        # stays a call time so the CSV is uniformly parseable
        identical = bool(all(
            np.array_equal(assignments[t], assignments["scalar"])
            for t in ("rebuild", "engine", "batched")))
        assert identical, \
            f"engine/batched/scalar trajectories diverged at {ranks} ranks"
        speedup = times["scalar"] / times["engine"]
        batched_speedup = times["scalar"] / times["batched"]
        incr_delta = times["rebuild"] / times["engine"]
        report(f"ccmlb_ranks_{ranks}_speedup", 0.0,
               f"engine {speedup:.2f}x, batched({BATCH_EVENTS}) "
               f"{batched_speedup:.2f}x over scalar, incremental "
               f"{incr_delta:.2f}x over rebuild, identical assignments")
        for k in range(-4, 0):
            records[k]["identical_assignments"] = identical
        speedup_largest = speedup
        batched_speedup_largest = batched_speedup
        incremental_delta_largest = incr_delta

    # fanout/round sweep at 64 ranks (engine path — the default)
    phase = random_phase(2, num_ranks=64, num_tasks=1600, num_blocks=192,
                         num_comms=3200, mem_cap=1e12)
    a0 = initial_assignment(phase)
    for fanout, rounds in ((2, 1), (4, 2), (8, 3)):
        t0 = time.perf_counter()
        res = ccm_lb(phase, a0, params, n_iter=3, k_rounds=rounds,
                     fanout=fanout, seed=0)
        dt = time.perf_counter() - t0
        report(f"ccmlb_f{fanout}_k{rounds}", dt * 1e6,
               f"imb_after={res.imbalance[-1]:.4f} transfers={res.transfers}")
        records.append({
            "ranks": 64, "tasks": 1600, "comms": 3200, "n_iter": 3,
            "fanout": fanout, "k_rounds": rounds, "engine": True,
            "seconds": dt, "seconds_per_iteration": dt / 3,
            "imbalance_after": float(res.imbalance[-1]),
            "transfers": int(res.transfers),
        })

    payload = {
        "benchmark": "ccmlb_scaling",
        "numpy": np.__version__,
        "results": records,
        "engine_speedup_largest_config": speedup_largest,
        "batched_speedup_largest_config": batched_speedup_largest,
        "incremental_over_rebuild_largest_config": incremental_delta_largest,
        "batch_lock_events": BATCH_EVENTS,
    }
    with open(JSON_PATH, "w") as f:
        json.dump(payload, f, indent=2)
    report("ccmlb_scaling_json", 0.0, f"written to {JSON_PATH}")
