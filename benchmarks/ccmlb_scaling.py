"""§IV scalability: CCM-LB solve time + quality vs rank count / fanout /
rounds (the paper reports <0.7 s at 14 ranks; we sweep up to 256)."""
from __future__ import annotations

import time

import numpy as np

from repro.core import CCMParams, CCMState, ccm_lb, random_phase
from repro.core.problem import initial_assignment


def run(report):
    params = CCMParams(delta=1e-9)
    for ranks in (16, 64, 256):
        phase = random_phase(1, num_ranks=ranks, num_tasks=25 * ranks,
                             num_blocks=3 * ranks, num_comms=50 * ranks,
                             mem_cap=1e12)
        a0 = initial_assignment(phase)
        st0 = CCMState.build(phase, a0, params)
        t0 = time.perf_counter()
        res = ccm_lb(phase, a0, params, n_iter=4, k_rounds=2, fanout=4,
                     seed=0)
        dt = time.perf_counter() - t0
        mean = phase.task_load.sum() / ranks
        report(f"ccmlb_ranks_{ranks}", dt * 1e6,
               f"imb {st0.imbalance():.2f}->{res.imbalance[-1]:.4f} "
               f"Wmax/mean={res.max_work[-1]/mean:.4f} "
               f"transfers={res.transfers}")
    # fanout/round sweep at 64 ranks
    phase = random_phase(2, num_ranks=64, num_tasks=1600, num_blocks=192,
                         num_comms=3200, mem_cap=1e12)
    a0 = initial_assignment(phase)
    for fanout, rounds in ((2, 1), (4, 2), (8, 3)):
        t0 = time.perf_counter()
        res = ccm_lb(phase, a0, params, n_iter=3, k_rounds=rounds,
                     fanout=fanout, seed=0)
        dt = time.perf_counter() - t0
        report(f"ccmlb_f{fanout}_k{rounds}", dt * 1e6,
               f"imb_after={res.imbalance[-1]:.4f} transfers={res.transfers}")
