"""Benchmark harness: one module per paper table/figure (+ beyond-paper).

Discovers every ``benchmarks/*.py`` module exposing a ``run(report)``
callable (no hand-maintained registry — a new benchmark file is picked up
automatically), prints ``name,us_per_call,derived`` CSV rows while running,
and finishes with one summary table of every ``BENCH_*.json`` artifact in
the working directory so the whole perf trajectory is visible in one
place.

  PYTHONPATH=src python -m benchmarks.run            # all
  PYTHONPATH=src python -m benchmarks.run ccmlb      # filter by substring
  PYTHONPATH=src python -m benchmarks.run --summary  # just the table
  PYTHONPATH=src python -m benchmarks.run --summary --records  # + records
"""
from __future__ import annotations

import glob
import importlib
import json
import pkgutil
import sys
import traceback

import benchmarks

# preferred display names (and run order) for the paper-figure modules;
# discovered modules not listed here run afterwards in alphabetical order
DISPLAY = {
    "milp_vs_ccmlb": "fig4a_milp_vs_ccmlb",
    "delta_sweep": "fig4b_delta_sweep",
    "assembly_scaling": "fig5_assembly_scaling",
    "costmodel_eval": "costmodel",
    "kernels_bench": "kernels",
}
ORDER = ["milp_vs_ccmlb", "delta_sweep", "assembly_scaling", "costmodel_eval",
         "ccmlb_scaling", "ccmlb_spec", "ccmlb_fleet", "ccmlb_pipeline",
         "ccmlb_async", "ccmlb_fault", "ccmlb_memory", "ccmlb_quiesce",
         "scorer_paths",
         "kernels_bench",
         "expert_placement",
         "roofline"]


def discover():
    """(display_name, module) for every benchmarks submodule with run()."""
    names = [m.name for m in pkgutil.iter_modules(benchmarks.__path__)
             if m.name not in ("run", "render_experiments")]
    names.sort(key=lambda n: (ORDER.index(n) if n in ORDER else len(ORDER), n))
    out = []
    for name in names:
        try:
            mod = importlib.import_module(f"benchmarks.{name}")
        except Exception:
            traceback.print_exc()
            continue
        if callable(getattr(mod, "run", None)):
            out.append((DISPLAY.get(name, name), mod))
    return out


def _fmt(v) -> str:
    if isinstance(v, bool):
        return str(v)
    if isinstance(v, float):
        return f"{v:.3f}"
    return str(v)


def _records_table(records, out):
    """Render a list of per-config record dicts as one aligned table.

    Different configs legitimately carry different fields (a spec record
    has window/rollback counters a scalar record doesn't; the fanout sweep
    has no backend column), so the columns are the UNION of keys in
    first-seen order and a record missing a field shows ``-`` instead of
    raising.  List/dict-valued fields are skipped — they don't fit a cell.
    """
    cols = []
    for rec in records:
        if not isinstance(rec, dict):
            return
        for k, v in rec.items():
            if k not in cols and not isinstance(v, (list, dict)):
                cols.append(k)
    if not cols:
        return
    table = [cols] + [[_fmt(rec[k]) if k in rec
                       and not isinstance(rec[k], (list, dict)) else "-"
                       for k in cols] for rec in records]
    widths = [max(len(row[i]) for row in table) for i in range(len(cols))]
    for row in table:
        out("    " + "  ".join(c.ljust(w) for c, w in zip(row, widths))
            .rstrip())


def summarize_bench_json(out=print, records: bool = False):
    """One table over every BENCH_*.json: headline scalar fields per file,
    plus (with ``records=True``) the per-record table of each artifact."""
    paths = sorted(glob.glob("BENCH_*.json"))
    if not paths:
        out("(no BENCH_*.json artifacts found)")
        return
    rows = []
    for path in paths:
        try:
            with open(path) as f:
                payload = json.load(f)
        except Exception as exc:  # unreadable artifact: surface, don't die
            rows.append((path, [f"UNREADABLE: {exc}"], None))
            continue
        fields = [f"{k}={_fmt(v)}" for k, v in payload.items()
                  if isinstance(v, (int, float, bool))
                  and not isinstance(v, str)]
        recs = payload.get("results", [])
        n = len(recs) if isinstance(recs, list) else 0
        if n:
            fields.insert(0, f"records={n}")
        rows.append((path, fields, recs if n else None))
    width = max(len(p) for p, _, _ in rows)
    out("")
    out("=" * 72)
    out("BENCH_*.json summary")
    out("=" * 72)
    for path, fields, recs in rows:
        out(f"{path:<{width}}  {'; '.join(fields) if fields else '-'}")
        if records and recs:
            _records_table(recs, out)
    out("=" * 72)


def main() -> None:
    args = [a for a in sys.argv[1:]]
    if "--summary" in args:
        summarize_bench_json(records="--records" in args)
        return
    args = [a for a in args if not a.startswith("--")]
    filt = args[0] if args else ""
    print("name,us_per_call,derived")

    def report(name: str, us: float, derived: str = ""):
        print(f"{name},{us:.1f},{derived}", flush=True)

    for name, mod in discover():
        if filt and filt not in name:
            continue
        try:
            mod.run(report)
        except Exception:
            traceback.print_exc()
            report(f"{name}_FAILED", 0.0, "see stderr")
    summarize_bench_json()


if __name__ == "__main__":
    main()
