"""Benchmark harness: one module per paper table/figure (+ beyond-paper).

Prints ``name,us_per_call,derived`` CSV rows.

  PYTHONPATH=src python -m benchmarks.run            # all
  PYTHONPATH=src python -m benchmarks.run fig4a      # filter by substring
"""
from __future__ import annotations

import sys
import traceback

from benchmarks import (assembly_scaling, ccmlb_pipeline, ccmlb_scaling,
                        costmodel_eval, delta_sweep, expert_placement,
                        kernels_bench, milp_vs_ccmlb, roofline)

MODULES = [
    ("fig4a_milp_vs_ccmlb", milp_vs_ccmlb),
    ("fig4b_delta_sweep", delta_sweep),
    ("fig5_assembly_scaling", assembly_scaling),
    ("costmodel", costmodel_eval),
    ("ccmlb_scaling", ccmlb_scaling),
    ("ccmlb_pipeline", ccmlb_pipeline),
    ("kernels", kernels_bench),
    ("expert_placement", expert_placement),
    ("roofline", roofline),
]


def main() -> None:
    filt = sys.argv[1] if len(sys.argv) > 1 else ""
    print("name,us_per_call,derived")

    def report(name: str, us: float, derived: str = ""):
        print(f"{name},{us:.1f},{derived}", flush=True)

    for name, mod in MODULES:
        if filt and filt not in name:
            continue
        try:
            mod.run(report)
        except Exception:
            traceback.print_exc()
            report(f"{name}_FAILED", 0.0, "see stderr")


if __name__ == "__main__":
    main()
