"""CCM work-model invariants (paper §III): update formulae == recomputation,
memory barrier, homing costs.  Property-based via hypothesis."""
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import CCMParams, CCMState, exchange_eval, random_phase
from repro.core.problem import initial_assignment

PARAMS = CCMParams(alpha=1.0, beta=1e-9, gamma=1e-11, delta=1e-9,
                   memory_constraint=False)


def _phase(seed, ranks=4, tasks=24, blocks=6, comms=40):
    return random_phase(seed, num_ranks=ranks, num_tasks=tasks,
                        num_blocks=blocks, num_comms=comms, mem_cap=1e12)


@settings(max_examples=50, deadline=None)
@given(seed=st.integers(0, 1000), data=st.data())
def test_exchange_eval_matches_recompute(seed, data):
    """Thm III.1 + eq (2) + comm updates: O(1) update formulae must equal a
    full rebuild after the exchange is applied."""
    phase = _phase(seed)
    a0 = initial_assignment(phase, "round_robin")
    state = CCMState.build(phase, a0, PARAMS)
    r_a, r_b = 0, data.draw(st.integers(1, phase.num_ranks - 1))
    on_a = np.nonzero(a0 == r_a)[0]
    on_b = np.nonzero(a0 == r_b)[0]
    n_ab = data.draw(st.integers(0, min(4, len(on_a))))
    n_ba = data.draw(st.integers(0, min(4, len(on_b))))
    tasks_ab = list(on_a[:n_ab])
    tasks_ba = list(on_b[:n_ba])

    ev = exchange_eval(state, tasks_ab, tasks_ba, r_a, r_b)

    a1 = a0.copy()
    a1[tasks_ab] = r_b
    a1[tasks_ba] = r_a
    truth = CCMState.build(phase, a1, PARAMS)
    assert ev.work_a_after == pytest.approx(truth.work(r_a), rel=1e-9, abs=1e-12)
    assert ev.work_b_after == pytest.approx(truth.work(r_b), rel=1e-9, abs=1e-12)


@settings(max_examples=30, deadline=None)
@given(seed=st.integers(0, 1000), data=st.data())
def test_apply_transfer_incremental_consistency(seed, data):
    """Repeated apply_transfer must keep every derived quantity equal to a
    from-scratch rebuild."""
    phase = _phase(seed)
    a0 = initial_assignment(phase, "home")
    state = CCMState.build(phase, a0, PARAMS)
    for _ in range(5):
        r_from = data.draw(st.integers(0, phase.num_ranks - 1))
        on = np.nonzero(state.assignment == r_from)[0]
        if len(on) == 0:
            continue
        n = data.draw(st.integers(1, min(3, len(on))))
        r_to = (r_from + 1 + data.draw(st.integers(0, phase.num_ranks - 2))) \
            % phase.num_ranks
        state.apply_transfer(on[:n], r_from, r_to)
    truth = CCMState.build(phase, state.assignment, PARAMS)
    np.testing.assert_allclose(state.load, truth.load, rtol=1e-9, atol=1e-9)
    np.testing.assert_allclose(state.vol, truth.vol, rtol=1e-9, atol=1e-6)
    np.testing.assert_array_equal(state.block_count, truth.block_count)
    np.testing.assert_allclose(state.mem_task, truth.mem_task, rtol=1e-9,
                               atol=1e-6)
    for r in range(phase.num_ranks):
        assert state.work(r) == pytest.approx(truth.work(r), rel=1e-9,
                                              abs=1e-9)


def test_memory_barrier_epsilon():
    """(9): infeasible rank -> W = +inf; feasible -> finite."""
    phase = _phase(0)
    phase.rank_mem_cap[:] = 1.0  # impossible
    params = CCMParams(memory_constraint=True)
    st_ = CCMState.build(phase, initial_assignment(phase, "home"), params)
    assert np.isinf(st_.max_work())
    phase.rank_mem_cap[:] = 1e15
    st2 = CCMState.build(phase, initial_assignment(phase, "home"), params)
    assert np.isfinite(st2.max_work())


def test_homing_cost_definition():
    """(10): M_H counts only off-home blocks present on the rank."""
    phase = _phase(3)
    a = initial_assignment(phase, "home")
    state = CCMState.build(phase, a, CCMParams())
    for r in range(phase.num_ranks):
        manual = 0.0
        for b in range(phase.num_blocks):
            present = np.any((a == r) & (phase.task_block == b))
            if present and phase.block_home[b] != r:
                manual += phase.block_size[b]
        assert state.homing_cost(r) == pytest.approx(manual)


def test_off_rank_volume_is_max_of_send_recv():
    """(5): V_notin = max(sent, received), excluding self-edges."""
    phase = _phase(4)
    a = initial_assignment(phase, "round_robin")
    state = CCMState.build(phase, a, PARAMS)
    for r in range(phase.num_ranks):
        sent = sum(v for s, d, v in zip(a[phase.comm_src], a[phase.comm_dst],
                                        phase.comm_vol) if s == r and d != r)
        recv = sum(v for s, d, v in zip(a[phase.comm_src], a[phase.comm_dst],
                                        phase.comm_vol) if d == r and s != r)
        assert state.off_rank_volume(r) == pytest.approx(max(sent, recv))


def test_speed_factors_scale_load():
    phase = _phase(5)
    phase.rank_speed[:] = 1.0
    phase.rank_speed[0] = 0.5
    a = initial_assignment(phase, "round_robin")
    state = CCMState.build(phase, a, CCMParams(alpha=1.0, beta=0, gamma=0,
                                               delta=0,
                                               memory_constraint=False))
    assert state.work(0) == pytest.approx(state.load[0] / 0.5)
