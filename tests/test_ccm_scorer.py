"""CCM scorer kernel parity: NumPy reference tiles vs the Pallas kernel
(interpret mode) must agree BITWISE — on raw packed tiles, on engine
scores through both backends, and on end-to-end CCM-LB assignments.  The
contract and why it is achievable (multiplication-free kernel body + shared
host combine) is documented in repro/kernels/ccm_scorer/kernel.py."""
import numpy as np
import pytest

from repro.core import CCMParams, CCMState, ccm_lb, random_phase
from repro.core.clusters import build_clusters
from repro.core.engine import ExchangeEvent, PhaseEngine
from repro.core.problem import Phase, initial_assignment
from repro.kernels.ccm_scorer import N_AV, N_PM, N_SC, SC, ops, ref

PARAMS = CCMParams(alpha=1.0, beta=1e-9, gamma=1e-11, delta=1e-9,
                   memory_constraint=True)


def _random_tiles(seed, e_n=4, a_n=16, b_n=16):
    rng = np.random.default_rng(seed)
    av = rng.uniform(-2, 2, (e_n, N_AV, a_n))
    bv = rng.uniform(-2, 2, (e_n, N_AV, b_n))
    pm = rng.uniform(-2, 2, (e_n, N_PM, a_n, b_n))
    sc = rng.uniform(0.1, 3.0, (e_n, N_SC))
    sc[:, SC.na] = rng.integers(0, a_n, e_n)
    sc[:, SC.nb] = rng.integers(0, b_n, e_n)
    return av, bv, pm, sc


# -------------------------------------------------------------- raw tiles
@pytest.mark.parametrize("seed", range(5))
def test_kernel_bitwise_matches_ref_on_random_tiles(seed):
    av, bv, pm, sc = _random_tiles(seed)
    got = ops.ccm_score_tiles(av, bv, pm, sc, backend="pallas",
                              interpret=True)
    want = ref.score_tiles(av, bv, pm, sc)
    np.testing.assert_array_equal(got, want)


def test_kernel_masked_tail():
    """Slots past (na, nb) must be exactly 0 (flow planes) / +inf (memory
    planes) so padded pairs can never look feasible."""
    av, bv, pm, sc = _random_tiles(7, e_n=2, a_n=8, b_n=8)
    sc[:, SC.na] = [2, 0]
    sc[:, SC.nb] = [3, 0]
    for backend in ("numpy", "pallas", "jit"):
        out = ops.ccm_score_tiles(av, bv, pm, sc, backend=backend)
        for e, (na, nb) in enumerate(((2, 3), (0, 0))):
            tail = np.ones((8, 8), bool)
            tail[:na + 1, :nb + 1] = False
            assert (out[e, :8][:, tail] == 0.0).all()
            assert np.isinf(out[e, 8:][:, tail]).all()
            assert np.isfinite(out[e, :, :na + 1, :nb + 1]).all()


# ------------------------------------------------------- engine backends
def _events_for(state, clusters, rank_pairs, n_cand=6):
    empty = np.zeros(0, np.int64)
    events = []
    for r_a, r_b in rank_pairs:
        cand_a = [empty] + clusters[r_a][:n_cand]
        cand_b = [empty] + clusters[r_b][:n_cand]
        pairs = [(ia, ib) for ia in range(len(cand_a))
                 for ib in range(len(cand_b)) if ia or ib]
        events.append(ExchangeEvent(r_a, r_b, cand_a, cand_b, pairs))
    return events


@pytest.mark.parametrize("seed", range(8))
def test_engine_backends_bitwise_equal_scores(seed):
    phase = random_phase(seed, num_ranks=8, num_tasks=120, num_blocks=14,
                        num_comms=260, mem_cap=4e8 if seed % 2 else 1e12)
    params = CCMParams(alpha=1.0, beta=1e-9, gamma=1e-11, delta=1e-9,
                       memory_constraint=bool(seed % 3))
    state = CCMState.build(
        phase, initial_assignment(phase, "home" if seed % 2 else
                                  "round_robin"), params)
    clusters = build_clusters(state)
    events = _events_for(state, clusters, ((0, 1), (2, 3), (4, 5), (6, 7)))
    res_np = PhaseEngine(state, backend="numpy") \
        .batch_exchange_eval_multi(events)
    res_pl = PhaseEngine(state, backend="pallas") \
        .batch_exchange_eval_multi(events)
    for (wa, wb, fe), (wa2, wb2, fe2) in zip(res_np, res_pl):
        np.testing.assert_array_equal(wa, wa2)
        np.testing.assert_array_equal(wb, wb2)
        np.testing.assert_array_equal(fe, fe2)


def test_engine_backends_empty_candidates():
    """na = nb = 0 (both sides only offer the empty cluster) must survive
    both backends: no pairs to score, no crash, empty outputs."""
    phase = random_phase(3, num_ranks=4, num_tasks=40, num_blocks=6,
                        num_comms=80, mem_cap=1e12)
    state = CCMState.build(phase, initial_assignment(phase, "home"), PARAMS)
    empty = np.zeros(0, np.int64)
    events = [ExchangeEvent(0, 1, [empty], [empty], [])]
    for backend in ("numpy", "pallas", "jit", "pallas_compiled"):
        [(wa, wb, fe)] = PhaseEngine(state, backend=backend) \
            .batch_exchange_eval_multi(events)
        assert wa.shape == wb.shape == fe.shape == (0,)


def test_engine_backends_single_task_phase():
    """One task, one candidate, one-sided give — the smallest real tile."""
    phase = Phase(
        task_load=np.array([2.0]), task_mem=np.array([8.0]),
        task_overhead=np.array([1.0]), task_block=np.array([0]),
        block_size=np.array([16.0]), block_home=np.array([0]),
        comm_src=np.array([0]), comm_dst=np.array([0]),
        comm_vol=np.array([3.0]),
        rank_mem_base=np.zeros(2), rank_mem_cap=np.full(2, 1e9))
    state = CCMState.build(phase, np.array([0]), PARAMS)
    clusters = build_clusters(state)
    empty = np.zeros(0, np.int64)
    cand_a = [empty] + clusters[0]
    events = [ExchangeEvent(0, 1, cand_a, [empty], [(1, 0)])]
    outs = {}
    for backend in ("numpy", "pallas"):
        [(wa, wb, fe)] = PhaseEngine(state, backend=backend) \
            .batch_exchange_eval_multi(events)
        outs[backend] = (wa, wb, fe)
        assert fe[0]
    np.testing.assert_array_equal(outs["numpy"][0], outs["pallas"][0])
    np.testing.assert_array_equal(outs["numpy"][1], outs["pallas"][1])
    # giving the only task away moves its load and block to rank 1
    from repro.core import exchange_eval
    ev = exchange_eval(state, clusters[0][0], [], 0, 1)
    np.testing.assert_allclose(outs["numpy"][0][0], ev.work_a_after,
                               rtol=1e-9, atol=1e-12)
    np.testing.assert_allclose(outs["numpy"][1][0], ev.work_b_after,
                               rtol=1e-9, atol=1e-12)


# ------------------------------------------------------------ end to end
@pytest.mark.parametrize("batch", [1, 4])
@pytest.mark.parametrize("backend", ["pallas", "jit"])
def test_ccmlb_f64_backends_identical_assignments(backend, batch):
    """Acceptance: the f64-bitwise backends (Pallas interpret, bucketed
    jit) and the NumPy engine produce bitwise-identical CCM-LB
    assignments (small phase — one launch per flush)."""
    phase = random_phase(11, num_ranks=6, num_tasks=90, num_blocks=12,
                        num_comms=200, mem_cap=5e8)
    params = CCMParams(delta=1e-9)
    a0 = initial_assignment(phase)
    ref_run = ccm_lb(phase, a0, params, n_iter=2, seed=1, backend="numpy",
                     batch_lock_events=batch)
    got = ccm_lb(phase, a0, params, n_iter=2, seed=1, backend=backend,
                 batch_lock_events=batch)
    np.testing.assert_array_equal(got.assignment, ref_run.assignment)
    assert got.max_work == ref_run.max_work
    assert got.transfers == ref_run.transfers


def test_unknown_backend_rejected():
    phase = random_phase(0, num_ranks=3, num_tasks=12, num_blocks=2,
                        num_comms=10, mem_cap=1e12)
    state = CCMState.build(phase, initial_assignment(phase, "home"), PARAMS)
    with pytest.raises(ValueError):
        PhaseEngine(state, backend="tpu")
    with pytest.raises(ValueError):
        ops.ccm_score_tiles(*_random_tiles(0, e_n=1), backend="cuda")
