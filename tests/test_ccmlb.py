"""CCM-LB algorithm behaviour (paper §IV): monotone improvement, feasibility
preservation, determinism, gossip reachability, lock protocol."""
import numpy as np
import pytest

from repro.core import CCMParams, CCMState, ccm_lb, random_phase
from repro.core.clusters import build_clusters, summarize_clusters, summarize_rank
from repro.core.gossip import build_peer_networks
from repro.core.locks import LockManager
from repro.core.problem import initial_assignment


def test_ccmlb_improves_and_stays_feasible():
    phase = random_phase(0, num_ranks=16, num_tasks=400, num_blocks=48,
                         num_comms=800, mem_cap=3e8)
    params = CCMParams(delta=1e-9)
    a0 = initial_assignment(phase)
    st0 = CCMState.build(phase, a0, params)
    res = ccm_lb(phase, a0, params, n_iter=4, k_rounds=2, fanout=4, seed=1)
    assert res.max_work[-1] <= st0.max_work() * (1 + 1e-9)
    # monotone per iteration
    for a, b in zip(res.max_work, res.max_work[1:]):
        assert b <= a + 1e-9
    final = CCMState.build(phase, res.assignment, params)
    for r in range(phase.num_ranks):
        assert final.memory_feasible(r)
    # close to the mean-load lower bound on this compute-dominated instance
    mean = phase.task_load.sum() / phase.num_ranks
    assert res.max_work[-1] <= mean * 1.10


def test_ccmlb_deterministic():
    phase = random_phase(3, num_ranks=8, num_tasks=120, num_blocks=16,
                         num_comms=240, mem_cap=1e9)
    a0 = initial_assignment(phase)
    params = CCMParams()
    r1 = ccm_lb(phase, a0, params, n_iter=3, seed=7)
    r2 = ccm_lb(phase, a0, params, n_iter=3, seed=7)
    np.testing.assert_array_equal(r1.assignment, r2.assignment)
    r3 = ccm_lb(phase, a0, params, n_iter=3, seed=8)
    # different seeds explore different peers (usually different result)
    assert r3.max_work[-1] <= r1.max_work[0]


def test_ccmlb_respects_tight_memory():
    """With tight caps, CCM-LB must refuse transfers that violate (9)."""
    phase = random_phase(5, num_ranks=8, num_tasks=100, num_blocks=12,
                         num_comms=100, mem_cap=2.2e8)
    params = CCMParams(memory_constraint=True)
    a0 = initial_assignment(phase)
    st0 = CCMState.build(phase, a0, params)
    if not all(st0.memory_feasible(r) for r in range(8)):
        pytest.skip("initial layout infeasible for this seed")
    res = ccm_lb(phase, a0, params, n_iter=3, seed=0)
    final = CCMState.build(phase, res.assignment, params)
    for r in range(phase.num_ranks):
        assert final.memory_feasible(r)


def test_gossip_reachability_and_payload():
    phase = random_phase(1, num_ranks=32, num_tasks=64, num_blocks=8,
                         num_comms=64, mem_cap=1e9)
    params = CCMParams()
    state = CCMState.build(phase, initial_assignment(phase), params)
    clusters = build_clusters(state)
    csum = summarize_clusters(state, clusters)
    summaries = {r: summarize_rank(state, r, csum[r]) for r in range(32)}
    info = build_peer_networks(summaries, k_rounds=2, fanout=4, seed=0)
    sizes = [len(info[r]) for r in range(32)]
    # with f=4, k=2 every rank should know >1 peer, well above fanout alone
    assert min(sizes) >= 2
    assert max(sizes) <= 32
    # payload carries the augmented info (clusters etc.)
    some = next(iter(info[0].values()))
    assert hasattr(some, "vol_off") and hasattr(some, "clusters")
    # rank always knows itself
    for r in range(32):
        assert r in info[r]


def test_gossip_more_rounds_more_peers():
    phase = random_phase(2, num_ranks=64, num_tasks=64, num_blocks=4,
                         num_comms=32, mem_cap=1e9)
    state = CCMState.build(phase, initial_assignment(phase), CCMParams())
    clusters = build_clusters(state)
    csum = summarize_clusters(state, clusters)
    summaries = {r: summarize_rank(state, r, csum[r]) for r in range(64)}
    n1 = np.mean([len(build_peer_networks(summaries, k_rounds=1, fanout=3,
                                          seed=0)[r]) for r in range(64)])
    n2 = np.mean([len(build_peer_networks(summaries, k_rounds=3, fanout=3,
                                          seed=0)[r]) for r in range(64)])
    assert n2 > n1


def test_lock_protocol_cycle_broken():
    """The r_x <= r_2 release rule (Fig. 1 line 45)."""
    lm = LockManager(3)
    assert lm.request(0, 1)          # 0 locks 1
    assert lm.request(1, 2)          # 1 (locked? no) locks 2
    assert lm.request(2, 0)          # 2 locks 0 -> cycle 0->1->2->0
    # now each holder is itself locked; check the yield rule fires for the
    # holder whose locker has lower-or-equal id than its held target
    yields = {r: lm.must_yield(r, held) for r, held in ((0, 1), (1, 2), (2, 0))}
    assert any(yields.values())      # at least one must yield -> no deadlock


def test_lock_queue_fifo():
    lm = LockManager(4)
    assert lm.request(1, 0)
    assert not lm.request(2, 0)
    assert not lm.request(3, 0)
    nxt = lm.release(1, 0)
    assert nxt == 2
    nxt = lm.release(2, 0)
    assert nxt == 3


def test_cluster_splitting_enables_replication():
    """Clusters finer than a block's task set let CCM-LB replicate blocks
    (paper §III-A4's parallelism-vs-memory trade)."""
    phase = random_phase(11, num_ranks=4, num_tasks=64, num_blocks=2,
                         num_comms=16, mem_cap=1e12)
    # all tasks on one block, huge loads on that block -> must split
    phase.task_block[:] = 0
    a0 = np.zeros(64, np.int64)
    phase.block_home[:] = 0
    params = CCMParams(alpha=1.0, beta=0.0, gamma=0.0, delta=1e-12,
                       memory_constraint=False)
    res = ccm_lb(phase, a0, params, n_iter=4, fanout=3, seed=0)
    final = CCMState.build(phase, res.assignment, params)
    # block 0 replicated on several ranks; max work near mean
    assert (final.block_count[:, 0] > 0).sum() >= 2
    mean = phase.task_load.sum() / 4
    assert res.max_work[-1] <= mean * 1.35
