"""Multi-pod dry-run smoke (subprocess: needs 512 placeholder devices, which
must never leak into this pytest process).  The full 33-cell x 2-mesh sweep
runs via `python -m repro.launch.dryrun --all --mesh both`; its cached
results are validated here too."""
import json
import os
import subprocess
import sys
from pathlib import Path

import pytest

ROOT = Path(__file__).resolve().parents[1]
RESULTS = ROOT / "benchmarks" / "results" / "dryrun.json"


@pytest.mark.slow
def test_dryrun_single_cell_subprocess():
    env = dict(os.environ)
    env["PYTHONPATH"] = str(ROOT / "src")
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch", "smollm-360m",
         "--shape", "decode_32k", "--mesh", "multi", "--force",
         "--out", "/tmp/dryrun_test.json"],
        env=env, capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, out.stdout[-2000:] + out.stderr[-2000:]
    data = json.loads(Path("/tmp/dryrun_test.json").read_text())
    rec = data["smollm-360m|decode_32k|2x16x16"]
    assert rec["ok"]
    assert rec["stats"]["flops"] > 0
    assert rec["roofline"]["dominant"] in ("compute", "memory", "collective")


def test_cached_dryrun_results_complete():
    """The committed sweep artifact must cover every runnable cell on both
    meshes with ok=True."""
    if not RESULTS.exists():
        pytest.skip("dry-run sweep artifact not present")
    from repro import configs
    data = json.loads(RESULTS.read_text())
    missing, failed = [], []
    for arch, shape in configs.cells():
        for mesh in ("16x16", "2x16x16"):
            rec = data.get(f"{arch}|{shape}|{mesh}")
            if rec is None:
                missing.append((arch, shape, mesh))
            elif not rec.get("ok"):
                failed.append((arch, shape, mesh))
    assert not failed, failed
    assert not missing, missing


def test_roofline_terms_sane():
    if not RESULTS.exists():
        pytest.skip("dry-run sweep artifact not present")
    data = json.loads(RESULTS.read_text())
    for key, rec in data.items():
        if not rec.get("ok"):
            continue
        r = rec["roofline"]
        assert r["compute_s"] >= 0 and r["memory_s"] >= 0
        assert r["collective_s"] >= 0
        if "unrolled" in rec["mesh"]:
            # exact accounting: the fraction is a true fraction
            assert 0 <= r["roofline_fraction"] <= 1.5, (key, r)
        # scan-lowered rows are per-period lower bounds (XLA counts a while
        # body once — see ModelConfig.unroll_stack), so no upper bound there.
        if rec["mesh"] == "16x16" and rec["kind"] == "train":
            # training cells must actually communicate (grad reduction)
            assert rec["stats"]["collective_bytes_total"] > 0, key
