"""MILP formulations (paper §V): Boolean/integer theorem checks, B&B vs
brute force, full-vs-reduced FWMP equivalence, CCM-LB optimality gap."""
import itertools

import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import CCMParams, CCMState, ccm_lb, random_phase
from repro.core.milp import (build_comcp, build_fwmp, build_fwmp_reduced,
                             simplex_solve, solve_milp)
from repro.core.problem import initial_assignment


def test_simplex_known_cases():
    r = simplex_solve(np.array([-1., -1.]),
                      A_ub=np.array([[1., 1.], [1., 0.], [0., 1.]]),
                      b_ub=np.array([4., 3., 2.]))
    assert r.status == "optimal" and r.objective == pytest.approx(-4.0)
    r = simplex_solve(np.array([1., 2.]), A_eq=np.array([[1., 1.]]),
                      b_eq=np.array([3.]), A_ub=np.array([[1., 0.]]),
                      b_ub=np.array([1.]))
    assert r.status == "optimal" and r.objective == pytest.approx(5.0)
    assert simplex_solve(np.array([1.]), A_ub=np.array([[1.]]),
                         b_ub=np.array([-1.])).status == "infeasible"
    assert simplex_solve(np.array([-1.])).status == "unbounded"


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 500))
def test_simplex_feasible_and_optimal_basic(seed):
    rng = np.random.default_rng(seed)
    n, m = int(rng.integers(2, 7)), int(rng.integers(2, 9))
    A = rng.normal(size=(m, n))
    b = np.abs(rng.normal(size=m)) + 0.5
    c = rng.normal(size=n)
    r = simplex_solve(c, A_ub=A, b_ub=b)
    if r.status == "optimal":
        assert (A @ r.x <= b + 1e-6).all()
        assert (r.x >= -1e-9).all()
        # optimality sanity: random feasible points never beat it
        for _ in range(50):
            x = np.abs(rng.normal(size=n)) * 0.2
            if (A @ x <= b).all():
                assert c @ x >= r.objective - 1e-6


def _brute_force(phase, params):
    best, best_a = np.inf, None
    for bits in itertools.product(range(phase.num_ranks),
                                  repeat=phase.num_tasks):
        a = np.array(bits)
        w = CCMState.build(phase, a, params).max_work()
        if w < best:
            best, best_a = w, a
    return best, best_a


def test_comcp_matches_brute_force():
    phase = random_phase(3, num_ranks=2, num_tasks=6, num_blocks=2,
                         num_comms=6, mem_cap=1e9)
    params = CCMParams(alpha=1.0, beta=0., gamma=0., delta=0.)
    res = solve_milp(build_comcp(phase, params), max_nodes=500)
    best, _ = _brute_force(phase, params)
    assert res.objective == pytest.approx(best, abs=1e-8)


@pytest.mark.parametrize("seed", [5, 9, 11])
def test_fwmp_matches_brute_force_and_reduced(seed):
    phase = random_phase(seed, num_ranks=2, num_tasks=5, num_blocks=2,
                         num_comms=5, mem_cap=1e9)
    params = CCMParams(alpha=1.0, beta=1e-8, gamma=1e-10, delta=1e-8)
    full = solve_milp(build_fwmp(phase, params), max_nodes=500)
    red = solve_milp(build_fwmp_reduced(phase, params), max_nodes=500)
    best, _ = _brute_force(phase, params)
    assert full.objective == pytest.approx(best, abs=1e-8)
    assert red.objective == pytest.approx(best, abs=1e-8)
    # decoded assignment evaluates to the same W_max under the CCM state
    from repro.core.milp.fwmp import MILP  # noqa: F401
    a = red.x[: 2 * 5].reshape(2, 5).argmax(0)
    assert CCMState.build(phase, a, params).max_work() == pytest.approx(
        best, abs=1e-8)


def test_memory_constraint_changes_optimum():
    """(19): tight memory must force a worse (but feasible) makespan."""
    phase = random_phase(13, num_ranks=2, num_tasks=6, num_blocks=2,
                         num_comms=4, mem_cap=1e12)
    params_loose = CCMParams(alpha=1.0, beta=0., gamma=0., delta=0.,
                             memory_constraint=True)
    loose = solve_milp(build_comcp(phase, params_loose), max_nodes=300)
    # tighten so one rank cannot hold everything
    phase.rank_mem_cap[:] = phase.block_size.sum() + phase.task_mem.sum()
    tight = solve_milp(build_comcp(phase, params_loose), max_nodes=300)
    assert tight.objective >= loose.objective - 1e-9


def test_constraint19_alignment_with_heuristic_gate():
    """(19) RHS alignment: the MILP charges memory against the SAME
    effective_mem_cap soft cap as the heuristic's feasibility gate
    (headroom shaving + relative tolerance), so a MILP-feasible decode
    always passes ``memory_feasible``.  The instance is built so the
    unconstrained load optimum ({0} | {1,2,3}, W=3) carries 7 bytes on
    one rank and violates the shaved cap of 6 — a looser RHS (the raw
    hardware cap of 12) would return it and fail the gate."""
    from repro.core.problem import Phase
    phase = Phase(task_load=[3.0, 1.0, 1.0, 1.0],
                  task_mem=[1.0, 3.0, 3.0, 1.0],
                  task_overhead=[0.0] * 4,
                  task_block=[-1] * 4,
                  block_size=[], block_home=[],
                  comm_src=[], comm_dst=[], comm_vol=[],
                  rank_mem_base=[0.0, 0.0],
                  rank_mem_cap=[12.0, 12.0])
    params = CCMParams(alpha=1.0, beta=0., gamma=0., delta=0.,
                       memory_constraint=True, mem_headroom=0.5)
    # soft cap = 6: the memory-feasible optimum is {0,3} | {1,2} at W=4
    for build in (build_comcp, build_fwmp_reduced):
        res = solve_milp(build(phase, params), max_nodes=500)
        assert res.status == "optimal"
        assert res.objective == pytest.approx(4.0, abs=1e-8)
        a = res.x[: 2 * 4].reshape(2, 4).argmax(0)
        st = CCMState.build(phase, a, params)
        assert all(st.memory_feasible(r) for r in range(2))


def test_ccmlb_gap_vs_optimal_paper_style():
    """Paper Fig 4a: CCM-LB within a few percent of the certified optimum."""
    phase = random_phase(7, num_ranks=4, num_tasks=14, num_blocks=4,
                         num_comms=16, mem_cap=5e8)
    params = CCMParams(alpha=1.0, beta=1e-9, gamma=1e-11, delta=1e-9)
    a0 = initial_assignment(phase)
    best_lb = min(ccm_lb(phase, a0, params, n_iter=4, fanout=3,
                         seed=s).max_work[-1] for s in range(12))
    res = solve_milp(build_fwmp_reduced(phase, params), max_nodes=1500,
                     time_limit_s=90)
    assert res.status in ("optimal", "node_limit")
    assert np.isfinite(res.objective)
    incr = (best_lb - res.objective) / res.objective
    assert incr >= -1e-9          # heuristic can't beat the optimum
    assert incr < 0.12            # and lands within ~10% on this small case
