"""End-to-end behaviour tests: training convergence, data determinism,
sharding rules, and the serving loop."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro import configs
from repro.data.pipeline import SyntheticLMData, make_batch
from repro.launch.mesh import make_local_mesh
from repro.launch.serve import serve_batch
from repro.launch.train import train_loop
from repro.models.layers import split_lp_tree
from repro.models.model import build_model
from repro.sharding import MeshAxes, spec_for

MESH = make_local_mesh(1, 1)


def test_training_reduces_loss():
    cfg = configs.get_smoke_config("tinyllama-1.1b")
    _, _, losses = train_loop(cfg, MESH, steps=40, seq_len=64,
                              global_batch=4, lr=3e-3, log_every=100)
    first = np.mean(losses[:5])
    last = np.mean(losses[-5:])
    assert last < first - 0.2, (first, last)


def test_moe_training_reduces_loss_and_reports_stats():
    # config pinned by an lr/warmup/steps sweep: the default
    # make_train_step warmup (100 steps) never ramped the lr within a
    # 20-step run, leaving the loss flat.  With warmup_steps=3 the measured
    # first5-last5 drops were lr 3e-3/20 steps: 0.06, 3e-3/30: 0.13,
    # 1e-2/30: 0.28 — the last gives a deterministic ~3x margin over the
    # 0.1 threshold asserted below.
    cfg = configs.get_smoke_config("qwen3-moe-30b-a3b")
    from repro.launch.steps import make_train_step
    from repro.optim import adamw_init
    n_steps = 30
    model = build_model(cfg, MESH)
    params, _ = split_lp_tree(model.init(jax.random.key(0)))
    opt = adamw_init(params)
    step = jax.jit(make_train_step(model, lr=1e-2, warmup_steps=3,
                                   total_steps=n_steps))
    losses = []
    for i in range(n_steps):
        batch = make_batch(cfg, 64, 4, i)
        params, opt, m = step(params, opt, batch)
        losses.append(float(m["loss"]))
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.1, (
        np.mean(losses[:5]), np.mean(losses[-5:]))
    counts = np.asarray(m["expert_counts"])
    assert counts.shape[-1] == cfg.num_experts
    # every token routed top_k times
    assert counts.sum() == pytest.approx(2 * 4 * 64 * cfg.top_k, rel=1e-6)


def test_data_pipeline_deterministic():
    d1 = SyntheticLMData(1024, 64, 4, seed=3)
    d2 = SyntheticLMData(1024, 64, 4, seed=3)
    b1, b2 = d1.batch(17), d2.batch(17)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    b3 = d1.batch(18)
    assert not np.array_equal(b1["tokens"], b3["tokens"])
    # targets are next-token shifted
    np.testing.assert_array_equal(b1["tokens"][:, 1:], b1["targets"][:, :-1])


def test_sharding_rules_divisibility_and_dedupe():
    mesh = MESH  # 1x1 — sizes 1, everything divisible
    axes = MeshAxes.for_mesh(mesh)
    # square matrix mapping two dims to the same axis -> deduped
    spec = spec_for(mesh, axes, ("rnn", "rnn"), (64, 64))
    named = [s for s in spec if s is not None]
    assert len(named) <= 1
    # non-divisible dim replicated (simulate with a fake larger mesh need:
    # on a 1-sized axis everything divides; check rule table instead)
    spec2 = spec_for(mesh, axes, ("vocab", "embed"), (100, 64))
    assert len(spec2) == 2


def test_serve_batch_all_families():
    rng = np.random.default_rng(0)
    for arch in ("smollm-360m", "rwkv6-7b"):
        cfg = configs.get_smoke_config(arch)
        model = build_model(cfg, MESH)
        params, _ = split_lp_tree(model.init(jax.random.key(0)))
        prompts = rng.integers(0, cfg.vocab_size, (2, 16)).astype(np.int32)
        out = serve_batch(model, params, prompts, max_new=8)
        assert out.shape == (2, 8)
        assert (out >= 0).all() and (out < cfg.vocab_size).all()


def test_greedy_decode_is_deterministic():
    cfg = configs.get_smoke_config("tinyllama-1.1b")
    model = build_model(cfg, MESH)
    params, _ = split_lp_tree(model.init(jax.random.key(0)))
    prompts = np.ones((2, 12), np.int32)
    o1 = serve_batch(model, params, prompts, max_new=6)
    o2 = serve_batch(model, params, prompts, max_new=6)
    np.testing.assert_array_equal(o1, o2)
