"""Memory as a live, binding constraint (PR 10 OOM scenario suite).

The pressure policy (repro/core/problem.py ``mem_headroom`` + the eq. 9
barrier in the stage-1/stage-2 scoring) and the replication move
vocabulary (repro/core/transfer.py ``memory_move_candidates``) must:

  - resolve over-cap ranks by migration, de-replication (copy eviction)
    or replication splits, never by silently landing tasks over a cap;
  - refuse cleanly (zero transfers) when no feasible candidate exists;
  - keep zero-pressure configs bitwise-identical to the legacy drivers;
  - hold the memory-feasibility invariant through the transfer-log
    replay gate in every driver (sync / async / pipeline) and through
    crash recovery (spill-aware ``_recover_survivors``).
"""
import numpy as np
import pytest

from repro.core import CCMParams, CCMState, ccm_lb, random_phase
from repro.core.async_sim import (FaultSpec, RankJoin, RecoveryOOMError,
                                  ccm_lb_async)
from repro.core.ccm import MEM_REL_TOL, effective_mem_cap
from repro.core.pipeline import ccm_lb_pipeline
from repro.core.problem import Phase, initial_assignment


def _phase(task_load, task_mem, task_block, block_size, block_home,
           mem_cap, n_ranks, task_overhead=None, mem_base=None):
    k = len(task_load)
    return Phase(
        task_load=np.asarray(task_load, np.float64),
        task_mem=np.asarray(task_mem, np.float64),
        task_overhead=(np.zeros(k) if task_overhead is None
                       else np.asarray(task_overhead, np.float64)),
        task_block=np.asarray(task_block, np.int64),
        block_size=np.asarray(block_size, np.float64),
        block_home=np.asarray(block_home, np.int64),
        comm_src=np.zeros(0, np.int64),
        comm_dst=np.zeros(0, np.int64),
        comm_vol=np.zeros(0),
        rank_mem_base=(np.zeros(n_ranks) if mem_base is None
                       else np.asarray(mem_base, np.float64)),
        rank_mem_cap=(np.asarray(mem_cap, np.float64)
                      if np.ndim(mem_cap) else
                      np.full(n_ranks, float(mem_cap))),
    )


def _assert_replay_and_feasible(phase, a0, res, params):
    """The OOM-suite invariant gate: the transfer log replays onto the
    initial assignment to the final one, and the final state satisfies
    every rank's (headroom-scaled) memory cap."""
    replay = a0.copy()
    for tasks, r_from, r_to in res.transfer_log:
        idx = np.array(tasks, np.int64)
        assert (replay[idx] == r_from).all(), "replay diverged"
        replay[idx] = r_to
    np.testing.assert_array_equal(replay, res.assignment)
    final = CCMState.build(phase, res.assignment, params)
    for r in range(phase.num_ranks):
        assert final.memory_feasible(r), f"rank {r} over its memory cap"


# ------------------------------------------------ relative tolerance (sat 1)
@pytest.mark.parametrize("scale", [1e-3, 1.0, 1e18])
def test_feasibility_tolerance_is_relative(scale):
    """The soft cap scales WITH the cap: half-a-relative-ulp over stays
    feasible at every magnitude (the old absolute +1e-6 epsilon rejected
    that at 1e18 bytes), and 1e-4 relative over is infeasible at every
    magnitude (the old epsilon accepted it below ~1e-2 bytes)."""
    cap = scale
    assert effective_mem_cap(cap) == cap + MEM_REL_TOL * cap
    params = CCMParams(memory_constraint=True)

    within = _phase([1.0], [cap * (1.0 + 0.5 * MEM_REL_TOL)], [-1],
                    [], [], cap, 1)
    st = CCMState.build(within, np.zeros(1, np.int64), params)
    assert st.memory_feasible(0)

    over = _phase([1.0], [cap * (1.0 + 1e-4)], [-1], [], [], cap, 1)
    st = CCMState.build(over, np.zeros(1, np.int64), params)
    assert not st.memory_feasible(0)


def test_effective_mem_cap_elementwise_and_inf():
    caps = np.array([1.0, 1e18, np.inf])
    eff = effective_mem_cap(caps)
    assert eff[0] == 1.0 + MEM_REL_TOL
    assert eff[1] == 1e18 + MEM_REL_TOL * 1e18
    assert eff[2] == np.inf
    p = CCMParams(mem_headroom=0.25)
    assert effective_mem_cap(8.0, p) == 6.0 + MEM_REL_TOL * 6.0


# -------------------------------------------------- replication splits wins
def _hot_block_phase():
    """A block-bound instance: rank 0 carries a 4-task shared-block
    cluster (load 6.0 — exactly at the ``_split_by_load`` cap, so the
    baseline clustering keeps it ATOMIC) plus three heavy singletons and
    a light mover; rank 1 carries three heavy singletons.  Every
    replication-free move is a wash (moving the block whole or swapping
    heavies just trades 24 for 24), so the baseline is stuck; splitting
    the block across both ranks — a replication move — is the only way
    down."""
    return _phase(
        task_load=[1.5] * 4 + [6.0] * 6 + [0.5],
        task_mem=[1.0] * 11,
        task_block=[0] * 4 + [-1] * 7,
        block_size=[10.0],
        block_home=[0],
        mem_cap=1e6, n_ranks=2)


def _hot_block_a0():
    return np.array([0] * 4 + [0] * 3 + [1] * 3 + [0], np.int64)


def test_replication_split_beats_replication_free():
    ph = _hot_block_phase()
    params = CCMParams(alpha=1.0, beta=0.0, gamma=0.0, delta=0.0)
    a0 = _hot_block_a0()
    base = ccm_lb(ph, a0, params, n_iter=4, seed=0)
    rep = ccm_lb(ph, a0, params, n_iter=4, seed=0, replicate=True)
    # replication-free is stuck at the atomic-cluster bound (24 = the
    # block riding whole with three heavies on one rank)
    assert base.max_work[-1] >= 24.0 - 1e-9
    assert rep.max_work[-1] <= base.max_work[-1] - 2.0
    # the hot block is genuinely materialized on both ranks
    assert int((rep.state.block_count[:, 0] > 0).sum()) == 2
    _assert_replay_and_feasible(ph, a0, rep, params)
    _assert_replay_and_feasible(ph, a0, base, params)


def test_replicate_noop_is_bitwise_identical():
    """No block has two tasks on one rank -> no replication candidates ->
    replicate=True must reproduce replicate=False bit for bit."""
    phase = random_phase(3, num_ranks=6, num_tasks=60, num_blocks=0,
                         num_comms=120, mem_cap=1e12)
    params = CCMParams(delta=1e-9)
    a0 = initial_assignment(phase, "home")
    ref = ccm_lb(phase, a0, params, n_iter=3, seed=1)
    got = ccm_lb(phase, a0, params, n_iter=3, seed=1, replicate=True)
    np.testing.assert_array_equal(got.assignment, ref.assignment)
    assert got.transfers == ref.transfers
    assert got.max_work == ref.max_work
    assert got.transfer_log == ref.transfer_log


def test_replicate_rejects_batched_and_spec_drivers():
    ph = _hot_block_phase()
    a0 = _hot_block_a0()
    params = CCMParams()
    with pytest.raises(ValueError, match="batch_lock_events"):
        ccm_lb(ph, a0, params, replicate=True, batch_lock_events=8)
    with pytest.raises(ValueError, match="spec_window"):
        ccm_lb(ph, a0, params, replicate=True, spec_window=4)


# ------------------------------------------------- eviction under pressure
def test_dereplication_relieves_overloaded_rank():
    """Rank 0 holds copies of blocks 0 and 1 and sits over its cap; block
    1 also lives on rank 1.  The pressure barrier (work = inf) drives an
    eviction: rank 0's block-1 tasks consolidate onto rank 1, the copy is
    dropped, and rank 0 comes back under its cap."""
    ph = _phase(task_load=[1.0, 1.0, 1.0, 1.0],
                task_mem=[0.5, 0.5, 0.5, 0.5],
                task_block=[0, 0, 1, 1],
                block_size=[4.0, 4.0],
                block_home=[0, 1],
                mem_cap=[8.0, 20.0], n_ranks=2)
    # tasks 0-2 on rank 0 (blocks 0 and 1 resident: 1.5 + 8 = 9.5 > 8),
    # task 3 on rank 1 (block 1 resident there too).  Cap 8 makes the
    # block-1 eviction (1.0 + 4 = 5.0) the ONLY feasibility-restoring
    # move: shedding a single block-0 task leaves 1.0 + 8 = 9.0 > 8.
    a0 = np.array([0, 0, 0, 1], np.int64)
    params = CCMParams(alpha=1e-3, beta=0.0, gamma=0.0, delta=0.0)
    st0 = CCMState.build(ph, a0, params)
    assert not st0.memory_feasible(0)

    res = ccm_lb(ph, a0, params, n_iter=4, seed=0, replicate=True)
    assert res.state.block_count[0, 1] == 0     # copy evicted
    _assert_replay_and_feasible(ph, a0, res, params)


def test_refusal_when_no_feasible_candidate():
    """Every rank over cap and no move can help: the balancer must refuse
    (zero transfers), not thrash or land tasks over a cap."""
    ph = _phase(task_load=[1.0, 1.0], task_mem=[5.0, 5.0],
                task_block=[-1, -1], block_size=[], block_home=[],
                mem_cap=2.0, n_ranks=2)
    a0 = np.array([0, 1], np.int64)
    params = CCMParams(alpha=1.0, beta=0.0, gamma=0.0, delta=0.0)
    res = ccm_lb(ph, a0, params, n_iter=3, seed=0, replicate=True)
    assert res.transfers == 0
    np.testing.assert_array_equal(res.assignment, a0)
    # still infeasible — reported, not hidden
    assert not res.state.memory_feasible(0)


# ---------------------------------------------------------- headroom policy
def test_mem_headroom_forces_spread():
    """Within the hard cap but inside the headroom band: the pressure
    policy must migrate until every rank clears cap*(1-headroom)."""
    ph = _phase(task_load=[0.0, 0.0], task_mem=[0.4, 0.4],
                task_block=[-1, -1], block_size=[], block_home=[],
                mem_cap=1.0, n_ranks=2)
    a0 = np.zeros(2, np.int64)
    soft = CCMParams(alpha=1.0, beta=0.0, gamma=0.0, delta=0.0,
                     mem_headroom=0.3)
    st0 = CCMState.build(ph, a0, soft)
    assert not st0.memory_feasible(0)           # 0.8 > 0.7 soft cap
    res = ccm_lb(ph, a0, soft, n_iter=3, seed=0)
    assert res.transfers >= 1
    _assert_replay_and_feasible(ph, a0, res, soft)

    # headroom off: same config is feasible and must not move at all
    hard = CCMParams(alpha=1.0, beta=0.0, gamma=0.0, delta=0.0)
    quiet = ccm_lb(ph, a0, hard, n_iter=3, seed=0)
    assert quiet.transfers == 0
    np.testing.assert_array_equal(quiet.assignment, a0)


# -------------------------------------------------------- async + pipeline
def test_async_replicate_matches_sync_at_zero_latency():
    ph = _hot_block_phase()
    params = CCMParams(alpha=1.0, beta=0.0, gamma=0.0, delta=0.0)
    a0 = _hot_block_a0()
    ref = ccm_lb(ph, a0, params, n_iter=4, seed=0, replicate=True)
    got = ccm_lb_async(ph, a0, params, n_iter=4, seed=0, replicate=True)
    np.testing.assert_array_equal(got.assignment, ref.assignment)
    assert got.transfer_log == ref.transfer_log
    assert got.max_work == ref.max_work
    _assert_replay_and_feasible(ph, a0, got, params)


def test_pipeline_threads_replicate_through_lb_kwargs():
    ph = _hot_block_phase()
    params = CCMParams(alpha=1.0, beta=0.0, gamma=0.0, delta=0.0)
    a0 = _hot_block_a0()
    pipe = ccm_lb_pipeline([ph, ph], params, a0=a0, seed=0, n_iter=4,
                           replicate=True)
    for run in pipe.runs:
        assert run.result.max_work[-1] <= 22.0
        final = CCMState.build(ph, run.result.assignment, params)
        for r in range(ph.num_ranks):
            assert final.memory_feasible(r)


# --------------------------------------------------- elastic shrink / join
def test_recovery_spills_to_feasible_survivor():
    """Rank 2 dies; rank 0 has no memory room, rank 1 plenty.  Stranded
    groups warm-started onto rank 0 must spill to rank 1 (counted), and
    the final state must satisfy every cap."""
    ph = _phase(task_load=[0.1, 1.0, 1.0, 1.0, 1.0],
                task_mem=[0.05, 1.0, 1.0, 1.0, 1.0],
                task_block=[-1] * 5, block_size=[], block_home=[],
                mem_cap=[0.1, 100.0, 100.0], n_ranks=3)
    a0 = np.array([0, 2, 2, 2, 2], np.int64)
    params = CCMParams(alpha=1.0, beta=0.0, gamma=0.0, delta=0.0)
    res = ccm_lb_async(ph, a0, params, n_iter=3, seed=0,
                       fault=FaultSpec(kill=((2, 0, 0.5),), seed=7))
    assert res.dead_ranks == [2]
    # the kill lands mid-iteration, so stage 2 may legitimately drain
    # some of rank 2's tasks before death — only the remainder strands
    assert res.fault_stats.recovered_tasks >= 1
    assert res.fault_stats.recovery_spills >= 1
    assert not (res.assignment == 2).any()
    final = CCMState.build(ph, res.assignment, params)
    for r in (0, 1):
        assert final.memory_feasible(r)
    _assert_replay_and_feasible(ph, a0, res, params)


def test_recovery_raises_structured_oom_when_no_survivor_fits():
    ph = _phase(task_load=[0.1, 1.0, 1.0],
                task_mem=[0.05, 5.0, 5.0],
                task_block=[-1] * 3, block_size=[], block_home=[],
                mem_cap=[1.0, 100.0], n_ranks=2)
    a0 = np.array([0, 1, 1], np.int64)
    params = CCMParams(alpha=1.0, beta=0.0, gamma=0.0, delta=0.0)
    with pytest.raises(RecoveryOOMError) as ei:
        ccm_lb_async(ph, a0, params, n_iter=3, seed=0,
                     fault=FaultSpec(kill=((1, 0, 0.5),), seed=7))
    assert ei.value.dead_rank == 1
    assert ei.value.overflow_bytes > 0
    assert len(ei.value.tasks) >= 1


def test_recovery_without_pressure_is_unchanged():
    """All survivors feasible -> the spill path must not fire and the
    migration sequence equals the unchecked warm start."""
    phase = random_phase(5, num_ranks=6, num_tasks=48, num_blocks=6,
                         num_comms=90, mem_cap=1e12)
    params = CCMParams(delta=1e-9)
    a0 = initial_assignment(phase, "home")
    kw = dict(n_iter=3, seed=0, fault=FaultSpec(kill=((4, 1, 0.5),),
                                                seed=3))
    res = ccm_lb_async(phase, a0, params, **kw)
    off = ccm_lb_async(phase, a0,
                       CCMParams(delta=1e-9, memory_constraint=False),
                       **kw)
    assert res.fault_stats.recovery_spills == 0
    assert res.recovery_log == off.recovery_log
    np.testing.assert_array_equal(res.assignment, off.assignment)


def test_join_relieves_memory_pressure():
    """Both initial ranks sit over the soft cap with nowhere to go; a
    mid-stream join brings capacity and the barrier drains tasks onto
    the fresh rank until everyone fits."""
    ph = _phase(task_load=[0.0] * 4, task_mem=[0.4] * 4,
                task_block=[-1] * 4, block_size=[], block_home=[],
                mem_cap=1.0, n_ranks=2)
    a0 = np.array([0, 0, 1, 1], np.int64)
    params = CCMParams(alpha=1.0, beta=0.0, gamma=0.0, delta=0.0,
                       mem_headroom=0.3)
    st0 = CCMState.build(ph, a0, params)
    assert not st0.memory_feasible(0) and not st0.memory_feasible(1)

    res = ccm_lb_async(ph, a0, params, n_iter=4, seed=0,
                       membership=(RankJoin(1, 1, mem_cap=10.0),))
    assert res.joined_ranks == [2]
    assert (res.assignment == 2).any()
    final = CCMState.build(res.state.phase, res.assignment, params)
    for r in range(3):
        assert final.memory_feasible(r)


# -------------------------------------------------- expert serving plans
def test_expert_placement_replication_becomes_real():
    from repro import configs
    from repro.balance import plan_expert_placement

    cfg = configs.get_config("qwen3-moe-30b-a3b")
    counts = np.full((2, 4), 50.0)
    counts[:, 0] = 2000.0                       # one hot expert per layer
    plan = plan_expert_placement(counts, cfg, 2, hbm_budget_bytes=1e12,
                                 shards_per_expert=4, replicate=True,
                                 quiesce_after=2)
    sp = plan.serving
    assert plan.replicated_blocks >= 1
    assert sp.within_budget()
    assert len(sp.replicated_experts) == plan.replicated_blocks
    # routing shares: one row per (layer, expert), massed on the replicas
    routed = sp.routing_shares.sum(axis=2)
    np.testing.assert_allclose(routed, 1.0)
    assert ((sp.routing_shares > 0) <= sp.replicas).all()
    # the hot expert's copies actually split its traffic
    l, e = sp.replicated_experts[0]
    assert (sp.routing_shares[l, e] > 0).sum() > 1


def test_expert_placement_unsharded_serving_is_single_copy():
    from repro import configs
    from repro.balance import plan_expert_placement

    cfg = configs.get_config("qwen3-moe-30b-a3b")
    rng = np.random.default_rng(0)
    counts = rng.uniform(10.0, 100.0, size=(2, 4))
    plan = plan_expert_placement(counts, cfg, 2, hbm_budget_bytes=1e12)
    sp = plan.serving
    assert plan.replicated_blocks == 0
    assert (sp.replicas.sum(axis=2) == 1).all()
    np.testing.assert_allclose(sp.routing_shares.sum(axis=2), 1.0)
