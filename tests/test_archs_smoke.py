"""Deliverable (f): per-architecture smoke tests — reduced config of the same
family, one forward/train step on CPU, asserting shapes + no NaNs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.launch.mesh import make_local_mesh
from repro.launch.steps import make_train_step
from repro.models.layers import split_lp_tree
from repro.models.model import build_model
from repro.optim import adamw_init

MESH = make_local_mesh(1, 1)


def _batch(cfg, b=2, s=32):
    rng = np.random.default_rng(0)
    if cfg.arch_type == "encdec":
        return {
            "audio_embed": jnp.asarray(
                rng.standard_normal((b, s, cfg.d_model)) * 0.1, jnp.bfloat16),
            "tokens": jnp.zeros((b, 8), jnp.int32),
            "targets": jnp.ones((b, 8), jnp.int32),
        }
    if cfg.frontend == "vision":
        return {
            "media_embed": jnp.asarray(
                rng.standard_normal((b, cfg.num_media_positions, cfg.d_model))
                * 0.1, jnp.bfloat16),
            "tokens": jnp.zeros((b, s), jnp.int32),
            "targets": jnp.ones((b, s), jnp.int32),
        }
    return {"tokens": jnp.zeros((b, s), jnp.int32),
            "targets": jnp.ones((b, s), jnp.int32)}


@pytest.mark.parametrize("arch", configs.ARCH_IDS)
def test_arch_smoke_forward_and_train_step(arch):
    cfg = configs.get_smoke_config(arch)
    model = build_model(cfg, MESH)
    params, _ = split_lp_tree(model.init(jax.random.key(0)))
    batch = _batch(cfg)
    loss, metrics = jax.jit(model.loss_fn)(params, batch)
    assert loss.shape == ()
    assert np.isfinite(float(loss)), arch
    # one full train step (grads + AdamW) — params move, no NaNs
    opt = adamw_init(params)
    step = jax.jit(make_train_step(model))
    p2, o2, m = step(params, opt, batch)
    assert np.isfinite(float(m["loss"]))
    moved = jax.tree.map(
        lambda a, b: float(jnp.abs(a.astype(jnp.float32)
                                   - b.astype(jnp.float32)).max()),
        params, p2)
    assert max(jax.tree.leaves(moved)) > 0.0
    for leaf in jax.tree.leaves(p2):
        assert np.isfinite(np.asarray(leaf, np.float32)).all(), arch


@pytest.mark.parametrize("arch", configs.ARCH_IDS)
def test_full_config_matches_assignment(arch):
    """The FULL configs carry the exact assigned hyperparameters."""
    cfg = configs.get_config(arch)
    expected = {
        "whisper-large-v3": (32, 1280, 20, 20, 5120, 51866),
        "llama3.2-3b": (28, 3072, 24, 8, 8192, 128256),
        "gemma2-27b": (46, 4608, 32, 16, 36864, 256000),
        "smollm-360m": (32, 960, 15, 5, 2560, 49152),
        "tinyllama-1.1b": (22, 2048, 32, 4, 5632, 32000),
        "llava-next-mistral-7b": (32, 4096, 32, 8, 14336, 32000),
        "qwen3-moe-30b-a3b": (48, 2048, 32, 4, None, 151936),
        "llama4-scout-17b-a16e": (48, 5120, 40, 8, 8192, 202048),
        "rwkv6-7b": (32, 4096, None, None, 14336, 65536),
        "recurrentgemma-9b": (38, 4096, 16, 1, 12288, 256000),
    }[arch]
    layers, d, h, kv, ff, vocab = expected
    assert cfg.num_layers == layers and cfg.d_model == d
    assert cfg.vocab_size == vocab
    if h is not None:
        assert cfg.num_heads == h and cfg.num_kv_heads == kv
    if ff is not None:
        assert cfg.d_ff == ff
    if arch == "qwen3-moe-30b-a3b":
        assert cfg.num_experts == 128 and cfg.top_k == 8 and cfg.moe_d_ff == 768
    if arch == "llama4-scout-17b-a16e":
        assert cfg.num_experts == 16 and cfg.top_k == 1


def test_shape_cells_cover_assignment():
    cells = list(configs.cells())
    # 10 archs x 4 shapes - 7 long_500k skips (DESIGN.md) = 33
    assert len(cells) == 33
    long_runners = {a for a, s in cells if s == "long_500k"}
    assert long_runners == {"gemma2-27b", "rwkv6-7b", "recurrentgemma-9b"}
