"""§Perf optimization knobs must be numerics-preserving: chunked CE, chunked
(flash-style) XLA attention, windowed ring KV cache, remat policies."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.launch.mesh import make_local_mesh
from repro.models.layers import split_lp_tree
from repro.models.model import build_model

MESH = make_local_mesh(1, 1)


def _loss(cfg, params, batch):
    model = build_model(cfg, MESH)
    loss, _ = jax.jit(model.loss_fn)(params, batch)
    return float(loss)


def test_chunked_ce_matches_full():
    cfg = configs.get_smoke_config("tinyllama-1.1b")
    model = build_model(cfg, MESH)
    params, _ = split_lp_tree(model.init(jax.random.key(0)))
    rng = np.random.default_rng(0)
    batch = {"tokens": rng.integers(0, cfg.vocab_size, (2, 64)).astype(np.int32),
             "targets": rng.integers(0, cfg.vocab_size, (2, 64)).astype(np.int32)}
    full = _loss(cfg, params, batch)
    chunked = _loss(dataclasses.replace(cfg, ce_chunk=16), params, batch)
    assert chunked == pytest.approx(full, rel=1e-5)


@pytest.mark.parametrize("arch", ["tinyllama-1.1b", "gemma2-27b"])
def test_chunked_attention_matches_full(arch):
    cfg = configs.get_smoke_config(arch)
    model = build_model(cfg, MESH)
    params, _ = split_lp_tree(model.init(jax.random.key(0)))
    rng = np.random.default_rng(1)
    batch = {"tokens": rng.integers(0, cfg.vocab_size, (2, 64)).astype(np.int32),
             "targets": rng.integers(0, cfg.vocab_size, (2, 64)).astype(np.int32)}
    full = _loss(cfg, params, batch)
    chunked = _loss(dataclasses.replace(cfg, attn_kv_chunk=16), params, batch)
    assert chunked == pytest.approx(full, rel=2e-3)


def test_remat_policies_match():
    cfg = configs.get_smoke_config("tinyllama-1.1b")
    model = build_model(cfg, MESH)
    params, _ = split_lp_tree(model.init(jax.random.key(0)))
    rng = np.random.default_rng(2)
    batch = {"tokens": rng.integers(0, cfg.vocab_size, (2, 32)).astype(np.int32),
             "targets": rng.integers(0, cfg.vocab_size, (2, 32)).astype(np.int32)}
    base = _loss(cfg, params, batch)
    for pol in ("dots", "none"):
        v = _loss(dataclasses.replace(cfg, remat_policy=pol), params, batch)
        assert v == pytest.approx(base, rel=1e-5), pol


def test_window_ring_cache_matches_full_cache():
    """gemma2-style local layers: ring cache decode == full-cache decode."""
    cfg = configs.get_smoke_config("gemma2-27b")   # window_size 16
    model_full = build_model(cfg, MESH)
    params, _ = split_lp_tree(model_full.init(jax.random.key(0)))
    cfg_ring = dataclasses.replace(cfg, window_kv_cache=True)
    model_ring = build_model(cfg_ring, MESH)

    rng = np.random.default_rng(3)
    prompt, extra = 20, 12                         # crosses the window=16 edge
    tokens = rng.integers(0, cfg.vocab_size, (2, prompt + extra)).astype(np.int32)

    from repro.launch.serve import pad_caches
    caches, logits_f = jax.jit(model_full.prefill_fn)(
        params, {"tokens": jnp.asarray(tokens[:, :prompt])})
    caches = pad_caches(caches, prompt + extra)

    # build the ring cache from the full prefill caches: slot = p % window
    w = cfg.window_size
    def to_ring(path, leaf):
        key = path[-1].key if hasattr(path[-1], "key") else None
        if key not in ("k", "v"):
            return leaf
        return leaf  # converted per-entry below
    ring_caches = jax.tree_util.tree_map_with_path(to_ring, caches)
    # manual conversion for local layers (b0 of each period is local in the
    # (local, full) gemma2 pattern)
    import jax.tree_util as jtu
    ring = jax.tree.map(lambda x: x, caches)
    for name, entry in ring["scan"].items():
        kind = cfg.block_pattern[int(name[1:])]
        if kind != "local_attn":
            continue
        for kk in ("k", "v"):
            full = entry[kk]                        # (P, B, S, hkv, hd)
            ringbuf = jnp.zeros(full.shape[:2] + (w,) + full.shape[3:],
                                full.dtype)
            for p in range(max(0, prompt - w), prompt):
                ringbuf = ringbuf.at[:, :, p % w].set(full[:, :, p])
            entry[kk] = ringbuf

    dec_f = jax.jit(model_full.decode_fn)
    dec_r = jax.jit(model_ring.decode_fn)
    cf, cr = caches, ring
    for i in range(extra):
        tok = jnp.asarray(tokens[:, prompt + i: prompt + i + 1])
        cf, lf = dec_f(params, cf, tok, jnp.int32(prompt + i))
        cr, lr = dec_r(params, cr, tok, jnp.int32(prompt + i))
        a = np.asarray(lf, np.float32)
        b = np.asarray(lr, np.float32)
        np.testing.assert_allclose(a, b, atol=0.05, rtol=0.05)
        np.testing.assert_array_equal(a.argmax(-1), b.argmax(-1))
