import os
import sys
from pathlib import Path

# smoke tests and benches must see 1 device — the 512-device override lives
# ONLY in launch/dryrun.py (run as a subprocess in test_dryrun).
sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import jax  # noqa: E402

jax.config.update("jax_enable_x64", False)

# hypothesis is a dev-only dependency (requirements-dev.txt); property tests
# importorskip it themselves — without the guard a missing install would kill
# the whole suite at collection time.
try:
    from hypothesis import HealthCheck, settings  # noqa: E402
except ModuleNotFoundError:
    pass
else:
    # deterministic property tests (CI reproducibility)
    settings.register_profile(
        "ci", derandomize=True, deadline=None,
        suppress_health_check=[HealthCheck.too_slow])
    settings.load_profile("ci")
